//! CNN case study (paper §4.3.2, Table 5): run the build-time-trained CNN
//! on its frozen test set with one conv layer's im2col GEMM substituted by
//! SpAMM, sweeping τ and reporting prediction-accuracy delta — the paper's
//! "acc loss" column.
//!
//!   cargo run --release --example cnn_inference -- [layer] [limit]

use std::collections::BTreeMap;

use cuspamm::cnn::{Cnn, GemmMode};
use cuspamm::prelude::*;

fn main() -> Result<()> {
    cuspamm::telemetry::init_logging();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let layer = args.first().cloned().unwrap_or_else(|| "conv2".to_string());
    let limit: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);

    let bundle = ArtifactBundle::load("artifacts")?;
    let meta = bundle
        .cnn
        .clone()
        .expect("bundle lacks CNN export — re-run `make artifacts`");
    let cnn = Cnn::load(&meta)?;
    let engine = SpammEngine::new(&bundle, SpammConfig::default())?;

    println!(
        "== CNN case study: layer {layer}, {limit} test images (build-time accuracy {:.2}%) ==",
        meta.test_accuracy * 100.0
    );

    let mut modes: BTreeMap<String, GemmMode> = BTreeMap::new();
    let baseline = cnn.accuracy(&modes, Some(&engine), 100, Some(limit))?;
    println!("exact inference accuracy: {:.2}%", baseline * 100.0);

    // Sweep τ like Table 5 sweeps per-layer thresholds.
    println!("\n      τ      accuracy    acc loss");
    for tau in [0.0f32, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0] {
        modes.insert(layer.clone(), GemmMode::Spamm { tau });
        let acc = cnn.accuracy(&modes, Some(&engine), 100, Some(limit))?;
        println!(
            "  {tau:8.2}    {:6.2}%    {:+.2}%",
            acc * 100.0,
            (acc - baseline) * 100.0
        );
    }
    println!("\n(Table 5's shape: accuracy is insensitive until τ gets large — \
              CNNs tolerate GEMM approximation)");
    Ok(())
}
