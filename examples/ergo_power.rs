//! End-to-end driver: the ergo case study (paper §4.3.1, Table 4 + Fig 6),
//! served through a `SpammSession` — each ergo matrix is registered
//! *once* and its power (C = A·A, what the paper's case study computes)
//! is requested repeatedly across the τ sweep, the serving pattern the
//! session amortizes (one normmap, one fingerprint, resident tiles).
//!
//!   cargo run --release --example ergo_power -- [devices] [n]
//!
//! Reports the paper's headline metrics: speedup over the dense baseline
//! (modeled as max per-device busy, DESIGN.md §2) and ‖E‖_F at every τ.
//! This run is recorded in EXPERIMENTS.md §End-to-end.

use cuspamm::config::SpammConfig;
use cuspamm::coordinator::Coordinator;
use cuspamm::matrix::ergo::{ergo_matrix, ERGO_SPECS};
use cuspamm::prelude::*;

fn main() -> Result<()> {
    cuspamm::telemetry::init_logging();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let devices: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1024);

    let bundle = ArtifactBundle::load("artifacts")?;
    let mut cfg = SpammConfig::default();
    cfg.lonum = 128; // MXU-native tile — best tile-GEMM throughput
    cfg.devices = devices;
    // Sequential-device mode: per-device busy clocks are contention-free,
    // so max(busy) models the wall-clock of truly independent devices
    // (this host's simulated devices share physical cores; DESIGN.md §2).
    cfg.sequential_devices = true;
    let session = SpammSession::new(&bundle, cfg.clone())?;
    // Dense baseline runs outside the session (cuBLAS stand-in).
    let coord = Coordinator::new(&bundle, cfg)?;

    println!("== ergo case study: matrix powers on {devices} device(s), N = {n} ==");
    let taus: [f32; 5] = [1e-10, 1e-8, 1e-6, 1e-4, 1e-2];

    for (no, target_norm, _) in ERGO_SPECS {
        let a = ergo_matrix(no, n, 42);
        // Register once; every τ below shares this operand's fingerprint,
        // normmap, and resident tiles.
        let aid = session.put(&a)?;
        // Dense baseline (the paper normalizes speedup to cuBLAS) and the
        // Eq. 5 reference (τ=0 on the same tile path, so ‖E‖ measures the
        // approximation, not float-summation noise).
        let dense = coord.dense(&a, &a)?;
        let mut plans = Vec::new();
        let exact_plan = session.prepare(aid, aid, Approx::Tau(0.0))?;
        plans.push(exact_plan);
        let exact = session.wait(session.submit(exact_plan)?)?;
        println!(
            "\nmatrix no.{no}  ‖A‖_F = {:.3e} (paper: {target_norm:.3e})  \
             dense {:.3}s  ‖C‖_F = {:.4e}",
            a.fnorm(),
            dense.wall_secs,
            exact.c.fnorm()
        );
        println!("      τ      valid%   wall(s)  speedup(modeled)  ‖E‖_F      ‖E‖/‖C‖");
        for tau in taus {
            let plan = session.prepare(aid, aid, Approx::Tau(tau))?;
            plans.push(plan);
            session.wait(session.submit(plan)?)?; // cold: upload + compile
            let rep = session.wait(session.submit(plan)?)?; // warm request
            let err = rep.c.error_fnorm(&exact.c)?;
            let modeled = rep
                .device_busy
                .iter()
                .cloned()
                .fold(0.0f64, f64::max)
                .max(1e-12);
            println!(
                "  {tau:9.0e}  {:6.2}  {:8.3}  {:10.2}  {:.3e}  {:.2e}",
                rep.valid_ratio * 100.0,
                rep.compute_secs,
                dense.wall_secs / modeled,
                err,
                err / dense.c.fnorm().max(1e-30)
            );
        }
        // The chain is done: release the plans (unpinning the operand in
        // the store and the device pools) and then the operand itself, so
        // the session can actually reclaim the memory.
        for plan in plans {
            session.release_plan(plan)?;
        }
        session.release(aid)?;
    }
    let store = session.store_stats();
    println!(
        "\nstore: {} puts ({} dedup hits); norm cache {} hit / {} miss",
        store.puts,
        store.dedup_hits,
        session.caches().norms.hits(),
        session.caches().norms.misses()
    );
    println!("(headline: speedup grows as τ rises while ‖E‖_F/‖C‖_F stays ≪ 1 — Table 4/Fig 6's shape)");
    Ok(())
}
