//! Quickstart: the minimal cuspamm workflow.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! Generates an algebraic-decay matrix pair (the paper's synthesized
//! dataset), tunes τ for a 10% valid ratio, runs SpAMM, and compares time
//! and error against the dense XLA baseline (the cuBLAS stand-in).

use cuspamm::prelude::*;

fn main() -> Result<()> {
    cuspamm::telemetry::init_logging();
    let bundle = ArtifactBundle::load("artifacts")?;
    let mut cfg = SpammConfig::default();
    cfg.lonum = 128; // MXU-native tile; best tile-GEMM throughput on this runtime
    let engine = SpammEngine::new(&bundle, cfg.clone())?;

    let n = 1024;
    println!("== cuspamm quickstart (N = {n}, LoNum = {}) ==", cfg.lonum);
    let a = Matrix::decay_algebraic(n, 0.1, 0.1, 7);
    let b = Matrix::decay_algebraic(n, 0.1, 0.1, 8);

    // 1. Tune τ for a target valid ratio (§3.5.2).
    let tuned = engine.tune_tau(&a, &b, 0.10)?;
    println!(
        "tuned τ = {:.5e} → valid ratio {:.2}% in {} iterations",
        tuned.tau,
        tuned.achieved_ratio * 100.0,
        tuned.iters
    );

    // 2. SpAMM multiply (skips ~90% of tile products).
    engine.multiply(&a, &b, tuned.tau)?; // warm (compile executables)
    let (c, stats) = engine.multiply_with_stats(&a, &b, tuned.tau)?;
    println!(
        "spamm:  {:.3}s  ({} of {} tile products executed, {} batches)",
        stats.total_secs, stats.valid_products, stats.total_products, stats.batches
    );
    println!(
        "        norm {:.1}ms | schedule {:.1}ms | gather {:.1}ms | exec {:.1}ms | scatter {:.1}ms",
        stats.norm_secs * 1e3,
        stats.schedule_secs * 1e3,
        stats.gather_secs * 1e3,
        stats.exec_secs * 1e3,
        stats.scatter_secs * 1e3
    );

    // 3. Dense baseline on the same runtime (warm, then timed).
    engine.dense(&a, &b)?;
    let t = std::time::Instant::now();
    let dense = engine.dense(&a, &b)?;
    let dense_secs = t.elapsed().as_secs_f64();
    println!("dense:  {dense_secs:.3}s");

    // 4. Accuracy report (the paper's Eq. 5 criterion).
    let err = c.error_fnorm(&dense)?;
    println!(
        "speedup {:.2}x   ‖E‖_F = {:.4e}   ‖E‖_F/‖C‖_F = {:.2e}",
        dense_secs / stats.total_secs,
        err,
        err / dense.fnorm()
    );
    Ok(())
}
