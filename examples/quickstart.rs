//! Quickstart: the cuspamm serving lifecycle — put → prepare → submit →
//! wait.
//!
//!   make artifacts && cargo run --release --example quickstart
//!
//! Registers an algebraic-decay matrix pair (the paper's synthesized
//! dataset) in a `SpammSession`, prepares a plan tuned for a 10% valid
//! ratio, executes it repeatedly to show the cold-vs-warm contrast the
//! session exists for, and compares time and error against the dense
//! XLA baseline (the cuBLAS stand-in).

use cuspamm::prelude::*;

fn main() -> Result<()> {
    cuspamm::telemetry::init_logging();
    let bundle = ArtifactBundle::load("artifacts")?;
    let mut cfg = SpammConfig::default();
    cfg.lonum = 128; // MXU-native tile; best tile-GEMM throughput on this runtime
    let session = SpammSession::new(&bundle, cfg.clone())?;

    let n = 1024;
    println!("== cuspamm quickstart (N = {n}, LoNum = {}) ==", cfg.lonum);

    // 1. Register the operands once (content-deduplicated, refcounted).
    let a = session.put(&Matrix::decay_algebraic(n, 0.1, 0.1, 7))?;
    let b = session.put(&Matrix::decay_algebraic(n, 0.1, 0.1, 8))?;

    // 2. Prepare once: τ tuned for a 10% valid ratio (§3.5.2), schedule
    //    compacted and pinned, operand tiles pinned in the device pool.
    let plan = session.prepare(a, b, Approx::ValidRatio(0.10))?;
    let (tau, rows, cols) = session.plan_info(plan)?;
    println!("prepared plan: τ = {tau:.5e}, output {rows}x{cols}");

    // 3. Execute asynchronously.  The first request is cold (it is
    //    charged the prepare phases, the operand upload, and the
    //    executable compile); the rest ride the caches, the resident
    //    runtime, and the device tile pool.
    let tickets: Vec<Ticket> = (0..4)
        .map(|_| session.submit(plan))
        .collect::<Result<_>>()?;
    let mut last = None;
    for t in tickets {
        let done = session.wait(t)?;
        println!(
            "req {:2}: {:.3}s  ({} of {} products, {} batches; norm {:.1}ms | \
             schedule {:.1}ms | gather {:.1}ms | exec {:.1}ms | {} KiB uploaded)",
            done.ticket.raw(),
            done.compute_secs,
            done.stats.valid_products,
            done.stats.total_products,
            done.stats.batches,
            done.stats.norm_secs * 1e3,
            done.stats.schedule_secs * 1e3,
            done.stats.gather_secs * 1e3,
            done.stats.exec_secs * 1e3,
            done.stats.transfer_bytes / 1024,
        );
        last = Some(done);
    }
    let warm = last.expect("four completions");

    // 4. Dense baseline on the same runtime (warm, then timed) and the
    //    paper's Eq. 5 accuracy criterion.
    let engine = SpammEngine::new(&bundle, cfg)?;
    let ma = Matrix::decay_algebraic(n, 0.1, 0.1, 7);
    let mb = Matrix::decay_algebraic(n, 0.1, 0.1, 8);
    engine.dense(&ma, &mb)?;
    let t = std::time::Instant::now();
    let dense = engine.dense(&ma, &mb)?;
    let dense_secs = t.elapsed().as_secs_f64();
    println!("dense:  {dense_secs:.3}s");

    let err = warm.c.error_fnorm(&dense)?;
    println!(
        "speedup {:.2}x (warm request)   ‖E‖_F = {:.4e}   ‖E‖_F/‖C‖_F = {:.2e}",
        dense_secs / warm.compute_secs,
        err,
        err / dense.fnorm()
    );
    Ok(())
}
