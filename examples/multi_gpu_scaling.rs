//! Multi-device scaling demo (paper §3.4 / Fig 5): the same SpAMM problem
//! across 1/2/4/8 simulated devices, reporting wall-clock, per-device busy
//! time, parallel efficiency, and the §3.5.1 load-balance comparison
//! (row-block vs strided assignment).
//!
//!   cargo run --release --example multi_gpu_scaling -- [n] [ratio]

use cuspamm::config::{Balance, SpammConfig};
use cuspamm::coordinator::Coordinator;
use cuspamm::prelude::*;

fn main() -> Result<()> {
    cuspamm::telemetry::init_logging();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(1024);
    let ratio: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.10);

    let bundle = ArtifactBundle::load("artifacts")?;
    let a = Matrix::decay_exponential(n, 1.0, 0.55, 3);
    let b = Matrix::decay_exponential(n, 1.0, 0.55, 4);

    println!("== multi-device scaling: N = {n}, target valid ratio {:.0}% ==", ratio * 100.0);
    let mut t1 = None;
    for devices in [1usize, 2, 4, 8] {
        for balance in [Balance::RowBlock, Balance::Strided(4)] {
            let mut cfg = SpammConfig::default();
            cfg.devices = devices;
            cfg.balance = balance;
            let coord = Coordinator::new(&bundle, cfg)?;
            let tuned = coord.tune_tau(&a, &b, ratio)?;
            coord.multiply(&a, &b, tuned.tau)?; // warm
            let rep = coord.multiply(&a, &b, tuned.tau)?;
            if devices == 1 && balance == Balance::RowBlock {
                t1 = Some(rep.wall_secs);
            }
            let scaling = t1.map(|t| t / rep.wall_secs).unwrap_or(1.0);
            println!(
                "{devices} dev {:9}  wall {:7.3}s  scaling {:4.2}x  imbalance {:.2}  eff {:4.0}%",
                format!("{balance:?}"),
                rep.wall_secs,
                scaling,
                rep.imbalance,
                rep.efficiency() * 100.0
            );
        }
    }
    println!(
        "\n(simulated devices share this host's cores: wall-clock scaling \
         saturates at the physical core count; the imbalance column shows \
         §3.5.1's strided policy evening out the decay-diagonal load)"
    );
    Ok(())
}
