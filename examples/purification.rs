//! Density-matrix purification — the electronic-structure application
//! SpAMM was built for (paper's motivation; Challacombe & Bock's original
//! O(N) use case).  Runs McWeeny iterations P ← 3P² − 2P³ with the SpAMM
//! engine at several τ and shows that purification converges while most
//! tile products are skipped — SpAMM's self-correcting sweet spot.
//!
//! The driver is the expression-graph path: each iteration runs as one
//! graph (P², P³, the 3P²−2P³ combine, and the idempotency probe all
//! device-side) and the iterate chains between iterations as a
//! device-resident value — compare the pool transfer counters against
//! the `mcweeny_purify_loop` baseline printed at the end.
//!
//!   cargo run --release --example purification -- [n] [devices]

use cuspamm::config::SpammConfig;
use cuspamm::coordinator::Coordinator;
use cuspamm::prelude::*;
use cuspamm::spamm::purification::{initial_density, mcweeny_purify, mcweeny_purify_loop};

fn main() -> Result<()> {
    cuspamm::telemetry::init_logging();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(512);
    let devices: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1);

    let bundle = ArtifactBundle::load("artifacts")?;
    let mut cfg = SpammConfig::default();
    cfg.lonum = if n >= 512 { 128 } else { 32 };
    cfg.devices = devices;
    let coord = Coordinator::new(&bundle, cfg)?;

    println!("== McWeeny purification, N = {n}, {devices} device(s) ==");
    let p0 = initial_density(n, 7);
    println!("initial ‖P₀‖_F = {:.4}", p0.fnorm());

    for tau in [0.0f32, 1e-8, 1e-5] {
        let r = mcweeny_purify(&coord, &p0, tau, 25, 1e-6)?;
        println!(
            "\nτ = {tau:>7.0e}: {} iterations, converged = {}",
            r.steps.len(),
            r.converged
        );
        println!("  iter   ‖P²−P‖_F    valid%   wall(s)");
        for s in r.steps.iter().take(6) {
            println!(
                "  {:4}   {:.3e}   {:6.2}   {:.3}",
                s.iter,
                s.idempotency_err,
                s.valid_ratio * 100.0,
                s.wall_secs
            );
        }
        if r.steps.len() > 6 {
            let s = r.steps.last().unwrap();
            println!(
                "  ...\n  {:4}   {:.3e}   {:6.2}   {:.3}",
                s.iter,
                s.idempotency_err,
                s.valid_ratio * 100.0,
                s.wall_secs
            );
        }
    }
    if let Some(pool) = coord.residency_pools().first() {
        let s = pool.stats();
        println!(
            "\nexpr path transfers: {} KiB uploaded, {} KiB saved \
             (iterates never re-uploaded)",
            s.uploaded_bytes / 1024,
            s.saved_bytes / 1024
        );
    }
    // A/B: the legacy per-multiply loop re-uploads the iterate each
    // iteration — same bits, more bus traffic.
    let mut cfg_loop = SpammConfig::default();
    cfg_loop.lonum = if n >= 512 { 128 } else { 32 };
    cfg_loop.devices = devices;
    let coord_loop = Coordinator::new(&bundle, cfg_loop)?;
    let r = mcweeny_purify_loop(&coord_loop, &p0, 1e-8, 25, 1e-6)?;
    if let Some(pool) = coord_loop.residency_pools().first() {
        let s = pool.stats();
        println!(
            "loop path transfers at τ=1e-8: {} KiB uploaded over {} iterations",
            s.uploaded_bytes / 1024,
            r.steps.len()
        );
    }
    println!(
        "\n(purification is self-correcting: SpAMM's skipped mass does not \
         prevent quadratic convergence — the paper's electronic-structure \
         motivation in action)"
    );
    Ok(())
}
