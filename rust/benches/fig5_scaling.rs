//! Figure 5 reproduction: SpAMM speedup over single-device dense while
//! scaling across 1/2/4/8 simulated devices, for a grid of valid ratios
//! and sizes.
//!
//! This testbed has a fixed physical core budget shared by all simulated
//! devices, so *wall-clock* cannot scale like the paper's 8 physical
//! GPUs.  We therefore report both:
//!   * wall  — measured wall-clock speedup (bounded by physical cores)
//!   * model — dense_time / max(per-device busy time): the speedup M
//!     independent devices of this throughput would deliver, which is the
//!     quantity Fig. 5 plots.  (Substitution documented in DESIGN.md §2.)

use cuspamm::bench_harness::{find_bundle, fmt_speedup, Table};
use cuspamm::config::SpammConfig;
use cuspamm::coordinator::Coordinator;
use cuspamm::matrix::Matrix;

fn main() {
    let bundle = find_bundle();
    let lonum = 128usize;
    let sizes: Vec<usize> = if std::env::var("CUSPAMM_BENCH_FULL").is_ok() {
        vec![1024, 2048]
    } else {
        vec![1024]
    };
    let ratios = [0.30, 0.15, 0.05];
    let device_counts = [1usize, 2, 4, 8];

    let mut table = Table::new(
        "Figure 5 — speedup vs dense while scaling devices (wall | modeled)",
        &["N", "valid ratio", "1 dev", "2 dev", "4 dev", "8 dev"],
    );

    for &n in &sizes {
        let a = Matrix::decay_algebraic(n, 0.1, 0.1, 7);
        let b = Matrix::decay_algebraic(n, 0.1, 0.1, 8);
        for &ratio in &ratios {
            let mut row = vec![n.to_string(), format!("≈{:.0}%", ratio * 100.0)];
            for &devices in &device_counts {
                let mut cfg = SpammConfig::default();
                cfg.lonum = lonum;
                cfg.devices = devices;
                cfg.sequential_devices = true;
                let coord = Coordinator::new(&bundle, cfg).expect("coordinator");
                let tuned = coord.tune_tau(&a, &b, ratio).expect("tune");
                // One warm run (compiles happen pre-barrier inside multiply,
                // but OS caches etc. settle on the first pass).
                coord.multiply(&a, &b, tuned.tau).expect("warm");
                let rep = coord.multiply(&a, &b, tuned.tau).expect("spamm");
                let dense = coord.dense(&a, &b).expect("dense");
                let wall = dense.wall_secs / rep.wall_secs;
                let modeled = dense.wall_secs
                    / rep
                        .device_busy
                        .iter()
                        .cloned()
                        .fold(0.0f64, f64::max)
                        .max(1e-12);
                row.push(format!(
                    "{} | {}",
                    fmt_speedup(wall),
                    fmt_speedup(modeled)
                ));
            }
            table.row(row);
        }
    }
    table.emit("fig5_scaling");
    println!(
        "(modeled column = dense / max per-device busy: the Fig. 5 quantity \
         on independent devices)"
    );
}
