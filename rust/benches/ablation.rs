//! Ablation benches for the design choices DESIGN.md calls out:
//!   A. tile size LoNum (32 vs 128) — tile-GEMM throughput + end-to-end
//!   B. batch bucket size — per-call overhead amortization
//!   C. load balance policy (§3.5.1) — rowblock vs strided imbalance
//!   D. normmap location — host vs on-device get-norm
//!   E. precision — f32 vs bf16 tile path

use std::time::Instant;

use cuspamm::bench_harness::{find_bundle, fmt_secs, Table};
use cuspamm::config::{Balance, SpammConfig};
use cuspamm::matrix::tiling::PaddedMatrix;
use cuspamm::matrix::Matrix;
use cuspamm::runtime::Runtime;
use cuspamm::spamm::balance::Assignment;
use cuspamm::spamm::normmap::normmap;
use cuspamm::spamm::schedule::Schedule;
use cuspamm::spamm::SpammEngine;

fn time_reps<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        f();
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

fn main() {
    let bundle = find_bundle();
    let rt = Runtime::new(&bundle).expect("runtime");

    // --- A+B: tile-GEMM throughput per (LoNum, bucket) -------------------
    let mut t_ab = Table::new(
        "Ablation A/B — tile-GEMM throughput per LoNum and batch bucket",
        &["LoNum", "bucket", "ms/call", "us/product", "GFLOPS"],
    );
    for (l, buckets) in [(32usize, vec![64usize, 256, 1024]), (128, vec![16, 64, 256])] {
        for cap in buckets {
            let a = Matrix::randn(cap * l, l, 1).into_vec();
            let b = Matrix::randn(cap * l, l, 2).into_vec();
            rt.tile_gemm(&a, &b, cap, l, "f32").unwrap(); // warm/compile
            let per = time_reps(3, || {
                rt.tile_gemm(&a, &b, cap, l, "f32").unwrap();
            });
            t_ab.row(vec![
                l.to_string(),
                cap.to_string(),
                format!("{:.2}", per * 1e3),
                format!("{:.1}", per / cap as f64 * 1e6),
                format!(
                    "{:.1}",
                    2.0 * cap as f64 * (l * l * l) as f64 / per / 1e9
                ),
            ]);
        }
    }
    t_ab.emit("ablation_tile_throughput");

    // --- C: load balance (§3.5.1) ----------------------------------------
    let mut t_c = Table::new(
        "Ablation C — load-balance policy on a decay schedule (N=1024, l=128)",
        &["devices", "rowblock imbalance", "strided:4 imbalance"],
    );
    let a = Matrix::decay_exponential(1024, 1.0, 0.55, 3);
    let na = normmap(&PaddedMatrix::new(&a, 128));
    let tuned = cuspamm::spamm::tuner::tune_tau(
        &na,
        &na,
        0.15,
        cuspamm::spamm::tuner::TuneParams::default(),
    )
    .unwrap();
    let sched = Schedule::build(&na, &na, tuned.tau).unwrap();
    for devices in [2usize, 4, 8] {
        let rb = Assignment::build(&sched, devices, Balance::RowBlock).imbalance(&sched);
        let st = Assignment::build(&sched, devices, Balance::Strided(4)).imbalance(&sched);
        t_c.row(vec![
            devices.to_string(),
            format!("{rb:.3}"),
            format!("{st:.3}"),
        ]);
    }
    t_c.emit("ablation_balance");

    // --- D: normmap host vs device ---------------------------------------
    let mut t_d = Table::new(
        "Ablation D — get-norm location (N=1024, l=128)",
        &["path", "time"],
    );
    let m = Matrix::decay_algebraic(1024, 0.1, 0.1, 5);
    let p = PaddedMatrix::new(&m, 128);
    let host = time_reps(5, || {
        normmap(&p);
    });
    rt.getnorm(&m, 128, false).unwrap(); // compile
    let dev = time_reps(5, || {
        rt.getnorm(&m, 128, false).unwrap();
    });
    t_d.row(vec!["host (rust)".into(), fmt_secs(host)]);
    t_d.row(vec!["device (get-norm artifact)".into(), fmt_secs(dev)]);
    t_d.emit("ablation_normmap");

    // --- E: precision ------------------------------------------------------
    let mut t_e = Table::new(
        "Ablation E — precision of the tile path (N=1024, l=128, ratio 10%)",
        &["precision", "multiply time", "‖E vs f32‖_F"],
    );
    let a = Matrix::decay_algebraic(1024, 0.1, 0.1, 7);
    let b = Matrix::decay_algebraic(1024, 0.1, 0.1, 8);
    let mut cfg = SpammConfig::default();
    cfg.lonum = 128;
    let engine_f32 = SpammEngine::new(&bundle, cfg.clone()).unwrap();
    cfg.precision = cuspamm::config::Precision::Bf16;
    let engine_bf16 = SpammEngine::new(&bundle, cfg).unwrap();
    let tuned = engine_f32.tune_tau(&a, &b, 0.10).unwrap();
    let c32 = engine_f32.multiply(&a, &b, tuned.tau).unwrap();
    let f32_t = time_reps(3, || {
        engine_f32.multiply(&a, &b, tuned.tau).unwrap();
    });
    let cbf = engine_bf16.multiply(&a, &b, tuned.tau).unwrap();
    let bf16_t = time_reps(3, || {
        engine_bf16.multiply(&a, &b, tuned.tau).unwrap();
    });
    t_e.row(vec!["f32".into(), fmt_secs(f32_t), "0".into()]);
    t_e.row(vec![
        "bf16".into(),
        fmt_secs(bf16_t),
        format!("{:.3e}", c32.error_fnorm(&cbf).unwrap()),
    ]);
    t_e.emit("ablation_precision");

    // --- F: Algorithm-4 rows vs SUMMA 2-D grid (comm volume model) --------
    use cuspamm::coordinator::summa::{comm_model_grid, comm_model_rows, grid_shape};
    let mut t_f = Table::new(
        "Ablation F — modeled per-run communication: row partition vs 2-D grid (N=1024)",
        &["devices", "grid", "rows total MB", "grid total MB", "saving"],
    );
    for devices in [2usize, 4, 8, 16] {
        let (pr, pc) = grid_shape(devices);
        let rows = comm_model_rows(1024, devices);
        let grid = comm_model_grid(1024, pr, pc);
        t_f.row(vec![
            devices.to_string(),
            format!("{pr}x{pc}"),
            format!("{:.1}", rows.total_bytes as f64 / 1e6),
            format!("{:.1}", grid.total_bytes as f64 / 1e6),
            format!("{:.2}x", rows.total_bytes as f64 / grid.total_bytes as f64),
        ]);
    }
    t_f.emit("ablation_summa_comm");
}
