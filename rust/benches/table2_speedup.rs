//! Table 2 reproduction: single-device speedup of SpAMM over the dense
//! baseline (cuBLAS stand-in) on synthesized algebraic-decay matrices, for
//! valid ratios 30%→5% and both precisions (f32 row, bf16 row — the
//! paper's FP32/FP16 pairing with the MXU as tensor-core analog).
//!
//! Expected shape (not absolute numbers): speedup grows as the ratio
//! falls; the crossover (speedup ≈ 1) sits in the 10–30% band on this
//! testbed (the tile-path vs dense-path efficiency gap of the PJRT-CPU
//! substrate shifts it — see EXPERIMENTS.md).

use std::time::Instant;

use cuspamm::bench_harness::{find_bundle, fmt_speedup, time_fn, Policy, Table};
use cuspamm::config::{Precision, SpammConfig};
use cuspamm::matrix::Matrix;
use cuspamm::spamm::SpammEngine;

fn main() {
    let bundle = find_bundle();
    let policy = Policy::from_env();
    let sizes: Vec<usize> = if std::env::var("CUSPAMM_BENCH_FULL").is_ok() {
        vec![256, 512, 1024, 2048]
    } else {
        vec![256, 512, 1024]
    };
    // Tile size per problem size: the paper tunes block hyper-parameters
    // (§2.2.2); on this runtime L=128 maximizes tile-GEMM throughput but
    // over-quantizes tiny problems, so N=256 uses L=32.
    let lonum_for = |n: usize| if n >= 512 { 128 } else { 32 };
    let ratios = [0.30, 0.25, 0.20, 0.15, 0.10, 0.05];

    let mut headers = vec!["valid ratio".to_string(), "prec".to_string()];
    headers.extend(sizes.iter().map(|n| n.to_string()));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Table 2 — SpAMM speedup over dense, single device (rows: f32 / bf16)",
        &hdr_refs,
    );

    for &ratio in &ratios {
        for precision in [Precision::F32, Precision::Bf16] {
            let mut row = vec![
                format!("≈{:.0}%", ratio * 100.0),
                precision.as_str().to_string(),
            ];
            for &n in &sizes {
                let mut cfg = SpammConfig::default();
                cfg.lonum = lonum_for(n);
                cfg.precision = precision;
                let engine = SpammEngine::new(&bundle, cfg).expect("engine");
                let a = Matrix::decay_algebraic(n, 0.1, 0.1, 7);
                let b = Matrix::decay_algebraic(n, 0.1, 0.1, 8);
                let tuned = engine.tune_tau(&a, &b, ratio).expect("tune");

                // Warm both paths (compile + caches), then time.
                engine.multiply(&a, &b, tuned.tau).expect("spamm warm");
                engine.dense(&a, &b).expect("dense warm");

                let spamm = time_fn(policy, || {
                    engine.multiply(&a, &b, tuned.tau).expect("spamm");
                });
                let t0 = Instant::now();
                for _ in 0..policy.reps.max(1) {
                    engine.dense(&a, &b).expect("dense");
                }
                let dense = t0.elapsed().as_secs_f64() / policy.reps.max(1) as f64;
                row.push(fmt_speedup(dense / spamm.median));
            }
            table.row(row);
        }
    }
    table.emit("table2_speedup");
    println!("(values are dense_time/spamm_time medians; >1.0 = SpAMM wins)");
}
