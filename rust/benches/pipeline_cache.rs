//! Execution-pipeline bench: repeated multiplies in the power-iteration
//! shape (same A, same τ) to measure (a) the norm+schedule phase saved by
//! the content-fingerprint caches and (b) the gather/exec/scatter overlap
//! of the stage-pipelined executor (per-stage second sums vs the
//! pipelined wall-clock span).

use cuspamm::bench_harness::{fmt_secs, Table};
use cuspamm::config::SpammConfig;
use cuspamm::matrix::Matrix;
use cuspamm::runtime::hostsim;
use cuspamm::spamm::SpammEngine;

fn main() {
    let bundle = hostsim::find_or_test_bundle().expect("artifact bundle");
    let n = 512usize;
    let iters = 10usize;
    let a = Matrix::decay_exponential(n, 1.0, 0.5, 7);
    let b = Matrix::decay_exponential(n, 1.0, 0.5, 8);

    // Tune on a throwaway engine so the measured engine's caches stay
    // genuinely cold for the baseline call.
    let tau = {
        let tuner = SpammEngine::new(&bundle, SpammConfig::default()).expect("tuner engine");
        tuner.tune_tau(&a, &b, 0.15).expect("tune").tau
    };
    let engine = SpammEngine::new(&bundle, SpammConfig::default()).expect("engine");

    // Cold call: norm + schedule phases computed from scratch.
    let (_, cold) = engine.multiply_with_stats(&a, &b, tau).expect("cold");
    let cold_phase = cold.norm_secs + cold.schedule_secs;

    // Warm calls (power-iteration shape: same operands, same τ).
    let mut warm_phase = 0.0f64;
    let mut warm_hits = 0usize;
    let mut stage_sum = 0.0f64;
    let mut span_sum = 0.0f64;
    for _ in 0..iters {
        let (_, s) = engine.multiply_with_stats(&a, &b, tau).expect("warm");
        warm_phase += s.norm_secs + s.schedule_secs;
        warm_hits += s.norm_cache_hits + s.schedule_cache_hits;
        stage_sum += s.gather_secs + s.exec_secs + s.scatter_secs;
        span_sum += s.exec_span_secs;
    }
    warm_phase /= iters as f64;

    let mut table = Table::new(
        "Execution pipeline — cache reuse and stage overlap",
        &["metric", "value"],
    );
    table.row(vec![
        "norm+schedule, cold".into(),
        fmt_secs(cold_phase),
    ]);
    table.row(vec![
        format!("norm+schedule, warm (avg of {iters})"),
        fmt_secs(warm_phase),
    ]);
    table.row(vec![
        "phase speedup on cache hits".into(),
        format!("{:.1}x", cold_phase / warm_phase.max(1e-12)),
    ]);
    table.row(vec![
        format!("cache hits over {iters} warm iters"),
        format!("{warm_hits} (3 per iter = all phases skipped)"),
    ]);
    table.row(vec![
        "Σ stage secs (gather+exec+scatter)".into(),
        fmt_secs(stage_sum),
    ]);
    table.row(vec![
        "Σ pipelined wall span".into(),
        fmt_secs(span_sum),
    ]);
    table.row(vec![
        "overlap factor (stage/span)".into(),
        format!("{:.2}", stage_sum / span_sum.max(1e-12)),
    ]);
    table.emit("pipeline_cache");
    println!(
        "(phase speedup ≥5x and overlap factor >1.0 are the PR-1 acceptance \
         targets; overlap >1 means gather/scatter ran concurrently with exec)"
    );
}
