//! Execution-pipeline bench: repeated multiplies in the power-iteration
//! shape (same A, same τ) to measure (a) the norm+schedule phase saved by
//! the content-fingerprint caches, (b) the gather/exec/scatter overlap of
//! the stage-pipelined executor (per-stage second sums vs the pipelined
//! wall-clock span), and (c) the host→device bytes the device-resident
//! tile pool saves once the operands are warm (transfer reduction and
//! reuse factor).
//!
//! `cargo bench --bench pipeline_cache -- --smoke` runs a one-iteration
//! test-mode pass (the CI smoke invocation keeping this bench honest).

use cuspamm::bench_harness::{fmt_secs, Table};
use cuspamm::config::SpammConfig;
use cuspamm::coordinator::Coordinator;
use cuspamm::matrix::Matrix;
use cuspamm::runtime::hostsim;
use cuspamm::spamm::power::{spamm_power, spamm_power_loop};
use cuspamm::spamm::SpammEngine;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let bundle = hostsim::find_or_test_bundle().expect("artifact bundle");
    let n = if smoke { 256usize } else { 512 };
    let iters = if smoke { 1usize } else { 10 };
    let a = Matrix::decay_exponential(n, 1.0, 0.5, 7);
    let b = Matrix::decay_exponential(n, 1.0, 0.5, 8);

    // Tune on a throwaway engine so the measured engine's caches stay
    // genuinely cold for the baseline call.
    let tau = {
        let tuner = SpammEngine::new(&bundle, SpammConfig::default()).expect("tuner engine");
        tuner.tune_tau(&a, &b, 0.15).expect("tune").tau
    };
    let engine = SpammEngine::new(&bundle, SpammConfig::default()).expect("engine");

    // Cold call: norm + schedule phases computed from scratch, every
    // operand tile uploaded (residency-pool misses).
    let (_, cold) = engine.multiply_with_stats(&a, &b, tau).expect("cold");
    let cold_phase = cold.norm_secs + cold.schedule_secs;

    // Warm calls (power-iteration shape: same operands, same τ).
    let mut warm_phase = 0.0f64;
    let mut warm_hits = 0usize;
    let mut stage_sum = 0.0f64;
    let mut span_sum = 0.0f64;
    let mut warm_transfer = 0u64;
    let mut warm_saved = 0u64;
    for _ in 0..iters {
        let (_, s) = engine.multiply_with_stats(&a, &b, tau).expect("warm");
        warm_phase += s.norm_secs + s.schedule_secs;
        warm_hits += s.norm_cache_hits + s.schedule_cache_hits;
        stage_sum += s.gather_secs + s.exec_secs + s.scatter_secs;
        span_sum += s.exec_span_secs;
        warm_transfer += s.transfer_bytes;
        warm_saved += s.transfer_saved_bytes;
    }
    warm_phase /= iters as f64;
    let warm_transfer_avg = warm_transfer / iters as u64;

    let mut table = Table::new(
        "Execution pipeline — cache reuse and stage overlap",
        &["metric", "value"],
    );
    table.row(vec![
        "norm+schedule, cold".into(),
        fmt_secs(cold_phase),
    ]);
    table.row(vec![
        format!("norm+schedule, warm (avg of {iters})"),
        fmt_secs(warm_phase),
    ]);
    table.row(vec![
        "phase speedup on cache hits".into(),
        format!("{:.1}x", cold_phase / warm_phase.max(1e-12)),
    ]);
    table.row(vec![
        format!("cache hits over {iters} warm iters"),
        format!("{warm_hits} (3 per iter = all phases skipped)"),
    ]);
    table.row(vec![
        "Σ stage secs (gather+exec+scatter)".into(),
        fmt_secs(stage_sum),
    ]);
    table.row(vec![
        "Σ pipelined wall span".into(),
        fmt_secs(span_sum),
    ]);
    table.row(vec![
        "overlap factor (stage/span)".into(),
        format!("{:.2}", stage_sum / span_sum.max(1e-12)),
    ]);
    table.emit("pipeline_cache");

    // ---- residency scenario: transfer bytes saved by the warm pool ------
    let pool = engine.residency().expect("residency on by default");
    let ps = pool.stats();
    // Reuse factor: share of operand-tile references served without a
    // host→device transfer (pool hits + within-chunk dedup).  Computed
    // from the per-call MultiplyStats aggregates — pool counters alone
    // miss the within-chunk dedup, which never reaches the pool.
    let total_uploaded = cold.transfer_bytes + warm_transfer;
    let total_saved = cold.transfer_saved_bytes + warm_saved;
    let reuse = total_saved as f64 / (total_uploaded + total_saved).max(1) as f64;
    let reduction = cold.transfer_bytes as f64 / warm_transfer_avg.max(1) as f64;

    let mut rtable = Table::new(
        "Residency — device-resident operand tiles",
        &["metric", "value"],
    );
    rtable.row(vec![
        "transfer bytes, cold multiply".into(),
        format!("{} KiB", cold.transfer_bytes / 1024),
    ]);
    rtable.row(vec![
        format!("transfer bytes, warm multiply (avg of {iters})"),
        format!("{} KiB", warm_transfer_avg / 1024),
    ]);
    rtable.row(vec![
        "warm transfer reduction".into(),
        if warm_transfer_avg == 0 {
            "∞ (zero warm transfers)".to_string()
        } else {
            format!("{reduction:.1}x")
        },
    ]);
    rtable.row(vec![
        "bytes saved across run".into(),
        format!("{} KiB", total_saved / 1024),
    ]);
    rtable.row(vec![
        "reuse factor (saved / referenced)".into(),
        format!("{:.1}%", reuse * 100.0),
    ]);
    rtable.row(vec![
        "pool hits / misses / evictions".into(),
        format!("{} / {} / {}", ps.hits, ps.misses, ps.evictions),
    ]);
    rtable.row(vec![
        "resident tiles (bytes)".into(),
        format!("{} ({} KiB)", ps.resident_tiles, ps.resident_bytes / 1024),
    ]);
    rtable.emit("pipeline_cache_residency");

    let pass = warm_transfer_avg * 4 <= cold.transfer_bytes;
    println!(
        "(acceptance: warm multiply transfers ≥4x fewer bytes than cold — {})",
        if pass { "PASS" } else { "FAIL" }
    );
    println!(
        "(phase speedup ≥5x and overlap factor >1.0 are the PR-1 acceptance \
         targets; overlap >1 means gather/scatter ran concurrently with exec)"
    );

    // ---- expression graphs: one A^4 chain plan vs the per-step loop ----
    // The loop path re-uploads and host-re-norms every intermediate; the
    // expression path keeps them device-resident under derived
    // fingerprints and refreshes norms from the scattered tiles.
    let kp = 4usize;
    let ptau = 1e-5f32;
    let base = Matrix::decay_exponential(n, 1.0, 0.5, 9);
    let c_loop = Coordinator::new(&bundle, SpammConfig::default()).expect("loop coord");
    let c_expr = Coordinator::new(&bundle, SpammConfig::default()).expect("expr coord");
    let t = std::time::Instant::now();
    let rl = spamm_power_loop(&c_loop, &base, kp, ptau).expect("loop power");
    let loop_wall = t.elapsed().as_secs_f64();
    let t = std::time::Instant::now();
    let re = spamm_power(&c_expr, &base, kp, ptau).expect("expr power");
    let expr_wall = t.elapsed().as_secs_f64();
    assert_eq!(
        re.value.data(),
        rl.value.data(),
        "expr path must be bitwise identical to the loop path"
    );
    let up_loop = c_loop.residency_pools()[0].stats().uploaded_bytes;
    let up_expr = c_expr.residency_pools()[0].stats().uploaded_bytes;
    let mut etable = Table::new(
        "Expression graph — A^4 chain (one plan) vs per-step loop",
        &["metric", "loop", "expr"],
    );
    etable.row(vec![
        "uploaded (KiB)".into(),
        format!("{}", up_loop / 1024),
        format!("{}", up_expr / 1024),
    ]);
    etable.row(vec![
        "transfer bytes saved vs loop".into(),
        "—".into(),
        format!(
            "{} KiB ({:.1}x less)",
            (up_loop.saturating_sub(up_expr)) / 1024,
            up_loop as f64 / up_expr.max(1) as f64
        ),
    ]);
    etable.row(vec![
        "host round-trips for intermediates".into(),
        format!("{}", kp - 2),
        "0 (resident, freed at retirement)".into(),
    ]);
    etable.row(vec![
        "host norm recomputes (cache misses)".into(),
        format!("{}", c_loop.caches().norms.misses()),
        format!(
            "{} (device-side refresh instead)",
            c_expr.caches().norms.misses()
        ),
    ]);
    etable.row(vec![
        "wall secs (incl. prepare)".into(),
        fmt_secs(loop_wall),
        fmt_secs(expr_wall),
    ]);
    etable.emit("pipeline_cache_expr");

    if smoke {
        assert!(pass, "smoke mode: warm residency must cut transfers ≥4x");
        assert!(
            up_expr * 2 <= up_loop,
            "smoke mode: expr chain must upload ≤ half the loop's bytes \
             ({up_expr} vs {up_loop})"
        );
        assert!(
            c_expr.caches().norms.misses() <= 1,
            "smoke mode: expr chain must not host-recompute intermediate norms"
        );
        println!(
            "smoke mode: residency + expr-vs-loop acceptance asserted — OK"
        );
    }
}
