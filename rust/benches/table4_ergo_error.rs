//! Table 4 reproduction: the ergo case study's error ladder — for each of
//! the four exponential-decay matrices (F-norms matched to the paper's),
//! compute the matrix power C = A·A under τ ∈ {1e-10 … 1e-2} and report
//! ‖E‖_F.
//!
//! Expected shape: error ≈ 0 at τ=1e-10 (no products skipped), rising
//! smoothly with τ, and always ≪ ‖C‖_F for matrices with large norms.

use cuspamm::bench_harness::{find_bundle, Table};
use cuspamm::config::SpammConfig;
use cuspamm::matrix::ergo::{ergo_matrix, ERGO_SPECS};
use cuspamm::spamm::SpammEngine;

fn main() {
    let bundle = find_bundle();
    let lonum = 128usize;
    let n: usize = if std::env::var("CUSPAMM_BENCH_FULL").is_ok() {
        2048
    } else {
        1024
    };
    let taus: [f32; 5] = [1e-10, 1e-8, 1e-6, 1e-4, 1e-2];

    let mut cfg = SpammConfig::default();
    cfg.lonum = lonum;
    let engine = SpammEngine::new(&bundle, cfg).expect("engine");

    let mut table = Table::new(
        "Table 4 — ergo matrices: ‖E‖_F under τ sweep (C = A·A)",
        &[
            "no.", "‖A‖_F", "‖C‖_F", "τ=1e-10", "1e-8", "1e-6", "1e-4", "1e-2",
        ],
    );

    for (no, _, _) in ERGO_SPECS {
        let a = ergo_matrix(no, n, 42);
        // Eq. 5 reference: the τ=0 product on the same tile path, so the
        // measured ‖E‖ is pure approximation error (skipped products) and
        // not the f32 noise floor between two different summation orders.
        let exact = engine.multiply(&a, &a, 0.0).expect("tau=0 reference");
        let mut row = vec![
            no.to_string(),
            format!("{:.3e}", a.fnorm()),
            format!("{:.3e}", exact.fnorm()),
        ];
        for &tau in &taus {
            let c = engine.multiply(&a, &a, tau).expect("spamm");
            row.push(format!("{:.3e}", exact.error_fnorm(&c).unwrap()));
        }
        table.row(row);
    }
    table.emit("table4_ergo_error");
    println!("(paper shape: errors ~0 at 1e-10, growing with τ, ‖E‖/‖C‖ ≪ 1)");
}
