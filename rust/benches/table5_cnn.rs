//! Table 5 reproduction: the VGG13 case study — substitute a conv layer's
//! im2col GEMM with SpAMM, sweep τ, and report valid ratio, end-task
//! accuracy loss, and the layer GEMM's speedup on 1/2/4 devices.
//!
//! Expected shape: accuracy loss ≈ 0 over a wide τ range (CNNs are
//! insensitive to GEMM approximation) while the conv GEMM accelerates;
//! losses only appear at aggressive ratios.

use std::collections::BTreeMap;
use std::time::Instant;

use cuspamm::bench_harness::{find_bundle, fmt_speedup, Table};
use cuspamm::cnn::{Cnn, GemmMode};
use cuspamm::config::SpammConfig;
use cuspamm::coordinator::Coordinator;
use cuspamm::matrix::im2col::{im2col, maxpool2, relu};
use cuspamm::matrix::Matrix;
use cuspamm::spamm::SpammEngine;

fn main() {
    let bundle = find_bundle();
    let meta = bundle.cnn.clone().expect("cnn export in bundle");
    let cnn = Cnn::load(&meta).expect("cnn load");
    let lonum = 32usize; // CNN GEMMs are small; MXU-128 padding would dominate
    let mut cfg = SpammConfig::default();
    cfg.lonum = lonum;
    let engine = SpammEngine::new(&bundle, cfg).expect("engine");
    let limit = if std::env::var("CUSPAMM_BENCH_FULL").is_ok() {
        500
    } else {
        200
    };

    let mut table = Table::new(
        "Table 5 — CNN case study: accuracy vs speedup per conv layer",
        &[
            "layer", "valid ratio", "acc loss", "τ",
            "GEMM speedup (1/2/4 dev)",
        ],
    );

    let no_modes: BTreeMap<String, GemmMode> = BTreeMap::new();
    let baseline = cnn
        .accuracy(&no_modes, Some(&engine), 100, Some(limit))
        .expect("baseline accuracy");
    println!("baseline accuracy over {limit} images: {:.2}%", baseline * 100.0);

    for layer in ["conv2", "conv3"] {
        // Build the layer's actual GEMM operands from real activations
        // (first test batch), for the timing column.
        let (x0, _) = cnn.test_batch(0, 100);
        let mut h = x0;
        {
            // replicate forward up to the target layer with host convs
            let w1 = &cnn_layer_weights(&cnn, "conv1");
            let cols = im2col(&h);
            let out = w1.matmul(&cols).unwrap();
            let mut t = cuspamm::matrix::im2col::gemm_out_to_nchw(&out, h.n, h.h, h.w);
            relu(&mut t);
            h = maxpool2(&t);
        }
        if layer == "conv3" {
            let w2 = &cnn_layer_weights(&cnn, "conv2");
            let cols = im2col(&h);
            let out = w2.matmul(&cols).unwrap();
            let mut t = cuspamm::matrix::im2col::gemm_out_to_nchw(&out, h.n, h.h, h.w);
            relu(&mut t);
            h = maxpool2(&t);
        }
        let w = cnn_layer_weights(&cnn, layer);
        let patches = im2col(&h);

        // The paper's Table 5 is driven by *valid ratio* targets (§3.5.2:
        // DNN users tune the ratio, not τ) — derive τ per target from the
        // layer's real normmaps via the tuner.
        let ratio_targets = [0.95f64, 0.80, 0.60, 0.40, 0.20, 0.10];
        for &target in &ratio_targets {
            let tau = {
                let mut tcfg = SpammConfig::default();
                tcfg.lonum = lonum;
                let coord = Coordinator::new(&bundle, tcfg).unwrap();
                coord.tune_tau(&w, &patches, target).unwrap().tau
            };
            // accuracy with this layer approximated
            let mut modes = BTreeMap::new();
            modes.insert(layer.to_string(), GemmMode::Spamm { tau });
            let acc = cnn
                .accuracy(&modes, Some(&engine), 100, Some(limit))
                .expect("approx accuracy");

            // layer GEMM speedup, 1/2/4 devices (modeled; see fig5 bench)
            let mut cells = Vec::new();
            let mut ratio_pct = String::new();
            for devices in [1usize, 2, 4] {
                let mut dcfg = SpammConfig::default();
                dcfg.lonum = lonum;
                dcfg.devices = devices;
                dcfg.sequential_devices = true;
                let coord = Coordinator::new(&bundle, dcfg).unwrap();
                coord.multiply(&w, &patches, tau).unwrap(); // warm
                let rep = coord.multiply(&w, &patches, tau).unwrap();
                if devices == 1 {
                    ratio_pct = format!("{:.2}%", rep.valid_ratio * 100.0);
                }
                // dense layer GEMM on the runtime (rect artifact exists at
                // batch 100 shapes; fall back to host matmul timing).
                let dense_secs = time_dense(&engine, &w, &patches);
                let spamm_secs = rep
                    .device_busy
                    .iter()
                    .cloned()
                    .fold(0.0f64, f64::max)
                    .max(1e-12);
                cells.push(fmt_speedup(dense_secs / spamm_secs));
            }
            table.row(vec![
                layer.to_string(),
                ratio_pct,
                format!("{:+.2}%", (acc - baseline) * 100.0),
                format!("{tau:.3}"),
                cells.join("/"),
            ]);
        }
    }
    table.emit("table5_cnn");
}

fn cnn_layer_weights(cnn: &Cnn, layer: &str) -> Matrix {
    // The Cnn struct keeps weights private; rebuild via its forward API is
    // overkill — load from the export directly.
    let t = cuspamm::matrix::tensorio::load_tensor(
        &cnn.meta.dir.join(format!("{layer}_w.cstn")),
    )
    .expect("weights");
    let (dims, data) = t.as_f32().expect("f32 weights");
    Matrix::from_vec(dims[0], dims[1], data.to_vec()).unwrap()
}

fn time_dense(engine: &SpammEngine, w: &Matrix, patches: &Matrix) -> f64 {
    // Prefer the dense rect artifact; otherwise host matmul.
    let runtime = engine.runtime();
    if runtime.dense(w, patches, "f32").is_ok() {
        runtime.dense(w, patches, "f32").unwrap();
        let t0 = Instant::now();
        runtime.dense(w, patches, "f32").unwrap();
        t0.elapsed().as_secs_f64()
    } else {
        let t0 = Instant::now();
        w.matmul(patches).unwrap();
        t0.elapsed().as_secs_f64()
    }
}
