//! Figure 6 reproduction: ergo case study speedup over dense while
//! sweeping τ and scaling 1→8 devices, for each of the four matrices.
//!
//! Expected shape: speedup grows with τ (more skipping) and with device
//! count (modeled column — see the Fig. 5 bench header for why wall-clock
//! cannot scale on a shared-core testbed).

use cuspamm::bench_harness::{find_bundle, fmt_speedup, Table};
use cuspamm::config::SpammConfig;
use cuspamm::coordinator::Coordinator;
use cuspamm::matrix::ergo::{ergo_matrix, ERGO_SPECS};

fn main() {
    let bundle = find_bundle();
    let lonum = 128usize;
    let n: usize = if std::env::var("CUSPAMM_BENCH_FULL").is_ok() {
        2048
    } else {
        1024
    };
    let taus: [f32; 3] = [1e-6, 1e-4, 1e-2];
    let device_counts = [1usize, 2, 4, 8];

    let mut table = Table::new(
        "Figure 6 — ergo speedup vs dense (modeled), scaling devices",
        &["no.", "τ", "valid%", "1 dev", "2 dev", "4 dev", "8 dev"],
    );

    for (no, _, _) in ERGO_SPECS {
        let a = ergo_matrix(no, n, 42);
        for &tau in &taus {
            let mut row = vec![no.to_string(), format!("{tau:.0e}")];
            let mut valid_pct = String::new();
            let mut cells = Vec::new();
            for &devices in &device_counts {
                let mut cfg = SpammConfig::default();
                cfg.lonum = lonum;
                cfg.devices = devices;
                cfg.sequential_devices = true;
                let coord = Coordinator::new(&bundle, cfg).expect("coordinator");
                coord.multiply(&a, &a, tau).expect("warm");
                let rep = coord.multiply(&a, &a, tau).expect("spamm");
                let dense = coord.dense(&a, &a).expect("dense");
                if devices == 1 {
                    valid_pct = format!("{:.1}", rep.valid_ratio * 100.0);
                }
                let modeled = rep
                    .device_busy
                    .iter()
                    .cloned()
                    .fold(0.0f64, f64::max)
                    .max(1e-12);
                cells.push(fmt_speedup(dense.wall_secs / modeled));
            }
            row.push(valid_pct);
            row.extend(cells);
            table.row(row);
        }
    }
    table.emit("fig6_ergo_scaling");
}
