//! Table 3 reproduction: SpAMM vs the CSR SpGEMM baseline (cuSPARSE
//! stand-in) at matched error levels.
//!
//! Protocol (paper §4.2.2): truncate the decay matrix at TRUN to produce
//! a CSR operand at a given nz-ratio; record the truncated product's error
//! ‖E‖_F; pick τ so SpAMM reaches the same error level; compare SpGEMM
//! time against SpAMM on 1/2/4/8 devices.  Format-conversion time is
//! excluded (as the paper excludes it).
//!
//! Expected shape: SpAMM ≫ SpGEMM at high nz ratios, the gap narrowing as
//! the matrix gets truly sparse.

use std::time::Instant;

use cuspamm::bench_harness::{find_bundle, fmt_speedup, Table};
use cuspamm::config::SpammConfig;
use cuspamm::coordinator::Coordinator;
use cuspamm::matrix::Matrix;
use cuspamm::sparse::spgemm::spgemm;
use cuspamm::sparse::CsrMatrix;

/// Find τ whose SpAMM error best matches `target_err` (bisection on the
/// monotone error-vs-τ curve, using the host reference for search).
fn match_tau(a: &Matrix, b: &Matrix, exact: &Matrix, target_err: f64, lonum: usize) -> f32 {
    let mut lo = 0.0f32;
    let mut hi = {
        // upper bound: τ big enough to zero everything
        let na = cuspamm::spamm::normmap::normmap(
            &cuspamm::matrix::tiling::PaddedMatrix::new(a, lonum),
        );
        let max = na.data().iter().cloned().fold(0.0f32, f32::max);
        max * max * 4.0
    };
    for _ in 0..24 {
        let mid = 0.5 * (lo + hi);
        let c = cuspamm::spamm::reference::spamm_flat_host(a, b, mid, lonum).unwrap();
        let err = exact.error_fnorm(&c).unwrap();
        if err < target_err {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

fn main() {
    let bundle = find_bundle();
    let lonum = 128usize;
    let sizes = [(1usize, 256usize), (2, 1024)]; // paper: 1024 and 8192
    // TRUN thresholds chosen to hit the paper's nz-ratio ladder
    // (~50% / ~25% / ~10%).  Entries are env(d)·U(−1,1) with
    // env(d) = 0.1/(d^0.1+1) ∈ [~0.033, 0.05], so keeping a fraction p
    // needs t ≈ (1−p)·env — thresholds sit in the 0.02–0.04 band.
    let truns = [0.019f32, 0.028, 0.0345];

    let mut table = Table::new(
        "Table 3 — SpAMM vs CSR SpGEMM at matched error",
        &[
            "no.", "nz ratio", "valid ratio", "‖E‖_F csr", "‖E‖_F spamm",
            "speedup (1/2/4/8 dev)",
        ],
    );

    for &(no, n) in &sizes {
        let a = Matrix::decay_algebraic(n, 0.1, 0.1, 7);
        let b = Matrix::decay_algebraic(n, 0.1, 0.1, 8);
        let exact = a.matmul(&b).unwrap();

        for &trun in &truns {
            // cuSPARSE side: truncate → CSR → SpGEMM (timed).
            let mut at = a.clone();
            let mut bt = b.clone();
            at.truncate(trun);
            bt.truncate(trun);
            let ca = CsrMatrix::from_dense(&at, 0.0);
            let cb = CsrMatrix::from_dense(&bt, 0.0);
            let nz = ca.nz_ratio();
            spgemm(&ca, &cb).unwrap(); // warm
            let t0 = Instant::now();
            let csr_prod = spgemm(&ca, &cb).unwrap();
            let csr_secs = t0.elapsed().as_secs_f64();
            let csr_err = exact.error_fnorm(&csr_prod.to_dense()).unwrap();

            // SpAMM side: τ matched to the same error level.
            let tau = match_tau(&a, &b, &exact, csr_err, lonum);
            let mut speedups = Vec::new();
            let mut spamm_err = 0.0;
            let mut ratio = 0.0;
            for devices in [1usize, 2, 4, 8] {
                let mut cfg = SpammConfig::default();
                cfg.lonum = lonum;
                cfg.devices = devices;
                cfg.sequential_devices = true;
                let coord = Coordinator::new(&bundle, cfg).unwrap();
                coord.multiply(&a, &b, tau).unwrap(); // warm
                let rep = coord.multiply(&a, &b, tau).unwrap();
                if devices == 1 {
                    spamm_err = rep.c.error_fnorm(&exact).unwrap();
                    ratio = rep.valid_ratio;
                }
                // modeled device time (see fig5 bench for rationale)
                let spamm_secs = rep
                    .device_busy
                    .iter()
                    .cloned()
                    .fold(0.0f64, f64::max)
                    .max(1e-12);
                speedups.push(fmt_speedup(csr_secs / spamm_secs));
            }
            table.row(vec![
                no.to_string(),
                format!("{:.2}%", nz * 100.0),
                format!("{:.2}%", ratio * 100.0),
                format!("{csr_err:.1}"),
                format!("{spamm_err:.1}"),
                speedups.join("/"),
            ]);
        }
    }
    table.emit("table3_cusparse");
    println!(
        "(speedups use modeled per-device time; SpGEMM runs single-threaded \
         like single-GPU cusparseScsrgemm; conversion time excluded per §4.1)"
    );
}
