//! Table 1 reproduction: τ found by the §3.5.2 search for each
//! (valid-ratio, N) cell on synthesized algebraic-decay matrices
//! (a_ij = 0.1/(|i−j|^0.1 + 1)), ≤20 tuner iterations, <1% ratio error.
//!
//! Absolute τ values differ from the paper's (different random draws and
//! testbed sizes); the *shape* that must hold: τ decreases with N at fixed
//! ratio, increases as the ratio target falls, and every cell is reached
//! within the iteration/error budget.

use cuspamm::bench_harness::{find_bundle, Table};
use cuspamm::matrix::tiling::PaddedMatrix;
use cuspamm::matrix::Matrix;
use cuspamm::spamm::normmap::normmap;
use cuspamm::spamm::tuner::{tune_tau, TuneParams};

fn main() {
    let bundle = find_bundle();
    let lonum = 128usize;
    let sizes: Vec<usize> = bundle
        .dense_sizes()
        .into_iter()
        .filter(|n| n % lonum == 0)
        .collect();
    let ratios = [0.30, 0.25, 0.20, 0.15, 0.10, 0.05];

    let mut headers = vec!["valid ratio \\ N".to_string()];
    headers.extend(sizes.iter().map(|n| n.to_string()));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(
        "Table 1 — τ per (valid ratio, N), algebraic decay 0.1/(|i−j|^0.1+1)",
        &hdr_refs,
    );
    let mut err_table = Table::new(
        "Table 1b — achieved ratio error (paper bound: <1%) and iterations",
        &hdr_refs,
    );

    // Precompute normmaps once per size.
    let normmaps: Vec<_> = sizes
        .iter()
        .map(|&n| {
            let a = Matrix::decay_algebraic(n, 0.1, 0.1, 7);
            let b = Matrix::decay_algebraic(n, 0.1, 0.1, 8);
            (
                normmap(&PaddedMatrix::new(&a, lonum)),
                normmap(&PaddedMatrix::new(&b, lonum)),
            )
        })
        .collect();

    for &ratio in &ratios {
        let mut row = vec![format!("≈{:.0}%", ratio * 100.0)];
        let mut erow = vec![format!("≈{:.0}%", ratio * 100.0)];
        for (na, nb) in &normmaps {
            let r = tune_tau(na, nb, ratio, TuneParams { max_iters: 20, tolerance: 0.0 })
                .expect("tune");
            row.push(format!("{:.6}", r.tau));
            erow.push(format!(
                "{:+.2}% ({} it)",
                (r.achieved_ratio - ratio) * 100.0,
                r.iters
            ));
        }
        table.row(row);
        err_table.row(erow);
    }
    table.emit("table1_tau");
    err_table.emit("table1_error");
}
