//! Benchmark harness substrate (criterion is not in the offline crate
//! set): warmup + repeated timing, summary statistics, the markdown /
//! CSV table renderers the paper-table benches use, and the
//! machine-readable `BENCH_<suite>.json` records CI diffs against
//! committed baselines.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::error::{Error, Result};
use crate::json::Value;
use crate::runtime::ArtifactBundle;
use crate::util::stats::Summary;

/// Locate the artifact bundle from a bench/test binary regardless of CWD
/// (workspace root vs package dir); honors CUSPAMM_ARTIFACTS.
pub fn find_bundle() -> ArtifactBundle {
    let candidates = [
        std::env::var("CUSPAMM_ARTIFACTS").unwrap_or_default(),
        "artifacts".to_string(),
        "../artifacts".to_string(),
    ];
    for c in candidates.iter().filter(|c| !c.is_empty()) {
        if std::path::Path::new(c).join("manifest.json").exists() {
            return ArtifactBundle::load(c).expect("manifest parse");
        }
    }
    panic!("artifact bundle not found — run `make artifacts` first");
}

/// Timing policy.
#[derive(Clone, Copy, Debug)]
pub struct Policy {
    pub warmup: usize,
    pub reps: usize,
}

impl Default for Policy {
    fn default() -> Self {
        // Each measured op here is macroscopic (ms–s), so few reps suffice.
        Policy { warmup: 1, reps: 3 }
    }
}

impl Policy {
    /// Honors CUSPAMM_BENCH_REPS / CUSPAMM_BENCH_WARMUP for quick CI runs.
    pub fn from_env() -> Policy {
        let mut p = Policy::default();
        if let Ok(v) = std::env::var("CUSPAMM_BENCH_REPS") {
            if let Ok(n) = v.parse() {
                p.reps = n;
            }
        }
        if let Ok(v) = std::env::var("CUSPAMM_BENCH_WARMUP") {
            if let Ok(n) = v.parse() {
                p.warmup = n;
            }
        }
        p
    }
}

/// Time `f` under the policy; returns per-rep seconds.
pub fn time_fn<F: FnMut()>(policy: Policy, mut f: F) -> Summary {
    for _ in 0..policy.warmup {
        f();
    }
    let mut samples = Vec::with_capacity(policy.reps.max(1));
    for _ in 0..policy.reps.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    Summary::from(&samples)
}

/// A rendered results table (markdown + CSV).
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells);
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let render = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:w$} |", c, w = widths[i]));
            }
            line
        };
        let mut out = format!("\n### {}\n\n", self.title);
        out.push_str(&render(&self.headers));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&render(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print to stdout and persist markdown+CSV under bench_results/.
    pub fn emit(&self, slug: &str) {
        println!("{}", self.to_markdown());
        let dir = std::path::Path::new("bench_results");
        if std::fs::create_dir_all(dir).is_ok() {
            let _ = std::fs::write(dir.join(format!("{slug}.md")), self.to_markdown());
            let _ = std::fs::write(dir.join(format!("{slug}.csv")), self.to_csv());
        }
    }
}

/// One machine-readable benchmark record, written as
/// `BENCH_<name>.json`.  Two sections with different contracts:
///
/// * `deterministic` — counts and exact figures (product totals, format
///   mixes, cache hit counts) that must reproduce bit-for-bit on any
///   machine.  CI regenerates the record and diffs this section against
///   the committed baseline; a drift is a behavior change someone must
///   either fix or re-baseline deliberately.
/// * `info` — timings and machine-dependent figures, recorded for eyes
///   only and never compared.
///
/// The baseline diff is subset-based: every key present in the baseline's
/// `deterministic` object must match the regenerated value, so a baseline
/// may pin fewer fields than the generator emits (and grow over time).
pub struct BenchRecord {
    pub name: String,
    deterministic: BTreeMap<String, f64>,
    info: BTreeMap<String, f64>,
}

impl BenchRecord {
    pub fn new(name: &str) -> BenchRecord {
        BenchRecord {
            name: name.to_string(),
            deterministic: BTreeMap::new(),
            info: BTreeMap::new(),
        }
    }

    /// Add a deterministic (CI-diffed) field.
    pub fn det(&mut self, key: &str, value: f64) -> &mut Self {
        self.deterministic.insert(key.to_string(), value);
        self
    }

    /// Add an informational (never-diffed) field.
    pub fn info(&mut self, key: &str, value: f64) -> &mut Self {
        self.info.insert(key.to_string(), value);
        self
    }

    pub fn to_value(&self) -> Value {
        let section = |m: &BTreeMap<String, f64>| {
            Value::Object(
                m.iter()
                    .map(|(k, v)| (k.clone(), Value::Number(*v)))
                    .collect(),
            )
        };
        let mut top = BTreeMap::new();
        top.insert("bench".to_string(), Value::String(self.name.clone()));
        top.insert("deterministic".to_string(), section(&self.deterministic));
        top.insert("info".to_string(), section(&self.info));
        Value::Object(top)
    }

    /// Write `BENCH_<name>.json` into `dir`, returning the path.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .map_err(|e| Error::Config(format!("bench out dir {}: {e}", dir.display())))?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_value().to_json())
            .map_err(|e| Error::Config(format!("write {}: {e}", path.display())))?;
        Ok(path)
    }

    /// Diff this record's deterministic section against a committed
    /// baseline file.  Returns the list of mismatches (empty = pass);
    /// keys only in the regenerated record are fine, keys only in the
    /// baseline are failures (the pinned behavior disappeared).
    pub fn check_against(&self, baseline: &Path) -> Result<Vec<String>> {
        let text = std::fs::read_to_string(baseline)
            .map_err(|e| Error::Config(format!("baseline {}: {e}", baseline.display())))?;
        let doc = Value::parse(&text)?;
        let pinned = doc.get("deterministic")?.as_object()?;
        let mut mismatches = Vec::new();
        for (key, want) in pinned {
            let want = want.as_f64()?;
            match self.deterministic.get(key) {
                Some(&got) if got == want => {}
                Some(&got) => mismatches.push(format!(
                    "{}: {key} = {got} (baseline pins {want})",
                    self.name
                )),
                None => mismatches.push(format!(
                    "{}: {key} missing (baseline pins {want})",
                    self.name
                )),
            }
        }
        Ok(mismatches)
    }

    /// Compare this record's `info` timings against a baseline's and
    /// return *warnings* for gross slowdowns.  Timings are
    /// machine-dependent, so this is deliberately loose — only a
    /// `_secs` field both at least [`TREND_FLOOR_SECS`] and more than
    /// [`TREND_RATIO`]× the baseline is flagged — and deliberately
    /// non-failing: the caller prints the warnings, it does not gate on
    /// them.  A baseline without an `info` section (or with non-timing
    /// keys only) yields no warnings.
    pub fn timing_trends_against(&self, baseline: &Path) -> Result<Vec<String>> {
        let text = std::fs::read_to_string(baseline)
            .map_err(|e| Error::Config(format!("baseline {}: {e}", baseline.display())))?;
        let doc = Value::parse(&text)?;
        let Ok(pinned) = doc.get("info").and_then(|v| v.as_object()) else {
            return Ok(Vec::new());
        };
        let mut warnings = Vec::new();
        for (key, was) in pinned {
            if !key.ends_with("_secs") {
                continue;
            }
            let Ok(was) = was.as_f64() else { continue };
            let Some(&now) = self.info.get(key) else {
                continue;
            };
            if now >= TREND_FLOOR_SECS && was > 0.0 && now > was * TREND_RATIO {
                warnings.push(format!(
                    "{}: {key} = {now:.4}s vs baseline {was:.4}s (>{TREND_RATIO}x; \
                     timings are informational — not a failure)",
                    self.name
                ));
            }
        }
        Ok(warnings)
    }
}

/// Slowdown ratio above which [`BenchRecord::timing_trends_against`]
/// warns.  Generous on purpose: CI machines vary wildly, and the check
/// exists to catch order-of-magnitude regressions, not jitter.
pub const TREND_RATIO: f64 = 3.0;

/// Absolute floor below which timings are never trend-checked — a 1 ms
/// op tripling is noise, not a trend.
pub const TREND_FLOOR_SECS: f64 = 0.05;

/// Format seconds for tables (μs/ms/s autoscale).
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

/// Format a speedup ratio like the paper's tables ("13.4").
pub fn fmt_speedup(x: f64) -> String {
    format!("{x:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_runs_and_reports() {
        let mut count = 0usize;
        let s = time_fn(Policy { warmup: 2, reps: 5 }, || {
            count += 1;
        });
        assert_eq!(count, 7);
        assert_eq!(s.n, 5);
        assert!(s.min >= 0.0);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "x".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| 1 | x |"));
        let csv = t.to_csv();
        assert_eq!(csv, "a,b\n1,x\n");
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["x,y".into()]);
        assert_eq!(t.to_csv(), "a\n\"x,y\"\n");
    }

    #[test]
    fn bench_record_round_trips_and_diffs() {
        let dir = std::env::temp_dir().join(format!("cuspamm_benchjson_{}", std::process::id()));
        let mut r = BenchRecord::new("unit");
        r.det("products", 64.0).det("dense", 0.0);
        r.info("wall_secs", 0.123);
        let path = r.write(&dir).unwrap();
        assert!(path.ends_with("BENCH_unit.json"));
        // Same record vs its own emission: clean.
        assert!(r.check_against(&path).unwrap().is_empty());
        // Baseline pinning a different value: flagged.
        std::fs::write(
            &path,
            r#"{"bench":"unit","deterministic":{"products":65,"gone":1},"info":{}}"#,
        )
        .unwrap();
        let bad = r.check_against(&path).unwrap();
        assert_eq!(bad.len(), 2, "{bad:?}");
        // Subset semantics: a baseline pinning fewer keys still passes.
        std::fs::write(
            &path,
            r#"{"bench":"unit","deterministic":{"dense":0},"info":{}}"#,
        )
        .unwrap();
        assert!(r.check_against(&path).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn timing_trends_warn_only_on_gross_slowdowns() {
        let dir = std::env::temp_dir().join(format!("cuspamm_benchtrend_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_trend.json");
        std::fs::write(
            &path,
            r#"{"bench":"trend","deterministic":{},
               "info":{"warm_secs":0.1,"tiny_secs":0.001,"count":5}}"#,
        )
        .unwrap();
        let mut r = BenchRecord::new("trend");
        // Gross slowdown above the floor: warned.
        r.info("warm_secs", 0.5);
        // Tiny op tripling: below the floor, ignored.
        r.info("tiny_secs", 0.004);
        // Non-timing key: ignored even if it grew.
        r.info("count", 50.0);
        let w = r.timing_trends_against(&path).unwrap();
        assert_eq!(w.len(), 1, "{w:?}");
        assert!(w[0].contains("warm_secs"), "{w:?}");
        // Within tolerance: silent.
        let mut ok = BenchRecord::new("trend");
        ok.info("warm_secs", 0.2);
        assert!(ok.timing_trends_against(&path).unwrap().is_empty());
        // Baseline without an info section: silent.
        std::fs::write(&path, r#"{"bench":"trend","deterministic":{}}"#).unwrap();
        assert!(r.timing_trends_against(&path).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_secs(0.5e-4), "50.0us");
        assert_eq!(fmt_secs(0.5), "500.00ms");
        assert_eq!(fmt_secs(2.0), "2.000s");
        assert_eq!(fmt_speedup(13.44), "13.4");
    }
}
