//! Binary tensor loader — Rust twin of python/compile/tensorio.py.
//!
//! Format: b"CSTN" | u32 version | u32 dtype (0=f32, 1=i32) | u32 ndim |
//! ndim×u32 dims | little-endian payload.

use std::io::Read;
use std::path::Path;

use crate::error::{Error, Result};

/// A loaded tensor: shape + flat data.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn dims(&self) -> &[usize] {
        match self {
            Tensor::F32 { dims, .. } | Tensor::I32 { dims, .. } => dims,
        }
    }

    pub fn as_f32(&self) -> Result<(&[usize], &[f32])> {
        match self {
            Tensor::F32 { dims, data } => Ok((dims, data)),
            _ => Err(Error::TensorIo("expected f32 tensor".into())),
        }
    }

    pub fn as_i32(&self) -> Result<(&[usize], &[i32])> {
        match self {
            Tensor::I32 { dims, data } => Ok((dims, data)),
            _ => Err(Error::TensorIo("expected i32 tensor".into())),
        }
    }
}

fn read_u32(r: &mut impl Read, what: &str) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)
        .map_err(|e| Error::TensorIo(format!("reading {what}: {e}")))?;
    Ok(u32::from_le_bytes(b))
}

/// Load a `.cstn` tensor file.
pub fn load_tensor(path: &Path) -> Result<Tensor> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| Error::TensorIo(format!("{}: {e}", path.display())))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)
        .map_err(|e| Error::TensorIo(format!("{}: {e}", path.display())))?;
    if &magic != b"CSTN" {
        return Err(Error::TensorIo(format!("{}: bad magic", path.display())));
    }
    let version = read_u32(&mut f, "version")?;
    if version != 1 {
        return Err(Error::TensorIo(format!("unsupported version {version}")));
    }
    let dtype = read_u32(&mut f, "dtype")?;
    let ndim = read_u32(&mut f, "ndim")? as usize;
    if ndim > 8 {
        return Err(Error::TensorIo(format!("implausible ndim {ndim}")));
    }
    let mut dims = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        dims.push(read_u32(&mut f, "dim")? as usize);
    }
    let count: usize = dims.iter().product::<usize>().max(1);
    let mut payload = Vec::new();
    f.read_to_end(&mut payload)?;
    if payload.len() != count * 4 {
        return Err(Error::TensorIo(format!(
            "{}: payload {} bytes, want {}",
            path.display(),
            payload.len(),
            count * 4
        )));
    }
    match dtype {
        0 => {
            let data = payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Ok(Tensor::F32 { dims, data })
        }
        1 => {
            let data = payload
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Ok(Tensor::I32 { dims, data })
        }
        _ => Err(Error::TensorIo(format!("unknown dtype id {dtype}"))),
    }
}

/// One encoding of the CSTN header (magic | version | dtype | ndim |
/// dims) shared by both writers — and, implicitly, the loader above.
fn header(dtype: u32, dims: &[usize], payload_len: usize) -> Result<Vec<u8>> {
    if dims.iter().product::<usize>().max(1) != payload_len.max(1) {
        return Err(Error::TensorIo("dims/product mismatch".into()));
    }
    let mut out = Vec::with_capacity(16 + 4 * dims.len() + 4 * payload_len);
    out.extend_from_slice(b"CSTN");
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&dtype.to_le_bytes());
    out.extend_from_slice(&(dims.len() as u32).to_le_bytes());
    for &d in dims {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    Ok(out)
}

/// Save a f32 tensor (test fixtures / results), dtype id 0.
pub fn save_tensor_f32(path: &Path, dims: &[usize], data: &[f32]) -> Result<()> {
    let mut out = header(0, dims, data.len())?;
    for &x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Save an i32 tensor (labels of frozen fixtures), dtype id 1.
pub fn save_tensor_i32(path: &Path, dims: &[usize], data: &[i32]) -> Result<()> {
    let mut out = header(1, dims, data.len())?;
    for &x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let dir = std::env::temp_dir().join("cuspamm_tensorio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.cstn");
        let data: Vec<f32> = (0..24).map(|i| i as f32 * 0.5).collect();
        save_tensor_f32(&p, &[2, 3, 4], &data).unwrap();
        let t = load_tensor(&p).unwrap();
        let (dims, got) = t.as_f32().unwrap();
        assert_eq!(dims, &[2, 3, 4]);
        assert_eq!(got, &data[..]);
    }

    #[test]
    fn roundtrip_i32() {
        let dir = std::env::temp_dir().join("cuspamm_tensorio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("labels.cstn");
        let data: Vec<i32> = vec![3, -1, 0, 7];
        save_tensor_i32(&p, &[4], &data).unwrap();
        let t = load_tensor(&p).unwrap();
        let (dims, got) = t.as_i32().unwrap();
        assert_eq!(dims, &[4]);
        assert_eq!(got, &data[..]);
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("cuspamm_tensorio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.cstn");
        std::fs::write(&p, b"NOPE\0\0\0\0\0\0\0\0\0\0\0\0").unwrap();
        assert!(load_tensor(&p).is_err());
    }

    #[test]
    fn truncated_payload_rejected() {
        let dir = std::env::temp_dir().join("cuspamm_tensorio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("trunc.cstn");
        save_tensor_f32(&p, &[4], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 4]).unwrap();
        assert!(load_tensor(&p).is_err());
    }
}
