//! im2col — convolution as GEMM, the transform the paper applies to VGG13
//! (§4.3.2).  Mirrors python/compile/cnn.py's `im2col` exactly (3×3 kernel,
//! pad 1, stride 1, NCHW) so Rust inference reproduces the trained model.

use super::Matrix;
use crate::error::{Error, Result};

/// NCHW activation tensor.
#[derive(Clone, Debug)]
pub struct Tensor4 {
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub data: Vec<f32>,
}

impl Tensor4 {
    pub fn zeros(n: usize, c: usize, h: usize, w: usize) -> Tensor4 {
        Tensor4 {
            n,
            c,
            h,
            w,
            data: vec![0.0; n * c * h * w],
        }
    }

    pub fn from_vec(n: usize, c: usize, h: usize, w: usize, data: Vec<f32>) -> Result<Tensor4> {
        if data.len() != n * c * h * w {
            return Err(Error::Shape(format!(
                "tensor {n}x{c}x{h}x{w} needs {} elems, got {}",
                n * c * h * w,
                data.len()
            )));
        }
        Ok(Tensor4 { n, c, h, w, data })
    }

    #[inline]
    pub fn at(&self, ni: usize, ci: usize, hi: usize, wi: usize) -> f32 {
        self.data[((ni * self.c + ci) * self.h + hi) * self.w + wi]
    }

    #[inline]
    pub fn at_mut(&mut self, ni: usize, ci: usize, hi: usize, wi: usize) -> &mut f32 {
        &mut self.data[((ni * self.c + ci) * self.h + hi) * self.w + wi]
    }

    /// Fraction of exact zeros — the near-sparsity the paper exploits.
    pub fn zero_ratio(&self) -> f64 {
        let z = self.data.iter().filter(|&&x| x == 0.0).count();
        z as f64 / self.data.len().max(1) as f64
    }
}

/// im2col for 3×3/pad-1/stride-1: output is (C·9, N·H·W), laid out to match
/// cnn.py: row index = c·9 + (dy·3 + dx), col index = n·(H·W) + y·W + x.
pub fn im2col(x: &Tensor4) -> Matrix {
    let (n, c, h, w) = (x.n, x.c, x.h, x.w);
    let rows = c * 9;
    let cols = n * h * w;
    let mut out = Matrix::zeros(rows, cols);
    for ci in 0..c {
        for dy in 0..3usize {
            for dx in 0..3usize {
                let row = ci * 9 + dy * 3 + dx;
                let orow = &mut out.data_mut()[row * cols..(row + 1) * cols];
                for ni in 0..n {
                    for y in 0..h {
                        let sy = y as isize + dy as isize - 1;
                        if sy < 0 || sy >= h as isize {
                            continue; // padded row → stays zero
                        }
                        for xx in 0..w {
                            let sx = xx as isize + dx as isize - 1;
                            if sx < 0 || sx >= w as isize {
                                continue;
                            }
                            orow[ni * h * w + y * w + xx] =
                                x.at(ni, ci, sy as usize, sx as usize);
                        }
                    }
                }
            }
        }
    }
    out
}

/// col2im inverse mapping of the *output* of a conv GEMM: reshape
/// (C_out, N·H·W) back to NCHW.
pub fn gemm_out_to_nchw(out: &Matrix, n: usize, h: usize, w: usize) -> Tensor4 {
    let c_out = out.rows();
    let mut t = Tensor4::zeros(n, c_out, h, w);
    for co in 0..c_out {
        let row = out.row(co);
        for ni in 0..n {
            for y in 0..h {
                for x in 0..w {
                    *t.at_mut(ni, co, y, x) = row[ni * h * w + y * w + x];
                }
            }
        }
    }
    t
}

/// 2×2 max-pool, stride 2 (NCHW).
pub fn maxpool2(x: &Tensor4) -> Tensor4 {
    let (n, c, h, w) = (x.n, x.c, x.h, x.w);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = Tensor4::zeros(n, c, oh, ow);
    for ni in 0..n {
        for ci in 0..c {
            for y in 0..oh {
                for xx in 0..ow {
                    let m = x
                        .at(ni, ci, 2 * y, 2 * xx)
                        .max(x.at(ni, ci, 2 * y, 2 * xx + 1))
                        .max(x.at(ni, ci, 2 * y + 1, 2 * xx))
                        .max(x.at(ni, ci, 2 * y + 1, 2 * xx + 1));
                    *out.at_mut(ni, ci, y, xx) = m;
                }
            }
        }
    }
    out
}

/// In-place ReLU.
pub fn relu(x: &mut Tensor4) {
    for v in &mut x.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn im2col_shape() {
        let x = Tensor4::zeros(4, 8, 16, 16);
        let m = im2col(&x);
        assert_eq!((m.rows(), m.cols()), (72, 4 * 256));
    }

    #[test]
    fn im2col_center_tap_is_identity() {
        // dy=1, dx=1 (row c·9+4) is the un-shifted image.
        let mut x = Tensor4::zeros(1, 1, 4, 4);
        for i in 0..16 {
            x.data[i] = i as f32;
        }
        let m = im2col(&x);
        assert_eq!(m.row(4), &x.data[..]);
    }

    #[test]
    fn im2col_conv_equals_direct_conv() {
        // Convolve with a known kernel both ways.
        let mut x = Tensor4::zeros(2, 2, 5, 5);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i * 7919) % 13) as f32 - 6.0;
        }
        let mut w = Matrix::zeros(3, 18); // 3 out-channels, 2 in × 9 taps
        for (i, v) in w.data_mut().iter_mut().enumerate() {
            *v = ((i * 104729) % 11) as f32 / 11.0 - 0.5;
        }
        let cols = im2col(&x);
        let out = w.matmul(&cols).unwrap();
        let out_t = gemm_out_to_nchw(&out, 2, 5, 5);
        // direct conv at a few probe points
        for &(ni, co, y, xx) in &[(0usize, 0usize, 0usize, 0usize), (1, 2, 2, 3), (0, 1, 4, 4)] {
            let mut want = 0.0f32;
            for ci in 0..2 {
                for dy in 0..3isize {
                    for dx in 0..3isize {
                        let sy = y as isize + dy - 1;
                        let sx = xx as isize + dx - 1;
                        if sy < 0 || sy >= 5 || sx < 0 || sx >= 5 {
                            continue;
                        }
                        want += w[(co, ci * 9 + (dy * 3 + dx) as usize)]
                            * x.at(ni, ci, sy as usize, sx as usize);
                    }
                }
            }
            let got = out_t.at(ni, co, y, xx);
            assert!((got - want).abs() < 1e-4, "({ni},{co},{y},{xx}) {got} vs {want}");
        }
    }

    #[test]
    fn maxpool_known() {
        let mut x = Tensor4::zeros(1, 1, 4, 4);
        for i in 0..16 {
            x.data[i] = i as f32;
        }
        let p = maxpool2(&x);
        assert_eq!(p.data, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn relu_zeroes_negatives() {
        let mut x = Tensor4::from_vec(1, 1, 1, 4, vec![-1.0, 2.0, -3.0, 0.0]).unwrap();
        relu(&mut x);
        assert_eq!(x.data, vec![0.0, 2.0, 0.0, 0.0]);
        assert!((x.zero_ratio() - 0.75).abs() < 1e-12);
    }
}
