//! Ergo-like matrices for the §4.3.1 case study.
//!
//! The paper derives four exponential-decay matrices (13,656², F-norms
//! 755 / 10,406 / 3.17e6 / 1.72e7) from the ergo electronic-structure code
//! on a water-cluster geometry, then benchmarks matrix *powers* under τ
//! sweeps.  Neither ergo nor the XYZ dataset is available here, so we
//! synthesize exponential-decay matrices whose F-norms match the paper's
//! four (DESIGN.md §2): the Table 4 / Fig 6 phenomenology depends only on
//! the decay profile and the norm magnitude relative to τ.

use super::decay::DecayKind;
use super::Matrix;

/// The paper's four matrices: (id, target ‖·‖_F, decay rate λ).
///
/// λ is calibrated so the *tile norm-product spectrum* spans the paper's
/// τ grid (1e-10 … 1e-2) at this testbed's N (~1k) AND the τ sweep cuts a
/// meaningful fraction of the schedule (valid ratio ~55 % → ~30 % across
/// the grid, like the paper's 13,656² matrices where most tile products
/// are skippable).  Too slow a decay makes the schedule τ-independent
/// (all products ≫ 1e-2); too fast underflows every off-diagonal tile to
/// exactly 0 (also τ-independent).  λ ∈ [0.87, 0.90] at N=1,024/L=128 is
/// the calibrated band (probe: DESIGN.md §Perf item 6).
pub const ERGO_SPECS: [(usize, f64, f64); 4] = [
    (1, 755.0, 0.90),
    (2, 10_406.0, 0.89),
    (3, 3_169_858.0, 0.88),
    (4, 17_171_990.0, 0.87),
];

/// Generate ergo-like matrix `no` (1-based, per Table 4) at size n.
///
/// The matrix is exponential-decay with unit amplitude, then globally
/// rescaled so its F-norm equals the paper's value for that matrix.
pub fn ergo_matrix(no: usize, n: usize, seed: u64) -> Matrix {
    let (_, target_norm, lambda) = ERGO_SPECS
        .iter()
        .copied()
        .find(|(id, _, _)| *id == no)
        .unwrap_or_else(|| panic!("ergo matrix no. must be 1..=4, got {no}"));
    let mut m = super::decay::generate(
        n,
        DecayKind::Exponential { c: 1.0, lambda },
        seed.wrapping_add(no as u64),
    );
    let norm = m.fnorm();
    m.scale((target_norm / norm) as f32);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms_match_table4() {
        for (no, target, _) in ERGO_SPECS {
            let m = ergo_matrix(no, 256, 42);
            let rel = (m.fnorm() - target).abs() / target;
            assert!(rel < 1e-4, "matrix {no}: fnorm {} vs {target}", m.fnorm());
        }
    }

    #[test]
    fn still_decays_after_scaling() {
        use crate::matrix::tiling::PaddedMatrix;
        use crate::spamm::normmap::normmap;
        // Tile norms must fall monotonically away from the diagonal and
        // the far corner must sit orders of magnitude below the diagonal.
        let m = ergo_matrix(4, 512, 42);
        let nm = normmap(&PaddedMatrix::new(&m, 128));
        assert!(nm[(0, 0)] > 10.0 * nm[(0, 3)], "{} vs {}", nm[(0, 0)], nm[(0, 3)]);
        assert!(nm[(0, 1)] > nm[(0, 2)]);
        assert!(nm[(0, 2)] > nm[(0, 3)]);
    }

    #[test]
    fn tile_product_spectrum_spans_tau_grid() {
        // The Table 4 experiment needs norm products both above 1e-2 and
        // below 1e-10 relative — i.e. the τ sweep must actually change
        // the schedule for every matrix.
        use crate::matrix::tiling::PaddedMatrix;
        use crate::spamm::normmap::normmap;
        use crate::spamm::schedule::Schedule;
        for (no, _, _) in ERGO_SPECS {
            let m = ergo_matrix(no, 1024, 42);
            let nm = normmap(&PaddedMatrix::new(&m, 128));
            let lo = Schedule::build(&nm, &nm, 1e-10).unwrap().valid_products();
            let hi = Schedule::build(&nm, &nm, 1e-2).unwrap().valid_products();
            assert!(
                hi < lo,
                "matrix {no}: τ sweep does not change the schedule ({lo} vs {hi})"
            );
        }
    }

    #[test]
    #[should_panic]
    fn bad_matrix_number_panics() {
        ergo_matrix(5, 64, 0);
    }
}
