//! Dense row-major f32 matrices plus the dataset generators the paper's
//! evaluation needs (decay matrices, ergo-like matrices, im2col).

pub mod decay;
pub mod ergo;
pub mod im2col;
pub mod tensorio;
pub mod tiling;

use crate::error::{Error, Result};
use crate::util::prng::Rng;

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Matrix> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "{rows}x{cols} needs {} elements, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Matrix with i.i.d. standard-normal entries (deterministic per seed).
    pub fn randn(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Matrix { rows, cols, data }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Frobenius norm (f64 accumulation).
    pub fn fnorm(&self) -> f64 {
        self.data
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// ‖self − other‖_F — the paper's error criterion (Eq. 5).
    pub fn error_fnorm(&self, other: &Matrix) -> Result<f64> {
        if self.rows != other.rows || self.cols != other.cols {
            return Err(Error::Shape(format!(
                "error_fnorm: {}x{} vs {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| {
                let d = (a - b) as f64;
                d * d
            })
            .sum::<f64>()
            .sqrt())
    }

    pub fn max_abs_diff(&self, other: &Matrix) -> Result<f32> {
        if self.rows != other.rows || self.cols != other.cols {
            return Err(Error::Shape("max_abs_diff shape mismatch".into()));
        }
        Ok(self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max))
    }

    /// Fraction of non-zero elements — the paper's *nz ratio*.
    pub fn nz_ratio(&self) -> f64 {
        let nz = self.data.iter().filter(|&&x| x != 0.0).count();
        nz as f64 / self.data.len() as f64
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[(c, r)] = self[(r, c)];
            }
        }
        out
    }

    /// Blocked single-thread host GEMM (f32 accumulate) — correctness
    /// reference and small-matrix fallback; not the benchmarked baseline
    /// (that is the XLA dense artifact).
    pub fn matmul(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.rows {
            return Err(Error::Shape(format!(
                "matmul: {}x{} @ {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        const BS: usize = 64;
        for i0 in (0..m).step_by(BS) {
            for k0 in (0..k).step_by(BS) {
                for j0 in (0..n).step_by(BS) {
                    for i in i0..(i0 + BS).min(m) {
                        for kk in k0..(k0 + BS).min(k) {
                            let a = self.data[i * k + kk];
                            if a == 0.0 {
                                continue;
                            }
                            let brow = &other.data[kk * n..kk * n + n];
                            let crow = &mut out.data[i * n..i * n + n];
                            for j in j0..(j0 + BS).min(n) {
                                crow[j] += a * brow[j];
                            }
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    /// Element-wise scale.
    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    /// Truncate: zero out all entries with |x| < threshold; returns count
    /// of zeroed entries.  This is the paper's `TRUN` preparation for the
    /// cuSPARSE baseline.
    pub fn truncate(&mut self, threshold: f32) -> usize {
        let mut zeroed = 0;
        for x in &mut self.data {
            if x.abs() < threshold && *x != 0.0 {
                *x = 0.0;
                zeroed += 1;
            }
        }
        zeroed
    }

    /// Copy a sub-block into a destination slice (row-major LoNum²).
    pub fn copy_block(&self, r0: usize, c0: usize, size: usize, dst: &mut [f32]) {
        debug_assert!(dst.len() >= size * size);
        debug_assert!(r0 + size <= self.rows && c0 + size <= self.cols);
        for r in 0..size {
            let src = &self.data[(r0 + r) * self.cols + c0..][..size];
            dst[r * size..(r + 1) * size].copy_from_slice(src);
        }
    }

    /// Add a row-major block into position (r0, c0).
    pub fn add_block(&mut self, r0: usize, c0: usize, size: usize, src: &[f32]) {
        debug_assert!(src.len() >= size * size);
        for r in 0..size {
            let dst = &mut self.data[(r0 + r) * self.cols + c0..][..size];
            for (d, s) in dst.iter_mut().zip(&src[r * size..(r + 1) * size]) {
                *d += s;
            }
        }
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;

    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let mut m = Matrix::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
        assert!(Matrix::from_vec(2, 2, vec![0.0; 3]).is_err());
    }

    #[test]
    fn fnorm_known() {
        let m = Matrix::from_vec(2, 2, vec![3.0, 0.0, 0.0, 4.0]).unwrap();
        assert!((m.fnorm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn matmul_identity() {
        let a = Matrix::randn(8, 8, 1);
        let c = a.matmul(&Matrix::eye(8)).unwrap();
        assert!(a.error_fnorm(&c).unwrap() < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]).unwrap();
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rectangular() {
        let a = Matrix::randn(3, 5, 2);
        let b = Matrix::randn(5, 7, 3);
        let c = a.matmul(&b).unwrap();
        assert_eq!((c.rows(), c.cols()), (3, 7));
        // one element by hand
        let want: f32 = (0..5).map(|k| a[(1, k)] * b[(k, 4)]).sum();
        assert!((c[(1, 4)] - want).abs() < 1e-4);
    }

    #[test]
    fn matmul_shape_mismatch() {
        assert!(Matrix::zeros(2, 3).matmul(&Matrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Matrix::randn(4, 6, 5);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn truncate_counts_and_zeroes() {
        let mut m = Matrix::from_vec(1, 4, vec![0.1, -0.01, 0.5, 0.0]).unwrap();
        let z = m.truncate(0.05);
        assert_eq!(z, 1);
        assert_eq!(m.data(), &[0.1, 0.0, 0.5, 0.0]);
        assert!((m.nz_ratio() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn block_copy_add_roundtrip() {
        let a = Matrix::randn(8, 8, 9);
        let mut buf = vec![0.0; 16];
        a.copy_block(4, 4, 4, &mut buf);
        let mut out = Matrix::zeros(8, 8);
        out.add_block(4, 4, 4, &buf);
        out.add_block(4, 4, 4, &buf);
        for r in 0..4 {
            for c in 0..4 {
                assert_eq!(out[(4 + r, 4 + c)], 2.0 * a[(4 + r, 4 + c)]);
            }
        }
        assert_eq!(out[(0, 0)], 0.0);
    }

    #[test]
    fn randn_deterministic() {
        assert_eq!(Matrix::randn(4, 4, 7), Matrix::randn(4, 4, 7));
        assert_ne!(Matrix::randn(4, 4, 7), Matrix::randn(4, 4, 8));
    }
}
