//! Tile-level views of matrices: zero padding to LoNum multiples, tile
//! gather for the coordinator's compacted schedule, and scatter-accumulate
//! of tile products back into C.

use super::Matrix;
use crate::error::{Error, Result};
use crate::util::round_up;

/// A matrix padded to LoNum-multiple dimensions, remembering its logical
/// (unpadded) shape — the paper pads inputs the same way (§3 notation).
#[derive(Clone, Debug)]
pub struct PaddedMatrix {
    pub inner: Matrix,
    pub logical_rows: usize,
    pub logical_cols: usize,
    pub lonum: usize,
}

impl PaddedMatrix {
    pub fn new(m: &Matrix, lonum: usize) -> PaddedMatrix {
        let pr = round_up(m.rows().max(1), lonum);
        let pc = round_up(m.cols().max(1), lonum);
        let mut inner = Matrix::zeros(pr, pc);
        for r in 0..m.rows() {
            inner.data_mut()[r * pc..r * pc + m.cols()].copy_from_slice(m.row(r));
        }
        PaddedMatrix {
            inner,
            logical_rows: m.rows(),
            logical_cols: m.cols(),
            lonum,
        }
    }

    /// Number of tile rows (BDIM_r).
    pub fn tile_rows(&self) -> usize {
        self.inner.rows() / self.lonum
    }

    /// Number of tile cols (BDIM_c).
    pub fn tile_cols(&self) -> usize {
        self.inner.cols() / self.lonum
    }

    /// Copy tile (ti, tj) into `dst` (row-major lonum²).
    pub fn copy_tile(&self, ti: usize, tj: usize, dst: &mut [f32]) {
        self.inner
            .copy_block(ti * self.lonum, tj * self.lonum, self.lonum, dst);
    }

    /// Clone with the listed tiles replaced by the payloads in `data` —
    /// the host-side half of a delta update.  `data` holds one row-major
    /// lonum² block per coordinate, concatenated in the order of `tiles`
    /// (tile-grid coordinates of the *padded* grid).  Untouched tiles are
    /// carried over bitwise, so downstream per-tile derivations (norms,
    /// density, fingerprint streams) of unchanged tiles stay identical.
    pub fn with_patched_tiles(
        &self,
        tiles: &[(usize, usize)],
        data: &[f32],
    ) -> Result<PaddedMatrix> {
        let l = self.lonum;
        let l2 = l * l;
        if data.len() != tiles.len() * l2 {
            return Err(Error::Shape(format!(
                "patch: {} payload floats for {} tiles of {l2} elems",
                data.len(),
                tiles.len()
            )));
        }
        let mut out = self.clone();
        let pc = out.inner.cols();
        for (slot, &(ti, tj)) in tiles.iter().enumerate() {
            if ti >= self.tile_rows() || tj >= self.tile_cols() {
                return Err(Error::Shape(format!(
                    "patch: tile ({ti},{tj}) out of {}x{} grid",
                    self.tile_rows(),
                    self.tile_cols()
                )));
            }
            let src = &data[slot * l2..(slot + 1) * l2];
            for r in 0..l {
                out.inner.data_mut()[(ti * l + r) * pc + tj * l..][..l]
                    .copy_from_slice(&src[r * l..(r + 1) * l]);
            }
        }
        Ok(out)
    }

    /// Crop back to the logical shape.
    pub fn crop(&self) -> Matrix {
        let mut out = Matrix::zeros(self.logical_rows, self.logical_cols);
        let pc = self.inner.cols();
        for r in 0..self.logical_rows {
            out.data_mut()[r * self.logical_cols..(r + 1) * self.logical_cols]
                .copy_from_slice(&self.inner.data()[r * pc..r * pc + self.logical_cols]);
        }
        out
    }
}

/// Gather the listed (row-tile, col-tile) pairs of `m` into a contiguous
/// `(batch, lonum, lonum)` buffer (row-major), zero-padding up to
/// `batch_cap` tiles — the layout the `tilegemm` artifacts expect.
pub fn gather_tiles(
    m: &PaddedMatrix,
    ids: &[(usize, usize)],
    batch_cap: usize,
    out: &mut Vec<f32>,
) -> Result<()> {
    if ids.len() > batch_cap {
        return Err(Error::Shape(format!(
            "gather: {} tiles > batch cap {batch_cap}",
            ids.len()
        )));
    }
    let l2 = m.lonum * m.lonum;
    out.clear();
    out.resize(batch_cap * l2, 0.0);
    for (slot, &(ti, tj)) in ids.iter().enumerate() {
        if ti >= m.tile_rows() || tj >= m.tile_cols() {
            return Err(Error::Shape(format!(
                "gather: tile ({ti},{tj}) out of {}x{} grid",
                m.tile_rows(),
                m.tile_cols()
            )));
        }
        m.copy_tile(ti, tj, &mut out[slot * l2..(slot + 1) * l2]);
    }
    Ok(())
}

/// Scatter-accumulate a `(batch, lonum, lonum)` product buffer into C:
/// `products[slot]` is added at output tile `c_ids[slot]`.
pub fn scatter_accumulate(
    c: &mut PaddedMatrix,
    c_ids: &[(usize, usize)],
    products: &[f32],
) -> Result<()> {
    let l = c.lonum;
    let l2 = l * l;
    if products.len() < c_ids.len() * l2 {
        return Err(Error::Shape(format!(
            "scatter: {} products for {} ids",
            products.len() / l2,
            c_ids.len()
        )));
    }
    for (slot, &(ti, tj)) in c_ids.iter().enumerate() {
        c.inner
            .add_block(ti * l, tj * l, l, &products[slot * l2..(slot + 1) * l2]);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_and_crop_roundtrip() {
        let m = Matrix::randn(33, 65, 1);
        let p = PaddedMatrix::new(&m, 32);
        assert_eq!(p.inner.rows(), 64);
        assert_eq!(p.inner.cols(), 96);
        assert_eq!(p.tile_rows(), 2);
        assert_eq!(p.tile_cols(), 3);
        assert_eq!(p.crop(), m);
    }

    #[test]
    fn padding_is_zero() {
        let m = Matrix::randn(10, 10, 2);
        let p = PaddedMatrix::new(&m, 32);
        // Everything outside 10x10 must be exactly zero.
        for r in 0..32 {
            for c in 0..32 {
                if r >= 10 || c >= 10 {
                    assert_eq!(p.inner[(r, c)], 0.0);
                }
            }
        }
        // padding preserves the F-norm
        assert!((p.inner.fnorm() - m.fnorm()).abs() < 1e-9);
    }

    #[test]
    fn exact_multiple_unchanged() {
        let m = Matrix::randn(64, 64, 3);
        let p = PaddedMatrix::new(&m, 32);
        assert_eq!(p.inner, m);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let m = Matrix::randn(64, 64, 4);
        let p = PaddedMatrix::new(&m, 32);
        let ids = [(0usize, 1usize), (1, 0)];
        let mut buf = Vec::new();
        gather_tiles(&p, &ids, 4, &mut buf).unwrap();
        assert_eq!(buf.len(), 4 * 32 * 32);
        // padded tail is zero
        assert!(buf[2 * 1024..].iter().all(|&x| x == 0.0));

        let mut c = PaddedMatrix::new(&Matrix::zeros(64, 64), 32);
        scatter_accumulate(&mut c, &ids, &buf).unwrap();
        for r in 0..32 {
            for cc in 0..32 {
                assert_eq!(c.inner[(r, 32 + cc)], m[(r, 32 + cc)]);
                assert_eq!(c.inner[(32 + r, cc)], m[(32 + r, cc)]);
                assert_eq!(c.inner[(r, cc)], 0.0);
            }
        }
    }

    #[test]
    fn with_patched_tiles_replaces_only_listed_blocks() {
        let m = Matrix::randn(64, 96, 7);
        let p = PaddedMatrix::new(&m, 32);
        let l2 = 32 * 32;
        let mut payload = vec![0.0f32; 2 * l2];
        payload[..l2].fill(3.5);
        for (i, v) in payload[l2..].iter_mut().enumerate() {
            *v = i as f32;
        }
        let q = p.with_patched_tiles(&[(0, 2), (1, 0)], &payload).unwrap();
        let mut buf = vec![0.0f32; l2];
        q.copy_tile(0, 2, &mut buf);
        assert_eq!(buf, payload[..l2]);
        q.copy_tile(1, 0, &mut buf);
        assert_eq!(buf, payload[l2..]);
        // Every other tile is carried over bitwise.
        let mut orig = vec![0.0f32; l2];
        for ti in 0..p.tile_rows() {
            for tj in 0..p.tile_cols() {
                if (ti, tj) == (0, 2) || (ti, tj) == (1, 0) {
                    continue;
                }
                p.copy_tile(ti, tj, &mut orig);
                q.copy_tile(ti, tj, &mut buf);
                assert_eq!(buf, orig);
            }
        }
        assert_eq!(q.logical_rows, p.logical_rows);
        assert_eq!(q.logical_cols, p.logical_cols);
        // Bad shapes and out-of-grid coordinates are rejected.
        assert!(p.with_patched_tiles(&[(0, 0)], &payload).is_err());
        assert!(p.with_patched_tiles(&[(2, 0)], &payload[..l2]).is_err());
    }

    #[test]
    fn gather_bounds_checked() {
        let p = PaddedMatrix::new(&Matrix::zeros(32, 32), 32);
        let mut buf = Vec::new();
        assert!(gather_tiles(&p, &[(1, 0)], 2, &mut buf).is_err());
        assert!(gather_tiles(&p, &[(0, 0); 5], 4, &mut buf).is_err());
    }
}
