//! Decay matrix generators — the paper's synthesized datasets (§4.1).
//!
//! Algebraic decay: |a_ij| ≤ c / (|i−j|^λ + 1)   (Table 1's dataset uses
//! c = 0.1, λ = 0.1).  Exponential decay: |a_ij| ≤ c·λ^|i−j| (the ergo-like
//! dataset).  Entries are the envelope multiplied by a uniform [−1, 1)
//! variate so the matrices are full-rank and sign-mixed, matching how the
//! paper's matrices behave under the F-norm.

use super::Matrix;
use crate::util::prng::Rng;

/// Decay profile.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DecayKind {
    /// c / (|i−j|^lambda + 1)
    Algebraic { c: f64, lambda: f64 },
    /// c · lambda^|i−j|
    Exponential { c: f64, lambda: f64 },
}

impl DecayKind {
    /// Envelope value at separation d = |i − j|.
    pub fn envelope(&self, d: usize) -> f64 {
        match *self {
            DecayKind::Algebraic { c, lambda } => c / ((d as f64).powf(lambda) + 1.0),
            DecayKind::Exponential { c, lambda } => c * lambda.powi(d as i32),
        }
    }
}

/// Generate an n×n decay matrix (seeded, deterministic).
pub fn generate(n: usize, kind: DecayKind, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut m = Matrix::zeros(n, n);
    // Precompute the envelope per separation (O(n) instead of O(n²) powf).
    let env: Vec<f32> = (0..n).map(|d| kind.envelope(d) as f32).collect();
    for i in 0..n {
        for j in 0..n {
            let d = i.abs_diff(j);
            m[(i, j)] = env[d] * rng.range_f32(-1.0, 1.0);
        }
    }
    m
}

impl Matrix {
    /// The paper's synthesized algebraic-decay matrix
    /// `a_ij = c/(|i−j|^λ + 1) · u`, u ~ U[−1, 1).
    pub fn decay_algebraic(n: usize, c: f64, lambda: f64, seed: u64) -> Matrix {
        generate(n, DecayKind::Algebraic { c, lambda }, seed)
    }

    /// Exponential-decay matrix `a_ij = c·λ^|i−j| · u` (ergo-like).
    pub fn decay_exponential(n: usize, c: f64, lambda: f64, seed: u64) -> Matrix {
        generate(n, DecayKind::Exponential { c, lambda }, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algebraic_envelope_bounds_entries() {
        let n = 64;
        let m = Matrix::decay_algebraic(n, 0.1, 0.1, 3);
        let kind = DecayKind::Algebraic { c: 0.1, lambda: 0.1 };
        for i in 0..n {
            for j in 0..n {
                let bound = kind.envelope(i.abs_diff(j)) as f32 + 1e-7;
                assert!(m[(i, j)].abs() <= bound, "({i},{j})");
            }
        }
    }

    #[test]
    fn exponential_decays_fast() {
        let m = Matrix::decay_exponential(128, 1.0, 0.5, 4);
        // At separation 40 the envelope is 0.5^40 ≈ 9e-13 — visually zero.
        assert!(m[(0, 60)].abs() < 1e-12);
        // Near-diagonal mass dominates.
        let diag_mass: f64 = (0..128).map(|i| (m[(i, i)] as f64).abs()).sum();
        let corner_mass: f64 = (0..64)
            .map(|i| (m[(i, 64 + i)] as f64).abs())
            .sum();
        assert!(diag_mass > 100.0 * corner_mass);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Matrix::decay_algebraic(32, 0.1, 0.1, 7);
        let b = Matrix::decay_algebraic(32, 0.1, 0.1, 7);
        let c = Matrix::decay_algebraic(32, 0.1, 0.1, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn envelope_monotone_in_separation() {
        for kind in [
            DecayKind::Algebraic { c: 0.1, lambda: 0.1 },
            DecayKind::Exponential { c: 1.0, lambda: 0.9 },
        ] {
            let mut prev = f64::INFINITY;
            for d in 0..100 {
                let e = kind.envelope(d);
                assert!(e <= prev);
                prev = e;
            }
        }
    }

    #[test]
    fn algebraic_is_near_sparse_not_sparse() {
        // The algebraic matrices of Table 1 are dense in the strict sense
        // (no exact zeros) but compressible under the F-norm test.
        let m = Matrix::decay_algebraic(128, 0.1, 0.1, 5);
        assert!(m.nz_ratio() > 0.99);
    }
}
