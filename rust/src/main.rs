//! cuspamm CLI — the Layer-3 launcher.
//!
//!   cuspamm info                          list artifacts + platform
//!   cuspamm run   --n 1024 --ratio 0.10   tuned SpAMM vs dense, with stats
//!   cuspamm tune  --n 1024 --ratio 0.10   τ search only (§3.5.2)
//!   cuspamm power --n 512 --k 4 --expr    A^k chain: expression graph vs
//!                                         per-step loop (--smoke for the CI
//!                                         transfer/identity assertion)
//!   cuspamm purify --n 256 --expr         McWeeny purification, same A/B
//!   cuspamm cnn   --tau 2.5 --layer conv2 case-study CNN accuracy probe
//!   cuspamm serve --requests 64           session serving bench (Zipf-hot
//!                                         operands, priorities; --smoke for
//!                                         the CI warm-plan assertion)
//!   cuspamm serve-net --clients 2         network serving tier over the framed
//!                                         TCP protocol: tenant quotas, plan
//!                                         batching, result cache (--smoke for
//!                                         the CI warm/shed/bitwise assertion)
//!   cuspamm update --steps 4              drifting-operand trace: delta
//!                                         updates + schedule repair (--smoke
//!                                         for the CI delta-cost assertion)
//!   cuspamm audit [plan|session|store]    static invariant auditor (--smoke
//!                                         for the CI clean-workloads +
//!                                         seeded-violation assertion)
//!
//! Global options: --artifacts <dir>, --devices, --precision, --balance,
//! --config <file> (key = value overrides, see config::SpammConfig).

use cuspamm::cli::Spec;
use cuspamm::config::SpammConfig;
use cuspamm::coordinator::Coordinator;
use cuspamm::error::{Error, Result};
use cuspamm::matrix::Matrix;
use cuspamm::prelude::*;
use cuspamm::telemetry;

fn main() {
    telemetry::init_logging();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(Error::Config(msg)) => {
            eprintln!("{msg}");
            2
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn common(spec: Spec) -> Spec {
    // Declared option defaults mirror SpammConfig::default() — derived,
    // not hand-synced, so the two default sources cannot drift.
    let d = SpammConfig::default();
    let balance = match d.balance {
        cuspamm::config::Balance::RowBlock => "rowblock".to_string(),
        cuspamm::config::Balance::Strided(s) => format!("strided:{s}"),
        cuspamm::config::Balance::ResidencyAware => "residency-aware".to_string(),
    };
    spec.opt("artifacts", "artifacts", "artifact bundle directory")
        .opt("devices", &d.devices.to_string(), "simulated device count")
        .opt("precision", d.precision.as_str(), "f32 | bf16")
        .opt("balance", &balance, "rowblock | strided:<s> | residency-aware")
        .opt(
            "pipeline-depth",
            &d.pipeline_depth.to_string(),
            "chunks buffered between executor pipeline stages (gather/exec/scatter)",
        )
        .flag(
            "no-cache",
            "disable normmap/schedule caching across multiplies",
        )
        .flag(
            "no-residency",
            "disable the device-resident operand-tile pools",
        )
        .opt(
            "device-mem-budget",
            "256m",
            "per-device resident-tile byte budget (k/m/g suffixes; non-zero while residency is on)",
        )
        .opt(
            "density-threshold",
            &d.density_threshold.to_string(),
            "per-tile format selector in [0, 1]: surviving products whose operand \
             tiles are both below this density run on the sparse/packed path \
             (0 = always dense, bitwise-identical to the classic executor)",
        )
        .opt(
            "store-dir",
            &d.store_dir,
            "content-addressed warm-start store directory (empty = no \
             persistence): normmaps, schedules, tuned τ, and synthesized \
             bundles survive process restarts",
        )
        .flag(
            "no-store",
            "disable the on-disk warm-start store even when --store-dir \
             (or a config file) names one",
        )
        .opt("config", "", "optional config file (key = value)")
}

fn build_config(a: &cuspamm::cli::Args) -> Result<SpammConfig> {
    let mut cfg = if a.get("config").is_empty() {
        SpammConfig::default()
    } else {
        SpammConfig::from_file(std::path::Path::new(a.get("config")))?
    };
    // CLI > config file > built-in defaults: when a config file is in
    // play, only explicitly-passed options override it (the declared CLI
    // defaults mirror SpammConfig::default(), which the file was folded
    // over already).
    let from_file = !a.get("config").is_empty();
    for (opt, key) in [
        ("devices", "devices"),
        ("precision", "precision"),
        ("balance", "balance"),
        ("pipeline-depth", "pipeline_depth"),
        ("device-mem-budget", "device_mem_budget"),
        ("density-threshold", "density_threshold"),
        ("store-dir", "store_dir"),
    ] {
        if a.provided(opt) || !from_file {
            cfg.apply(key, a.get(opt))?;
        }
    }
    if a.flag("no-cache") {
        cfg.cache_enabled = false;
    }
    if a.flag("no-residency") {
        cfg.residency_enabled = false;
    }
    if a.flag("no-store") {
        cfg.store_enabled = false;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { &[][..] } else { &args[1..] };
    match cmd {
        "info" => cmd_info(rest),
        "run" => cmd_run(rest),
        "multiply" => cmd_multiply(rest),
        "tune" => cmd_tune(rest),
        "power" => cmd_power(rest),
        "purify" => cmd_purify(rest),
        "cnn" => cmd_cnn(rest),
        "serve" => cmd_serve(rest),
        "serve-net" => cmd_serve_net(rest),
        "update" => cmd_update(rest),
        "coordinate" => cmd_coordinate(rest),
        "bench" => cmd_bench(rest),
        "store" => cmd_store(rest),
        "warmstart" => cmd_warmstart(rest),
        "audit" => cmd_audit(rest),
        "help" | "--help" | "-h" => {
            println!(
                "cuspamm — SpAMM on an AOT-compiled XLA runtime\n\n\
                 subcommands:\n  info   list the artifact bundle\n  run    \
                 tuned SpAMM vs dense baseline\n  multiply  density-adaptive \
                 tile-format multiply (--smoke for the CI format assertion)\n  \
                 tune   τ search for a valid \
                 ratio\n  power  A^k chain — expression graph vs per-step \
                 loop (--expr/--loop)\n  purify McWeeny purification, same \
                 A/B\n  cnn    case-study CNN accuracy probe\n  serve  \
                 session serving bench: registered operands, prepared plans, \
                 priority queue\n  serve-net  serve the session over the framed \
                 TCP protocol: multi-tenant quotas, plan batching, result \
                 cache (--smoke for the CI warm/shed/bitwise assertion)\n  \
                 update drifting-operand trace: delta \
                 updates with schedule repair (--smoke for the CI \
                 delta-cost assertion)\n  coordinate  multi-device partition bench: \
                 per-device transfer/busy table, residency-aware vs rowblock \
                 (--smoke)\n  bench  machine-readable BENCH_<suite>.json \
                 records (--check diffs deterministic fields vs committed \
                 baselines)\n  store  warm-start store administration: \
                 ls | gc --budget <bytes> | verify [--heal]\n  warmstart  \
                 restart-to-warm demo over a --store-dir (--smoke for the \
                 CI zero-recompute + bitwise-identity assertion)\n  audit  \
                 static invariant auditor: plan | session | store verbs \
                 (--smoke audits every workload class clean and proves \
                 each seeded violation class is detected)\n\nUse \
                 `cuspamm <cmd> --help` for options."
            );
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown subcommand '{other}' (try `cuspamm help`)"
        ))),
    }
}

fn cmd_info(args: &[String]) -> Result<()> {
    let spec = common(Spec::new("cuspamm info", "inspect the artifact bundle"));
    let a = spec.parse(args)?;
    let bundle = ArtifactBundle::load(a.get("artifacts"))?;
    println!("artifact bundle: {}", bundle.dir.display());
    println!("LoNum: {}", bundle.lonum);
    for name in bundle.names() {
        let m = bundle.get(name)?;
        println!("  {:32} kind={:12} inputs={:?}", m.name, m.kind, m.input_shapes);
    }
    if let Some(cnn) = &bundle.cnn {
        println!(
            "cnn: {} conv layers, build-time test accuracy {:.2}%",
            cnn.conv_specs.len(),
            cnn.test_accuracy * 100.0
        );
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let spec = common(Spec::new("cuspamm run", "tuned SpAMM vs the dense baseline"))
        .opt("n", "1024", "matrix size (needs a dense_n<N> artifact)")
        .opt("ratio", "0.10", "target valid ratio")
        .opt("seed", "7", "workload seed")
        .opt("kind", "algebraic", "decay kind: algebraic | exponential");
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let n = a.usize("n")?;
    let ratio = a.f64("ratio")?;
    let seed = a.usize("seed")? as u64;

    let bundle = ArtifactBundle::load(a.get("artifacts"))?;
    let coord = Coordinator::new(&bundle, cfg.clone())?;

    let (ma, mb) = match a.get("kind") {
        "exponential" => (
            Matrix::decay_exponential(n, 1.0, 0.5, seed),
            Matrix::decay_exponential(n, 1.0, 0.5, seed + 1),
        ),
        _ => (
            Matrix::decay_algebraic(n, 0.1, 0.1, seed),
            Matrix::decay_algebraic(n, 0.1, 0.1, seed + 1),
        ),
    };

    let tuned = coord.tune_tau(&ma, &mb, ratio)?;
    println!(
        "tuned τ = {:.6e} (achieved ratio {:.2}%, {} iters, expansion k={})",
        tuned.tau,
        tuned.achieved_ratio * 100.0,
        tuned.iters,
        tuned.expansion_k
    );

    let report = coord.multiply(&ma, &mb, tuned.tau)?;
    println!("spamm: {}", report.summary_line());

    let dense = coord.dense(&ma, &mb)?;
    println!("dense: wall {:.3}s", dense.wall_secs);
    println!(
        "speedup: {:.2}x   ‖E‖_F = {:.4e}  (‖C‖_F = {:.4e})",
        dense.wall_secs / report.wall_secs,
        report.c.error_fnorm(&dense.c)?,
        dense.c.fnorm()
    );
    let t = telemetry::global();
    println!(
        "caches: norm {} hit / {} miss, schedule {} hit / {} miss",
        t.get("spamm.norm_cache.hits"),
        t.get("spamm.norm_cache.misses"),
        t.get("spamm.schedule_cache.hits"),
        t.get("spamm.schedule_cache.misses")
    );
    // All five figures share the same scope: the SpAMM multiply above.
    println!(
        "residency: {} hit / {} miss / {} evicted, {} KiB uploaded, {} KiB saved",
        report.stage.residency_hits,
        report.stage.residency_misses,
        report.stage.residency_evictions,
        report.stage.transfer_bytes / 1024,
        report.stage.transfer_saved_bytes / 1024
    );
    print_format_mix(&report.stage);
    Ok(())
}

/// Per-tile format mix of one multiply (density-adaptive executor).
fn print_format_mix(s: &cuspamm::spamm::executor::MultiplyStats) {
    println!(
        "formats: {} dense / {} sparse / {} packed products ({} sparse dispatches, \
         {} KiB saved vs dense staging)",
        s.dense_products,
        s.sparse_products,
        s.packed_products,
        s.sparse_dispatches,
        s.format_saved_bytes / 1024
    );
}

/// One multiply at an explicit τ with the density-adaptive executor —
/// the format-mix probe (`run` tunes τ from a valid-ratio target; this
/// command takes τ and the density threshold directly).
fn cmd_multiply(args: &[String]) -> Result<()> {
    let spec = common(Spec::new(
        "cuspamm multiply",
        "density-adaptive multiply: per-tile dense/sparse/packed format \
         selection below --density-threshold",
    ))
    .opt("n", "256", "matrix size (tiles of the bundle's LoNum)")
    .opt("tau", "0.0", "SpAMM threshold τ")
    .opt("seed", "7", "workload seed")
    .opt(
        "spikes",
        "8",
        "nonzeros per tile of the scattered-sparse workload (smoke/default \
         workload; high-norm, low-density tiles)",
    )
    .flag(
        "smoke",
        "CI assertion: threshold 0 is bitwise-identical to the default \
         executor; a positive threshold selects sparse/packed formats on a \
         scattered-sparse workload, uploads ≥2x fewer bytes, and agrees with \
         the all-dense result",
    );
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let bundle = load_bundle_or_hostsim(&a)?;
    let n = a.usize("n")?;
    let tau = a.f64("tau")? as f32;
    let seed = a.usize("seed")? as u64;
    let spikes = a.usize("spikes")?;
    let ma = scattered_sparse(n, bundle.lonum, spikes, seed);
    let mb = scattered_sparse(n, bundle.lonum, spikes, seed + 1);
    if a.flag("smoke") {
        return multiply_smoke(&bundle, cfg, &ma, &mb, tau);
    }
    let coord = Coordinator::new(&bundle, cfg.clone())?;
    let rep = coord.multiply(&ma, &mb, tau)?;
    println!(
        "== multiply: n={n} τ={tau:.1e} density-threshold={} ==",
        cfg.density_threshold
    );
    println!("spamm: {}", rep.summary_line());
    print_format_mix(&rep.stage);
    Ok(())
}

/// Scattered-sparse workload: every tile holds `spikes` large entries at
/// seeded random positions — low density but high norm, so τ keeps the
/// products while the density threshold reroutes them off the dense path.
/// (Decay matrices can't exercise this: their low-density tiles are also
/// low-norm, so τ prunes them before format selection matters.)
fn scattered_sparse(n: usize, lonum: usize, spikes: usize, seed: u64) -> Matrix {
    let mut rng = cuspamm::util::prng::Rng::new(seed);
    let mut m = Matrix::zeros(n, n);
    let tiles = n.div_ceil(lonum);
    for ti in 0..tiles {
        for tj in 0..tiles {
            for _ in 0..spikes {
                let i = (ti * lonum + rng.below(lonum)).min(n - 1);
                let j = (tj * lonum + rng.below(lonum)).min(n - 1);
                let mag = rng.range_f32(0.25, 1.0);
                m[(i, j)] = if rng.next_u64() & 1 == 0 { mag } else { -mag };
            }
        }
    }
    m
}

/// CI smoke for `multiply` (`--smoke`): the density-adaptive executor's
/// three headline contracts on a scattered-sparse workload.
fn multiply_smoke(
    bundle: &ArtifactBundle,
    cfg: SpammConfig,
    ma: &Matrix,
    mb: &Matrix,
    tau: f32,
) -> Result<()> {
    const THRESHOLD: f32 = 0.5;

    // 1. Threshold 0 (explicit) is bitwise-identical to the default
    //    config's executor: the adaptive plumbing at 0 must be inert.
    let mut cfg0 = cfg.clone();
    cfg0.density_threshold = 0.0;
    let c0 = Coordinator::new(bundle, cfg0.clone())?;
    let rep0 = c0.multiply(ma, mb, tau)?;
    let cd = Coordinator::new(bundle, SpammConfig::default())?;
    let repd = cd.multiply(ma, mb, tau)?;
    assert_eq!(
        rep0.c.data(),
        repd.c.data(),
        "threshold 0 diverged from the default executor"
    );
    assert_eq!(
        rep0.stage.sparse_products + rep0.stage.packed_products,
        0,
        "threshold 0 must never select a sparse format"
    );

    // 2. A positive threshold selects sparse/packed formats and stages
    //    measurably fewer bytes (packed payloads instead of full tiles).
    let mut cfg1 = cfg;
    cfg1.density_threshold = THRESHOLD;
    let c1 = Coordinator::new(bundle, cfg1)?;
    let rep1 = c1.multiply(ma, mb, tau)?;
    let routed = rep1.stage.sparse_products + rep1.stage.packed_products;
    println!(
        "smoke: threshold {THRESHOLD} routed {routed} of {} products off the dense \
         path ({} sparse dispatches)",
        rep1.stage.valid_products, rep1.stage.sparse_dispatches
    );
    assert!(
        routed > 0,
        "low-density tiles were not routed to the sparse/packed path"
    );
    assert!(
        rep1.stage.sparse_dispatches > 0,
        "sparse products selected but never dispatched"
    );
    println!(
        "smoke: uploaded — all-dense {} KiB, adaptive {} KiB ({} KiB saved vs \
         dense staging)",
        rep0.stage.transfer_bytes / 1024,
        rep1.stage.transfer_bytes / 1024,
        rep1.stage.format_saved_bytes / 1024
    );
    assert!(
        rep1.stage.transfer_bytes * 2 <= rep0.stage.transfer_bytes,
        "adaptive path must upload ≤ half the dense bytes: {} vs {}",
        rep1.stage.transfer_bytes,
        rep0.stage.transfer_bytes
    );
    assert!(
        rep1.stage.format_saved_bytes > 0,
        "packed staging reported no bytes saved"
    );

    // 3. The mixed-format result agrees with the all-dense result: the
    //    sparse path computes the same products exactly, so only the
    //    accumulation order may differ.
    let err = rep1.c.error_fnorm(&rep0.c)?;
    let scale = rep0.c.fnorm().max(1.0);
    assert!(
        err <= 1e-5 * scale,
        "mixed-format result drifted: ‖E‖_F = {err:.3e} vs ‖C‖_F = {scale:.3e}"
    );
    println!(
        "smoke: OK — threshold 0 bitwise-inert, sparse/packed selected with ≥2x \
         fewer uploaded bytes, mixed-format ‖E‖_F = {err:.3e}"
    );
    Ok(())
}

fn cmd_tune(args: &[String]) -> Result<()> {
    let spec = common(Spec::new("cuspamm tune", "τ search (§3.5.2)"))
        .opt("n", "1024", "matrix size")
        .opt("ratio", "0.10", "target valid ratio")
        .opt("seed", "7", "workload seed");
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let bundle = ArtifactBundle::load(a.get("artifacts"))?;
    let coord = Coordinator::new(&bundle, cfg)?;
    let n = a.usize("n")?;
    let ma = Matrix::decay_algebraic(n, 0.1, 0.1, a.usize("seed")? as u64);
    let mb = Matrix::decay_algebraic(n, 0.1, 0.1, a.usize("seed")? as u64 + 1);
    let r = coord.tune_tau(&ma, &mb, a.f64("ratio")?)?;
    println!(
        "τ = {:.6e}  ratio = {:.3}%  iters = {}  expansion k = {}",
        r.tau,
        r.achieved_ratio * 100.0,
        r.iters,
        r.expansion_k
    );
    Ok(())
}

/// Load the artifact bundle, falling back to the synthesized offline
/// hostsim bundle when the default directory is absent (the CI path) —
/// an explicitly passed `--artifacts` must exist.
fn load_bundle_or_hostsim(a: &cuspamm::cli::Args) -> Result<ArtifactBundle> {
    match ArtifactBundle::load(a.get("artifacts")) {
        Ok(b) => Ok(b),
        Err(e) if !a.provided("artifacts") => {
            log::info!("no artifact bundle ({e}); using the offline hostsim bundle");
            cuspamm::runtime::hostsim::find_or_test_bundle()
        }
        Err(e) => Err(e),
    }
}

fn expr_or_loop(a: &cuspamm::cli::Args) -> Result<bool> {
    if a.flag("expr") && a.flag("loop") {
        return Err(Error::Config("pick one of --expr / --loop".into()));
    }
    Ok(a.flag("loop"))
}

fn cmd_power(args: &[String]) -> Result<()> {
    use cuspamm::spamm::power::{spamm_power, spamm_power_loop};

    let spec = common(Spec::new(
        "cuspamm power",
        "A^k power chain — expression graph (device-resident intermediates, \
         propagated norms) vs the legacy one-multiply-per-step loop",
    ))
    .opt("n", "256", "matrix size")
    .opt("k", "4", "power to compute (k ≥ 2 for a chain)")
    .opt("tau", "0.0", "SpAMM threshold τ")
    .opt("seed", "7", "workload seed")
    .flag("expr", "run the expression-graph path (default)")
    .flag("loop", "run the legacy one-multiply-per-step path")
    .flag(
        "smoke",
        "CI assertion: run both paths, assert bitwise identity, ≥2x fewer \
         uploaded bytes on the expr path, and zero host norm recomputes for \
         intermediates",
    );
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let bundle = load_bundle_or_hostsim(&a)?;
    let n = a.usize("n")?;
    let k = a.usize("k")?;
    let tau = a.f64("tau")? as f32;
    let m = Matrix::decay_exponential(n, 1.0, 0.5, a.usize("seed")? as u64);
    if a.flag("smoke") {
        return power_smoke(&bundle, cfg, &m, k, tau);
    }
    let use_loop = expr_or_loop(&a)?;
    let coord = Coordinator::new(&bundle, cfg)?;
    let r = if use_loop {
        spamm_power_loop(&coord, &m, k, tau)?
    } else {
        spamm_power(&coord, &m, k, tau)?
    };
    println!(
        "== A^{k} (n={n}, τ={tau:.1e}) via the {} path ==",
        if use_loop { "loop" } else { "expression" }
    );
    println!("  power   valid%    wall(s)    ‖A^p‖_F");
    for s in &r.steps {
        println!(
            "  {:5}   {:6.2}   {:8.4}   {:.4e}",
            s.power,
            s.valid_ratio * 100.0,
            s.wall_secs,
            s.result_fnorm
        );
    }
    print_pool_transfers(&coord);
    println!(
        "  norm cache: {} hit / {} miss (loop pays one miss per intermediate; \
         expr refreshes norms device-side)",
        coord.caches().norms.hits(),
        coord.caches().norms.misses()
    );
    Ok(())
}

/// Transfer totals aggregated over every device pool (`devices > 1`
/// reports the whole fleet, not just device 0).
fn print_pool_transfers(coord: &Coordinator) {
    let pools = coord.residency_pools();
    if pools.is_empty() {
        return;
    }
    let mut up = 0u64;
    let mut sv = 0u64;
    let mut hits = 0u64;
    let mut misses = 0u64;
    for p in pools {
        let s = p.stats();
        up += s.uploaded_bytes;
        sv += s.saved_bytes;
        hits += s.hits;
        misses += s.misses;
    }
    println!(
        "  transfers ({} device pools): {} KiB uploaded, {} KiB saved ({} hits / {} misses)",
        pools.len(),
        up / 1024,
        sv / 1024,
        hits,
        misses
    );
}

/// CI smoke for `power` (`--smoke`): both paths on fresh coordinators —
/// bitwise identity, the expr path uploads ≤ half the bytes (it never
/// re-uploads intermediates), and its norm cache sees only the leaf.
fn power_smoke(
    bundle: &ArtifactBundle,
    cfg: SpammConfig,
    a: &Matrix,
    k: usize,
    tau: f32,
) -> Result<()> {
    use cuspamm::spamm::power::{spamm_power, spamm_power_loop};

    if !cfg.residency_enabled {
        return Err(Error::Config(
            "power --smoke measures pool transfers; run without --no-residency".into(),
        ));
    }
    if k < 3 {
        return Err(Error::Config(
            "power --smoke needs k ≥ 3 (at least two chained intermediates)".into(),
        ));
    }
    let c_loop = Coordinator::new(bundle, cfg.clone())?;
    let c_expr = Coordinator::new(bundle, cfg)?;
    let looped = spamm_power_loop(&c_loop, a, k, tau)?;
    let expr = spamm_power(&c_expr, a, k, tau)?;
    assert_eq!(
        expr.value.data(),
        looped.value.data(),
        "expression path diverged from the loop path"
    );
    let up_loop = c_loop.residency_pools()[0].stats().uploaded_bytes;
    let up_expr = c_expr.residency_pools()[0].stats().uploaded_bytes;
    println!(
        "smoke: loop uploaded {} KiB, expr uploaded {} KiB ({:.1}x less)",
        up_loop / 1024,
        up_expr / 1024,
        up_loop as f64 / up_expr.max(1) as f64
    );
    assert!(
        up_expr * 2 <= up_loop,
        "expr path must upload ≤ half the loop's bytes: {up_expr} vs {up_loop}"
    );
    let miss = c_expr.caches().norms.misses();
    assert!(
        miss <= 1,
        "expr path host-recomputed intermediate normmaps ({miss} misses; only \
         the leaf may miss)"
    );
    println!(
        "smoke: OK — bitwise identical to the loop, ≥2x fewer uploaded bytes, \
         intermediate norms never recomputed on host"
    );
    Ok(())
}

fn cmd_purify(args: &[String]) -> Result<()> {
    use cuspamm::spamm::purification::{initial_density, mcweeny_purify, mcweeny_purify_loop};

    let spec = common(Spec::new(
        "cuspamm purify",
        "McWeeny purification P ← 3P²−2P³ — expression graph (resident \
         iterate, device-side combine) vs the per-multiply loop",
    ))
    .opt("n", "256", "matrix size")
    .opt("tau", "1e-6", "SpAMM threshold τ")
    .opt("iters", "8", "maximum iterations")
    .opt("tol", "1e-6", "idempotency tolerance ‖P²−P‖_F")
    .opt("seed", "7", "workload seed")
    .flag("expr", "run the expression-graph path (default)")
    .flag("loop", "run the legacy per-multiply path");
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let bundle = load_bundle_or_hostsim(&a)?;
    let n = a.usize("n")?;
    let tau = a.f64("tau")? as f32;
    let use_loop = expr_or_loop(&a)?;
    let p0 = initial_density(n, a.usize("seed")? as u64);
    let coord = Coordinator::new(&bundle, cfg)?;
    let r = if use_loop {
        mcweeny_purify_loop(&coord, &p0, tau, a.usize("iters")?, a.f64("tol")?)?
    } else {
        mcweeny_purify(&coord, &p0, tau, a.usize("iters")?, a.f64("tol")?)?
    };
    println!(
        "== McWeeny purification (n={n}, τ={tau:.1e}) via the {} path: {} \
         iterations, converged = {} ==",
        if use_loop { "loop" } else { "expression" },
        r.steps.len(),
        r.converged
    );
    println!("  iter   ‖P²−P‖_F    valid% (P²/P³)   wall(s)   combine(s)");
    for s in &r.steps {
        println!(
            "  {:4}   {:.3e}   {:6.2} / {:6.2}   {:7.4}   {:8.5}",
            s.iter,
            s.idempotency_err,
            s.valid_ratio_p2 * 100.0,
            s.valid_ratio_p3 * 100.0,
            s.wall_secs,
            s.combine_secs
        );
    }
    print_pool_transfers(&coord);
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let spec = common(Spec::new(
        "cuspamm serve",
        "run a synthetic session workload (Zipf-hot registered operands, mixed \
         priorities) through the SpammSession front-end and report serving stats",
    ))
    .opt("requests", "24", "number of requests in the trace")
    .opt("operands", "6", "registered operand pool size")
    .opt("n", "256", "matrix size per operand")
    .opt("zipf", "1.1", "Zipf exponent of operand popularity (higher = hotter head)")
    .opt("ratio", "0.01", "valid-ratio target for the smoke bench plan")
    .opt("seed", "7", "trace seed")
    .opt("queue-depth", "64", "session admission-queue depth (defaults to the config's)")
    .opt("store-budget", "1g", "operand-store byte budget (defaults to the config's)")
    .flag(
        "smoke",
        "CI smoke bench: one registered operand, 8 repeated multiplies; asserts \
         warm plans ≥2x cheaper than the cold request and bitwise identity with \
         the one-shot coordinator path",
    )
    .flag("legacy", "drive the deprecated SpammService shim instead of the session");
    let a = spec.parse(args)?;
    let mut cfg = build_config(&a)?;
    for (opt, key) in [("queue-depth", "queue_depth"), ("store-budget", "store_budget")] {
        if a.provided(opt) {
            cfg.apply(key, a.get(opt))?;
        }
    }
    cfg.validate()?;
    // The serve path is exercised in CI on every push, where no AOT
    // bundle exists: fall back to the synthesized hostsim bundle unless
    // the caller pointed at a real one.
    let bundle = load_bundle_or_hostsim(&a)?;
    if a.flag("smoke") {
        return serve_smoke(&bundle, cfg, a.f64("ratio")?);
    }
    if a.flag("legacy") {
        return serve_legacy(
            &bundle,
            cfg,
            a.usize("requests")?,
            a.usize("n")?,
            a.usize("seed")? as u64,
        );
    }
    serve_session(
        &bundle,
        cfg,
        a.usize("requests")?,
        a.usize("operands")?,
        a.usize("n")?,
        a.f64("zipf")?,
        a.usize("seed")? as u64,
    )
}

/// The session serving bench: put a Zipf-hot operand pool, prepare plans
/// per distinct (a, b, approx), submit with mixed priorities under the
/// admission depth, and report cold-vs-warm per-plan compute.
fn serve_session(
    bundle: &ArtifactBundle,
    cfg: SpammConfig,
    requests: usize,
    operands: usize,
    n: usize,
    zipf: f64,
    seed: u64,
) -> Result<()> {
    use cuspamm::coordinator::session::synthetic_session_trace;
    use cuspamm::coordinator::{Completion, SpammSession};
    use cuspamm::util::stats::Summary;

    let trace = synthetic_session_trace(requests, operands, n, zipf, seed);
    let session = SpammSession::new(bundle, cfg)?;
    let t0 = std::time::Instant::now();
    let ids = trace
        .operands
        .iter()
        .map(|m| session.put(m))
        .collect::<Result<Vec<_>>>()?;
    let depth = session.config().queue_depth;
    let mut completions: Vec<Completion> = Vec::with_capacity(requests);
    for r in &trace.requests {
        // Backpressure: drain a completion when the admission window is
        // full instead of letting submit fail.
        while session.pending() >= depth {
            match session.try_recv() {
                Some(done) => completions.push(done?),
                None => std::thread::sleep(std::time::Duration::from_millis(1)),
            }
        }
        let plan = session.prepare(ids[r.a], ids[r.b], r.approx)?;
        session.submit_with(plan, r.priority)?;
    }
    completions.extend(session.wait_all()?);
    let wall = t0.elapsed().as_secs_f64();

    // Cold-vs-warm: per plan, the first job pays compile/τ/upload; the
    // rest ride the caches, the resident runtime, and the tile pools.
    let mut by_plan: std::collections::BTreeMap<u64, Vec<&Completion>> =
        std::collections::BTreeMap::new();
    for c in &completions {
        by_plan.entry(c.plan.raw()).or_default().push(c);
    }
    let mut cold = Vec::new();
    let mut warm = Vec::new();
    for jobs in by_plan.values() {
        // The cold job is whichever executed first and was charged the
        // plan's prepare phases (under priorities that is not always the
        // lowest ticket) — it is the one carrying nonzero front clocks.
        let charged = jobs
            .iter()
            .position(|c| c.stats.norm_secs + c.stats.schedule_secs > 0.0)
            .unwrap_or(0);
        for (i, c) in jobs.iter().enumerate() {
            if i == charged {
                cold.push(c.compute_secs);
            } else {
                warm.push(c.compute_secs);
            }
        }
    }
    println!(
        "completed {} requests over {} operands ({} distinct plans) in {:.3}s — {:.2} req/s",
        completions.len(),
        trace.operands.len(),
        by_plan.len(),
        wall,
        completions.len() as f64 / wall.max(1e-12)
    );
    for pr in ["high", "normal", "low"] {
        let lat: Vec<f64> = completions
            .iter()
            .filter(|c| c.priority.as_str() == pr)
            .map(|c| c.latency_secs)
            .collect();
        if !lat.is_empty() {
            let s = Summary::from(&lat);
            println!(
                "  {pr:6}: {:3} jobs, latency p50 {:.4}s p95 {:.4}s",
                lat.len(),
                s.median,
                s.p95
            );
        }
    }
    if !cold.is_empty() && !warm.is_empty() {
        let cold_mean = cold.iter().sum::<f64>() / cold.len() as f64;
        let warm_mean = warm.iter().sum::<f64>() / warm.len() as f64;
        println!(
            "  compute: cold (first of plan) mean {:.4}s over {} plans, warm mean {:.4}s \
             over {} jobs — {:.1}x",
            cold_mean,
            cold.len(),
            warm_mean,
            warm.len(),
            cold_mean / warm_mean.max(1e-12)
        );
    }
    let store = session.store_stats();
    println!(
        "  store: {} puts ({} dedup hits), {} operands / {} KiB resident, {} evicted",
        store.puts,
        store.dedup_hits,
        store.resident_operands,
        store.resident_bytes / 1024,
        store.evictions
    );
    println!(
        "  caches: norm {} hit / {} miss, schedule {} hit / {} miss",
        session.caches().norms.hits(),
        session.caches().norms.misses(),
        session.caches().schedules.hits(),
        session.caches().schedules.misses()
    );
    for (d, pool) in session.residency_pools().iter().enumerate() {
        let s = pool.stats();
        println!(
            "  residency[{d}]: {} hit / {} miss / {} evicted, {} KiB uploaded, {} KiB saved",
            s.hits,
            s.misses,
            s.evictions,
            s.uploaded_bytes / 1024,
            s.saved_bytes / 1024
        );
    }
    Ok(())
}

/// Legacy shim path (`--legacy`): the deprecated blocking FIFO facade.
#[allow(deprecated)]
fn serve_legacy(
    bundle: &ArtifactBundle,
    cfg: SpammConfig,
    requests: usize,
    n: usize,
    seed: u64,
) -> Result<()> {
    use cuspamm::coordinator::service::{synthetic_trace, SpammService};

    let mut svc = SpammService::new(bundle, cfg)?;
    for (ma, mb, approx) in synthetic_trace(requests, n, seed) {
        svc.submit(ma, mb, approx);
    }
    println!("draining {} requests ...", svc.pending());
    let (responses, stats) = svc.drain()?;
    for r in responses.iter().take(5) {
        println!(
            "  req {:3}: τ={:.3e} valid {:5.1}%  compute {:.3}s  latency {:.3}s",
            r.id,
            r.tau,
            r.valid_ratio * 100.0,
            r.compute_secs,
            r.latency_secs
        );
    }
    if responses.len() > 5 {
        println!("  ... ({} more)", responses.len() - 5);
    }
    match stats.latency {
        Some(lat) => println!(
            "completed {} in {:.3}s — {:.2} req/s, latency p50 {:.3}s p95 {:.3}s",
            stats.completed, stats.wall_secs, stats.throughput_rps, lat.median, lat.p95
        ),
        None => println!("completed 0 requests (empty trace)"),
    }
    Ok(())
}

/// CI smoke bench (`--smoke`): one registered operand, one prepared plan,
/// 8 repeated multiplies — the repeated-operand serving pattern.  Asserts
/// the session's headline contract: warm requests at least 2x cheaper
/// than the cold first request, zero warm transfer bytes, and bitwise
/// identity with the one-shot `Coordinator::multiply` path.
fn serve_smoke(bundle: &ArtifactBundle, cfg: SpammConfig, ratio: f64) -> Result<()> {
    use cuspamm::coordinator::{Approx, SpammSession};

    const REPEATS: usize = 8;
    let n = 512;
    let a = Matrix::decay_algebraic(n, 0.1, 0.1, 7);
    let session = SpammSession::new(bundle, cfg.clone())?;
    let aid = session.put(&a)?;
    let plan = session.prepare(aid, aid, Approx::ValidRatio(ratio))?;
    let (tau, rows, cols) = session.plan_info(plan)?;
    println!("smoke: n={n} τ={tau:.4e} (ratio target {ratio}), output {rows}x{cols}");
    let tickets: Vec<_> = (0..REPEATS)
        .map(|_| session.submit(plan))
        .collect::<Result<Vec<_>>>()?;
    let mut jobs = Vec::with_capacity(REPEATS);
    for t in tickets {
        jobs.push(session.wait(t)?);
    }
    let cold = &jobs[0];
    let warm_min = jobs[1..]
        .iter()
        .map(|c| c.compute_secs)
        .fold(f64::MAX, f64::min);
    let warm_mean =
        jobs[1..].iter().map(|c| c.compute_secs).sum::<f64>() / (REPEATS - 1) as f64;
    println!(
        "smoke: cold {:.4}s, warm min {:.4}s / mean {:.4}s — {:.1}x",
        cold.compute_secs,
        warm_min,
        warm_mean,
        cold.compute_secs / warm_min.max(1e-12)
    );
    for (i, c) in jobs.iter().enumerate().skip(1) {
        assert_eq!(
            c.stats.transfer_bytes, 0,
            "warm request {i} uploaded operand bytes"
        );
        assert!(
            c.stats.residency_hits > 0,
            "warm request {i} saw no residency hits"
        );
        // Warm plans skip the front phases entirely — the prepare cost
        // was charged to the cold first job.
        assert_eq!(
            c.stats.norm_secs, 0.0,
            "warm request {i} recomputed normmaps"
        );
        assert_eq!(
            c.stats.schedule_secs, 0.0,
            "warm request {i} rebuilt the schedule"
        );
    }
    // Bitwise identity with the legacy one-shot path on a fresh
    // coordinator (cold caches, same schedule math).
    let coord = cuspamm::coordinator::Coordinator::new(bundle, cfg)?;
    let reference = coord.multiply(&a, &a, tau)?;
    for (i, c) in jobs.iter().enumerate() {
        assert_eq!(
            c.c.data(),
            reference.c.data(),
            "session result {i} diverged from Coordinator::multiply"
        );
    }
    assert!(
        cold.compute_secs >= 2.0 * warm_min,
        "warm plans must be ≥2x cheaper: cold {:.4}s vs warm min {:.4}s",
        cold.compute_secs,
        warm_min
    );
    println!(
        "smoke: OK — warm plans ≥2x cheaper, zero warm transfers, bitwise-identical \
         to the one-shot path"
    );
    Ok(())
}

fn cmd_serve_net(args: &[String]) -> Result<()> {
    let spec = common(Spec::new(
        "cuspamm serve-net",
        "serve the session over the framed TCP wire protocol: multi-tenant \
         quotas at admission, plan-aware batching, and a fingerprint-keyed \
         result cache with repair-aware invalidation",
    ))
    .opt("addr", "127.0.0.1:0", "listen address (port 0 = ephemeral)")
    .opt("clients", "2", "concurrent demo clients (tenants)")
    .opt("requests", "8", "requests per demo client")
    .opt("n", "256", "matrix size per operand")
    .opt("ratio", "0.01", "valid-ratio target for the smoke plan")
    .opt("queue-depth", "64", "session admission-queue depth (defaults to the config's)")
    .opt(
        "client-store-budget",
        "0",
        "per-tenant put-bytes budget, sheds with QuotaExceeded \
         (k/m/g suffixes; 0 = unlimited)",
    )
    .opt(
        "client-queue-depth",
        "0",
        "per-tenant inflight-submit depth, sheds with QuotaExceeded \
         (0 = unlimited)",
    )
    .flag(
        "no-result-cache",
        "disable the fingerprint-keyed result cache (bitwise-inert: every \
         submit executes)",
    )
    .flag(
        "smoke",
        "CI smoke: in-process server + clients over localhost; asserts warm \
         cache-hit rounds ≥2x cheaper than the cold round, executed=false \
         re-submits, typed quota + busy shedding on a live connection, and \
         bitwise identity with a direct in-process session",
    );
    let a = spec.parse(args)?;
    let mut cfg = build_config(&a)?;
    for (opt, key) in [
        ("queue-depth", "queue_depth"),
        ("client-store-budget", "client_store_budget"),
        ("client-queue-depth", "client_queue_depth"),
    ] {
        if a.provided(opt) {
            cfg.apply(key, a.get(opt))?;
        }
    }
    if a.flag("no-result-cache") {
        cfg.result_cache_enabled = false;
    }
    cfg.validate()?;
    let bundle = load_bundle_or_hostsim(&a)?;
    if a.flag("smoke") {
        return serve_net_smoke(&bundle, cfg, a.f64("ratio")?);
    }
    serve_net_demo(
        &bundle,
        cfg,
        a.get("addr"),
        a.usize("clients")?,
        a.usize("requests")?,
        a.usize("n")?,
    )
}

/// Multi-tenant demo workload: each client connects as its own tenant,
/// registers two operands, and round-robins submits over three τ levels
/// (retrying politely on `Busy`).  Ends with the server's counter table.
fn serve_net_demo(
    bundle: &ArtifactBundle,
    cfg: SpammConfig,
    addr: &str,
    clients: usize,
    requests: usize,
    n: usize,
) -> Result<()> {
    use cuspamm::serve::{PutOutcome, RemoteApprox, ServeClient, ServeServer, SubmitOutcome};

    let server = ServeServer::start(bundle, cfg, addr)?;
    let addr = server.local_addr();
    println!("serving on {addr}");
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|ci| {
            std::thread::spawn(move || -> Result<(usize, usize)> {
                let mut c = ServeClient::connect(addr, &format!("tenant-{ci}"))?;
                let a = match c.put(&Matrix::decay_algebraic(n, 0.1, 0.1, 2 * ci as u64 + 1))? {
                    PutOutcome::Ok(id) => id,
                    PutOutcome::QuotaExceeded(m) => return Err(Error::Session(m)),
                };
                let b = match c.put(&Matrix::decay_algebraic(n, 0.1, 0.1, 2 * ci as u64 + 2))? {
                    PutOutcome::Ok(id) => id,
                    PutOutcome::QuotaExceeded(m) => return Err(Error::Session(m)),
                };
                let plans = [0.0f32, 0.05, 0.1]
                    .iter()
                    .map(|&t| c.prepare(a, b, RemoteApprox::Tau(t)).map(|p| p.id))
                    .collect::<Result<Vec<_>>>()?;
                let (mut executed, mut warm) = (0, 0);
                for r in 0..requests {
                    let plan = plans[r % plans.len()];
                    let ticket = loop {
                        match c.submit(plan)? {
                            SubmitOutcome::Ticket(t, _) => break t,
                            SubmitOutcome::Busy(_) | SubmitOutcome::QuotaExceeded(_) => {
                                std::thread::sleep(std::time::Duration::from_millis(2));
                            }
                        }
                    };
                    let done = c.wait(ticket)?;
                    if done.executed {
                        executed += 1;
                    } else {
                        warm += 1;
                    }
                }
                Ok((executed, warm))
            })
        })
        .collect();
    let mut executed = 0;
    let mut warm = 0;
    for h in handles {
        let joined = match h.join() {
            Ok(r) => r,
            Err(_) => return Err(Error::Session("demo client panicked".into())),
        };
        let (e, w) = joined?;
        executed += e;
        warm += w;
    }
    let wall = t0.elapsed().as_secs_f64();
    let mut probe = ServeClient::connect(addr, "probe")?;
    let stats = probe.stats()?;
    println!(
        "completed {} requests from {clients} tenants in {wall:.3}s — {} executed on device, \
         {} answered warm (cache or batch)",
        clients * requests,
        executed,
        warm
    );
    println!(
        "  server: {} frames, {} executed, {} batched, {} cache hits / {} misses, \
         shed {} busy / {} quota",
        stats.requests,
        stats.executed,
        stats.batched,
        stats.result_cache_hits,
        stats.result_cache_misses,
        stats.shed_busy,
        stats.shed_quota
    );
    println!(
        "  store: {} puts ({} dedup hits), {} KiB resident",
        stats.store_puts,
        stats.store_dedup_hits,
        stats.store_resident_bytes / 1024
    );
    drop(probe);
    server.shutdown();
    Ok(())
}

/// CI smoke for `serve-net` (`--smoke`): an in-process [`ServeServer`]
/// and clients over localhost.  Asserts, in order: (1) warm re-submits
/// are result-cache hits — `executed == false`, zero compiles, wall
/// ≥2x cheaper than the cold round; (2) the per-tenant store budget
/// sheds a `put` with a typed `QuotaExceeded` on a connection that stays
/// usable, while a second tenant's own budget is untouched; (3) flooding
/// distinct-τ submits at `queue_depth = 1` sheds with typed `Busy` and
/// every admitted ticket is still redeemed (zero lost tickets); (4) the
/// remote product is bitwise identical to a direct in-process session.
fn serve_net_smoke(bundle: &ArtifactBundle, mut cfg: SpammConfig, ratio: f64) -> Result<()> {
    use cuspamm::coordinator::{Approx, SpammSession};
    use cuspamm::serve::{PutOutcome, RemoteApprox, ServeClient, ServeServer, SubmitOutcome};

    const REPEATS: usize = 8;
    const FLOOD: usize = 16;
    let n = 512;
    // One operand fits the tenant store budget exactly; the session's
    // global admission queue is a single slot so the flood sheds.
    cfg.client_store_budget = n * n * 4;
    cfg.queue_depth = 1;
    let a_mat = Matrix::decay_algebraic(n, 0.1, 0.1, 7);
    let server = ServeServer::start(bundle, cfg.clone(), "127.0.0.1:0")?;
    let addr = server.local_addr();
    let mut client = ServeClient::connect(addr, "smoke")?;
    let aid = match client.put(&a_mat)? {
        PutOutcome::Ok(id) => id,
        PutOutcome::QuotaExceeded(m) => {
            return Err(Error::Session(format!("first put must fit the budget: {m}")))
        }
    };
    let plan = client.prepare(aid, aid, RemoteApprox::ValidRatio(ratio))?;
    println!(
        "smoke: n={n} τ={:.4e} (ratio target {ratio}) over {addr}, output {}x{}",
        plan.tau,
        plan.rows,
        plan.cols
    );

    // (1) Cold round executes; every re-submit is a result-cache hit.
    let mut rounds = Vec::with_capacity(REPEATS);
    for i in 0..REPEATS {
        let t0 = std::time::Instant::now();
        let ticket = match client.submit(plan.id)? {
            SubmitOutcome::Ticket(t, cached) => {
                assert_eq!(cached, i > 0, "round {i}: cache admission flag");
                t
            }
            other => return Err(Error::Session(format!("round {i}: unexpected {other:?}"))),
        };
        let done = client.wait(ticket)?;
        rounds.push((t0.elapsed().as_secs_f64(), done));
    }
    assert!(rounds[0].1.executed, "cold round must execute on device");
    for (i, (_, done)) in rounds.iter().enumerate().skip(1) {
        assert!(!done.executed, "warm round {i} dispatched device work");
        assert_eq!(done.compiles, 0, "warm round {i} compiled kernels");
        assert_eq!(
            done.c.data(),
            rounds[0].1.c.data(),
            "warm round {i} diverged from the cold product"
        );
    }
    let cold_wall = rounds[0].0;
    let warm_min = rounds[1..].iter().map(|(w, _)| *w).fold(f64::MAX, f64::min);
    println!(
        "smoke: cold round {:.4}s, warm min {:.4}s — {:.1}x",
        cold_wall,
        warm_min,
        cold_wall / warm_min.max(1e-12)
    );
    assert!(
        cold_wall >= 2.0 * warm_min,
        "warm cache-hit rounds must be ≥2x cheaper: cold {cold_wall:.4}s vs warm {warm_min:.4}s"
    );

    // (2) Store-budget shed: the budget holds exactly one operand, so a
    // second distinct put sheds typed — and the connection stays usable.
    let b_mat = Matrix::decay_algebraic(n, 0.1, 0.1, 8);
    match client.put(&b_mat)? {
        PutOutcome::QuotaExceeded(m) => println!("smoke: put shed as expected ({m})"),
        PutOutcome::Ok(_) => {
            return Err(Error::Session("second put must exceed the store budget".into()))
        }
    }
    // Tenant isolation: another tenant's budget is its own.
    let mut other = ServeClient::connect(addr, "other")?;
    match other.put(&b_mat)? {
        PutOutcome::Ok(_) => {}
        PutOutcome::QuotaExceeded(m) => {
            return Err(Error::Session(format!(
                "tenant budgets must be isolated, second tenant shed: {m}"
            )))
        }
    }

    // (3) Busy shed: distinct-τ (cold) submits flood the single-slot
    // admission queue faster than the worker drains it.
    let flood_plans = (0..FLOOD)
        .map(|i| {
            client
                .prepare(aid, aid, RemoteApprox::Tau(0.011 * (i + 1) as f32))
                .map(|p| p.id)
        })
        .collect::<Result<Vec<_>>>()?;
    let mut admitted = Vec::new();
    let mut saw_busy = false;
    for p in &flood_plans {
        match client.submit(*p)? {
            SubmitOutcome::Ticket(t, cached) => {
                assert!(!cached, "distinct-τ flood plans cannot be cache hits");
                admitted.push(t);
            }
            SubmitOutcome::Busy(m) => {
                println!("smoke: submit shed busy after {} admissions ({m})", admitted.len());
                saw_busy = true;
                break;
            }
            SubmitOutcome::QuotaExceeded(m) => {
                return Err(Error::Session(format!("flood shed on quota, not busy: {m}")))
            }
        }
    }
    assert!(saw_busy, "flooding {FLOOD} cold submits at queue_depth=1 must shed Busy");
    // Zero lost tickets: everything admitted before the shed redeems.
    for (i, t) in admitted.iter().enumerate() {
        let done = client.wait(*t)?;
        assert!(done.executed, "flood ticket {i} was admitted cold, must execute");
        assert_eq!(
            (done.c.rows(), done.c.cols()),
            (plan.rows, plan.cols),
            "flood ticket {i} has the wrong output shape"
        );
    }

    // (4) Bitwise identity with a direct in-process session at the same
    // resolved τ.
    let session = SpammSession::new(bundle, cfg)?;
    let da = session.put(&a_mat)?;
    let dplan = session.prepare(da, da, Approx::Tau(plan.tau))?;
    let direct = session.wait(session.submit(dplan)?)?;
    assert_eq!(
        rounds[0].1.c.data(),
        direct.c.data(),
        "remote product diverged from the direct in-process session"
    );

    let stats = client.stats()?;
    assert!(stats.shed_quota >= 1, "stats must count the quota shed");
    assert!(stats.shed_busy >= 1, "stats must count the busy shed");
    assert_eq!(
        stats.result_cache_hits,
        (REPEATS - 1) as u64,
        "every warm round must be a result-cache hit"
    );
    drop(client);
    drop(other);
    server.shutdown();
    println!(
        "smoke: OK — warm rounds ≥2x cheaper with executed=false, typed quota/busy \
         shedding on live connections, bitwise-identical to the in-process session"
    );
    Ok(())
}

fn cmd_coordinate(args: &[String]) -> Result<()> {
    let spec = common(Spec::new(
        "cuspamm coordinate",
        "multi-device partition bench: per-device load/busy/transfer table \
         under the configured balance policy; --smoke asserts the \
         residency-aware policy beats rowblock on a warm pool",
    ))
    .opt("n", "512", "matrix size")
    .opt("ratio", "0.20", "target valid ratio")
    .opt("seed", "7", "workload seed")
    .flag(
        "smoke",
        "CI assertion: pools warmed by a strided(2) run; residency-aware \
         re-partitioning must transfer ≥2x fewer bytes than rowblock, \
         bitwise-identically, and a 4-device expr power chain must use \
         every device",
    );
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let bundle = load_bundle_or_hostsim(&a)?;
    let n = a.usize("n")?;
    let seed = a.usize("seed")? as u64;
    let ma = Matrix::decay_algebraic(n, 0.1, 0.1, seed);
    let mb = Matrix::decay_algebraic(n, 0.1, 0.1, seed + 1);
    if a.flag("smoke") {
        return coordinate_smoke(&bundle, cfg, &ma, &mb, a.f64("ratio")?);
    }
    let coord = Coordinator::new(&bundle, cfg.clone())?;
    let tuned = coord.tune_tau(&ma, &mb, a.f64("ratio")?)?;
    let rep = coord.multiply(&ma, &mb, tuned.tau)?;
    println!(
        "== coordinate: n={n} τ={:.4e} devices={} balance={:?} ==",
        tuned.tau, cfg.devices, cfg.balance
    );
    println!("spamm: {}", rep.summary_line());
    println!("  device   load   busy(s)  xfer(s)  uploaded(KiB)  resident(KiB)  cross(KiB)");
    for d in 0..cfg.devices {
        println!(
            "  {:6} {:6} {:9.4} {:8.4} {:14} {:14} {:11}",
            d,
            rep.device_load.get(d).copied().unwrap_or(0),
            rep.device_busy.get(d).copied().unwrap_or(0.0),
            rep.device_transfer_secs.get(d).copied().unwrap_or(0.0),
            rep.device_transfer_bytes.get(d).copied().unwrap_or(0) / 1024,
            rep.device_resident_bytes.get(d).copied().unwrap_or(0) / 1024,
            rep.device_cross_bytes.get(d).copied().unwrap_or(0) / 1024
        );
    }
    Ok(())
}

/// CI smoke for `coordinate` (`--smoke`): pools warmed by a previous
/// workload under a *different* placement (strided(2)); on the warm
/// pools the residency-aware policy keeps every tile on its warm device
/// (zero uploads) while rowblock re-partitions by contiguous rows and
/// re-uploads what moved — ≥2x fewer transferred bytes, bitwise
/// identical.  Then a 4-device expression power chain must report
/// nonzero work on every device.
fn coordinate_smoke(
    bundle: &ArtifactBundle,
    mut cfg: SpammConfig,
    ma: &Matrix,
    mb: &Matrix,
    ratio: f64,
) -> Result<()> {
    use cuspamm::config::Balance;
    use cuspamm::runtime::residency::ResidencyPool;
    use cuspamm::spamm::cache::ExecCaches;
    use std::sync::Arc;

    if !cfg.residency_enabled {
        return Err(Error::Config(
            "coordinate --smoke measures pool transfers; run without --no-residency".into(),
        ));
    }
    if cfg.devices < 2 {
        cfg.devices = 4;
    }
    let tau = Coordinator::new(bundle, cfg.clone())?
        .tune_tau(ma, mb, ratio)?
        .tau;

    // Two identically-warmed pool sets: each is warmed by a strided(2)
    // run (the "previous workload" that placed the tiles), then one is
    // re-partitioned by rowblock, the other by residency-aware.
    let run = |balance: Balance| -> Result<(cuspamm::coordinator::MultiDeviceReport, u64)> {
        let pools: Vec<Arc<ResidencyPool>> = (0..cfg.devices)
            .map(|_| Arc::new(ResidencyPool::new(cfg.device_mem_budget)))
            .collect();
        let mut warm_cfg = cfg.clone();
        warm_cfg.balance = Balance::Strided(2);
        let warm = Coordinator::with_shared(
            bundle,
            warm_cfg,
            Arc::new(ExecCaches::new()),
            Some(pools.clone()),
        )?;
        warm.multiply(ma, mb, tau)?;
        let warmed: u64 = pools.iter().map(|p| p.stats().uploaded_bytes).sum();

        let mut cold_cfg = cfg.clone();
        cold_cfg.balance = balance;
        let coord = Coordinator::with_shared(
            bundle,
            cold_cfg,
            Arc::new(ExecCaches::new()),
            Some(pools.clone()),
        )?;
        let rep = coord.multiply(ma, mb, tau)?;
        let total: u64 = pools.iter().map(|p| p.stats().uploaded_bytes).sum();
        Ok((rep, total - warmed))
    };
    let (rep_rb, up_rb) = run(Balance::RowBlock)?;
    let (rep_ra, up_ra) = run(Balance::ResidencyAware)?;
    assert_eq!(
        rep_ra.c.data(),
        rep_rb.c.data(),
        "residency-aware partition changed the result bits"
    );
    println!(
        "smoke: warm re-partition uploaded — rowblock {} KiB, residency-aware {} KiB",
        up_rb / 1024,
        up_ra / 1024
    );
    assert!(up_rb > 0, "rowblock re-partition moved no bytes; scenario broken");
    assert!(
        up_ra * 2 <= up_rb,
        "residency-aware must transfer ≥2x fewer bytes than rowblock on a warm \
         pool: {up_ra} vs {up_rb}"
    );

    // Multi-device expression graphs: an A³ chain must fan out — every
    // device reports nonzero tile products.
    use cuspamm::coordinator::{Approx, ExprGraph, ExprSource};
    let coord = Coordinator::new(bundle, cfg.clone())?;
    let mut g = ExprGraph::new();
    let leaf = g.operand();
    let p2 = g.spamm(leaf, leaf, Approx::Tau(tau));
    let p3 = g.spamm(p2, leaf, Approx::Tau(tau));
    g.output(p3);
    let plan = coord.prepare_expr(&g, &[ExprSource::Host(ma)])?;
    let rep = coord.execute_expr(&plan)?;
    println!(
        "smoke: expr A^3 on {} devices — products {:?}, cross-device {} KiB",
        cfg.devices,
        rep.device_products,
        rep.stats.cross_device_bytes / 1024
    );
    assert_eq!(rep.device_products.len(), cfg.devices);
    assert!(
        rep.device_products.iter().all(|&p| p > 0),
        "every device must execute expr work: {:?}",
        rep.device_products
    );
    // Single-device reference: the multi-device expr path is bitwise
    // identical.
    let mut solo_cfg = cfg.clone();
    solo_cfg.devices = 1;
    let solo = Coordinator::new(bundle, solo_cfg)?;
    let solo_plan = solo.prepare_expr(&g, &[ExprSource::Host(ma)])?;
    let solo_rep = solo.execute_expr(&solo_plan)?;
    assert_eq!(
        rep.to_matrix().data(),
        solo_rep.to_matrix().data(),
        "multi-device expr diverged from the single-device path"
    );
    println!(
        "smoke: OK — ≥2x fewer warm-pool transfer bytes than rowblock, bitwise \
         identity, and all {} devices executed expr work",
        cfg.devices
    );
    Ok(())
}

/// `cuspamm update`: the drifting-operand serving pattern (an SCF loop's
/// Hamiltonian, a slowly-changing weight matrix) — one registered
/// operand, one prepared plan, and per step a small fraction of tiles
/// rewritten via `SpammSession::update` followed by a warm resubmit.
/// Prints the per-step `UpdateReport`; `--smoke` additionally asserts
/// the delta contract for CI.
fn cmd_update(args: &[String]) -> Result<()> {
    use cuspamm::coordinator::{Approx, SpammSession};
    use cuspamm::util::prng::Rng;

    let spec = common(Spec::new(
        "cuspamm update",
        "drifting-operand trace: delta-update a registered operand between \
         submits of one prepared plan; --smoke asserts uploads scale with \
         the delta (≥5x fewer bytes than re-registering), the normmap is \
         patched (never recomputed in full), the schedule is repaired (not \
         rebuilt), and results stay bitwise identical to a from-scratch \
         re-put of the drifted operand",
    ))
    .opt("n", "512", "matrix size (rounded down to a LoNum multiple)")
    .opt("tau", "1e-4", "SpAMM threshold τ")
    .opt("steps", "4", "drift steps (one update + one warm submit each)")
    .opt("churn", "0.05", "fraction of tiles rewritten per step")
    .opt("seed", "7", "workload seed")
    .flag(
        "smoke",
        "CI assertion: ≥5x fewer uploaded bytes than re-put, normmap \
         patched not recomputed, schedule repaired not rebuilt, bitwise \
         identity per step",
    );
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let bundle = load_bundle_or_hostsim(&a)?;
    let smoke = a.flag("smoke");
    if smoke && !cfg.residency_enabled {
        return Err(Error::Config(
            "update --smoke measures pool uploads; run without --no-residency".into(),
        ));
    }
    if smoke && !cfg.cache_enabled {
        return Err(Error::Config(
            "update --smoke asserts normmap patching; run without --no-cache".into(),
        ));
    }
    let l = bundle.lonum;
    let n = (a.usize("n")?.max(2 * l) / l) * l;
    let tau = a.f64("tau")? as f32;
    let steps = a.usize("steps")?.max(1);
    let churn = a.f64("churn")?;
    let seed = a.usize("seed")? as u64;

    let mut host_a = Matrix::decay_algebraic(n, 0.1, 0.1, seed);
    let b = Matrix::decay_algebraic(n, 0.1, 0.1, seed + 1);
    let side = n / l;
    let total_tiles = side * side;
    let churn_tiles = ((total_tiles as f64 * churn).round() as usize).clamp(1, total_tiles);

    // Incremental session: one operand, one plan, drift via update().
    let inc = SpammSession::new(&bundle, cfg.clone())?;
    let aid = inc.put(&host_a)?;
    let bid = inc.put(&b)?;
    let plan = inc.prepare(aid, bid, Approx::Tau(tau))?;
    let cold = inc.wait(inc.submit(plan)?)?;
    // Reference session: same drift, but each step re-registers the
    // drifted matrix from scratch (full re-fingerprint + re-upload).
    let reput = SpammSession::new(&bundle, cfg.clone())?;
    let rbid = reput.put(&b)?;
    let warm_b = reput.prepare(rbid, rbid, Approx::Tau(tau))?;
    let _ = reput.wait(reput.submit(warm_b)?)?;

    let pool_bytes = |s: &SpammSession| -> u64 {
        s.residency_pools()
            .iter()
            .map(|p| p.stats().uploaded_bytes)
            .sum()
    };
    println!(
        "== update: n={n} τ={tau:.1e} steps={steps} — {churn_tiles}/{total_tiles} \
         tiles per step, cold submit {:.4}s ==",
        cold.compute_secs
    );

    let mut rng = Rng::new(seed ^ 0xD1F7);
    let l2 = l * l;
    let (mut inc_up_total, mut reput_up_total) = (0u64, 0u64);
    for step in 0..steps {
        // Pick distinct tile coordinates and fresh (mild) payloads; the
        // host mirror gets the identical patch so the re-put reference
        // sees the same drifted content.
        let mut changed: Vec<(usize, usize)> = Vec::new();
        while changed.len() < churn_tiles {
            let t = (rng.below(side), rng.below(side));
            if !changed.contains(&t) {
                changed.push(t);
            }
        }
        let mut data = Vec::with_capacity(churn_tiles * l2);
        for (k, &(ti, tj)) in changed.iter().enumerate() {
            let block = Matrix::randn(l, l, seed.wrapping_add((step * 4096 + k) as u64 + 1));
            data.extend(block.data().iter().map(|x| x * 0.05));
            for r in 0..l {
                host_a.data_mut()[(ti * l + r) * n + tj * l..][..l]
                    .copy_from_slice(&data[k * l2 + r * l..k * l2 + (r + 1) * l]);
            }
        }

        let before = pool_bytes(&inc);
        let report = inc.update(aid, &changed, &data)?;
        let job = inc.wait(inc.submit(plan)?)?;
        let inc_up = pool_bytes(&inc) - before;
        inc_up_total += inc_up;

        let before = pool_bytes(&reput);
        let said = reput.put(&host_a)?;
        let splan = reput.prepare(said, rbid, Approx::Tau(tau))?;
        let sjob = reput.wait(reput.submit(splan)?)?;
        let reput_up = pool_bytes(&reput) - before;
        reput_up_total += reput_up;

        println!(
            "step {step}: {} tiles — uploaded {} KiB (re-put {} KiB), norm tiles \
             patched {}, schedules repaired {} (+{} -{} ~{} products), plans \
             migrated {}, warm submit {:.4}s",
            report.tiles_changed,
            inc_up / 1024,
            reput_up / 1024,
            report.norm_tiles_patched,
            report.schedules_repaired,
            report.products_added,
            report.products_removed,
            report.products_retagged,
            report.plans_migrated,
            job.compute_secs,
        );
        // The delta path must be invisible in the result bits, smoke or
        // not: same content, same τ, same threshold → same product.
        assert_eq!(
            job.c.data(),
            sjob.c.data(),
            "step {step}: incremental result diverged from the re-put rebuild"
        );
        if smoke {
            assert!(
                report.norm_patched,
                "step {step}: normmap was recomputed in full, not patched"
            );
            assert_eq!(
                report.norm_tiles_patched, report.tiles_changed,
                "step {step}: patched more norm tiles than changed tiles"
            );
            assert!(
                report.plans_migrated >= 1,
                "step {step}: the prepared plan did not migrate"
            );
            assert!(
                job.stats.schedules_repaired >= 1,
                "step {step}: warm submit did not run on a repaired schedule"
            );
            assert_eq!(
                job.stats.schedule_cache_misses, 0,
                "step {step}: schedule was rebuilt, not repaired"
            );
        }
        reput.release_plan(splan)?;
        reput.release(said)?;
    }
    println!(
        "uploaded over {steps} steps: incremental {} KiB vs re-put {} KiB",
        inc_up_total / 1024,
        reput_up_total / 1024
    );
    if smoke {
        assert!(
            inc_up_total * 5 <= reput_up_total,
            "delta updates must upload ≥5x fewer bytes than re-registering: \
             {inc_up_total} vs {reput_up_total}"
        );
        println!(
            "smoke: OK — ≥5x fewer uploaded bytes than re-put, normmap patched, \
             schedule repaired, bitwise identity on every step"
        );
    }
    Ok(())
}

/// `cuspamm bench`: regenerate the machine-readable benchmark records
/// (`BENCH_multiply.json`, `BENCH_serve.json`, `BENCH_expr.json`) on small
/// deterministic hostsim workloads, and optionally diff their
/// deterministic sections against committed baselines (`--check`).
fn cmd_bench(args: &[String]) -> Result<()> {
    let spec = common(Spec::new(
        "cuspamm bench",
        "emit BENCH_<suite>.json records; --check <dir> diffs the \
         deterministic fields (counts, format mixes, cache behavior) \
         against committed baselines",
    ))
    .opt(
        "suite",
        "all",
        "all | multiply | serve | serve-net | expr | multidevice",
    )
    .opt("out", "bench_results", "output directory for BENCH_*.json")
    .opt(
        "check",
        "",
        "baseline directory to diff against (empty = just emit)",
    );
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let bundle = load_bundle_or_hostsim(&a)?;
    let suite = a.get("suite").to_string();
    let pick = |name: &str| suite == "all" || suite == name;
    let mut records = Vec::new();
    if pick("multiply") {
        records.push(bench_multiply(&bundle, &cfg)?);
    }
    if pick("serve") {
        records.push(bench_serve(&bundle, &cfg)?);
    }
    if pick("serve-net") {
        records.push(bench_serve_net(&bundle, &cfg)?);
    }
    if pick("expr") {
        records.push(bench_expr(&bundle, &cfg)?);
    }
    if pick("multidevice") {
        records.push(bench_multidevice(&bundle, &cfg)?);
    }
    if records.is_empty() {
        return Err(Error::Config(format!(
            "unknown suite '{suite}' (all | multiply | serve | serve-net | expr | multidevice)"
        )));
    }
    let out = std::path::Path::new(a.get("out"));
    for r in &records {
        let path = r.write(out)?;
        println!("wrote {}", path.display());
    }
    if !a.get("check").is_empty() {
        let dir = std::path::Path::new(a.get("check"));
        let mut mismatches = Vec::new();
        for r in &records {
            let baseline = dir.join(format!("BENCH_{}.json", r.name));
            mismatches.extend(r.check_against(&baseline)?);
        }
        if !mismatches.is_empty() {
            return Err(Error::Config(format!(
                "bench baselines drifted ({}):\n  {}\n(re-baseline deliberately by \
                 copying the regenerated files over {})",
                mismatches.len(),
                mismatches.join("\n  "),
                dir.display()
            )));
        }
        // Timing-trend pass over the info fields: machine-dependent, so
        // gross slowdowns are *warned*, never failed.
        for r in &records {
            let baseline = dir.join(format!("BENCH_{}.json", r.name));
            for w in r.timing_trends_against(&baseline)? {
                println!("warning: timing trend — {w}");
            }
        }
        println!("baselines OK ({} records)", records.len());
    }
    Ok(())
}

/// Multiply suite: the density-adaptive format mix on the scattered-sparse
/// workload, against the all-dense threshold-0 run.
fn bench_multiply(
    bundle: &ArtifactBundle,
    cfg: &SpammConfig,
) -> Result<cuspamm::bench_harness::BenchRecord> {
    use cuspamm::bench_harness::BenchRecord;

    let l = bundle.lonum;
    let n = 4 * l;
    let ma = scattered_sparse(n, l, 8, 11);
    let mb = scattered_sparse(n, l, 8, 12);
    let mut cfg0 = cfg.clone();
    cfg0.density_threshold = 0.0;
    let rep0 = Coordinator::new(bundle, cfg0)?.multiply(&ma, &mb, 0.0)?;
    let mut cfg1 = cfg.clone();
    cfg1.density_threshold = 0.5;
    let rep1 = Coordinator::new(bundle, cfg1)?.multiply(&ma, &mb, 0.0)?;

    let mut r = BenchRecord::new("multiply");
    r.det("n", n as f64)
        .det("total_products", rep1.stage.total_products as f64)
        .det("valid_products", rep1.stage.valid_products as f64)
        .det("dense_products", rep1.stage.dense_products as f64)
        .det("sparse_products", rep1.stage.sparse_products as f64)
        .det("packed_products", rep1.stage.packed_products as f64)
        .det("sparse_dispatches", rep1.stage.sparse_dispatches as f64)
        .det(
            "all_dense_products_at_zero_threshold",
            rep0.stage.dense_products as f64,
        )
        .det(
            "routed_at_zero_threshold",
            (rep0.stage.sparse_products + rep0.stage.packed_products) as f64,
        );
    r.info("wall_secs_dense", rep0.wall_secs)
        .info("wall_secs_adaptive", rep1.wall_secs)
        .info("uploaded_bytes_dense", rep0.stage.transfer_bytes as f64)
        .info("uploaded_bytes_adaptive", rep1.stage.transfer_bytes as f64)
        .info("format_saved_bytes", rep1.stage.format_saved_bytes as f64);
    Ok(r)
}

/// Serve suite: warm prepared-plan requests ride the caches and the
/// residency pools — zero warm transfers, zero warm norm recomputes.
fn bench_serve(
    bundle: &ArtifactBundle,
    cfg: &SpammConfig,
) -> Result<cuspamm::bench_harness::BenchRecord> {
    use cuspamm::bench_harness::BenchRecord;
    use cuspamm::coordinator::{Approx, SpammSession};

    const REQUESTS: usize = 4;
    let n = 4 * bundle.lonum;
    let a = Matrix::decay_algebraic(n, 0.1, 0.1, 7);
    let session = SpammSession::new(bundle, cfg.clone())?;
    let aid = session.put(&a)?;
    let plan = session.prepare(aid, aid, Approx::Tau(0.0))?;
    let mut jobs = Vec::with_capacity(REQUESTS);
    for _ in 0..REQUESTS {
        let t = session.submit(plan)?;
        jobs.push(session.wait(t)?);
    }
    let warm = &jobs[1..];
    let mut r = BenchRecord::new("serve");
    r.det("requests", REQUESTS as f64)
        .det("warm_requests", warm.len() as f64)
        .det("valid_products", jobs[0].stats.valid_products as f64)
        .det(
            "warm_transfer_bytes",
            warm.iter().map(|c| c.stats.transfer_bytes).sum::<u64>() as f64,
        )
        .det(
            "warm_norm_recomputes",
            warm.iter().filter(|c| c.stats.norm_secs > 0.0).count() as f64,
        );
    r.info("cold_compute_secs", jobs[0].compute_secs).info(
        "warm_compute_secs_mean",
        warm.iter().map(|c| c.compute_secs).sum::<f64>() / warm.len() as f64,
    );
    Ok(r)
}

/// Serve-net suite: one sequential tenant over the wire protocol, so every
/// pinned counter is an exact frame-trace regression.  With a per-tenant
/// inflight depth of 1, the second of two back-to-back cold submits sheds
/// `QuotaExceeded` deterministically (inflight is charged at admission and
/// released at wait, independent of device timing); warm re-submits of the
/// first plan are result-cache hits that never reach the device.
fn bench_serve_net(
    bundle: &ArtifactBundle,
    cfg: &SpammConfig,
) -> Result<cuspamm::bench_harness::BenchRecord> {
    use cuspamm::bench_harness::BenchRecord;
    use cuspamm::serve::{PutOutcome, RemoteApprox, ServeClient, ServeServer, SubmitOutcome};

    const WARM_ROUNDS: usize = 3;
    let n = 4 * bundle.lonum;
    let mut cfg = cfg.clone();
    cfg.client_queue_depth = 1;
    let t0 = std::time::Instant::now();
    let server = ServeServer::start(bundle, cfg, "127.0.0.1:0")?;
    let mut c = ServeClient::connect(server.local_addr(), "bench")?;
    let put = |out: PutOutcome| match out {
        PutOutcome::Ok(id) => Ok(id),
        PutOutcome::QuotaExceeded(m) => Err(Error::Session(format!("bench put shed: {m}"))),
    };
    let ticket = |out: SubmitOutcome| match out {
        SubmitOutcome::Ticket(t, _) => Ok(t),
        other => Err(Error::Session(format!("bench submit shed: {other:?}"))),
    };
    let ida = put(c.put(&Matrix::decay_algebraic(n, 0.1, 0.1, 7))?)?;
    let idb = put(c.put(&Matrix::decay_algebraic(n, 0.1, 0.1, 8))?)?;
    let p0 = c.prepare(ida, idb, RemoteApprox::Tau(0.0))?.id;
    // Cold round then warm re-submits: all three must come back from the
    // result cache without executing.
    let mut warm_executed = 0u64;
    for round in 0..=WARM_ROUNDS {
        let t = ticket(c.submit(p0)?)?;
        let done = c.wait(t)?;
        if round > 0 && done.executed {
            warm_executed += 1;
        }
    }
    // Two fresh plans, inflight depth 1: submit p1, then p2 sheds typed,
    // then p2 is admitted once p1's wait releases the slot.
    let p1 = c.prepare(ida, idb, RemoteApprox::Tau(0.125))?.id;
    let p2 = c.prepare(ida, idb, RemoteApprox::Tau(0.25))?.id;
    let t1 = ticket(c.submit(p1)?)?;
    let shed = match c.submit(p2)? {
        SubmitOutcome::QuotaExceeded(_) => 1u64,
        other => return Err(Error::Session(format!("expected a typed quota shed, got {other:?}"))),
    };
    c.wait(t1)?;
    let t2 = ticket(c.submit(p2)?)?;
    c.wait(t2)?;
    let stats = c.stats()?;
    let wall = t0.elapsed().as_secs_f64();
    drop(c);
    server.shutdown();

    let mut r = BenchRecord::new("serve_net");
    r.det("requests", stats.requests as f64)
        .det("executed", stats.executed as f64)
        .det("batched", stats.batched as f64)
        .det("result_cache_hits", stats.result_cache_hits as f64)
        .det("result_cache_misses", stats.result_cache_misses as f64)
        .det("result_cache_len", stats.result_cache_len as f64)
        .det("shed_quota", stats.shed_quota as f64)
        .det("shed_busy", stats.shed_busy as f64)
        .det("observed_quota_sheds", shed as f64)
        .det("store_puts", stats.store_puts as f64)
        .det("store_dedup_hits", stats.store_dedup_hits as f64)
        .det("warm_executed", warm_executed as f64);
    r.info("wall_secs", wall);
    Ok(r)
}

/// Expr suite: the A³ power chain — device-resident intermediates mean
/// exactly one host norm computation (the leaf), fully valid at τ = 0.
fn bench_expr(
    bundle: &ArtifactBundle,
    cfg: &SpammConfig,
) -> Result<cuspamm::bench_harness::BenchRecord> {
    use cuspamm::bench_harness::BenchRecord;
    use cuspamm::spamm::power::spamm_power;

    let n = 4 * bundle.lonum;
    let m = Matrix::decay_exponential(n, 1.0, 0.5, 7);
    let coord = Coordinator::new(bundle, cfg.clone())?;
    let r0 = spamm_power(&coord, &m, 3, 0.0)?;
    let mut r = BenchRecord::new("expr");
    r.det("steps", r0.steps.len() as f64)
        .det(
            "fully_valid_steps",
            r0.steps.iter().filter(|s| s.valid_ratio == 1.0).count() as f64,
        )
        .det("leaf_norm_misses", coord.caches().norms.misses() as f64);
    r.info("wall_secs", r0.steps.iter().map(|s| s.wall_secs).sum::<f64>());
    Ok(r)
}

/// Multidevice suite: a forced 4-device strided run.  Pinned counters
/// are structural — the τ = 0 schedule keeps every product, the strided
/// policy hands each of the 4 devices exactly 2 of the 8 tile rows, and
/// a warm prepared-plan resubmit re-uploads nothing — so the partition,
/// the per-device load vector, and the residency contract are all CI
/// regressions, not timings.
fn bench_multidevice(
    bundle: &ArtifactBundle,
    cfg: &SpammConfig,
) -> Result<cuspamm::bench_harness::BenchRecord> {
    use cuspamm::bench_harness::BenchRecord;
    use cuspamm::coordinator::{Approx, SpammSession};
    use cuspamm::spamm::power::spamm_power;

    const DEVICES: usize = 4;
    let l = bundle.lonum;
    let n = 8 * l;
    let mut cfg = cfg.clone();
    cfg.devices = DEVICES;
    cfg.balance = cuspamm::config::Balance::Strided(DEVICES);
    let ma = Matrix::decay_algebraic(n, 0.1, 0.1, 91);
    let mb = Matrix::decay_algebraic(n, 0.1, 0.1, 92);

    // Session path: cold submit populates the per-device pools, the warm
    // resubmit of the same pinned plan must transfer zero bytes.
    let session = SpammSession::new(bundle, cfg.clone())?;
    let ida = session.put(&ma)?;
    let idb = session.put(&mb)?;
    let plan = session.prepare(ida, idb, Approx::Tau(0.0))?;
    let t_cold = session.submit(plan)?;
    let cold = session.wait(t_cold)?;
    let t_warm = session.submit(plan)?;
    let warm = session.wait(t_warm)?;

    // Coordinator path: the per-device partition counters for the same
    // workload, then the A³ chain over the now-shared pools.
    let coord = Coordinator::new(bundle, cfg.clone())?;
    let rep = coord.multiply(&ma, &mb, 0.0)?;
    let power = spamm_power(&coord, &ma, 3, 0.0)?;

    let mut r = BenchRecord::new("multidevice");
    r.det("devices", DEVICES as f64)
        .det("total_products", rep.total_products as f64)
        .det("valid_products", rep.valid_products as f64);
    for (d, &load) in rep.device_load.iter().enumerate() {
        r.det(&format!("device{d}_products"), load as f64);
    }
    r.det(
        "multiply_cross_device_bytes",
        rep.stage.cross_device_bytes as f64,
    )
    .det("warm_transfer_bytes", warm.stats.transfer_bytes as f64)
    .det("warm_residency_misses", warm.stats.residency_misses as f64)
    .det("warm_norm_recomputes", warm.stats.norm_cache_misses as f64)
    .det("expr_steps", power.steps.len() as f64)
    .det(
        "expr_fully_valid_steps",
        power.steps.iter().filter(|s| s.valid_ratio == 1.0).count() as f64,
    );
    r.info("cold_transfer_bytes", cold.stats.transfer_bytes as f64)
        .info("cold_residency_misses", cold.stats.residency_misses as f64)
        .info("warm_residency_hits", warm.stats.residency_hits as f64)
        .info(
            "multiply_transfer_bytes",
            rep.stage.transfer_bytes as f64,
        )
        .info(
            "expr_wall_secs",
            power.steps.iter().map(|s| s.wall_secs).sum::<f64>(),
        )
        .info("cold_compute_secs", cold.compute_secs)
        .info("warm_compute_secs", warm.compute_secs);
    Ok(r)
}

/// `cuspamm store`: administer a warm-start store directory without
/// running a workload — list entries, GC under a byte budget, or
/// re-verify every payload against its manifest checksum.
fn cmd_store(args: &[String]) -> Result<()> {
    let spec = Spec::new(
        "cuspamm store",
        "warm-start store administration — verbs: ls (entry table), gc \
         --budget <bytes> (evict least-recently-used payloads until the \
         store fits), verify [--heal] (re-checksum every payload; --heal \
         evicts failures instead of erroring)",
    )
    .opt(
        "store-dir",
        "",
        "store directory (falls back to the config file's store_dir)",
    )
    .opt("config", "", "optional config file (key = value)")
    .opt("budget", "64m", "gc byte budget (k/m/g suffixes)")
    .flag("heal", "verify: evict entries that fail instead of erroring");
    let a = spec.parse(args)?;
    let verb = a.positionals.first().map(|s| s.as_str()).unwrap_or("ls");
    let dir = if !a.get("store-dir").is_empty() {
        a.get("store-dir").to_string()
    } else if !a.get("config").is_empty() {
        SpammConfig::from_file(std::path::Path::new(a.get("config")))?.store_dir
    } else {
        String::new()
    };
    if dir.is_empty() {
        return Err(Error::Config(
            "store: pass --store-dir <dir> (or a --config whose store_dir is set)".into(),
        ));
    }
    let store = WarmStore::open(std::path::Path::new(&dir))?;
    match verb {
        "ls" => {
            let mut entries = store.ls()?;
            entries.sort_by(|x, y| x.0.cmp(&y.0));
            let total: u64 = entries.iter().map(|(_, e, _)| e.bytes).sum();
            println!("{:<44} {:<10} {:>12}  {}", "KEY", "KIND", "BYTES", "PATH");
            for (key, e, _) in &entries {
                println!("{:<44} {:<10} {:>12}  {}", key, e.kind, e.bytes, e.path);
            }
            println!(
                "{} entries, {} KiB in {}",
                entries.len(),
                total / 1024,
                store.dir().display()
            );
        }
        "gc" => {
            let rep = store.gc(a.bytes("budget")? as u64)?;
            println!(
                "gc: evicted {} of {} entries, {} -> {} KiB",
                rep.evicted,
                rep.entries_before,
                rep.bytes_before / 1024,
                rep.bytes_after / 1024
            );
        }
        "verify" => {
            let rep = store.verify(a.flag("heal"))?;
            for (key, why) in &rep.bad {
                println!("BAD {key}: {why}");
            }
            println!("verify: {} ok, {} bad", rep.ok, rep.bad.len());
            if !rep.bad.is_empty() && !a.flag("heal") {
                return Err(Error::Store(format!(
                    "{} store entries failed verification (re-run with --heal to evict them)",
                    rep.bad.len()
                )));
            }
        }
        other => {
            return Err(Error::Config(format!(
                "unknown store verb '{other}' (ls | gc | verify)"
            )))
        }
    }
    Ok(())
}

/// `cuspamm warmstart`: the restart-to-warm contract, end to end.  Run a
/// valid-ratio workload against a `--store-dir`, drop every piece of
/// process state, and run the identical workload again: request one of
/// the second "process" must restore both normmaps, the schedule, the
/// tuned τ, and the synthesized hostsim bundle from disk — zero
/// cold-path recomputation, bitwise-identical result.  `--smoke` also
/// drives the incremental-update re-persist path and a corrupted-store
/// fallback, asserting the whole contract for CI.
fn cmd_warmstart(args: &[String]) -> Result<()> {
    use cuspamm::runtime::hostsim::{warm_bundle, HostsimSpec};

    let spec = common(Spec::new(
        "cuspamm warmstart",
        "restart-to-warm demo over a --store-dir: cold run, then a fresh \
         session (a simulated process restart) whose first request hits \
         the store for every artifact kind; --smoke asserts zero \
         recomputes + bitwise identity, re-persisted incremental patches, \
         and cold fallback from a corrupted store",
    ))
    .opt("n", "256", "matrix size (rounded down to a LoNum multiple)")
    .opt("ratio", "0.5", "target valid ratio (exercises the τ tuner)")
    .opt("seed", "11", "workload seed")
    .flag(
        "smoke",
        "CI assertion: the warm restart recomputes nothing (all four \
         artifact kinds restore from disk), results are bitwise identical \
         cold vs warm vs --no-store, and a corrupted store falls back \
         cold then self-heals",
    );
    let a = spec.parse(args)?;
    let mut cfg = build_config(&a)?;
    let smoke = a.flag("smoke");
    if !cfg.store_enabled {
        return Err(Error::Config(
            "warmstart exercises the store; run without --no-store".into(),
        ));
    }
    if !cfg.cache_enabled {
        return Err(Error::Config(
            "warmstart restores into the in-memory caches; run without --no-cache".into(),
        ));
    }
    if cfg.store_dir.is_empty() {
        cfg.store_dir = std::env::temp_dir()
            .join("cuspamm_warmstore")
            .to_string_lossy()
            .into_owned();
        println!("note: no --store-dir given; using {}", cfg.store_dir);
    }
    if smoke {
        // The cold phase must actually be cold: wipe any prior contents
        // so repeat CI runs over the same --store-dir stay deterministic.
        let _ = std::fs::remove_dir_all(&cfg.store_dir);
    }
    let store_dir = std::path::PathBuf::from(&cfg.store_dir);
    let hspec = HostsimSpec::default();

    // Phase A — cold against an empty store.  The bundle synthesis is
    // itself store-backed: the frozen artifact directory persists too.
    let s1 = WarmStore::open(&store_dir)?;
    let (bundle_a, bundle_hit_a) = warm_bundle(&s1, &hspec)?;
    let l = bundle_a.lonum;
    let n = (a.usize("n")?.max(2 * l) / l) * l;
    let seed = a.usize("seed")? as u64;
    let ratio = a.f64("ratio")?;
    let ma = Matrix::decay_algebraic(n, 0.1, 0.1, seed);
    let mb = Matrix::decay_algebraic(n, 0.1, 0.1, seed + 1);

    // One full "process": fresh session, register, prepare to a
    // valid-ratio target (runs or restores the tuner), submit, wait.
    let run = |cfg: &SpammConfig, bundle: &ArtifactBundle| -> Result<Completion> {
        let s = SpammSession::new(bundle, cfg.clone())?;
        let ida = s.put(&ma)?;
        let idb = s.put(&mb)?;
        let plan = s.prepare(ida, idb, Approx::ValidRatio(ratio))?;
        s.wait(s.submit(plan)?)
    };
    let describe = |tag: &str, job: &Completion| {
        println!(
            "phase {tag}: τ={:.6e} valid={:.1}% — norm misses {}, schedule \
             misses {}, τ tunes {}; store hits: normmap {}, schedule {}, τ {}",
            job.tau,
            job.valid_ratio * 100.0,
            job.stats.norm_cache_misses,
            job.stats.schedule_cache_misses,
            job.stats.tau_tuned,
            job.stats.store_normmap_hits,
            job.stats.store_schedule_hits,
            job.stats.store_tau_hits,
        );
    };

    let cold = run(&cfg, &bundle_a)?;
    println!(
        "== warmstart: n={n} ratio={ratio} store {} ==",
        store_dir.display()
    );
    describe("A cold     ", &cold);
    if smoke {
        assert!(!bundle_hit_a, "phase A: the wiped store restored a bundle");
        assert_eq!(cold.stats.tau_tuned, 1, "phase A: the tuner did not run");
        assert_eq!(
            cold.stats.norm_cache_misses, 2,
            "phase A: expected both normmaps computed cold"
        );
        assert_eq!(
            cold.stats.schedule_cache_misses, 1,
            "phase A: expected the schedule built cold"
        );
        assert_eq!(
            cold.stats.store_normmap_hits
                + cold.stats.store_schedule_hits
                + cold.stats.store_tau_hits,
            0,
            "phase A: an empty store produced hits"
        );
    }

    // Phase B — the restart.  A fresh store handle and a fresh session
    // share nothing in memory with phase A; every artifact must come
    // back from disk on the very first request.
    let s2 = WarmStore::open(&store_dir)?;
    let (bundle_b, bundle_hit_b) = warm_bundle(&s2, &hspec)?;
    let warm = run(&cfg, &bundle_b)?;
    describe("B restarted", &warm);
    if smoke {
        assert!(bundle_hit_b, "phase B: bundle was re-synthesized, not restored");
        assert_eq!(
            (
                warm.stats.norm_cache_misses,
                warm.stats.schedule_cache_misses,
                warm.stats.tau_tuned
            ),
            (0, 0, 0),
            "phase B: the restarted session recomputed on the cold path"
        );
        assert_eq!(
            (
                warm.stats.store_normmap_hits,
                warm.stats.store_schedule_hits,
                warm.stats.store_tau_hits
            ),
            (2, 1, 1),
            "phase B: expected every artifact restored from the store"
        );
        assert_eq!(
            warm.tau.to_bits(),
            cold.tau.to_bits(),
            "phase B: restored τ differs from the tuned τ"
        );
        assert_eq!(
            warm.c.data(),
            cold.c.data(),
            "phase B: warm result diverged from the cold run"
        );
    }

    // Phase E — incremental updates re-persist.  "Process" one drifts an
    // operand (patched normmap + repaired schedule land in the store
    // under the patched fingerprint); a restarted session that applies
    // the same delta must find the repaired schedule on disk.
    let side = n / l;
    let changed = vec![(0usize, 0usize), (side - 1, side - 1)];
    let l2 = l * l;
    let mut delta = Vec::with_capacity(changed.len() * l2);
    for k in 0..changed.len() {
        let block = Matrix::randn(l, l, seed + 100 + k as u64);
        delta.extend(block.data().iter().map(|x| x * 0.05));
    }
    let e1 = SpammSession::new(&bundle_b, cfg.clone())?;
    let ea = e1.put(&ma)?;
    let eb = e1.put(&mb)?;
    let eplan = e1.prepare(ea, eb, Approx::ValidRatio(ratio))?;
    let _ = e1.wait(e1.submit(eplan)?)?;
    let report = e1.update(ea, &changed, &delta)?;
    let r1 = e1.wait(e1.submit(eplan)?)?;
    let e2 = SpammSession::new(&bundle_b, cfg.clone())?;
    let fa = e2.put(&ma)?;
    let fb = e2.put(&mb)?;
    e2.update(fa, &changed, &delta)?;
    // The migrated plan keeps its tuned τ, so the restarted session pins
    // the same threshold to hit the re-persisted (rekeyed) schedule.
    let fplan = e2.prepare(fa, fb, Approx::Tau(r1.tau))?;
    let r2 = e2.wait(e2.submit(fplan)?)?;
    describe("E repatched", &r2);
    if smoke {
        assert!(
            report.schedules_repaired >= 1,
            "phase E: the drift did not repair a schedule"
        );
        assert!(
            r2.stats.store_schedule_hits >= 1,
            "phase E: the repaired schedule was not re-persisted"
        );
        assert_eq!(
            r2.stats.schedule_cache_misses, 0,
            "phase E: the restarted session rebuilt the repaired schedule"
        );
        assert_eq!(
            r2.c.data(),
            r1.c.data(),
            "phase E: restored-patched result diverged from the live-patched run"
        );
    }

    // Phase C — kill switch.  With the store disabled the cold path runs
    // end to end and produces the identical bits.
    let mut cfg_off = cfg.clone();
    cfg_off.store_enabled = false;
    let off = run(&cfg_off, &bundle_a)?;
    describe("C no-store ", &off);
    if smoke {
        assert_eq!(
            off.stats.store_normmap_hits
                + off.stats.store_schedule_hits
                + off.stats.store_tau_hits
                + off.stats.store_bundle_hits,
            0,
            "phase C: --no-store still touched the store"
        );
        assert_eq!(off.stats.tau_tuned, 1, "phase C: the tuner did not run");
        assert_eq!(
            off.tau.to_bits(),
            cold.tau.to_bits(),
            "phase C: no-store τ differs from the tuned τ"
        );
        assert_eq!(
            off.c.data(),
            cold.c.data(),
            "phase C: no-store result diverged from the cold run"
        );
    }

    // Phase D — corruption (smoke only: it vandalizes the store).  Flip
    // one bit in every payload; the next run must detect the checksum
    // mismatches, evict, fall back cold bitwise-identically, and
    // re-persist good copies.  verify --heal sweeps the stragglers the
    // workload never re-touched.
    if smoke {
        let mut flipped = 0usize;
        if let Ok(rd) = std::fs::read_dir(store_dir.join("objects")) {
            for ent in rd.flatten() {
                let p = ent.path();
                if p.extension().and_then(|e| e.to_str()) != Some("bin") {
                    continue;
                }
                let Ok(mut bytes) = std::fs::read(&p) else {
                    continue;
                };
                if let Some(b) = bytes.first_mut() {
                    *b ^= 0x01;
                    std::fs::write(&p, &bytes)?;
                    flipped += 1;
                }
            }
        }
        assert!(flipped >= 4, "phase D: expected payloads to corrupt, found {flipped}");
        let hurt = run(&cfg, &bundle_a)?;
        describe("D corrupted", &hurt);
        assert_eq!(
            hurt.stats.store_normmap_hits
                + hurt.stats.store_schedule_hits
                + hurt.stats.store_tau_hits,
            0,
            "phase D: a corrupted store produced hits"
        );
        assert_eq!(
            (hurt.stats.norm_cache_misses, hurt.stats.schedule_cache_misses),
            (2, 1),
            "phase D: corruption fallback was not fully cold"
        );
        assert_eq!(hurt.stats.tau_tuned, 1, "phase D: the tuner did not re-run");
        assert_eq!(
            hurt.c.data(),
            cold.c.data(),
            "phase D: corruption fallback diverged from the cold run"
        );
        let s3 = WarmStore::open(&store_dir)?;
        let healed = s3.verify(true)?;
        println!(
            "phase D: flipped {flipped} payloads; cold fallback re-persisted \
             {} entries, verify --heal evicted {}",
            healed.ok,
            healed.bad.len()
        );
        let clean = s3.verify(false)?;
        assert!(
            clean.bad.is_empty(),
            "phase D: store still dirty after healing: {:?}",
            clean.bad
        );
        println!(
            "smoke: OK — restart restored all four artifact kinds with zero \
             recomputation, incremental patches re-persisted, --no-store and \
             corrupted-store runs stayed bitwise identical"
        );
    }
    Ok(())
}

fn cmd_cnn(args: &[String]) -> Result<()> {
    let spec = common(Spec::new("cuspamm cnn", "case-study CNN accuracy probe"))
        .opt("tau", "0.0", "SpAMM τ for the chosen layer")
        .opt("layer", "conv2", "conv layer to substitute")
        .opt("limit", "200", "test images to evaluate");
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let bundle = ArtifactBundle::load(a.get("artifacts"))?;
    let meta = bundle
        .cnn
        .clone()
        .ok_or_else(|| Error::Artifact("bundle has no CNN export".into()))?;
    let cnn = cuspamm::cnn::Cnn::load(&meta)?;
    let engine = SpammEngine::new(&bundle, cfg)?;

    let mut modes = std::collections::BTreeMap::new();
    let baseline = cnn.accuracy(&modes, Some(&engine), 100, Some(a.usize("limit")?))?;
    let tau = a.f64("tau")? as f32;
    modes.insert(a.get("layer").to_string(), cuspamm::cnn::GemmMode::Spamm { tau });
    let approx = cnn.accuracy(&modes, Some(&engine), 100, Some(a.usize("limit")?))?;
    println!(
        "layer {} τ={}: accuracy {:.2}% → {:.2}% (Δ {:+.2}%)",
        a.get("layer"),
        tau,
        baseline * 100.0,
        approx * 100.0,
        (approx - baseline) * 100.0
    );
    Ok(())
}

/// `cuspamm audit`: static invariant verification — no kernels are
/// launched by any verb.  `plan` builds a schedule + assignment
/// host-side and sweeps culling/strategy/packed-run/ownership
/// invariants; `session` drives representative workloads through a
/// live session and audits its plan table, expression dataflow, pool
/// accounting, and pins; `store` cross-checks a warm-store manifest
/// against its payloads.  `--smoke` is the CI contract: every workload
/// class must audit clean, then one corruption per violation class is
/// seeded and the auditor must catch each with the correct report kind.
fn cmd_audit(args: &[String]) -> Result<()> {
    use cuspamm::audit;
    use cuspamm::matrix::tiling::PaddedMatrix;
    use cuspamm::spamm::balance::Assignment;
    use cuspamm::spamm::normmap::{normmap_with_density, resolve_density_threshold};
    use cuspamm::spamm::Schedule;

    let spec = common(Spec::new(
        "cuspamm audit",
        "static invariant auditor — verbs: plan (schedule + assignment \
         soundness for a synthetic workload), session (audit a live session \
         after multiply/expr/update workloads), store (manifest/payload \
         cross-check of --store-dir); --smoke runs every workload class, \
         requires each audit clean, then seeds one corruption per violation \
         class and requires detection with the correct kind",
    ))
    .opt("n", "256", "matrix size (rounded down to a LoNum multiple)")
    .opt("tau", "1e-4", "SpAMM threshold τ")
    .opt("seed", "7", "workload seed")
    .flag(
        "smoke",
        "CI assertion: all workload classes audit clean + every seeded \
         violation class is detected",
    );
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    if a.flag("smoke") {
        return audit_smoke(&a, cfg);
    }
    let verb = a.positionals.first().map(|s| s.as_str()).unwrap_or("session");
    match verb {
        "plan" => {
            let bundle = load_bundle_or_hostsim(&a)?;
            let l = bundle.lonum;
            let n = (a.usize("n")?.max(2 * l) / l) * l;
            let tau = a.f64("tau")? as f32;
            let seed = a.usize("seed")? as u64;
            let ma = Matrix::decay_algebraic(n, 0.1, 0.1, seed);
            let mb = Matrix::decay_algebraic(n, 0.1, 0.1, seed + 1);
            let na = normmap_with_density(&PaddedMatrix::new(&ma, l));
            let nb = normmap_with_density(&PaddedMatrix::new(&mb, l));
            let dt = resolve_density_threshold(&cfg, &na, &nb);
            let sched = Schedule::build_adaptive(&na, &nb, tau, dt)?;
            let asg = Assignment::build(&sched, cfg.devices, cfg.balance);
            let mut r = audit::audit_schedule(&na, &nb, tau, dt, &sched);
            r.merge(audit::audit_assignment(&sched, &asg));
            report_gate("plan", &r)
        }
        "session" => {
            let bundle = load_bundle_or_hostsim(&a)?;
            let session = SpammSession::new(&bundle, cfg)?;
            audit_run_workloads(&a, &bundle, &session)?;
            report_gate("session", &session.audit()?)
        }
        "store" => {
            if cfg.store_dir.is_empty() {
                return Err(Error::Config(
                    "audit store: pass --store-dir <dir> (or a --config whose \
                     store_dir is set)"
                        .into(),
                ));
            }
            let store = WarmStore::open(std::path::Path::new(&cfg.store_dir))?;
            report_gate("store", &audit::audit_store(&store))
        }
        other => Err(Error::Config(format!(
            "unknown audit verb '{other}' (plan | session | store)"
        ))),
    }
}

/// Print an audit report and turn any violation into a nonzero exit.
fn report_gate(what: &str, r: &cuspamm::audit::AuditReport) -> Result<()> {
    r.publish();
    for v in &r.violations {
        println!("VIOLATION {v}");
    }
    println!(
        "audit {what}: {} checks, {} violations",
        r.checks,
        r.violations.len()
    );
    if r.ok() {
        Ok(())
    } else {
        Err(Error::Audit(format!(
            "audit {what}: {} invariant violations",
            r.violations.len()
        )))
    }
}

/// The representative workload mix behind `audit session` and the clean
/// half of `audit --smoke`: a prepared multiply, a mixed-priority serve
/// burst, an A³ expression chain, and a delta update with a warm
/// re-submit — the session is left live for `SpammSession::audit`.
fn audit_run_workloads(
    a: &cuspamm::cli::Args,
    bundle: &ArtifactBundle,
    session: &SpammSession,
) -> Result<()> {
    let l = bundle.lonum;
    let n = (a.usize("n")?.max(2 * l) / l) * l;
    let tau = a.f64("tau")? as f32;
    let seed = a.usize("seed")? as u64;
    let host_a = Matrix::decay_algebraic(n, 0.1, 0.1, seed);
    let host_b = Matrix::decay_algebraic(n, 0.1, 0.1, seed + 1);

    // multiply: one prepared plan, one submit.
    let ida = session.put(&host_a)?;
    let idb = session.put(&host_b)?;
    let plan = session.prepare(ida, idb, Approx::Tau(tau))?;
    session.wait(session.submit(plan)?)?;

    // serve: a mixed-priority burst over the warm plan.
    for pri in [Priority::High, Priority::Normal, Priority::Low] {
        session.submit_with(plan, pri)?;
    }
    session.wait_all()?;

    // expr: an A³ chain through the expression planner.
    let mut g = ExprGraph::new();
    let leaf = g.operand();
    let c2 = g.spamm(leaf, leaf, Approx::Tau(tau));
    let c3 = g.spamm(c2, leaf, Approx::Tau(tau));
    g.output(c3);
    let eplan = session.prepare_expr(&g, &[ida])?;
    session.wait(session.submit_expr(eplan)?)?;

    // update: drift two tiles, then a warm submit on the repaired plan.
    let l2 = l * l;
    let side = n / l;
    let mut changed = vec![(0usize, 0usize)];
    if side > 1 {
        changed.push((1, side - 1));
    }
    let mut data = Vec::with_capacity(changed.len() * l2);
    for (k, _) in changed.iter().enumerate() {
        let block = Matrix::randn(l, l, seed + 100 + k as u64);
        data.extend(block.data().iter().map(|x| x * 0.05));
    }
    session.update(ida, &changed, &data)?;
    session.wait(session.submit(plan)?)?;
    Ok(())
}

/// `audit --smoke`: the clean workloads, then seeded corruption per
/// violation class.  Runs against a throwaway warm store so the store
/// sweep has real payloads to corrupt.
fn audit_smoke(a: &cuspamm::cli::Args, mut cfg: SpammConfig) -> Result<()> {
    use cuspamm::audit::{self, AuditKind, AuditReport};
    use cuspamm::spamm::balance::Assignment;
    use cuspamm::spamm::cache::Fingerprint;
    use cuspamm::spamm::{NormMap, Schedule, TileStrategy};

    fn expect_detected(r: &AuditReport, kind: AuditKind, what: &str) -> Result<()> {
        match r.find(kind) {
            Some(v) => {
                println!("  detected {what}: {v}");
                Ok(())
            }
            None => Err(Error::Audit(format!(
                "seeded {what} was NOT detected as {kind:?} \
                 (got {} other violations)",
                r.violations.len()
            ))),
        }
    }

    let tmp = std::env::temp_dir().join(format!("cuspamm-audit-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    cfg.store_dir = tmp.to_string_lossy().into_owned();
    cfg.store_enabled = true;
    let bundle = load_bundle_or_hostsim(a)?;

    // -- Phase 1: every workload class must audit clean. ----------------
    let session = SpammSession::new(&bundle, cfg.clone())?;
    audit_run_workloads(a, &bundle, &session)?;
    report_gate("smoke workloads (multiply/serve/expr/update)", &session.audit()?)?;
    drop(session);

    // warmstart: a fresh session over the same store must also audit
    // clean after restoring its artifacts from disk.
    {
        let warm = SpammSession::new(&bundle, cfg.clone())?;
        let l = bundle.lonum;
        let n = (a.usize("n")?.max(2 * l) / l) * l;
        let tau = a.f64("tau")? as f32;
        let seed = a.usize("seed")? as u64;
        let wa = warm.put(&Matrix::decay_algebraic(n, 0.1, 0.1, seed))?;
        let wb = warm.put(&Matrix::decay_algebraic(n, 0.1, 0.1, seed + 1))?;
        let wp = warm.prepare(wa, wb, Approx::Tau(tau))?;
        warm.wait(warm.submit(wp)?)?;
        report_gate("smoke workload (warmstart)", &warm.audit()?)?;
    }
    let store = WarmStore::open(&tmp)?;
    report_gate("smoke store", &audit::audit_store(&store))?;

    // -- Phase 2: seeded corruption per violation class. ----------------
    println!("seeding one corruption per violation class:");

    // A synthetic 2×2-output grid, contraction depth 3, engineered so
    // every culling/strategy/packed case appears (τ = 1, threshold 0.5):
    //   slot (0,0): ks [0]    [Dense]
    //   slot (0,1): ks [0,1]  [Packed, Packed]
    //   slot (1,0): ks [0]    [Dense]
    //   slot (1,1): ks [0,1]  [Dense, Dense]
    let na = NormMap {
        norms: Matrix::from_vec(2, 3, vec![2.0, 1.0, 0.1, 1.0, 2.0, 0.5])?,
        density: Matrix::from_vec(2, 3, vec![0.1, 0.1, 1.0, 1.0, 1.0, 1.0])?,
    };
    let nb = NormMap {
        norms: Matrix::from_vec(3, 2, vec![2.0, 1.0, 0.1, 2.0, 1.0, 1.0])?,
        density: Matrix::from_vec(3, 2, vec![1.0, 0.1, 1.0, 0.1, 1.0, 1.0])?,
    };
    let (tau, dt) = (1.0f32, 0.5f32);
    let pristine = Schedule::build_adaptive(&na, &nb, tau, dt)?;
    let base = audit::audit_schedule(&na, &nb, tau, dt, &pristine);
    if !base.ok() {
        return Err(Error::Audit(
            "the pristine synthetic schedule failed its own audit".into(),
        ));
    }

    // Un-cull a below-τ product (k=1 in slot (0,0) has bound 0.1 < 1).
    let mut s = pristine.clone();
    s.valid_k[0].push(1);
    s.strategies[0].push(TileStrategy::Dense);
    expect_detected(
        &audit::audit_schedule(&na, &nb, tau, dt, &s),
        AuditKind::SpuriousProduct,
        "un-culled below-τ product",
    )?;

    // Drop a surviving product (k=0 in slot (1,1) has bound 1 ≥ 1).
    let mut s = pristine.clone();
    s.valid_k[3].remove(0);
    s.strategies[3].remove(0);
    expect_detected(
        &audit::audit_schedule(&na, &nb, tau, dt, &s),
        AuditKind::MissedProduct,
        "dropped surviving product",
    )?;

    // Break k-list ordering (compaction requires strictly ascending k).
    let mut s = pristine.clone();
    s.valid_k[3].swap(0, 1);
    expect_detected(
        &audit::audit_schedule(&na, &nb, tau, dt, &s),
        AuditKind::MalformedKList,
        "descending k-list",
    )?;

    // Mistag a dense product as sparse (census says both tiles dense).
    let mut s = pristine.clone();
    s.strategies[2][0] = TileStrategy::Sparse;
    expect_detected(
        &audit::audit_schedule(&na, &nb, tau, dt, &s),
        AuditKind::StrategyMismatch,
        "dense product mistagged sparse",
    )?;

    // Split a packed run (second element of the (0,1) run de-packed).
    let mut s = pristine.clone();
    s.strategies[1][1] = TileStrategy::Dense;
    expect_detected(
        &audit::audit_schedule(&na, &nb, tau, dt, &s),
        AuditKind::BrokenPackedRun,
        "split packed run",
    )?;

    // Ownership: a short owner map, then an out-of-range device.
    let asg = Assignment::build(&pristine, 2, cuspamm::config::Balance::RowBlock);
    let mut bad = asg.clone();
    bad.owner.pop();
    expect_detected(
        &audit::audit_assignment(&pristine, &bad),
        AuditKind::OwnerMapMismatch,
        "owner map shorter than the tile grid",
    )?;
    let mut bad = asg.clone();
    bad.owner[0] = 9;
    expect_detected(
        &audit::audit_assignment(&pristine, &bad),
        AuditKind::OwnerOutOfRange,
        "tile owned by a nonexistent device",
    )?;

    // Residency: a pin no live plan accounts for.
    let pool = cuspamm::runtime::residency::ResidencyPool::new(1 << 20);
    pool.pin_operand(Fingerprint(0xdead, 0xbeef));
    let live: std::collections::HashSet<Fingerprint> = std::collections::HashSet::new();
    expect_detected(
        &audit::audit_pool(&pool, Some(&live)),
        AuditKind::OrphanPin,
        "pin with no live plan",
    )?;

    // Store: corrupt three distinct on-disk payloads — a flipped byte, a
    // truncation, a deletion — and require the matching kinds.
    let objects: Vec<(String, cuspamm::store::Entry)> = store
        .entries()?
        .into_iter()
        .filter(|(_, e)| e.kind != "bundle")
        .collect();
    if objects.len() < 3 {
        return Err(Error::Audit(format!(
            "smoke store has {} object payloads, need 3 to corrupt",
            objects.len()
        )));
    }
    let path0 = tmp.join(&objects[0].1.path);
    let mut bytes = std::fs::read(&path0)?;
    if let Some(last) = bytes.last_mut() {
        *last ^= 0xFF;
    }
    std::fs::write(&path0, &bytes)?;
    let path1 = tmp.join(&objects[1].1.path);
    let bytes = std::fs::read(&path1)?;
    std::fs::write(&path1, &bytes[..bytes.len().saturating_sub(1)])?;
    std::fs::remove_file(tmp.join(&objects[2].1.path))?;
    let r = audit::audit_store(&store);
    expect_detected(&r, AuditKind::StoreChecksum, "flipped payload byte")?;
    expect_detected(&r, AuditKind::StoreSizeMismatch, "truncated payload")?;
    expect_detected(&r, AuditKind::StoreUnreadable, "deleted payload")?;

    // Healing must evict exactly the corrupted entries and leave the
    // store clean again.
    let healed = store.verify(true)?;
    if healed.bad.len() != 3 {
        return Err(Error::Audit(format!(
            "heal evicted {} entries, expected the 3 corrupted ones",
            healed.bad.len()
        )));
    }
    report_gate("smoke store (healed)", &audit::audit_store(&store))?;

    let _ = std::fs::remove_dir_all(&tmp);
    println!(
        "audit --smoke: all workload classes clean, all 11 seeded violation \
         classes detected"
    );
    Ok(())
}
