//! cuspamm CLI — the Layer-3 launcher.
//!
//!   cuspamm info                          list artifacts + platform
//!   cuspamm run   --n 1024 --ratio 0.10   tuned SpAMM vs dense, with stats
//!   cuspamm tune  --n 1024 --ratio 0.10   τ search only (§3.5.2)
//!   cuspamm cnn   --tau 2.5 --layer conv2 case-study CNN accuracy probe
//!
//! Global options: --artifacts <dir>, --devices, --precision, --balance,
//! --config <file> (key = value overrides, see config::SpammConfig).

use cuspamm::cli::Spec;
use cuspamm::config::SpammConfig;
use cuspamm::coordinator::Coordinator;
use cuspamm::error::{Error, Result};
use cuspamm::matrix::Matrix;
use cuspamm::prelude::*;
use cuspamm::telemetry;

fn main() {
    telemetry::init_logging();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(Error::Config(msg)) => {
            eprintln!("{msg}");
            2
        }
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn common(spec: Spec) -> Spec {
    // Declared option defaults mirror SpammConfig::default() — derived,
    // not hand-synced, so the two default sources cannot drift.
    let d = SpammConfig::default();
    let balance = match d.balance {
        cuspamm::config::Balance::RowBlock => "rowblock".to_string(),
        cuspamm::config::Balance::Strided(s) => format!("strided:{s}"),
    };
    spec.opt("artifacts", "artifacts", "artifact bundle directory")
        .opt("devices", &d.devices.to_string(), "simulated device count")
        .opt("precision", d.precision.as_str(), "f32 | bf16")
        .opt("balance", &balance, "rowblock | strided:<s>")
        .opt(
            "pipeline-depth",
            &d.pipeline_depth.to_string(),
            "chunks buffered between executor pipeline stages (gather/exec/scatter)",
        )
        .flag(
            "no-cache",
            "disable normmap/schedule caching across multiplies",
        )
        .flag(
            "no-residency",
            "disable the device-resident operand-tile pools",
        )
        .opt(
            "device-mem-budget",
            "256m",
            "per-device resident-tile byte budget (k/m/g suffixes; 0 = unlimited)",
        )
        .opt("config", "", "optional config file (key = value)")
}

fn build_config(a: &cuspamm::cli::Args) -> Result<SpammConfig> {
    let mut cfg = if a.get("config").is_empty() {
        SpammConfig::default()
    } else {
        SpammConfig::from_file(std::path::Path::new(a.get("config")))?
    };
    // CLI > config file > built-in defaults: when a config file is in
    // play, only explicitly-passed options override it (the declared CLI
    // defaults mirror SpammConfig::default(), which the file was folded
    // over already).
    let from_file = !a.get("config").is_empty();
    for (opt, key) in [
        ("devices", "devices"),
        ("precision", "precision"),
        ("balance", "balance"),
        ("pipeline-depth", "pipeline_depth"),
        ("device-mem-budget", "device_mem_budget"),
    ] {
        if a.provided(opt) || !from_file {
            cfg.apply(key, a.get(opt))?;
        }
    }
    if a.flag("no-cache") {
        cfg.cache_enabled = false;
    }
    if a.flag("no-residency") {
        cfg.residency_enabled = false;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn dispatch(args: &[String]) -> Result<()> {
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if args.is_empty() { &[][..] } else { &args[1..] };
    match cmd {
        "info" => cmd_info(rest),
        "run" => cmd_run(rest),
        "tune" => cmd_tune(rest),
        "cnn" => cmd_cnn(rest),
        "serve" => cmd_serve(rest),
        "help" | "--help" | "-h" => {
            println!(
                "cuspamm — SpAMM on an AOT-compiled XLA runtime\n\n\
                 subcommands:\n  info   list the artifact bundle\n  run    \
                 tuned SpAMM vs dense baseline\n  tune   τ search for a valid \
                 ratio\n  cnn    case-study CNN accuracy probe\n  serve  \
                 process a synthetic request trace with service stats\n\nUse \
                 `cuspamm <cmd> --help` for options."
            );
            Ok(())
        }
        other => Err(Error::Config(format!(
            "unknown subcommand '{other}' (try `cuspamm help`)"
        ))),
    }
}

fn cmd_info(args: &[String]) -> Result<()> {
    let spec = common(Spec::new("cuspamm info", "inspect the artifact bundle"));
    let a = spec.parse(args)?;
    let bundle = ArtifactBundle::load(a.get("artifacts"))?;
    println!("artifact bundle: {}", bundle.dir.display());
    println!("LoNum: {}", bundle.lonum);
    for name in bundle.names() {
        let m = bundle.get(name)?;
        println!("  {:32} kind={:12} inputs={:?}", m.name, m.kind, m.input_shapes);
    }
    if let Some(cnn) = &bundle.cnn {
        println!(
            "cnn: {} conv layers, build-time test accuracy {:.2}%",
            cnn.conv_specs.len(),
            cnn.test_accuracy * 100.0
        );
    }
    Ok(())
}

fn cmd_run(args: &[String]) -> Result<()> {
    let spec = common(Spec::new("cuspamm run", "tuned SpAMM vs the dense baseline"))
        .opt("n", "1024", "matrix size (needs a dense_n<N> artifact)")
        .opt("ratio", "0.10", "target valid ratio")
        .opt("seed", "7", "workload seed")
        .opt("kind", "algebraic", "decay kind: algebraic | exponential");
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let n = a.usize("n")?;
    let ratio = a.f64("ratio")?;
    let seed = a.usize("seed")? as u64;

    let bundle = ArtifactBundle::load(a.get("artifacts"))?;
    let coord = Coordinator::new(&bundle, cfg.clone())?;

    let (ma, mb) = match a.get("kind") {
        "exponential" => (
            Matrix::decay_exponential(n, 1.0, 0.5, seed),
            Matrix::decay_exponential(n, 1.0, 0.5, seed + 1),
        ),
        _ => (
            Matrix::decay_algebraic(n, 0.1, 0.1, seed),
            Matrix::decay_algebraic(n, 0.1, 0.1, seed + 1),
        ),
    };

    let tuned = coord.tune_tau(&ma, &mb, ratio)?;
    println!(
        "tuned τ = {:.6e} (achieved ratio {:.2}%, {} iters, expansion k={})",
        tuned.tau,
        tuned.achieved_ratio * 100.0,
        tuned.iters,
        tuned.expansion_k
    );

    let report = coord.multiply(&ma, &mb, tuned.tau)?;
    println!("spamm: {}", report.summary_line());

    let dense = coord.dense(&ma, &mb)?;
    println!("dense: wall {:.3}s", dense.wall_secs);
    println!(
        "speedup: {:.2}x   ‖E‖_F = {:.4e}  (‖C‖_F = {:.4e})",
        dense.wall_secs / report.wall_secs,
        report.c.error_fnorm(&dense.c)?,
        dense.c.fnorm()
    );
    let t = telemetry::global();
    println!(
        "caches: norm {} hit / {} miss, schedule {} hit / {} miss",
        t.get("spamm.norm_cache.hits"),
        t.get("spamm.norm_cache.misses"),
        t.get("spamm.schedule_cache.hits"),
        t.get("spamm.schedule_cache.misses")
    );
    // All five figures share the same scope: the SpAMM multiply above.
    println!(
        "residency: {} hit / {} miss / {} evicted, {} KiB uploaded, {} KiB saved",
        report.stage.residency_hits,
        report.stage.residency_misses,
        report.stage.residency_evictions,
        report.stage.transfer_bytes / 1024,
        report.stage.transfer_saved_bytes / 1024
    );
    Ok(())
}

fn cmd_tune(args: &[String]) -> Result<()> {
    let spec = common(Spec::new("cuspamm tune", "τ search (§3.5.2)"))
        .opt("n", "1024", "matrix size")
        .opt("ratio", "0.10", "target valid ratio")
        .opt("seed", "7", "workload seed");
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let bundle = ArtifactBundle::load(a.get("artifacts"))?;
    let coord = Coordinator::new(&bundle, cfg)?;
    let n = a.usize("n")?;
    let ma = Matrix::decay_algebraic(n, 0.1, 0.1, a.usize("seed")? as u64);
    let mb = Matrix::decay_algebraic(n, 0.1, 0.1, a.usize("seed")? as u64 + 1);
    let r = coord.tune_tau(&ma, &mb, a.f64("ratio")?)?;
    println!(
        "τ = {:.6e}  ratio = {:.3}%  iters = {}  expansion k = {}",
        r.tau,
        r.achieved_ratio * 100.0,
        r.iters,
        r.expansion_k
    );
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    use cuspamm::coordinator::service::{synthetic_trace, SpammService};

    let spec = common(Spec::new(
        "cuspamm serve",
        "drain a synthetic SpAMM request trace, report service stats",
    ))
    .opt("requests", "8", "number of requests in the trace")
    .opt("n", "512", "matrix size per request")
    .opt("seed", "7", "trace seed");
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let bundle = ArtifactBundle::load(a.get("artifacts"))?;
    let mut svc = SpammService::new(&bundle, cfg)?;
    for (ma, mb, approx) in
        synthetic_trace(a.usize("requests")?, a.usize("n")?, a.usize("seed")? as u64)
    {
        svc.submit(ma, mb, approx);
    }
    println!("draining {} requests ...", svc.pending());
    let (responses, stats) = svc.drain()?;
    for r in responses.iter().take(5) {
        println!(
            "  req {:3}: τ={:.3e} valid {:5.1}%  compute {:.3}s  latency {:.3}s",
            r.id,
            r.tau,
            r.valid_ratio * 100.0,
            r.compute_secs,
            r.latency_secs
        );
    }
    if responses.len() > 5 {
        println!("  ... ({} more)", responses.len() - 5);
    }
    println!(
        "completed {} in {:.3}s — {:.2} req/s, latency p50 {:.3}s p95 {:.3}s",
        stats.completed,
        stats.wall_secs,
        stats.throughput_rps,
        stats.latency.median,
        stats.latency.p95
    );
    Ok(())
}

fn cmd_cnn(args: &[String]) -> Result<()> {
    let spec = common(Spec::new("cuspamm cnn", "case-study CNN accuracy probe"))
        .opt("tau", "0.0", "SpAMM τ for the chosen layer")
        .opt("layer", "conv2", "conv layer to substitute")
        .opt("limit", "200", "test images to evaluate");
    let a = spec.parse(args)?;
    let cfg = build_config(&a)?;
    let bundle = ArtifactBundle::load(a.get("artifacts"))?;
    let meta = bundle
        .cnn
        .clone()
        .ok_or_else(|| Error::Artifact("bundle has no CNN export".into()))?;
    let cnn = cuspamm::cnn::Cnn::load(&meta)?;
    let engine = SpammEngine::new(&bundle, cfg)?;

    let mut modes = std::collections::BTreeMap::new();
    let baseline = cnn.accuracy(&modes, Some(&engine), 100, Some(a.usize("limit")?))?;
    let tau = a.f64("tau")? as f32;
    modes.insert(a.get("layer").to_string(), cuspamm::cnn::GemmMode::Spamm { tau });
    let approx = cnn.accuracy(&modes, Some(&engine), 100, Some(a.usize("limit")?))?;
    println!(
        "layer {} τ={}: accuracy {:.2}% → {:.2}% (Δ {:+.2}%)",
        a.get("layer"),
        tau,
        baseline * 100.0,
        approx * 100.0,
        (approx - baseline) * 100.0
    );
    Ok(())
}
