//! The SpAMM core: normmaps, schedule compaction (bitmap → map_offset),
//! load balance, τ tuning, reference implementations, and the
//! single-device executor.  The multi-device coordinator builds on these
//! in [`crate::coordinator`].

pub mod balance;
pub mod cache;
pub mod error_analysis;
pub mod executor;
pub mod normmap;
pub mod power;
pub mod purification;
pub mod reference;
pub mod schedule;
pub mod tuner;

pub use cache::{ExecCaches, NormCache, ScheduleCache};
pub use executor::{MultiplyStats, SpammEngine};
pub use normmap::NormMap;
pub use schedule::{Schedule, TileStrategy};
pub use tuner::{tune_tau, TuneParams, TuneResult};
