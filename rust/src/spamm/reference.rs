//! Host reference implementations: the original recursive SpAMM
//! (Algorithm 1, quad-tree) and the flat masked SpAMM — used as oracles by
//! tests and by the accuracy-analysis benches (no XLA involved).

use crate::error::{Error, Result};
use crate::matrix::tiling::PaddedMatrix;
use crate::matrix::Matrix;
use crate::spamm::normmap::normmap;
use crate::spamm::schedule::Schedule;

/// Flat SpAMM on the host: schedule + per-tile host matmuls.
/// C[i,j] = Σ_{k: ‖A[i,k]‖·‖B[k,j]‖ ≥ τ} A[i,k]·B[k,j].
pub fn spamm_flat_host(a: &Matrix, b: &Matrix, tau: f32, lonum: usize) -> Result<Matrix> {
    if a.cols() != b.rows() {
        return Err(Error::Shape(format!(
            "spamm: {}x{} @ {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    let pa = PaddedMatrix::new(a, lonum);
    let pb = PaddedMatrix::new(b, lonum);
    let na = normmap(&pa);
    let nb = normmap(&pb);
    let sched = Schedule::build(&na, &nb, tau)?;
    let mut pc = PaddedMatrix::new(&Matrix::zeros(a.rows(), b.cols()), lonum);

    let l = lonum;
    let mut ta = vec![0.0f32; l * l];
    let mut tb = vec![0.0f32; l * l];
    let mut tc = vec![0.0f32; l * l];
    for i in 0..sched.tile_rows {
        for j in 0..sched.tile_cols {
            for &k in sched.ks(i, j) {
                pa.copy_tile(i, k as usize, &mut ta);
                pb.copy_tile(k as usize, j, &mut tb);
                tile_matmul(&ta, &tb, &mut tc, l);
                pc.inner.add_block(i * l, j * l, l, &tc);
            }
        }
    }
    Ok(pc.crop())
}

fn tile_matmul(a: &[f32], b: &[f32], c: &mut [f32], l: usize) {
    c.fill(0.0);
    for i in 0..l {
        for k in 0..l {
            let av = a[i * l + k];
            if av == 0.0 {
                continue;
            }
            let brow = &b[k * l..(k + 1) * l];
            let crow = &mut c[i * l..(i + 1) * l];
            for j in 0..l {
                crow[j] += av * brow[j];
            }
        }
    }
}

/// Original recursive SpAMM (Algorithm 1): quad-tree, cut off at `lonum`.
/// Inputs must be square; they are zero-padded to the next power-of-two
/// multiple of lonum (padding norms are 0, so padded branches prune).
pub fn spamm_recursive(a: &Matrix, b: &Matrix, tau: f32, lonum: usize) -> Result<Matrix> {
    if a.rows() != a.cols() || b.rows() != b.cols() || a.rows() != b.rows() {
        return Err(Error::Shape("recursive SpAMM needs square same-size inputs".into()));
    }
    let n0 = a.rows();
    let mut n = lonum;
    while n < n0 {
        n *= 2;
    }
    let mut ap = Matrix::zeros(n, n);
    let mut bp = Matrix::zeros(n, n);
    for r in 0..n0 {
        ap.data_mut()[r * n..r * n + n0].copy_from_slice(a.row(r));
        bp.data_mut()[r * n..r * n + n0].copy_from_slice(b.row(r));
    }
    let mut cp = Matrix::zeros(n, n);
    rec(&ap, &bp, &mut cp, 0, 0, 0, 0, 0, 0, n, tau, lonum);
    let mut c = Matrix::zeros(n0, n0);
    for r in 0..n0 {
        c.data_mut()[r * n0..(r + 1) * n0].copy_from_slice(&cp.data()[r * n..r * n + n0]);
    }
    Ok(c)
}

/// Frobenius norm of the size×size block of m at (r0, c0).
fn block_norm(m: &Matrix, r0: usize, c0: usize, size: usize) -> f64 {
    let mut acc = 0.0f64;
    for r in r0..r0 + size {
        for c in c0..c0 + size {
            let x = m[(r, c)] as f64;
            acc += x * x;
        }
    }
    acc.sqrt()
}

#[allow(clippy::too_many_arguments)]
fn rec(
    a: &Matrix,
    b: &Matrix,
    c: &mut Matrix,
    ar: usize,
    ac: usize,
    br: usize,
    bc: usize,
    cr: usize,
    cc: usize,
    size: usize,
    tau: f32,
    lonum: usize,
) {
    if size <= lonum {
        // leaf: dense block multiply-accumulate
        for i in 0..size {
            for k in 0..size {
                let av = a[(ar + i, ac + k)];
                if av == 0.0 {
                    continue;
                }
                for j in 0..size {
                    c[(cr + i, cc + j)] += av * b[(br + k, bc + j)];
                }
            }
        }
        return;
    }
    let h = size / 2;
    for i in 0..2 {
        for j in 0..2 {
            for k in 0..2 {
                // Norm test on the child product (Alg. 1 lines 7/10).
                let an = block_norm(a, ar + i * h, ac + k * h, h);
                let bn = block_norm(b, br + k * h, bc + j * h, h);
                if (an * bn) as f32 >= tau {
                    rec(
                        a,
                        b,
                        c,
                        ar + i * h,
                        ac + k * h,
                        br + k * h,
                        bc + j * h,
                        cr + i * h,
                        cc + j * h,
                        h,
                        tau,
                        lonum,
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_tau_zero_is_dense() {
        let a = Matrix::randn(96, 96, 1);
        let b = Matrix::randn(96, 96, 2);
        let got = spamm_flat_host(&a, &b, 0.0, 32).unwrap();
        let want = a.matmul(&b).unwrap();
        assert!(got.error_fnorm(&want).unwrap() < 1e-2);
    }

    #[test]
    fn flat_rectangular_with_padding() {
        let a = Matrix::randn(50, 70, 3);
        let b = Matrix::randn(70, 40, 4);
        let got = spamm_flat_host(&a, &b, 0.0, 32).unwrap();
        let want = a.matmul(&b).unwrap();
        assert_eq!((got.rows(), got.cols()), (50, 40));
        assert!(got.error_fnorm(&want).unwrap() < 1e-2);
    }

    #[test]
    fn recursive_tau_zero_is_dense() {
        let a = Matrix::randn(64, 64, 5);
        let b = Matrix::randn(64, 64, 6);
        let got = spamm_recursive(&a, &b, 0.0, 32).unwrap();
        let want = a.matmul(&b).unwrap();
        assert!(got.error_fnorm(&want).unwrap() < 1e-2);
    }

    #[test]
    fn recursive_non_pow2_padding() {
        let a = Matrix::randn(48, 48, 7);
        let b = Matrix::randn(48, 48, 8);
        let got = spamm_recursive(&a, &b, 0.0, 16).unwrap();
        let want = a.matmul(&b).unwrap();
        assert!(got.error_fnorm(&want).unwrap() < 1e-2);
    }

    #[test]
    fn flat_error_monotone_in_tau() {
        let a = Matrix::decay_exponential(128, 1.0, 0.5, 9);
        let b = Matrix::decay_exponential(128, 1.0, 0.5, 10);
        let exact = a.matmul(&b).unwrap();
        let mut prev = -1.0;
        for tau in [0.0f32, 1e-4, 1e-2, 1.0] {
            let c = spamm_flat_host(&a, &b, tau, 32).unwrap();
            let e = exact.error_fnorm(&c).unwrap();
            assert!(e >= prev - 1e-9, "tau={tau}: {e} < {prev}");
            prev = e;
        }
    }

    #[test]
    fn flat_error_bounded_by_recursive_error() {
        // Interior pruning makes recursion skip ⊇ flat skips.
        let a = Matrix::decay_exponential(128, 1.0, 0.5, 11);
        let b = Matrix::decay_exponential(128, 1.0, 0.5, 12);
        let exact = a.matmul(&b).unwrap();
        for tau in [1e-3f32, 1e-2, 1e-1] {
            let ef = exact
                .error_fnorm(&spamm_flat_host(&a, &b, tau, 32).unwrap())
                .unwrap();
            let er = exact
                .error_fnorm(&spamm_recursive(&a, &b, tau, 32).unwrap())
                .unwrap();
            assert!(ef <= er + 1e-3, "tau={tau}: flat {ef} rec {er}");
        }
    }

    #[test]
    fn huge_tau_gives_zero() {
        let a = Matrix::randn(64, 64, 13);
        let c = spamm_flat_host(&a, &a, f32::MAX, 32).unwrap();
        assert_eq!(c.fnorm(), 0.0);
    }
}
