//! Density-matrix purification under SpAMM — the application SpAMM was
//! invented for (Challacombe & Bock; the paper's electronic-structure
//! motivation, refs [5, 11, 26]).
//!
//! McWeeny purification iterates  P ← 3P² − 2P³  to drive a near-idempotent
//! density matrix to the exact spectral projector.  Each iteration is two
//! decay-matrix products — exactly SpAMM's sweet spot — and purification is
//! self-correcting, so per-step SpAMM error is tolerated (the same
//! robustness the paper exploits for CNNs in §4.3.2).
//!
//! [`mcweeny_purify`] drives each iteration as an expression graph
//! ([`crate::coordinator::expr`]): P², P³, the 3P²−2P³ combine, and the
//! idempotency residual all run device-side, and the iterate chains into
//! the next iteration as a device-resident value — P never round-trips
//! through the host until the final download.  The pre-expression driver
//! survives as [`mcweeny_purify_loop`], the bitwise-identical A/B
//! baseline.

use std::time::Instant;

use crate::coordinator::expr::{ExprGraph, ExprSource, ExprValue};
use crate::coordinator::{Approx, Coordinator};
use crate::error::Result;
use crate::matrix::Matrix;

/// Per-iteration record.
#[derive(Clone, Debug)]
pub struct PurifyStep {
    pub iter: usize,
    /// Idempotency residual ‖P² − P‖_F (convergence measure).
    pub idempotency_err: f64,
    /// Headline ratio of the iteration: the *minimum* of the two
    /// products' valid ratios (both recorded below — the old field
    /// silently reported only the P·P product).
    pub valid_ratio: f64,
    /// Valid ratio of the P·P product.
    pub valid_ratio_p2: f64,
    /// Valid ratio of the P²·P product.
    pub valid_ratio_p3: f64,
    /// Full iteration wall: both multiplies **plus** the 3P²−2P³ combine
    /// (the old field omitted the combine).
    pub wall_secs: f64,
    /// Seconds inside the combine alone (host elementwise on the loop
    /// path, device-side axpby on the expression path).
    pub combine_secs: f64,
}

/// Result of a purification run.
pub struct PurifyResult {
    pub p: Matrix,
    pub steps: Vec<PurifyStep>,
    pub converged: bool,
}

/// Build a near-idempotent decay matrix to purify: P0 = V·diag(f)·Vᵀ with
/// occupations f pushed near {0, 1} would need an eigensolver; instead we
/// use the standard trick of starting from a scaled banded Hamiltonian:
/// P0 = (μI − H)/λ mapped into [0, 1] spectrum-wise, which for a
/// diagonally-dominant decay H is near-idempotent enough for McWeeny to
/// converge and keeps the decay structure SpAMM needs.
pub fn initial_density(n: usize, seed: u64) -> Matrix {
    // Symmetric banded decay matrix.
    let h = Matrix::decay_exponential(n, 1.0, 0.5, seed);
    let mut sym = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            sym[(i, j)] = 0.5 * (h[(i, j)] + h[(j, i)]);
        }
    }
    // Gershgorin bounds → affine map of the spectrum into ~[0.05, 0.95].
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for i in 0..n {
        let d = sym[(i, i)] as f64;
        let r: f64 = (0..n)
            .filter(|&j| j != i)
            .map(|j| (sym[(i, j)] as f64).abs())
            .sum();
        lo = lo.min(d - r);
        hi = hi.max(d + r);
    }
    let scale = 0.9 / (hi - lo).max(1e-12);
    let mut p = sym;
    p.scale(scale as f32);
    let shift = (0.05 - lo * scale) as f32;
    for i in 0..n {
        p[(i, i)] += shift;
    }
    p
}

/// Run McWeeny purification with SpAMM products at threshold τ, one
/// expression graph per iteration with the iterate chained
/// device-resident between iterations.
pub fn mcweeny_purify(
    coord: &Coordinator,
    p0: &Matrix,
    tau: f32,
    max_iters: usize,
    tol: f64,
) -> Result<PurifyResult> {
    // One graph shape serves every iteration; only the input binding
    // changes (host P₀ cold, resident iterate thereafter).
    let mut g = ExprGraph::new();
    let p = g.operand();
    let p2 = g.spamm(p, p, Approx::Tau(tau));
    let idem = g.diff_fnorm(p2, p); // ‖P² − P‖_F, device-side
    let p3 = g.spamm(p2, p, Approx::Tau(tau));
    let next = g.axpby(3.0, p2, -2.0, p3); // P ← 3P² − 2P³
    g.output(next);

    let mut steps = Vec::new();
    let mut value: Option<ExprValue> = None;
    for iter in 0..max_iters {
        let rep = {
            // The plan (holding a pin on the chained input) drops right
            // after execution so the superseded iterate can be evicted.
            let plan = match &value {
                None => coord.prepare_expr(&g, &[ExprSource::Host(p0)])?,
                Some(v) => coord.prepare_expr(&g, &[ExprSource::Resident(v)])?,
            };
            coord.execute_expr(&plan)?
        };
        let idem_v = rep.scalar(idem).expect("diff node is always reported");
        let r2 = rep.node(p2).expect("P² node is always reported");
        let r3 = rep.node(p3).expect("P³ node is always reported");
        let rc = rep.node(next).expect("combine node is always reported");
        steps.push(PurifyStep {
            iter,
            idempotency_err: idem_v,
            valid_ratio: r2.valid_ratio.min(r3.valid_ratio),
            valid_ratio_p2: r2.valid_ratio,
            valid_ratio_p3: r3.valid_ratio,
            wall_secs: r2.wall_secs + r3.wall_secs + rc.wall_secs,
            combine_secs: rc.wall_secs,
        });
        // The superseded iterate retires here — free its device tiles
        // eagerly instead of leaving them as LRU prey.
        if let Some(old) = value.take() {
            coord.evict_value(old);
        }
        if idem_v < tol {
            let p = rep.value.to_matrix(); // the run's one download
            coord.evict_value(rep.value);
            return Ok(PurifyResult {
                p,
                steps,
                converged: true,
            });
        }
        value = Some(rep.value);
    }
    let converged = steps
        .last()
        .map(|s| s.idempotency_err < tol * 10.0)
        .unwrap_or(false);
    let p = match &value {
        Some(v) => v.to_matrix(),
        None => p0.clone(), // max_iters == 0
    };
    if let Some(v) = value.take() {
        coord.evict_value(v);
    }
    Ok(PurifyResult {
        p,
        steps,
        converged,
    })
}

/// The pre-expression driver: one `Coordinator::multiply` per product,
/// every iterate pulled to host, combined element-wise on the CPU, and
/// re-uploaded next iteration.  Kept as the A/B baseline — bitwise
/// identical to [`mcweeny_purify`] at the same τ (including the
/// idempotency residuals, so the two paths take identical convergence
/// decisions).
pub fn mcweeny_purify_loop(
    coord: &Coordinator,
    p0: &Matrix,
    tau: f32,
    max_iters: usize,
    tol: f64,
) -> Result<PurifyResult> {
    let mut p = p0.clone();
    let mut steps = Vec::new();
    for iter in 0..max_iters {
        let rep2 = coord.multiply(&p, &p, tau)?; // P²
        let p2 = rep2.c;
        // idempotency residual before update
        let idem = p2.error_fnorm(&p)?;
        let rep3 = coord.multiply(&p2, &p, tau)?; // P³
        let p3 = rep3.c;
        // P ← 3P² − 2P³ (host combine — timed, unlike the old driver).
        let t_combine = Instant::now();
        let mut next = p2.clone();
        for ((nx, &a), &b) in next
            .data_mut()
            .iter_mut()
            .zip(p2.data())
            .zip(p3.data())
        {
            *nx = 3.0 * a - 2.0 * b;
        }
        let combine_secs = t_combine.elapsed().as_secs_f64();
        steps.push(PurifyStep {
            iter,
            idempotency_err: idem,
            valid_ratio: rep2.valid_ratio.min(rep3.valid_ratio),
            valid_ratio_p2: rep2.valid_ratio,
            valid_ratio_p3: rep3.valid_ratio,
            wall_secs: rep2.wall_secs + rep3.wall_secs + combine_secs,
            combine_secs,
        });
        p = next;
        if idem < tol {
            return Ok(PurifyResult {
                p,
                steps,
                converged: true,
            });
        }
    }
    let converged = steps
        .last()
        .map(|s| s.idempotency_err < tol * 10.0)
        .unwrap_or(false);
    Ok(PurifyResult {
        p,
        steps,
        converged,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpammConfig;
    use crate::runtime::ArtifactBundle;

    fn bundle() -> Option<ArtifactBundle> {
        // Real AOT bundle when present, offline hostsim bundle otherwise.
        crate::runtime::hostsim::find_or_test_bundle().ok()
    }

    #[test]
    fn initial_density_is_symmetric_decay() {
        let p = initial_density(96, 1);
        for i in 0..96 {
            for j in 0..96 {
                assert!((p[(i, j)] - p[(j, i)]).abs() < 1e-6);
            }
        }
        // decay: far corner ≪ diagonal scale
        assert!(p[(0, 90)].abs() < 0.05 * p.fnorm() as f32 / 96.0 + 1e-2);
    }

    #[test]
    fn purification_reduces_idempotency_error() {
        let Some(b) = bundle() else { return };
        let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
        let p0 = initial_density(96, 2);
        let r = mcweeny_purify(&coord, &p0, 0.0, 30, 1e-6).unwrap();
        assert!(r.steps.len() >= 2);
        let first = r.steps.first().unwrap().idempotency_err;
        let last = r.steps.last().unwrap().idempotency_err;
        assert!(
            last < first,
            "purification must make progress: {first} → {last}"
        );
        // Both products' ratios are recorded and the combine is timed.
        for s in &r.steps {
            assert!(s.valid_ratio <= s.valid_ratio_p2.min(s.valid_ratio_p3) + 1e-12);
            assert!(s.wall_secs >= s.combine_secs);
        }
    }

    #[test]
    fn expr_and_loop_paths_agree_bitwise() {
        let Some(b) = bundle() else { return };
        for tau in [0.0f32, 1e-5] {
            let c1 = Coordinator::new(&b, SpammConfig::default()).unwrap();
            let c2 = Coordinator::new(&b, SpammConfig::default()).unwrap();
            let p0 = initial_density(96, 4);
            let expr = mcweeny_purify(&c1, &p0, tau, 4, 0.0).unwrap();
            let looped = mcweeny_purify_loop(&c2, &p0, tau, 4, 0.0).unwrap();
            assert_eq!(
                expr.p.data(),
                looped.p.data(),
                "expr vs loop diverged at τ={tau}"
            );
            assert_eq!(expr.steps.len(), looped.steps.len());
            for (se, sl) in expr.steps.iter().zip(&looped.steps) {
                // Residuals match bitwise → identical convergence control
                // flow even for tol > 0.
                assert_eq!(
                    se.idempotency_err.to_bits(),
                    sl.idempotency_err.to_bits(),
                    "idempotency residual diverged at τ={tau}"
                );
                assert_eq!(se.valid_ratio_p2, sl.valid_ratio_p2);
                assert_eq!(se.valid_ratio_p3, sl.valid_ratio_p3);
            }
        }
    }

    #[test]
    fn spamm_purification_tracks_exact() {
        let Some(b) = bundle() else { return };
        let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
        let p0 = initial_density(96, 3);
        let exact = mcweeny_purify(&coord, &p0, 0.0, 10, 0.0).unwrap();
        let approx = mcweeny_purify(&coord, &p0, 1e-6, 10, 0.0).unwrap();
        let rel = approx.p.error_fnorm(&exact.p).unwrap() / exact.p.fnorm().max(1e-30);
        assert!(rel < 1e-2, "rel divergence {rel}");
    }
}
