//! Density-matrix purification under SpAMM — the application SpAMM was
//! invented for (Challacombe & Bock; the paper's electronic-structure
//! motivation, refs [5, 11, 26]).
//!
//! McWeeny purification iterates  P ← 3P² − 2P³  to drive a near-idempotent
//! density matrix to the exact spectral projector.  Each iteration is two
//! decay-matrix products — exactly SpAMM's sweet spot — and purification is
//! self-correcting, so per-step SpAMM error is tolerated (the same
//! robustness the paper exploits for CNNs in §4.3.2).

use crate::coordinator::Coordinator;
use crate::error::Result;
use crate::matrix::Matrix;

/// Per-iteration record.
#[derive(Clone, Debug)]
pub struct PurifyStep {
    pub iter: usize,
    /// Idempotency residual ‖P² − P‖_F (convergence measure).
    pub idempotency_err: f64,
    /// Valid ratio of the P·P product this iteration.
    pub valid_ratio: f64,
    pub wall_secs: f64,
}

/// Result of a purification run.
pub struct PurifyResult {
    pub p: Matrix,
    pub steps: Vec<PurifyStep>,
    pub converged: bool,
}

/// Build a near-idempotent decay matrix to purify: P0 = V·diag(f)·Vᵀ with
/// occupations f pushed near {0, 1} would need an eigensolver; instead we
/// use the standard trick of starting from a scaled banded Hamiltonian:
/// P0 = (μI − H)/λ mapped into [0, 1] spectrum-wise, which for a
/// diagonally-dominant decay H is near-idempotent enough for McWeeny to
/// converge and keeps the decay structure SpAMM needs.
pub fn initial_density(n: usize, seed: u64) -> Matrix {
    // Symmetric banded decay matrix.
    let h = Matrix::decay_exponential(n, 1.0, 0.5, seed);
    let mut sym = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            sym[(i, j)] = 0.5 * (h[(i, j)] + h[(j, i)]);
        }
    }
    // Gershgorin bounds → affine map of the spectrum into ~[0.05, 0.95].
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for i in 0..n {
        let d = sym[(i, i)] as f64;
        let r: f64 = (0..n)
            .filter(|&j| j != i)
            .map(|j| (sym[(i, j)] as f64).abs())
            .sum();
        lo = lo.min(d - r);
        hi = hi.max(d + r);
    }
    let scale = 0.9 / (hi - lo).max(1e-12);
    let mut p = sym;
    p.scale(scale as f32);
    let shift = (0.05 - lo * scale) as f32;
    for i in 0..n {
        p[(i, i)] += shift;
    }
    p
}

/// Run McWeeny purification with SpAMM products at threshold τ.
pub fn mcweeny_purify(
    coord: &Coordinator,
    p0: &Matrix,
    tau: f32,
    max_iters: usize,
    tol: f64,
) -> Result<PurifyResult> {
    let mut p = p0.clone();
    let mut steps = Vec::new();
    for iter in 0..max_iters {
        let rep2 = coord.multiply(&p, &p, tau)?; // P²
        let p2 = rep2.c;
        // idempotency residual before update
        let idem = p2.error_fnorm(&p)?;
        let rep3 = coord.multiply(&p2, &p, tau)?; // P³
        let p3 = rep3.c;
        // P ← 3P² − 2P³
        let mut next = p2.clone();
        for ((nx, &a), &b) in next
            .data_mut()
            .iter_mut()
            .zip(p2.data())
            .zip(p3.data())
        {
            *nx = 3.0 * a - 2.0 * b;
        }
        steps.push(PurifyStep {
            iter,
            idempotency_err: idem,
            valid_ratio: rep2.valid_ratio,
            wall_secs: rep2.wall_secs + rep3.wall_secs,
        });
        p = next;
        if idem < tol {
            return Ok(PurifyResult {
                p,
                steps,
                converged: true,
            });
        }
    }
    let converged = steps
        .last()
        .map(|s| s.idempotency_err < tol * 10.0)
        .unwrap_or(false);
    Ok(PurifyResult {
        p,
        steps,
        converged,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpammConfig;
    use crate::runtime::ArtifactBundle;

    fn bundle() -> Option<ArtifactBundle> {
        // Real AOT bundle when present, offline hostsim bundle otherwise.
        crate::runtime::hostsim::find_or_test_bundle().ok()
    }

    #[test]
    fn initial_density_is_symmetric_decay() {
        let p = initial_density(96, 1);
        for i in 0..96 {
            for j in 0..96 {
                assert!((p[(i, j)] - p[(j, i)]).abs() < 1e-6);
            }
        }
        // decay: far corner ≪ diagonal scale
        assert!(p[(0, 90)].abs() < 0.05 * p.fnorm() as f32 / 96.0 + 1e-2);
    }

    #[test]
    fn purification_reduces_idempotency_error() {
        let Some(b) = bundle() else { return };
        let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
        let p0 = initial_density(96, 2);
        let r = mcweeny_purify(&coord, &p0, 0.0, 30, 1e-6).unwrap();
        assert!(r.steps.len() >= 2);
        let first = r.steps.first().unwrap().idempotency_err;
        let last = r.steps.last().unwrap().idempotency_err;
        assert!(
            last < first,
            "purification must make progress: {first} → {last}"
        );
    }

    #[test]
    fn spamm_purification_tracks_exact() {
        let Some(b) = bundle() else { return };
        let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
        let p0 = initial_density(96, 3);
        let exact = mcweeny_purify(&coord, &p0, 0.0, 10, 0.0).unwrap();
        let approx = mcweeny_purify(&coord, &p0, 1e-6, 10, 0.0).unwrap();
        let rel = approx.p.error_fnorm(&exact.p).unwrap() / exact.p.fnorm().max(1e-30);
        assert!(rel < 1e-2, "rel divergence {rel}");
    }
}
