//! Norm/schedule caching for the execution pipeline (§3.3/§3.4 reuse).
//!
//! The get-norm and schedule-compaction phases depend only on the operand
//! *contents*, the tile size, and τ — inside `power`/`purification` loops
//! (and for repeated service requests on the same operands) they are pure
//! recomputation.  [`NormCache`] memoizes normmaps keyed on a 128-bit
//! content fingerprint of the padded operand; [`ScheduleCache`] memoizes
//! compacted schedules keyed on both operand fingerprints plus the exact
//! τ bits.  Hit/miss counts are surfaced through
//! [`MultiplyStats`](crate::spamm::MultiplyStats) and the global
//! [`telemetry`](crate::telemetry) counters.
//!
//! Both caches are interior-mutable (engines take `&self`) and bounded
//! with LRU eviction (a hit refreshes recency, so the constant operand
//! of a long power chain survives arbitrarily many intermediate
//! inserts); fingerprints are two independent FNV-1a streams
//! over the f32 bit patterns, so a collision needs ~2⁶⁴ distinct operands
//! in flight — far beyond any cache capacity here.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::Result;
use crate::matrix::tiling::PaddedMatrix;
use crate::spamm::executor::MultiplyStats;
use crate::spamm::normmap::NormMap;
use crate::spamm::schedule::Schedule;
use crate::telemetry;

/// 128-bit content fingerprint of a padded operand (dims + lonum + data).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Fingerprint(pub u64, pub u64);

impl Fingerprint {
    /// Derive the fingerprint of an *operation result* from its input
    /// fingerprints — the content identity of a value that never
    /// materializes on the host (an expression-graph intermediate).
    ///
    /// The derivation folds the op tag, every input fingerprint in order,
    /// and the op's numeric parameters (τ for `spamm`, α/β for `axpby`,
    /// the exact f32 bits in all cases) into both FNV streams, so any
    /// variation — different op, different operand order, different τ —
    /// yields a different key.  Determinism is what makes derived keys
    /// sound cache/residency keys: the pipeline's tile products are
    /// bitwise-reproducible for fixed inputs and τ, so equal derived
    /// fingerprints imply equal tile contents.
    pub fn derive(op: &str, inputs: &[Fingerprint], params: &[f32]) -> Fingerprint {
        let mut h1 = Fnv::new(0xa076_1d64_78bd_642f);
        let mut h2 = Fnv::new(0xe703_7ed1_a0b4_28db);
        for h in [&mut h1, &mut h2] {
            h.mix(op.len() as u64);
            for b in op.as_bytes() {
                h.mix(*b as u64);
            }
            h.mix(inputs.len() as u64);
            h.mix(params.len() as u64);
        }
        for f in inputs {
            h1.mix(f.0);
            h1.mix(f.1);
            h2.mix(f.1.rotate_left(29));
            h2.mix(f.0.rotate_left(11));
        }
        for p in params {
            let bits = p.to_bits() as u64;
            h1.mix(bits);
            h2.mix(bits.rotate_left(17));
        }
        Fingerprint(h1.0, h2.0)
    }
}

struct Fnv(u64);

impl Fnv {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    fn new(seed: u64) -> Fnv {
        Fnv(Self::OFFSET ^ seed)
    }

    #[inline]
    fn mix(&mut self, v: u64) {
        self.0 ^= v;
        self.0 = self.0.wrapping_mul(Self::PRIME);
    }
}

/// Fingerprint a padded matrix: one pass over the data, two FNV streams.
pub fn fingerprint(p: &PaddedMatrix) -> Fingerprint {
    let mut h1 = Fnv::new(0x5bd1_e995_0000_0001);
    let mut h2 = Fnv::new(0x9e37_79b9_7f4a_7c15);
    for h in [&mut h1, &mut h2] {
        h.mix(p.logical_rows as u64);
        h.mix(p.logical_cols as u64);
        h.mix(p.lonum as u64);
    }
    let data = p.inner.data();
    let mut chunks = data.chunks_exact(2);
    for pair in &mut chunks {
        let v = (pair[0].to_bits() as u64) | ((pair[1].to_bits() as u64) << 32);
        h1.mix(v);
        h2.mix(v.rotate_left(17));
    }
    if let [last] = chunks.remainder() {
        let v = last.to_bits() as u64;
        h1.mix(v);
        h2.mix(v.rotate_left(17));
    }
    Fingerprint(h1.0, h2.0)
}

/// Derive the content fingerprint of an operand after a delta update:
/// fold the *previous* fingerprint, the touched tile coordinates, and the
/// new content of exactly those tiles (read from the already-patched
/// padded matrix) into two fresh FNV streams.  `tiles` must be sorted and
/// deduplicated — the caller's canonical delta order — so the same update
/// always derives the same key.
///
/// The derived key is deterministic in (old fingerprint, delta), which is
/// what the caches and pools need: equal keys imply equal content.  Two
/// *different* delta paths to the same final content yield different keys
/// (like any derived fingerprint, e.g. A³ built as (A·A)·A vs A·(A·A)) —
/// that only costs a cold cache entry, never correctness.
pub fn fingerprint_patch(
    base: Fingerprint,
    p: &PaddedMatrix,
    tiles: &[(usize, usize)],
) -> Fingerprint {
    let mut h1 = Fnv::new(0x1f83_d9ab_fb41_bd6b);
    let mut h2 = Fnv::new(0x5be0_cd19_137e_2179);
    h1.mix(base.0);
    h1.mix(base.1);
    h2.mix(base.1.rotate_left(29));
    h2.mix(base.0.rotate_left(11));
    for h in [&mut h1, &mut h2] {
        h.mix(tiles.len() as u64);
    }
    let l = p.lonum;
    let cols = p.inner.cols();
    let data = p.inner.data();
    for &(ti, tj) in tiles {
        h1.mix(((ti as u64) << 32) | tj as u64);
        h2.mix(((tj as u64) << 32) | ti as u64);
        for r in 0..l {
            let row = &data[(ti * l + r) * cols + tj * l..][..l];
            let mut chunks = row.chunks_exact(2);
            for pair in &mut chunks {
                let v = (pair[0].to_bits() as u64) | ((pair[1].to_bits() as u64) << 32);
                h1.mix(v);
                h2.mix(v.rotate_left(17));
            }
            if let [last] = chunks.remainder() {
                let v = last.to_bits() as u64;
                h1.mix(v);
                h2.mix(v.rotate_left(17));
            }
        }
    }
    Fingerprint(h1.0, h2.0)
}

/// Bounded LRU map shared by both caches (`order` front = least
/// recently used).
struct BoundedMap<K, V> {
    cap: usize,
    map: HashMap<K, V>,
    order: VecDeque<K>,
}

impl<K: Clone + Eq + std::hash::Hash, V: Clone> BoundedMap<K, V> {
    fn new(cap: usize) -> Self {
        BoundedMap {
            cap: cap.max(1),
            map: HashMap::new(),
            order: VecDeque::new(),
        }
    }

    /// Move `key` to the most-recently-used position.
    fn touch(&mut self, key: &K) {
        if let Some(pos) = self.order.iter().position(|k| k == key) {
            if let Some(k) = self.order.remove(pos) {
                self.order.push_back(k);
            }
        }
    }

    fn get(&mut self, key: &K) -> Option<V> {
        let hit = self.map.get(key).cloned();
        if hit.is_some() {
            self.touch(key);
        }
        hit
    }

    fn insert(&mut self, key: K, value: V) {
        if self.map.contains_key(&key) {
            self.touch(&key);
            self.map.insert(key, value);
            return;
        }
        while self.order.len() >= self.cap {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
            }
        }
        self.order.push_back(key.clone());
        self.map.insert(key, value);
    }

    fn remove(&mut self, key: &K) -> Option<V> {
        let v = self.map.remove(key);
        if v.is_some() {
            if let Some(pos) = self.order.iter().position(|k| k == key) {
                self.order.remove(pos);
            }
        }
        v
    }

    /// Snapshot of the entries matching `pred` (no recency change).
    fn entries_where(&self, mut pred: impl FnMut(&K) -> bool) -> Vec<(K, V)> {
        self.map
            .iter()
            .filter(|(k, _)| pred(k))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect()
    }

    fn len(&self) -> usize {
        self.map.len()
    }
}

/// Memoized norm+density maps keyed on operand fingerprints.
pub struct NormCache {
    inner: Mutex<BoundedMap<Fingerprint, Arc<NormMap>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl NormCache {
    pub fn new(cap: usize) -> NormCache {
        NormCache {
            inner: Mutex::new(BoundedMap::new(cap)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Fetch the normmap for `key`, computing (outside the lock) on miss.
    /// Returns the normmap and whether this was a hit.
    pub fn get_or_compute(
        &self,
        key: Fingerprint,
        compute: impl FnOnce() -> Result<NormMap>,
    ) -> Result<(Arc<NormMap>, bool)> {
        if let Some(hit) = self.inner.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            telemetry::global().add("spamm.norm_cache.hits", 1);
            return Ok((hit, true));
        }
        let value = Arc::new(compute()?);
        self.inner.lock().unwrap().insert(key, value.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        telemetry::global().add("spamm.norm_cache.misses", 1);
        Ok((value, false))
    }

    /// Silent lookup: refreshes recency but bumps no hit/miss counter —
    /// the delta-update path probing whether an entry is patchable, which
    /// must not masquerade as request traffic in the stats.
    pub fn lookup(&self, key: Fingerprint) -> Option<Arc<NormMap>> {
        self.inner.lock().unwrap().get(&key)
    }

    /// Register a normmap computed outside the cache — a patched map
    /// inserted under its post-update fingerprint.
    pub fn insert(&self, key: Fingerprint, value: Arc<NormMap>) {
        self.inner.lock().unwrap().insert(key, value);
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Key of a compacted schedule: both operand fingerprints + exact τ bits
/// + exact density-threshold bits (adaptive strategies change the
/// schedule's per-product format tags, so two thresholds must never share
/// an entry).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ScheduleKey {
    pub a: Fingerprint,
    pub b: Fingerprint,
    pub tau_bits: u32,
    pub density_bits: u32,
}

/// Memoized compacted schedules.
pub struct ScheduleCache {
    inner: Mutex<BoundedMap<ScheduleKey, Arc<Schedule>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ScheduleCache {
    pub fn new(cap: usize) -> ScheduleCache {
        ScheduleCache {
            inner: Mutex::new(BoundedMap::new(cap)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    pub fn get_or_compute(
        &self,
        key: ScheduleKey,
        compute: impl FnOnce() -> Result<Schedule>,
    ) -> Result<(Arc<Schedule>, bool)> {
        if let Some(hit) = self.inner.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            telemetry::global().add("spamm.schedule_cache.hits", 1);
            return Ok((hit, true));
        }
        let value = Arc::new(compute()?);
        self.inner.lock().unwrap().insert(key, value.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        telemetry::global().add("spamm.schedule_cache.misses", 1);
        Ok((value, false))
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Snapshot of every cached entry whose key references operand `fp`
    /// on either side (no recency change) — the delta-update repair scan.
    pub fn entries_involving(&self, fp: Fingerprint) -> Vec<(ScheduleKey, Arc<Schedule>)> {
        self.inner
            .lock()
            .unwrap()
            .entries_where(|k| k.a == fp || k.b == fp)
    }

    /// Register a schedule built outside `get_or_compute` — a repaired
    /// schedule inserted under its post-update key.
    pub fn insert(&self, key: ScheduleKey, value: Arc<Schedule>) {
        self.inner.lock().unwrap().insert(key, value);
    }

    /// Drop one entry (stale key after an update, or an entry whose
    /// repair inputs are gone — it will rebuild on next use).
    pub fn remove(&self, key: &ScheduleKey) {
        self.inner.lock().unwrap().remove(key);
    }
}

/// The cache pair every executor front-end (engine, coordinator) owns.
///
/// When a [`WarmStore`](crate::store::WarmStore) handle is attached
/// ([`ExecCaches::with_store`]), it acts as a persistent second tier
/// behind both in-memory caches: a memory miss consults the store before
/// computing, and every cold compute (and every delta patch/repair) is
/// written behind under its content key — so a restarted process reaches
/// warm latency on request one.  Store-restored entries count as
/// `store_*_hits` in [`MultiplyStats`], not as cache misses: the cold
/// recompute never ran.
pub struct ExecCaches {
    pub norms: NormCache,
    pub schedules: ScheduleCache,
    store: Option<Arc<crate::store::WarmStore>>,
}

/// Default capacity of the norm cache (operands in flight).
pub const NORM_CACHE_CAP: usize = 32;
/// Default capacity of the schedule cache ((A, B, τ) triples).
pub const SCHEDULE_CACHE_CAP: usize = 64;

impl Default for ExecCaches {
    fn default() -> Self {
        ExecCaches {
            norms: NormCache::new(NORM_CACHE_CAP),
            schedules: ScheduleCache::new(SCHEDULE_CACHE_CAP),
            store: None,
        }
    }
}

impl ExecCaches {
    pub fn new() -> ExecCaches {
        ExecCaches::default()
    }

    /// Caches backed by an optional on-disk warm-start store tier.
    pub fn with_store(store: Option<Arc<crate::store::WarmStore>>) -> ExecCaches {
        ExecCaches {
            store,
            ..ExecCaches::default()
        }
    }

    /// The attached warm store, if any.
    pub fn store(&self) -> Option<&Arc<crate::store::WarmStore>> {
        self.store.as_ref()
    }

    /// Cached normmap of a padded operand: fingerprint + norm-cache
    /// lookup, computing via `compute` on a miss.  `enabled = false`
    /// bypasses the cache entirely (no fingerprinting, no counter
    /// bumps).  Hit/miss counts land in `stats`.
    pub fn normmap_via(
        &self,
        enabled: bool,
        p: &PaddedMatrix,
        stats: &mut MultiplyStats,
        compute: impl FnOnce() -> Result<NormMap>,
    ) -> Result<(Arc<NormMap>, Option<Fingerprint>)> {
        if !enabled {
            return Ok((Arc::new(compute()?), None));
        }
        let fp = fingerprint(p);
        let nm = self.normmap_keyed(fp, stats, compute)?;
        Ok((nm, Some(fp)))
    }

    /// Cached normmap of an operand whose fingerprint is *already known*
    /// (a registered session operand): the norm-cache lookup happens
    /// directly on `fp`, skipping the O(N²) re-hash `normmap_via` pays on
    /// every call.  This is the fingerprint-by-id entry point the session
    /// front-end uses.
    pub fn normmap_keyed(
        &self,
        fp: Fingerprint,
        stats: &mut MultiplyStats,
        compute: impl FnOnce() -> Result<NormMap>,
    ) -> Result<Arc<NormMap>> {
        let mut from_store = false;
        let (nm, hit) = self.norms.get_or_compute(fp, || {
            if let Some(store) = &self.store {
                if let Some(nm) = store.load_normmap(fp) {
                    from_store = true;
                    return Ok(nm);
                }
            }
            let nm = compute()?;
            if let Some(store) = &self.store {
                store.save_normmap(fp, &nm);
            }
            Ok(nm)
        })?;
        if hit {
            stats.norm_cache_hits += 1;
        } else if from_store {
            // Restored from disk: warm, not a recompute.
            stats.store_normmap_hits += 1;
        } else {
            stats.norm_cache_misses += 1;
        }
        Ok(nm)
    }

    /// Cached compacted schedule for (A, B, τ, density threshold):
    /// consults the schedule cache when both operand fingerprints are
    /// present, building directly otherwise (caching disabled upstream).
    /// The build is density-adaptive; a zero threshold yields the
    /// historical all-dense schedule.  Hit/miss counts land in `stats`.
    pub fn schedule_via(
        &self,
        fa: Option<Fingerprint>,
        fb: Option<Fingerprint>,
        tau: f32,
        density_threshold: f32,
        na: &NormMap,
        nb: &NormMap,
        stats: &mut MultiplyStats,
    ) -> Result<Arc<Schedule>> {
        let (Some(a), Some(b)) = (fa, fb) else {
            return Ok(Arc::new(Schedule::build_adaptive(
                na,
                nb,
                tau,
                density_threshold,
            )?));
        };
        let key = ScheduleKey {
            a,
            b,
            tau_bits: tau.to_bits(),
            density_bits: density_threshold.to_bits(),
        };
        let mut from_store = false;
        let (sched, hit) = self.schedules.get_or_compute(key, || {
            if let Some(store) = &self.store {
                let expect = (na.norms.rows(), nb.norms.cols(), na.norms.cols());
                if let Some(s) = store.load_schedule(&key, expect.0, expect.1, expect.2) {
                    from_store = true;
                    return Ok(s);
                }
            }
            let s = Schedule::build_adaptive(na, nb, tau, density_threshold)?;
            if let Some(store) = &self.store {
                store.save_schedule(&key, &s);
            }
            Ok(s)
        })?;
        if hit {
            stats.schedule_cache_hits += 1;
        } else if from_store {
            stats.store_schedule_hits += 1;
        } else {
            stats.schedule_cache_misses += 1;
        }
        Ok(sched)
    }

    /// Delta-update a cached normmap: clone the entry under `old_fp`,
    /// recompute just the touched tiles from the patched operand (bitwise
    /// identical per tile to a full recompute — see
    /// [`NormMap::patch_tiles`]), and register the result under `new_fp`.
    /// Returns `None` when the old entry is not cached (evicted, or the
    /// operand was never multiplied) — the caller falls back to a full
    /// recompute on next use, which is always correct.
    pub fn patch_normmap(
        &self,
        old_fp: Fingerprint,
        new_fp: Fingerprint,
        p_new: &PaddedMatrix,
        tiles: &[(usize, usize)],
    ) -> Option<Arc<NormMap>> {
        let old = self.norms.lookup(old_fp)?;
        let mut patched = (*old).clone();
        patched.patch_tiles(p_new, tiles);
        let patched = Arc::new(patched);
        self.norms.insert(new_fp, patched.clone());
        if let Some(store) = &self.store {
            // Persist the post-update identity so a restart warms at the
            // drifted fingerprint, not the original one.
            store.save_normmap(new_fp, &patched);
        }
        telemetry::global().add("spamm.norm_cache.patched", 1);
        Some(patched)
    }

    /// Repair every cached schedule that references `old_fp` on either
    /// side, re-keying it to `new_fp`: only output tiles in a touched row
    /// (A side) or column (B side) are re-culled/retagged
    /// ([`Schedule::repair`]), everything else is carried over verbatim.
    /// Entries whose *other* operand's normmap is no longer cached are
    /// dropped instead (they rebuild from scratch on next use — cold but
    /// correct).  `new_nm` is the updated operand's patched normmap.
    pub fn repair_schedules(
        &self,
        old_fp: Fingerprint,
        new_fp: Fingerprint,
        new_nm: &Arc<NormMap>,
        tiles: &[(usize, usize)],
    ) -> ScheduleRepairOutcome {
        let mut out = ScheduleRepairOutcome::default();
        for (key, sched) in self.schedules.entries_involving(old_fp) {
            let other_nm = |fp: Fingerprint| -> Option<Arc<NormMap>> {
                if fp == old_fp {
                    Some(new_nm.clone())
                } else {
                    self.norms.lookup(fp)
                }
            };
            let (Some(na), Some(nb)) = (other_nm(key.a), other_nm(key.b)) else {
                self.schedules.remove(&key);
                out.dropped += 1;
                continue;
            };
            let tau = f32::from_bits(key.tau_bits);
            let dt = f32::from_bits(key.density_bits);
            let touched_a = (key.a == old_fp).then_some(tiles);
            let touched_b = (key.b == old_fp).then_some(tiles);
            match sched.repair(&na, &nb, tau, dt, touched_a, touched_b) {
                Ok((repaired, rs)) => {
                    // Always-on debug audit: the repaired schedule must be
                    // *structurally* sound against the patched normmaps —
                    // every cull/survivor/tag re-derived from first
                    // principles, not just bitwise-stable (this choke
                    // point covers every update path: session, deferred
                    // flush, and coordinator).
                    #[cfg(debug_assertions)]
                    crate::audit::debug_assert_clean(
                        &crate::audit::audit_schedule(&na, &nb, tau, dt, &repaired),
                        "schedule repair",
                    );
                    self.schedules.remove(&key);
                    let rekeyed = ScheduleKey {
                        a: if key.a == old_fp { new_fp } else { key.a },
                        b: if key.b == old_fp { new_fp } else { key.b },
                        ..key
                    };
                    let repaired = Arc::new(repaired);
                    if let Some(store) = &self.store {
                        store.save_schedule(&rekeyed, &repaired);
                    }
                    self.schedules.insert(rekeyed, repaired);
                    out.repaired += 1;
                    out.products_added += rs.products_added;
                    out.products_removed += rs.products_removed;
                    out.products_retagged += rs.products_retagged;
                    telemetry::global().add("spamm.schedule_cache.repaired", 1);
                }
                Err(_) => {
                    // Shape drift or out-of-range coords: the entry cannot
                    // describe the updated operand — drop it.
                    self.schedules.remove(&key);
                    out.dropped += 1;
                }
            }
        }
        out
    }
}

/// Summary of one [`ExecCaches::repair_schedules`] sweep.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScheduleRepairOutcome {
    /// Cached schedules patched in place and re-keyed to the new
    /// fingerprint.
    pub repaired: usize,
    /// Entries dropped (missing repair inputs) — they rebuild on next use.
    pub dropped: usize,
    /// Products added across all repaired schedules (norm products newly
    /// crossing τ).
    pub products_added: usize,
    /// Products culled across all repaired schedules.
    pub products_removed: usize,
    /// Surviving products whose [`TileStrategy`](crate::spamm::schedule::TileStrategy)
    /// flipped under the density threshold.
    pub products_retagged: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Matrix;

    fn nmz(rows: usize, cols: usize) -> NormMap {
        NormMap::dense_like(Matrix::zeros(rows, cols))
    }

    #[test]
    fn fingerprint_distinguishes_content_and_shape() {
        let a = Matrix::randn(16, 16, 1);
        let b = Matrix::randn(16, 16, 2);
        let pa = PaddedMatrix::new(&a, 8);
        let pb = PaddedMatrix::new(&b, 8);
        assert_eq!(fingerprint(&pa), fingerprint(&pa));
        assert_ne!(fingerprint(&pa), fingerprint(&pb));
        // Same content, different tile size → different key.
        let pa16 = PaddedMatrix::new(&a, 16);
        assert_ne!(fingerprint(&pa), fingerprint(&pa16));
    }

    #[test]
    fn derived_fingerprints_are_deterministic_and_collision_free() {
        let a = Fingerprint(1, 2);
        let b = Fingerprint(3, 4);
        let base = Fingerprint::derive("spamm", &[a, b], &[1e-4]);
        // Deterministic.
        assert_eq!(base, Fingerprint::derive("spamm", &[a, b], &[1e-4]));
        // Op tag, operand order, operand identity, and τ all matter.
        assert_ne!(base, Fingerprint::derive("axpby", &[a, b], &[1e-4]));
        assert_ne!(base, Fingerprint::derive("spamm", &[b, a], &[1e-4]));
        assert_ne!(base, Fingerprint::derive("spamm", &[a, a], &[1e-4]));
        assert_ne!(base, Fingerprint::derive("spamm", &[a, b], &[2e-4]));
        assert_ne!(base, Fingerprint::derive("spamm", &[a, b], &[0.0]));
        // Exact bit sensitivity: τ and -τ, 0.0 and -0.0 differ.
        assert_ne!(
            Fingerprint::derive("spamm", &[a, b], &[0.0]),
            Fingerprint::derive("spamm", &[a, b], &[-0.0])
        );
        // A derived key never collides with its own inputs.
        assert_ne!(base, a);
        assert_ne!(base, b);
        // Multi-parameter ops: α/β variations separate.
        let x = Fingerprint::derive("axpby", &[a, b], &[3.0, -2.0]);
        assert_ne!(x, Fingerprint::derive("axpby", &[a, b], &[-2.0, 3.0]));
        assert_ne!(x, Fingerprint::derive("axpby", &[a, b], &[3.0]));
        // Chained derivation (a power chain) keeps every step distinct.
        let c2 = Fingerprint::derive("spamm", &[a, a], &[1e-4]);
        let c3 = Fingerprint::derive("spamm", &[c2, a], &[1e-4]);
        let c4 = Fingerprint::derive("spamm", &[c3, a], &[1e-4]);
        assert_ne!(c2, c3);
        assert_ne!(c3, c4);
        assert_ne!(c2, c4);
    }

    #[test]
    fn norm_cache_hits_and_bounds() {
        let cache = NormCache::new(2);
        let key = |i: u64| Fingerprint(i, i.wrapping_mul(31));
        let (_, hit) = cache
            .get_or_compute(key(1), || Ok(nmz(1, 1)))
            .unwrap();
        assert!(!hit);
        let (_, hit) = cache
            .get_or_compute(key(1), || panic!("must not recompute"))
            .unwrap();
        assert!(hit);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        // Eviction beyond capacity 2: key 1 is least recently used.
        cache
            .get_or_compute(key(2), || Ok(nmz(1, 1)))
            .unwrap();
        cache
            .get_or_compute(key(3), || Ok(nmz(1, 1)))
            .unwrap();
        assert_eq!(cache.len(), 2);
        let (_, hit) = cache
            .get_or_compute(key(1), || Ok(nmz(1, 1)))
            .unwrap();
        assert!(!hit, "least-recently-used entry must have been evicted");
    }

    #[test]
    fn lru_hit_refreshes_recency() {
        // The power-chain pattern: a constant operand hit on every
        // iteration must survive arbitrarily many one-shot inserts.
        let cache = NormCache::new(2);
        let key = |i: u64| Fingerprint(i, !i);
        cache
            .get_or_compute(key(1), || Ok(nmz(1, 1)))
            .unwrap();
        for i in 2..10 {
            // Hit the hot key, then insert a fresh one-shot key.
            let (_, hit) = cache
                .get_or_compute(key(1), || Ok(nmz(1, 1)))
                .unwrap();
            assert!(hit, "hot key evicted at iteration {i}");
            cache
                .get_or_compute(key(i), || Ok(nmz(1, 1)))
                .unwrap();
        }
    }

    #[test]
    fn schedule_cache_keys_on_tau() {
        let cache = ScheduleCache::new(4);
        let fp = Fingerprint(7, 11);
        let na = Matrix::zeros(2, 2);
        let mk = |tau: f32, dt: f32| ScheduleKey {
            a: fp,
            b: fp,
            tau_bits: tau.to_bits(),
            density_bits: dt.to_bits(),
        };
        let build = || Schedule::build(&na, &na, 0.5);
        let (_, h1) = cache.get_or_compute(mk(0.5, 0.0), build).unwrap();
        let (_, h2) = cache.get_or_compute(mk(0.5, 0.0), build).unwrap();
        let (_, h3) = cache.get_or_compute(mk(0.25, 0.0), build).unwrap();
        // Same τ, different density threshold: a distinct entry.
        let (_, h4) = cache.get_or_compute(mk(0.5, 0.25), build).unwrap();
        assert!(!h1 && h2 && !h3 && !h4);
    }

    #[test]
    fn keyed_normmap_skips_hashing_and_shares_entries() {
        // A keyed lookup and a hashed lookup of the same operand must hit
        // the same cache entry (the session's by-id path and the legacy
        // by-content path are views of one cache).
        let caches = ExecCaches::new();
        let m = Matrix::randn(16, 16, 3);
        let p = PaddedMatrix::new(&m, 8);
        let fp = fingerprint(&p);
        let mut stats = MultiplyStats::default();
        let via = caches
            .normmap_via(true, &p, &mut stats, || {
                Ok(crate::spamm::normmap::normmap_with_density(&p))
            })
            .unwrap();
        assert_eq!(via.1, Some(fp));
        let keyed = caches
            .normmap_keyed(fp, &mut stats, || panic!("must hit the shared entry"))
            .unwrap();
        assert_eq!(keyed.norms.data(), via.0.norms.data());
        assert_eq!(stats.norm_cache_hits, 1);
        assert_eq!(stats.norm_cache_misses, 1);
    }

    #[test]
    fn fingerprint_patch_is_deterministic_and_delta_sensitive() {
        let m = Matrix::randn(64, 64, 21);
        let p = PaddedMatrix::new(&m, 32);
        let base = fingerprint(&p);
        let a = fingerprint_patch(base, &p, &[(0, 1)]);
        assert_eq!(a, fingerprint_patch(base, &p, &[(0, 1)]));
        assert_ne!(a, base);
        assert_ne!(a, fingerprint_patch(base, &p, &[(1, 0)]));
        assert_ne!(a, fingerprint_patch(base, &p, &[(0, 1), (1, 1)]));
        // Different base → different key even for the same delta.
        assert_ne!(a, fingerprint_patch(Fingerprint(1, 2), &p, &[(0, 1)]));
    }

    #[test]
    fn patch_normmap_matches_full_recompute() {
        use crate::spamm::normmap::normmap_with_density;
        let caches = ExecCaches::new();
        let m0 = Matrix::randn(64, 64, 22);
        let p0 = PaddedMatrix::new(&m0, 32);
        let f0 = fingerprint(&p0);
        let mut stats = MultiplyStats::default();
        caches
            .normmap_keyed(f0, &mut stats, || Ok(normmap_with_density(&p0)))
            .unwrap();
        let mut m1 = m0.clone();
        for r in 32..64 {
            for c in 0..32 {
                m1[(r, c)] = 0.25 * r as f32;
            }
        }
        let p1 = PaddedMatrix::new(&m1, 32);
        let f1 = fingerprint_patch(f0, &p1, &[(1, 0)]);
        let patched = caches
            .patch_normmap(f0, f1, &p1, &[(1, 0)])
            .expect("old entry cached");
        let full = normmap_with_density(&p1);
        assert_eq!(patched.norms.data(), full.norms.data());
        assert_eq!(patched.density.data(), full.density.data());
        // The patched map is now cached under the new fingerprint.
        let hit = caches
            .normmap_keyed(f1, &mut stats, || panic!("must hit the patched entry"))
            .unwrap();
        assert_eq!(hit.norms.data(), full.norms.data());
        // Unknown old fingerprint → None (caller recomputes on next use).
        assert!(caches
            .patch_normmap(Fingerprint(9, 9), f1, &p1, &[(0, 0)])
            .is_none());
    }

    #[test]
    fn error_is_not_cached() {
        let cache = NormCache::new(4);
        let key = Fingerprint(1, 2);
        let r = cache.get_or_compute(key, || {
            Err(crate::error::Error::Shape("boom".into()))
        });
        assert!(r.is_err());
        let (_, hit) = cache
            .get_or_compute(key, || Ok(nmz(1, 1)))
            .unwrap();
        assert!(!hit);
    }
}
