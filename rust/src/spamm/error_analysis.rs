//! Error analysis for SpAMM — the theory the paper leans on (§5.1).
//!
//! Artemov (2019) proves that for matrices with exponential decay the
//! absolute SpAMM error behaves as ‖E‖_F = O(N^{1/2} · τ^{p/2}) with
//! p < 2.  This module provides:
//!
//! * an *a-priori* upper bound on ‖E‖_F from the schedule alone (the sum
//!   of skipped norm products — submultiplicativity of ‖·‖_F), usable
//!   before any multiplication happens;
//! * an empirical scaling-exponent estimator used by the tests/benches to
//!   check the measured error against Artemov's τ^{p/2}, p < 2 form.

use crate::error::Result;
use crate::matrix::Matrix;
use crate::spamm::schedule::Schedule;

/// A-priori bound: ‖E‖_F ≤ Σ_{skipped (i,k,j)} ‖A[i,k]‖·‖B[k,j]‖.
///
/// Follows from E = Σ_skipped A[i,k]B[k,j] (as block contributions) and
/// ‖A[i,k]B[k,j]‖_F ≤ ‖A[i,k]‖_F·‖B[k,j]‖_F; each skipped product is
/// < τ by construction, so the bound is also ≤ τ·(#skipped).
pub fn apriori_error_bound(na: &Matrix, nb: &Matrix, tau: f32) -> Result<f64> {
    let sched = Schedule::build(na, nb, tau)?;
    let mut bound = 0.0f64;
    for i in 0..sched.tile_rows {
        for j in 0..sched.tile_cols {
            let kept = sched.ks(i, j);
            let mut ki = 0usize;
            for k in 0..sched.tile_k {
                if ki < kept.len() && kept[ki] == k as u32 {
                    ki += 1;
                    continue;
                }
                bound += (na[(i, k)] as f64) * (nb[(k, j)] as f64);
            }
        }
    }
    Ok(bound)
}

/// Least-squares slope of log(err) vs log(τ) over (τ, ‖E‖) samples with
/// err > floor.  Artemov: slope = p/2 with p < 2 ⇒ slope < 1.
pub fn tau_scaling_exponent(samples: &[(f64, f64)], floor: f64) -> Option<f64> {
    let pts: Vec<(f64, f64)> = samples
        .iter()
        .filter(|(t, e)| *t > 0.0 && *e > floor)
        .map(|(t, e)| (t.ln(), e.ln()))
        .collect();
    if pts.len() < 2 {
        return None;
    }
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return None;
    }
    Some((n * sxy - sx * sy) / denom)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::tiling::PaddedMatrix;
    use crate::spamm::normmap::normmap;
    use crate::spamm::reference::spamm_flat_host;

    fn setup(n: usize) -> (Matrix, Matrix, Matrix, Matrix) {
        let a = Matrix::decay_exponential(n, 1.0, 0.7, 5);
        let b = Matrix::decay_exponential(n, 1.0, 0.7, 6);
        let na = normmap(&PaddedMatrix::new(&a, 32));
        let nb = normmap(&PaddedMatrix::new(&b, 32));
        (a, b, na, nb)
    }

    #[test]
    fn bound_dominates_measured_error() {
        let (a, b, na, nb) = setup(128);
        let exact = a.matmul(&b).unwrap();
        for tau in [1e-4f32, 1e-3, 1e-2, 1e-1] {
            let c = spamm_flat_host(&a, &b, tau, 32).unwrap();
            let err = exact.error_fnorm(&c).unwrap();
            let bound = apriori_error_bound(&na, &nb, tau).unwrap();
            assert!(
                err <= bound + 1e-3,
                "τ={tau}: measured {err} > bound {bound}"
            );
        }
    }

    #[test]
    fn bound_zero_when_nothing_skipped() {
        let (_, _, na, nb) = setup(64);
        assert_eq!(apriori_error_bound(&na, &nb, 0.0).unwrap(), 0.0);
    }

    #[test]
    fn bound_monotone_in_tau() {
        let (_, _, na, nb) = setup(128);
        let mut prev = -1.0;
        for tau in [0.0f32, 1e-4, 1e-2, 1.0] {
            let b = apriori_error_bound(&na, &nb, tau).unwrap();
            assert!(b >= prev);
            prev = b;
        }
    }

    #[test]
    fn artemov_exponent_below_one() {
        // Measured error must scale sub-linearly in τ (p/2 < 1).
        let (a, b, _, _) = setup(128);
        let exact = a.matmul(&b).unwrap();
        let mut samples = Vec::new();
        for tau in [1e-5f32, 1e-4, 1e-3, 1e-2, 1e-1] {
            let c = spamm_flat_host(&a, &b, tau, 32).unwrap();
            samples.push((tau as f64, exact.error_fnorm(&c).unwrap()));
        }
        let slope = tau_scaling_exponent(&samples, 1e-9).expect("enough samples");
        assert!(slope > 0.0, "error must grow with τ, slope {slope}");
        assert!(slope < 1.5, "Artemov p/2 < 1 (slack for sampling), slope {slope}");
    }

    #[test]
    fn exponent_estimator_on_known_powerlaw() {
        // err = τ^0.7 exactly → slope 0.7.
        let samples: Vec<(f64, f64)> =
            [1e-4, 1e-3, 1e-2, 1e-1].iter().map(|&t| (t, f64::powf(t, 0.7))).collect();
        let s = tau_scaling_exponent(&samples, 0.0).unwrap();
        assert!((s - 0.7).abs() < 1e-9);
    }

    #[test]
    fn exponent_estimator_degenerate() {
        assert!(tau_scaling_exponent(&[], 0.0).is_none());
        assert!(tau_scaling_exponent(&[(1e-3, 1.0)], 0.0).is_none());
    }
}
