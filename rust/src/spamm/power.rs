//! Matrix powers under SpAMM — the ergo case study's actual computation
//! (§4.3.1 "we use cuSpAMM to calculate the power of these matrices") and
//! the decay-matrix application domain the paper motivates (matrix
//! inverse/exponential iterations, density-matrix purification).
//!
//! Computes A^k by repeated SpAMM with per-step error accounting: products
//! of decay matrices lose decay slowly, so τ can stay fixed while the
//! valid ratio drifts — the tracker reports both.

use crate::coordinator::Coordinator;
use crate::error::Result;
use crate::matrix::Matrix;

/// Per-step record of a SpAMM power chain.
#[derive(Clone, Debug)]
pub struct PowerStep {
    /// Which power this step produced (2 = A², ...).
    pub power: usize,
    pub valid_ratio: f64,
    pub wall_secs: f64,
    /// ‖result‖_F after this step.
    pub result_fnorm: f64,
}

/// Result of a power computation.
pub struct PowerResult {
    pub value: Matrix,
    pub steps: Vec<PowerStep>,
}

/// Compute A^k (k ≥ 1) with SpAMM at fixed τ via iterated multiplication.
///
/// Uses plain left-to-right iteration (k−1 multiplies) rather than
/// binary powering: the intermediate *decay structure* is what SpAMM
/// exploits, and A^(2^j) chains lose decay faster than A^j·A — matching
/// how electronic-structure codes iterate.
pub fn spamm_power(
    coord: &Coordinator,
    a: &Matrix,
    k: usize,
    tau: f32,
) -> Result<PowerResult> {
    assert!(k >= 1, "k must be ≥ 1");
    let mut value = a.clone();
    let mut steps = Vec::new();
    for p in 2..=k {
        let rep = coord.multiply(&value, a, tau)?;
        steps.push(PowerStep {
            power: p,
            valid_ratio: rep.valid_ratio,
            wall_secs: rep.wall_secs,
            result_fnorm: rep.c.fnorm(),
        });
        value = rep.c;
    }
    Ok(PowerResult { value, steps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpammConfig;
    use crate::runtime::ArtifactBundle;

    fn bundle() -> Option<ArtifactBundle> {
        // Real AOT bundle when present, offline hostsim bundle otherwise.
        crate::runtime::hostsim::find_or_test_bundle().ok()
    }

    #[test]
    fn power_one_is_identity_copy() {
        let Some(b) = bundle() else { return };
        let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
        let a = Matrix::decay_exponential(64, 1.0, 0.5, 1);
        let r = spamm_power(&coord, &a, 1, 0.0).unwrap();
        assert_eq!(r.value, a);
        assert!(r.steps.is_empty());
    }

    #[test]
    fn cube_matches_host_reference_at_tau_zero() {
        let Some(b) = bundle() else { return };
        let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
        let a = Matrix::decay_exponential(96, 1.0, 0.5, 2);
        let r = spamm_power(&coord, &a, 3, 0.0).unwrap();
        let want = a.matmul(&a).unwrap().matmul(&a).unwrap();
        let rel = r.value.error_fnorm(&want).unwrap() / want.fnorm().max(1e-30);
        assert!(rel < 1e-4, "rel err {rel}");
        assert_eq!(r.steps.len(), 2);
        assert_eq!(r.steps[0].power, 2);
        assert_eq!(r.steps[1].power, 3);
    }

    #[test]
    fn approximation_error_stays_controlled() {
        let Some(b) = bundle() else { return };
        let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
        let a = Matrix::decay_exponential(96, 1.0, 0.45, 3);
        let exact = spamm_power(&coord, &a, 3, 0.0).unwrap().value;
        let approx = spamm_power(&coord, &a, 3, 1e-4).unwrap();
        let rel = approx.value.error_fnorm(&exact).unwrap() / exact.fnorm().max(1e-30);
        assert!(rel < 1e-2, "rel err {rel}");
        // valid ratio drifts up as powers densify, but must stay ≤ 1.
        for s in &approx.steps {
            assert!(s.valid_ratio <= 1.0);
        }
    }
}
