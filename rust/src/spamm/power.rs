//! Matrix powers under SpAMM — the ergo case study's actual computation
//! (§4.3.1 "we use cuSpAMM to calculate the power of these matrices") and
//! the decay-matrix application domain the paper motivates (matrix
//! inverse/exponential iterations, density-matrix purification).
//!
//! Computes A^k by repeated SpAMM with per-step error accounting: products
//! of decay matrices lose decay slowly, so τ can stay fixed while the
//! valid ratio drifts — the tracker reports both.
//!
//! [`spamm_power`] builds the whole chain as **one expression graph**
//! ([`crate::coordinator::expr`]): every intermediate power stays
//! device-resident, step *k+1*'s schedule comes from step *k*'s
//! device-side norms (no host normmap recompute, no re-upload), and the
//! result is bitwise identical to the one-multiply-per-step
//! [`spamm_power_loop`] at the same τ — the A/B baseline the
//! `power --expr/--loop` CLI and the `pipeline_cache` bench compare.

use std::borrow::Cow;

use crate::coordinator::expr::{ExprGraph, ExprSource};
use crate::coordinator::{Approx, Coordinator};
use crate::error::Result;
use crate::matrix::Matrix;

/// Per-step record of a SpAMM power chain.
#[derive(Clone, Debug)]
pub struct PowerStep {
    /// Which power this step produced (2 = A², ...).
    pub power: usize,
    pub valid_ratio: f64,
    pub wall_secs: f64,
    /// ‖result‖_F after this step.
    pub result_fnorm: f64,
}

/// Result of a power computation.
pub struct PowerResult<'a> {
    /// A^k.  For `k == 1` this is `Cow::Borrowed(a)` — no multiply runs
    /// and no deep clone is paid; call `into_owned()` when an owned
    /// matrix is needed.  For `k ≥ 2` it is owned.
    pub value: Cow<'a, Matrix>,
    /// Per-step records; **empty for `k == 1`** (A¹ involves no product).
    pub steps: Vec<PowerStep>,
}

/// Compute A^k (k ≥ 1) with SpAMM at fixed τ via iterated multiplication,
/// as one prepared expression graph with device-resident intermediates.
///
/// Uses plain left-to-right iteration (k−1 multiplies) rather than
/// binary powering: the intermediate *decay structure* is what SpAMM
/// exploits, and A^(2^j) chains lose decay faster than A^j·A — matching
/// how electronic-structure codes iterate.
pub fn spamm_power<'a>(
    coord: &Coordinator,
    a: &'a Matrix,
    k: usize,
    tau: f32,
) -> Result<PowerResult<'a>> {
    assert!(k >= 1, "k must be ≥ 1");
    if k == 1 {
        return Ok(PowerResult {
            value: Cow::Borrowed(a),
            steps: Vec::new(),
        });
    }
    let mut g = ExprGraph::new();
    let leaf = g.operand();
    let mut cur = leaf;
    let mut spamm_nodes = Vec::with_capacity(k - 1);
    for _ in 2..=k {
        cur = g.spamm(cur, leaf, Approx::Tau(tau));
        spamm_nodes.push(cur);
    }
    g.output(cur);
    let plan = coord.prepare_expr(&g, &[ExprSource::Host(a)])?;
    let rep = coord.execute_expr(&plan)?;
    let steps = spamm_nodes
        .iter()
        .enumerate()
        .map(|(i, id)| {
            let nr = rep.node(*id).expect("every spamm node is reported");
            PowerStep {
                power: i + 2,
                valid_ratio: nr.valid_ratio,
                wall_secs: nr.wall_secs,
                result_fnorm: nr.result_fnorm,
            }
        })
        .collect();
    let value = rep.value.to_matrix(); // the chain's one download
    coord.evict_value(rep.value);
    Ok(PowerResult {
        value: Cow::Owned(value),
        steps,
    })
}

/// The pre-expression driver: one [`Coordinator::multiply`] per step,
/// every intermediate scattered to host, re-fingerprinted, re-normed, and
/// re-uploaded.  Kept as the A/B baseline — bitwise identical to
/// [`spamm_power`] at the same τ, just slower and chattier on the bus.
pub fn spamm_power_loop<'a>(
    coord: &Coordinator,
    a: &'a Matrix,
    k: usize,
    tau: f32,
) -> Result<PowerResult<'a>> {
    assert!(k >= 1, "k must be ≥ 1");
    if k == 1 {
        return Ok(PowerResult {
            value: Cow::Borrowed(a),
            steps: Vec::new(),
        });
    }
    let mut value = a.clone();
    let mut steps = Vec::new();
    for p in 2..=k {
        let rep = coord.multiply(&value, a, tau)?;
        steps.push(PowerStep {
            power: p,
            valid_ratio: rep.valid_ratio,
            wall_secs: rep.wall_secs,
            result_fnorm: rep.c.fnorm(),
        });
        value = rep.c;
    }
    Ok(PowerResult {
        value: Cow::Owned(value),
        steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SpammConfig;
    use crate::runtime::ArtifactBundle;

    fn bundle() -> Option<ArtifactBundle> {
        // Real AOT bundle when present, offline hostsim bundle otherwise.
        crate::runtime::hostsim::find_or_test_bundle().ok()
    }

    #[test]
    fn power_one_is_identity_copy() {
        let Some(b) = bundle() else { return };
        let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
        let a = Matrix::decay_exponential(64, 1.0, 0.5, 1);
        let r = spamm_power(&coord, &a, 1, 0.0).unwrap();
        assert_eq!(*r.value, a);
        assert!(
            matches!(r.value, Cow::Borrowed(_)),
            "k = 1 must borrow, not deep-clone"
        );
        assert!(r.steps.is_empty(), "k = 1 runs no products");
        let rl = spamm_power_loop(&coord, &a, 1, 0.0).unwrap();
        assert!(matches!(rl.value, Cow::Borrowed(_)));
    }

    #[test]
    fn cube_matches_host_reference_at_tau_zero() {
        let Some(b) = bundle() else { return };
        let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
        let a = Matrix::decay_exponential(96, 1.0, 0.5, 2);
        let r = spamm_power(&coord, &a, 3, 0.0).unwrap();
        let want = a.matmul(&a).unwrap().matmul(&a).unwrap();
        let rel = r.value.error_fnorm(&want).unwrap() / want.fnorm().max(1e-30);
        assert!(rel < 1e-4, "rel err {rel}");
        assert_eq!(r.steps.len(), 2);
        assert_eq!(r.steps[0].power, 2);
        assert_eq!(r.steps[1].power, 3);
    }

    #[test]
    fn expr_and_loop_paths_agree_bitwise() {
        let Some(b) = bundle() else { return };
        for tau in [0.0f32, 1e-4] {
            // Fresh coordinators per path: no shared cache/pool state.
            let c1 = Coordinator::new(&b, SpammConfig::default()).unwrap();
            let c2 = Coordinator::new(&b, SpammConfig::default()).unwrap();
            let a = Matrix::decay_exponential(96, 1.0, 0.5, 5);
            let expr = spamm_power(&c1, &a, 4, tau).unwrap();
            let looped = spamm_power_loop(&c2, &a, 4, tau).unwrap();
            assert_eq!(
                expr.value.data(),
                looped.value.data(),
                "expr vs loop diverged at τ={tau}"
            );
            for (se, sl) in expr.steps.iter().zip(&looped.steps) {
                assert_eq!(se.power, sl.power);
                assert_eq!(se.valid_ratio, sl.valid_ratio, "τ={tau}");
                assert_eq!(
                    se.result_fnorm.to_bits(),
                    sl.result_fnorm.to_bits(),
                    "step fnorm diverged at τ={tau}"
                );
            }
        }
    }

    #[test]
    fn approximation_error_stays_controlled() {
        let Some(b) = bundle() else { return };
        let coord = Coordinator::new(&b, SpammConfig::default()).unwrap();
        let a = Matrix::decay_exponential(96, 1.0, 0.45, 3);
        let exact = spamm_power(&coord, &a, 3, 0.0).unwrap().value.into_owned();
        let approx = spamm_power(&coord, &a, 3, 1e-4).unwrap();
        let rel = approx.value.error_fnorm(&exact).unwrap() / exact.fnorm().max(1e-30);
        assert!(rel < 1e-2, "rel err {rel}");
        // valid ratio drifts up as powers densify, but must stay ≤ 1.
        for s in &approx.steps {
            assert!(s.valid_ratio <= 1.0);
        }
    }
}
