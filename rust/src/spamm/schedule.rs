//! Schedule compaction — the paper's bitmap → map_offset transform
//! (Alg. 2 lines 5–14, Fig. 3b), hoisted from the CUDA kernel into the
//! coordinator (DESIGN.md §2: on a CPU-PJRT backend this is what makes
//! skipped tiles *actually* skipped).
//!
//! For every output tile C[i,j] the bitmap over k marks which products
//! ‖A[i,k]‖·‖B[k,j]‖ ≥ τ survive; the compacted per-tile k-lists are the
//! map_offset equivalent, and their concatenation is the dense batch the
//! tile-GEMM artifacts execute.

use crate::error::{Error, Result};
use crate::matrix::Matrix;
use crate::spamm::normmap::NormMap;

/// Execution strategy of one surviving tile product, chosen from the
/// operand tiles' density census (see [`NormMap`]).
///
/// * `Dense` — the historical batched tile-GEMM path; always correct.
/// * `Sparse` — both operand tiles fall below the density threshold, so
///   the product stages COO-compressed payloads and runs the sparse tile
///   kernel (`sparse::spgemm` semantics).
/// * `Packed` — a run of ≥ 2 consecutive `Sparse` products of the same
///   output tile, fused into a single wider sparse dispatch
///   (`C[i,j] += [A_ik…]·[B_kj…]` as one (L×nL)·(nL×L) product).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TileStrategy {
    Dense,
    Sparse,
    Packed,
}

impl TileStrategy {
    /// Stable one-byte wire tag — the warm-store schedule payload format.
    pub fn to_tag(self) -> u8 {
        match self {
            TileStrategy::Dense => 0,
            TileStrategy::Sparse => 1,
            TileStrategy::Packed => 2,
        }
    }

    /// Inverse of [`TileStrategy::to_tag`]; unknown tags are a store
    /// error (corrupt or future-format payload), never a panic.
    pub fn from_tag(tag: u8) -> Result<TileStrategy> {
        match tag {
            0 => Ok(TileStrategy::Dense),
            1 => Ok(TileStrategy::Sparse),
            2 => Ok(TileStrategy::Packed),
            other => Err(Error::Store(format!("unknown tile-strategy tag {other}"))),
        }
    }
}

/// Compacted SpAMM schedule for C = A·B with BDIM-tiled operands.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Tile grid: C is tile_rows × tile_cols, contraction depth tile_k.
    pub tile_rows: usize,
    pub tile_cols: usize,
    pub tile_k: usize,
    /// Per output tile (row-major), the compacted list of surviving k.
    pub valid_k: Vec<Vec<u32>>,
    /// Parallel to `valid_k`: the strategy of each surviving product.
    /// `Schedule::build` fills all-`Dense`; `build_adaptive` assigns
    /// `Sparse`/`Packed` from the operands' density census.
    pub strategies: Vec<Vec<TileStrategy>>,
}

impl Schedule {
    /// Build from normmaps: na is (tile_rows × tile_k), nb is
    /// (tile_k × tile_cols).  Every product gets the `Dense` strategy —
    /// this is the historical all-dense schedule, bitwise identical to
    /// `build_adaptive` with a zero density threshold.
    pub fn build(na: &Matrix, nb: &Matrix, tau: f32) -> Result<Schedule> {
        if na.cols() != nb.rows() {
            return Err(Error::Shape(format!(
                "normmap shapes {}x{} vs {}x{}",
                na.rows(),
                na.cols(),
                nb.rows(),
                nb.cols()
            )));
        }
        let (tr, tk, tc) = (na.rows(), na.cols(), nb.cols());
        let mut valid_k = Vec::with_capacity(tr * tc);
        for i in 0..tr {
            for j in 0..tc {
                // bitmap[k] = [‖A[i,k]‖·‖B[k,j]‖ ≥ τ]; compacted on the fly
                // (the map_offset prefix-sum of Alg. 2 lines 9–14).
                let mut ks = Vec::new();
                for k in 0..tk {
                    if na[(i, k)] * nb[(k, j)] >= tau {
                        ks.push(k as u32);
                    }
                }
                valid_k.push(ks);
            }
        }
        let strategies = valid_k
            .iter()
            .map(|ks| vec![TileStrategy::Dense; ks.len()])
            .collect();
        Ok(Schedule {
            tile_rows: tr,
            tile_cols: tc,
            tile_k: tk,
            valid_k,
            strategies,
        })
    }

    /// Build with density-adaptive per-product strategies.  τ-culling is
    /// identical to [`Schedule::build`] over `na.norms`/`nb.norms`; on top
    /// of it a product A[i,k]·B[k,j] goes `Sparse` when **both** operand
    /// tiles' densities fall *strictly below* `density_threshold` (strict,
    /// so a zero threshold never selects sparse and the schedule is
    /// bitwise the all-dense one), and runs of ≥ 2 consecutive `Sparse`
    /// products in one output tile's k-list are promoted to `Packed`.
    pub fn build_adaptive(
        na: &NormMap,
        nb: &NormMap,
        tau: f32,
        density_threshold: f32,
    ) -> Result<Schedule> {
        let mut s = Schedule::build(&na.norms, &nb.norms, tau)?;
        if density_threshold <= 0.0 {
            return Ok(s);
        }
        for i in 0..s.tile_rows {
            for j in 0..s.tile_cols {
                let slot = i * s.tile_cols + j;
                s.strategies[slot] =
                    tile_strategies(na, nb, density_threshold, i, j, &s.valid_k[slot]);
            }
        }
        Ok(s)
    }

    /// Repair this schedule after a delta update of one (or both)
    /// operands, instead of rebuilding the whole grid.  Culling, strategy
    /// tagging, and packed-run fusion are all *per output tile* — the
    /// product list of C[i,j] depends only on A row i and B column j — so
    /// only tiles in a touched A row (`touched_a` holds updated A tile
    /// coords (i,k)) or touched B column (`touched_b` holds updated B
    /// tile coords (k,j)) are re-derived, via the exact per-tile logic of
    /// [`Schedule::build_adaptive`]; every other slot is carried over
    /// verbatim.  The result is bitwise identical to a full
    /// `build_adaptive` over the updated normmaps, at a cost proportional
    /// to the touched rows/columns.
    ///
    /// `na`/`nb` are the *post-update* normmaps.  Returns the repaired
    /// schedule plus added/removed/retagged product counts.
    pub fn repair(
        &self,
        na: &NormMap,
        nb: &NormMap,
        tau: f32,
        density_threshold: f32,
        touched_a: Option<&[(usize, usize)]>,
        touched_b: Option<&[(usize, usize)]>,
    ) -> Result<(Schedule, RepairStats)> {
        if na.tile_rows() != self.tile_rows
            || na.tile_cols() != self.tile_k
            || nb.tile_rows() != self.tile_k
            || nb.tile_cols() != self.tile_cols
        {
            return Err(Error::Shape(format!(
                "repair: normmaps {}x{} / {}x{} do not match schedule grid {}x{}x{}",
                na.tile_rows(),
                na.tile_cols(),
                nb.tile_rows(),
                nb.tile_cols(),
                self.tile_rows,
                self.tile_k,
                self.tile_cols,
            )));
        }
        let mut rows = std::collections::BTreeSet::new();
        for &(i, k) in touched_a.unwrap_or(&[]) {
            if i >= self.tile_rows || k >= self.tile_k {
                return Err(Error::Shape(format!(
                    "repair: touched A tile ({i},{k}) outside {}x{} grid",
                    self.tile_rows, self.tile_k
                )));
            }
            rows.insert(i);
        }
        let mut cols = std::collections::BTreeSet::new();
        for &(k, j) in touched_b.unwrap_or(&[]) {
            if k >= self.tile_k || j >= self.tile_cols {
                return Err(Error::Shape(format!(
                    "repair: touched B tile ({k},{j}) outside {}x{} grid",
                    self.tile_k, self.tile_cols
                )));
            }
            cols.insert(j);
        }
        let mut out = self.clone();
        let mut stats = RepairStats::default();
        for i in 0..self.tile_rows {
            for j in 0..self.tile_cols {
                if !rows.contains(&i) && !cols.contains(&j) {
                    continue;
                }
                let slot = i * self.tile_cols + j;
                // Re-cull this tile's k-list (same loop as `build`).
                let mut ks = Vec::new();
                for k in 0..self.tile_k {
                    if na.norms[(i, k)] * nb.norms[(k, j)] >= tau {
                        ks.push(k as u32);
                    }
                }
                let strat = tile_strategies(na, nb, density_threshold, i, j, &ks);
                // Diff against the old slot (both k-lists are ascending).
                let (old_ks, old_st) = (&self.valid_k[slot], &self.strategies[slot]);
                let (mut a, mut b) = (0usize, 0usize);
                while a < old_ks.len() || b < ks.len() {
                    match (old_ks.get(a), ks.get(b)) {
                        (Some(&ko), Some(&kn)) if ko == kn => {
                            if old_st[a] != strat[b] {
                                stats.products_retagged += 1;
                            }
                            a += 1;
                            b += 1;
                        }
                        (Some(&ko), Some(&kn)) if ko < kn => {
                            stats.products_removed += 1;
                            a += 1;
                        }
                        (Some(_), Some(_)) | (None, Some(_)) => {
                            stats.products_added += 1;
                            b += 1;
                        }
                        (Some(_), None) => {
                            stats.products_removed += 1;
                            a += 1;
                        }
                        (None, None) => unreachable!(),
                    }
                }
                stats.tiles_rebuilt += 1;
                out.valid_k[slot] = ks;
                out.strategies[slot] = strat;
            }
        }
        Ok((out, stats))
    }

    /// (dense, sparse, packed) product counts over the whole schedule.
    pub fn strategy_counts(&self) -> (usize, usize, usize) {
        let (mut d, mut s, mut p) = (0, 0, 0);
        for strat in &self.strategies {
            for t in strat {
                match t {
                    TileStrategy::Dense => d += 1,
                    TileStrategy::Sparse => s += 1,
                    TileStrategy::Packed => p += 1,
                }
            }
        }
        (d, s, p)
    }

    /// The strategies parallel to `ks(i, j)`.
    pub fn strategies_for(&self, i: usize, j: usize) -> &[TileStrategy] {
        &self.strategies[i * self.tile_cols + j]
    }

    /// The paper's *valid multiplication* count v for tile (i, j) (§3.5.1).
    pub fn v(&self, i: usize, j: usize) -> usize {
        self.valid_k[i * self.tile_cols + j].len()
    }

    /// The V matrix of §3.5.1 (per-tile valid product counts).
    pub fn v_matrix(&self) -> Matrix {
        let mut m = Matrix::zeros(self.tile_rows, self.tile_cols);
        for i in 0..self.tile_rows {
            for j in 0..self.tile_cols {
                m[(i, j)] = self.v(i, j) as f32;
            }
        }
        m
    }

    /// Total surviving tile products.
    pub fn valid_products(&self) -> usize {
        self.valid_k.iter().map(|v| v.len()).sum()
    }

    /// All possible tile products (BDIM³ for square).
    pub fn total_products(&self) -> usize {
        self.tile_rows * self.tile_cols * self.tile_k
    }

    /// valid ratio = Σ V / BDIM³ (§3.5.2).
    pub fn valid_ratio(&self) -> f64 {
        self.valid_products() as f64 / self.total_products().max(1) as f64
    }

    /// Iterate the compacted products of one output tile as (k) list.
    pub fn ks(&self, i: usize, j: usize) -> &[u32] {
        &self.valid_k[i * self.tile_cols + j]
    }

    /// Does any surviving product consume A tile (ti, tk)?  A[i,k] feeds
    /// C[i,*], so scan row `ti`'s compacted k-lists for `tk`.  The
    /// serving tier's result cache uses this to decide whether a delta
    /// update of A dirtied a cached output: a changed tile that no valid
    /// product reads cannot change the result.
    pub fn touches_a_tile(&self, ti: usize, tk: usize) -> bool {
        if ti >= self.tile_rows || tk >= self.tile_k {
            return false;
        }
        let tk = tk as u32;
        (0..self.tile_cols).any(|j| self.ks(ti, j).contains(&tk))
    }

    /// Does any surviving product consume B tile (tk, tj)?  B[k,j] feeds
    /// C[*,j], so scan column `tj`'s compacted k-lists for `tk` — the B
    /// twin of [`Schedule::touches_a_tile`].
    pub fn touches_b_tile(&self, tk: usize, tj: usize) -> bool {
        if tk >= self.tile_k || tj >= self.tile_cols {
            return false;
        }
        let tk = tk as u32;
        (0..self.tile_rows).any(|i| self.ks(i, tj).contains(&tk))
    }

    /// Propagated norm upper bound of the product this schedule computes:
    /// bound[i, j] = Σ_{k surviving} ‖A[i,k]‖·‖B[k,j]‖ ≥ ‖C[i,j]‖_F (the
    /// triangle inequality over the compacted k-list, with Frobenius
    /// submultiplicativity per term).  The expression planner uses this to
    /// carry tile-norm information through a graph *without* computing the
    /// intermediate — τ resolution and schedule estimates for step k+1
    /// come from step k's bound; exact norms are refreshed from the
    /// device-resident output tiles only when τ-pruning demands them.
    pub fn bound_normmap(&self, na: &Matrix, nb: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(self.tile_rows, self.tile_cols);
        for i in 0..self.tile_rows {
            for j in 0..self.tile_cols {
                let mut acc = 0.0f64;
                for &k in self.ks(i, j) {
                    acc += (na[(i, k as usize)] as f64) * (nb[(k as usize, j)] as f64);
                }
                out[(i, j)] = acc as f32;
            }
        }
        out
    }

    /// Flatten a subset of output tiles into a (a_tile, b_tile, c_tile)
    /// product list — the batch feed for tile-GEMM execution.
    pub fn products_for_tiles<'a>(
        &'a self,
        tiles: impl IntoIterator<Item = (usize, usize)> + 'a,
    ) -> impl Iterator<Item = ProductRef> + 'a {
        tiles.into_iter().flat_map(move |(i, j)| {
            self.ks(i, j)
                .iter()
                .zip(self.strategies_for(i, j))
                .map(move |(&k, &strategy)| ProductRef {
                    a: (i, k as usize),
                    b: (k as usize, j),
                    c: (i, j),
                    strategy,
                })
        })
    }
}

/// Strategy tags of one output tile's surviving k-list: `Sparse` where
/// both operand tiles fall strictly below the density threshold, then
/// runs of ≥ 2 consecutive `Sparse` promoted to `Packed`.  The single
/// per-tile source of truth shared by [`Schedule::build_adaptive`] (full
/// grid) and [`Schedule::repair`] (touched tiles only) — one code path,
/// so a repaired tile cannot drift from a rebuilt one.  A non-positive
/// threshold yields all-`Dense`.
fn tile_strategies(
    na: &NormMap,
    nb: &NormMap,
    density_threshold: f32,
    i: usize,
    j: usize,
    ks: &[u32],
) -> Vec<TileStrategy> {
    let mut strat = vec![TileStrategy::Dense; ks.len()];
    if density_threshold <= 0.0 {
        return strat;
    }
    for (pos, &k) in ks.iter().enumerate() {
        let k = k as usize;
        if na.density[(i, k)] < density_threshold && nb.density[(k, j)] < density_threshold {
            strat[pos] = TileStrategy::Sparse;
        }
    }
    // Promote runs of ≥2 consecutive Sparse to Packed.
    let mut pos = 0;
    while pos < strat.len() {
        if strat[pos] != TileStrategy::Sparse {
            pos += 1;
            continue;
        }
        let mut end = pos + 1;
        while end < strat.len() && strat[end] == TileStrategy::Sparse {
            end += 1;
        }
        if end - pos >= 2 {
            for s in &mut strat[pos..end] {
                *s = TileStrategy::Packed;
            }
        }
        pos = end;
    }
    strat
}

/// Per-slot change counts from one [`Schedule::repair`] pass.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RepairStats {
    /// Output tiles whose k-list/strategies were re-derived.
    pub tiles_rebuilt: usize,
    /// Products newly crossing τ (present after, absent before).
    pub products_added: usize,
    /// Products newly culled by τ.
    pub products_removed: usize,
    /// Surviving products whose [`TileStrategy`] flipped.
    pub products_retagged: usize,
}

/// One surviving tile product A[i,k]·B[k,j] → C[i,j].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProductRef {
    pub a: (usize, usize),
    pub b: (usize, usize),
    pub c: (usize, usize),
    /// How the executor should stage and run this product.
    pub strategy: TileStrategy,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nm(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Matrix {
        let mut m = Matrix::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m[(r, c)] = f(r, c);
            }
        }
        m
    }

    #[test]
    fn tau_zero_keeps_everything() {
        let na = nm(3, 4, |_, _| 1.0);
        let nb = nm(4, 2, |_, _| 1.0);
        let s = Schedule::build(&na, &nb, 0.0).unwrap();
        assert_eq!(s.valid_products(), 3 * 4 * 2);
        assert_eq!(s.valid_ratio(), 1.0);
        for i in 0..3 {
            for j in 0..2 {
                assert_eq!(s.ks(i, j), &[0, 1, 2, 3]);
            }
        }
    }

    #[test]
    fn huge_tau_keeps_nothing() {
        let na = nm(2, 2, |_, _| 1.0);
        let s = Schedule::build(&na, &na, 10.0).unwrap();
        assert_eq!(s.valid_products(), 0);
        assert_eq!(s.valid_ratio(), 0.0);
    }

    #[test]
    fn threshold_is_inclusive() {
        // The paper's test is ≥ τ (Alg. 1 line 7).
        let na = nm(1, 1, |_, _| 2.0);
        let nb = nm(1, 1, |_, _| 3.0);
        let s = Schedule::build(&na, &nb, 6.0).unwrap();
        assert_eq!(s.valid_products(), 1);
        let s = Schedule::build(&na, &nb, 6.0 + 1e-4).unwrap();
        assert_eq!(s.valid_products(), 0);
    }

    #[test]
    fn selective_k() {
        // na row 0 = [1, 0], nb col 0 = [1, 1]: only k=0 survives τ=0.5.
        let na = nm(1, 2, |_, k| if k == 0 { 1.0 } else { 0.0 });
        let nb = nm(2, 1, |_, _| 1.0);
        let s = Schedule::build(&na, &nb, 0.5).unwrap();
        assert_eq!(s.ks(0, 0), &[0]);
    }

    #[test]
    fn v_matrix_diagonal_dominates_for_decay() {
        use crate::matrix::tiling::PaddedMatrix;
        use crate::spamm::normmap::normmap;

        let a = Matrix::decay_exponential(256, 1.0, 0.5, 1);
        let p = PaddedMatrix::new(&a, 32);
        let na = normmap(&p);
        let s = Schedule::build(&na, &na, 1e-4).unwrap();
        let v = s.v_matrix();
        // §3.5.1's observation: v is largest near the diagonal.
        let center = v[(4, 4)];
        let corner = v[(0, 7)];
        assert!(center > corner, "center {center} corner {corner}");
    }

    #[test]
    fn products_cover_compaction() {
        let na = nm(2, 3, |i, k| (i + k) as f32);
        let nb = nm(3, 2, |k, j| (k * j) as f32 + 0.5);
        let s = Schedule::build(&na, &nb, 1.0).unwrap();
        let all: Vec<ProductRef> = s
            .products_for_tiles((0..2).flat_map(|i| (0..2).map(move |j| (i, j))))
            .collect();
        assert_eq!(all.len(), s.valid_products());
        for p in all {
            assert!(na[(p.a.0, p.a.1)] * nb[(p.b.0, p.b.1)] >= 1.0);
            assert_eq!(p.a.1, p.b.0);
        }
    }

    #[test]
    fn bound_normmap_dominates_exact_product_norms() {
        use crate::matrix::tiling::PaddedMatrix;
        use crate::spamm::normmap::normmap;

        let a = Matrix::decay_exponential(128, 1.0, 0.5, 6);
        let b = Matrix::decay_exponential(128, 1.0, 0.5, 7);
        let pa = PaddedMatrix::new(&a, 32);
        let pb = PaddedMatrix::new(&b, 32);
        let (na, nb) = (normmap(&pa), normmap(&pb));
        let s = Schedule::build(&na, &nb, 0.0).unwrap();
        let bound = s.bound_normmap(&na, &nb);
        // Exact norms of the actual product C = A·B.
        let c = a.matmul(&b).unwrap();
        let nc = normmap(&PaddedMatrix::new(&c, 32));
        for i in 0..nc.rows() {
            for j in 0..nc.cols() {
                assert!(
                    bound[(i, j)] >= nc[(i, j)] * (1.0 - 1e-5),
                    "bound {} < exact {} at ({i},{j})",
                    bound[(i, j)],
                    nc[(i, j)]
                );
            }
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let na = nm(2, 3, |_, _| 1.0);
        let nb = nm(2, 2, |_, _| 1.0);
        assert!(Schedule::build(&na, &nb, 0.0).is_err());
    }

    #[test]
    fn adaptive_zero_threshold_matches_dense_build() {
        let norms_a = nm(3, 4, |i, k| (i + k) as f32 + 0.5);
        let norms_b = nm(4, 3, |k, j| (k * j) as f32 + 0.25);
        // Low density everywhere: would go sparse at any positive threshold.
        let na = NormMap {
            norms: norms_a.clone(),
            density: nm(3, 4, |_, _| 0.01),
        };
        let nb = NormMap {
            norms: norms_b.clone(),
            density: nm(4, 3, |_, _| 0.01),
        };
        let adaptive = Schedule::build_adaptive(&na, &nb, 1.0, 0.0).unwrap();
        let dense = Schedule::build(&norms_a, &norms_b, 1.0).unwrap();
        assert_eq!(adaptive.valid_k, dense.valid_k);
        assert_eq!(adaptive.strategy_counts().0, adaptive.valid_products());
        assert_eq!(adaptive.strategy_counts().1 + adaptive.strategy_counts().2, 0);
    }

    #[test]
    fn adaptive_requires_both_operands_sparse() {
        let norms = nm(1, 2, |_, _| 1.0);
        let norms_b = nm(2, 1, |_, _| 1.0);
        // A tiles sparse, B tile k=0 dense, k=1 sparse → only k=1 product
        // may leave the dense path (single product: Sparse, not Packed).
        let na = NormMap {
            norms,
            density: nm(1, 2, |_, _| 0.1),
        };
        let nb = NormMap {
            norms: norms_b,
            density: nm(2, 1, |k, _| if k == 0 { 0.9 } else { 0.1 }),
        };
        let s = Schedule::build_adaptive(&na, &nb, 0.0, 0.5).unwrap();
        assert_eq!(s.strategies_for(0, 0), &[TileStrategy::Dense, TileStrategy::Sparse]);
    }

    #[test]
    fn repair_matches_full_rebuild_bitwise() {
        use crate::matrix::tiling::PaddedMatrix;
        use crate::spamm::normmap::normmap_with_density;

        // A drifts in tiles (0,1) and (3,2); B drifts in (1,0).  Repair
        // over the touched rows/columns must equal a full rebuild for
        // every (τ, threshold) combination.
        let a0 = Matrix::decay_exponential(128, 1.0, 0.5, 31);
        let b0 = Matrix::decay_exponential(128, 1.0, 0.5, 32);
        let mut a1 = a0.clone();
        let mut b1 = b0.clone();
        for r in 0..32 {
            for c in 32..64 {
                a1[(r, c)] += 0.75;
            }
        }
        for r in 96..128 {
            for c in 64..96 {
                a1[(r, c)] = 0.0;
            }
        }
        for r in 32..64 {
            for c in 0..32 {
                b1[(r, c)] += 1.5;
            }
        }
        let nm = |m: &Matrix| normmap_with_density(&PaddedMatrix::new(m, 32));
        let (na0, nb0) = (nm(&a0), nm(&b0));
        let (na1, nb1) = (nm(&a1), nm(&b1));
        for tau in [0.0f32, 1e-3] {
            for dt in [0.0f32, 0.25, 0.9] {
                let old = Schedule::build_adaptive(&na0, &nb0, tau, dt).unwrap();
                let (repaired, rs) = old
                    .repair(
                        &na1,
                        &nb1,
                        tau,
                        dt,
                        Some(&[(0, 1), (3, 2)]),
                        Some(&[(1, 0)]),
                    )
                    .unwrap();
                let rebuilt = Schedule::build_adaptive(&na1, &nb1, tau, dt).unwrap();
                assert_eq!(repaired.valid_k, rebuilt.valid_k, "tau {tau} dt {dt}");
                assert_eq!(repaired.strategies, rebuilt.strategies, "tau {tau} dt {dt}");
                // Touched rows {0,3} + column {0}: 2 rows × 4 cols + 2
                // remaining tiles of column 0.
                assert_eq!(rs.tiles_rebuilt, 2 * 4 + 2, "tau {tau} dt {dt}");
            }
        }
        // A-side-only repair with no B changes.
        let old = Schedule::build_adaptive(&na0, &nb0, 1e-3, 0.25).unwrap();
        let (repaired, _) = old
            .repair(&na1, &nb0, 1e-3, 0.25, Some(&[(0, 1), (3, 2)]), None)
            .unwrap();
        let rebuilt = Schedule::build_adaptive(&na1, &nb0, 1e-3, 0.25).unwrap();
        assert_eq!(repaired.valid_k, rebuilt.valid_k);
        assert_eq!(repaired.strategies, rebuilt.strategies);
    }

    #[test]
    fn repair_counts_added_removed_retagged() {
        // 1x1 tile grid with tile_k = 2: start with both products
        // surviving, then push k=0 below τ and flip k=1's density.
        let mk = |n0: f32, n1: f32, d0: f32, d1: f32| NormMap {
            norms: nm(1, 2, |_, k| if k == 0 { n0 } else { n1 }),
            density: nm(1, 2, |_, k| if k == 0 { d0 } else { d1 }),
        };
        let mkb = |d: f32| NormMap {
            norms: nm(2, 1, |_, _| 1.0),
            density: nm(2, 1, |_, _| d),
        };
        let na0 = mk(1.0, 1.0, 0.9, 0.9);
        let nb = mkb(0.1);
        let old = Schedule::build_adaptive(&na0, &nb, 0.5, 0.5).unwrap();
        assert_eq!(old.ks(0, 0), &[0, 1]);
        // After the update: k=0 culled (norm 0.1 < τ), k=1 goes sparse.
        let na1 = mk(0.1, 1.0, 0.9, 0.2);
        let (repaired, rs) = old
            .repair(&na1, &nb, 0.5, 0.5, Some(&[(0, 0), (0, 1)]), None)
            .unwrap();
        assert_eq!(repaired.ks(0, 0), &[1]);
        assert_eq!(repaired.strategies_for(0, 0), &[TileStrategy::Sparse]);
        assert_eq!(rs.products_removed, 1);
        assert_eq!(rs.products_retagged, 1);
        assert_eq!(rs.products_added, 0);
        // Reverse direction: the culled product reappears.
        let (back, rs2) = repaired
            .repair(&na0, &nb, 0.5, 0.5, Some(&[(0, 0), (0, 1)]), None)
            .unwrap();
        assert_eq!(back.ks(0, 0), &[0, 1]);
        assert_eq!(rs2.products_added, 1);
    }

    #[test]
    fn repair_rejects_bad_coords_and_shapes() {
        let na = NormMap::dense_like(nm(2, 2, |_, _| 1.0));
        let s = Schedule::build_adaptive(&na, &na, 0.0, 0.0).unwrap();
        assert!(s.repair(&na, &na, 0.0, 0.0, Some(&[(2, 0)]), None).is_err());
        assert!(s.repair(&na, &na, 0.0, 0.0, None, Some(&[(0, 5)])).is_err());
        let wrong = NormMap::dense_like(nm(3, 2, |_, _| 1.0));
        assert!(s.repair(&wrong, &na, 0.0, 0.0, None, None).is_err());
    }

    #[test]
    fn adaptive_packs_consecutive_sparse_runs() {
        // 4 products for one output tile; k=1..=2 dense-blocked in the
        // middle would split the run. Here densities: sparse, sparse,
        // dense, sparse → [Packed, Packed, Dense, Sparse].
        let na = NormMap {
            norms: nm(1, 4, |_, _| 1.0),
            density: nm(1, 4, |_, k| if k == 2 { 0.9 } else { 0.1 }),
        };
        let nb = NormMap {
            norms: nm(4, 1, |_, _| 1.0),
            density: nm(4, 1, |_, _| 0.1),
        };
        let s = Schedule::build_adaptive(&na, &nb, 0.0, 0.5).unwrap();
        assert_eq!(
            s.strategies_for(0, 0),
            &[
                TileStrategy::Packed,
                TileStrategy::Packed,
                TileStrategy::Dense,
                TileStrategy::Sparse,
            ]
        );
        assert_eq!(s.strategy_counts(), (1, 1, 2));
        // products_for_tiles carries the strategy through.
        let prods: Vec<ProductRef> = s.products_for_tiles([(0, 0)]).collect();
        assert_eq!(prods[0].strategy, TileStrategy::Packed);
        assert_eq!(prods[2].strategy, TileStrategy::Dense);
    }
}
