//! Single-device SpAMM executor: the paper's two-kernel pipeline driven
//! from Rust — get-norm (host or device), τ tuning, schedule compaction,
//! and *stage-pipelined* batched tile-GEMM execution with genuine work
//! skipping.
//!
//! Three levels of reuse/overlap (§3.3 blocking, §3.4 pipeline):
//!
//! * **Caching** — normmaps and compacted schedules are memoized in
//!   [`ExecCaches`] keyed on operand content fingerprints + τ, so
//!   `power`/`purification` loops and repeated service requests skip the
//!   get-norm and schedule phases entirely on hits.
//! * **Residency** — operand tiles are uploaded once into a per-device
//!   [`ResidencyPool`] keyed on content fingerprint + tile coordinate; the
//!   gather stage resolves refcounted *handles* and only cache misses
//!   transfer bytes.  Repeated multiplies on warm operands skip phase-3
//!   transfers entirely, and a tile referenced by k products of one chunk
//!   is staged once, not k times.
//! * **Pipelining** — [`execute_batches`] runs one gather∥exec∥scatter
//!   pipeline across *all* pipeline batches: the transfer worker stages
//!   batch *i+1*'s chunks while this thread runs tile-GEMM on batch *i*'s
//!   (no per-batch join), and a scatter worker drains finished products.
//!   With overlap, the per-stage second sums in [`MultiplyStats`] exceed
//!   the `exec_span_secs` wall clock.

use std::collections::HashMap;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::config::{Precision, SpammConfig};
use crate::error::{Error, Result};
use crate::matrix::tiling::{gather_tiles, scatter_accumulate, PaddedMatrix};
use crate::matrix::Matrix;
use crate::runtime::residency::{
    DeviceTile, PatchOutcome, ResidencyPool, ResidentOperand, TileHandle, TileKey,
};
use crate::runtime::{ArtifactBundle, Runtime};
use crate::sparse::{pack_tile, packed_to_coo, spgemm};
use crate::spamm::cache::{
    fingerprint, fingerprint_patch, ExecCaches, Fingerprint, ScheduleRepairOutcome,
};
use crate::spamm::normmap::{normmap_with_density, resolve_density_threshold, NormMap};
use crate::spamm::schedule::{ProductRef, Schedule, TileStrategy};
use crate::spamm::tuner::{self, TuneParams};
use crate::telemetry;

pub use crate::spamm::tuner::TuneResult;

/// Timing/counting breakdown of one multiply call.
#[derive(Clone, Debug, Default)]
pub struct MultiplyStats {
    pub valid_products: usize,
    pub total_products: usize,
    pub valid_ratio: f64,
    pub norm_secs: f64,
    pub schedule_secs: f64,
    /// Seconds inside the gather/transfer stage (overlaps exec when
    /// pipelined): handle resolution plus cache-miss uploads.
    pub gather_secs: f64,
    /// Seconds inside tile-GEMM execution (includes the device-side pack
    /// of resident tiles into the batch buffer).
    pub exec_secs: f64,
    /// Seconds inside the scatter-accumulate stage (overlaps exec).
    pub scatter_secs: f64,
    /// Wall-clock span of the pipelined gather/exec/scatter loop.  With
    /// overlap, `gather_secs + exec_secs + scatter_secs > exec_span_secs`.
    pub exec_span_secs: f64,
    pub total_secs: f64,
    pub batches: usize,
    /// Pipeline depth (in-flight chunks) used by the executor.
    pub pipeline_depth: usize,
    /// Norm-cache hits/misses for this call's operands.
    pub norm_cache_hits: usize,
    pub norm_cache_misses: usize,
    /// Schedule-cache hits/misses for this call's (A, B, τ) key.
    pub schedule_cache_hits: usize,
    pub schedule_cache_misses: usize,
    /// Residency-pool hits/misses/evictions for this call's operand tiles
    /// (all zero when residency is disabled).
    pub residency_hits: usize,
    pub residency_misses: usize,
    pub residency_evictions: usize,
    /// Expression-graph norm accounting: schedules built directly from
    /// *propagated* norm upper bounds (no norm computation at all), and
    /// exact intermediate normmaps *refreshed* from device-resident
    /// output tiles (no host recomputation, no transfer).  Host norm
    /// recomputations of intermediates would show up as
    /// `norm_cache_misses` instead — the expression path keeps that at
    /// zero.
    pub norms_propagated: usize,
    pub norms_refreshed: usize,
    /// Bytes actually uploaded host→device by the gather stage.
    pub transfer_bytes: u64,
    /// Bytes *not* uploaded thanks to residency hits and within-chunk
    /// operand-tile deduplication.
    pub transfer_saved_bytes: u64,
    /// Surviving products executed through the dense tile-GEMM path.
    pub dense_products: usize,
    /// Surviving products whose tile pair fell below the density
    /// threshold and ran through the sparse (COO sptile) path singly.
    pub sparse_products: usize,
    /// Sparse products fused into multi-tile packed dispatches.
    pub packed_products: usize,
    /// sptile kernel dispatches issued (each covers ≥1 sparse/packed
    /// products of one output tile).
    pub sparse_dispatches: usize,
    /// Bytes *not* uploaded because sparse-strategy tiles staged in
    /// packed COO layout instead of full LoNum² buffers — the
    /// density-adaptive format win, disjoint from residency-hit savings.
    pub format_saved_bytes: u64,
    /// Bytes of *device-produced* tiles (expression intermediates) that
    /// had to bounce through the host because the consuming device did
    /// not have them resident — the multi-device expression graphs'
    /// cross-device traffic.  A subset of `transfer_bytes`; always zero
    /// on single-device runs (an eviction-forced re-stage there is not a
    /// bounce), and on multi-device runs it includes eviction-forced
    /// re-bounces alongside true producer/consumer mismatches.
    pub cross_device_bytes: u64,
    /// Delta-update accounting, folded into the first submit after an
    /// operand update (front-end fields like the cache counters — not
    /// absorbed from device workers): norm-map tiles re-censused in
    /// place instead of a full get-norm pass, cached schedules repaired
    /// in place instead of rebuilt, and the product-level churn those
    /// repairs applied.
    pub norm_tiles_patched: usize,
    pub schedules_repaired: usize,
    pub repair_products_added: usize,
    pub repair_products_removed: usize,
    pub repair_products_retagged: usize,
    /// Warm-start store accounting (front-end fields, all zero without a
    /// store): artifacts restored from disk instead of recomputed.  A
    /// store hit is *neither* a cache hit nor a cache miss — the
    /// in-memory tier missed, but the cold recompute never ran.
    pub store_normmap_hits: usize,
    pub store_schedule_hits: usize,
    pub store_tau_hits: usize,
    pub store_bundle_hits: usize,
    /// τ auto-tunes actually executed (the bisection ran); a store-
    /// restored tune increments `store_tau_hits` instead.
    pub tau_tuned: usize,
    /// Fresh executable compiles this call paid across every runtime it
    /// touched (device workers and, for expression graphs, the
    /// orchestrator).  Warm requests on persistent per-device worker
    /// runtimes hold this at zero — the serving tier's no-recompile
    /// contract.
    pub compiles: u64,
    /// Seconds inside those compiles (excluded from the busy clocks and
    /// the pipeline walls, like the paper excludes warmup).
    pub compile_secs: f64,
}

impl MultiplyStats {
    /// Fold another record's pipeline-stage measurements into this one —
    /// used to aggregate per-device worker stats into a multi-device
    /// report.  Cache and schedule-phase fields are left untouched (they
    /// belong to the front-end, not the device workers).
    pub fn absorb_stages(&mut self, other: &MultiplyStats) {
        self.gather_secs += other.gather_secs;
        self.exec_secs += other.exec_secs;
        self.scatter_secs += other.scatter_secs;
        self.exec_span_secs += other.exec_span_secs;
        self.batches += other.batches;
        self.pipeline_depth = self.pipeline_depth.max(other.pipeline_depth);
        self.residency_hits += other.residency_hits;
        self.residency_misses += other.residency_misses;
        self.residency_evictions += other.residency_evictions;
        self.norms_propagated += other.norms_propagated;
        self.norms_refreshed += other.norms_refreshed;
        self.dense_products += other.dense_products;
        self.sparse_products += other.sparse_products;
        self.packed_products += other.packed_products;
        self.sparse_dispatches += other.sparse_dispatches;
        self.format_saved_bytes += other.format_saved_bytes;
        self.transfer_bytes += other.transfer_bytes;
        self.transfer_saved_bytes += other.transfer_saved_bytes;
        self.cross_device_bytes += other.cross_device_bytes;
    }
}

/// Where an operand's tiles come from.
///
/// `Host` is the classic padded host matrix — the gather stage uploads
/// pool misses from it.  `Resident` is an expression-graph intermediate
/// living entirely in the device pool: its tiles were produced by a
/// previous node's scatter, so gathers are guaranteed pool hits (the
/// holder's handles pin them) and transfer zero bytes; the fill fallback
/// copies from the held handles, never from host data.
#[derive(Clone, Copy)]
pub enum TileSource<'a> {
    Host(&'a PaddedMatrix),
    Resident(&'a ResidentOperand),
}

impl<'a> TileSource<'a> {
    pub fn lonum(&self) -> usize {
        match self {
            TileSource::Host(p) => p.lonum,
            TileSource::Resident(r) => r.lonum(),
        }
    }

    pub fn tile_rows(&self) -> usize {
        match self {
            TileSource::Host(p) => p.tile_rows(),
            TileSource::Resident(r) => r.tile_rows(),
        }
    }

    pub fn tile_cols(&self) -> usize {
        match self {
            TileSource::Host(p) => p.tile_cols(),
            TileSource::Resident(r) => r.tile_cols(),
        }
    }

    pub fn copy_tile(&self, ti: usize, tj: usize, dst: &mut [f32]) {
        match self {
            TileSource::Host(p) => p.copy_tile(ti, tj, dst),
            TileSource::Resident(r) => r.copy_tile(ti, tj, dst),
        }
    }
}

/// An operand (tile source) plus its content fingerprint — the identity
/// the residency pool keys device-resident tiles on.  `fp == None`
/// (caching and residency both disabled) downgrades the gather stage to
/// plain copies.
#[derive(Clone, Copy)]
pub struct Operand<'a> {
    pub src: TileSource<'a>,
    pub fp: Option<Fingerprint>,
}

impl<'a> Operand<'a> {
    pub fn new(padded: &'a PaddedMatrix, fp: Option<Fingerprint>) -> Operand<'a> {
        Operand {
            src: TileSource::Host(padded),
            fp,
        }
    }

    /// An expression intermediate: device tiles under a derived
    /// fingerprint, no host backing.
    pub fn resident(r: &'a ResidentOperand) -> Operand<'a> {
        Operand {
            src: TileSource::Resident(r),
            fp: Some(r.fingerprint()),
        }
    }
}

/// Result of one delta update applied through
/// [`SpammEngine::update_operand`]: the patched padded operand and its
/// incrementally-derived fingerprint, plus what the caches and the
/// residency pool did with the touched tiles.
#[derive(Debug)]
pub struct OperandUpdate {
    /// Padded operand with the changed tiles overwritten (untouched tiles
    /// bitwise identical to the previous content).
    pub padded: PaddedMatrix,
    /// New content fingerprint, derived incrementally from the old one
    /// plus the changed tiles only.
    pub fp: Fingerprint,
    /// Whether the norm map was patched in place (old entry was cached)
    /// rather than recomputed from scratch.
    pub norm_patched: bool,
    /// Touched tiles re-censused (norm + density) — zero on the full
    /// recompute fallback.
    pub norm_tiles_patched: usize,
    /// What the residency pool migrated/uploaded/dropped.
    pub pool: PatchOutcome,
    /// Cached-schedule repair summary across every entry involving the
    /// operand.
    pub repair: ScheduleRepairOutcome,
}

/// Single-device SpAMM engine.
pub struct SpammEngine {
    rt: Runtime,
    cfg: SpammConfig,
    caches: ExecCaches,
    /// Device-resident operand-tile pool (None under `--no-residency`).
    pool: Option<Arc<ResidencyPool>>,
}

impl SpammEngine {
    pub fn new(bundle: &ArtifactBundle, cfg: SpammConfig) -> Result<SpammEngine> {
        cfg.validate()?;
        let pool = cfg
            .residency_enabled
            .then(|| Arc::new(ResidencyPool::new(cfg.device_mem_budget)));
        let caches = ExecCaches::with_store(crate::store::WarmStore::from_config(&cfg));
        Ok(SpammEngine {
            rt: Runtime::new(bundle)?,
            cfg,
            caches,
            pool,
        })
    }

    pub fn config(&self) -> &SpammConfig {
        &self.cfg
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// The engine's norm/schedule caches (hit/miss inspection).
    pub fn caches(&self) -> &ExecCaches {
        &self.caches
    }

    /// The engine's device-resident tile pool (None under
    /// `--no-residency`).
    pub fn residency(&self) -> Option<&ResidencyPool> {
        self.pool.as_deref()
    }

    /// normmap of a padded matrix — on-device (get-norm artifact) when
    /// configured and available, host otherwise.  The host pass also
    /// takes the per-tile density census (near-free: same traversal); the
    /// device get-norm artifact reports norms only, so its result is
    /// marked fully dense — device-normed operands never select the
    /// sparse path, which is conservative, never wrong.
    pub fn normmap_of(&self, p: &PaddedMatrix) -> Result<NormMap> {
        if self.cfg.device_normmap && p.inner.rows() == p.inner.cols() {
            let mxu = self.cfg.precision == Precision::Bf16;
            if self
                .rt
                .bundle()
                .getnorm(p.inner.rows(), self.cfg.lonum, mxu)
                .is_ok()
            {
                return Ok(NormMap::dense_like(
                    self.rt.getnorm(&p.inner, self.cfg.lonum, mxu)?,
                ));
            }
            log::debug!(
                "no get-norm artifact for n={}, falling back to host",
                p.inner.rows()
            );
        }
        Ok(normmap_with_density(p))
    }

    /// Cached normmap: fingerprint the operand and consult the norm cache
    /// (bypassed entirely when `cache_enabled` is off).
    fn cached_normmap(
        &self,
        p: &PaddedMatrix,
        stats: &mut MultiplyStats,
    ) -> Result<(Arc<NormMap>, Option<Fingerprint>)> {
        self.caches
            .normmap_via(self.cfg.cache_enabled, p, stats, || self.normmap_of(p))
    }

    /// Tune τ for a target valid ratio (§3.5.2; host twin of tune.py).
    pub fn tune_tau(&self, a: &Matrix, b: &Matrix, target: f64) -> Result<TuneResult> {
        check_inner_dims("tune_tau", a, b)?;
        let pa = PaddedMatrix::new(a, self.cfg.lonum);
        let pb = PaddedMatrix::new(b, self.cfg.lonum);
        let mut scratch = MultiplyStats::default();
        let (na, _) = self.cached_normmap(&pa, &mut scratch)?;
        let (nb, _) = self.cached_normmap(&pb, &mut scratch)?;
        tuner::tune_tau(&na.norms, &nb.norms, target, TuneParams::default())
    }

    /// SpAMM multiply: C ≈ A·B skipping tile products under τ.
    pub fn multiply(&self, a: &Matrix, b: &Matrix, tau: f32) -> Result<Matrix> {
        Ok(self.multiply_with_stats(a, b, tau)?.0)
    }

    /// Multiply with a full stats breakdown.
    pub fn multiply_with_stats(
        &self,
        a: &Matrix,
        b: &Matrix,
        tau: f32,
    ) -> Result<(Matrix, MultiplyStats)> {
        check_inner_dims("multiply", a, b)?;
        let t_total = Instant::now();
        let (compiles0, compile_secs0) = (self.rt.compiles(), self.rt.compile_secs());
        let mut stats = MultiplyStats::default();

        let pa = PaddedMatrix::new(a, self.cfg.lonum);
        let pb = PaddedMatrix::new(b, self.cfg.lonum);

        let t = Instant::now();
        let (na, mut fa) = self.cached_normmap(&pa, &mut stats)?;
        let (nb, mut fb) = self.cached_normmap(&pb, &mut stats)?;
        stats.norm_secs = t.elapsed().as_secs_f64();

        let t = Instant::now();
        let dt = resolve_density_threshold(&self.cfg, &na, &nb);
        let sched = self
            .caches
            .schedule_via(fa, fb, tau, dt, &na, &nb, &mut stats)?;
        stats.schedule_secs = t.elapsed().as_secs_f64();
        stats.valid_products = sched.valid_products();
        stats.total_products = sched.total_products();
        stats.valid_ratio = sched.valid_ratio();

        // Residency keys on content fingerprints; compute them here even
        // when the norm cache (which normally provides them) is off.
        if self.pool.is_some() {
            fa = fa.or_else(|| Some(fingerprint(&pa)));
            fb = fb.or_else(|| Some(fingerprint(&pb)));
        }

        let c = self.execute_all_tiles(
            Operand::new(&pa, fa),
            Operand::new(&pb, fb),
            &sched,
            a.rows(),
            b.cols(),
            &mut stats,
        )?;
        stats.compiles = self.rt.compiles() - compiles0;
        stats.compile_secs = self.rt.compile_secs() - compile_secs0;
        stats.total_secs = t_total.elapsed().as_secs_f64();
        Ok((c, stats))
    }

    /// Multiply operands whose padded form and content fingerprints are
    /// *already known* (registered session handles): the norm and
    /// schedule caches are consulted by id — no O(N²) re-hash per call —
    /// and the residency pool keys on the same fingerprints.  The
    /// fingerprint-by-id twin of [`SpammEngine::multiply_with_stats`].
    pub fn multiply_prepared_with_stats(
        &self,
        pa: &PaddedMatrix,
        fa: Fingerprint,
        pb: &PaddedMatrix,
        fb: Fingerprint,
        tau: f32,
    ) -> Result<(Matrix, MultiplyStats)> {
        if pa.logical_cols != pb.logical_rows {
            return Err(Error::Shape(format!(
                "multiply_prepared: inner dimensions disagree: A is {}x{}, B is {}x{}",
                pa.logical_rows, pa.logical_cols, pb.logical_rows, pb.logical_cols
            )));
        }
        let t_total = Instant::now();
        let (compiles0, compile_secs0) = (self.rt.compiles(), self.rt.compile_secs());
        let mut stats = MultiplyStats::default();
        let cached = self.cfg.cache_enabled;
        let t = Instant::now();
        let (na, nb) = if cached {
            (
                self.caches.normmap_keyed(fa, &mut stats, || self.normmap_of(pa))?,
                self.caches.normmap_keyed(fb, &mut stats, || self.normmap_of(pb))?,
            )
        } else {
            (Arc::new(self.normmap_of(pa)?), Arc::new(self.normmap_of(pb)?))
        };
        stats.norm_secs = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let dt = resolve_density_threshold(&self.cfg, &na, &nb);
        let sched = if cached {
            self.caches
                .schedule_via(Some(fa), Some(fb), tau, dt, &na, &nb, &mut stats)?
        } else {
            Arc::new(Schedule::build_adaptive(&na, &nb, tau, dt)?)
        };
        stats.schedule_secs = t.elapsed().as_secs_f64();
        stats.valid_products = sched.valid_products();
        stats.total_products = sched.total_products();
        stats.valid_ratio = sched.valid_ratio();

        let c = self.execute_all_tiles(
            Operand::new(pa, Some(fa)),
            Operand::new(pb, Some(fb)),
            &sched,
            pa.logical_rows,
            pb.logical_cols,
            &mut stats,
        )?;
        stats.compiles = self.rt.compiles() - compiles0;
        stats.compile_secs = self.rt.compile_secs() - compile_secs0;
        stats.total_secs = t_total.elapsed().as_secs_f64();
        Ok((c, stats))
    }

    /// Apply a delta update to a prepared operand: overwrite the listed
    /// tiles with `data` (one row-major LoNum² block per coordinate, in
    /// the order of `changed`), derive the new content fingerprint
    /// incrementally, patch the cached norm map (touched tiles only),
    /// migrate the operand's resident tiles (uploading only the changed
    /// ones), and *repair* every cached schedule involving the operand
    /// instead of rebuilding it.  The engine twin of the session-level
    /// `update`: the caller keeps the returned padded matrix +
    /// fingerprint and threads them into
    /// [`SpammEngine::multiply_prepared_with_stats`].
    pub fn update_operand(
        &self,
        padded: &PaddedMatrix,
        fp: Fingerprint,
        changed: &[(usize, usize)],
        data: &[f32],
    ) -> Result<OperandUpdate> {
        let new_padded = padded.with_patched_tiles(changed, data)?;
        let mut tiles = changed.to_vec();
        tiles.sort_unstable();
        tiles.dedup();
        let new_fp = fingerprint_patch(fp, &new_padded, &tiles);
        let (nm, norm_patched) = match self.caches.patch_normmap(fp, new_fp, &new_padded, &tiles)
        {
            Some(nm) => (nm, true),
            None => {
                // Old norms not cached (cold operand or caching off):
                // nothing to patch — take the full pass once and register
                // it so the repair sweep and the next submit share it.
                let nm = Arc::new(self.normmap_of(&new_padded)?);
                if self.cfg.cache_enabled {
                    self.caches.norms.insert(new_fp, nm.clone());
                }
                (nm, false)
            }
        };
        let pool = match &self.pool {
            Some(pool) => {
                let l2 = new_padded.lonum * new_padded.lonum;
                pool.patch_operand(fp, new_fp, &tiles, l2, |t, buf| {
                    new_padded.copy_tile(t.0, t.1, buf)
                })
            }
            None => PatchOutcome::default(),
        };
        let repair = self.caches.repair_schedules(fp, new_fp, &nm, &tiles);
        Ok(OperandUpdate {
            padded: new_padded,
            fp: new_fp,
            norm_patched,
            norm_tiles_patched: if norm_patched { tiles.len() } else { 0 },
            pool,
            repair,
        })
    }

    /// Shared execution tail of both multiply entry points: allocate the
    /// padded output, run every output tile of the schedule through
    /// [`execute_batches`], crop to the logical shape.
    fn execute_all_tiles(
        &self,
        pa: Operand<'_>,
        pb: Operand<'_>,
        sched: &Schedule,
        out_rows: usize,
        out_cols: usize,
        stats: &mut MultiplyStats,
    ) -> Result<Matrix> {
        let mut pc = PaddedMatrix::new(&Matrix::zeros(out_rows, out_cols), self.cfg.lonum);
        let all_tiles: Vec<(usize, usize)> = (0..sched.tile_rows)
            .flat_map(|i| (0..sched.tile_cols).map(move |j| (i, j)))
            .collect();
        execute_batches(
            &self.rt,
            &self.cfg,
            self.pool.as_deref(),
            pa,
            pb,
            &mut pc,
            sched,
            &[all_tiles.as_slice()],
            stats,
        )?;
        Ok(pc.crop())
    }

    /// Dense baseline (cuBLAS stand-in) on the same runtime.
    pub fn dense(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        check_inner_dims("dense", a, b)?;
        self.rt.dense(a, b, self.cfg.precision.as_str())
    }

    /// The paper's general form (§2.1): C ← α·SpAMM(A, B, τ) + β·C.
    pub fn multiply_axpby(
        &self,
        alpha: f32,
        a: &Matrix,
        b: &Matrix,
        tau: f32,
        beta: f32,
        c: &Matrix,
    ) -> Result<Matrix> {
        if c.rows() != a.rows() || c.cols() != b.cols() {
            return Err(Error::Shape(format!(
                "axpby: C is {}x{}, want {}x{}",
                c.rows(),
                c.cols(),
                a.rows(),
                b.cols()
            )));
        }
        let mut prod = self.multiply(a, b, tau)?;
        for (p, &cv) in prod.data_mut().iter_mut().zip(c.data()) {
            *p = alpha * *p + beta * cv;
        }
        Ok(prod)
    }

    /// Fused single-call SpAMM (on-device normmaps + masked multiply) —
    /// the numerics oracle path; requires a `spamm_fused_n{N}` artifact.
    pub fn multiply_fused(&self, a: &Matrix, b: &Matrix, tau: f32) -> Result<Matrix> {
        check_inner_dims("multiply_fused", a, b)?;
        self.rt
            .spamm_fused(a, b, tau, self.cfg.precision.as_str())
    }
}

/// Validate the inner dimensions of A·B.  Mismatches that pad to the same
/// tile count (e.g. 17 vs 20 at lonum 32) would otherwise silently produce
/// garbage — the schedule only sees tile grids.
pub fn check_inner_dims(op: &str, a: &Matrix, b: &Matrix) -> Result<()> {
    if a.cols() != b.rows() {
        return Err(Error::Shape(format!(
            "{op}: inner dimensions disagree: A is {}x{}, B is {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    Ok(())
}

/// Greedy bucket packing: take the largest full bucket that fits the
/// remainder; the final partial chunk uses the smallest covering bucket.
/// Keeps zero-padding waste on the tail only (e.g. 153 products over
/// buckets {16,64,256} → 64+64+16+16 with 4.6% padding, instead of one
/// padded 256-call with 67% padding).  Every chunk — including the
/// sub-smallest-bucket tail — respects `cfg.max_tile_batch`.
pub fn pack_chunks<'a>(
    bundle: &ArtifactBundle,
    cfg: &SpammConfig,
    products: &'a [ProductRef],
) -> Result<Vec<&'a [ProductRef]>> {
    let precision = cfg.precision.as_str();
    let buckets = bundle.tilegemm_buckets(cfg.lonum, precision);
    if buckets.is_empty() {
        return Err(Error::Artifact(format!(
            "no tilegemm artifacts for lonum {} precision {precision}",
            cfg.lonum
        )));
    }
    let cap_limit = cfg.max_tile_batch.clamp(1, *buckets.last().unwrap());
    let mut chunks = Vec::new();
    let mut rest = products;
    while !rest.is_empty() {
        let take = buckets
            .iter()
            .rev()
            .find(|&&b| b <= rest.len() && b <= cap_limit)
            .copied()
            // Below the smallest bucket: still clamp the tail to the
            // configured cap (the unclamped fallback was a bug — a tail
            // larger than max_tile_batch leaked through).
            .unwrap_or_else(|| rest.len().min(cap_limit))
            .min(rest.len());
        let (head, tail) = rest.split_at(take);
        chunks.push(head);
        rest = tail;
    }
    Ok(chunks)
}

/// Order a pipeline batch's products for residency: a stable sort by
/// A-tile coordinate packs the products that share an A-tile into the
/// same chunk, so the §3.3 A-block is staged/uploaded once per chunk
/// instead of once per product.
///
/// Bitwise-safe: every product belongs to exactly one output tile, and
/// for a fixed output tile (i, j) the products' A-tiles are (i, k) with
/// strictly increasing k — a stable sort keyed on the A coordinate
/// preserves each output tile's accumulation order exactly, so the f32
/// sums are unchanged down to the last bit.
fn order_for_residency(products: &mut [ProductRef]) {
    products.sort_by_key(|p| p.a);
}

/// Where executed tile products land.  The single-device engine scatters
/// into the padded output matrix; the coordinator's per-device workers
/// accumulate into their owned-tile map.
pub trait ScatterSink: Send {
    fn scatter(&mut self, c_ids: &[(usize, usize)], products: &[f32]) -> Result<()>;
}

impl ScatterSink for PaddedMatrix {
    fn scatter(&mut self, c_ids: &[(usize, usize)], products: &[f32]) -> Result<()> {
        scatter_accumulate(self, c_ids, products)
    }
}

/// Per-tile accumulator for coordinator device workers: only owned output
/// tiles are accepted.
pub struct TileAccumulator {
    lonum: usize,
    acc: std::collections::BTreeMap<(usize, usize), Vec<f32>>,
}

impl TileAccumulator {
    pub fn new(lonum: usize, owned: impl IntoIterator<Item = (usize, usize)>) -> TileAccumulator {
        let l2 = lonum * lonum;
        TileAccumulator {
            lonum,
            acc: owned.into_iter().map(|t| (t, vec![0.0f32; l2])).collect(),
        }
    }

    /// Consume the accumulator into (tile coords, data) pairs.
    pub fn into_tiles(self) -> Vec<((usize, usize), Vec<f32>)> {
        self.acc.into_iter().collect()
    }
}

impl ScatterSink for TileAccumulator {
    fn scatter(&mut self, c_ids: &[(usize, usize)], products: &[f32]) -> Result<()> {
        let l2 = self.lonum * self.lonum;
        for (slot, c) in c_ids.iter().enumerate() {
            let dst = self.acc.get_mut(c).ok_or_else(|| {
                Error::Coordinator(format!("product for unowned tile {c:?}"))
            })?;
            for (d, s) in dst.iter_mut().zip(&products[slot * l2..(slot + 1) * l2]) {
                *d += s;
            }
        }
        Ok(())
    }
}

/// Transfer-stage counters accumulated by the gather worker and folded
/// into [`MultiplyStats`] after the pipeline joins.
#[derive(Default)]
struct TransferCounters {
    secs: f64,
    hits: usize,
    misses: usize,
    evictions: usize,
    uploaded_bytes: u64,
    saved_bytes: u64,
    /// Misses on device-produced (resident-source) tiles: host bounces.
    cross_bytes: u64,
}

impl TransferCounters {
    fn fold_into(&self, stats: &mut MultiplyStats) {
        stats.gather_secs += self.secs;
        stats.residency_hits += self.hits;
        stats.residency_misses += self.misses;
        stats.residency_evictions += self.evictions;
        stats.transfer_bytes += self.uploaded_bytes;
        stats.transfer_saved_bytes += self.saved_bytes;
        stats.cross_device_bytes += self.cross_bytes;
    }
}

/// One operand's staging for a chunk: the *unique* tiles (as device
/// handles) plus a per-product slot map into them.  A tile referenced by
/// k products appears once in `tiles` and k times in `slots`.
struct StagedOperand {
    tiles: Vec<TileHandle>,
    slots: Vec<u32>,
}

/// Resolve a chunk's tile ids into deduplicated pool handles: a tile
/// referenced k times stages once, tiles already resident cost a refcount
/// bump, and only pool misses upload.  For a [`TileSource::Resident`]
/// operand on a single device every acquire is a hit by construction
/// (the holder's handles pin the tiles), so intermediates gather with
/// zero transfer bytes; on multi-device runs (`cross` true) a miss on a
/// resident-source tile is a cross-device host bounce.
fn stage_operand(
    pool: &ResidencyPool,
    fp: Fingerprint,
    src: TileSource<'_>,
    ids: &[(usize, usize)],
    cross: bool,
    ctr: &mut TransferCounters,
) -> Result<StagedOperand> {
    let l2 = src.lonum() * src.lonum();
    let tile_bytes = (l2 * std::mem::size_of::<f32>()) as u64;
    let mut index: HashMap<(usize, usize), u32> = HashMap::with_capacity(ids.len());
    let mut tiles: Vec<TileHandle> = Vec::new();
    let mut slots: Vec<u32> = Vec::with_capacity(ids.len());
    for &(ti, tj) in ids {
        if ti >= src.tile_rows() || tj >= src.tile_cols() {
            return Err(Error::Shape(format!(
                "gather: tile ({ti},{tj}) out of {}x{} grid",
                src.tile_rows(),
                src.tile_cols()
            )));
        }
        if let Some(&slot) = index.get(&(ti, tj)) {
            // Within-chunk dedup: the tile is already staged for this
            // chunk — no second copy, no second upload.
            ctr.saved_bytes += tile_bytes;
            slots.push(slot);
            continue;
        }
        let got = pool.acquire(TileKey::new(fp, (ti, tj)), l2, |dst| {
            src.copy_tile(ti, tj, dst)
        });
        if got.hit {
            ctr.hits += 1;
            ctr.saved_bytes += tile_bytes;
        } else {
            ctr.misses += 1;
            ctr.uploaded_bytes += tile_bytes;
            if cross && matches!(src, TileSource::Resident(_)) {
                // The tile was produced on *some* device but is not
                // resident here: it bounces through the host mirror —
                // the multi-device expression path's cross-device
                // traffic.
                ctr.cross_bytes += tile_bytes;
            }
        }
        ctr.evictions += got.evicted;
        let slot = tiles.len() as u32;
        tiles.push(got.handle);
        index.insert((ti, tj), slot);
        slots.push(slot);
    }
    Ok(StagedOperand { tiles, slots })
}

/// Raw gather of a tile source into a `(cap, L, L)` batch buffer — the
/// `--no-residency` path.  Host sources go through
/// [`gather_tiles`] byte-for-byte; resident sources copy from the held
/// device handles with the same layout and bounds checks.
fn gather_source(
    src: TileSource<'_>,
    ids: &[(usize, usize)],
    cap: usize,
    out: &mut Vec<f32>,
) -> Result<()> {
    if let TileSource::Host(p) = src {
        return gather_tiles(p, ids, cap, out);
    }
    if ids.len() > cap {
        return Err(Error::Shape(format!(
            "gather: {} tiles > batch cap {cap}",
            ids.len()
        )));
    }
    let l2 = src.lonum() * src.lonum();
    out.clear();
    out.resize(cap * l2, 0.0);
    for (slot, &(ti, tj)) in ids.iter().enumerate() {
        if ti >= src.tile_rows() || tj >= src.tile_cols() {
            return Err(Error::Shape(format!(
                "gather: tile ({ti},{tj}) out of {}x{} grid",
                src.tile_rows(),
                src.tile_cols()
            )));
        }
        src.copy_tile(ti, tj, &mut out[slot * l2..(slot + 1) * l2]);
    }
    Ok(())
}

/// Assemble the contiguous `(cap, L, L)` batch buffer the tile-GEMM
/// artifacts expect from a staged operand's handles — the device-side
/// pack (resident tiles → batch buffer; no host transfer).
fn pack_staged(staged: &StagedOperand, cap: usize, l2: usize, buf: &mut Vec<f32>) {
    buf.clear();
    buf.resize(cap * l2, 0.0);
    for (slot, &t) in staged.slots.iter().enumerate() {
        buf[slot * l2..(slot + 1) * l2].copy_from_slice(&staged.tiles[t as usize].data);
    }
}

/// One gathered chunk traveling from the transfer worker to the exec
/// stage.
enum GatheredChunk {
    /// Handle-based staging (residency pool active): deduplicated
    /// operand-tile handles plus the per-product slot maps.
    Resident {
        cap: usize,
        a: StagedOperand,
        b: StagedOperand,
        c_ids: Vec<(usize, usize)>,
    },
    /// Raw per-slot copies straight into (recycled) batch buffers — the
    /// `--no-residency` path, byte-for-byte the pre-residency gather.
    Raw {
        cap: usize,
        a_buf: Vec<f32>,
        b_buf: Vec<f32>,
        c_ids: Vec<(usize, usize)>,
    },
}

/// Execute the surviving products of a sequence of pipeline batches in
/// batched tile-GEMM calls, scatter-accumulating into `sink`.  Shared by
/// the single-device engine (one batch of all tiles) and the per-device
/// workers of the coordinator (the paper's P batches).
///
/// Stage-pipelined (§3.4) across *all* batches: a transfer worker
/// resolves chunk *i+1*'s tile handles (uploading residency misses into
/// `pool`) while this thread (which owns the non-`Send` PJRT runtime)
/// executes chunk *i*, and a scatter worker drains finished products.
/// Chunks stream across batch boundaries — batch *i+1*'s uploads overlap
/// batch *i*'s tile-GEMM instead of joining at a per-batch stream sync.
/// `cfg.pipeline_depth` bounds the in-flight chunks per channel.  Returns
/// the executed product count.
#[allow(clippy::too_many_arguments)]
pub fn execute_batches<S: ScatterSink>(
    rt: &Runtime,
    cfg: &SpammConfig,
    pool: Option<&ResidencyPool>,
    pa: Operand<'_>,
    pb: Operand<'_>,
    sink: &mut S,
    sched: &Schedule,
    batches: &[&[(usize, usize)]],
    stats: &mut MultiplyStats,
) -> Result<usize> {
    let residency = pool.is_some() && pa.fp.is_some() && pb.fp.is_some();
    let pool = if residency { pool } else { None };
    // Split every batch by tile strategy: dense products flow through the
    // unchanged tile-GEMM pipeline below (bitwise identical to the
    // all-dense executor), sparse/packed products are pulled out into
    // per-output-tile groups for the COO sptile path.  A group is a
    // maximal run of non-dense products of one output tile — the
    // schedule's `Packed` runs arrive consecutive by construction, so a
    // group maps to one fused dispatch.
    let mut batch_products: Vec<Vec<ProductRef>> = Vec::with_capacity(batches.len());
    let mut sparse_groups: Vec<((usize, usize), Vec<ProductRef>)> = Vec::new();
    let (mut n_dense, mut n_sparse, mut n_packed) = (0usize, 0usize, 0usize);
    for tiles in batches {
        let mut dense: Vec<ProductRef> = Vec::new();
        let mut run: Vec<ProductRef> = Vec::new();
        for p in sched.products_for_tiles(tiles.iter().copied()) {
            match p.strategy {
                TileStrategy::Dense => {
                    n_dense += 1;
                    if !run.is_empty() {
                        sparse_groups.push((run[0].c, std::mem::take(&mut run)));
                    }
                    dense.push(p);
                }
                TileStrategy::Sparse | TileStrategy::Packed => {
                    if p.strategy == TileStrategy::Sparse {
                        n_sparse += 1;
                    } else {
                        n_packed += 1;
                    }
                    if run.last().is_some_and(|last| last.c != p.c) {
                        sparse_groups.push((run[0].c, std::mem::take(&mut run)));
                    }
                    run.push(p);
                }
            }
        }
        if !run.is_empty() {
            sparse_groups.push((run[0].c, std::mem::take(&mut run)));
        }
        if residency {
            order_for_residency(&mut dense);
        }
        batch_products.push(dense);
    }
    stats.dense_products += n_dense;
    stats.sparse_products += n_sparse;
    stats.packed_products += n_packed;
    if n_sparse + n_packed > 0 {
        telemetry::global().add("spamm.format.sparse_products", n_sparse as u64);
        telemetry::global().add("spamm.format.packed_products", n_packed as u64);
    }
    telemetry::global().add("spamm.format.dense_products", n_dense as u64);
    let executed: usize = batch_products.iter().map(|b| b.len()).sum::<usize>()
        + sparse_groups.iter().map(|(_, g)| g.len()).sum::<usize>();
    stats.pipeline_depth = cfg.pipeline_depth.max(1);
    if executed == 0 {
        // Zero surviving products (huge τ): the output is exactly the
        // sink's current contents — no kernel launches at all.
        return Ok(0);
    }
    if !sparse_groups.is_empty() {
        execute_sparse_groups(rt, cfg, pool, pa, pb, sink, &sparse_groups, stats)?;
        if batch_products.iter().all(|b| b.is_empty()) {
            return Ok(executed);
        }
    }
    let precision = cfg.precision.as_str();
    // Chunk every batch and resolve each chunk's compiled batch capacity
    // up front so the transfer worker never touches the artifact registry.
    let mut work: Vec<(&[ProductRef], usize)> = Vec::new();
    for products in &batch_products {
        for chunk in pack_chunks(rt.bundle(), cfg, products)? {
            let meta = rt.bundle().tilegemm(chunk.len(), cfg.lonum, precision)?;
            let cap = meta.param_usize("batch").unwrap_or(chunk.len());
            debug_assert!(cap >= chunk.len());
            work.push((chunk, cap));
        }
    }
    let depth = cfg.pipeline_depth.max(1);
    let l2 = cfg.lonum * cfg.lonum;
    let tile_bytes = (l2 * std::mem::size_of::<f32>()) as u64;
    // Cross-device accounting only makes sense with more than one
    // device; a single-device eviction re-stage is not a host bounce.
    let cross = cfg.devices > 1;

    // Stage one chunk: handle-based when the pool is active, raw copies
    // into `bufs` (reused across chunks) otherwise.
    let stage_chunk = |chunk: &[ProductRef],
                       cap: usize,
                       bufs: (Vec<f32>, Vec<f32>),
                       ctr: &mut TransferCounters|
     -> Result<GatheredChunk> {
        let c_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.c).collect();
        let a_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.a).collect();
        let b_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.b).collect();
        if let (Some(pool), Some(fpa), Some(fpb)) = (pool, pa.fp, pb.fp) {
            let a = stage_operand(pool, fpa, pa.src, &a_ids, cross, ctr)?;
            let b = stage_operand(pool, fpb, pb.src, &b_ids, cross, ctr)?;
            Ok(GatheredChunk::Resident { cap, a, b, c_ids })
        } else {
            let (mut a_buf, mut b_buf) = bufs;
            gather_source(pa.src, &a_ids, cap, &mut a_buf)?;
            gather_source(pb.src, &b_ids, cap, &mut b_buf)?;
            // Every *host-backed* slot is a fresh host→device copy on
            // this path; resident intermediates were produced on device
            // and move no bus bytes even without a pool.
            let host_ops = [&pa, &pb]
                .iter()
                .filter(|o| matches!(o.src, TileSource::Host(_)))
                .count() as u64;
            let moved = host_ops * chunk.len() as u64 * tile_bytes;
            ctr.uploaded_bytes += moved;
            telemetry::global().add("spamm.transfer.uploaded_bytes", moved);
            Ok(GatheredChunk::Raw {
                cap,
                a_buf,
                b_buf,
                c_ids,
            })
        }
    };

    // A single chunk has nothing to overlap with — run the stages
    // inline and skip the worker spawn/channel setup entirely.
    if work.len() == 1 {
        let span = Instant::now();
        let (chunk, cap) = work[0];
        let mut ctr = TransferCounters::default();
        let t = Instant::now();
        let staged = stage_chunk(chunk, cap, Default::default(), &mut ctr)?;
        ctr.secs = t.elapsed().as_secs_f64();
        ctr.fold_into(stats);
        let t = Instant::now();
        let mut a_scratch = Vec::new();
        let mut b_scratch = Vec::new();
        let (c_ids, out) = match staged {
            GatheredChunk::Resident { cap, a, b, c_ids } => {
                pack_staged(&a, cap, l2, &mut a_scratch);
                pack_staged(&b, cap, l2, &mut b_scratch);
                (c_ids, rt.tile_gemm(&a_scratch, &b_scratch, cap, cfg.lonum, precision)?)
            }
            GatheredChunk::Raw {
                cap,
                a_buf,
                b_buf,
                c_ids,
            } => (c_ids, rt.tile_gemm(&a_buf, &b_buf, cap, cfg.lonum, precision)?),
        };
        stats.exec_secs += t.elapsed().as_secs_f64();
        stats.batches += 1;
        let t = Instant::now();
        sink.scatter(&c_ids, &out)?;
        stats.scatter_secs += t.elapsed().as_secs_f64();
        stats.exec_span_secs += span.elapsed().as_secs_f64();
        return Ok(executed);
    }

    let span = Instant::now();
    let mut exec_secs = 0.0f64;
    let mut exec_batches = 0usize;
    let result = std::thread::scope(|scope| -> Result<()> {
        let (gather_tx, gather_rx) = mpsc::sync_channel::<GatheredChunk>(depth);
        let (scatter_tx, scatter_rx) =
            mpsc::sync_channel::<(Vec<(usize, usize)>, Vec<f32>)>(depth);
        // Exec returns spent raw-path buffers to the transfer worker so
        // the `--no-residency` hot loop reuses allocations.
        let (recycle_tx, recycle_rx) = mpsc::channel::<(Vec<f32>, Vec<f32>)>();

        // Stage 1: transfer worker — the device's transfer queue.  Streams
        // handle resolution (and residency-miss uploads) across every
        // chunk of every batch with no per-batch join.
        let work_feed = work;
        let stage_chunk = &stage_chunk;
        let gather_worker = scope.spawn(move || -> Result<TransferCounters> {
            let mut ctr = TransferCounters::default();
            for (chunk, cap) in work_feed {
                let bufs = recycle_rx.try_recv().unwrap_or_default();
                let t = Instant::now();
                let staged = stage_chunk(chunk, cap, bufs, &mut ctr)?;
                ctr.secs += t.elapsed().as_secs_f64();
                if gather_tx.send(staged).is_err() {
                    break; // exec stage bailed out; stop producing
                }
            }
            Ok(ctr)
        });

        // Stage 3: scatter worker (owns the sink for the span).
        let scatter_worker = scope.spawn(move || -> Result<f64> {
            let mut secs = 0.0f64;
            for (c_ids, out) in scatter_rx {
                let t = Instant::now();
                sink.scatter(&c_ids, &out)?;
                secs += t.elapsed().as_secs_f64();
            }
            Ok(secs)
        });

        // Stage 2: tile-GEMM execution on this thread (the PJRT client is
        // not Send; it never crosses threads).  The scratch pack buffers
        // live here and are reused across chunks.
        let mut exec_err: Option<Error> = None;
        let mut a_scratch: Vec<f32> = Vec::new();
        let mut b_scratch: Vec<f32> = Vec::new();
        for staged in gather_rx {
            let t = Instant::now();
            let (c_ids, gemm) = match staged {
                GatheredChunk::Resident { cap, a, b, c_ids } => {
                    pack_staged(&a, cap, l2, &mut a_scratch);
                    pack_staged(&b, cap, l2, &mut b_scratch);
                    // Handles drop here: the tiles stay resident in the
                    // pool but become evictable once no in-flight chunk
                    // pins them.
                    drop((a, b));
                    (
                        c_ids,
                        rt.tile_gemm(&a_scratch, &b_scratch, cap, cfg.lonum, precision),
                    )
                }
                GatheredChunk::Raw {
                    cap,
                    a_buf,
                    b_buf,
                    c_ids,
                } => {
                    let gemm = rt.tile_gemm(&a_buf, &b_buf, cap, cfg.lonum, precision);
                    // Hand the buffers back for reuse (gather may already
                    // be gone; that's fine).
                    let _ = recycle_tx.send((a_buf, b_buf));
                    (c_ids, gemm)
                }
            };
            match gemm {
                Ok(out) => {
                    exec_secs += t.elapsed().as_secs_f64();
                    exec_batches += 1;
                    if scatter_tx.send((c_ids, out)).is_err() {
                        exec_err =
                            Some(Error::Coordinator("scatter stage terminated early".into()));
                        break;
                    }
                }
                Err(e) => {
                    exec_err = Some(e);
                    break;
                }
            }
        }
        drop(scatter_tx);

        let gather_res = gather_worker
            .join()
            .map_err(|_| Error::Coordinator("transfer worker panicked".into()))?;
        let scatter_res = scatter_worker
            .join()
            .map_err(|_| Error::Coordinator("scatter worker panicked".into()))?;
        // Report errors in pipeline order; a genuine scatter error beats
        // the synthetic channel-closed error it caused upstream.
        match gather_res {
            Ok(ctr) => ctr.fold_into(stats),
            Err(e) => return Err(e),
        }
        match scatter_res {
            Ok(secs) => stats.scatter_secs += secs,
            Err(e) => return Err(e),
        }
        if let Some(e) = exec_err {
            return Err(e);
        }
        Ok(())
    });
    stats.exec_secs += exec_secs;
    stats.batches += exec_batches;
    stats.exec_span_secs += span.elapsed().as_secs_f64();
    result?;
    Ok(executed)
}

/// Stage one operand tile in packed COO layout (`[nnz, idx, val, …]`,
/// packed at floor 0.0 so the payload is exact).  With a pool the payload
/// is content-addressed under [`TileKey::packed`] — hits skip the
/// pack+upload entirely; misses upload only the *actual* payload bytes
/// and credit the dense-vs-packed difference to `fmt_saved`.
fn stage_packed_tile(
    pool: Option<&ResidencyPool>,
    fp: Option<Fingerprint>,
    src: TileSource<'_>,
    (ti, tj): (usize, usize),
    l: usize,
    ctr: &mut TransferCounters,
    fmt_saved: &mut u64,
) -> Result<TileHandle> {
    if ti >= src.tile_rows() || tj >= src.tile_cols() {
        return Err(Error::Shape(format!(
            "sparse gather: tile ({ti},{tj}) out of {}x{} grid",
            src.tile_rows(),
            src.tile_cols()
        )));
    }
    let dense_bytes = (l * l * std::mem::size_of::<f32>()) as u64;
    let build = || {
        let mut buf = vec![0.0f32; l * l];
        src.copy_tile(ti, tj, &mut buf);
        pack_tile(&buf, l, 0.0)
    };
    match (pool, fp) {
        (Some(pool), Some(fp)) => {
            let got = pool.acquire_with(TileKey::packed(fp, (ti, tj)), build);
            let bytes = (got.handle.data.len() * std::mem::size_of::<f32>()) as u64;
            if got.hit {
                ctr.hits += 1;
                ctr.saved_bytes += bytes;
            } else {
                ctr.misses += 1;
                ctr.uploaded_bytes += bytes;
                *fmt_saved += dense_bytes.saturating_sub(bytes);
            }
            ctr.evictions += got.evicted;
            Ok(got.handle)
        }
        _ => {
            let data = build();
            let bytes = (data.len() * std::mem::size_of::<f32>()) as u64;
            ctr.uploaded_bytes += bytes;
            *fmt_saved += dense_bytes.saturating_sub(bytes);
            telemetry::global().add("spamm.transfer.uploaded_bytes", bytes);
            Ok(Arc::new(DeviceTile { data }))
        }
    }
}

/// Execute the sparse/packed product groups of a multiply: each group —
/// ≥1 consecutive below-threshold products of one output tile — becomes
/// one fused `sptile` dispatch over COO-packed operands, block-
/// concatenated along the contraction axis (C[i,j] += [A_ik1…A_ikn] ·
/// [B_k1j; …; B_knj]).  Groups wider than the largest compiled run
/// bucket split; when the bundle carries no sptile artifacts at all
/// (external artifact dirs) the host CSR SpGEMM computes the same
/// contraction per product — `sparse::spgemm` as the sparse kernel.
#[allow(clippy::too_many_arguments)]
fn execute_sparse_groups<S: ScatterSink>(
    rt: &Runtime,
    cfg: &SpammConfig,
    pool: Option<&ResidencyPool>,
    pa: Operand<'_>,
    pb: Operand<'_>,
    sink: &mut S,
    groups: &[((usize, usize), Vec<ProductRef>)],
    stats: &mut MultiplyStats,
) -> Result<()> {
    let l = cfg.lonum;
    let l2 = l * l;
    let runs = rt.bundle().sptile_runs(l);
    let max_run = runs.last().copied().unwrap_or(0);
    let mut ctr = TransferCounters::default();
    let mut fmt_saved = 0u64;
    let mut dispatches = 0u64;
    let span = Instant::now();
    for (c, members) in groups {
        for chunk in members.chunks(if max_run == 0 { members.len() } else { max_run }) {
            // Gather: stage both operands of every member in packed form.
            let t = Instant::now();
            let mut staged: Vec<(TileHandle, TileHandle)> = Vec::with_capacity(chunk.len());
            for p in chunk {
                let a = stage_packed_tile(pool, pa.fp, pa.src, p.a, l, &mut ctr, &mut fmt_saved)?;
                let b = stage_packed_tile(pool, pb.fp, pb.src, p.b, l, &mut ctr, &mut fmt_saved)?;
                staged.push((a, b));
            }
            ctr.secs += t.elapsed().as_secs_f64();
            let t = Instant::now();
            let out = if max_run == 0 {
                // Host fallback: per-member CSR SpGEMM, accumulated.
                let mut acc = vec![0.0f32; l2];
                for (a, b) in &staged {
                    let ac = packed_to_coo(&a.data, l, l)?.to_csr();
                    let bc = packed_to_coo(&b.data, l, l)?.to_csr();
                    let prod = spgemm(&ac, &bc)?;
                    for r in 0..l {
                        for i in prod.indptr[r]..prod.indptr[r + 1] {
                            acc[r * l + prod.indices[i]] += prod.values[i];
                        }
                    }
                }
                acc
            } else {
                // Fused dispatch: re-index each member's entries into the
                // block-concatenated l×(run·l) / (run·l)×l coordinates.
                let run = runs
                    .iter()
                    .find(|&&r| r >= chunk.len())
                    .copied()
                    .unwrap_or(max_run);
                let kw = run * l;
                let (mut a_idx, mut a_vals) = (Vec::new(), Vec::new());
                let (mut b_idx, mut b_vals) = (Vec::new(), Vec::new());
                for (m, (a, b)) in staged.iter().enumerate() {
                    for e in 0..crate::sparse::packed_nnz(&a.data) {
                        let idx = a.data[1 + 2 * e] as usize;
                        let (r, k) = (idx / l, idx % l);
                        a_idx.push((r * kw + m * l + k) as f32);
                        a_vals.push(a.data[2 + 2 * e]);
                    }
                    for e in 0..crate::sparse::packed_nnz(&b.data) {
                        let idx = b.data[1 + 2 * e] as usize;
                        let (k, col) = (idx / l, idx % l);
                        b_idx.push(((m * l + k) * l + col) as f32);
                        b_vals.push(b.data[2 + 2 * e]);
                    }
                }
                rt.sptile(&a_idx, &a_vals, &b_idx, &b_vals, run, l)?
            };
            stats.exec_secs += t.elapsed().as_secs_f64();
            stats.sparse_dispatches += 1;
            dispatches += 1;
            let t = Instant::now();
            sink.scatter(&[*c], &out)?;
            stats.scatter_secs += t.elapsed().as_secs_f64();
        }
    }
    stats.exec_span_secs += span.elapsed().as_secs_f64();
    ctr.fold_into(stats);
    stats.format_saved_bytes += fmt_saved;
    telemetry::global().add("spamm.format.saved_bytes", fmt_saved);
    telemetry::global().add("spamm.format.sparse_dispatches", dispatches);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tilegemm-only hostsim bundle with buckets {16, 64, 256} — written
    /// through `runtime::hostsim` so the manifest/op schema has a single
    /// owner, into a pid-suffixed dir so concurrent test runs can't race.
    fn bucket_bundle(tag: &str) -> ArtifactBundle {
        use crate::runtime::hostsim::{write_bundle, HostsimSpec};
        let dir = std::env::temp_dir().join(format!("{tag}_{}", std::process::id()));
        let spec = HostsimSpec {
            lonum: 32,
            dense_sizes: vec![],
            dense_rect: vec![],
            getnorm_sizes: vec![],
            tilegemm_batches: vec![16, 64, 256],
            axpby_batches: vec![],
            tune_bdims: vec![],
            fused_sizes: vec![],
            precisions: vec!["f32"],
            cnn: false,
        };
        write_bundle(&dir, &spec).unwrap();
        ArtifactBundle::load(&dir).unwrap()
    }

    fn product(i: usize) -> ProductRef {
        ProductRef {
            a: (0, i),
            b: (i, 0),
            c: (0, 0),
            strategy: TileStrategy::Dense,
        }
    }

    #[test]
    fn pack_chunks_empty_products() {
        let bundle = bucket_bundle("cuspamm_pack_empty");
        let cfg = SpammConfig::default();
        let chunks = pack_chunks(&bundle, &cfg, &[]).unwrap();
        assert!(chunks.is_empty());
    }

    #[test]
    fn pack_chunks_greedy_buckets() {
        let bundle = bucket_bundle("cuspamm_pack_greedy");
        let cfg = SpammConfig::default(); // max_tile_batch 1024 > largest
        let products: Vec<ProductRef> = (0..153).map(product).collect();
        let chunks = pack_chunks(&bundle, &cfg, &products).unwrap();
        let sizes: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![64, 64, 16, 9]);
        assert_eq!(sizes.iter().sum::<usize>(), 153);
    }

    #[test]
    fn pack_chunks_cap_smaller_than_smallest_bucket() {
        // Regression: the sub-smallest-bucket tail used to bypass
        // max_tile_batch via the unclamped fallback.
        let bundle = bucket_bundle("cuspamm_pack_cap");
        let mut cfg = SpammConfig::default();
        cfg.max_tile_batch = 10; // below the smallest bucket (16)
        let products: Vec<ProductRef> = (0..25).map(product).collect();
        let chunks = pack_chunks(&bundle, &cfg, &products).unwrap();
        assert!(
            chunks.iter().all(|c| c.len() <= 10),
            "chunk exceeded cap: {:?}",
            chunks.iter().map(|c| c.len()).collect::<Vec<_>>()
        );
        assert_eq!(chunks.iter().map(|c| c.len()).sum::<usize>(), 25);
    }

    #[test]
    fn pack_chunks_tail_below_smallest_bucket() {
        let bundle = bucket_bundle("cuspamm_pack_tail");
        let cfg = SpammConfig::default();
        let products: Vec<ProductRef> = (0..7).map(product).collect();
        let chunks = pack_chunks(&bundle, &cfg, &products).unwrap();
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].len(), 7);
    }

    #[test]
    fn pack_chunks_respects_cap_above_bucket() {
        let bundle = bucket_bundle("cuspamm_pack_mid");
        let mut cfg = SpammConfig::default();
        cfg.max_tile_batch = 64;
        let products: Vec<ProductRef> = (0..300).map(product).collect();
        let chunks = pack_chunks(&bundle, &cfg, &products).unwrap();
        assert!(chunks.iter().all(|c| c.len() <= 64));
        assert_eq!(chunks.iter().map(|c| c.len()).sum::<usize>(), 300);
    }

    #[test]
    fn tile_accumulator_rejects_unowned() {
        let mut acc = TileAccumulator::new(2, [(0usize, 0usize)]);
        let tile = vec![1.0f32; 4];
        acc.scatter(&[(0, 0)], &tile).unwrap();
        assert!(acc.scatter(&[(1, 1)], &tile).is_err());
        let tiles = acc.into_tiles();
        assert_eq!(tiles.len(), 1);
        assert_eq!(tiles[0].1, vec![1.0; 4]);
    }

    #[test]
    fn check_inner_dims_catches_padded_equal_grids() {
        // 17 and 20 both pad to one 32-tile: the tile grids agree, the
        // logical shapes do not.
        let a = Matrix::zeros(16, 17);
        let b = Matrix::zeros(20, 8);
        assert!(check_inner_dims("multiply", &a, &b).is_err());
        let ok = Matrix::zeros(17, 20);
        let b2 = Matrix::zeros(20, 8);
        assert!(check_inner_dims("multiply", &ok, &b2).is_ok());
    }

    #[test]
    fn residency_ordering_preserves_per_output_tile_k_order() {
        // Products of several output tiles in one row share A-tiles; the
        // residency sort must group them by A-tile while keeping every
        // output tile's k order ascending (the bitwise-identity invariant).
        let d = TileStrategy::Dense;
        let mut products = vec![
            ProductRef { a: (0, 0), b: (0, 0), c: (0, 0), strategy: d },
            ProductRef { a: (0, 1), b: (1, 0), c: (0, 0), strategy: d },
            ProductRef { a: (0, 0), b: (0, 1), c: (0, 1), strategy: d },
            ProductRef { a: (0, 1), b: (1, 1), c: (0, 1), strategy: d },
        ];
        order_for_residency(&mut products);
        // Grouped by A-tile: both (0,0)-A products first.
        assert_eq!(products[0].a, (0, 0));
        assert_eq!(products[1].a, (0, 0));
        assert_eq!(products[2].a, (0, 1));
        assert_eq!(products[3].a, (0, 1));
        // Per-output-tile k order unchanged (k=0 before k=1 for both).
        for c in [(0usize, 0usize), (0, 1)] {
            let ks: Vec<usize> = products
                .iter()
                .filter(|p| p.c == c)
                .map(|p| p.a.1)
                .collect();
            assert_eq!(ks, vec![0, 1]);
        }
    }

    #[test]
    fn stage_operand_dedupes_within_chunk() {
        let m = Matrix::randn(64, 64, 9);
        let p = PaddedMatrix::new(&m, 32);
        let fp = fingerprint(&p);
        let pool = ResidencyPool::new(0);
        let ids = [(0usize, 0usize), (0, 1), (0, 0), (0, 0), (1, 1)];
        let mut ctr = TransferCounters::default();
        let staged =
            stage_operand(&pool, fp, TileSource::Host(&p), &ids, false, &mut ctr).unwrap();
        assert_eq!(staged.tiles.len(), 3, "3 unique tiles");
        assert_eq!(staged.slots, vec![0, 1, 0, 0, 2]);
        let tile_bytes = (32 * 32 * 4) as u64;
        assert_eq!(ctr.misses, 3);
        assert_eq!(ctr.uploaded_bytes, 3 * tile_bytes);
        assert_eq!(ctr.saved_bytes, 2 * tile_bytes, "2 duplicate refs saved");
        // Packing replicates the deduped tile into every slot.
        let mut buf = Vec::new();
        pack_staged(&staged, 8, 32 * 32, &mut buf);
        assert_eq!(buf.len(), 8 * 32 * 32);
        assert_eq!(buf[..1024], buf[2 * 1024..3 * 1024]);
        assert_eq!(buf[..1024], buf[3 * 1024..4 * 1024]);
        assert!(buf[5 * 1024..].iter().all(|&x| x == 0.0), "padded tail zero");
    }

    #[test]
    fn stage_operand_pool_uploads_once_across_chunks() {
        let m = Matrix::randn(64, 64, 10);
        let p = PaddedMatrix::new(&m, 32);
        let fp = fingerprint(&p);
        let pool = ResidencyPool::new(0);
        let ids = [(0usize, 0usize), (0, 1)];
        let mut ctr = TransferCounters::default();
        stage_operand(&pool, fp, TileSource::Host(&p), &ids, false, &mut ctr).unwrap();
        assert_eq!(ctr.misses, 2);
        assert_eq!(ctr.hits, 0);
        // A second chunk touching the same tiles transfers nothing.
        let mut ctr2 = TransferCounters::default();
        stage_operand(&pool, fp, TileSource::Host(&p), &ids, false, &mut ctr2).unwrap();
        assert_eq!(ctr2.misses, 0);
        assert_eq!(ctr2.hits, 2);
        assert_eq!(ctr2.uploaded_bytes, 0);
    }

    #[test]
    fn stage_operand_bounds_checked() {
        let p = PaddedMatrix::new(&Matrix::zeros(32, 32), 32);
        let pool = ResidencyPool::new(0);
        let mut ctr = TransferCounters::default();
        let fp = fingerprint(&p);
        assert!(
            stage_operand(&pool, fp, TileSource::Host(&p), &[(1, 0)], false, &mut ctr).is_err()
        );
    }
}
