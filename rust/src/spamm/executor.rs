//! Single-device SpAMM executor: the paper's two-kernel pipeline driven
//! from Rust — get-norm (host or device), τ tuning, schedule compaction,
//! and batched tile-GEMM execution with genuine work skipping.

use std::time::Instant;

use crate::config::{Precision, SpammConfig};
use crate::error::Result;
use crate::matrix::tiling::{gather_tiles, scatter_accumulate, PaddedMatrix};
use crate::matrix::Matrix;
use crate::runtime::{ArtifactBundle, Runtime};
use crate::spamm::normmap::normmap;
use crate::spamm::schedule::{ProductRef, Schedule};
use crate::spamm::tuner::{self, TuneParams};

pub use crate::spamm::tuner::TuneResult;

/// Timing/counting breakdown of one multiply call.
#[derive(Clone, Debug, Default)]
pub struct MultiplyStats {
    pub valid_products: usize,
    pub total_products: usize,
    pub valid_ratio: f64,
    pub norm_secs: f64,
    pub schedule_secs: f64,
    pub gather_secs: f64,
    pub exec_secs: f64,
    pub scatter_secs: f64,
    pub total_secs: f64,
    pub batches: usize,
}

/// Single-device SpAMM engine.
pub struct SpammEngine {
    rt: Runtime,
    cfg: SpammConfig,
}

impl SpammEngine {
    pub fn new(bundle: &ArtifactBundle, cfg: SpammConfig) -> Result<SpammEngine> {
        cfg.validate()?;
        Ok(SpammEngine {
            rt: Runtime::new(bundle)?,
            cfg,
        })
    }

    pub fn config(&self) -> &SpammConfig {
        &self.cfg
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// normmap of a padded matrix — on-device (get-norm artifact) when
    /// configured and available, host otherwise.
    pub fn normmap_of(&self, p: &PaddedMatrix) -> Result<Matrix> {
        if self.cfg.device_normmap && p.inner.rows() == p.inner.cols() {
            let mxu = self.cfg.precision == Precision::Bf16;
            if self
                .rt
                .bundle()
                .getnorm(p.inner.rows(), self.cfg.lonum, mxu)
                .is_ok()
            {
                return self.rt.getnorm(&p.inner, self.cfg.lonum, mxu);
            }
            log::debug!(
                "no get-norm artifact for n={}, falling back to host",
                p.inner.rows()
            );
        }
        Ok(normmap(p))
    }

    /// Tune τ for a target valid ratio (§3.5.2; host twin of tune.py).
    pub fn tune_tau(&self, a: &Matrix, b: &Matrix, target: f64) -> Result<TuneResult> {
        let pa = PaddedMatrix::new(a, self.cfg.lonum);
        let pb = PaddedMatrix::new(b, self.cfg.lonum);
        let na = self.normmap_of(&pa)?;
        let nb = self.normmap_of(&pb)?;
        tuner::tune_tau(&na, &nb, target, TuneParams::default())
    }

    /// SpAMM multiply: C ≈ A·B skipping tile products under τ.
    pub fn multiply(&self, a: &Matrix, b: &Matrix, tau: f32) -> Result<Matrix> {
        Ok(self.multiply_with_stats(a, b, tau)?.0)
    }

    /// Multiply with a full stats breakdown.
    pub fn multiply_with_stats(
        &self,
        a: &Matrix,
        b: &Matrix,
        tau: f32,
    ) -> Result<(Matrix, MultiplyStats)> {
        let t_total = Instant::now();
        let mut stats = MultiplyStats::default();

        let pa = PaddedMatrix::new(a, self.cfg.lonum);
        let pb = PaddedMatrix::new(b, self.cfg.lonum);

        let t = Instant::now();
        let na = self.normmap_of(&pa)?;
        let nb = self.normmap_of(&pb)?;
        stats.norm_secs = t.elapsed().as_secs_f64();

        let t = Instant::now();
        let sched = Schedule::build(&na, &nb, tau)?;
        stats.schedule_secs = t.elapsed().as_secs_f64();
        stats.valid_products = sched.valid_products();
        stats.total_products = sched.total_products();
        stats.valid_ratio = sched.valid_ratio();

        let mut pc = PaddedMatrix::new(&Matrix::zeros(a.rows(), b.cols()), self.cfg.lonum);
        let all_tiles: Vec<(usize, usize)> = (0..sched.tile_rows)
            .flat_map(|i| (0..sched.tile_cols).map(move |j| (i, j)))
            .collect();
        execute_products(
            &self.rt,
            &self.cfg,
            &pa,
            &pb,
            &mut pc,
            &sched,
            &all_tiles,
            &mut stats,
        )?;

        stats.total_secs = t_total.elapsed().as_secs_f64();
        Ok((pc.crop(), stats))
    }

    /// Dense baseline (cuBLAS stand-in) on the same runtime.
    pub fn dense(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        self.rt.dense(a, b, self.cfg.precision.as_str())
    }

    /// The paper's general form (§2.1): C ← α·SpAMM(A, B, τ) + β·C.
    pub fn multiply_axpby(
        &self,
        alpha: f32,
        a: &Matrix,
        b: &Matrix,
        tau: f32,
        beta: f32,
        c: &Matrix,
    ) -> Result<Matrix> {
        if c.rows() != a.rows() || c.cols() != b.cols() {
            return Err(crate::error::Error::Shape(format!(
                "axpby: C is {}x{}, want {}x{}",
                c.rows(),
                c.cols(),
                a.rows(),
                b.cols()
            )));
        }
        let mut prod = self.multiply(a, b, tau)?;
        for (p, &cv) in prod.data_mut().iter_mut().zip(c.data()) {
            *p = alpha * *p + beta * cv;
        }
        Ok(prod)
    }

    /// Fused single-call SpAMM (on-device normmaps + masked multiply) —
    /// the numerics oracle path; requires a `spamm_fused_n{N}` artifact.
    pub fn multiply_fused(&self, a: &Matrix, b: &Matrix, tau: f32) -> Result<Matrix> {
        self.rt
            .spamm_fused(a, b, tau, self.cfg.precision.as_str())
    }
}

/// Greedy bucket packing: take the largest full bucket that fits the
/// remainder; the final partial chunk uses the smallest covering bucket.
/// Keeps zero-padding waste on the tail only (e.g. 153 products over
/// buckets {16,64,256} → 64+64+16+16 with 4.6% padding, instead of one
/// padded 256-call with 67% padding).
pub fn pack_chunks<'a>(
    bundle: &crate::runtime::ArtifactBundle,
    cfg: &SpammConfig,
    products: &'a [ProductRef],
) -> Result<Vec<&'a [ProductRef]>> {
    let precision = cfg.precision.as_str();
    let buckets = bundle.tilegemm_buckets(cfg.lonum, precision);
    if buckets.is_empty() {
        return Err(crate::error::Error::Artifact(format!(
            "no tilegemm artifacts for lonum {} precision {precision}",
            cfg.lonum
        )));
    }
    let cap_limit = cfg.max_tile_batch.clamp(1, *buckets.last().unwrap());
    let mut chunks = Vec::new();
    let mut rest = products;
    while !rest.is_empty() {
        let take = buckets
            .iter()
            .rev()
            .find(|&&b| b <= rest.len() && b <= cap_limit)
            .copied()
            .unwrap_or(rest.len()) // below the smallest bucket
            .min(rest.len());
        let (head, tail) = rest.split_at(take);
        chunks.push(head);
        rest = tail;
    }
    Ok(chunks)
}

/// Execute the surviving products of `tiles` in batched tile-GEMM calls,
/// scatter-accumulating into `pc`.  Shared by the single-device engine and
/// the per-device workers of the coordinator.
#[allow(clippy::too_many_arguments)]
pub fn execute_products(
    rt: &Runtime,
    cfg: &SpammConfig,
    pa: &PaddedMatrix,
    pb: &PaddedMatrix,
    pc: &mut PaddedMatrix,
    sched: &Schedule,
    tiles: &[(usize, usize)],
    stats: &mut MultiplyStats,
) -> Result<()> {
    let products: Vec<ProductRef> = sched
        .products_for_tiles(tiles.iter().copied())
        .collect();
    let precision = cfg.precision.as_str();
    let chunks = pack_chunks(rt.bundle(), cfg, &products)?;
    let mut a_buf = Vec::new();
    let mut b_buf = Vec::new();
    for chunk in chunks {
        // Pick the smallest compiled batch bucket that fits this chunk.
        let meta = rt.bundle().tilegemm(chunk.len(), cfg.lonum, precision)?;
        let cap = meta.param_usize("batch").unwrap_or(chunk.len());
        debug_assert!(cap >= chunk.len());

        let t = Instant::now();
        let a_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.a).collect();
        let b_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.b).collect();
        gather_tiles(pa, &a_ids, cap, &mut a_buf)?;
        gather_tiles(pb, &b_ids, cap, &mut b_buf)?;
        stats.gather_secs += t.elapsed().as_secs_f64();

        let t = Instant::now();
        let out = rt.tile_gemm(&a_buf, &b_buf, cap, cfg.lonum, precision)?;
        stats.exec_secs += t.elapsed().as_secs_f64();

        let t = Instant::now();
        let c_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.c).collect();
        scatter_accumulate(pc, &c_ids, &out)?;
        stats.scatter_secs += t.elapsed().as_secs_f64();
        stats.batches += 1;
    }
    Ok(())
}
