//! Single-device SpAMM executor: the paper's two-kernel pipeline driven
//! from Rust — get-norm (host or device), τ tuning, schedule compaction,
//! and *stage-pipelined* batched tile-GEMM execution with genuine work
//! skipping.
//!
//! Two levels of reuse/overlap (§3.3 blocking, §3.4 pipeline):
//!
//! * **Caching** — normmaps and compacted schedules are memoized in
//!   [`ExecCaches`] keyed on operand content fingerprints + τ, so
//!   `power`/`purification` loops and repeated service requests skip the
//!   get-norm and schedule phases entirely on hits.
//! * **Pipelining** — [`execute_products`] double-buffers chunk
//!   execution: a gather worker stages chunk *i+1* while this thread runs
//!   tile-GEMM on chunk *i*, and a scatter worker drains finished
//!   products from a channel.  With overlap, the per-stage second sums in
//!   [`MultiplyStats`] exceed the `exec_span_secs` wall clock.

use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::config::{Precision, SpammConfig};
use crate::error::{Error, Result};
use crate::matrix::tiling::{gather_tiles, scatter_accumulate, PaddedMatrix};
use crate::matrix::Matrix;
use crate::runtime::{ArtifactBundle, Runtime};
use crate::spamm::cache::{ExecCaches, Fingerprint};
use crate::spamm::normmap::normmap;
use crate::spamm::schedule::{ProductRef, Schedule};
use crate::spamm::tuner::{self, TuneParams};

pub use crate::spamm::tuner::TuneResult;

/// Timing/counting breakdown of one multiply call.
#[derive(Clone, Debug, Default)]
pub struct MultiplyStats {
    pub valid_products: usize,
    pub total_products: usize,
    pub valid_ratio: f64,
    pub norm_secs: f64,
    pub schedule_secs: f64,
    /// Seconds inside the gather stage (overlaps exec when pipelined).
    pub gather_secs: f64,
    /// Seconds inside tile-GEMM execution.
    pub exec_secs: f64,
    /// Seconds inside the scatter-accumulate stage (overlaps exec).
    pub scatter_secs: f64,
    /// Wall-clock span of the pipelined gather/exec/scatter loop.  With
    /// overlap, `gather_secs + exec_secs + scatter_secs > exec_span_secs`.
    pub exec_span_secs: f64,
    pub total_secs: f64,
    pub batches: usize,
    /// Pipeline depth (in-flight chunks) used by the executor.
    pub pipeline_depth: usize,
    /// Norm-cache hits/misses for this call's operands.
    pub norm_cache_hits: usize,
    pub norm_cache_misses: usize,
    /// Schedule-cache hits/misses for this call's (A, B, τ) key.
    pub schedule_cache_hits: usize,
    pub schedule_cache_misses: usize,
}

impl MultiplyStats {
    /// Fold another record's pipeline-stage measurements into this one —
    /// used to aggregate per-device worker stats into a multi-device
    /// report.  Cache and schedule-phase fields are left untouched (they
    /// belong to the front-end, not the device workers).
    pub fn absorb_stages(&mut self, other: &MultiplyStats) {
        self.gather_secs += other.gather_secs;
        self.exec_secs += other.exec_secs;
        self.scatter_secs += other.scatter_secs;
        self.exec_span_secs += other.exec_span_secs;
        self.batches += other.batches;
        self.pipeline_depth = self.pipeline_depth.max(other.pipeline_depth);
    }
}

/// Single-device SpAMM engine.
pub struct SpammEngine {
    rt: Runtime,
    cfg: SpammConfig,
    caches: ExecCaches,
}

impl SpammEngine {
    pub fn new(bundle: &ArtifactBundle, cfg: SpammConfig) -> Result<SpammEngine> {
        cfg.validate()?;
        Ok(SpammEngine {
            rt: Runtime::new(bundle)?,
            cfg,
            caches: ExecCaches::new(),
        })
    }

    pub fn config(&self) -> &SpammConfig {
        &self.cfg
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// The engine's norm/schedule caches (hit/miss inspection).
    pub fn caches(&self) -> &ExecCaches {
        &self.caches
    }

    /// normmap of a padded matrix — on-device (get-norm artifact) when
    /// configured and available, host otherwise.
    pub fn normmap_of(&self, p: &PaddedMatrix) -> Result<Matrix> {
        if self.cfg.device_normmap && p.inner.rows() == p.inner.cols() {
            let mxu = self.cfg.precision == Precision::Bf16;
            if self
                .rt
                .bundle()
                .getnorm(p.inner.rows(), self.cfg.lonum, mxu)
                .is_ok()
            {
                return self.rt.getnorm(&p.inner, self.cfg.lonum, mxu);
            }
            log::debug!(
                "no get-norm artifact for n={}, falling back to host",
                p.inner.rows()
            );
        }
        Ok(normmap(p))
    }

    /// Cached normmap: fingerprint the operand and consult the norm cache
    /// (bypassed entirely when `cache_enabled` is off).
    fn cached_normmap(
        &self,
        p: &PaddedMatrix,
        stats: &mut MultiplyStats,
    ) -> Result<(Arc<Matrix>, Option<Fingerprint>)> {
        self.caches
            .normmap_via(self.cfg.cache_enabled, p, stats, || self.normmap_of(p))
    }

    /// Tune τ for a target valid ratio (§3.5.2; host twin of tune.py).
    pub fn tune_tau(&self, a: &Matrix, b: &Matrix, target: f64) -> Result<TuneResult> {
        check_inner_dims("tune_tau", a, b)?;
        let pa = PaddedMatrix::new(a, self.cfg.lonum);
        let pb = PaddedMatrix::new(b, self.cfg.lonum);
        let mut scratch = MultiplyStats::default();
        let (na, _) = self.cached_normmap(&pa, &mut scratch)?;
        let (nb, _) = self.cached_normmap(&pb, &mut scratch)?;
        tuner::tune_tau(&na, &nb, target, TuneParams::default())
    }

    /// SpAMM multiply: C ≈ A·B skipping tile products under τ.
    pub fn multiply(&self, a: &Matrix, b: &Matrix, tau: f32) -> Result<Matrix> {
        Ok(self.multiply_with_stats(a, b, tau)?.0)
    }

    /// Multiply with a full stats breakdown.
    pub fn multiply_with_stats(
        &self,
        a: &Matrix,
        b: &Matrix,
        tau: f32,
    ) -> Result<(Matrix, MultiplyStats)> {
        check_inner_dims("multiply", a, b)?;
        let t_total = Instant::now();
        let mut stats = MultiplyStats::default();

        let pa = PaddedMatrix::new(a, self.cfg.lonum);
        let pb = PaddedMatrix::new(b, self.cfg.lonum);

        let t = Instant::now();
        let (na, fa) = self.cached_normmap(&pa, &mut stats)?;
        let (nb, fb) = self.cached_normmap(&pb, &mut stats)?;
        stats.norm_secs = t.elapsed().as_secs_f64();

        let t = Instant::now();
        let sched = self
            .caches
            .schedule_via(fa, fb, tau, &na, &nb, &mut stats)?;
        stats.schedule_secs = t.elapsed().as_secs_f64();
        stats.valid_products = sched.valid_products();
        stats.total_products = sched.total_products();
        stats.valid_ratio = sched.valid_ratio();

        let mut pc = PaddedMatrix::new(&Matrix::zeros(a.rows(), b.cols()), self.cfg.lonum);
        let all_tiles: Vec<(usize, usize)> = (0..sched.tile_rows)
            .flat_map(|i| (0..sched.tile_cols).map(move |j| (i, j)))
            .collect();
        execute_products(
            &self.rt,
            &self.cfg,
            &pa,
            &pb,
            &mut pc,
            &sched,
            &all_tiles,
            &mut stats,
        )?;

        stats.total_secs = t_total.elapsed().as_secs_f64();
        Ok((pc.crop(), stats))
    }

    /// Dense baseline (cuBLAS stand-in) on the same runtime.
    pub fn dense(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        check_inner_dims("dense", a, b)?;
        self.rt.dense(a, b, self.cfg.precision.as_str())
    }

    /// The paper's general form (§2.1): C ← α·SpAMM(A, B, τ) + β·C.
    pub fn multiply_axpby(
        &self,
        alpha: f32,
        a: &Matrix,
        b: &Matrix,
        tau: f32,
        beta: f32,
        c: &Matrix,
    ) -> Result<Matrix> {
        if c.rows() != a.rows() || c.cols() != b.cols() {
            return Err(Error::Shape(format!(
                "axpby: C is {}x{}, want {}x{}",
                c.rows(),
                c.cols(),
                a.rows(),
                b.cols()
            )));
        }
        let mut prod = self.multiply(a, b, tau)?;
        for (p, &cv) in prod.data_mut().iter_mut().zip(c.data()) {
            *p = alpha * *p + beta * cv;
        }
        Ok(prod)
    }

    /// Fused single-call SpAMM (on-device normmaps + masked multiply) —
    /// the numerics oracle path; requires a `spamm_fused_n{N}` artifact.
    pub fn multiply_fused(&self, a: &Matrix, b: &Matrix, tau: f32) -> Result<Matrix> {
        check_inner_dims("multiply_fused", a, b)?;
        self.rt
            .spamm_fused(a, b, tau, self.cfg.precision.as_str())
    }
}

/// Validate the inner dimensions of A·B.  Mismatches that pad to the same
/// tile count (e.g. 17 vs 20 at lonum 32) would otherwise silently produce
/// garbage — the schedule only sees tile grids.
pub fn check_inner_dims(op: &str, a: &Matrix, b: &Matrix) -> Result<()> {
    if a.cols() != b.rows() {
        return Err(Error::Shape(format!(
            "{op}: inner dimensions disagree: A is {}x{}, B is {}x{}",
            a.rows(),
            a.cols(),
            b.rows(),
            b.cols()
        )));
    }
    Ok(())
}

/// Greedy bucket packing: take the largest full bucket that fits the
/// remainder; the final partial chunk uses the smallest covering bucket.
/// Keeps zero-padding waste on the tail only (e.g. 153 products over
/// buckets {16,64,256} → 64+64+16+16 with 4.6% padding, instead of one
/// padded 256-call with 67% padding).  Every chunk — including the
/// sub-smallest-bucket tail — respects `cfg.max_tile_batch`.
pub fn pack_chunks<'a>(
    bundle: &ArtifactBundle,
    cfg: &SpammConfig,
    products: &'a [ProductRef],
) -> Result<Vec<&'a [ProductRef]>> {
    let precision = cfg.precision.as_str();
    let buckets = bundle.tilegemm_buckets(cfg.lonum, precision);
    if buckets.is_empty() {
        return Err(Error::Artifact(format!(
            "no tilegemm artifacts for lonum {} precision {precision}",
            cfg.lonum
        )));
    }
    let cap_limit = cfg.max_tile_batch.clamp(1, *buckets.last().unwrap());
    let mut chunks = Vec::new();
    let mut rest = products;
    while !rest.is_empty() {
        let take = buckets
            .iter()
            .rev()
            .find(|&&b| b <= rest.len() && b <= cap_limit)
            .copied()
            // Below the smallest bucket: still clamp the tail to the
            // configured cap (the unclamped fallback was a bug — a tail
            // larger than max_tile_batch leaked through).
            .unwrap_or_else(|| rest.len().min(cap_limit))
            .min(rest.len());
        let (head, tail) = rest.split_at(take);
        chunks.push(head);
        rest = tail;
    }
    Ok(chunks)
}

/// Where executed tile products land.  The single-device engine scatters
/// into the padded output matrix; the coordinator's per-device workers
/// accumulate into their owned-tile map.
pub trait ScatterSink: Send {
    fn scatter(&mut self, c_ids: &[(usize, usize)], products: &[f32]) -> Result<()>;
}

impl ScatterSink for PaddedMatrix {
    fn scatter(&mut self, c_ids: &[(usize, usize)], products: &[f32]) -> Result<()> {
        scatter_accumulate(self, c_ids, products)
    }
}

/// Per-tile accumulator for coordinator device workers: only owned output
/// tiles are accepted.
pub struct TileAccumulator {
    lonum: usize,
    acc: std::collections::BTreeMap<(usize, usize), Vec<f32>>,
}

impl TileAccumulator {
    pub fn new(lonum: usize, owned: impl IntoIterator<Item = (usize, usize)>) -> TileAccumulator {
        let l2 = lonum * lonum;
        TileAccumulator {
            lonum,
            acc: owned.into_iter().map(|t| (t, vec![0.0f32; l2])).collect(),
        }
    }

    /// Consume the accumulator into (tile coords, data) pairs.
    pub fn into_tiles(self) -> Vec<((usize, usize), Vec<f32>)> {
        self.acc.into_iter().collect()
    }
}

impl ScatterSink for TileAccumulator {
    fn scatter(&mut self, c_ids: &[(usize, usize)], products: &[f32]) -> Result<()> {
        let l2 = self.lonum * self.lonum;
        for (slot, c) in c_ids.iter().enumerate() {
            let dst = self.acc.get_mut(c).ok_or_else(|| {
                Error::Coordinator(format!("product for unowned tile {c:?}"))
            })?;
            for (d, s) in dst.iter_mut().zip(&products[slot * l2..(slot + 1) * l2]) {
                *d += s;
            }
        }
        Ok(())
    }
}

/// One gathered chunk traveling from the gather worker to the exec stage.
struct GatheredChunk {
    cap: usize,
    a_buf: Vec<f32>,
    b_buf: Vec<f32>,
    c_ids: Vec<(usize, usize)>,
}

/// Execute the surviving products of `tiles` in batched tile-GEMM calls,
/// scatter-accumulating into `sink`.  Shared by the single-device engine
/// and the per-device workers of the coordinator.
///
/// Stage-pipelined (§3.4): a gather worker stages chunk *i+1* while this
/// thread (which owns the non-`Send` PJRT runtime) executes chunk *i*, and
/// a scatter worker drains finished products.  `cfg.pipeline_depth` bounds
/// the in-flight chunks per channel.  Returns the executed product count.
#[allow(clippy::too_many_arguments)]
pub fn execute_products<S: ScatterSink>(
    rt: &Runtime,
    cfg: &SpammConfig,
    pa: &PaddedMatrix,
    pb: &PaddedMatrix,
    sink: &mut S,
    sched: &Schedule,
    tiles: &[(usize, usize)],
    stats: &mut MultiplyStats,
) -> Result<usize> {
    let products: Vec<ProductRef> = sched
        .products_for_tiles(tiles.iter().copied())
        .collect();
    let executed = products.len();
    stats.pipeline_depth = cfg.pipeline_depth.max(1);
    if products.is_empty() {
        // Zero surviving products (huge τ): the output is exactly the
        // sink's current contents — no kernel launches at all.
        return Ok(0);
    }
    let precision = cfg.precision.as_str();
    let chunks = pack_chunks(rt.bundle(), cfg, &products)?;
    // Resolve each chunk's compiled batch capacity up front so the gather
    // worker never touches the artifact registry.
    let mut caps = Vec::with_capacity(chunks.len());
    for chunk in &chunks {
        let meta = rt.bundle().tilegemm(chunk.len(), cfg.lonum, precision)?;
        let cap = meta.param_usize("batch").unwrap_or(chunk.len());
        debug_assert!(cap >= chunk.len());
        caps.push(cap);
    }
    let depth = cfg.pipeline_depth.max(1);
    let work: Vec<(&[ProductRef], usize)> = chunks.into_iter().zip(caps).collect();

    // A single chunk has nothing to overlap with — run the stages
    // inline and skip the worker spawn/channel setup entirely.
    if work.len() == 1 {
        let span = Instant::now();
        let (chunk, cap) = work[0];
        let t = Instant::now();
        let a_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.a).collect();
        let b_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.b).collect();
        let c_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.c).collect();
        let mut a_buf = Vec::new();
        let mut b_buf = Vec::new();
        gather_tiles(pa, &a_ids, cap, &mut a_buf)?;
        gather_tiles(pb, &b_ids, cap, &mut b_buf)?;
        stats.gather_secs += t.elapsed().as_secs_f64();
        let t = Instant::now();
        let out = rt.tile_gemm(&a_buf, &b_buf, cap, cfg.lonum, precision)?;
        stats.exec_secs += t.elapsed().as_secs_f64();
        stats.batches += 1;
        let t = Instant::now();
        sink.scatter(&c_ids, &out)?;
        stats.scatter_secs += t.elapsed().as_secs_f64();
        stats.exec_span_secs += span.elapsed().as_secs_f64();
        return Ok(executed);
    }

    let span = Instant::now();
    let result = std::thread::scope(|scope| -> Result<()> {
        let (gather_tx, gather_rx) = mpsc::sync_channel::<GatheredChunk>(depth);
        let (scatter_tx, scatter_rx) =
            mpsc::sync_channel::<(Vec<(usize, usize)>, Vec<f32>)>(depth);
        // Exec returns spent staging buffers to the gather worker so the
        // hot loop reuses allocations instead of mallocing per chunk.
        let (recycle_tx, recycle_rx) = mpsc::channel::<(Vec<f32>, Vec<f32>)>();

        // Stage 1: gather worker (reads pa/pb, stages contiguous buffers).
        let gather_worker = scope.spawn(move || -> Result<f64> {
            let mut secs = 0.0f64;
            for (chunk, cap) in work {
                let (mut a_buf, mut b_buf) = recycle_rx.try_recv().unwrap_or_default();
                let t = Instant::now();
                let a_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.a).collect();
                let b_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.b).collect();
                let c_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.c).collect();
                gather_tiles(pa, &a_ids, cap, &mut a_buf)?;
                gather_tiles(pb, &b_ids, cap, &mut b_buf)?;
                secs += t.elapsed().as_secs_f64();
                let staged = GatheredChunk {
                    cap,
                    a_buf,
                    b_buf,
                    c_ids,
                };
                if gather_tx.send(staged).is_err() {
                    break; // exec stage bailed out; stop producing
                }
            }
            Ok(secs)
        });

        // Stage 3: scatter worker (owns the sink for the span).
        let scatter_worker = scope.spawn(move || -> Result<f64> {
            let mut secs = 0.0f64;
            for (c_ids, out) in scatter_rx {
                let t = Instant::now();
                sink.scatter(&c_ids, &out)?;
                secs += t.elapsed().as_secs_f64();
            }
            Ok(secs)
        });

        // Stage 2: tile-GEMM execution on this thread (the PJRT client is
        // not Send; it never crosses threads).
        let mut exec_err: Option<Error> = None;
        for staged in gather_rx {
            let GatheredChunk {
                cap,
                a_buf,
                b_buf,
                c_ids,
            } = staged;
            let t = Instant::now();
            match rt.tile_gemm(&a_buf, &b_buf, cap, cfg.lonum, precision) {
                Ok(out) => {
                    stats.exec_secs += t.elapsed().as_secs_f64();
                    stats.batches += 1;
                    // Hand the buffers back for reuse (gather may already
                    // be gone; that's fine).
                    let _ = recycle_tx.send((a_buf, b_buf));
                    if scatter_tx.send((c_ids, out)).is_err() {
                        exec_err =
                            Some(Error::Coordinator("scatter stage terminated early".into()));
                        break;
                    }
                }
                Err(e) => {
                    exec_err = Some(e);
                    break;
                }
            }
        }
        drop(scatter_tx);

        let gather_res = gather_worker
            .join()
            .map_err(|_| Error::Coordinator("gather worker panicked".into()))?;
        let scatter_res = scatter_worker
            .join()
            .map_err(|_| Error::Coordinator("scatter worker panicked".into()))?;
        // Report errors in pipeline order; a genuine scatter error beats
        // the synthetic channel-closed error it caused upstream.
        match gather_res {
            Ok(secs) => stats.gather_secs += secs,
            Err(e) => return Err(e),
        }
        match scatter_res {
            Ok(secs) => stats.scatter_secs += secs,
            Err(e) => return Err(e),
        }
        if let Some(e) = exec_err {
            return Err(e);
        }
        Ok(())
    });
    stats.exec_span_secs += span.elapsed().as_secs_f64();
    result?;
    Ok(executed)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tilegemm-only hostsim bundle with buckets {16, 64, 256} — written
    /// through `runtime::hostsim` so the manifest/op schema has a single
    /// owner, into a pid-suffixed dir so concurrent test runs can't race.
    fn bucket_bundle(tag: &str) -> ArtifactBundle {
        use crate::runtime::hostsim::{write_bundle, HostsimSpec};
        let dir = std::env::temp_dir().join(format!("{tag}_{}", std::process::id()));
        let spec = HostsimSpec {
            lonum: 32,
            dense_sizes: vec![],
            getnorm_sizes: vec![],
            tilegemm_batches: vec![16, 64, 256],
            tune_bdims: vec![],
            fused_sizes: vec![],
            precisions: vec!["f32"],
        };
        write_bundle(&dir, &spec).unwrap();
        ArtifactBundle::load(&dir).unwrap()
    }

    fn product(i: usize) -> ProductRef {
        ProductRef {
            a: (0, i),
            b: (i, 0),
            c: (0, 0),
        }
    }

    #[test]
    fn pack_chunks_empty_products() {
        let bundle = bucket_bundle("cuspamm_pack_empty");
        let cfg = SpammConfig::default();
        let chunks = pack_chunks(&bundle, &cfg, &[]).unwrap();
        assert!(chunks.is_empty());
    }

    #[test]
    fn pack_chunks_greedy_buckets() {
        let bundle = bucket_bundle("cuspamm_pack_greedy");
        let cfg = SpammConfig::default(); // max_tile_batch 1024 > largest
        let products: Vec<ProductRef> = (0..153).map(product).collect();
        let chunks = pack_chunks(&bundle, &cfg, &products).unwrap();
        let sizes: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
        assert_eq!(sizes, vec![64, 64, 16, 9]);
        assert_eq!(sizes.iter().sum::<usize>(), 153);
    }

    #[test]
    fn pack_chunks_cap_smaller_than_smallest_bucket() {
        // Regression: the sub-smallest-bucket tail used to bypass
        // max_tile_batch via the unclamped fallback.
        let bundle = bucket_bundle("cuspamm_pack_cap");
        let mut cfg = SpammConfig::default();
        cfg.max_tile_batch = 10; // below the smallest bucket (16)
        let products: Vec<ProductRef> = (0..25).map(product).collect();
        let chunks = pack_chunks(&bundle, &cfg, &products).unwrap();
        assert!(
            chunks.iter().all(|c| c.len() <= 10),
            "chunk exceeded cap: {:?}",
            chunks.iter().map(|c| c.len()).collect::<Vec<_>>()
        );
        assert_eq!(chunks.iter().map(|c| c.len()).sum::<usize>(), 25);
    }

    #[test]
    fn pack_chunks_tail_below_smallest_bucket() {
        let bundle = bucket_bundle("cuspamm_pack_tail");
        let cfg = SpammConfig::default();
        let products: Vec<ProductRef> = (0..7).map(product).collect();
        let chunks = pack_chunks(&bundle, &cfg, &products).unwrap();
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].len(), 7);
    }

    #[test]
    fn pack_chunks_respects_cap_above_bucket() {
        let bundle = bucket_bundle("cuspamm_pack_mid");
        let mut cfg = SpammConfig::default();
        cfg.max_tile_batch = 64;
        let products: Vec<ProductRef> = (0..300).map(product).collect();
        let chunks = pack_chunks(&bundle, &cfg, &products).unwrap();
        assert!(chunks.iter().all(|c| c.len() <= 64));
        assert_eq!(chunks.iter().map(|c| c.len()).sum::<usize>(), 300);
    }

    #[test]
    fn tile_accumulator_rejects_unowned() {
        let mut acc = TileAccumulator::new(2, [(0usize, 0usize)]);
        let tile = vec![1.0f32; 4];
        acc.scatter(&[(0, 0)], &tile).unwrap();
        assert!(acc.scatter(&[(1, 1)], &tile).is_err());
        let tiles = acc.into_tiles();
        assert_eq!(tiles.len(), 1);
        assert_eq!(tiles[0].1, vec![1.0; 4]);
    }

    #[test]
    fn check_inner_dims_catches_padded_equal_grids() {
        // 17 and 20 both pad to one 32-tile: the tile grids agree, the
        // logical shapes do not.
        let a = Matrix::zeros(16, 17);
        let b = Matrix::zeros(20, 8);
        assert!(check_inner_dims("multiply", &a, &b).is_err());
        let ok = Matrix::zeros(17, 20);
        let b2 = Matrix::zeros(20, 8);
        assert!(check_inner_dims("multiply", &ok, &b2).is_ok());
    }
}
