//! Host-side τ search for a target valid ratio — the §3.5.2 procedure:
//! expanding binary search over [0, k·ave] where ave is the mean norm
//! product, k grows while the bracket cannot reach the target, and the
//! user bounds iterations and tolerable ratio error.  Twin of the
//! on-device `tune_tau` graph (python/compile/kernels/tune.py).

use crate::error::{Error, Result};
use crate::matrix::Matrix;

/// Search parameters (§3.5.2: "users can specify the number of iterations
/// and tolerable error of valid ratio").
#[derive(Clone, Copy, Debug)]
pub struct TuneParams {
    pub max_iters: usize,
    pub tolerance: f64,
}

impl Default for TuneParams {
    fn default() -> Self {
        // The paper constrains its Table 1 tuning to 20 iterations and
        // reports <1% ratio error.
        TuneParams {
            max_iters: 20,
            tolerance: 0.01,
        }
    }
}

/// Result of a τ search.
#[derive(Clone, Copy, Debug)]
pub struct TuneResult {
    pub tau: f32,
    pub achieved_ratio: f64,
    pub iters: usize,
    /// Final expansion coefficient k (1 = no expansion needed).
    pub expansion_k: usize,
}

fn ratio_at(na: &Matrix, nb: &Matrix, tau: f32) -> f64 {
    let (tr, tk, tc) = (na.rows(), na.cols(), nb.cols());
    let mut count = 0usize;
    for i in 0..tr {
        for k in 0..tk {
            let av = na[(i, k)];
            for j in 0..tc {
                if av * nb[(k, j)] >= tau {
                    count += 1;
                }
            }
        }
    }
    count as f64 / (tr * tk * tc).max(1) as f64
}

/// Find τ such that valid_ratio(τ) ≈ target.
pub fn tune_tau(
    na: &Matrix,
    nb: &Matrix,
    target: f64,
    params: TuneParams,
) -> Result<TuneResult> {
    if na.cols() != nb.rows() {
        return Err(Error::Shape("tune_tau: normmap shapes".into()));
    }
    if !(0.0..=1.0).contains(&target) {
        return Err(Error::Config(format!("target ratio {target} outside [0,1]")));
    }
    // ave = mean norm product (the tuning kernel's first step).
    let (tr, tk, tc) = (na.rows(), na.cols(), nb.cols());
    let mut sum = 0.0f64;
    for i in 0..tr {
        for k in 0..tk {
            for j in 0..tc {
                sum += (na[(i, k)] as f64) * (nb[(k, j)] as f64);
            }
        }
    }
    let ave = (sum / (tr * tk * tc).max(1) as f64) as f32;
    if ave == 0.0 {
        // All-zero inputs: every product is 0 ≥ τ=0 → ratio 1 at τ=0.
        return Ok(TuneResult {
            tau: 0.0,
            achieved_ratio: 1.0,
            iters: 0,
            expansion_k: 1,
        });
    }

    // Expansion phase: grow upper bound k·ave until ratio(k·ave) ≤ target.
    let mut k = 1usize;
    while ratio_at(na, nb, k as f32 * ave) > target && k < 1 << 20 {
        k += 1;
    }

    // Bisection.
    let (mut lo, mut hi) = (0.0f32, k as f32 * ave);
    let mut iters = 0usize;
    let mut best = TuneResult {
        tau: hi,
        achieved_ratio: ratio_at(na, nb, hi),
        iters: 0,
        expansion_k: k,
    };
    while iters < params.max_iters {
        let mid = 0.5 * (lo + hi);
        let r = ratio_at(na, nb, mid);
        iters += 1;
        if (r - target).abs() < (best.achieved_ratio - target).abs() {
            best = TuneResult {
                tau: mid,
                achieved_ratio: r,
                iters,
                expansion_k: k,
            };
        }
        if (r - target).abs() <= params.tolerance {
            best.iters = iters;
            return Ok(best);
        }
        if r > target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    best.iters = iters;
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::tiling::PaddedMatrix;
    use crate::spamm::normmap::normmap;
    use crate::spamm::schedule::Schedule;

    fn decay_normmaps(n: usize) -> (Matrix, Matrix) {
        let a = Matrix::decay_algebraic(n, 0.1, 0.1, 7);
        let b = Matrix::decay_algebraic(n, 0.1, 0.1, 8);
        (
            normmap(&PaddedMatrix::new(&a, 32)),
            normmap(&PaddedMatrix::new(&b, 32)),
        )
    }

    #[test]
    fn hits_table1_targets() {
        let (na, nb) = decay_normmaps(512);
        for target in [0.30, 0.25, 0.20, 0.15, 0.10, 0.05] {
            let r = tune_tau(&na, &nb, target, TuneParams::default()).unwrap();
            assert!(
                (r.achieved_ratio - target).abs() < 0.01,
                "target {target}: got {}",
                r.achieved_ratio
            );
            // Consistency with the Schedule's own counting.
            let s = Schedule::build(&na, &nb, r.tau).unwrap();
            assert!((s.valid_ratio() - r.achieved_ratio).abs() < 1e-9);
        }
    }

    #[test]
    fn ratio_monotone_decreasing_in_tau() {
        let (na, nb) = decay_normmaps(256);
        let mut prev = 1.1;
        for t in [0.0f32, 1e-4, 1e-3, 1e-2, 1e-1, 1.0] {
            let r = ratio_at(&na, &nb, t);
            assert!(r <= prev + 1e-12);
            prev = r;
        }
    }

    #[test]
    fn tiny_target_engages_expansion() {
        let (na, nb) = decay_normmaps(256);
        let r = tune_tau(&na, &nb, 0.002, TuneParams { max_iters: 40, tolerance: 0.001 })
            .unwrap();
        assert!(r.expansion_k > 1, "expected expansion, k={}", r.expansion_k);
        assert!((r.achieved_ratio - 0.002).abs() < 0.005);
    }

    #[test]
    fn zero_matrix_degenerate() {
        let z = Matrix::zeros(4, 4);
        let r = tune_tau(&z, &z, 0.5, TuneParams::default()).unwrap();
        assert_eq!(r.tau, 0.0);
        assert_eq!(r.achieved_ratio, 1.0);
    }

    #[test]
    fn rejects_bad_target() {
        let (na, nb) = decay_normmaps(256);
        assert!(tune_tau(&na, &nb, 1.5, TuneParams::default()).is_err());
    }

    #[test]
    fn agrees_with_paper_iteration_budget() {
        // <1% error within 20 iterations (the Table 1 protocol).
        let (na, nb) = decay_normmaps(512);
        let r = tune_tau(&na, &nb, 0.10, TuneParams { max_iters: 20, tolerance: 0.0 })
            .unwrap();
        assert!(r.iters <= 20);
        assert!((r.achieved_ratio - 0.10).abs() < 0.01);
    }
}
