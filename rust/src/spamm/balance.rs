//! Load balancing (§3.5.1): assignment of output tiles to devices/blocks.
//!
//! For decay matrices the V matrix (valid products per output tile) is
//! largest near the diagonal, so contiguous row-block partitions leave the
//! devices holding off-diagonal stripes idle.  The paper's fix assigns each
//! worker `s` sub-matrices at equal stride; we implement both policies and
//! an imbalance metric so the ablation bench can quantify the gain.

use crate::config::Balance;
use crate::spamm::schedule::Schedule;

/// Assignment of every output tile (row-major index) to a device.
#[derive(Clone, Debug)]
pub struct Assignment {
    pub devices: usize,
    /// tile index (i·tile_cols + j) → device.
    pub owner: Vec<usize>,
}

impl Assignment {
    /// Build an assignment for `devices` workers under the given policy.
    pub fn build(s: &Schedule, devices: usize, policy: Balance) -> Assignment {
        let tiles = s.tile_rows * s.tile_cols;
        let mut owner = vec![0usize; tiles];
        match policy {
            Balance::RowBlock => {
                // Algorithm 4: device d owns tile rows [d·TR/M, (d+1)·TR/M).
                for i in 0..s.tile_rows {
                    let d = i * devices / s.tile_rows.max(1);
                    for j in 0..s.tile_cols {
                        owner[i * s.tile_cols + j] = d.min(devices - 1);
                    }
                }
            }
            Balance::Strided(stride) => {
                // §3.5.1 generalized: walk tiles in row-major order jumping
                // by `stride` rows per step so each device interleaves
                // diagonal-near and diagonal-far tiles.
                let s_eff = stride.max(1);
                for i in 0..s.tile_rows {
                    // Interleave rows: row i goes to device ((i / s_eff) +
                    // (i % s_eff) * ceil(TR / s_eff)) % devices — a strided
                    // permutation of rows, then round-robin.
                    let groups = s.tile_rows.div_ceil(s_eff);
                    let permuted = (i % s_eff) * groups + i / s_eff;
                    let d = permuted % devices;
                    for j in 0..s.tile_cols {
                        owner[i * s.tile_cols + j] = d;
                    }
                }
            }
        }
        Assignment { devices, owner }
    }

    /// Tiles owned by device d, as (i, j) pairs in row-major order.
    pub fn tiles_of(&self, s: &Schedule, d: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for i in 0..s.tile_rows {
            for j in 0..s.tile_cols {
                if self.owner[i * s.tile_cols + j] == d {
                    out.push((i, j));
                }
            }
        }
        out
    }

    /// Valid products per device — the workload vector.
    pub fn load(&self, s: &Schedule) -> Vec<usize> {
        let mut load = vec![0usize; self.devices];
        for i in 0..s.tile_rows {
            for j in 0..s.tile_cols {
                load[self.owner[i * s.tile_cols + j]] += s.v(i, j);
            }
        }
        load
    }

    /// Imbalance = max(load)/mean(load) (1.0 = perfect).
    pub fn imbalance(&self, s: &Schedule) -> f64 {
        let load = self.load(s);
        let total: usize = load.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / self.devices as f64;
        let max = *load.iter().max().unwrap() as f64;
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::tiling::PaddedMatrix;
    use crate::matrix::Matrix;
    use crate::spamm::normmap::normmap;

    fn decay_schedule(n: usize, tau: f32) -> Schedule {
        let a = Matrix::decay_exponential(n, 1.0, 0.55, 3);
        let na = normmap(&PaddedMatrix::new(&a, 32));
        Schedule::build(&na, &na, tau).unwrap()
    }

    #[test]
    fn every_tile_owned_exactly_once() {
        let s = decay_schedule(256, 1e-3);
        for policy in [Balance::RowBlock, Balance::Strided(2), Balance::Strided(4)] {
            for devices in [1, 2, 3, 4, 8] {
                let a = Assignment::build(&s, devices, policy);
                assert_eq!(a.owner.len(), s.tile_rows * s.tile_cols);
                assert!(a.owner.iter().all(|&d| d < devices));
                // Union of tiles_of over devices = all tiles, disjoint.
                let mut seen = vec![false; a.owner.len()];
                for d in 0..devices {
                    for (i, j) in a.tiles_of(&s, d) {
                        let idx = i * s.tile_cols + j;
                        assert!(!seen[idx]);
                        seen[idx] = true;
                    }
                }
                assert!(seen.iter().all(|&x| x));
            }
        }
    }

    #[test]
    fn single_device_gets_everything() {
        let s = decay_schedule(128, 0.0);
        let a = Assignment::build(&s, 1, Balance::RowBlock);
        assert_eq!(a.load(&s), vec![s.valid_products()]);
        assert_eq!(a.imbalance(&s), 1.0);
    }

    #[test]
    fn strided_beats_rowblock_on_decay() {
        // §3.5.1's whole point: on a strongly diagonal V matrix the strided
        // policy balances better than contiguous row blocks.
        let s = decay_schedule(512, 5e-1);
        assert!(s.valid_ratio() < 0.7, "need an imbalanced schedule");
        let devices = 4;
        let rb = Assignment::build(&s, devices, Balance::RowBlock).imbalance(&s);
        let st = Assignment::build(&s, devices, Balance::Strided(4)).imbalance(&s);
        assert!(
            st <= rb + 1e-9,
            "strided {st:.3} should be ≤ rowblock {rb:.3}"
        );
    }

    #[test]
    fn empty_schedule_is_balanced() {
        let s = decay_schedule(128, f32::MAX);
        let a = Assignment::build(&s, 4, Balance::RowBlock);
        assert_eq!(a.imbalance(&s), 1.0);
    }
}
