//! Load balancing (§3.5.1): assignment of output tiles to devices/blocks.
//!
//! For decay matrices the V matrix (valid products per output tile) is
//! largest near the diagonal, so contiguous row-block partitions leave the
//! devices holding off-diagonal stripes idle.  The paper's fix assigns each
//! worker `s` sub-matrices at equal stride; we implement both policies and
//! an imbalance metric so the ablation bench can quantify the gain.
//!
//! The third policy, [`Assignment::build_residency_aware`], models
//! *communication* per partition rather than tile counts alone (the
//! SUMMA-style analysis of Yang/Buluç/Owens, arXiv:1803.08601): an output
//! tile whose A/B operand tiles are already resident in a device's
//! [`crate::runtime::residency::ResidencyPool`] is kept on that device
//! (zero transfer); the rest are placed greedily by valid-product load
//! with estimated transfer bytes as the tie-break, and each device's
//! distinct-operand-tile working set is kept under its memory budget when
//! a feasible placement exists.

use std::collections::HashSet;

use crate::config::Balance;
use crate::spamm::schedule::Schedule;

/// One device's residency/budget view for the residency-aware policy —
/// a snapshot taken from the device's pool right before partitioning
/// (via [`crate::runtime::residency::ResidencyPool::resident_tiles_of`] /
/// `resident_bytes_of`).
#[derive(Clone, Debug)]
pub struct DeviceView {
    /// A-operand tiles (coords in A's tile grid) resident on the device.
    pub a_resident: HashSet<(usize, usize)>,
    /// B-operand tiles resident on the device.
    pub b_resident: HashSet<(usize, usize)>,
    /// Working-set byte budget (`usize::MAX` = unlimited).
    pub budget_bytes: usize,
}

impl Default for DeviceView {
    fn default() -> Self {
        DeviceView {
            a_resident: HashSet::new(),
            b_resident: HashSet::new(),
            budget_bytes: usize::MAX,
        }
    }
}

/// Tag distinguishing A-operand from B-operand tiles in working sets.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
enum Op {
    A,
    B,
}

/// Row-block tile→device map over a bare grid (Algorithm 4's default:
/// device d owns tile rows [d·TR/M, (d+1)·TR/M)) — the one canonical
/// formula, shared by [`Assignment::build`] and the expression planner's
/// element-wise placement fallback.
pub fn rowblock_owner(tile_rows: usize, tile_cols: usize, devices: usize) -> Vec<usize> {
    let mut owner = vec![0usize; tile_rows * tile_cols];
    if devices > 1 {
        for i in 0..tile_rows {
            let d = (i * devices / tile_rows.max(1)).min(devices - 1);
            for j in 0..tile_cols {
                owner[i * tile_cols + j] = d;
            }
        }
    }
    owner
}

/// Assignment of every output tile (row-major index) to a device.
#[derive(Clone, Debug)]
pub struct Assignment {
    pub devices: usize,
    /// tile index (i·tile_cols + j) → device.
    pub owner: Vec<usize>,
}

impl Assignment {
    /// Build an assignment for `devices` workers under the given policy.
    pub fn build(s: &Schedule, devices: usize, policy: Balance) -> Assignment {
        let tiles = s.tile_rows * s.tile_cols;
        let mut owner = vec![0usize; tiles];
        match policy {
            Balance::RowBlock => {
                owner = rowblock_owner(s.tile_rows, s.tile_cols, devices);
            }
            Balance::Strided(stride) => {
                // §3.5.1 generalized: walk tiles in row-major order jumping
                // by `stride` rows per step so each device interleaves
                // diagonal-near and diagonal-far tiles.
                let s_eff = stride.max(1);
                for i in 0..s.tile_rows {
                    // Interleave rows: row i goes to device ((i / s_eff) +
                    // (i % s_eff) * ceil(TR / s_eff)) % devices — a strided
                    // permutation of rows, then round-robin.
                    let groups = s.tile_rows.div_ceil(s_eff);
                    let permuted = (i % s_eff) * groups + i / s_eff;
                    let d = permuted % devices;
                    for j in 0..s.tile_cols {
                        owner[i * s.tile_cols + j] = d;
                    }
                }
            }
            Balance::ResidencyAware => {
                // Residency needs pool views; without them (this cold
                // builder) the policy degrades to its cold greedy fill.
                return Assignment::build_residency_aware(s, devices, &[], 1);
            }
        }
        Assignment { devices, owner }
    }

    /// Residency- and memory-aware assignment (see module docs).
    ///
    /// Two deterministic phases over the output tiles:
    ///
    /// 1. **Warm affinity** — a tile whose needed A/B operand tiles are
    ///    *all* resident on some device stays on that device (ties:
    ///    least load, then lowest device index).  Adding it moves zero
    ///    bytes, so warm devices keep their tiles.
    /// 2. **Greedy fill** — remaining tiles, in descending valid-product
    ///    order (LPT), go to the budget-feasible device with the least
    ///    load; estimated new transfer bytes (needed tiles not resident
    ///    and not already in the device's accumulated working set) break
    ///    ties, then the device index.  When no device is feasible the
    ///    budget is ignored for that tile — like the pool itself, the
    ///    partition overflows rather than dropping work.
    ///
    /// `views.len()` may be shorter than `devices` (missing devices are
    /// treated as cold and unbounded).  `tile_bytes` is the device
    /// memory footprint of one operand tile (LoNum²·4).
    pub fn build_residency_aware(
        s: &Schedule,
        devices: usize,
        views: &[DeviceView],
        tile_bytes: usize,
    ) -> Assignment {
        let tiles = s.tile_rows * s.tile_cols;
        let mut owner = vec![0usize; tiles];
        if devices <= 1 || tiles == 0 {
            return Assignment { devices, owner };
        }
        let cold = DeviceView::default();
        let view = |d: usize| views.get(d).unwrap_or(&cold);

        // Output tiles in descending valid-product order (stable on the
        // row-major index) — the LPT order both phases walk.
        let mut order: Vec<usize> = (0..tiles).collect();
        order.sort_by_key(|&t| {
            let (i, j) = (t / s.tile_cols, t % s.tile_cols);
            (std::cmp::Reverse(s.v(i, j)), t)
        });

        // Needed operand tiles of output tile t: A row-i tiles and
        // B column-j tiles at the schedule's surviving k.
        let needed = |t: usize| -> Vec<(Op, (usize, usize))> {
            let (i, j) = (t / s.tile_cols, t % s.tile_cols);
            let mut v = Vec::with_capacity(2 * s.v(i, j));
            for &k in s.ks(i, j) {
                v.push((Op::A, (i, k as usize)));
                v.push((Op::B, (k as usize, j)));
            }
            v
        };
        let is_resident = |d: usize, op: Op, tile: (usize, usize)| match op {
            Op::A => view(d).a_resident.contains(&tile),
            Op::B => view(d).b_resident.contains(&tile),
        };

        // Per-device accumulated state: valid-product load and the
        // distinct-operand-tile working set (resident or not — resident
        // tiles occupy device memory too, so they count toward budget).
        let mut load = vec![0usize; devices];
        let mut ws: Vec<HashSet<(Op, (usize, usize))>> =
            (0..devices).map(|_| HashSet::new()).collect();
        let mut ws_bytes = vec![0usize; devices];
        let mut assigned = vec![false; tiles];

        let mut place = |t: usize,
                         d: usize,
                         load: &mut Vec<usize>,
                         ws: &mut Vec<HashSet<(Op, (usize, usize))>>,
                         ws_bytes: &mut Vec<usize>| {
            let (i, j) = (t / s.tile_cols, t % s.tile_cols);
            owner[t] = d;
            load[d] += s.v(i, j);
            for item in needed(t) {
                if ws[d].insert(item) {
                    ws_bytes[d] += tile_bytes;
                }
            }
        };

        // Phase 1: warm affinity.  Tiles with zero valid products have
        // nothing to transfer and carry no load — leave them to phase 2.
        if views.iter().any(|v| !v.a_resident.is_empty() || !v.b_resident.is_empty()) {
            for &t in &order {
                let (i, j) = (t / s.tile_cols, t % s.tile_cols);
                if s.v(i, j) == 0 {
                    continue;
                }
                let need = needed(t);
                let home = (0..devices)
                    .filter(|&d| need.iter().all(|&(op, tile)| is_resident(d, op, tile)))
                    .min_by_key(|&d| (load[d], d));
                if let Some(d) = home {
                    assigned[t] = true;
                    place(t, d, &mut load, &mut ws, &mut ws_bytes);
                }
            }
        }

        // Phase 2: greedy fill of everything else.
        for &t in &order {
            if assigned[t] {
                continue;
            }
            let need = needed(t);
            let new_bytes = |d: usize| -> usize {
                need.iter()
                    .filter(|&&(op, tile)| {
                        !is_resident(d, op, tile) && !ws[d].contains(&(op, tile))
                    })
                    .count()
                    * tile_bytes
            };
            let ws_growth = |d: usize| -> usize {
                need.iter().filter(|item| !ws[d].contains(*item)).count() * tile_bytes
            };
            let pick = (0..devices)
                .filter(|&d| {
                    ws_bytes[d].saturating_add(ws_growth(d)) <= view(d).budget_bytes
                })
                .min_by_key(|&d| (load[d], new_bytes(d), d))
                // No device can fit this tile's working set: ignore the
                // budget for it (the pool's LRU absorbs the overflow).
                .unwrap_or_else(|| {
                    (0..devices)
                        .min_by_key(|&d| (load[d], new_bytes(d), d))
                        .expect("devices >= 1")
                });
            place(t, pick, &mut load, &mut ws, &mut ws_bytes);
        }
        Assignment { devices, owner }
    }

    /// Estimated transfer bytes of this assignment against the given
    /// residency views: for each device, its distinct needed operand
    /// tiles that are *not* resident there.  The partition-level cost the
    /// residency-aware policy minimizes; reported for diagnostics.
    pub fn transfer_bytes(
        &self,
        s: &Schedule,
        views: &[DeviceView],
        tile_bytes: usize,
    ) -> u64 {
        let cold = DeviceView::default();
        let mut total = 0u64;
        for d in 0..self.devices {
            let view = views.get(d).unwrap_or(&cold);
            let mut seen: HashSet<(Op, (usize, usize))> = HashSet::new();
            for (i, j) in self.tiles_of(s, d) {
                for &k in s.ks(i, j) {
                    let a = (Op::A, (i, k as usize));
                    if seen.insert(a) && !view.a_resident.contains(&(i, k as usize)) {
                        total += tile_bytes as u64;
                    }
                    let b = (Op::B, (k as usize, j));
                    if seen.insert(b) && !view.b_resident.contains(&(k as usize, j)) {
                        total += tile_bytes as u64;
                    }
                }
            }
        }
        total
    }

    /// Tiles owned by device d, as (i, j) pairs in row-major order.
    pub fn tiles_of(&self, s: &Schedule, d: usize) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for i in 0..s.tile_rows {
            for j in 0..s.tile_cols {
                if self.owner[i * s.tile_cols + j] == d {
                    out.push((i, j));
                }
            }
        }
        out
    }

    /// Valid products per device — the workload vector.
    pub fn load(&self, s: &Schedule) -> Vec<usize> {
        let mut load = vec![0usize; self.devices];
        for i in 0..s.tile_rows {
            for j in 0..s.tile_cols {
                load[self.owner[i * s.tile_cols + j]] += s.v(i, j);
            }
        }
        load
    }

    /// Imbalance = max(load)/mean(load) (1.0 = perfect).
    pub fn imbalance(&self, s: &Schedule) -> f64 {
        let load = self.load(s);
        let total: usize = load.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / self.devices as f64;
        let max = *load.iter().max().unwrap() as f64;
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::tiling::PaddedMatrix;
    use crate::matrix::Matrix;
    use crate::spamm::normmap::normmap;

    fn decay_schedule(n: usize, tau: f32) -> Schedule {
        let a = Matrix::decay_exponential(n, 1.0, 0.55, 3);
        let na = normmap(&PaddedMatrix::new(&a, 32));
        Schedule::build(&na, &na, tau).unwrap()
    }

    #[test]
    fn every_tile_owned_exactly_once() {
        let s = decay_schedule(256, 1e-3);
        for policy in [Balance::RowBlock, Balance::Strided(2), Balance::Strided(4)] {
            for devices in [1, 2, 3, 4, 8] {
                let a = Assignment::build(&s, devices, policy);
                assert_eq!(a.owner.len(), s.tile_rows * s.tile_cols);
                assert!(a.owner.iter().all(|&d| d < devices));
                // Union of tiles_of over devices = all tiles, disjoint.
                let mut seen = vec![false; a.owner.len()];
                for d in 0..devices {
                    for (i, j) in a.tiles_of(&s, d) {
                        let idx = i * s.tile_cols + j;
                        assert!(!seen[idx]);
                        seen[idx] = true;
                    }
                }
                assert!(seen.iter().all(|&x| x));
            }
        }
    }

    #[test]
    fn single_device_gets_everything() {
        let s = decay_schedule(128, 0.0);
        let a = Assignment::build(&s, 1, Balance::RowBlock);
        assert_eq!(a.load(&s), vec![s.valid_products()]);
        assert_eq!(a.imbalance(&s), 1.0);
    }

    #[test]
    fn strided_beats_rowblock_on_decay() {
        // §3.5.1's whole point: on a strongly diagonal V matrix the strided
        // policy balances better than contiguous row blocks.
        let s = decay_schedule(512, 5e-1);
        assert!(s.valid_ratio() < 0.7, "need an imbalanced schedule");
        let devices = 4;
        let rb = Assignment::build(&s, devices, Balance::RowBlock).imbalance(&s);
        let st = Assignment::build(&s, devices, Balance::Strided(4)).imbalance(&s);
        assert!(
            st <= rb + 1e-9,
            "strided {st:.3} should be ≤ rowblock {rb:.3}"
        );
    }

    #[test]
    fn empty_schedule_is_balanced() {
        let s = decay_schedule(128, f32::MAX);
        let a = Assignment::build(&s, 4, Balance::RowBlock);
        assert_eq!(a.imbalance(&s), 1.0);
    }

    /// Working set of one device under an assignment: distinct (operand,
    /// tile) pairs its output tiles need.
    fn working_set_bytes(a: &Assignment, s: &Schedule, d: usize, tile_bytes: usize) -> usize {
        let mut set = std::collections::HashSet::new();
        for (i, j) in a.tiles_of(s, d) {
            for &k in s.ks(i, j) {
                set.insert((0u8, i, k as usize));
                set.insert((1u8, k as usize, j));
            }
        }
        set.len() * tile_bytes
    }

    #[test]
    fn residency_aware_is_a_partition_and_balances_cold() {
        let s = decay_schedule(512, 5e-1);
        for devices in [1usize, 2, 3, 4, 8] {
            let a = Assignment::build_residency_aware(&s, devices, &[], 4096);
            assert_eq!(a.owner.len(), s.tile_rows * s.tile_cols);
            assert!(a.owner.iter().all(|&d| d < devices));
        }
        // Cold pools: the greedy LPT fill must balance at least as well
        // as contiguous row blocks on a diagonal-heavy decay schedule.
        let rb = Assignment::build(&s, 4, Balance::RowBlock).imbalance(&s);
        let ra = Assignment::build_residency_aware(&s, 4, &[], 4096).imbalance(&s);
        assert!(ra <= rb + 1e-9, "residency-aware {ra:.3} vs rowblock {rb:.3}");
    }

    #[test]
    fn residency_aware_keeps_fully_resident_tiles_home() {
        let s = decay_schedule(256, 1e-3);
        let devices = 4;
        // Warm device 2 with everything an existing strided partition
        // staged there; every tile of that partition must stay on 2.
        let strided = Assignment::build(&s, devices, Balance::Strided(4));
        let mut views: Vec<DeviceView> = (0..devices).map(|_| DeviceView::default()).collect();
        for (i, j) in strided.tiles_of(&s, 2) {
            for &k in s.ks(i, j) {
                views[2].a_resident.insert((i, k as usize));
                views[2].b_resident.insert((k as usize, j));
            }
        }
        let a = Assignment::build_residency_aware(&s, devices, &views, 4096);
        for (i, j) in strided.tiles_of(&s, 2) {
            if s.v(i, j) == 0 {
                continue;
            }
            assert_eq!(
                a.owner[i * s.tile_cols + j],
                2,
                "tile ({i},{j}) moved off its fully-resident device"
            );
        }
        // And a fully warm snapshot yields zero estimated transfer.
        let mut full: Vec<DeviceView> = (0..devices).map(|_| DeviceView::default()).collect();
        for d in 0..devices {
            for (i, j) in strided.tiles_of(&s, d) {
                for &k in s.ks(i, j) {
                    full[d].a_resident.insert((i, k as usize));
                    full[d].b_resident.insert((k as usize, j));
                }
            }
        }
        let warm = Assignment::build_residency_aware(&s, devices, &full, 4096);
        assert_eq!(warm.transfer_bytes(&s, &full, 4096), 0);
        assert!(
            Assignment::build(&s, devices, Balance::RowBlock).transfer_bytes(&s, &full, 4096) > 0,
            "row blocks must actually move tiles off the strided-warm devices"
        );
    }

    #[test]
    fn residency_aware_respects_working_set_budget() {
        // Hand-traceable 2×2 output grid, tile_k = 2, every product valid:
        // each output tile needs 2 A-tiles + 2 B-tiles; 8 distinct operand
        // tiles total.  With a 6-tile budget per device the greedy fill
        // must split row-wise (ws = 6 tiles each), never overflowing.
        let ones = Matrix::from_vec(2, 2, vec![1.0; 4]).unwrap();
        let s = Schedule::build(&ones, &ones, 0.5).unwrap();
        assert_eq!(s.valid_products(), 8);
        let tb = 4096usize;
        let views: Vec<DeviceView> = (0..2)
            .map(|_| DeviceView {
                budget_bytes: 6 * tb,
                ..DeviceView::default()
            })
            .collect();
        let a = Assignment::build_residency_aware(&s, 2, &views, tb);
        for d in 0..2 {
            let ws = working_set_bytes(&a, &s, d, tb);
            assert!(ws <= 6 * tb, "device {d}: working set {ws} > budget {}", 6 * tb);
        }
        // Load is perfectly balanced (4 valid products each).
        assert_eq!(a.load(&s), vec![4, 4]);
        // An impossible budget (below one tile's own needs) falls back to
        // overflow instead of leaving tiles unassigned.
        let tight: Vec<DeviceView> = (0..2)
            .map(|_| DeviceView {
                budget_bytes: tb,
                ..DeviceView::default()
            })
            .collect();
        let b = Assignment::build_residency_aware(&s, 2, &tight, tb);
        assert_eq!(b.owner.len(), 4);
        assert!(b.owner.iter().all(|&d| d < 2));
    }
}
