//! Host-side get-norm: tile Frobenius norms of a padded matrix.  Twin of
//! the Layer-1 `get_norm` Pallas kernel (which the runtime can use instead
//! via `SpammConfig::device_normmap`); both must agree to float tolerance —
//! rust/tests/integration.rs checks that.

use crate::matrix::tiling::PaddedMatrix;
use crate::matrix::Matrix;

/// Frobenius norm of one row-major tile buffer (f64 accumulation, f32
/// result) — the per-tile kernel both [`normmap`] and the expression
/// graph's device-side norm refresh share.  Summation runs in buffer
/// (row-major) order, exactly like [`normmap`]'s inner loop, so a norm
/// computed from a scatter-accumulated output tile is bitwise identical
/// to the host normmap of the same content.
pub fn tile_fnorm(tile: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for &x in tile {
        acc += (x as f64) * (x as f64);
    }
    acc.sqrt() as f32
}

/// normmap[i, j] = ‖tile(i, j)‖_F (f64 accumulation, f32 result — same
/// contract as the kernel, which accumulates the reduce in f32 over ≤128²
/// elements; the difference is below f32 epsilon·k).
pub fn normmap(p: &PaddedMatrix) -> Matrix {
    let (tr, tc, l) = (p.tile_rows(), p.tile_cols(), p.lonum);
    let cols = p.inner.cols();
    let data = p.inner.data();
    let mut out = Matrix::zeros(tr, tc);
    for ti in 0..tr {
        for tj in 0..tc {
            let mut acc = 0.0f64;
            for r in 0..l {
                let row = &data[(ti * l + r) * cols + tj * l..][..l];
                for &x in row {
                    acc += (x as f64) * (x as f64);
                }
            }
            out[(ti, tj)] = acc.sqrt() as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_tile_is_full_fnorm() {
        let m = Matrix::randn(32, 32, 1);
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap(&p);
        assert_eq!((nm.rows(), nm.cols()), (1, 1));
        assert!((nm[(0, 0)] as f64 - m.fnorm()).abs() < 1e-3);
    }

    #[test]
    fn sum_of_squares_invariant() {
        let m = Matrix::randn(96, 64, 2);
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap(&p);
        let total: f64 = nm.data().iter().map(|&x| (x as f64).powi(2)).sum();
        assert!((total - m.fnorm().powi(2)).abs() / total < 1e-6);
    }

    #[test]
    fn tile_fnorm_matches_normmap_bitwise() {
        // The device-side refresh path sums in the same order as the host
        // normmap, so the two must agree to the last bit per tile.
        let m = Matrix::randn(96, 64, 5);
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap(&p);
        let mut buf = vec![0.0f32; 32 * 32];
        for ti in 0..p.tile_rows() {
            for tj in 0..p.tile_cols() {
                p.copy_tile(ti, tj, &mut buf);
                assert_eq!(tile_fnorm(&buf).to_bits(), nm[(ti, tj)].to_bits());
            }
        }
    }

    #[test]
    fn padded_region_contributes_zero() {
        let m = Matrix::randn(40, 40, 3);
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap(&p);
        assert_eq!((nm.rows(), nm.cols()), (2, 2));
        // the (1,1) tile is 8x8 real data + zero padding
        let mut acc = 0.0f64;
        for r in 32..40 {
            for c in 32..40 {
                acc += (m[(r, c)] as f64).powi(2);
            }
        }
        assert!((nm[(1, 1)] as f64 - acc.sqrt()).abs() < 1e-4);
    }
}
