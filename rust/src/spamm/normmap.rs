//! Host-side get-norm: tile Frobenius norms of a padded matrix.  Twin of
//! the Layer-1 `get_norm` Pallas kernel (which the runtime can use instead
//! via `SpammConfig::device_normmap`); both must agree to float tolerance —
//! rust/tests/integration.rs checks that.

use crate::config::SpammConfig;
use crate::matrix::tiling::PaddedMatrix;
use crate::matrix::Matrix;

/// Magnitude floor for the per-tile density census: an entry counts as
/// structurally nonzero only when `|x| > DENSITY_FLOOR`.  Decay-dominated
/// operands (the paper's core workload, `exp(-0.5·d)` off a diagonal) fall
/// below this floor a few tens of entries out, so their surviving tiles
/// report low density while gaussian tiles report ≈ 1.0.
pub const DENSITY_FLOOR: f32 = 1e-6;

/// Per-tile norm *and* density map of one padded operand — the Layer-1
/// get-norm output extended with the near-free density census that the
/// adaptive scheduler keys tile-format selection on.
///
/// `norms[(i,j)]` is ‖tile(i,j)‖_F exactly as [`normmap`] computes it;
/// `density[(i,j)]` is the fraction of the tile's `LoNum²` entries with
/// magnitude above [`DENSITY_FLOOR`].  Both are produced by one pass over
/// the operand ([`normmap_with_density`]); the norm accumulation order is
/// bitwise identical to [`normmap`] / [`tile_fnorm`].
#[derive(Clone, Debug)]
pub struct NormMap {
    pub norms: Matrix,
    pub density: Matrix,
}

impl NormMap {
    /// Wrap a bare norm map with an all-dense density (1.0 everywhere).
    /// Used for device-side get-norm results, propagated norm *bounds*,
    /// and device-resident intermediates — sources with no host census.
    /// Such operands never select the sparse path, which keeps staging
    /// decisions conservative (dense is always correct).
    pub fn dense_like(norms: Matrix) -> NormMap {
        let density = Matrix::from_vec(
            norms.rows(),
            norms.cols(),
            vec![1.0; norms.rows() * norms.cols()],
        )
        .expect("dense_like: shape");
        NormMap { norms, density }
    }

    pub fn tile_rows(&self) -> usize {
        self.norms.rows()
    }

    pub fn tile_cols(&self) -> usize {
        self.norms.cols()
    }

    /// Recompute norm + density census for just the listed tiles of `p` —
    /// the delta-update path.  Each touched tile runs the exact inner loop
    /// of [`normmap_with_density`] (same traversal, same f64 accumulation,
    /// same census rule), so a patched map is bitwise identical to a full
    /// recompute of the updated operand.  Untouched tiles are left alone.
    pub fn patch_tiles(&mut self, p: &PaddedMatrix, tiles: &[(usize, usize)]) {
        let l = p.lonum;
        let cols = p.inner.cols();
        let data = p.inner.data();
        let inv_elems = 1.0f32 / (l * l) as f32;
        for &(ti, tj) in tiles {
            let mut acc = 0.0f64;
            let mut nnz = 0usize;
            for r in 0..l {
                let row = &data[(ti * l + r) * cols + tj * l..][..l];
                for &x in row {
                    acc += (x as f64) * (x as f64);
                    nnz += (x.abs() > DENSITY_FLOOR) as usize;
                }
            }
            self.norms[(ti, tj)] = acc.sqrt() as f32;
            self.density[(ti, tj)] = nnz as f32 * inv_elems;
        }
    }

    /// Reassemble a map from separately materialized norm and density
    /// matrices — the warm-store restore path.  Validates that the two
    /// grids agree and that every value is in its legal range (norms
    /// finite and non-negative, densities in [0, 1]); a corrupt payload
    /// must fail here rather than poison the scheduler.
    pub fn from_parts(norms: Matrix, density: Matrix) -> crate::error::Result<NormMap> {
        if norms.rows() != density.rows() || norms.cols() != density.cols() {
            return Err(crate::error::Error::Store(format!(
                "normmap grids disagree: norms {}x{}, density {}x{}",
                norms.rows(),
                norms.cols(),
                density.rows(),
                density.cols()
            )));
        }
        if norms.data().iter().any(|&x| !x.is_finite() || x < 0.0) {
            return Err(crate::error::Error::Store(
                "normmap holds a negative or non-finite norm".into(),
            ));
        }
        if density.data().iter().any(|&x| !(0.0..=1.0).contains(&x)) {
            return Err(crate::error::Error::Store(
                "normmap density outside [0, 1]".into(),
            ));
        }
        Ok(NormMap { norms, density })
    }
}

/// Minimum bimodality gap for [`auto_density_threshold`]: if no pair of
/// adjacent sorted densities is separated by at least this much, the
/// census is considered unimodal and auto mode disables format routing
/// (returns 0.0) rather than split a continuum arbitrarily.
pub const AUTO_THRESHOLD_MIN_GAP: f32 = 0.25;

/// Derive a density threshold from the operands' density histograms
/// instead of a hand-tuned knob: sort the combined per-tile densities,
/// find the largest gap between adjacent values, and return its midpoint
/// when the gap is at least [`AUTO_THRESHOLD_MIN_GAP`] (a clearly bimodal
/// census — e.g. decayed tiles near 0 vs gaussian tiles near 1).
/// Unimodal censuses return 0.0, which disables adaptive routing — the
/// conservative all-dense behavior.  Deterministic: a pure function of
/// the two density maps, so the resolved value (and with it the
/// schedule-cache key) is stable across calls for the same operand pair.
pub fn auto_density_threshold(na: &NormMap, nb: &NormMap) -> f32 {
    let mut ds: Vec<f32> = na
        .density
        .data()
        .iter()
        .chain(nb.density.data().iter())
        .copied()
        .collect();
    if ds.len() < 2 {
        return 0.0;
    }
    ds.sort_by(f32::total_cmp);
    let mut best_gap = 0.0f32;
    let mut best_mid = 0.0f32;
    for w in ds.windows(2) {
        let gap = w[1] - w[0];
        if gap > best_gap {
            best_gap = gap;
            best_mid = w[0] + 0.5 * gap;
        }
    }
    if best_gap < AUTO_THRESHOLD_MIN_GAP {
        0.0
    } else {
        best_mid.clamp(0.0, 1.0)
    }
}

/// The density threshold a schedule build should use for this operand
/// pair: the configured value, or the histogram-derived one when
/// `--density-threshold auto` is in effect.  Explicit values (including
/// the default 0) bypass the histogram entirely — exact legacy behavior.
pub fn resolve_density_threshold(cfg: &SpammConfig, na: &NormMap, nb: &NormMap) -> f32 {
    if cfg.density_threshold_auto {
        auto_density_threshold(na, nb)
    } else {
        cfg.density_threshold
    }
}

/// Frobenius norm of one row-major tile buffer (f64 accumulation, f32
/// result) — the per-tile kernel both [`normmap`] and the expression
/// graph's device-side norm refresh share.  Summation runs in buffer
/// (row-major) order, exactly like [`normmap`]'s inner loop, so a norm
/// computed from a scatter-accumulated output tile is bitwise identical
/// to the host normmap of the same content.
pub fn tile_fnorm(tile: &[f32]) -> f32 {
    let mut acc = 0.0f64;
    for &x in tile {
        acc += (x as f64) * (x as f64);
    }
    acc.sqrt() as f32
}

/// Census twin of [`tile_fnorm`]: the fraction of a row-major tile
/// buffer's entries with `|x| > DENSITY_FLOOR`, computed with the same
/// count-then-scale arithmetic as [`normmap_with_density`] — a census
/// taken from a device-resident tile is bitwise identical to the host
/// census of the same content.
pub fn tile_density(tile: &[f32]) -> f32 {
    let nnz = tile.iter().filter(|x| x.abs() > DENSITY_FLOOR).count();
    nnz as f32 * (1.0f32 / tile.len() as f32)
}

/// normmap[i, j] = ‖tile(i, j)‖_F (f64 accumulation, f32 result — same
/// contract as the kernel, which accumulates the reduce in f32 over ≤128²
/// elements; the difference is below f32 epsilon·k).
pub fn normmap(p: &PaddedMatrix) -> Matrix {
    normmap_with_density(p).norms
}

/// One pass over the padded operand producing both the tile Frobenius
/// norms (bitwise identical to the historical [`normmap`], which now
/// delegates here) and the per-tile density census: the fraction of each
/// tile's `LoNum²` entries with `|x| > DENSITY_FLOOR`.  The census rides
/// the same cache-friendly row traversal the norm pass already pays for,
/// so density is near-free.
pub fn normmap_with_density(p: &PaddedMatrix) -> NormMap {
    let (tr, tc, l) = (p.tile_rows(), p.tile_cols(), p.lonum);
    let cols = p.inner.cols();
    let data = p.inner.data();
    let mut norms = Matrix::zeros(tr, tc);
    let mut density = Matrix::zeros(tr, tc);
    let inv_elems = 1.0f32 / (l * l) as f32;
    for ti in 0..tr {
        for tj in 0..tc {
            let mut acc = 0.0f64;
            let mut nnz = 0usize;
            for r in 0..l {
                let row = &data[(ti * l + r) * cols + tj * l..][..l];
                for &x in row {
                    acc += (x as f64) * (x as f64);
                    nnz += (x.abs() > DENSITY_FLOOR) as usize;
                }
            }
            norms[(ti, tj)] = acc.sqrt() as f32;
            density[(ti, tj)] = nnz as f32 * inv_elems;
        }
    }
    NormMap { norms, density }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_tile_is_full_fnorm() {
        let m = Matrix::randn(32, 32, 1);
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap(&p);
        assert_eq!((nm.rows(), nm.cols()), (1, 1));
        assert!((nm[(0, 0)] as f64 - m.fnorm()).abs() < 1e-3);
    }

    #[test]
    fn sum_of_squares_invariant() {
        let m = Matrix::randn(96, 64, 2);
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap(&p);
        let total: f64 = nm.data().iter().map(|&x| (x as f64).powi(2)).sum();
        assert!((total - m.fnorm().powi(2)).abs() / total < 1e-6);
    }

    #[test]
    fn tile_fnorm_matches_normmap_bitwise() {
        // The device-side refresh path sums in the same order as the host
        // normmap, so the two must agree to the last bit per tile.
        let m = Matrix::randn(96, 64, 5);
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap(&p);
        let mut buf = vec![0.0f32; 32 * 32];
        for ti in 0..p.tile_rows() {
            for tj in 0..p.tile_cols() {
                p.copy_tile(ti, tj, &mut buf);
                assert_eq!(tile_fnorm(&buf).to_bits(), nm[(ti, tj)].to_bits());
            }
        }
    }

    #[test]
    fn density_census_rides_norm_pass() {
        // Half the tile above the floor, half exactly zero.
        let mut m = Matrix::zeros(32, 32);
        for r in 0..16 {
            for c in 0..32 {
                m[(r, c)] = 1.0 + r as f32;
            }
        }
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap_with_density(&p);
        assert_eq!(nm.norms[(0, 0)].to_bits(), normmap(&p)[(0, 0)].to_bits());
        assert!((nm.density[(0, 0)] - 0.5).abs() < 1e-6);
        // Sub-floor magnitudes do not count as nonzero.
        let tiny = Matrix::from_vec(8, 8, vec![DENSITY_FLOOR * 0.5; 64]).unwrap();
        let pt = PaddedMatrix::new(&tiny, 8);
        assert_eq!(normmap_with_density(&pt).density[(0, 0)], 0.0);
    }

    #[test]
    fn dense_like_reports_full_density() {
        let m = Matrix::randn(64, 64, 7);
        let p = PaddedMatrix::new(&m, 32);
        let nm = NormMap::dense_like(normmap(&p));
        assert_eq!((nm.tile_rows(), nm.tile_cols()), (2, 2));
        for ti in 0..2 {
            for tj in 0..2 {
                assert_eq!(nm.density[(ti, tj)], 1.0);
            }
        }
    }

    #[test]
    fn patch_tiles_matches_full_recompute_bitwise() {
        let m0 = Matrix::randn(96, 96, 11);
        let mut m1 = m0.clone();
        // Drift two tiles: (0,1) and (2,2) of the 3x3 grid.
        for r in 0..32 {
            for c in 32..64 {
                m1[(r, c)] += 0.5;
            }
        }
        for r in 64..96 {
            for c in 64..96 {
                m1[(r, c)] = 0.0;
            }
        }
        let p1 = PaddedMatrix::new(&m1, 32);
        let mut patched = normmap_with_density(&PaddedMatrix::new(&m0, 32));
        patched.patch_tiles(&p1, &[(0, 1), (2, 2)]);
        let full = normmap_with_density(&p1);
        for ti in 0..3 {
            for tj in 0..3 {
                assert_eq!(
                    patched.norms[(ti, tj)].to_bits(),
                    full.norms[(ti, tj)].to_bits()
                );
                assert_eq!(
                    patched.density[(ti, tj)].to_bits(),
                    full.density[(ti, tj)].to_bits()
                );
            }
        }
    }

    #[test]
    fn tile_density_matches_census_bitwise() {
        let m = Matrix::decay_exponential(96, 1.0, 0.5, 13);
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap_with_density(&p);
        let mut buf = vec![0.0f32; 32 * 32];
        for ti in 0..p.tile_rows() {
            for tj in 0..p.tile_cols() {
                p.copy_tile(ti, tj, &mut buf);
                assert_eq!(tile_density(&buf).to_bits(), nm.density[(ti, tj)].to_bits());
            }
        }
    }

    #[test]
    fn auto_threshold_splits_bimodal_census() {
        // Bimodal: sparse cluster near 0.1, dense cluster at 1.0.
        let mk = |vals: Vec<f32>| {
            let n = vals.len();
            NormMap {
                norms: Matrix::from_vec(1, n, vec![1.0; n]).unwrap(),
                density: Matrix::from_vec(1, n, vals).unwrap(),
            }
        };
        let na = mk(vec![0.05, 0.08, 1.0, 1.0]);
        let nb = mk(vec![0.1, 1.0, 1.0, 1.0]);
        let t = auto_density_threshold(&na, &nb);
        assert!(t > 0.1 && t < 1.0, "got {t}");
        // Unimodal: everything dense — no split, routing disabled.
        let all_dense = mk(vec![1.0; 4]);
        assert_eq!(auto_density_threshold(&all_dense, &all_dense), 0.0);
        // Explicit config bypasses the histogram.
        let mut cfg = SpammConfig {
            density_threshold: 0.3,
            ..SpammConfig::default()
        };
        assert_eq!(resolve_density_threshold(&cfg, &na, &nb), 0.3);
        cfg.density_threshold_auto = true;
        assert_eq!(resolve_density_threshold(&cfg, &na, &nb), t);
    }

    #[test]
    fn padded_region_contributes_zero() {
        let m = Matrix::randn(40, 40, 3);
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap(&p);
        assert_eq!((nm.rows(), nm.cols()), (2, 2));
        // the (1,1) tile is 8x8 real data + zero padding
        let mut acc = 0.0f64;
        for r in 32..40 {
            for c in 32..40 {
                acc += (m[(r, c)] as f64).powi(2);
            }
        }
        assert!((nm[(1, 1)] as f64 - acc.sqrt()).abs() < 1e-4);
    }
}
