//! # cuspamm — Sparse Approximate Matrix Multiplication, reproduced
//!
//! A Rust + JAX + Pallas reproduction of *"Accelerating Sparse Approximate
//! Matrix Multiplication on GPUs"* (cuSpAMM, Liu et al., 2021).
//!
//! The system is a three-layer stack:
//!
//! * **Layer 1 (build time)** — Pallas kernels (`python/compile/kernels/`):
//!   the paper's *get-norm* and *multiplication* kernels, plus a batched
//!   tile-GEMM used by the coordinator's compacted schedule.
//! * **Layer 2 (build time)** — JAX graphs (`python/compile/model.py`)
//!   AOT-lowered to HLO text artifacts (`make artifacts`).
//! * **Layer 3 (request path, this crate)** — the coordinator: artifact
//!   loading and execution over PJRT ([`runtime`]), SpAMM scheduling and
//!   tuning ([`spamm`]), multi-device orchestration ([`coordinator`]), and
//!   every substrate the evaluation needs ([`matrix`], [`sparse`], ...).
//!
//! Python never runs on the request path; after `make artifacts` the Rust
//! binary is self-contained.  Without a python/JAX toolchain the vendored
//! offline PJRT simulator executes synthesized *hostsim* bundles
//! ([`runtime::hostsim`]) with the same manifest schema and numeric
//! contract, so the full request path stays testable.
//!
//! ## Execution pipeline, caching & residency
//!
//! The execution layer is stage-pipelined, cache-aware, and keeps
//! operand tiles device-resident:
//!
//! * **Norm/schedule caches** ([`spamm::cache`]) — normmaps are memoized
//!   keyed on a 128-bit content fingerprint of the padded operand
//!   (dims + LoNum + data bits); compacted schedules are memoized keyed
//!   on both operand fingerprints plus the exact τ bits.  Iterative
//!   workloads (`spamm::power`, `spamm::purification`, repeated service
//!   requests) skip the get-norm and schedule phases entirely on hits.
//!   Hit/miss counts surface in [`spamm::MultiplyStats`] and the global
//!   [`telemetry`] counters (`spamm.norm_cache.*`,
//!   `spamm.schedule_cache.*`); `--no-cache` (CLI) or
//!   `cache_enabled = false` (config) bypasses both caches.
//! * **Tile residency** ([`runtime::residency`]) — each device owns a
//!   pool of resident operand tiles keyed on content fingerprint + tile
//!   coordinate (the paper's §3.3 A-block reuse).  The gather stage
//!   resolves refcounted handles; only pool misses transfer bytes, a
//!   tile referenced k times in one chunk is staged once, and warm
//!   operands (power chains, purification, repeated service calls) skip
//!   phase-3 transfers entirely.  LRU eviction under
//!   `device_mem_budget`; pinned (in-flight) tiles are never evicted.
//!   `--no-residency` disables the pools.
//! * **Stage overlap** ([`spamm::executor::execute_batches`]) — chunk
//!   execution is double-buffered: a transfer worker stages chunk *i+1*
//!   while the engine thread (which owns the non-`Send` PJRT client)
//!   runs tile-GEMM on chunk *i*, and a scatter worker drains finished
//!   products from a bounded channel.  Coordinator device workers
//!   stream all P pipeline batches through one pipeline (no per-batch
//!   join), overlapping batch *i+1*'s uploads with batch *i*'s compute.
//!   `--pipeline-depth` / the `pipeline_depth` config key bound the
//!   in-flight chunks.  With overlap,
//!   `gather_secs + exec_secs + scatter_secs` exceeds the
//!   `exec_span_secs` wall clock in [`spamm::MultiplyStats`].
//!
//! Both the single-device [`spamm::SpammEngine`] and the multi-device
//! [`coordinator::Coordinator`] (whose per-device workers share the same
//! executor, each with its own residency pool) go through this path.
//!
//! ## Serving sessions
//!
//! The request-path API is [`coordinator::SpammSession`]: **register**
//! operands once, **prepare** plans once, **execute** cheaply many
//! times.  A session's operand store deduplicates by content
//! fingerprint (refcounted, byte-budgeted LRU), `prepare` resolves τ
//! (tuner for valid-ratio targets) and pins the compacted schedule, and
//! a background worker — owning the coordinator plus, single-device, a
//! long-lived runtime with persistent compiled executables — drains a
//! priority queue asynchronously.  Warm requests skip get-norm,
//! scheduling, τ tuning, operand upload, and compilation entirely.
//! The old `SpammService` (submit whole matrices per call, blocking
//! FIFO drain) is deprecated and now a thin shim over the session.
//!
//! ## Incremental operands
//!
//! Iterative workloads — SCF cycles, MD steps — re-run the *same* plan
//! against an operand that drifted in a few tiles.
//! [`coordinator::SpammSession::update`] charges only the delta: the
//! content fingerprint is patched incrementally
//! ([`spamm::cache::fingerprint_patch`]), changed tiles re-upload while
//! unchanged resident tiles (dense and still-valid packed payloads)
//! re-key with zero transfer (stale packed variants of changed tiles
//! are dropped), the [`spamm::NormMap`] norms + density census are
//! recomputed for the touched tiles only, and cached schedules are
//! *repaired* in the affected rows/columns ([`spamm::Schedule::repair`])
//! instead of rebuilt — bitwise identical to a cold rebuild at the same
//! τ/threshold.  Prepared plans referencing the operand migrate (pins
//! included) and their next submit runs warm:
//!
//! ```no_run
//! use cuspamm::prelude::*;
//!
//! let bundle = ArtifactBundle::load("artifacts").unwrap();
//! let session = SpammSession::new(&bundle, SpammConfig::default()).unwrap();
//! let density = Matrix::decay_algebraic(1024, 0.1, 0.1, 7);
//! let p = session.put(&density).unwrap();
//! let plan = session.prepare(p, p, Approx::Tau(1e-4)).unwrap();
//! session.wait(session.submit(plan).unwrap()).unwrap(); // cold SCF step
//!
//! // Next SCF step: two tiles drifted — patch them, don't re-put.
//! let changed = [(0, 1), (2, 2)];
//! let blocks = vec![0.0f32; changed.len() * 32 * 32]; // new tile contents
//! let rep = session.update(p, &changed, &blocks).unwrap();
//! assert_eq!(rep.norm_tiles_patched, rep.tiles_changed);
//! let warm = session.wait(session.submit(plan).unwrap()).unwrap(); // delta cost
//! println!("{} tiles uploaded, {} schedules repaired", rep.uploaded_tiles, rep.schedules_repaired);
//! # let _ = warm;
//! ```
//!
//! [`coordinator::Coordinator::update_operand`] is the session-free
//! twin; `cuspamm update --smoke` is the CI gate asserting delta
//! uploads ≥5x cheaper than re-put and bitwise identity with the cold
//! rebuild.
//!
//! ## Warm-start store
//!
//! The caches above are in-memory: a restarted process pays the full
//! cold path on request one.  [`store::WarmStore`] (`store_dir` config /
//! `--store-dir` CLI) adds a content-addressed on-disk tier behind the
//! same caches, persisting all four artifact kinds — normmaps (keyed on
//! the operand fingerprint), compacted schedules (both fingerprints +
//! exact τ and density-threshold bits), tuned τ results (fingerprints +
//! target and tuner-parameter bits), and frozen synthesized hostsim
//! bundles (synthesis spec).  Restores are bitwise (f32s round-trip as
//! raw bit patterns), every load is re-validated (schema version, kind,
//! size, 128-bit checksum, payload-internal shape consistency), and any
//! mismatch falls back cold and evicts the entry — the store can make a
//! run *warm*, never *wrong*.  Saves are write-behind and crash-safe
//! (temp file + atomic rename); an incremental update
//! ([`coordinator::SpammSession::update`]) re-persists the patched
//! normmap and repaired schedule under the new fingerprint.  Per-job restore counts surface as
//! [`spamm::MultiplyStats`]`::store_*_hits` (a store hit is neither a
//! cache hit nor a recompute) plus `tau_tuned`; global counters land in
//! [`telemetry`] under `spamm.store.*`.  `--no-store`
//! (`store_enabled = false`) is the kill switch, `cuspamm store
//! ls|gc|verify` administers a store directory (byte-budgeted
//! LRU-by-mtime GC), and `cuspamm warmstart --smoke` asserts the
//! restart-to-warm contract end to end in CI.
//!
//! ## Expression graphs
//!
//! Iterated workloads — matrix powers (§4.3.1), McWeeny purification —
//! chain products, and a `multiply`-per-step driver round-trips every
//! intermediate through the host.  [`coordinator::expr::ExprGraph`]
//! turns the whole chain into **one prepared plan** with
//! device-resident intermediates:
//!
//! ```text
//!  host:    A ──put/prepare──┐                         ┌──► C = A⁴ (one download)
//!                            ▼                         │
//!  device:  [A tiles]──spamm──►[A² tiles]──spamm──►[A³ tiles]──spamm──►[A⁴]
//!            pool hit          derived fp ▲            │ freed when last
//!                              + exact norms at scatter┘ consumer retires
//! ```
//!
//! A spamm node's output tiles scatter straight into the
//! [`runtime::residency::ResidencyPool`] under a *derived* fingerprint
//! (hash of input fingerprints + op + τ), the consuming node gathers
//! them with zero transfer bytes, and step *k+1*'s schedule is built
//! without pulling step *k* to host: norm upper bounds propagate
//! through the graph at prepare; exact norms refresh lazily from the
//! resident output tiles (device-side get-norm) only when τ-pruning
//! needs them.  `axpby`/`scale`/`add_diag` run as tiled device ops, so
//! purification's 3P²−2P³ never leaves the pool, and `diff_fnorm`
//! probes convergence device-side.  The expression path is **bitwise
//! identical** to the loop path at the same τ.
//!
//! Migrating a power/purify loop:
//!
//! ```no_run
//! use cuspamm::prelude::*;
//!
//! let bundle = ArtifactBundle::load("artifacts").unwrap();
//! let coord = Coordinator::new(&bundle, SpammConfig::default()).unwrap();
//! let a = Matrix::decay_algebraic(1024, 0.1, 0.1, 7);
//!
//! // Before: one multiply per step (A² and A³ bounce through host).
//! // let c2 = coord.multiply(&a, &a, 1e-4).unwrap().c;
//! // let c3 = coord.multiply(&c2, &a, 1e-4).unwrap().c;
//! // let c4 = coord.multiply(&c3, &a, 1e-4).unwrap().c;
//!
//! // After: one graph, intermediates stay on device.
//! let mut g = ExprGraph::new();
//! let leaf = g.operand();
//! let c2 = g.spamm(leaf, leaf, Approx::Tau(1e-4));
//! let c3 = g.spamm(c2, leaf, Approx::Tau(1e-4));
//! let c4 = g.spamm(c3, leaf, Approx::Tau(1e-4));
//! g.output(c4);
//! let plan = coord.prepare_expr(&g, &[ExprSource::Host(&a)]).unwrap();
//! let rep = coord.execute_expr(&plan).unwrap();
//! println!("‖A⁴‖_F = {} ({} B uploaded)", rep.to_matrix().fnorm(), rep.stats.transfer_bytes);
//! ```
//!
//! `spamm::power::spamm_power` and `spamm::purification::mcweeny_purify`
//! are thin builders over this API (their `*_loop` twins keep the old
//! driver as the A/B baseline), sessions queue whole graphs via
//! `SpammSession::prepare_expr`/`submit_expr` (one ticket per graph,
//! per-node stats on the completion), and the `power`/`purify` CLI
//! subcommands expose `--expr` vs `--loop`.
//!
//! ## Tile formats & mixed-precision paths
//!
//! τ-culling picks *which* tile products run; the density-adaptive
//! format selector picks *how*.  [`spamm::normmap`]'s pass performs a
//! per-tile density census alongside the norms
//! ([`spamm::NormMap`]`{ norms, density }`), and
//! [`spamm::Schedule::build_adaptive`] tags each surviving product with
//! a [`spamm::TileStrategy`]: `Dense` (classic batched tile-GEMM),
//! `Sparse` (both operand tiles strictly below `density_threshold`:
//! staged as a COO payload via [`sparse::pack_tile`] — bitwise
//! invertible at a zero floor — so pools store and account compressed
//! bytes, the savings reported as
//! [`spamm::MultiplyStats`]`::format_saved_bytes`), and `Packed` (runs
//! of ≥2 consecutive sparse products fused into one wider `sptile`
//! dispatch, counted by `sparse_dispatches`).  Selection is
//! schedule-driven, so the format mix is partition-independent;
//! `density_threshold = 0` (the default) disables routing and is
//! bitwise identical to the classic executor on every path
//! (`tests/multidevice.rs`).  Expression-graph leaves carry the census;
//! computed intermediates and propagated bounds are density-unknown and
//! conservatively stay dense.  Schedule-cache keys include the
//! threshold bits.  bf16 precision applies to dense tile uploads only —
//! sparse payloads keep exact f32 indices — so the two axes compose.
//!
//! ## Multi-device
//!
//! `devices = M` is a first-class path for every API: multiplies,
//! prepared session plans, and expression graphs all partition output
//! tiles across M device workers.  Tile ownership is exclusive and
//! per-tile accumulation order is schedule-fixed, so every placement is
//! **bitwise identical** — placement moves time and bytes, never bits.
//! Three `balance` policies: `rowblock`, `strided:<s>` (§3.5.1), and
//! `residency-aware`, which models communication per partition: tiles
//! whose A/B operand tiles are already resident in a device's
//! [`runtime::residency::ResidencyPool`] stay on that device (probed
//! via `ResidencyPool::resident_bytes_of` — warm devices keep their
//! tiles), the rest fill greedily by load with transfer bytes as the
//! tie-break under each device's `device_mem_budget`.  Expression plans
//! carry per-node tile→device maps ([`coordinator::expr::ExprGraph`]
//! `::prepare_placed`); each device scatters its owned node-output
//! tiles into its own pool, and cross-device consumption bounces
//! through a host mirror, reported as
//! `MultiplyStats::cross_device_bytes`.  [`coordinator::MultiDeviceReport`]
//! adds per-device transferred/resident/cross bytes and the imbalance
//! metric; the `coordinate` CLI subcommand prints the per-device table
//! and `coordinate --smoke` asserts the warm-pool ≥2x transfer cut vs
//! `rowblock` in CI.
//!
//! ## Serving over the wire
//!
//! The [`serve`] module exposes the whole session lifecycle over TCP —
//! a [`ServeServer`](serve::ServeServer) owns one resident
//! [`SpammSession`](coordinator::SpammSession) (and its persistent
//! per-device worker runtimes) and any number of tenants drive it with
//! the framed protocol in [`serve::proto`]:
//!
//! | offset | size | field |
//! |---|---|---|
//! | 0 | 4 | magic `0x4353_4E50` ("CSNP", little-endian) |
//! | 4 | 2 | protocol version (currently 1) |
//! | 6 | 1 | frame kind tag |
//! | 7 | 1 | reserved (0) |
//! | 8 | 4 | payload length (≤ 64 MiB, checked before allocation) |
//!
//! The payload is compact JSON ([`json`]); matrix data crosses as
//! IEEE-754 bit-pattern hex, so remote products are **bitwise** equal to
//! in-process execution.  Admission is multi-tenant: per-client
//! store-bytes (`--client-store-budget`) and inflight-submit depth
//! (`--client-queue-depth`) budgets shed with typed `QuotaExceeded`
//! replies, global queue saturation sheds with `Busy`, and a shed never
//! drops the connection.  Concurrent same-plan submits coalesce into
//! one device dispatch, and completed products land in a result cache
//! keyed on the plan's derived fingerprint — a warm re-submit is
//! answered with zero device work, and incremental updates invalidate
//! only the cached products their schedule repair actually changed
//! (`--no-result-cache` disables the cache bitwise-inertly).
//! `cuspamm serve-net --smoke` drives server + clients in-process as
//! the CI gate.
//!
//! ## Static analysis & invariants
//!
//! Every fast path above (schedule repair, normmap patching, pool
//! re-keying, warm-store restores) must preserve structural invariants
//! the end-to-end bitwise tests only observe indirectly.  The [`audit`]
//! module re-derives those invariants from first principles and verifies
//! the artifacts **without executing**:
//!
//! | Invariant | Owning layer | Checker |
//! |---|---|---|
//! | Culling: survivor ⇔ ‖A_ik‖·‖B_kj‖ ≥ τ (inclusive) | [`spamm::Schedule`] | [`audit::audit_schedule`] |
//! | Strategy tags match the density census; packed runs are consecutive ≥ 2 | [`spamm::Schedule`] | [`audit::audit_schedule`] |
//! | Every output tile owned by exactly one in-range device | `spamm::balance` | [`audit::audit_assignment`] |
//! | Intermediates freed at last consumer; no use-after-free | [`coordinator::expr`] | [`audit::audit_expr_plan`] |
//! | Derived fingerprints unique; dataflow acyclic; placement maps cover the grid | [`coordinator::expr`] | [`audit::audit_expr_plan`] |
//! | Pool byte counter = Σ resident payload bytes; pins belong to live plans | [`runtime::residency`] | [`audit::audit_pool`] |
//! | Store manifest ↔ object agreement (schema, size, checksum) | [`store`] | [`audit::audit_store`] |
//!
//! The checkers are deliberately *independent reimplementations* — they
//! never call `Schedule::build`/`repair`, so a builder bug cannot hide
//! from them.  Under `cfg(debug_assertions)` the session and coordinator
//! run them at the end of every `prepare`/`submit`/`update` (the whole
//! test suite doubles as an audit fuzzer); release builds compile the
//! hooks out entirely.  On demand: `cuspamm audit plan|session|store`
//! re-audits artifacts in a release binary, and `cuspamm audit --smoke`
//! runs the multiply/serve/expr/update/warmstart smoke workloads plus
//! seeded corruption detection as the CI gate.
//!
//! ## Quick start
//!
//! The serving lifecycle — put → prepare → submit → wait:
//!
//! ```no_run
//! use cuspamm::prelude::*;
//!
//! let bundle = ArtifactBundle::load("artifacts").unwrap();
//! let session = SpammSession::new(&bundle, SpammConfig::default()).unwrap();
//!
//! // Register operands once (content-deduplicated, refcounted).
//! let a = session.put(&Matrix::decay_algebraic(1024, 0.1, 0.1, 7)).unwrap();
//! let b = session.put(&Matrix::decay_algebraic(1024, 0.1, 0.1, 8)).unwrap();
//!
//! // Prepare once: τ tuned for a 10% valid ratio, schedule compacted
//! // and pinned, operand tiles pinned in the device pools.
//! let plan = session.prepare(a, b, Approx::ValidRatio(0.10)).unwrap();
//!
//! // Execute many times — warm requests ride the caches and the
//! // resident runtime.  Completions arrive out of order, by ticket.
//! let tickets: Vec<Ticket> =
//!     (0..8).map(|_| session.submit_with(plan, Priority::High).unwrap()).collect();
//! for t in tickets {
//!     let done = session.wait(t).unwrap();
//!     println!("‖C‖_F = {} in {:.4}s", done.c.fnorm(), done.compute_secs);
//! }
//! ```
//!
//! For one-shot library use the [`spamm::SpammEngine`] remains:
//!
//! ```no_run
//! use cuspamm::prelude::*;
//!
//! let bundle = ArtifactBundle::load("artifacts").unwrap();
//! let engine = SpammEngine::new(&bundle, SpammConfig::default()).unwrap();
//! let a = Matrix::decay_algebraic(1024, 0.1, 0.1, 7);
//! let b = Matrix::decay_algebraic(1024, 0.1, 0.1, 8);
//! let tuned = engine.tune_tau(&a, &b, 0.10).unwrap(); // 10% valid ratio
//! let c = engine.multiply(&a, &b, tuned.tau).unwrap();
//! println!("‖C‖_F = {}", c.fnorm());
//! ```

#![forbid(unsafe_code)]

pub mod audit;
pub mod bench_harness;
pub mod cli;
pub mod cnn;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod json;
pub mod matrix;
pub mod proptest;
pub mod runtime;
pub mod serve;
pub mod spamm;
pub mod sparse;
pub mod store;
pub mod telemetry;
pub mod util;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::config::SpammConfig;
    pub use crate::coordinator::{
        Approx, Completion, Coordinator, ExprGraph, ExprPlanId, ExprReport, ExprSource,
        ExprTicket, ExprValue, MultiDeviceReport, OperandId, PlanId, Priority, SpammSession,
        Ticket, UpdateReport,
    };
    pub use crate::error::{Error, Result};
    pub use crate::matrix::Matrix;
    pub use crate::runtime::{ArtifactBundle, Runtime};
    pub use crate::serve::{
        PutOutcome, RemoteApprox, RemoteCompletion, ServeClient, ServeServer, SubmitOutcome,
    };
    pub use crate::spamm::{SpammEngine, TuneResult};
    pub use crate::sparse::CsrMatrix;
    pub use crate::store::WarmStore;
}
