//! Mini property-testing framework (proptest is not in the offline crate
//! set): seeded case generation with failure reporting and linear input
//! shrinking for numeric parameter tuples.

use crate::util::prng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig {
            cases: 64,
            seed: 0xC0FFEE,
        }
    }
}

/// Run `prop` on `cases` generated inputs; panic with the seed and case
/// index of the first failure (reproducible: the generator is seeded).
pub fn forall<T, G, P>(cfg: PropConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property failed at case {case}/{} (seed {:#x}): input = {input:?}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Like `forall` but the property returns Result with a message.
pub fn forall_ok<T, G, P>(cfg: PropConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{} (seed {:#x}): {msg}\n  input = {input:?}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::prng::Rng;

    /// usize in [lo, hi].
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    /// f32 in [lo, hi).
    pub fn f32_in(rng: &mut Rng, lo: f32, hi: f32) -> f32 {
        rng.range_f32(lo, hi)
    }

    /// Power of two in [lo, hi] (both powers of two).
    pub fn pow2_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        let lo_exp = lo.trailing_zeros();
        let hi_exp = hi.trailing_zeros();
        1 << usize_in(rng, lo_exp as usize, hi_exp as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            PropConfig::default(),
            |rng| gen::usize_in(rng, 1, 100),
            |&x| x >= 1 && x <= 100,
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        forall(
            PropConfig { cases: 50, seed: 1 },
            |rng| gen::usize_in(rng, 0, 10),
            |&x| x < 9,
        );
    }

    #[test]
    fn pow2_generator_in_range() {
        let mut rng = crate::util::prng::Rng::new(3);
        for _ in 0..100 {
            let x = gen::pow2_in(&mut rng, 8, 64);
            assert!(x.is_power_of_two() && (8..=64).contains(&x));
        }
    }
}
