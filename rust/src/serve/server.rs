//! The serving tier's TCP server: one resident [`SpammSession`] behind a
//! framed wire protocol, multi-tenant admission quotas, plan-aware
//! batching, and the fingerprint-keyed result cache.
//!
//! Request lifecycle (mirroring the in-process session API): `hello` →
//! `put` → `prepare` → `submit` → `wait`, with `update` / `release` /
//! `release-plan` / `stats` interleaved freely.  Admission control is
//! per-tenant (the `hello` client name): a store-bytes budget gates
//! `put`, an inflight-submit depth gates `submit`, and both shed with a
//! *typed* reply ([`FrameKind::QuotaExceeded`]) on the open connection —
//! the server never drops a connection to shed load.  Saturation of the
//! session's global admission queue sheds as [`FrameKind::Busy`].
//!
//! Same-plan submits racing through the server coalesce: the first
//! becomes the *leader* (it occupies the session queue and reports
//! `executed = true`), later ones attach as followers and are answered
//! from the leader's completion (`executed = false`).  Completed
//! products land in the [`ResultCache`] keyed on
//! `derive("serve.result", [fa, fb], [τ, density])`; a warm re-submit is
//! answered at admission with zero device work.  Incremental operand
//! updates invalidate *only* the cached products a schedule repair
//! actually changed — untouched entries migrate to their post-update
//! keys (see [`ServeServer`]'s update handling).

use std::collections::{BTreeMap, HashMap};
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::config::SpammConfig;
use crate::coordinator::{Approx, OperandId, PlanId, Priority, SpammSession, Ticket};
use crate::error::{Error, Result};
use crate::json::Value;
use crate::matrix::Matrix;
use crate::runtime::ArtifactBundle;
use crate::serve::cache::{result_key, CachedResult, ResultCache};
use crate::serve::proto::{self, Frame, FrameKind};
use crate::spamm::cache::Fingerprint;
use crate::spamm::schedule::Schedule;
use crate::telemetry;

/// Result-cache capacity when enabled (entries, FIFO-evicted).
const RESULT_CACHE_CAPACITY: usize = 256;

/// Per-connection read poll interval — bounds shutdown latency while a
/// client is idle (reads retry on timeout until the stop flag is set).
const READ_POLL: Duration = Duration::from_millis(50);

#[derive(Default)]
struct Tenant {
    store_bytes: usize,
    inflight: usize,
}

struct OpEntry {
    id: OperandId,
    bytes: usize,
    tenant: String,
}

struct PlanMeta {
    id: PlanId,
    a: OperandId,
    b: OperandId,
    key: Fingerprint,
    tenant: String,
}

/// One completed served product, shareable across batched waiters.
#[derive(Clone)]
struct ServedResult {
    c: Matrix,
    tau: f32,
    valid_ratio: f64,
    compute_secs: f64,
    compiles: u64,
}

/// In-flight same-plan batch: the leader holds the session ticket, all
/// waiters rendezvous on the condvar.
struct Batch {
    key: Fingerprint,
    state: Mutex<BatchState>,
    cv: Condvar,
}

#[derive(Default)]
struct BatchState {
    /// Present until a waiter claims the blocking `session.wait`.
    session_ticket: Option<Ticket>,
    done: Option<std::result::Result<ServedResult, String>>,
}

enum TicketState {
    /// Answered from the result cache at submit time.
    Cached(CachedResult),
    Pending {
        batch: Arc<Batch>,
        leader: bool,
        tenant: String,
    },
}

#[derive(Default)]
struct Counters {
    requests: AtomicU64,
    executed: AtomicU64,
    batched: AtomicU64,
    shed_busy: AtomicU64,
    shed_quota: AtomicU64,
}

struct Inner {
    session: SpammSession,
    cfg: SpammConfig,
    cache: Mutex<ResultCache>,
    tenants: Mutex<HashMap<String, Tenant>>,
    ops: Mutex<HashMap<u64, OpEntry>>,
    plans: Mutex<HashMap<u64, PlanMeta>>,
    tickets: Mutex<HashMap<u64, TicketState>>,
    pending: Mutex<HashMap<Fingerprint, Arc<Batch>>>,
    next_op: AtomicU64,
    next_plan: AtomicU64,
    next_ticket: AtomicU64,
    counters: Counters,
}

/// The network serving tier.  Owns one [`SpammSession`] (and through it
/// the persistent per-device worker runtimes) and serves any number of
/// concurrent framed-protocol connections.
pub struct ServeServer {
    inner: Arc<Inner>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl ServeServer {
    /// Build the session and start accepting on `addr` (use
    /// `"127.0.0.1:0"` for an ephemeral test port).
    pub fn start(bundle: &ArtifactBundle, cfg: SpammConfig, addr: &str) -> Result<ServeServer> {
        let session = SpammSession::new(bundle, cfg.clone())?;
        let capacity = if cfg.result_cache_enabled {
            RESULT_CACHE_CAPACITY
        } else {
            0
        };
        let inner = Arc::new(Inner {
            session,
            cfg,
            cache: Mutex::new(ResultCache::new(capacity)),
            tenants: Mutex::new(HashMap::new()),
            ops: Mutex::new(HashMap::new()),
            plans: Mutex::new(HashMap::new()),
            tickets: Mutex::new(HashMap::new()),
            pending: Mutex::new(HashMap::new()),
            next_op: AtomicU64::new(1),
            next_plan: AtomicU64::new(1),
            next_ticket: AtomicU64::new(1),
            counters: Counters::default(),
        });
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let inner = inner.clone();
            let stop = stop.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("spamm-serve-accept".into())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        let Ok(stream) = incoming else { continue };
                        let inner = inner.clone();
                        let stop = stop.clone();
                        let handle = std::thread::Builder::new()
                            .name("spamm-serve-conn".into())
                            .spawn(move || serve_connection(inner, stream, stop));
                        if let Ok(h) = handle {
                            conns.lock().unwrap().push(h);
                        }
                    }
                })?
        };
        Ok(ServeServer {
            inner,
            addr: local,
            stop,
            accept: Some(accept),
            conns,
        })
    }

    /// The bound address (for clients to connect to).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Direct access to the underlying session (in-process comparisons).
    pub fn session(&self) -> &SpammSession {
        &self.inner.session
    }

    /// Stop accepting, drain connection threads, and shut down.
    pub fn shutdown(mut self) {
        self.stop_threads();
    }

    fn stop_threads(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let handles: Vec<_> = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for ServeServer {
    fn drop(&mut self) {
        self.stop_threads();
    }
}

// ---------------------------------------------------------------------
// connection loop
// ---------------------------------------------------------------------

enum Fill {
    Full,
    Eof(usize),
    Stopped,
}

/// Read exactly `buf.len()` bytes, retrying on poll timeouts until the
/// stop flag is raised (so shutdown never waits on an idle client).
fn fill(stream: &mut TcpStream, buf: &mut [u8], stop: &AtomicBool) -> Result<Fill> {
    let mut filled = 0;
    while filled < buf.len() {
        match stream.read(&mut buf[filled..]) {
            Ok(0) => return Ok(Fill::Eof(filled)),
            Ok(n) => filled += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if stop.load(Ordering::Relaxed) {
                    return Ok(Fill::Stopped);
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::Protocol(format!("connection read failed: {e}"))),
        }
    }
    Ok(Fill::Full)
}

fn serve_connection(inner: Arc<Inner>, mut stream: TcpStream, stop: Arc<AtomicBool>) {
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let _ = stream.set_nodelay(true);
    // The tenant this connection authenticated as (via `hello`).
    let mut tenant: Option<String> = None;
    loop {
        let frame = match read_request(&mut stream, &stop) {
            Ok(Some(f)) => f,
            Ok(None) => break,
            Err(e) => {
                // Framing is lost on a corrupt stream: answer with a
                // typed error, then close (resync is impossible).
                let _ = send(&mut stream, FrameKind::ErrorReply, &[(
                    "message",
                    Value::String(e.to_string()),
                )]);
                break;
            }
        };
        inner.counters.requests.fetch_add(1, Ordering::Relaxed);
        telemetry::global().add("serve.requests", 1);
        let reply = dispatch(&inner, &mut tenant, &frame);
        let (kind, payload) = match reply {
            Ok(r) => r,
            Err(e) => (
                FrameKind::ErrorReply,
                object(&[("message", Value::String(e.to_string()))]),
            ),
        };
        if proto::write_frame(&mut stream, kind, &payload).is_err() {
            break;
        }
    }
}

fn read_request(stream: &mut TcpStream, stop: &AtomicBool) -> Result<Option<Frame>> {
    let mut header = [0u8; proto::HEADER_LEN];
    match fill(stream, &mut header, stop)? {
        Fill::Eof(0) | Fill::Stopped => return Ok(None),
        Fill::Eof(n) => {
            return Err(Error::Protocol(format!(
                "truncated frame header: got {n} of {} bytes",
                proto::HEADER_LEN
            )))
        }
        Fill::Full => {}
    }
    let (kind, len) = proto::decode_header(&header)?;
    let mut body = vec![0u8; len];
    match fill(stream, &mut body, stop)? {
        Fill::Full => {}
        Fill::Stopped => return Ok(None),
        Fill::Eof(n) => {
            return Err(Error::Protocol(format!(
                "truncated frame payload: got {n} of {len} bytes"
            )))
        }
    }
    let text = std::str::from_utf8(&body)
        .map_err(|_| Error::Protocol("frame payload is not UTF-8".into()))?;
    let payload = Value::parse(text)
        .map_err(|e| Error::Protocol(format!("unparseable frame payload: {e}")))?;
    Ok(Some(Frame { kind, payload }))
}

fn send(stream: &mut TcpStream, kind: FrameKind, fields: &[(&str, Value)]) -> Result<()> {
    proto::write_frame(stream, kind, &object(fields))
}

fn object(fields: &[(&str, Value)]) -> Value {
    let mut m = BTreeMap::new();
    for (k, v) in fields {
        m.insert((*k).to_string(), v.clone());
    }
    Value::Object(m)
}

fn num(x: u64) -> Value {
    Value::Number(x as f64)
}

// ---------------------------------------------------------------------
// request dispatch
// ---------------------------------------------------------------------

type Reply = (FrameKind, Value);

fn dispatch(inner: &Inner, tenant: &mut Option<String>, frame: &Frame) -> Result<Reply> {
    if frame.kind == FrameKind::Hello {
        return handle_hello(inner, tenant, &frame.payload);
    }
    let who = tenant
        .clone()
        .ok_or_else(|| Error::Protocol("hello required before other requests".into()))?;
    match frame.kind {
        FrameKind::Put => handle_put(inner, &who, &frame.payload),
        FrameKind::Prepare => handle_prepare(inner, &who, &frame.payload),
        FrameKind::Submit => handle_submit(inner, &who, &frame.payload),
        FrameKind::Wait => handle_wait(inner, &who, &frame.payload),
        FrameKind::Update => handle_update(inner, &who, &frame.payload),
        FrameKind::Release => handle_release(inner, &who, &frame.payload),
        FrameKind::ReleasePlan => handle_release_plan(inner, &who, &frame.payload),
        FrameKind::Stats => handle_stats(inner),
        other => Err(Error::Protocol(format!(
            "unexpected frame kind {other:?} in a request position"
        ))),
    }
}

fn handle_hello(inner: &Inner, tenant: &mut Option<String>, p: &Value) -> Result<Reply> {
    let client = proto::get_str(p, "client")?;
    if client.is_empty() {
        return Err(Error::Protocol("hello: empty client name".into()));
    }
    inner
        .tenants
        .lock()
        .unwrap()
        .entry(client.to_string())
        .or_default();
    *tenant = Some(client.to_string());
    Ok((
        FrameKind::HelloOk,
        object(&[
            ("version", num(proto::VERSION as u64)),
            ("devices", num(inner.cfg.devices as u64)),
            ("lonum", num(inner.cfg.lonum as u64)),
        ]),
    ))
}

fn handle_put(inner: &Inner, who: &str, p: &Value) -> Result<Reply> {
    let rows = proto::get_u64(p, "rows")? as usize;
    let cols = proto::get_u64(p, "cols")? as usize;
    let data = proto::decode_f32s(proto::get_str(p, "data")?)?;
    let m = Matrix::from_vec(rows, cols, data)?;
    let bytes = rows * cols * 4;
    // Admission: the tenant's logical store budget (charged per put,
    // refunded per release; session-level content dedup is invisible to
    // the quota — admission accounts what the tenant asked to store).
    let budget = inner.cfg.client_store_budget;
    {
        let mut tenants = inner.tenants.lock().unwrap();
        let t = tenants.entry(who.to_string()).or_default();
        if budget > 0 && t.store_bytes.saturating_add(bytes) > budget {
            inner.counters.shed_quota.fetch_add(1, Ordering::Relaxed);
            telemetry::global().add("serve.shed_quota", 1);
            return Ok((
                FrameKind::QuotaExceeded,
                object(&[(
                    "message",
                    Value::String(format!(
                        "store budget exceeded: {} + {} > {} bytes",
                        t.store_bytes, bytes, budget
                    )),
                )]),
            ));
        }
        t.store_bytes += bytes;
    }
    let id = match inner.session.put(&m) {
        Ok(id) => id,
        Err(e) => {
            let mut tenants = inner.tenants.lock().unwrap();
            if let Some(t) = tenants.get_mut(who) {
                t.store_bytes = t.store_bytes.saturating_sub(bytes);
            }
            return Err(e);
        }
    };
    let wire = inner.next_op.fetch_add(1, Ordering::Relaxed);
    inner.ops.lock().unwrap().insert(
        wire,
        OpEntry {
            id,
            bytes,
            tenant: who.to_string(),
        },
    );
    Ok((FrameKind::PutOk, object(&[("op", num(wire))])))
}

fn lookup_op(inner: &Inner, who: &str, wire: u64) -> Result<OperandId> {
    let ops = inner.ops.lock().unwrap();
    let e = ops
        .get(&wire)
        .ok_or_else(|| Error::Session(format!("operand {wire} not registered")))?;
    if e.tenant != who {
        return Err(Error::Session(format!(
            "operand {wire} belongs to another tenant"
        )));
    }
    Ok(e.id)
}

fn handle_prepare(inner: &Inner, who: &str, p: &Value) -> Result<Reply> {
    let a = lookup_op(inner, who, proto::get_u64(p, "a")?)?;
    let b = lookup_op(inner, who, proto::get_u64(p, "b")?)?;
    let approx = match proto::get_str(p, "approx")? {
        "tau" => Approx::Tau(proto::get_f64(p, "value")? as f32),
        "valid_ratio" => Approx::ValidRatio(proto::get_f64(p, "value")?),
        other => {
            return Err(Error::Protocol(format!(
                "unknown approx mode '{other}' (tau | valid_ratio)"
            )))
        }
    };
    let plan = inner.session.prepare(a, b, approx)?;
    let (tau, rows, cols) = inner.session.plan_info(plan)?;
    let (fa, fb) = inner.session.plan_fingerprints(plan)?;
    let (_, _, density) = inner.session.plan_schedule(plan)?;
    let key = result_key(fa, fb, tau, density);
    let wire = inner.next_plan.fetch_add(1, Ordering::Relaxed);
    inner.plans.lock().unwrap().insert(
        wire,
        PlanMeta {
            id: plan,
            a,
            b,
            key,
            tenant: who.to_string(),
        },
    );
    Ok((
        FrameKind::PrepareOk,
        object(&[
            ("plan", num(wire)),
            ("tau", Value::Number(tau as f64)),
            ("rows", num(rows as u64)),
            ("cols", num(cols as u64)),
        ]),
    ))
}

fn handle_submit(inner: &Inner, who: &str, p: &Value) -> Result<Reply> {
    let wire_plan = proto::get_u64(p, "plan")?;
    let priority = match p.get_opt("priority") {
        Some(v) => Priority::parse(v.as_str()?)?,
        None => Priority::default(),
    };
    let (plan_id, key) = {
        let plans = inner.plans.lock().unwrap();
        let meta = plans
            .get(&wire_plan)
            .ok_or_else(|| Error::Session(format!("plan {wire_plan} not prepared")))?;
        if meta.tenant != who {
            return Err(Error::Session(format!(
                "plan {wire_plan} belongs to another tenant"
            )));
        }
        (meta.id, meta.key)
    };
    // Result cache first: a warm hit costs no quota, no queue slot, no
    // device work.
    if let Some(hit) = inner.cache.lock().unwrap().get(&key).cloned() {
        telemetry::global().add("serve.result_cache_hits", 1);
        let ticket = inner.next_ticket.fetch_add(1, Ordering::Relaxed);
        inner
            .tickets
            .lock()
            .unwrap()
            .insert(ticket, TicketState::Cached(hit));
        return Ok((
            FrameKind::SubmitOk,
            object(&[("ticket", num(ticket)), ("cached", Value::Bool(true))]),
        ));
    }
    // Per-tenant inflight depth (0 = unlimited).
    let depth = inner.cfg.client_queue_depth;
    {
        let mut tenants = inner.tenants.lock().unwrap();
        let t = tenants.entry(who.to_string()).or_default();
        if depth > 0 && t.inflight >= depth {
            inner.counters.shed_quota.fetch_add(1, Ordering::Relaxed);
            telemetry::global().add("serve.shed_quota", 1);
            return Ok((
                FrameKind::QuotaExceeded,
                object(&[(
                    "message",
                    Value::String(format!(
                        "inflight budget exceeded: {} submits outstanding, depth {depth}",
                        t.inflight
                    )),
                )]),
            ));
        }
        t.inflight += 1;
    }
    // Plan-aware batching: coalesce with an in-flight submit of the same
    // result key, else lead a new batch.  The pending map is held across
    // the session submit so racing same-key submits coalesce
    // deterministically instead of double-dispatching.
    let mut pending = inner.pending.lock().unwrap();
    let (batch, leader) = if let Some(b) = pending.get(&key) {
        inner.counters.batched.fetch_add(1, Ordering::Relaxed);
        telemetry::global().add("serve.batched", 1);
        (b.clone(), false)
    } else {
        match inner.session.submit_with(plan_id, priority) {
            Ok(t) => {
                let b = Arc::new(Batch {
                    key,
                    state: Mutex::new(BatchState {
                        session_ticket: Some(t),
                        done: None,
                    }),
                    cv: Condvar::new(),
                });
                pending.insert(key, b.clone());
                inner.counters.executed.fetch_add(1, Ordering::Relaxed);
                telemetry::global().add("serve.executed", 1);
                (b, true)
            }
            Err(e) => {
                drop(pending);
                tenant_dec_inflight(inner, who);
                return match e {
                    Error::Session(m) if m.contains("admission queue full") => {
                        inner.counters.shed_busy.fetch_add(1, Ordering::Relaxed);
                        telemetry::global().add("serve.shed_busy", 1);
                        Ok((
                            FrameKind::Busy,
                            object(&[("message", Value::String(m))]),
                        ))
                    }
                    other => Err(other),
                };
            }
        }
    };
    drop(pending);
    let ticket = inner.next_ticket.fetch_add(1, Ordering::Relaxed);
    inner.tickets.lock().unwrap().insert(
        ticket,
        TicketState::Pending {
            batch,
            leader,
            tenant: who.to_string(),
        },
    );
    Ok((
        FrameKind::SubmitOk,
        object(&[("ticket", num(ticket)), ("cached", Value::Bool(false))]),
    ))
}

fn tenant_dec_inflight(inner: &Inner, who: &str) {
    let mut tenants = inner.tenants.lock().unwrap();
    if let Some(t) = tenants.get_mut(who) {
        t.inflight = t.inflight.saturating_sub(1);
    }
}

fn handle_wait(inner: &Inner, who: &str, p: &Value) -> Result<Reply> {
    let wire = proto::get_u64(p, "ticket")?;
    let state = inner
        .tickets
        .lock()
        .unwrap()
        .remove(&wire)
        .ok_or_else(|| Error::Session(format!("ticket {wire} unknown or already redeemed")))?;
    match state {
        TicketState::Cached(hit) => Ok(result_reply(
            &ServedResult {
                c: hit.c,
                tau: hit.tau,
                valid_ratio: hit.valid_ratio,
                compute_secs: 0.0,
                compiles: 0,
            },
            false,
        )),
        TicketState::Pending {
            batch,
            leader,
            tenant,
        } => {
            if tenant != who {
                // Put it back: the ticket is not this tenant's to redeem.
                inner.tickets.lock().unwrap().insert(
                    wire,
                    TicketState::Pending {
                        batch,
                        leader,
                        tenant,
                    },
                );
                return Err(Error::Session(format!(
                    "ticket {wire} belongs to another tenant"
                )));
            }
            let served = wait_batch(inner, &batch);
            tenant_dec_inflight(inner, who);
            match served {
                Ok(r) => Ok(result_reply(&r, leader)),
                Err(m) => Err(Error::Session(m)),
            }
        }
    }
}

/// Rendezvous on a batch: the first waiter claims the blocking session
/// wait and publishes the completion; everyone else parks on the condvar.
fn wait_batch(inner: &Inner, batch: &Arc<Batch>) -> std::result::Result<ServedResult, String> {
    let claimed = {
        let mut st = batch.state.lock().unwrap();
        loop {
            if let Some(done) = &st.done {
                return done.clone();
            }
            if let Some(t) = st.session_ticket.take() {
                break t;
            }
            st = batch.cv.wait(st).unwrap();
        }
    };
    let outcome = inner.session.wait(claimed).map(|c| ServedResult {
        c: c.c,
        tau: c.tau,
        valid_ratio: c.valid_ratio,
        compute_secs: c.compute_secs,
        compiles: c.stats.compiles,
    });
    // Publish to the cache and retire the pending entry *before* waking
    // the batch, so a re-submit after any waiter returns sees the cache.
    if let Ok(r) = &outcome {
        inner.cache.lock().unwrap().insert(
            batch.key,
            CachedResult {
                c: r.c.clone(),
                tau: r.tau,
                valid_ratio: r.valid_ratio,
            },
        );
    }
    {
        let mut pending = inner.pending.lock().unwrap();
        if let Some(cur) = pending.get(&batch.key) {
            if Arc::ptr_eq(cur, batch) {
                pending.remove(&batch.key);
            }
        }
    }
    let shared = outcome.map_err(|e| e.to_string());
    let mut st = batch.state.lock().unwrap();
    st.done = Some(shared.clone());
    batch.cv.notify_all();
    shared
}

fn result_reply(r: &ServedResult, executed: bool) -> Reply {
    (
        FrameKind::ResultOk,
        object(&[
            ("rows", num(r.c.rows() as u64)),
            ("cols", num(r.c.cols() as u64)),
            ("data", Value::String(proto::encode_f32s(r.c.data()))),
            ("tau", Value::Number(r.tau as f64)),
            ("valid_ratio", Value::Number(r.valid_ratio)),
            ("executed", Value::Bool(executed)),
            ("compute_secs", Value::Number(r.compute_secs)),
            ("compiles", num(r.compiles)),
        ]),
    )
}

fn handle_update(inner: &Inner, who: &str, p: &Value) -> Result<Reply> {
    let wire_op = proto::get_u64(p, "op")?;
    let op = lookup_op(inner, who, wire_op)?;
    let tiles_v = p.get("tiles")?.as_array()?;
    let mut changed = Vec::with_capacity(tiles_v.len());
    for t in tiles_v {
        let pair = t.as_array()?;
        if pair.len() != 2 {
            return Err(Error::Protocol("update: tile entries are [ti, tj] pairs".into()));
        }
        changed.push((pair[0].as_usize()?, pair[1].as_usize()?));
    }
    let data = proto::decode_f32s(proto::get_str(p, "data")?)?;
    // Capture the schedules the affected plans executed *before* the
    // update — repair-aware invalidation needs both sides of the repair.
    struct Affected {
        wire: u64,
        plan: PlanId,
        is_a: bool,
        is_b: bool,
        old_key: Fingerprint,
        old_sched: Option<Arc<Schedule>>,
    }
    let mut affected: Vec<Affected> = {
        let plans = inner.plans.lock().unwrap();
        plans
            .iter()
            .filter(|(_, m)| m.a == op || m.b == op)
            .map(|(w, m)| Affected {
                wire: *w,
                plan: m.id,
                is_a: m.a == op,
                is_b: m.b == op,
                old_key: m.key,
                old_sched: None,
            })
            .collect()
    };
    for a in &mut affected {
        a.old_sched = inner.session.plan_schedule(a.plan).ok().map(|(s, _, _)| s);
    }
    let report = inner.session.update(op, &changed, &data)?;
    // Repair-aware result-cache maintenance: a cached product is dirty
    // iff a changed tile feeds a surviving product of the old *or* the
    // repaired schedule (removed products change the sum too); clean
    // entries migrate to the post-update key with their bits intact.
    let mut invalidated = 0u64;
    let mut rekeyed = 0u64;
    for a in &affected {
        let Ok((new_sched, tau, density)) = inner.session.plan_schedule(a.plan) else {
            continue;
        };
        let Ok((fa, fb)) = inner.session.plan_fingerprints(a.plan) else {
            continue;
        };
        let new_key = result_key(fa, fb, tau, density);
        let touched = |s: &Schedule| {
            changed.iter().any(|&(ti, tj)| {
                (a.is_a && s.touches_a_tile(ti, tj)) || (a.is_b && s.touches_b_tile(ti, tj))
            })
        };
        let dirty =
            a.old_sched.as_deref().map(&touched).unwrap_or(true) || touched(new_sched.as_ref());
        {
            let mut cache = inner.cache.lock().unwrap();
            if dirty {
                cache.invalidate(&a.old_key);
                invalidated += 1;
            } else {
                cache.rekey(&a.old_key, new_key);
                rekeyed += 1;
            }
        }
        if let Some(meta) = inner.plans.lock().unwrap().get_mut(&a.wire) {
            meta.key = new_key;
        }
    }
    Ok((
        FrameKind::UpdateOk,
        object(&[
            ("tiles_changed", num(report.tiles_changed as u64)),
            ("norm_patched", Value::Bool(report.norm_patched)),
            ("schedules_repaired", num(report.schedules_repaired as u64)),
            ("products_added", num(report.products_added as u64)),
            ("products_removed", num(report.products_removed as u64)),
            ("plans_migrated", num(report.plans_migrated as u64)),
            ("invalidated", num(invalidated)),
            ("rekeyed", num(rekeyed)),
        ]),
    ))
}

fn handle_release(inner: &Inner, who: &str, p: &Value) -> Result<Reply> {
    let wire = proto::get_u64(p, "op")?;
    let entry = {
        let mut ops = inner.ops.lock().unwrap();
        let owned = ops
            .get(&wire)
            .map(|e| e.tenant == who)
            .ok_or_else(|| Error::Session(format!("operand {wire} not registered")))?;
        if !owned {
            return Err(Error::Session(format!(
                "operand {wire} belongs to another tenant"
            )));
        }
        ops.remove(&wire).expect("entry exists under the lock")
    };
    inner.session.release(entry.id)?;
    let mut tenants = inner.tenants.lock().unwrap();
    if let Some(t) = tenants.get_mut(who) {
        t.store_bytes = t.store_bytes.saturating_sub(entry.bytes);
    }
    Ok((FrameKind::ReleaseOk, object(&[("op", num(wire))])))
}

fn handle_release_plan(inner: &Inner, who: &str, p: &Value) -> Result<Reply> {
    let wire = proto::get_u64(p, "plan")?;
    let meta = {
        let mut plans = inner.plans.lock().unwrap();
        let owned = plans
            .get(&wire)
            .map(|m| m.tenant == who)
            .ok_or_else(|| Error::Session(format!("plan {wire} not prepared")))?;
        if !owned {
            return Err(Error::Session(format!(
                "plan {wire} belongs to another tenant"
            )));
        }
        plans.remove(&wire).expect("entry exists under the lock")
    };
    inner.session.release_plan(meta.id)?;
    Ok((FrameKind::ReleaseOk, object(&[("plan", num(wire))])))
}

fn handle_stats(inner: &Inner) -> Result<Reply> {
    let store = inner.session.store_stats();
    let cache = inner.cache.lock().unwrap();
    let c = &inner.counters;
    Ok((
        FrameKind::StatsOk,
        object(&[
            ("requests", num(c.requests.load(Ordering::Relaxed))),
            ("executed", num(c.executed.load(Ordering::Relaxed))),
            ("batched", num(c.batched.load(Ordering::Relaxed))),
            ("shed_busy", num(c.shed_busy.load(Ordering::Relaxed))),
            ("shed_quota", num(c.shed_quota.load(Ordering::Relaxed))),
            ("result_cache_hits", num(cache.hits())),
            ("result_cache_misses", num(cache.misses())),
            ("result_cache_invalidations", num(cache.invalidations())),
            ("result_cache_rekeys", num(cache.rekeys())),
            ("result_cache_len", num(cache.len() as u64)),
            ("store_puts", num(store.puts)),
            ("store_dedup_hits", num(store.dedup_hits)),
            ("store_resident_bytes", num(store.resident_bytes)),
        ]),
    ))
}
