//! Fingerprint-keyed result cache for the serving tier.
//!
//! A served multiply is pure in its plan identity: the operand content
//! fingerprints, the executed τ, and the density threshold determine the
//! product bitwise (the pipeline's tile products are deterministic for
//! fixed inputs).  The cache keys on
//! `Fingerprint::derive("serve.result", [fa, fb], [τ, density])` so a
//! re-submitted warm plan is answered from the host without touching a
//! device — and, because [`crate::coordinator::SpammSession::update`]
//! migrates plan fingerprints, entries survive *clean* incremental
//! updates by re-keying (see the server's repair-aware invalidation).
//!
//! Bounded FIFO by insertion order: the serving tier's hot set is the
//! Zipf head of repeated plans, and a stale entry costs only a re-execute.

use std::collections::{HashMap, VecDeque};

use crate::matrix::Matrix;
use crate::spamm::cache::Fingerprint;

/// A cached served product.
#[derive(Clone, Debug)]
pub struct CachedResult {
    pub c: Matrix,
    pub tau: f32,
    pub valid_ratio: f64,
}

/// Derive the result-cache key of a prepared plan.
pub fn result_key(fa: Fingerprint, fb: Fingerprint, tau: f32, density: f32) -> Fingerprint {
    Fingerprint::derive("serve.result", &[fa, fb], &[tau, density])
}

/// Capacity-bounded result cache with typed hit/miss counters.
#[derive(Debug)]
pub struct ResultCache {
    entries: HashMap<Fingerprint, CachedResult>,
    order: VecDeque<Fingerprint>,
    capacity: usize,
    hits: u64,
    misses: u64,
    invalidations: u64,
    rekeys: u64,
}

impl ResultCache {
    /// `capacity` = 0 disables caching entirely (every lookup misses,
    /// every insert is dropped) — the `--no-result-cache` kill switch.
    pub fn new(capacity: usize) -> ResultCache {
        ResultCache {
            entries: HashMap::new(),
            order: VecDeque::new(),
            capacity,
            hits: 0,
            misses: 0,
            invalidations: 0,
            rekeys: 0,
        }
    }

    pub fn get(&mut self, key: &Fingerprint) -> Option<&CachedResult> {
        match self.entries.get(key) {
            Some(r) => {
                self.hits += 1;
                Some(r)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn insert(&mut self, key: Fingerprint, result: CachedResult) {
        if self.capacity == 0 {
            return;
        }
        if self.entries.insert(key, result).is_none() {
            self.order.push_back(key);
        }
        while self.entries.len() > self.capacity {
            let Some(victim) = self.order.pop_front() else {
                break;
            };
            self.entries.remove(&victim);
        }
    }

    /// Drop an entry whose product a repair actually changed.
    pub fn invalidate(&mut self, key: &Fingerprint) {
        if self.entries.remove(key).is_some() {
            self.invalidations += 1;
            self.order.retain(|k| k != key);
        }
    }

    /// Migrate an entry untouched by a repair to its post-update key.
    pub fn rekey(&mut self, old: &Fingerprint, new: Fingerprint) {
        if old == &new {
            return;
        }
        if let Some(r) = self.entries.remove(old) {
            self.rekeys += 1;
            self.order.retain(|k| k != old);
            self.insert(new, r);
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn hits(&self) -> u64 {
        self.hits
    }

    pub fn misses(&self) -> u64 {
        self.misses
    }

    pub fn invalidations(&self) -> u64 {
        self.invalidations
    }

    pub fn rekeys(&self) -> u64 {
        self.rekeys
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(seed: u64) -> CachedResult {
        CachedResult {
            c: Matrix::randn(2, 2, seed),
            tau: 0.5,
            valid_ratio: 1.0,
        }
    }

    fn key(i: f32) -> Fingerprint {
        Fingerprint::derive("test", &[], &[i])
    }

    #[test]
    fn fifo_eviction_and_counters() {
        let mut c = ResultCache::new(2);
        c.insert(key(1.0), entry(1));
        c.insert(key(2.0), entry(2));
        c.insert(key(3.0), entry(3));
        assert_eq!(c.len(), 2);
        assert!(c.get(&key(1.0)).is_none());
        assert!(c.get(&key(3.0)).is_some());
        assert_eq!((c.hits(), c.misses()), (1, 1));
    }

    #[test]
    fn zero_capacity_is_inert() {
        let mut c = ResultCache::new(0);
        c.insert(key(1.0), entry(1));
        assert!(c.is_empty());
        assert!(c.get(&key(1.0)).is_none());
    }

    #[test]
    fn rekey_preserves_content() {
        let mut c = ResultCache::new(4);
        c.insert(key(1.0), entry(7));
        c.rekey(&key(1.0), key(2.0));
        assert!(c.get(&key(1.0)).is_none());
        let got = c.get(&key(2.0)).unwrap();
        assert_eq!(got.c, Matrix::randn(2, 2, 7));
        assert_eq!(c.rekeys(), 1);
        c.invalidate(&key(2.0));
        assert!(c.is_empty());
        assert_eq!(c.invalidations(), 1);
    }
}
