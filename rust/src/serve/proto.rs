//! Framed wire protocol for the network serving tier.
//!
//! Every message on the wire is one *frame*: a fixed 12-byte header
//! followed by a JSON payload (the in-tree [`crate::json`] substrate —
//! serde is not in the offline crate set).  The header mirrors the
//! object-header discipline of [`crate::store`]: magic, schema version,
//! and a kind tag are checked *before* any payload byte is trusted, and
//! the declared length is bounds-checked before allocation.
//!
//! ```text
//! offset  size  field
//! 0       4     magic   0x4353_4E50  ("CSNP", little-endian)
//! 4       2     version 1            (little-endian)
//! 6       1     kind    FrameKind tag
//! 7       1     reserved (must be 0)
//! 8       4     payload length in bytes (little-endian, ≤ 64 MiB)
//! 12      len   payload: UTF-8 JSON
//! ```
//!
//! Decoding failures are *typed*: a truncated header or payload, a wrong
//! magic, an unsupported version, an unknown kind tag, an oversized
//! length prefix, or an unparseable payload each surface as
//! [`Error::Protocol`] — never a panic, never an unbounded read.

use std::io::{Read, Write};

use crate::error::{Error, Result};
use crate::json::Value;

/// Frame magic: `"CSNP"` (cuSpAMM Network Protocol) as little-endian u32.
pub const MAGIC: u32 = 0x4353_4E50;

/// Wire schema version.  Bumped on any header or payload-shape change;
/// a server rejects frames from a different version with a typed error.
pub const VERSION: u16 = 1;

/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 12;

/// Hard ceiling on a frame payload.  The length prefix is validated
/// against this *before* the payload buffer is allocated, so a hostile
/// or corrupt length cannot trigger an outsized allocation.
pub const MAX_PAYLOAD: u32 = 64 * 1024 * 1024;

/// Message kind.  Requests use the low tag space, replies the high
/// space (bit 7 set), and shedding/error replies the 0xE0 block.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FrameKind {
    /// Client handshake: names the tenant; must precede other requests.
    Hello,
    /// Register an operand matrix.
    Put,
    /// Prepare a multiply plan over two registered operands.
    Prepare,
    /// Submit a prepared plan for execution.
    Submit,
    /// Block for a submitted ticket's result.
    Wait,
    /// Delta-update a registered operand's tiles.
    Update,
    /// Drop one reference to a registered operand.
    Release,
    /// Drop one reference to a prepared plan.
    ReleasePlan,
    /// Server + session counters snapshot.
    Stats,
    /// Reply to [`FrameKind::Hello`].
    HelloOk,
    /// Reply to [`FrameKind::Put`].
    PutOk,
    /// Reply to [`FrameKind::Prepare`].
    PrepareOk,
    /// Reply to [`FrameKind::Submit`]: the ticket was admitted.
    SubmitOk,
    /// Reply to [`FrameKind::Wait`]: the product matrix.
    ResultOk,
    /// Reply to [`FrameKind::Update`]: the incremental-update receipt.
    UpdateOk,
    /// Reply to [`FrameKind::Release`] / [`FrameKind::ReleasePlan`].
    ReleaseOk,
    /// Reply to [`FrameKind::Stats`].
    StatsOk,
    /// Request failed; the connection stays usable.
    ErrorReply,
    /// Graceful shed: the admission queue is saturated.  Not an error —
    /// the client may retry; the connection stays open.
    Busy,
    /// Graceful shed: the request would exceed the tenant's budget.
    QuotaExceeded,
}

impl FrameKind {
    /// The on-wire tag byte.
    pub fn to_tag(self) -> u8 {
        match self {
            FrameKind::Hello => 0x01,
            FrameKind::Put => 0x02,
            FrameKind::Prepare => 0x03,
            FrameKind::Submit => 0x04,
            FrameKind::Wait => 0x05,
            FrameKind::Update => 0x06,
            FrameKind::Release => 0x07,
            FrameKind::ReleasePlan => 0x08,
            FrameKind::Stats => 0x09,
            FrameKind::HelloOk => 0x81,
            FrameKind::PutOk => 0x82,
            FrameKind::PrepareOk => 0x83,
            FrameKind::SubmitOk => 0x84,
            FrameKind::ResultOk => 0x85,
            FrameKind::UpdateOk => 0x86,
            FrameKind::ReleaseOk => 0x87,
            FrameKind::StatsOk => 0x88,
            FrameKind::ErrorReply => 0xE0,
            FrameKind::Busy => 0xE1,
            FrameKind::QuotaExceeded => 0xE2,
        }
    }

    /// Decode a tag byte; unknown tags are a typed protocol error.
    pub fn from_tag(tag: u8) -> Result<FrameKind> {
        Ok(match tag {
            0x01 => FrameKind::Hello,
            0x02 => FrameKind::Put,
            0x03 => FrameKind::Prepare,
            0x04 => FrameKind::Submit,
            0x05 => FrameKind::Wait,
            0x06 => FrameKind::Update,
            0x07 => FrameKind::Release,
            0x08 => FrameKind::ReleasePlan,
            0x09 => FrameKind::Stats,
            0x81 => FrameKind::HelloOk,
            0x82 => FrameKind::PutOk,
            0x83 => FrameKind::PrepareOk,
            0x84 => FrameKind::SubmitOk,
            0x85 => FrameKind::ResultOk,
            0x86 => FrameKind::UpdateOk,
            0x87 => FrameKind::ReleaseOk,
            0x88 => FrameKind::StatsOk,
            0xE0 => FrameKind::ErrorReply,
            0xE1 => FrameKind::Busy,
            0xE2 => FrameKind::QuotaExceeded,
            _ => {
                return Err(Error::Protocol(format!(
                    "unknown frame kind tag 0x{tag:02x}"
                )))
            }
        })
    }

    /// Every kind, for conformance sweeps.
    pub fn all() -> &'static [FrameKind] {
        &[
            FrameKind::Hello,
            FrameKind::Put,
            FrameKind::Prepare,
            FrameKind::Submit,
            FrameKind::Wait,
            FrameKind::Update,
            FrameKind::Release,
            FrameKind::ReleasePlan,
            FrameKind::Stats,
            FrameKind::HelloOk,
            FrameKind::PutOk,
            FrameKind::PrepareOk,
            FrameKind::SubmitOk,
            FrameKind::ResultOk,
            FrameKind::UpdateOk,
            FrameKind::ReleaseOk,
            FrameKind::StatsOk,
            FrameKind::ErrorReply,
            FrameKind::Busy,
            FrameKind::QuotaExceeded,
        ]
    }
}

/// One decoded frame.
#[derive(Clone, Debug, PartialEq)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Value,
}

/// Encode a frame into a byte buffer (header + compact JSON payload).
pub fn encode_frame(kind: FrameKind, payload: &Value) -> Result<Vec<u8>> {
    let body = payload.to_json().into_bytes();
    if body.len() > MAX_PAYLOAD as usize {
        return Err(Error::Protocol(format!(
            "payload of {} bytes exceeds the {} byte frame ceiling",
            body.len(),
            MAX_PAYLOAD
        )));
    }
    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(kind.to_tag());
    out.push(0);
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    Ok(out)
}

/// Write one frame to a stream.
pub fn write_frame<W: Write>(w: &mut W, kind: FrameKind, payload: &Value) -> Result<()> {
    let bytes = encode_frame(kind, payload)?;
    w.write_all(&bytes)?;
    w.flush()?;
    Ok(())
}

/// Validate a 12-byte header; returns `(kind, payload_len)`.
pub fn decode_header(h: &[u8; HEADER_LEN]) -> Result<(FrameKind, usize)> {
    let magic = u32::from_le_bytes([h[0], h[1], h[2], h[3]]);
    if magic != MAGIC {
        return Err(Error::Protocol(format!(
            "bad frame magic 0x{magic:08x} (want 0x{MAGIC:08x})"
        )));
    }
    let version = u16::from_le_bytes([h[4], h[5]]);
    if version != VERSION {
        return Err(Error::Protocol(format!(
            "unsupported protocol version {version} (want {VERSION})"
        )));
    }
    let kind = FrameKind::from_tag(h[6])?;
    if h[7] != 0 {
        return Err(Error::Protocol(format!(
            "non-zero reserved header byte 0x{:02x}",
            h[7]
        )));
    }
    let len = u32::from_le_bytes([h[8], h[9], h[10], h[11]]);
    if len > MAX_PAYLOAD {
        return Err(Error::Protocol(format!(
            "frame length {len} exceeds the {MAX_PAYLOAD} byte ceiling"
        )));
    }
    Ok((kind, len as usize))
}

/// Read exactly `buf.len()` bytes, mapping any short read to a typed
/// protocol error (`what` names the part that truncated).
fn read_exact_proto<R: Read>(r: &mut R, buf: &mut [u8], what: &str) -> Result<()> {
    let mut filled = 0;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(Error::Protocol(format!(
                    "truncated {what}: got {filled} of {} bytes",
                    buf.len()
                )))
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(Error::Protocol(format!("read failed mid-{what}: {e}"))),
        }
    }
    Ok(())
}

/// Read one frame.  A clean end-of-stream *at a frame boundary* returns
/// `Ok(None)` (the peer hung up between messages); any mid-frame
/// truncation or corruption is a typed [`Error::Protocol`].
pub fn try_read_frame<R: Read>(r: &mut R) -> Result<Option<Frame>> {
    let mut header = [0u8; HEADER_LEN];
    // First byte decides between clean EOF and truncation.
    let mut first = 0;
    loop {
        match r.read(&mut header[..1]) {
            Ok(0) => return Ok(None),
            Ok(n) => {
                first = n;
                break;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => {
                return Err(Error::Protocol(format!(
                    "read failed at frame boundary: {e}"
                )))
            }
        }
    }
    debug_assert_eq!(first, 1);
    read_exact_proto(r, &mut header[1..], "frame header")?;
    let (kind, len) = decode_header(&header)?;
    let mut body = vec![0u8; len];
    read_exact_proto(r, &mut body, "frame payload")?;
    let text = std::str::from_utf8(&body)
        .map_err(|_| Error::Protocol("frame payload is not UTF-8".into()))?;
    let payload = Value::parse(text)
        .map_err(|e| Error::Protocol(format!("unparseable frame payload: {e}")))?;
    Ok(Some(Frame { kind, payload }))
}

/// Read one frame, treating end-of-stream as an error (for clients,
/// which always expect a reply).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
    try_read_frame(r)?
        .ok_or_else(|| Error::Protocol("connection closed while awaiting a frame".into()))
}

// ---------------------------------------------------------------------
// f32 payload codec
// ---------------------------------------------------------------------

/// Encode an f32 slice as fixed-width hex of the IEEE-754 bit patterns
/// (8 hex chars per element).  JSON numbers are f64 and cannot round-trip
/// every f32 bit pattern textually; the bit-level codec keeps results
/// bitwise identical across the wire.
pub fn encode_f32s(data: &[f32]) -> String {
    use std::fmt::Write as _;
    let mut s = String::with_capacity(data.len() * 8);
    for x in data {
        let _ = write!(s, "{:08x}", x.to_bits());
    }
    s
}

/// Decode [`encode_f32s`] output; length and digit errors are typed.
pub fn decode_f32s(s: &str) -> Result<Vec<f32>> {
    let b = s.as_bytes();
    if b.len() % 8 != 0 {
        return Err(Error::Protocol(format!(
            "f32 hex payload length {} is not a multiple of 8",
            b.len()
        )));
    }
    let mut out = Vec::with_capacity(b.len() / 8);
    for chunk in b.chunks_exact(8) {
        let text = std::str::from_utf8(chunk)
            .map_err(|_| Error::Protocol("f32 hex payload is not ASCII".into()))?;
        let bits = u32::from_str_radix(text, 16)
            .map_err(|_| Error::Protocol(format!("bad f32 hex chunk '{text}'")))?;
        out.push(f32::from_bits(bits));
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// payload accessors (shared by client and server)
// ---------------------------------------------------------------------

/// Object field as u64 (wire ids are small counters, exact under f64).
pub fn get_u64(v: &Value, key: &str) -> Result<u64> {
    let x = v.get(key)?.as_f64()?;
    if !(0.0..=9.007_199_254_740_992e15).contains(&x) || x.fract() != 0.0 {
        return Err(Error::Protocol(format!(
            "field '{key}' is not an exact non-negative integer: {x}"
        )));
    }
    Ok(x as u64)
}

/// Object field as f64.
pub fn get_f64(v: &Value, key: &str) -> Result<f64> {
    v.get(key)?.as_f64()
}

/// Object field as str.
pub fn get_str<'a>(v: &'a Value, key: &str) -> Result<&'a str> {
    v.get(key)?.as_str()
}

/// Object field as bool.
pub fn get_bool(v: &Value, key: &str) -> Result<bool> {
    match v.get(key)? {
        Value::Bool(b) => Ok(*b),
        other => Err(Error::Protocol(format!(
            "field '{key}' is not a bool: {other:?}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    #[test]
    fn tag_roundtrip_every_kind() {
        for &k in FrameKind::all() {
            assert_eq!(FrameKind::from_tag(k.to_tag()).unwrap(), k);
        }
        assert!(FrameKind::from_tag(0x00).is_err());
        assert!(FrameKind::from_tag(0x7f).is_err());
    }

    #[test]
    fn frame_roundtrip() {
        let mut obj = BTreeMap::new();
        obj.insert("op".into(), Value::Number(7.0));
        let payload = Value::Object(obj);
        let bytes = encode_frame(FrameKind::Put, &payload).unwrap();
        assert_eq!(bytes.len(), HEADER_LEN + payload.to_json().len());
        let f = read_frame(&mut &bytes[..]).unwrap();
        assert_eq!(f.kind, FrameKind::Put);
        assert_eq!(f.payload, payload);
    }

    #[test]
    fn f32_codec_bitwise() {
        let data = vec![0.0f32, -0.0, 1.5, f32::MIN_POSITIVE, -3.25e-12, 1e30];
        let dec = decode_f32s(&encode_f32s(&data)).unwrap();
        assert_eq!(data.len(), dec.len());
        for (a, b) in data.iter().zip(&dec) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(decode_f32s("abc").is_err());
        assert!(decode_f32s("zzzzzzzz").is_err());
    }

    #[test]
    fn oversized_length_rejected_before_alloc() {
        let mut bytes = encode_frame(FrameKind::Stats, &Value::Object(BTreeMap::new())).unwrap();
        bytes[8..12].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        let err = read_frame(&mut &bytes[..]).unwrap_err();
        assert!(matches!(err, Error::Protocol(_)), "{err}");
    }

    #[test]
    fn clean_eof_is_none_truncation_is_error() {
        assert!(try_read_frame(&mut &[][..]).unwrap().is_none());
        let bytes = encode_frame(FrameKind::Stats, &Value::Object(BTreeMap::new())).unwrap();
        for cut in 1..bytes.len() {
            let err = try_read_frame(&mut &bytes[..cut]).unwrap_err();
            assert!(matches!(err, Error::Protocol(_)), "cut={cut}: {err}");
        }
    }
}
