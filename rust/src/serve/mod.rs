//! Network serving tier — the session API over a framed TCP protocol.
//!
//! Serving over the wire:
//!
//! * [`proto`] — the length-prefixed frame codec.  A 12-byte header
//!   (magic `0x4353_4E50`, version, kind tag, payload length — the same
//!   validate-before-trust discipline as [`crate::store`]'s object
//!   headers) frames a compact-JSON payload ([`crate::json`]); f32 data
//!   crosses as IEEE-754 bit-pattern hex so products stay *bitwise*
//!   identical to in-process execution.
//! * [`server`] — [`ServeServer`]: one resident
//!   [`SpammSession`](crate::coordinator::SpammSession) (and its
//!   persistent per-device worker runtimes) behind any number of tenant
//!   connections, with per-tenant store-bytes and inflight-depth quotas
//!   enforced at admission, plan-aware batching of concurrent same-plan
//!   submits, and a fingerprint-keyed result cache with repair-aware
//!   invalidation on incremental updates.
//! * [`cache`] — the [`ResultCache`] keyed on
//!   `derive("serve.result", [fa, fb], [τ, density])`.
//! * [`client`] — [`ServeClient`]: the blocking tenant-side API with
//!   typed shed outcomes (`Busy` / `QuotaExceeded` are values, not
//!   errors; a shed never costs the connection).
//!
//! ```no_run
//! # use cuspamm::serve::{ServeClient, RemoteApprox, SubmitOutcome};
//! # use cuspamm::matrix::Matrix;
//! # fn main() -> cuspamm::error::Result<()> {
//! let mut client = ServeClient::connect("127.0.0.1:7477", "tenant-a")?;
//! let a = match client.put(&Matrix::randn(256, 256, 1))? {
//!     cuspamm::serve::PutOutcome::Ok(id) => id,
//!     cuspamm::serve::PutOutcome::QuotaExceeded(m) => panic!("over budget: {m}"),
//! };
//! let b = match client.put(&Matrix::randn(256, 256, 2))? {
//!     cuspamm::serve::PutOutcome::Ok(id) => id,
//!     cuspamm::serve::PutOutcome::QuotaExceeded(m) => panic!("over budget: {m}"),
//! };
//! let plan = client.prepare(a, b, RemoteApprox::Tau(0.05))?;
//! match client.submit(plan.id)? {
//!     SubmitOutcome::Ticket(t, _cached) => {
//!         let done = client.wait(t)?;
//!         println!("C is {}x{}, executed={}", done.c.rows(), done.c.cols(), done.executed);
//!     }
//!     SubmitOutcome::Busy(m) => println!("shed, retry later: {m}"),
//!     SubmitOutcome::QuotaExceeded(m) => println!("over budget: {m}"),
//! }
//! # Ok(()) }
//! ```

pub mod cache;
pub mod client;
pub mod proto;
pub mod server;

pub use cache::{result_key, CachedResult, ResultCache};
pub use client::{
    PutOutcome, RemoteApprox, RemoteCompletion, RemoteOperandId, RemotePlan, RemotePlanId,
    RemoteStats, RemoteTicket, RemoteUpdateReport, ServeClient, SubmitOutcome,
};
pub use proto::{Frame, FrameKind, MAX_PAYLOAD};
pub use server::ServeServer;
