//! Framed-protocol client for the serving tier — the remote twin of
//! [`crate::coordinator::SpammSession`]'s put → prepare → submit → wait
//! lifecycle.  Shed replies ([`FrameKind::Busy`] /
//! [`FrameKind::QuotaExceeded`]) surface as typed outcome variants, not
//! errors: the connection stays usable and the caller decides whether
//! to retry, back off, or release budget.

use std::net::{TcpStream, ToSocketAddrs};

use crate::error::{Error, Result};
use crate::json::Value;
use crate::matrix::Matrix;
use crate::serve::proto::{self, Frame, FrameKind};

/// Server-issued operand handle (wire id, not the session's internal id).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RemoteOperandId(pub u64);

/// Server-issued plan handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RemotePlanId(pub u64);

/// Server-issued ticket.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RemoteTicket(pub u64);

/// Approximation target for [`ServeClient::prepare`].
#[derive(Clone, Copy, Debug)]
pub enum RemoteApprox {
    Tau(f32),
    ValidRatio(f64),
}

/// A prepared remote plan with its resolved τ and output shape.
#[derive(Clone, Copy, Debug)]
pub struct RemotePlan {
    pub id: RemotePlanId,
    pub tau: f32,
    pub rows: usize,
    pub cols: usize,
}

/// What a `put` request came back as.
#[derive(Clone, Debug)]
pub enum PutOutcome {
    Ok(RemoteOperandId),
    /// Shed at admission: the tenant's store budget is exhausted.
    QuotaExceeded(String),
}

/// What a `submit` request came back as.
#[derive(Clone, Debug)]
pub enum SubmitOutcome {
    /// Admitted.  `cached` means the result cache will answer the wait
    /// without any device work.
    Ticket(RemoteTicket, bool),
    /// Shed: the session's global admission queue is saturated.
    Busy(String),
    /// Shed: the tenant's inflight-submit budget is exhausted.
    QuotaExceeded(String),
}

/// A redeemed result.
#[derive(Clone, Debug)]
pub struct RemoteCompletion {
    pub c: Matrix,
    pub tau: f32,
    pub valid_ratio: f64,
    /// Whether redeeming this ticket dispatched device work (`false`
    /// for result-cache hits and batched followers).
    pub executed: bool,
    pub compute_secs: f64,
    /// Kernel compiles the execution charged (0 on warm paths).
    pub compiles: u64,
}

/// Incremental-update receipt, extended with the server's result-cache
/// maintenance (how many cached products the repair invalidated vs.
/// migrated untouched).
#[derive(Clone, Copy, Debug, Default)]
pub struct RemoteUpdateReport {
    pub tiles_changed: usize,
    pub norm_patched: bool,
    pub schedules_repaired: usize,
    pub products_added: usize,
    pub products_removed: usize,
    pub plans_migrated: usize,
    pub invalidated: u64,
    pub rekeyed: u64,
}

/// Server + session counter snapshot ([`ServeClient::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct RemoteStats {
    pub requests: u64,
    pub executed: u64,
    pub batched: u64,
    pub shed_busy: u64,
    pub shed_quota: u64,
    pub result_cache_hits: u64,
    pub result_cache_misses: u64,
    pub result_cache_invalidations: u64,
    pub result_cache_rekeys: u64,
    pub result_cache_len: u64,
    pub store_puts: u64,
    pub store_dedup_hits: u64,
    pub store_resident_bytes: u64,
}

/// One tenant connection to a [`crate::serve::ServeServer`].
pub struct ServeClient {
    stream: TcpStream,
    /// Server device count (from the hello reply).
    pub devices: usize,
    /// Server tile size — update payloads carry `lonum²` f32 per tile.
    pub lonum: usize,
}

impl ServeClient {
    /// Connect and handshake as tenant `client`.
    pub fn connect<A: ToSocketAddrs>(addr: A, client: &str) -> Result<ServeClient> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        let mut c = ServeClient {
            stream,
            devices: 0,
            lonum: 0,
        };
        let reply = c.call(
            FrameKind::Hello,
            &[("client", Value::String(client.to_string()))],
        )?;
        let p = expect(reply, FrameKind::HelloOk)?;
        let version = proto::get_u64(&p, "version")?;
        if version != proto::VERSION as u64 {
            return Err(Error::Protocol(format!(
                "server speaks protocol version {version}, client wants {}",
                proto::VERSION
            )));
        }
        c.devices = proto::get_u64(&p, "devices")? as usize;
        c.lonum = proto::get_u64(&p, "lonum")? as usize;
        Ok(c)
    }

    fn call(&mut self, kind: FrameKind, fields: &[(&str, Value)]) -> Result<Frame> {
        let mut m = std::collections::BTreeMap::new();
        for (k, v) in fields {
            m.insert((*k).to_string(), v.clone());
        }
        proto::write_frame(&mut self.stream, kind, &Value::Object(m))?;
        proto::read_frame(&mut self.stream)
    }

    /// Register an operand.
    pub fn put(&mut self, m: &Matrix) -> Result<PutOutcome> {
        let reply = self.call(
            FrameKind::Put,
            &[
                ("rows", num(m.rows() as u64)),
                ("cols", num(m.cols() as u64)),
                ("data", Value::String(proto::encode_f32s(m.data()))),
            ],
        )?;
        match reply.kind {
            FrameKind::PutOk => Ok(PutOutcome::Ok(RemoteOperandId(proto::get_u64(
                &reply.payload,
                "op",
            )?))),
            FrameKind::QuotaExceeded => Ok(PutOutcome::QuotaExceeded(message(&reply.payload))),
            _ => Err(unexpected(&reply, FrameKind::PutOk)),
        }
    }

    /// Prepare a multiply plan.
    pub fn prepare(
        &mut self,
        a: RemoteOperandId,
        b: RemoteOperandId,
        approx: RemoteApprox,
    ) -> Result<RemotePlan> {
        let (mode, value) = match approx {
            RemoteApprox::Tau(t) => ("tau", t as f64),
            RemoteApprox::ValidRatio(r) => ("valid_ratio", r),
        };
        let p = expect(
            self.call(
                FrameKind::Prepare,
                &[
                    ("a", num(a.0)),
                    ("b", num(b.0)),
                    ("approx", Value::String(mode.to_string())),
                    ("value", Value::Number(value)),
                ],
            )?,
            FrameKind::PrepareOk,
        )?;
        Ok(RemotePlan {
            id: RemotePlanId(proto::get_u64(&p, "plan")?),
            tau: proto::get_f64(&p, "tau")? as f32,
            rows: proto::get_u64(&p, "rows")? as usize,
            cols: proto::get_u64(&p, "cols")? as usize,
        })
    }

    /// Submit a prepared plan at normal priority.
    pub fn submit(&mut self, plan: RemotePlanId) -> Result<SubmitOutcome> {
        self.submit_with(plan, "normal")
    }

    /// Submit with an explicit priority class (`low | normal | high`).
    pub fn submit_with(&mut self, plan: RemotePlanId, priority: &str) -> Result<SubmitOutcome> {
        let reply = self.call(
            FrameKind::Submit,
            &[
                ("plan", num(plan.0)),
                ("priority", Value::String(priority.to_string())),
            ],
        )?;
        match reply.kind {
            FrameKind::SubmitOk => Ok(SubmitOutcome::Ticket(
                RemoteTicket(proto::get_u64(&reply.payload, "ticket")?),
                proto::get_bool(&reply.payload, "cached")?,
            )),
            FrameKind::Busy => Ok(SubmitOutcome::Busy(message(&reply.payload))),
            FrameKind::QuotaExceeded => Ok(SubmitOutcome::QuotaExceeded(message(&reply.payload))),
            _ => Err(unexpected(&reply, FrameKind::SubmitOk)),
        }
    }

    /// Block for a submitted ticket's product.
    pub fn wait(&mut self, ticket: RemoteTicket) -> Result<RemoteCompletion> {
        let p = expect(
            self.call(FrameKind::Wait, &[("ticket", num(ticket.0))])?,
            FrameKind::ResultOk,
        )?;
        let rows = proto::get_u64(&p, "rows")? as usize;
        let cols = proto::get_u64(&p, "cols")? as usize;
        let data = proto::decode_f32s(proto::get_str(&p, "data")?)?;
        Ok(RemoteCompletion {
            c: Matrix::from_vec(rows, cols, data)?,
            tau: proto::get_f64(&p, "tau")? as f32,
            valid_ratio: proto::get_f64(&p, "valid_ratio")?,
            executed: proto::get_bool(&p, "executed")?,
            compute_secs: proto::get_f64(&p, "compute_secs")?,
            compiles: proto::get_u64(&p, "compiles")?,
        })
    }

    /// Delta-update tiles of a registered operand (`data` holds one
    /// row-major `lonum²` block per entry of `changed`, concatenated).
    pub fn update(
        &mut self,
        op: RemoteOperandId,
        changed: &[(usize, usize)],
        data: &[f32],
    ) -> Result<RemoteUpdateReport> {
        let tiles = Value::Array(
            changed
                .iter()
                .map(|&(ti, tj)| Value::Array(vec![num(ti as u64), num(tj as u64)]))
                .collect(),
        );
        let p = expect(
            self.call(
                FrameKind::Update,
                &[
                    ("op", num(op.0)),
                    ("tiles", tiles),
                    ("data", Value::String(proto::encode_f32s(data))),
                ],
            )?,
            FrameKind::UpdateOk,
        )?;
        Ok(RemoteUpdateReport {
            tiles_changed: proto::get_u64(&p, "tiles_changed")? as usize,
            norm_patched: proto::get_bool(&p, "norm_patched")?,
            schedules_repaired: proto::get_u64(&p, "schedules_repaired")? as usize,
            products_added: proto::get_u64(&p, "products_added")? as usize,
            products_removed: proto::get_u64(&p, "products_removed")? as usize,
            plans_migrated: proto::get_u64(&p, "plans_migrated")? as usize,
            invalidated: proto::get_u64(&p, "invalidated")?,
            rekeyed: proto::get_u64(&p, "rekeyed")?,
        })
    }

    /// Drop one reference to a registered operand.
    pub fn release(&mut self, op: RemoteOperandId) -> Result<()> {
        expect(
            self.call(FrameKind::Release, &[("op", num(op.0))])?,
            FrameKind::ReleaseOk,
        )?;
        Ok(())
    }

    /// Drop one reference to a prepared plan.
    pub fn release_plan(&mut self, plan: RemotePlanId) -> Result<()> {
        expect(
            self.call(FrameKind::ReleasePlan, &[("plan", num(plan.0))])?,
            FrameKind::ReleaseOk,
        )?;
        Ok(())
    }

    /// Server + session counter snapshot.
    pub fn stats(&mut self) -> Result<RemoteStats> {
        let p = expect(self.call(FrameKind::Stats, &[])?, FrameKind::StatsOk)?;
        Ok(RemoteStats {
            requests: proto::get_u64(&p, "requests")?,
            executed: proto::get_u64(&p, "executed")?,
            batched: proto::get_u64(&p, "batched")?,
            shed_busy: proto::get_u64(&p, "shed_busy")?,
            shed_quota: proto::get_u64(&p, "shed_quota")?,
            result_cache_hits: proto::get_u64(&p, "result_cache_hits")?,
            result_cache_misses: proto::get_u64(&p, "result_cache_misses")?,
            result_cache_invalidations: proto::get_u64(&p, "result_cache_invalidations")?,
            result_cache_rekeys: proto::get_u64(&p, "result_cache_rekeys")?,
            result_cache_len: proto::get_u64(&p, "result_cache_len")?,
            store_puts: proto::get_u64(&p, "store_puts")?,
            store_dedup_hits: proto::get_u64(&p, "store_dedup_hits")?,
            store_resident_bytes: proto::get_u64(&p, "store_resident_bytes")?,
        })
    }
}

fn num(x: u64) -> Value {
    Value::Number(x as f64)
}

fn message(p: &Value) -> String {
    p.get_opt("message")
        .and_then(|v| v.as_str().ok())
        .unwrap_or("(no message)")
        .to_string()
}

/// Unwrap a reply of the expected kind; server errors become typed
/// session errors, anything else is a protocol violation.
fn expect(frame: Frame, want: FrameKind) -> Result<Value> {
    if frame.kind == want {
        return Ok(frame.payload);
    }
    Err(unexpected(&frame, want))
}

fn unexpected(frame: &Frame, want: FrameKind) -> Error {
    match frame.kind {
        FrameKind::ErrorReply => Error::Session(format!("server: {}", message(&frame.payload))),
        FrameKind::Busy => Error::Session(format!("server busy: {}", message(&frame.payload))),
        FrameKind::QuotaExceeded => {
            Error::Session(format!("quota exceeded: {}", message(&frame.payload)))
        }
        got => Error::Protocol(format!("expected {want:?} reply, got {got:?}")),
    }
}
