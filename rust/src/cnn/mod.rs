//! Rust inference engine for the case-study CNN (the VGG13 analog of
//! §4.3.2).  Loads the build-time-trained weights + frozen test set
//! (python/compile/cnn.py exports), runs im2col-GEMM convolutions, and
//! lets any conv layer's GEMM be computed exactly (dense artifact) or
//! approximately (SpAMM engine) — which is precisely the paper's Table 5
//! experiment: sweep τ / valid-ratio per layer and watch end-task accuracy.

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::matrix::im2col::{gemm_out_to_nchw, im2col, maxpool2, relu, Tensor4};
use crate::matrix::tensorio::load_tensor;
use crate::matrix::Matrix;
use crate::runtime::artifact::CnnMeta;
use crate::spamm::SpammEngine;

/// How a conv layer's GEMM is executed.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GemmMode {
    /// Host matmul (tiny layers / baseline-independent reference).
    Host,
    /// Dense XLA artifact (the cuBLAS stand-in).
    DenseArtifact,
    /// SpAMM with the given τ.
    Spamm { tau: f32 },
}

/// The loaded model.
pub struct Cnn {
    pub meta: CnnMeta,
    /// conv weights: name → (C_out, C_in·9) matrix.
    conv_w: BTreeMap<String, Matrix>,
    conv_b: BTreeMap<String, Vec<f32>>,
    fc_w: Matrix,
    fc_b: Vec<f32>,
    pub test_images: Tensor4,
    pub test_labels: Vec<i32>,
}

impl Cnn {
    /// Load weights + test data exported under `<artifacts>/cnn/`.
    pub fn load(meta: &CnnMeta) -> Result<Cnn> {
        let dir = &meta.dir;
        let mut conv_w = BTreeMap::new();
        let mut conv_b = BTreeMap::new();
        for (name, cin, cout) in &meta.conv_specs {
            let (dims, data) = load_tensor(&dir.join(format!("{name}_w.cstn")))?
                .as_f32()
                .map(|(d, v)| (d.to_vec(), v.to_vec()))?;
            if dims != [*cout, cin * 9] {
                return Err(Error::Artifact(format!(
                    "{name}_w: dims {dims:?}, want [{cout}, {}]",
                    cin * 9
                )));
            }
            conv_w.insert(name.clone(), Matrix::from_vec(dims[0], dims[1], data)?);
            let (_, bias) = load_tensor(&dir.join(format!("{name}_b.cstn")))?
                .as_f32()
                .map(|(d, v)| (d.to_vec(), v.to_vec()))?;
            conv_b.insert(name.clone(), bias);
        }
        let (fdims, fdata) = load_tensor(&dir.join("fc_w.cstn"))?
            .as_f32()
            .map(|(d, v)| (d.to_vec(), v.to_vec()))?;
        let fc_w = Matrix::from_vec(fdims[0], fdims[1], fdata)?;
        let (_, fc_b) = load_tensor(&dir.join("fc_b.cstn"))?
            .as_f32()
            .map(|(d, v)| (d.to_vec(), v.to_vec()))?;

        let (idims, idata) = load_tensor(&dir.join("test_images.cstn"))?
            .as_f32()
            .map(|(d, v)| (d.to_vec(), v.to_vec()))?;
        let test_images = Tensor4::from_vec(idims[0], idims[1], idims[2], idims[3], idata)?;
        let (_, labels) = load_tensor(&dir.join("test_labels.cstn"))?
            .as_i32()
            .map(|(d, v)| (d.to_vec(), v.to_vec()))?;

        Ok(Cnn {
            meta: meta.clone(),
            conv_w,
            conv_b,
            fc_w,
            fc_b,
            test_images,
            test_labels: labels,
        })
    }

    /// Conv layer names in forward order.
    pub fn layers(&self) -> Vec<String> {
        self.meta.conv_specs.iter().map(|(n, _, _)| n.clone()).collect()
    }

    /// One conv layer as GEMM: W(C_out × C_in·9) @ im2col(x) + bias.
    fn conv_layer(
        &self,
        name: &str,
        x: &Tensor4,
        mode: GemmMode,
        engine: Option<&SpammEngine>,
    ) -> Result<Tensor4> {
        let w = &self.conv_w[name];
        let bias = &self.conv_b[name];
        let cols = im2col(x);
        let mut out = match mode {
            GemmMode::Host => w.matmul(&cols)?,
            GemmMode::DenseArtifact => {
                let eng =
                    engine.ok_or_else(|| Error::Config("dense mode needs engine".into()))?;
                eng.runtime()
                    .dense(w, &cols, eng.config().precision.as_str())?
            }
            GemmMode::Spamm { tau } => {
                let eng =
                    engine.ok_or_else(|| Error::Config("spamm mode needs engine".into()))?;
                eng.multiply(w, &cols, tau)?
            }
        };
        // bias add
        let ocols = out.cols();
        for r in 0..out.rows() {
            let b = bias[r];
            for v in &mut out.data_mut()[r * ocols..(r + 1) * ocols] {
                *v += b;
            }
        }
        Ok(gemm_out_to_nchw(&out, x.n, x.h, x.w))
    }

    /// Full forward pass; `modes[layer]` overrides the default (Host).
    pub fn forward(
        &self,
        x: &Tensor4,
        modes: &BTreeMap<String, GemmMode>,
        engine: Option<&SpammEngine>,
    ) -> Result<Matrix> {
        let get = |n: &str| modes.get(n).copied().unwrap_or(GemmMode::Host);
        let mut h = self.conv_layer("conv1", x, get("conv1"), engine)?;
        relu(&mut h);
        let mut h = maxpool2(&h);
        h = self.conv_layer("conv2", &h, get("conv2"), engine)?;
        relu(&mut h);
        let mut h = maxpool2(&h);
        h = self.conv_layer("conv3", &h, get("conv3"), engine)?;
        relu(&mut h);
        // flatten (N, C·H·W) — matches jnp reshape(N, -1) on NCHW.
        let n = h.n;
        let feat = h.c * h.h * h.w;
        let mut flat = Matrix::zeros(n, feat);
        for ni in 0..n {
            for ci in 0..h.c {
                for y in 0..h.h {
                    for xx in 0..h.w {
                        flat[(ni, ci * h.h * h.w + y * h.w + xx)] = h.at(ni, ci, y, xx);
                    }
                }
            }
        }
        // fc: (N, feat) @ (feat, classes) + b
        let mut logits = flat.matmul(&self.fc_w)?;
        for r in 0..logits.rows() {
            for (c, b) in self.fc_b.iter().enumerate() {
                logits[(r, c)] += b;
            }
        }
        Ok(logits)
    }

    /// Slice `count` test images starting at `start` (clamped).
    pub fn test_batch(&self, start: usize, count: usize) -> (Tensor4, &[i32]) {
        let n = self.test_images.n;
        let s = start.min(n);
        let e = (start + count).min(n);
        let per = self.test_images.c * self.test_images.h * self.test_images.w;
        let data = self.test_images.data[s * per..e * per].to_vec();
        (
            Tensor4::from_vec(e - s, self.test_images.c, self.test_images.h, self.test_images.w, data)
                .expect("slice shape"),
            &self.test_labels[s..e],
        )
    }

    /// Accuracy over the frozen test set (batched like the paper's
    /// batch-size-100 evaluation).
    pub fn accuracy(
        &self,
        modes: &BTreeMap<String, GemmMode>,
        engine: Option<&SpammEngine>,
        batch: usize,
        limit: Option<usize>,
    ) -> Result<f64> {
        let total = limit.unwrap_or(self.test_images.n).min(self.test_images.n);
        let mut hits = 0usize;
        let mut seen = 0usize;
        let mut start = 0;
        while start < total {
            let count = batch.min(total - start);
            let (x, labels) = self.test_batch(start, count);
            let logits = self.forward(&x, modes, engine)?;
            for (r, &label) in labels.iter().enumerate() {
                let row = logits.row(r);
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap();
                if pred == label {
                    hits += 1;
                }
            }
            seen += count;
            start += count;
        }
        Ok(hits as f64 / seen.max(1) as f64)
    }
}
