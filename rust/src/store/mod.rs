//! Content-addressed on-disk warm-start store.
//!
//! Everything hot on the request path is already content-fingerprint-keyed
//! *in memory* — normmaps ([`NormCache`](crate::spamm::cache::NormCache)),
//! compacted schedules ([`ScheduleCache`](crate::spamm::cache::ScheduleCache)),
//! tuned τ results, and the synthesized hostsim artifact bundle — but all
//! of it dies with the process, so a restarted server pays the full cold
//! path on request one.  [`WarmStore`] persists those four artifact kinds
//! package-manager-style:
//!
//! ```text
//!   <store_dir>/
//!     manifest.json            versioned manifest: key → {kind, schema
//!                              version, key bits, payload path, byte
//!                              size, checksum}
//!     objects/<key>.bin        binary payloads (normmap / schedule / τ),
//!                              f32s stored as raw bit patterns
//!     bundles/<key>/           frozen hostsim artifact bundles
//! ```
//!
//! Keys embed the full invalidation state: normmaps are keyed by operand
//! fingerprint alone, schedules by both operand fingerprints **plus the
//! exact τ bits and density-threshold bits**, tuned τ by both fingerprints
//! plus the target-ratio and tuner-parameter bits, bundles by their
//! synthesis spec.  Payloads round-trip f32s by bit pattern, so a restored
//! artifact is bitwise identical to the one computed cold.
//!
//! The store must never be able to make a result wrong — only warm.
//! Every load is validated (manifest schema version, kind, byte size,
//! 128-bit checksum, payload-internal shape/τ/threshold consistency) and
//! any mismatch falls back to the cold path and evicts the bad entry.
//! Writes are crash-safe: payloads land in a temp file first and are
//! atomically renamed into place, then the manifest is re-read, merged,
//! and itself atomically replaced — a concurrent writer of the same entry
//! loses nothing worse than a redundant write.  Saves are write-behind in
//! the failure sense: an unwritable store logs and counts an error but
//! never surfaces one on the request path.
//!
//! `cuspamm store ls|gc|verify` administers a store directory; GC is
//! byte-budgeted with LRU-by-mtime eviction.  Telemetry lands on the
//! global counters `spamm.store.{hits,misses,read_bytes,write_bytes,
//! evictions,errors}`.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::SpammConfig;
use crate::error::{Error, Result};
use crate::json::Value;
use crate::matrix::Matrix;
use crate::spamm::cache::{Fingerprint, ScheduleKey};
use crate::spamm::normmap::NormMap;
use crate::spamm::schedule::{Schedule, TileStrategy};
use crate::spamm::tuner::{TuneParams, TuneResult};
use crate::telemetry;

/// Schema version of the manifest + payload formats.  Bump on any layout
/// change: entries written under another version are treated as cold and
/// evicted on contact.
pub const SCHEMA_VERSION: u64 = 1;

const MANIFEST: &str = "manifest.json";
const OBJECTS: &str = "objects";
const BUNDLES: &str = "bundles";

/// Payload header magic ("CSWS").
const MAGIC: u32 = 0x4353_5753;

const KIND_NORMMAP: &str = "normmap";
const KIND_SCHEDULE: &str = "schedule";
const KIND_TAU: &str = "tau";
const KIND_BUNDLE: &str = "bundle";

/// Key of a persisted tuned-τ result: both operand fingerprints, the
/// exact target-ratio bits, and the tuner parameters that shaped the
/// search (different parameters may converge to a different τ).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TauKey {
    pub a: Fingerprint,
    pub b: Fingerprint,
    pub target_bits: u64,
    pub max_iters: u64,
    pub tolerance_bits: u64,
}

impl TauKey {
    pub fn new(a: Fingerprint, b: Fingerprint, target: f64, params: &TuneParams) -> TauKey {
        TauKey {
            a,
            b,
            target_bits: target.to_bits(),
            max_iters: params.max_iters as u64,
            tolerance_bits: params.tolerance.to_bits(),
        }
    }
}

/// One manifest entry (the wolfpack `PackageMeta` shape: checksum + path
/// + byte size, plus our schema version and kind tag).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Entry {
    pub kind: String,
    /// Schema version the payload was written under.
    pub version: u64,
    /// Payload path relative to the store root.
    pub path: String,
    pub bytes: u64,
    /// 128-bit FNV checksum over the payload bytes, hex-encoded (JSON
    /// numbers are f64 and cannot carry u64s exactly).
    pub checksum: String,
}

/// Byte-budgeted GC sweep summary.
#[derive(Clone, Debug, Default)]
pub struct GcReport {
    pub entries_before: usize,
    pub evicted: usize,
    pub bytes_before: u64,
    pub bytes_after: u64,
}

/// `store verify` sweep summary.
#[derive(Clone, Debug, Default)]
pub struct VerifyReport {
    pub ok: usize,
    /// Keys that failed verification, with the reason.
    pub bad: Vec<(String, String)>,
}

/// Monotonic store counters (also mirrored onto the global telemetry).
#[derive(Default)]
struct Counters {
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    errors: AtomicU64,
}

/// The content-addressed on-disk warm-start store.  Handles are cheap and
/// stateless: every operation re-reads the manifest from disk, so
/// multiple processes (or a process that restarted) always observe the
/// latest committed state.
pub struct WarmStore {
    dir: PathBuf,
    /// Serializes manifest read-merge-write cycles within this process;
    /// cross-process writers are handled by atomic rename semantics.
    manifest_lock: Mutex<()>,
    counters: Counters,
}

impl WarmStore {
    /// Open (creating if needed) a store rooted at `dir`.
    pub fn open(dir: &Path) -> Result<WarmStore> {
        fs::create_dir_all(dir.join(OBJECTS))?;
        fs::create_dir_all(dir.join(BUNDLES))?;
        Ok(WarmStore {
            dir: dir.to_path_buf(),
            manifest_lock: Mutex::new(()),
            counters: Counters::default(),
        })
    }

    /// Open the store named by the config (`store_dir` + the
    /// `store_enabled` kill switch).  Never fails: an unusable directory
    /// logs a warning and yields `None` — the caller runs cold, which is
    /// always correct.
    pub fn from_config(cfg: &SpammConfig) -> Option<Arc<WarmStore>> {
        if !cfg.store_enabled || cfg.store_dir.is_empty() {
            return None;
        }
        match WarmStore::open(Path::new(&cfg.store_dir)) {
            Ok(s) => Some(Arc::new(s)),
            Err(e) => {
                log::warn!("warm store '{}' unusable ({e}); running cold", cfg.store_dir);
                None
            }
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn hits(&self) -> u64 {
        self.counters.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.counters.misses.load(Ordering::Relaxed)
    }

    pub fn evictions(&self) -> u64 {
        self.counters.evictions.load(Ordering::Relaxed)
    }

    pub fn errors(&self) -> u64 {
        self.counters.errors.load(Ordering::Relaxed)
    }

    // ----- normmaps ------------------------------------------------------

    /// Restore the normmap persisted under operand fingerprint `fp`, or
    /// `None` (validated; mismatch or corruption evicts and runs cold).
    pub fn load_normmap(&self, fp: Fingerprint) -> Option<NormMap> {
        let key = normmap_key(fp);
        let bytes = self.load_verified(&key, KIND_NORMMAP)?;
        match decode_normmap(&bytes) {
            Ok(nm) => Some(nm),
            Err(e) => {
                self.evict_bad(&key, &format!("undecodable normmap payload: {e}"));
                None
            }
        }
    }

    /// Persist a normmap under its operand fingerprint (write-behind:
    /// failures log + count, never propagate).
    pub fn save_normmap(&self, fp: Fingerprint, nm: &NormMap) {
        self.save_object(&normmap_key(fp), KIND_NORMMAP, encode_normmap(nm));
    }

    // ----- schedules -----------------------------------------------------

    /// Restore the compacted schedule persisted under `key`, validated
    /// against the expected tile grid (`tile_rows × tile_cols`, inner
    /// dimension `tile_k`).
    pub fn load_schedule(
        &self,
        key: &ScheduleKey,
        tile_rows: usize,
        tile_cols: usize,
        tile_k: usize,
    ) -> Option<Schedule> {
        let skey = schedule_key(key);
        let bytes = self.load_verified(&skey, KIND_SCHEDULE)?;
        match decode_schedule(&bytes) {
            Ok(s) if s.tile_rows == tile_rows && s.tile_cols == tile_cols && s.tile_k == tile_k => {
                Some(s)
            }
            Ok(s) => {
                self.evict_bad(
                    &skey,
                    &format!(
                        "schedule shape {}x{}x{} does not match operands {}x{}x{}",
                        s.tile_rows, s.tile_cols, s.tile_k, tile_rows, tile_cols, tile_k
                    ),
                );
                None
            }
            Err(e) => {
                self.evict_bad(&skey, &format!("undecodable schedule payload: {e}"));
                None
            }
        }
    }

    pub fn save_schedule(&self, key: &ScheduleKey, sched: &Schedule) {
        self.save_object(&schedule_key(key), KIND_SCHEDULE, encode_schedule(sched));
    }

    // ----- tuned τ -------------------------------------------------------

    pub fn load_tau(&self, key: &TauKey) -> Option<TuneResult> {
        let tkey = tau_key(key);
        let bytes = self.load_verified(&tkey, KIND_TAU)?;
        match decode_tau(&bytes) {
            Ok(r) => Some(r),
            Err(e) => {
                self.evict_bad(&tkey, &format!("undecodable τ payload: {e}"));
                None
            }
        }
    }

    pub fn save_tau(&self, key: &TauKey, result: &TuneResult) {
        self.save_object(&tau_key(key), KIND_TAU, encode_tau(result));
    }

    // ----- frozen artifact bundles --------------------------------------

    /// Restore the frozen artifact-bundle directory persisted under
    /// `name` (a synthesis-spec key, not a fingerprint).  The directory's
    /// content checksum is re-verified file by file before it is handed
    /// out; any drift evicts the whole bundle.
    pub fn load_bundle_dir(&self, name: &str) -> Option<PathBuf> {
        let key = bundle_key(name);
        let entry = match self.read_manifest() {
            Ok(m) => m.get(&key).cloned(),
            Err(_) => None,
        };
        let Some(entry) = entry else {
            self.miss();
            return None;
        };
        if entry.kind != KIND_BUNDLE || entry.version != SCHEMA_VERSION {
            self.evict_bad(&key, "bundle entry kind/version mismatch");
            return None;
        }
        let dir = self.dir.join(&entry.path);
        match dir_digest(&dir) {
            Ok((bytes, sum)) if bytes == entry.bytes && sum == entry.checksum => {
                self.hit(bytes);
                Some(dir)
            }
            Ok(_) => {
                self.evict_bad(&key, "bundle content drifted from its manifest checksum");
                None
            }
            Err(e) => {
                self.evict_bad(&key, &format!("bundle unreadable: {e}"));
                None
            }
        }
    }

    /// Persist a synthesized bundle directory under `name` by copying it
    /// into the store (temp dir + atomic rename).  Returns the stored
    /// path, or `None` on failure (the caller keeps using its own copy).
    pub fn save_bundle_dir(&self, name: &str, src: &Path) -> Option<PathBuf> {
        let key = bundle_key(name);
        let dst = self.dir.join(BUNDLES).join(name);
        let tmp = self
            .dir
            .join(BUNDLES)
            .join(format!(".tmp-{}-{}", name, std::process::id()));
        let staged = (|| -> Result<()> {
            let _ = fs::remove_dir_all(&tmp);
            copy_dir(src, &tmp)?;
            match fs::rename(&tmp, &dst) {
                Ok(()) => Ok(()),
                Err(_) if dst.is_dir() => {
                    // A concurrent writer won the rename; keep its copy
                    // (same content key → same content).
                    let _ = fs::remove_dir_all(&tmp);
                    Ok(())
                }
                Err(e) => Err(e.into()),
            }
        })();
        if let Err(e) = staged {
            self.write_error(&key, &e);
            let _ = fs::remove_dir_all(&tmp);
            return None;
        }
        let (bytes, checksum) = match dir_digest(&dst) {
            Ok(d) => d,
            Err(e) => {
                self.write_error(&key, &e);
                return None;
            }
        };
        let entry = Entry {
            kind: KIND_BUNDLE.into(),
            version: SCHEMA_VERSION,
            path: format!("{BUNDLES}/{name}"),
            bytes,
            checksum,
        };
        match self.commit_entry(&key, entry) {
            Ok(()) => {
                telemetry::global().add("spamm.store.write_bytes", bytes);
                Some(dst)
            }
            Err(e) => {
                self.write_error(&key, &e);
                None
            }
        }
    }

    // ----- administration ------------------------------------------------

    /// Snapshot of the manifest entries (key, entry, payload mtime).
    pub fn ls(&self) -> Result<Vec<(String, Entry, Option<std::time::SystemTime>)>> {
        let man = self.read_manifest()?;
        Ok(man
            .into_iter()
            .map(|(k, e)| {
                let mtime = entry_mtime(&self.dir.join(&e.path));
                (k, e, mtime)
            })
            .collect())
    }

    /// Evict one entry by key: drop it from the manifest and best-effort
    /// remove its payload.
    pub fn evict(&self, key: &str) {
        let entry = self
            .read_manifest()
            .ok()
            .and_then(|m| m.get(key).cloned());
        if let Err(e) = self.with_manifest(|m| {
            m.remove(key);
        }) {
            self.write_error(key, &e);
            return;
        }
        if let Some(e) = entry {
            let path = self.dir.join(&e.path);
            if e.kind == KIND_BUNDLE {
                let _ = fs::remove_dir_all(&path);
            } else {
                let _ = fs::remove_file(&path);
            }
        }
        self.counters.evictions.fetch_add(1, Ordering::Relaxed);
        telemetry::global().add("spamm.store.evictions", 1);
    }

    /// Evict a stored bundle by its logical name (the caller-facing
    /// handle `save_bundle_dir` was given, not the manifest key).
    pub fn evict_bundle(&self, name: &str) {
        self.evict(&bundle_key(name));
    }

    /// Byte-budgeted GC: evict least-recently-touched entries (payload
    /// mtime order — loads do not rewrite payloads, so mtime tracks the
    /// write side; a warm entry that keeps being *re-saved* stays fresh)
    /// until the store fits `budget_bytes`.
    pub fn gc(&self, budget_bytes: u64) -> Result<GcReport> {
        let mut entries = self.ls()?;
        let mut report = GcReport {
            entries_before: entries.len(),
            bytes_before: entries.iter().map(|(_, e, _)| e.bytes).sum(),
            ..GcReport::default()
        };
        report.bytes_after = report.bytes_before;
        // LRU by mtime: oldest payloads first; entries whose payload is
        // already gone sort first (they are pure manifest garbage).
        entries.sort_by_key(|(_, _, mtime)| *mtime);
        let mut i = 0;
        while report.bytes_after > budget_bytes && i < entries.len() {
            let (key, e, _) = &entries[i];
            self.evict(key);
            report.evicted += 1;
            report.bytes_after = report.bytes_after.saturating_sub(e.bytes);
            i += 1;
        }
        Ok(report)
    }

    /// Manifest snapshot keyed by entry key — the auditor's view of the
    /// store ([`crate::audit::audit_store`] sweeps these against their
    /// on-disk payloads).
    pub fn entries(&self) -> Result<BTreeMap<String, Entry>> {
        self.read_manifest()
    }

    /// Size + 128-bit checksum of one entry's on-disk payload (a
    /// directory digest for bundles, a flat file digest otherwise) —
    /// the raw fact the auditor compares against the manifest.
    pub fn payload_digest(&self, e: &Entry) -> Result<(u64, String)> {
        let path = self.dir.join(&e.path);
        if e.kind == KIND_BUNDLE {
            dir_digest(&path)
        } else {
            let b = fs::read(&path)?;
            let sum = checksum_hex(&b);
            Ok((b.len() as u64, sum))
        }
    }

    /// Re-verify every manifest entry against its payload (size +
    /// checksum + schema version).  With `heal`, bad entries are evicted
    /// so the store self-repairs; without it the store is left untouched.
    ///
    /// The sweep itself is [`crate::audit::audit_store`] — store
    /// verification has exactly one implementation, shared with
    /// `cuspamm audit store`.
    pub fn verify(&self, heal: bool) -> Result<VerifyReport> {
        let total = self.read_manifest()?.len();
        let audit = crate::audit::audit_store(self);
        let mut report = VerifyReport::default();
        for v in &audit.violations {
            let key = v.key.clone().unwrap_or_default();
            if heal {
                self.evict(&key);
            }
            report.bad.push((key, v.detail.clone()));
        }
        report.ok = total - report.bad.len();
        Ok(report)
    }

    // ----- internals -----------------------------------------------------

    fn hit(&self, bytes: u64) {
        self.counters.hits.fetch_add(1, Ordering::Relaxed);
        telemetry::global().add("spamm.store.hits", 1);
        telemetry::global().add("spamm.store.read_bytes", bytes);
    }

    fn miss(&self) {
        self.counters.misses.fetch_add(1, Ordering::Relaxed);
        telemetry::global().add("spamm.store.misses", 1);
    }

    fn write_error(&self, key: &str, e: &Error) {
        log::warn!("warm store: failed to persist '{key}': {e}");
        self.counters.errors.fetch_add(1, Ordering::Relaxed);
        telemetry::global().add("spamm.store.errors", 1);
    }

    fn evict_bad(&self, key: &str, why: &str) {
        log::warn!("warm store: evicting '{key}' ({why}); falling back cold");
        telemetry::global().add("spamm.store.errors", 1);
        self.miss();
        self.evict(key);
    }

    /// Read + fully validate one object payload; any failure evicts the
    /// entry and reports a miss.
    fn load_verified(&self, key: &str, kind: &str) -> Option<Vec<u8>> {
        let man = match self.read_manifest() {
            Ok(m) => m,
            Err(_) => {
                // Unparseable or version-skewed manifest: the store is
                // cold until the next save rewrites it.
                self.miss();
                return None;
            }
        };
        let Some(entry) = man.get(key) else {
            self.miss();
            return None;
        };
        if entry.kind != kind || entry.version != SCHEMA_VERSION {
            self.evict_bad(key, "entry kind/version mismatch");
            return None;
        }
        let bytes = match fs::read(self.dir.join(&entry.path)) {
            Ok(b) => b,
            Err(e) => {
                self.evict_bad(key, &format!("payload unreadable: {e}"));
                return None;
            }
        };
        if bytes.len() as u64 != entry.bytes {
            self.evict_bad(
                key,
                &format!("payload is {} bytes, manifest says {}", bytes.len(), entry.bytes),
            );
            return None;
        }
        if checksum_hex(&bytes) != entry.checksum {
            self.evict_bad(key, "checksum mismatch");
            return None;
        }
        let mut r = Reader::new(&bytes);
        let (magic, version, k) = match (r.u32(), r.u32(), r.str_field()) {
            (Ok(m), Ok(v), Ok(k)) => (m, v, k),
            _ => {
                self.evict_bad(key, "truncated payload header");
                return None;
            }
        };
        if magic != MAGIC || version as u64 != SCHEMA_VERSION || k != kind {
            self.evict_bad(key, "payload header mismatch");
            return None;
        }
        self.hit(entry.bytes);
        Some(bytes)
    }

    /// Write-behind object save: payload to a temp file, atomic rename,
    /// then manifest read-merge-write.  Never surfaces an error.
    fn save_object(&self, key: &str, kind: &str, body: Vec<u8>) {
        let mut payload = Writer::new();
        payload.u32(MAGIC);
        payload.u32(SCHEMA_VERSION as u32);
        payload.str_field(kind);
        payload.bytes(&body);
        let payload = payload.into_inner();
        let rel = format!("{OBJECTS}/{key}.bin");
        let entry = Entry {
            kind: kind.into(),
            version: SCHEMA_VERSION,
            path: rel.clone(),
            bytes: payload.len() as u64,
            checksum: checksum_hex(&payload),
        };
        let written = (|| -> Result<()> {
            atomic_write(&self.dir.join(&rel), &payload)?;
            self.commit_entry(key, entry)
        })();
        match written {
            Ok(()) => telemetry::global().add("spamm.store.write_bytes", payload.len() as u64),
            Err(e) => self.write_error(key, &e),
        }
    }

    fn commit_entry(&self, key: &str, entry: Entry) -> Result<()> {
        self.with_manifest(|m| {
            m.insert(key.to_string(), entry);
        })
    }

    /// Read-merge-write cycle over the on-disk manifest, serialized
    /// in-process and atomically renamed on disk.
    fn with_manifest(&self, edit: impl FnOnce(&mut BTreeMap<String, Entry>)) -> Result<()> {
        let _guard = self.manifest_lock.lock().unwrap();
        let mut man = self.read_manifest().unwrap_or_default();
        edit(&mut man);
        let mut entries = BTreeMap::new();
        for (k, e) in &man {
            let mut obj = BTreeMap::new();
            obj.insert("kind".into(), Value::String(e.kind.clone()));
            obj.insert("version".into(), Value::Number(e.version as f64));
            obj.insert("path".into(), Value::String(e.path.clone()));
            obj.insert("bytes".into(), Value::Number(e.bytes as f64));
            obj.insert("checksum".into(), Value::String(e.checksum.clone()));
            entries.insert(k.clone(), Value::Object(obj));
        }
        let mut root = BTreeMap::new();
        root.insert("version".into(), Value::Number(SCHEMA_VERSION as f64));
        root.insert("entries".into(), Value::Object(entries));
        atomic_write(
            &self.dir.join(MANIFEST),
            Value::Object(root).to_json().as_bytes(),
        )
    }

    /// Parse the on-disk manifest.  A missing file is an empty store; an
    /// unparseable or wrong-version manifest is an error (callers treat
    /// it as cold; the next save rewrites it wholesale).
    fn read_manifest(&self) -> Result<BTreeMap<String, Entry>> {
        let path = self.dir.join(MANIFEST);
        let text = match fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(BTreeMap::new()),
            Err(e) => return Err(e.into()),
        };
        let root = Value::parse(&text)?;
        let version = root.get("version")?.as_f64()? as u64;
        if version != SCHEMA_VERSION {
            return Err(Error::Store(format!(
                "manifest schema version {version} (this build reads {SCHEMA_VERSION})"
            )));
        }
        let mut out = BTreeMap::new();
        for (k, v) in root.get("entries")?.as_object()? {
            out.insert(
                k.clone(),
                Entry {
                    kind: v.get("kind")?.as_str()?.to_string(),
                    version: v.get("version")?.as_f64()? as u64,
                    path: v.get("path")?.as_str()?.to_string(),
                    bytes: v.get("bytes")?.as_f64()? as u64,
                    checksum: v.get("checksum")?.as_str()?.to_string(),
                },
            );
        }
        Ok(out)
    }
}

// ----- keys ---------------------------------------------------------------

fn fp_hex(fp: Fingerprint) -> String {
    format!("{:016x}{:016x}", fp.0, fp.1)
}

fn normmap_key(fp: Fingerprint) -> String {
    format!("nm-{}", fp_hex(fp))
}

fn schedule_key(key: &ScheduleKey) -> String {
    format!(
        "sc-{}-{}-t{:08x}-d{:08x}",
        fp_hex(key.a),
        fp_hex(key.b),
        key.tau_bits,
        key.density_bits
    )
}

fn tau_key(key: &TauKey) -> String {
    format!(
        "tau-{}-{}-r{:016x}-i{}-o{:016x}",
        fp_hex(key.a),
        fp_hex(key.b),
        key.target_bits,
        key.max_iters,
        key.tolerance_bits
    )
}

fn bundle_key(name: &str) -> String {
    format!("bundle-{name}")
}

// ----- checksums -----------------------------------------------------------

/// 128-bit checksum over raw bytes: two independent FNV-1a streams (the
/// same construction as the operand fingerprints), hex-encoded.
pub fn checksum_hex(bytes: &[u8]) -> String {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h1 = OFFSET ^ 0x9e37_79b9_7f4a_7c15;
    let mut h2 = OFFSET ^ 0x5bd1_e995_0000_0003;
    for &b in bytes {
        h1 = (h1 ^ b as u64).wrapping_mul(PRIME);
        h2 = (h2 ^ (b as u64).rotate_left(7)).wrapping_mul(PRIME);
    }
    h2 = (h2 ^ bytes.len() as u64).wrapping_mul(PRIME);
    format!("{h1:016x}{h2:016x}")
}

/// Digest a bundle directory: byte total + checksum over every file's
/// relative path and content, in sorted path order (rename-atomic
/// directories have no single payload file to hash).
fn dir_digest(dir: &Path) -> Result<(u64, String)> {
    let mut files = Vec::new();
    collect_files(dir, dir, &mut files)?;
    files.sort();
    let mut total = 0u64;
    let mut acc = Vec::new();
    for rel in &files {
        let content = fs::read(dir.join(rel))?;
        total += content.len() as u64;
        acc.extend_from_slice(rel.as_bytes());
        acc.push(0);
        acc.extend_from_slice(checksum_hex(&content).as_bytes());
    }
    Ok((total, checksum_hex(&acc)))
}

fn collect_files(root: &Path, dir: &Path, out: &mut Vec<String>) -> Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_files(root, &path, out)?;
        } else {
            let rel = path
                .strip_prefix(root)
                .map_err(|_| Error::Store("bundle path escaped its root".into()))?;
            out.push(rel.to_string_lossy().into_owned());
        }
    }
    Ok(())
}

fn copy_dir(src: &Path, dst: &Path) -> Result<()> {
    fs::create_dir_all(dst)?;
    for entry in fs::read_dir(src)? {
        let entry = entry?;
        let from = entry.path();
        let to = dst.join(entry.file_name());
        if from.is_dir() {
            copy_dir(&from, &to)?;
        } else {
            fs::copy(&from, &to)?;
        }
    }
    Ok(())
}

fn entry_mtime(path: &Path) -> Option<std::time::SystemTime> {
    let meta = fs::metadata(path).ok()?;
    if meta.is_dir() {
        // Bundles: freshest file inside (the rename itself may not touch
        // the directory mtime on every filesystem).
        let mut newest = meta.modified().ok();
        let mut files = Vec::new();
        if collect_files(path, path, &mut files).is_ok() {
            for rel in files {
                if let Ok(m) = fs::metadata(path.join(rel)) {
                    let t = m.modified().ok();
                    if t > newest {
                        newest = t;
                    }
                }
            }
        }
        newest
    } else {
        meta.modified().ok()
    }
}

/// Crash-safe write: temp file in the target's directory, then atomic
/// rename over the destination.
fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = path
        .parent()
        .ok_or_else(|| Error::Store(format!("no parent directory for {}", path.display())))?;
    let name = path
        .file_name()
        .ok_or_else(|| Error::Store(format!("no file name in {}", path.display())))?;
    let tmp = dir.join(format!(
        ".tmp-{}-{}",
        name.to_string_lossy(),
        std::process::id()
    ));
    fs::write(&tmp, bytes)?;
    match fs::rename(&tmp, path) {
        Ok(()) => Ok(()),
        Err(e) => {
            let _ = fs::remove_file(&tmp);
            Err(e.into())
        }
    }
}

// ----- binary payload codecs ----------------------------------------------
//
// f32s are stored as raw little-endian bit patterns so a restored
// artifact is bitwise identical to the computed one (decimal text would
// not round-trip).

struct Writer(Vec<u8>);

impl Writer {
    fn new() -> Writer {
        Writer(Vec::new())
    }

    fn into_inner(self) -> Vec<u8> {
        self.0
    }

    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    fn f32_bits(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    fn bytes(&mut self, b: &[u8]) {
        self.0.extend_from_slice(b);
    }

    fn str_field(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Store("truncated payload".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn f32_bits(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn str_field(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        if n > self.buf.len() {
            return Err(Error::Store("truncated payload".into()));
        }
        String::from_utf8(self.take(n)?.to_vec())
            .map_err(|_| Error::Store("non-utf8 string field".into()))
    }

    fn done(&self) -> Result<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(Error::Store("trailing bytes in payload".into()))
        }
    }
}

/// Skip the common header (already validated by `load_verified`).
fn body_reader(bytes: &[u8]) -> Result<Reader<'_>> {
    let mut r = Reader::new(bytes);
    r.u32()?;
    r.u32()?;
    r.str_field()?;
    Ok(r)
}

fn encode_matrix(w: &mut Writer, m: &Matrix) {
    w.u32(m.rows() as u32);
    w.u32(m.cols() as u32);
    for &v in m.data() {
        w.f32_bits(v);
    }
}

fn decode_matrix(r: &mut Reader) -> Result<Matrix> {
    let rows = r.u32()? as usize;
    let cols = r.u32()? as usize;
    let count = rows
        .checked_mul(cols)
        .ok_or_else(|| Error::Store("matrix dims overflow".into()))?;
    if count > r.buf.len() / 4 + 1 {
        return Err(Error::Store("matrix dims exceed payload".into()));
    }
    let mut data = Vec::with_capacity(count);
    for _ in 0..count {
        data.push(r.f32_bits()?);
    }
    Matrix::from_vec(rows, cols, data)
}

fn encode_normmap(nm: &NormMap) -> Vec<u8> {
    let mut w = Writer::new();
    encode_matrix(&mut w, &nm.norms);
    encode_matrix(&mut w, &nm.density);
    w.into_inner()
}

fn decode_normmap(bytes: &[u8]) -> Result<NormMap> {
    let mut r = body_reader(bytes)?;
    let norms = decode_matrix(&mut r)?;
    let density = decode_matrix(&mut r)?;
    r.done()?;
    NormMap::from_parts(norms, density)
}

fn encode_schedule(s: &Schedule) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(s.tile_rows as u32);
    w.u32(s.tile_cols as u32);
    w.u32(s.tile_k as u32);
    for (ks, tags) in s.valid_k.iter().zip(&s.strategies) {
        w.u32(ks.len() as u32);
        for &k in ks {
            w.u32(k);
        }
        for &t in tags {
            w.u8(t.to_tag());
        }
    }
    w.into_inner()
}

fn decode_schedule(bytes: &[u8]) -> Result<Schedule> {
    let mut r = body_reader(bytes)?;
    let tile_rows = r.u32()? as usize;
    let tile_cols = r.u32()? as usize;
    let tile_k = r.u32()? as usize;
    let slots = tile_rows
        .checked_mul(tile_cols)
        .ok_or_else(|| Error::Store("schedule dims overflow".into()))?;
    if slots > bytes.len() {
        return Err(Error::Store("schedule dims exceed payload".into()));
    }
    let mut valid_k = Vec::with_capacity(slots);
    let mut strategies = Vec::with_capacity(slots);
    for _ in 0..slots {
        let len = r.u32()? as usize;
        if len > tile_k {
            return Err(Error::Store("slot has more products than tile_k".into()));
        }
        let mut ks = Vec::with_capacity(len);
        for _ in 0..len {
            let k = r.u32()?;
            if k as usize >= tile_k {
                return Err(Error::Store("product index out of k range".into()));
            }
            ks.push(k);
        }
        let mut tags = Vec::with_capacity(len);
        for _ in 0..len {
            tags.push(TileStrategy::from_tag(r.u8()?)?);
        }
        valid_k.push(ks);
        strategies.push(tags);
    }
    r.done()?;
    Ok(Schedule {
        tile_rows,
        tile_cols,
        tile_k,
        valid_k,
        strategies,
    })
}

fn encode_tau(t: &TuneResult) -> Vec<u8> {
    let mut w = Writer::new();
    w.u32(t.tau.to_bits());
    w.u64(t.achieved_ratio.to_bits());
    w.u64(t.iters as u64);
    w.u64(t.expansion_k as u64);
    w.into_inner()
}

fn decode_tau(bytes: &[u8]) -> Result<TuneResult> {
    let mut r = body_reader(bytes)?;
    let tau = f32::from_bits(r.u32()?);
    let achieved_ratio = f64::from_bits(r.u64()?);
    let iters = r.u64()? as usize;
    let expansion_k = r.u64()? as usize;
    r.done()?;
    if !tau.is_finite() || tau < 0.0 || !achieved_ratio.is_finite() {
        return Err(Error::Store("non-finite tuned τ".into()));
    }
    Ok(TuneResult {
        tau,
        achieved_ratio,
        iters,
        expansion_k,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::tiling::PaddedMatrix;
    use crate::spamm::cache::fingerprint;
    use crate::spamm::normmap::normmap_with_density;

    fn tmp_store(tag: &str) -> (PathBuf, WarmStore) {
        let dir = std::env::temp_dir().join(format!(
            "cuspamm_store_unit_{}_{}",
            tag,
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        let store = WarmStore::open(&dir).unwrap();
        (dir, store)
    }

    #[test]
    fn normmap_round_trips_bitwise() {
        let (dir, store) = tmp_store("nm");
        let m = Matrix::randn(64, 64, 3);
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap_with_density(&p);
        let fp = fingerprint(&p);
        assert!(store.load_normmap(fp).is_none());
        store.save_normmap(fp, &nm);
        let restored = store.load_normmap(fp).expect("persisted entry");
        assert_eq!(restored.norms.data(), nm.norms.data());
        assert_eq!(restored.density.data(), nm.density.data());
        // A fresh handle over the same directory (the "restarted
        // process") sees the entry too.
        let reopened = WarmStore::open(&dir).unwrap();
        let again = reopened.load_normmap(fp).expect("restart warm");
        assert_eq!(again.norms.data(), nm.norms.data());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn schedule_round_trips_and_validates_shape() {
        let (dir, store) = tmp_store("sc");
        let m = Matrix::randn(64, 64, 5);
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap_with_density(&p);
        let sched = Schedule::build_adaptive(&nm, &nm, 1e-3, 0.5).unwrap();
        let key = ScheduleKey {
            a: Fingerprint(1, 2),
            b: Fingerprint(3, 4),
            tau_bits: 1e-3f32.to_bits(),
            density_bits: 0.5f32.to_bits(),
        };
        store.save_schedule(&key, &sched);
        let r = store
            .load_schedule(&key, sched.tile_rows, sched.tile_cols, sched.tile_k)
            .expect("persisted schedule");
        assert_eq!(r.valid_k, sched.valid_k);
        assert_eq!(r.strategies, sched.strategies);
        // Wrong expected grid → cold + evicted.
        assert!(store.load_schedule(&key, 99, 99, 99).is_none());
        assert!(store
            .load_schedule(&key, sched.tile_rows, sched.tile_cols, sched.tile_k)
            .is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn tau_round_trips_exactly() {
        let (dir, store) = tmp_store("tau");
        let key = TauKey::new(
            Fingerprint(7, 8),
            Fingerprint(9, 10),
            0.1,
            &TuneParams::default(),
        );
        let t = TuneResult {
            tau: 3.0339e-4,
            achieved_ratio: 0.10312,
            iters: 9,
            expansion_k: 3,
        };
        store.save_tau(&key, &t);
        let r = store.load_tau(&key).expect("persisted τ");
        assert_eq!(r.tau.to_bits(), t.tau.to_bits());
        assert_eq!(r.achieved_ratio.to_bits(), t.achieved_ratio.to_bits());
        assert_eq!((r.iters, r.expansion_k), (t.iters, t.expansion_k));
        // Different target ratio → different key → miss.
        let other = TauKey::new(key.a, key.b, 0.2, &TuneParams::default());
        assert!(store.load_tau(&other).is_none());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_evicts_lru_by_mtime_under_budget() {
        let (dir, store) = tmp_store("gc");
        let m = Matrix::randn(64, 64, 6);
        let p = PaddedMatrix::new(&m, 32);
        let nm = normmap_with_density(&p);
        for i in 0..4u64 {
            store.save_normmap(Fingerprint(i, i + 100), &nm);
            // mtime granularity: space the writes out.
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        let total: u64 = store.ls().unwrap().iter().map(|(_, e, _)| e.bytes).sum();
        let one = total / 4;
        let report = store.gc(2 * one + one / 2).unwrap();
        assert_eq!(report.entries_before, 4);
        assert_eq!(report.evicted, 2);
        assert!(report.bytes_after <= 2 * one + one / 2);
        // The *oldest* entries went: 0 and 1 are gone, 2 and 3 remain.
        assert!(store.load_normmap(Fingerprint(0, 100)).is_none());
        assert!(store.load_normmap(Fingerprint(1, 101)).is_none());
        assert!(store.load_normmap(Fingerprint(2, 102)).is_some());
        assert!(store.load_normmap(Fingerprint(3, 103)).is_some());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_is_position_and_length_sensitive() {
        assert_ne!(checksum_hex(b"ab"), checksum_hex(b"ba"));
        assert_ne!(checksum_hex(b""), checksum_hex(b"\0"));
        assert_eq!(checksum_hex(b"xyz"), checksum_hex(b"xyz"));
    }
}
