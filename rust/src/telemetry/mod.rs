//! Telemetry substrate: a `log`-facade logger plus lightweight counters and
//! wall-clock timers used by the coordinator and benches (env_logger is not
//! in the offline crate set).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Stderr logger honoring `CUSPAMM_LOG` (error|warn|info|debug|trace).
struct StderrLogger {
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if self.enabled(record.metadata()) {
            eprintln!(
                "[{:5}] {}: {}",
                record.level(),
                record.target(),
                record.args()
            );
        }
    }

    fn flush(&self) {}
}

/// Install the logger once; later calls are no-ops.
pub fn init_logging() {
    static INIT: std::sync::Once = std::sync::Once::new();
    INIT.call_once(|| {
        let level = match std::env::var("CUSPAMM_LOG").as_deref() {
            Ok("trace") => log::LevelFilter::Trace,
            Ok("debug") => log::LevelFilter::Debug,
            Ok("info") => log::LevelFilter::Info,
            Ok("warn") => log::LevelFilter::Warn,
            Ok("error") => log::LevelFilter::Error,
            _ => log::LevelFilter::Warn,
        };
        let logger = Box::leak(Box::new(StderrLogger { level }));
        let _ = log::set_logger(logger);
        log::set_max_level(level);
    });
}

/// Process-wide counter set: executor caches and other subsystems record
/// hit/miss/throughput counters here so operators can snapshot them
/// without threading a `Counters` handle through every layer.
pub fn global() -> &'static Counters {
    static GLOBAL: std::sync::OnceLock<Counters> = std::sync::OnceLock::new();
    GLOBAL.get_or_init(Counters::new)
}

/// A named monotonically-increasing counter set (thread-safe).
#[derive(Default)]
pub struct Counters {
    inner: Mutex<BTreeMap<String, u64>>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, name: &str, v: u64) {
        let mut m = self.inner.lock().unwrap();
        *m.entry(name.to_string()).or_insert(0) += v;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.inner.lock().unwrap().clone()
    }
}

/// Cumulative nanosecond clock, safe to bump from many threads.
#[derive(Default)]
pub struct NanoClock(AtomicU64);

impl NanoClock {
    pub fn add(&self, nanos: u64) {
        self.0.fetch_add(nanos, Ordering::Relaxed);
    }

    pub fn secs(&self) -> f64 {
        self.0.load(Ordering::Relaxed) as f64 / 1e9
    }
}

/// Scope timer: `let _t = ScopedTimer::new(&clock);`
pub struct ScopedTimer<'a> {
    clock: &'a NanoClock,
    start: Instant,
}

impl<'a> ScopedTimer<'a> {
    pub fn new(clock: &'a NanoClock) -> Self {
        ScopedTimer {
            clock,
            start: Instant::now(),
        }
    }
}

impl Drop for ScopedTimer<'_> {
    fn drop(&mut self) {
        self.clock.add(self.start.elapsed().as_nanos() as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let c = Counters::new();
        c.add("x", 2);
        c.add("x", 3);
        c.add("y", 1);
        assert_eq!(c.get("x"), 5);
        assert_eq!(c.get("y"), 1);
        assert_eq!(c.get("z"), 0);
        assert_eq!(c.snapshot().len(), 2);
    }

    #[test]
    fn counters_threaded() {
        let c = std::sync::Arc::new(Counters::new());
        let hs: Vec<_> = (0..4)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.add("n", 1);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(c.get("n"), 4000);
    }

    #[test]
    fn scoped_timer_accumulates() {
        let clock = NanoClock::default();
        {
            let _t = ScopedTimer::new(&clock);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert!(clock.secs() >= 0.004);
    }

    #[test]
    fn global_counters_shared() {
        let before = global().get("test.telemetry.global");
        global().add("test.telemetry.global", 2);
        assert_eq!(global().get("test.telemetry.global"), before + 2);
    }

    #[test]
    fn init_logging_idempotent() {
        init_logging();
        init_logging();
        log::warn!("logger alive");
    }
}
