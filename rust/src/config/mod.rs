//! Configuration system: typed run configuration + a small `key = value`
//! config-file format (TOML-subset: sections, strings, numbers, booleans,
//! comments) with CLI overrides.  serde/toml are not in the offline crate
//! set, so the parser is a substrate of this repo.

use std::path::Path;

use crate::error::{Error, Result};

/// Precision of the multiplication pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// f32 everywhere — the paper's `cublasSgemm`-class configuration.
    F32,
    /// bf16 operands, f32 accumulation — the tensor-core (MXU) analog.
    Bf16,
}

impl Precision {
    pub fn parse(s: &str) -> Result<Precision> {
        match s {
            "f32" | "fp32" => Ok(Precision::F32),
            "bf16" | "fp16" | "mixed" => Ok(Precision::Bf16),
            _ => Err(Error::Config(format!("unknown precision '{s}'"))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Bf16 => "bf16",
        }
    }
}

/// Load-balance strategy for assigning output tiles to workers (§3.5.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Balance {
    /// Contiguous row blocks (Algorithm 4 default).
    RowBlock,
    /// Strided assignment with stride `s`: worker w computes tiles
    /// {w, w+s, w+2s, ...} in row-major tile order, spreading the
    /// diagonal-heavy load of decay matrices evenly.
    Strided(usize),
    /// Residency- and memory-aware assignment: output tiles whose A/B
    /// operand tiles are already resident in a device's pool stay on
    /// that device (zero transfer), the rest are placed greedily by
    /// valid-product load with transfer bytes as the tie-break, keeping
    /// each device's working set under its `device_mem_budget`.  With
    /// residency disabled (or operand fingerprints unavailable) the
    /// policy degrades to its cold greedy fill — a load-balanced (LPT)
    /// partition, not row blocks.
    ResidencyAware,
}

/// Full engine/coordinator configuration.
#[derive(Clone, Debug)]
pub struct SpammConfig {
    /// Tile edge (the paper's LoNum).  Must match the compiled artifacts.
    pub lonum: usize,
    /// Numeric configuration.
    pub precision: Precision,
    /// Number of simulated devices (paper: GPUs; here: worker threads each
    /// owning a PJRT CPU client).
    pub devices: usize,
    /// Transfer/compute batches per device (the paper's P).
    pub pipeline_batches: usize,
    /// Max tile products per tile-GEMM executable call.
    pub max_tile_batch: usize,
    /// In-flight chunks buffered between executor pipeline stages
    /// (gather → exec → scatter).  Higher values let fast stages run
    /// further ahead; even depth 1 overlaps stages (one staged chunk
    /// per channel), it just minimizes buffering.
    pub pipeline_depth: usize,
    /// Memoize normmaps and compacted schedules across multiplies keyed on
    /// operand content fingerprints + τ (`--no-cache` turns this off).
    pub cache_enabled: bool,
    /// Keep operand tiles device-resident across chunks, batches, and
    /// multiplies (per-device pool keyed on content fingerprint + tile
    /// coordinate; `--no-residency` turns this off).
    pub residency_enabled: bool,
    /// Byte budget of each device's resident-tile pool (LRU eviction;
    /// pinned tiles are never evicted).  Historically `0` meant
    /// "unlimited", but real device memory never is — an unbounded pool
    /// on a GPU is an OOM waiting for traffic — so configs must now size
    /// the budget explicitly (or disable residency); the raw
    /// `ResidencyPool::new(0)` escape hatch remains for tests.  Accepts
    /// `k`/`m`/`g` suffixes in config files and on the CLI.
    pub device_mem_budget: usize,
    /// Bounded admission depth of the session queue: `submit` fails once
    /// this many jobs are queued (backpressure instead of unbounded
    /// buffering).
    pub queue_depth: usize,
    /// Byte budget of the session operand store (registered padded
    /// operands; LRU eviction of released, unpinned entries).
    /// 0 = unlimited.  Accepts `k`/`m`/`g` suffixes.
    pub store_budget: usize,
    /// Directory of the content-addressed on-disk warm-start store
    /// ([`crate::store::WarmStore`]): normmaps, compacted schedules,
    /// tuned τ results, and frozen hostsim bundles persist here across
    /// process restarts.  Empty (the default) disables persistence.
    pub store_dir: String,
    /// Kill switch for the warm-start store (`--no-store`): when false,
    /// `store_dir` is ignored and every request runs the in-memory-only
    /// cold path, byte-identical to a build without the store.
    pub store_enabled: bool,
    /// Load-balance strategy.
    pub balance: Balance,
    /// Compute normmaps on-device (get-norm artifact) or on the host.
    pub device_normmap: bool,
    /// Per-tile density threshold in [0, 1] for the adaptive format
    /// selector: a surviving product whose A *and* B tile densities
    /// (fraction of entries above the census floor) are strictly below
    /// this runs through the sparse COO path; runs of sparse products
    /// fuse into packed dispatches.  `0.0` (the default) disables the
    /// selector — every product takes the dense tile-GEMM path, bitwise
    /// identical to the pre-adaptive executor.
    pub density_threshold: f32,
    /// `--density-threshold auto`: derive the threshold per operand pair
    /// from the normmap density histogram
    /// ([`crate::spamm::normmap::auto_density_threshold`] — largest-gap
    /// split of the combined census) instead of the fixed
    /// `density_threshold` value.  Explicit numeric values (and the
    /// default 0) keep exact legacy behavior.
    pub density_threshold_auto: bool,
    /// Serving tier: cache completed results keyed on derived operand
    /// fingerprints + approximation knobs, so an idempotent re-submitted
    /// plan returns without executing (`--no-result-cache` turns this
    /// off; bitwise-inert — a miss and a hit return the same bytes).
    pub result_cache_enabled: bool,
    /// Serving tier: per-client byte budget for `put` operands across one
    /// connection's live handles, enforced at admission with a typed
    /// `QuotaExceeded` reply.  0 = unlimited.  Accepts `k`/`m`/`g`
    /// suffixes.
    pub client_store_budget: usize,
    /// Serving tier: per-client in-flight submit budget, enforced at
    /// admission with a typed `Busy` reply.  0 = inherit `queue_depth`
    /// (whole-session bound only).
    pub client_queue_depth: usize,
    /// Run device pipelines one after another instead of concurrently.
    /// On a testbed whose simulated devices share physical cores the
    /// concurrent mode inflates each device's busy clock with contention;
    /// sequential mode yields clean per-device times whose max models the
    /// wall-clock of truly independent devices (used by the Fig. 5/6
    /// benches; see DESIGN.md §2).
    pub sequential_devices: bool,
}

impl Default for SpammConfig {
    fn default() -> Self {
        SpammConfig {
            lonum: 32,
            precision: Precision::F32,
            devices: 1,
            pipeline_batches: 4,
            max_tile_batch: 1024,
            pipeline_depth: 2,
            cache_enabled: true,
            residency_enabled: true,
            device_mem_budget: 256 * 1024 * 1024,
            queue_depth: 64,
            store_budget: 1024 * 1024 * 1024,
            store_dir: String::new(),
            store_enabled: true,
            balance: Balance::Strided(4),
            density_threshold: 0.0,
            density_threshold_auto: false,
            device_normmap: false,
            result_cache_enabled: true,
            client_store_budget: 0,
            client_queue_depth: 0,
            sequential_devices: false,
        }
    }
}

impl SpammConfig {
    /// Apply `key = value` pairs (from file or CLI) onto the config.
    pub fn apply(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "lonum" => self.lonum = parse_num(key, value)?,
            "precision" => self.precision = Precision::parse(value)?,
            "devices" => self.devices = parse_num(key, value)?,
            "pipeline_batches" => self.pipeline_batches = parse_num(key, value)?,
            "max_tile_batch" => self.max_tile_batch = parse_num(key, value)?,
            "pipeline_depth" => self.pipeline_depth = parse_num(key, value)?,
            "cache_enabled" => self.cache_enabled = parse_bool(key, value)?,
            "residency_enabled" => self.residency_enabled = parse_bool(key, value)?,
            "device_mem_budget" => self.device_mem_budget = parse_bytes(key, value)?,
            "queue_depth" => self.queue_depth = parse_num(key, value)?,
            "store_budget" => self.store_budget = parse_bytes(key, value)?,
            "store_dir" => self.store_dir = value.to_string(),
            "store_enabled" => self.store_enabled = parse_bool(key, value)?,
            "result_cache_enabled" => self.result_cache_enabled = parse_bool(key, value)?,
            "client_store_budget" => self.client_store_budget = parse_bytes(key, value)?,
            "client_queue_depth" => self.client_queue_depth = parse_num(key, value)?,
            "density_threshold" => {
                if value.trim() == "auto" {
                    self.density_threshold_auto = true;
                    self.density_threshold = 0.0;
                } else {
                    self.density_threshold = parse_unit_interval(key, value)?;
                    self.density_threshold_auto = false;
                }
            }
            "device_normmap" => {
                self.device_normmap = parse_bool(key, value)?;
            }
            "sequential_devices" => {
                self.sequential_devices = parse_bool(key, value)?;
            }
            "balance" => {
                self.balance = if value == "rowblock" {
                    Balance::RowBlock
                } else if value == "residency-aware" || value == "residency_aware" {
                    Balance::ResidencyAware
                } else if let Some(s) = value.strip_prefix("strided:") {
                    Balance::Strided(parse_num(key, s)?)
                } else {
                    return Err(Error::Config(format!(
                        "balance must be 'rowblock', 'strided:<s>', or 'residency-aware', \
                         got '{value}'"
                    )));
                };
            }
            _ => return Err(Error::Config(format!("unknown config key '{key}'"))),
        }
        Ok(())
    }

    /// Load a config file and fold it over the defaults.
    pub fn from_file(path: &Path) -> Result<SpammConfig> {
        let mut cfg = SpammConfig::default();
        for (k, v) in parse_config_text(&std::fs::read_to_string(path)?)? {
            cfg.apply(&k, &v)?;
        }
        Ok(cfg)
    }

    /// Validate internal consistency.
    pub fn validate(&self) -> Result<()> {
        if self.lonum == 0 || !self.lonum.is_power_of_two() {
            return Err(Error::Config(format!(
                "lonum must be a power of two, got {}",
                self.lonum
            )));
        }
        if self.devices == 0 {
            return Err(Error::Config("devices must be ≥ 1".into()));
        }
        if self.max_tile_batch == 0 {
            return Err(Error::Config("max_tile_batch must be ≥ 1".into()));
        }
        if self.pipeline_batches == 0 {
            return Err(Error::Config("pipeline_batches must be ≥ 1".into()));
        }
        if self.pipeline_depth == 0 {
            return Err(Error::Config("pipeline_depth must be ≥ 1".into()));
        }
        if let Balance::Strided(0) = self.balance {
            return Err(Error::Config("stride must be ≥ 1".into()));
        }
        if self.residency_enabled && self.device_mem_budget == 0 {
            return Err(Error::Config(
                "device_mem_budget must be non-zero while residency is enabled — device \
                 memory is finite, so size the pool explicitly (e.g. 256m) or disable it \
                 with residency_enabled = false / --no-residency"
                    .into(),
            ));
        }
        if self.queue_depth == 0 {
            return Err(Error::Config("queue_depth must be ≥ 1".into()));
        }
        if !(0.0..=1.0).contains(&self.density_threshold) {
            // NaN fails the range test too: NaN comparisons are false.
            return Err(Error::Config(format!(
                "density_threshold must be in [0, 1], got {}",
                self.density_threshold
            )));
        }
        Ok(())
    }
}

/// Parse an f32 in the closed unit interval [0, 1]; rejects NaN,
/// infinities, and out-of-range values.  Public for CLI flags that share
/// the constraint (`--density-threshold`).
pub fn parse_unit_interval(key: &str, value: &str) -> Result<f32> {
    let x: f32 = value
        .trim()
        .parse()
        .map_err(|_| Error::Config(format!("{key}: expected number in [0, 1], got '{value}'")))?;
    if !(0.0..=1.0).contains(&x) {
        return Err(Error::Config(format!(
            "{key}: expected number in [0, 1], got '{value}'"
        )));
    }
    Ok(x)
}

/// Parse a byte count with an optional `k`/`m`/`g` suffix — the public
/// twin of the config-file parser, for CLI byte-valued options.
pub fn parse_byte_size(key: &str, value: &str) -> Result<usize> {
    parse_bytes(key, value)
}

fn parse_num(key: &str, value: &str) -> Result<usize> {
    value
        .trim()
        .parse()
        .map_err(|_| Error::Config(format!("{key}: expected integer, got '{value}'")))
}

/// Parse a byte count with an optional `k`/`m`/`g` (KiB/MiB/GiB) suffix,
/// e.g. `device_mem_budget = 256m`.
fn parse_bytes(key: &str, value: &str) -> Result<usize> {
    let v = value.trim().to_ascii_lowercase();
    let (digits, mult) = if let Some(d) = v.strip_suffix('k') {
        (d, 1usize << 10)
    } else if let Some(d) = v.strip_suffix('m') {
        (d, 1 << 20)
    } else if let Some(d) = v.strip_suffix('g') {
        (d, 1 << 30)
    } else {
        (v.as_str(), 1)
    };
    digits
        .trim()
        .parse::<usize>()
        .ok()
        .and_then(|n| n.checked_mul(mult))
        .ok_or_else(|| {
            Error::Config(format!(
                "{key}: expected bytes (integer, optional k/m/g suffix), got '{value}'"
            ))
        })
}

fn parse_bool(key: &str, value: &str) -> Result<bool> {
    match value.trim() {
        "true" | "1" | "yes" => Ok(true),
        "false" | "0" | "no" => Ok(false),
        _ => Err(Error::Config(format!("{key}: expected bool, got '{value}'"))),
    }
}

/// Parse `key = value` lines; `#`/`;` comments; `[section]` headers prefix
/// keys as `section.key`; quoted strings unquoted.
pub fn parse_config_text(text: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split(['#', ';']).next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            Error::Config(format!("line {}: expected 'key = value'", lineno + 1))
        })?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let mut val = v.trim().to_string();
        if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
            val = val[1..val.len() - 1].to_string();
        }
        out.push((key, val));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        SpammConfig::default().validate().unwrap();
    }

    #[test]
    fn apply_overrides() {
        let mut c = SpammConfig::default();
        c.apply("devices", "8").unwrap();
        c.apply("precision", "bf16").unwrap();
        c.apply("balance", "strided:2").unwrap();
        assert_eq!(c.devices, 8);
        assert_eq!(c.precision, Precision::Bf16);
        assert_eq!(c.balance, Balance::Strided(2));
        c.apply("balance", "residency-aware").unwrap();
        assert_eq!(c.balance, Balance::ResidencyAware);
        c.apply("balance", "residency_aware").unwrap();
        assert_eq!(c.balance, Balance::ResidencyAware);
        c.validate().unwrap();
    }

    #[test]
    fn pipeline_and_cache_keys() {
        let mut c = SpammConfig::default();
        assert!(c.cache_enabled);
        c.apply("pipeline_depth", "4").unwrap();
        c.apply("cache_enabled", "false").unwrap();
        assert_eq!(c.pipeline_depth, 4);
        assert!(!c.cache_enabled);
        c.validate().unwrap();
        c.pipeline_depth = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn residency_keys_and_byte_suffixes() {
        let mut c = SpammConfig::default();
        assert!(c.residency_enabled);
        assert_eq!(c.device_mem_budget, 256 << 20);
        c.apply("residency_enabled", "false").unwrap();
        assert!(!c.residency_enabled);
        for (v, want) in [
            ("4096", 4096usize),
            ("64k", 64 << 10),
            ("256m", 256 << 20),
            ("2g", 2 << 30),
        ] {
            c.apply("device_mem_budget", v).unwrap();
            assert_eq!(c.device_mem_budget, want, "value '{v}'");
        }
        assert!(c.apply("device_mem_budget", "lots").is_err());
        assert!(c.apply("device_mem_budget", "1.5m").is_err());
        c.validate().unwrap();
    }

    #[test]
    fn zero_device_budget_requires_residency_off() {
        let mut c = SpammConfig::default();
        c.apply("device_mem_budget", "0").unwrap();
        assert!(c.validate().is_err(), "0 budget with residency enabled");
        c.apply("residency_enabled", "false").unwrap();
        c.validate().unwrap();
    }

    #[test]
    fn session_keys_and_validation() {
        let mut c = SpammConfig::default();
        assert_eq!(c.queue_depth, 64);
        assert_eq!(c.store_budget, 1 << 30);
        c.apply("queue_depth", "8").unwrap();
        c.apply("store_budget", "64m").unwrap();
        assert_eq!(c.queue_depth, 8);
        assert_eq!(c.store_budget, 64 << 20);
        c.validate().unwrap();
        c.queue_depth = 0;
        assert!(c.validate().is_err());
        // store_budget 0 = unlimited is fine.
        c.queue_depth = 1;
        c.store_budget = 0;
        c.validate().unwrap();
    }

    #[test]
    fn serve_keys() {
        let mut c = SpammConfig::default();
        assert!(c.result_cache_enabled);
        assert_eq!(c.client_store_budget, 0);
        assert_eq!(c.client_queue_depth, 0);
        c.apply("result_cache_enabled", "false").unwrap();
        c.apply("client_store_budget", "64k").unwrap();
        c.apply("client_queue_depth", "2").unwrap();
        assert!(!c.result_cache_enabled);
        assert_eq!(c.client_store_budget, 64 << 10);
        assert_eq!(c.client_queue_depth, 2);
        c.validate().unwrap();
        assert!(c.apply("client_store_budget", "lots").is_err());
        assert!(c.apply("client_queue_depth", "-1").is_err());
    }

    #[test]
    fn density_threshold_key_and_bounds() {
        let mut c = SpammConfig::default();
        assert_eq!(c.density_threshold, 0.0);
        c.apply("density_threshold", "0.25").unwrap();
        assert_eq!(c.density_threshold, 0.25);
        c.validate().unwrap();
        for bad in ["-0.1", "1.5", "NaN", "inf", "lots"] {
            assert!(c.apply("density_threshold", bad).is_err(), "accepted '{bad}'");
        }
        // Out-of-range values set directly still fail validation.
        c.density_threshold = f32::NAN;
        assert!(c.validate().is_err());
        c.density_threshold = 2.0;
        assert!(c.validate().is_err());
        c.density_threshold = 1.0;
        c.validate().unwrap();
    }

    #[test]
    fn density_threshold_auto_keyword() {
        let mut c = SpammConfig::default();
        assert!(!c.density_threshold_auto);
        c.apply("density_threshold", "auto").unwrap();
        assert!(c.density_threshold_auto);
        assert_eq!(c.density_threshold, 0.0);
        c.validate().unwrap();
        // An explicit value switches auto back off.
        c.apply("density_threshold", "0.25").unwrap();
        assert!(!c.density_threshold_auto);
        assert_eq!(c.density_threshold, 0.25);
    }

    #[test]
    fn rejects_bad_values() {
        let mut c = SpammConfig::default();
        assert!(c.apply("devices", "lots").is_err());
        assert!(c.apply("precision", "f8").is_err());
        assert!(c.apply("balance", "zigzag").is_err());
        assert!(c.apply("nonsense", "1").is_err());
    }

    #[test]
    fn invalid_configs_fail_validation() {
        let mut c = SpammConfig::default();
        c.lonum = 33;
        assert!(c.validate().is_err());
        let mut c = SpammConfig::default();
        c.devices = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn config_text_parses() {
        let text = r#"
            # comment
            lonum = 64
            precision = "bf16"   ; trailing comment
            [run]
            devices = 4
        "#;
        let kv = parse_config_text(text).unwrap();
        assert_eq!(
            kv,
            vec![
                ("lonum".to_string(), "64".to_string()),
                ("precision".to_string(), "bf16".to_string()),
                ("run.devices".to_string(), "4".to_string()),
            ]
        );
    }

    #[test]
    fn config_text_bad_line() {
        assert!(parse_config_text("just words").is_err());
    }
}
