//! Expression-plan dataflow analysis: liveness, fingerprint uniqueness,
//! acyclicity, shape coherence, placement coverage, and pinned-schedule
//! soundness over a prepared [`ExprPlan`].

use std::collections::HashMap;

use crate::coordinator::expr::{ExprPlan, NodeKind};

use super::{audit_schedule, AuditKind, AuditLayer, AuditReport};

/// Statically verify a prepared expression plan (see module docs of
/// [`crate::audit`]).  The liveness model mirrors the executor exactly:
/// each node's value is retired after `uses` consumption events, where
/// the events are its consumers plus one extra for the root and each
/// kept node — a stored count above that leaks the intermediate's
/// resident tiles forever; a count below frees them before the last
/// consumer reads them.
pub fn audit_expr_plan(plan: &ExprPlan) -> AuditReport {
    let mut r = AuditReport::default();
    let nodes = &plan.nodes;
    let n = nodes.len();

    r.checks += 1;
    if plan.root >= n {
        r.push(
            AuditLayer::ExprPlan,
            AuditKind::DanglingInput,
            None,
            Some(plan.root),
            None,
            format!("root references node {} of {n}", plan.root),
        );
        return r;
    }

    // Recompute consumer counts and check acyclicity in one walk: the
    // node list is execution order, so every input must strictly
    // precede its consumer.
    let mut uses = vec![0usize; n];
    for (idx, node) in nodes.iter().enumerate() {
        let inputs: Vec<usize> = match node.kind {
            NodeKind::Operand { .. } => Vec::new(),
            NodeKind::Spamm { a, b, .. } => vec![a.raw(), b.raw()],
            NodeKind::Axpby { x, y, .. } | NodeKind::DiffNorm { x, y } => {
                vec![x.raw(), y.raw()]
            }
            NodeKind::Scale { x, .. } | NodeKind::AddDiag { x, .. } => vec![x.raw()],
        };
        for inp in inputs {
            r.checks += 1;
            if inp >= idx {
                r.push(
                    AuditLayer::ExprPlan,
                    AuditKind::DanglingInput,
                    None,
                    Some(idx),
                    None,
                    format!("node {idx} consumes node {inp}, which does not precede it"),
                );
            } else {
                uses[inp] += 1;
            }
        }
    }
    uses[plan.root] += 1;
    for &k in &plan.keeps {
        if k < n {
            uses[k] += 1;
        } else {
            r.checks += 1;
            r.push(
                AuditLayer::ExprPlan,
                AuditKind::DanglingInput,
                None,
                Some(k),
                None,
                format!("kept node {k} of {n}"),
            );
        }
    }
    for (idx, node) in nodes.iter().enumerate() {
        r.checks += 1;
        if node.uses != uses[idx] {
            let what = if node.uses > uses[idx] {
                "leaked: its resident tiles are never freed"
            } else {
                "freed before its last consumer reads it"
            };
            r.push(
                AuditLayer::ExprPlan,
                AuditKind::UseCountMismatch,
                None,
                Some(idx),
                None,
                format!(
                    "node plans {} retirement events, dataflow has {} — the value is {what}",
                    node.uses, uses[idx]
                ),
            );
        }
    }

    // Derived fingerprints must be unique across *compute* nodes — two
    // intermediates sharing a fingerprint would alias in the residency
    // pool, and retiring one would free the other's tiles.  (Operand
    // nodes may legitimately share: two slots bound to the same operand.)
    let mut seen: HashMap<(u64, u64), usize> = HashMap::new();
    for (idx, node) in nodes.iter().enumerate() {
        if matches!(node.kind, NodeKind::Operand { .. }) {
            continue;
        }
        r.checks += 1;
        if let Some(&prev) = seen.get(&(node.fp.0, node.fp.1)) {
            r.push(
                AuditLayer::ExprPlan,
                AuditKind::FingerprintCollision,
                None,
                Some(idx),
                Some(super::fp_hex(node.fp)),
                format!("derived fingerprint collides with node {prev}"),
            );
        } else {
            seen.insert((node.fp.0, node.fp.1), idx);
        }
    }

    // Shape coherence node by node, plus placement coverage: every
    // compute matrix node's owner map must cover its output grid with
    // in-range devices (the static half of cross-device bounce
    // accounting — execution charges a host bounce exactly when a
    // consumer's owner differs from the producer's, so a missing or
    // ill-sized map breaks that attribution).
    for (idx, node) in nodes.iter().enumerate() {
        match node.kind {
            NodeKind::Operand { .. } => {}
            NodeKind::Spamm { a, b, .. } => {
                if a.raw() < idx && b.raw() < idx {
                    let (pa, pb) = (&nodes[a.raw()], &nodes[b.raw()]);
                    r.checks += 1;
                    if pa.cols != pb.rows
                        || node.rows != pa.rows
                        || node.cols != pb.cols
                        || node.tile_rows != pa.tile_rows
                        || node.tile_cols != pb.tile_cols
                    {
                        r.push(
                            AuditLayer::ExprPlan,
                            AuditKind::ShapeMismatch,
                            None,
                            Some(idx),
                            None,
                            format!(
                                "spamm {}x{} · {}x{} planned as {}x{}",
                                pa.rows, pa.cols, pb.rows, pb.cols, node.rows, node.cols
                            ),
                        );
                    }
                }
            }
            NodeKind::Axpby { x, y, .. } | NodeKind::DiffNorm { x, y } => {
                if x.raw() < idx && y.raw() < idx {
                    let (px, py) = (&nodes[x.raw()], &nodes[y.raw()]);
                    r.checks += 1;
                    if px.rows != py.rows || px.cols != py.cols {
                        r.push(
                            AuditLayer::ExprPlan,
                            AuditKind::ShapeMismatch,
                            None,
                            Some(idx),
                            None,
                            format!(
                                "element-wise inputs {}x{} vs {}x{}",
                                px.rows, px.cols, py.rows, py.cols
                            ),
                        );
                    }
                }
            }
            NodeKind::Scale { x, .. } => {
                if x.raw() < idx {
                    let px = &nodes[x.raw()];
                    r.checks += 1;
                    if node.rows != px.rows || node.cols != px.cols {
                        r.push(
                            AuditLayer::ExprPlan,
                            AuditKind::ShapeMismatch,
                            None,
                            Some(idx),
                            None,
                            format!(
                                "scale of {}x{} planned as {}x{}",
                                px.rows, px.cols, node.rows, node.cols
                            ),
                        );
                    }
                }
            }
            NodeKind::AddDiag { x, .. } => {
                r.checks += 1;
                if node.rows != node.cols {
                    r.push(
                        AuditLayer::ExprPlan,
                        AuditKind::ShapeMismatch,
                        None,
                        Some(idx),
                        None,
                        format!("add_diag on non-square {}x{}", node.rows, node.cols),
                    );
                }
                let _ = x;
            }
        }
        // Placement maps: required on every compute matrix node.
        let is_compute_matrix = !matches!(
            node.kind,
            NodeKind::Operand { .. } | NodeKind::DiffNorm { .. }
        );
        if is_compute_matrix {
            r.checks += 1;
            match &node.owner {
                None => r.push(
                    AuditLayer::ExprPlan,
                    AuditKind::OwnerMapMismatch,
                    None,
                    Some(idx),
                    None,
                    "compute node carries no tile->device placement map".into(),
                ),
                Some(o) => {
                    if o.len() != node.tile_rows * node.tile_cols {
                        r.push(
                            AuditLayer::ExprPlan,
                            AuditKind::OwnerMapMismatch,
                            None,
                            Some(idx),
                            None,
                            format!(
                                "placement map covers {} tiles, node output has {}",
                                o.len(),
                                node.tile_rows * node.tile_cols
                            ),
                        );
                    }
                    for (t, &d) in o.iter().enumerate() {
                        r.checks += 1;
                        if d >= plan.devices {
                            r.push(
                                AuditLayer::ExprPlan,
                                AuditKind::OwnerOutOfRange,
                                Some((t / node.tile_cols.max(1), t % node.tile_cols.max(1))),
                                Some(idx),
                                None,
                                format!(
                                    "tile placed on device {d}, plan targets {}",
                                    plan.devices
                                ),
                            );
                        }
                    }
                }
            }
        }
        // Pinned schedules were built from the inputs' propagated bounds
        // — recheck them for soundness against those very bounds.
        if let (NodeKind::Spamm { a, b, .. }, Some(sched)) = (&node.kind, &node.sched) {
            if a.raw() < idx && b.raw() < idx {
                if let (Some(na), Some(nb)) =
                    (&nodes[a.raw()].bound, &nodes[b.raw()].bound)
                {
                    let mut sub = audit_schedule(na, nb, node.tau, node.dt, sched);
                    for v in &mut sub.violations {
                        v.layer = AuditLayer::ExprPlan;
                        v.index = Some(idx);
                    }
                    r.merge(sub);
                }
            }
        }
    }
    r
}
