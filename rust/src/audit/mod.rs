//! Cross-layer invariant auditor: static verification of schedules,
//! expression plans, residency state, and the warm store.
//!
//! Every fast path in the crate — schedule *repair* instead of rebuild,
//! normmap patching, pool re-keying, warm-store restores — is validated
//! end-to-end by bitwise-identity tests, which prove *the output happened
//! to match* but say nothing about the structural invariants those paths
//! must preserve.  This module re-derives the invariants from first
//! principles and checks the artifacts **without executing anything**:
//!
//! * **Schedule soundness** ([`audit_schedule`]) — for a
//!   (NormMap_A, NormMap_B, τ, density-threshold, [`Schedule`]) tuple:
//!   every culled product violates the paper's bound
//!   ‖A_ik‖·‖B_kj‖ ≥ τ, every survivor satisfies it, every
//!   [`TileStrategy`] tag agrees with the density census, and packed
//!   runs are genuinely consecutive (≥ 2).  The checker is a deliberate
//!   independent reimplementation — it never calls [`Schedule::build`]
//!   or `Schedule::repair`, so a bug in the builder cannot hide from it.
//! * **Assignment exclusivity** ([`audit_assignment`]) — every output
//!   tile is owned by exactly one in-range device.
//! * **Expression-plan dataflow** ([`audit_expr_plan`]) — liveness over
//!   the planned node list: use counts free every resident intermediate
//!   at its last consumer (no leak, no use-after-free), derived
//!   fingerprints are unique and the dataflow acyclic, shapes are
//!   coherent, and per-node placement maps cover the node's full output
//!   grid with in-range owners (the static half of cross-device bounce
//!   accounting).  Pinned node schedules are re-checked for soundness
//!   against the propagated bounds.
//! * **Residency accounting** ([`audit_pool`]) — the pool's byte counter
//!   equals the sum of resident payload bytes exactly, and every pinned
//!   operand fingerprint belongs to a live plan.
//! * **Warm-store integrity** ([`audit_store`]) — manifest/object
//!   cross-checks (schema version, readability, byte size, 128-bit
//!   checksum).  This is the *one* implementation of store verification:
//!   [`crate::store::WarmStore::verify`] (and `cuspamm store verify`)
//!   delegate here.
//!
//! Violations come back as a structured [`AuditReport`] — kind, layer,
//! tile/node coordinates — and publish `spamm.audit.*` telemetry.  Under
//! `cfg(debug_assertions)` the session/coordinator front-ends run these
//! checks at the end of every `prepare`/`submit`/`update`, so the whole
//! test suite doubles as an audit fuzzer; release builds compile the
//! hooks out and pay nothing unless `cuspamm audit` asks explicitly.

use std::collections::{HashMap, HashSet};
use std::fmt;

use crate::runtime::residency::{ResidencyPool, TileFormat};
use crate::spamm::balance::Assignment;
use crate::spamm::cache::Fingerprint;
use crate::spamm::normmap::NormMap;
use crate::spamm::schedule::{Schedule, TileStrategy};
use crate::store::WarmStore;
use crate::telemetry;

mod expr;

pub use expr::audit_expr_plan;

/// Which artifact layer a violation was found in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AuditLayer {
    Schedule,
    Assignment,
    ExprPlan,
    Residency,
    Store,
}

impl AuditLayer {
    pub fn as_str(self) -> &'static str {
        match self {
            AuditLayer::Schedule => "schedule",
            AuditLayer::Assignment => "assignment",
            AuditLayer::ExprPlan => "expr_plan",
            AuditLayer::Residency => "residency",
            AuditLayer::Store => "store",
        }
    }
}

impl fmt::Display for AuditLayer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Violation class.  Mutation tests assert one kind per seeded
/// corruption, so these stay fine-grained.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AuditKind {
    /// Grid dimensions of an artifact disagree with its operands.
    ShapeMismatch,
    /// A surviving product's norm bound falls below τ (it should have
    /// been culled).
    SpuriousProduct,
    /// A culled product meets the τ bound (it should have survived).
    MissedProduct,
    /// A k-list is not strictly ascending, out of range, or its
    /// strategy list has a different length.
    MalformedKList,
    /// A Dense/Sparse tag disagrees with the density census.
    StrategyMismatch,
    /// A Packed tag outside a genuine consecutive run of ≥ 2
    /// sparse-eligible products (or a run left un-promoted / split).
    BrokenPackedRun,
    /// A tile owner index ≥ the device count.
    OwnerOutOfRange,
    /// An owner map is missing or does not cover the output grid
    /// exactly once per tile.
    OwnerMapMismatch,
    /// A planned node's use count disagrees with its recomputed
    /// consumer count (leak if too high, use-after-free if too low).
    UseCountMismatch,
    /// A node consumes a node that does not precede it (cycle or
    /// dangling reference).
    DanglingInput,
    /// Two distinct compute nodes derived the same fingerprint — their
    /// pool tiles would alias and retire each other's data.
    FingerprintCollision,
    /// Pool byte counter differs from the sum of resident payloads.
    ByteAccounting,
    /// A pinned operand fingerprint belongs to no live plan.
    OrphanPin,
    /// Store payload written under a different schema version.
    StoreSchema,
    /// Store payload missing or unreadable.
    StoreUnreadable,
    /// Store payload size differs from its manifest entry.
    StoreSizeMismatch,
    /// Store payload checksum differs from its manifest entry.
    StoreChecksum,
}

/// One structural violation: kind, layer, and the coordinates needed to
/// find it (output tile, k/node index, store key or fingerprint).
#[derive(Clone, Debug)]
pub struct Violation {
    pub layer: AuditLayer,
    pub kind: AuditKind,
    /// Output-tile coordinate, when the violation is tile-local.
    pub tile: Option<(usize, usize)>,
    /// k index (schedule products) or node index (expression plans).
    pub index: Option<usize>,
    /// Store key or operand fingerprint, when applicable.
    pub key: Option<String>,
    pub detail: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {:?}", self.layer, self.kind)?;
        if let Some((i, j)) = self.tile {
            write!(f, " tile ({i},{j})")?;
        }
        if let Some(k) = self.index {
            write!(f, " index {k}")?;
        }
        if let Some(key) = &self.key {
            write!(f, " key {key}")?;
        }
        write!(f, ": {}", self.detail)
    }
}

/// Result of one audit pass: how many facts were checked and every
/// violation found.  Merge reports from several checkers with
/// [`AuditReport::merge`]; publish counters with
/// [`AuditReport::publish`].
#[derive(Clone, Debug, Default)]
pub struct AuditReport {
    /// Individual facts verified (products, tags, tiles, nodes, store
    /// entries) — a clean report with zero checks proves nothing.
    pub checks: usize,
    pub violations: Vec<Violation>,
}

impl AuditReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    pub fn merge(&mut self, other: AuditReport) {
        self.checks += other.checks;
        self.violations.extend(other.violations);
    }

    /// First violation of `kind`, if any (mutation-test surface).
    pub fn find(&self, kind: AuditKind) -> Option<&Violation> {
        self.violations.iter().find(|v| v.kind == kind)
    }

    /// Record this report on the global `spamm.audit.*` telemetry
    /// counters (reports, checks, violations, and per-layer violation
    /// counts).  Returns `self.ok()` for call-site convenience.
    pub fn publish(&self) -> bool {
        let t = telemetry::global();
        t.add("spamm.audit.reports", 1);
        t.add("spamm.audit.checks", self.checks as u64);
        t.add("spamm.audit.violations", self.violations.len() as u64);
        for v in &self.violations {
            t.add(&format!("spamm.audit.{}.violations", v.layer), 1);
        }
        self.ok()
    }

    fn push(
        &mut self,
        layer: AuditLayer,
        kind: AuditKind,
        tile: Option<(usize, usize)>,
        index: Option<usize>,
        key: Option<String>,
        detail: String,
    ) {
        self.violations.push(Violation {
            layer,
            kind,
            tile,
            index,
            key,
            detail,
        });
    }
}

/// Panic (debug builds' always-on hooks) with every violation listed.
/// Publishes the report's telemetry either way.
pub fn debug_assert_clean(report: &AuditReport, what: &str) {
    report.publish();
    assert!(
        report.ok(),
        "audit({what}): {} violation(s) over {} checks:\n{}",
        report.violations.len(),
        report.checks,
        report
            .violations
            .iter()
            .map(|v| format!("  {v}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

fn fp_hex(fp: Fingerprint) -> String {
    format!("{:016x}{:016x}", fp.0, fp.1)
}

// ---------------------------------------------------------------------
// Schedule soundness
// ---------------------------------------------------------------------

/// The expected strategy tags for one output tile's surviving k-list,
/// re-derived from the density census alone.  Deliberately independent
/// of `spamm::schedule::tile_strategies`: Sparse iff both operand tile
/// densities are strictly below the threshold, then every maximal run of
/// ≥ 2 consecutive Sparse entries is promoted to Packed; a threshold
/// ≤ 0 disables routing entirely (all Dense).
fn expected_strategies(
    na: &NormMap,
    nb: &NormMap,
    density_threshold: f32,
    i: usize,
    j: usize,
    ks: &[u32],
) -> Vec<TileStrategy> {
    if density_threshold <= 0.0 {
        return vec![TileStrategy::Dense; ks.len()];
    }
    let eligible: Vec<bool> = ks
        .iter()
        .map(|&k| {
            let k = k as usize;
            na.density[(i, k)] < density_threshold && nb.density[(k, j)] < density_threshold
        })
        .collect();
    let mut out = Vec::with_capacity(ks.len());
    let mut pos = 0;
    while pos < eligible.len() {
        if !eligible[pos] {
            out.push(TileStrategy::Dense);
            pos += 1;
            continue;
        }
        let mut end = pos;
        while end < eligible.len() && eligible[end] {
            end += 1;
        }
        let tag = if end - pos >= 2 {
            TileStrategy::Packed
        } else {
            TileStrategy::Sparse
        };
        out.extend(std::iter::repeat(tag).take(end - pos));
        pos = end;
    }
    out
}

/// Recheck a compacted schedule against the artifacts it was built from.
///
/// Independent reimplementation of the culling rule (Algorithm 1 line 7:
/// a product survives iff ‖A_ik‖·‖B_kj‖ ≥ τ, inclusive) and the
/// density-adaptive tagging rule — no call into `Schedule::build`,
/// `build_adaptive`, or `repair`.
pub fn audit_schedule(
    na: &NormMap,
    nb: &NormMap,
    tau: f32,
    density_threshold: f32,
    s: &Schedule,
) -> AuditReport {
    let mut r = AuditReport::default();
    let (tr, tk) = (na.norms.rows(), na.norms.cols());
    let (tkb, tc) = (nb.norms.rows(), nb.norms.cols());
    r.checks += 1;
    if tk != tkb {
        r.push(
            AuditLayer::Schedule,
            AuditKind::ShapeMismatch,
            None,
            None,
            None,
            format!("normmaps disagree on the inner grid: A is {tr}x{tk}, B is {tkb}x{tc}"),
        );
        return r;
    }
    r.checks += 1;
    if s.tile_rows != tr || s.tile_cols != tc || s.tile_k != tk {
        r.push(
            AuditLayer::Schedule,
            AuditKind::ShapeMismatch,
            None,
            None,
            None,
            format!(
                "schedule grid {}x{} (k {}) vs normmap grid {tr}x{tc} (k {tk})",
                s.tile_rows, s.tile_cols, s.tile_k
            ),
        );
        return r;
    }
    r.checks += 1;
    if s.valid_k.len() != tr * tc || s.strategies.len() != tr * tc {
        r.push(
            AuditLayer::Schedule,
            AuditKind::ShapeMismatch,
            None,
            None,
            None,
            format!(
                "schedule has {} k-lists and {} strategy lists for {} output tiles",
                s.valid_k.len(),
                s.strategies.len(),
                tr * tc
            ),
        );
        return r;
    }
    for i in 0..tr {
        for j in 0..tc {
            let slot = i * tc + j;
            let ks = &s.valid_k[slot];
            let tags = &s.strategies[slot];
            r.checks += 1;
            if tags.len() != ks.len() {
                r.push(
                    AuditLayer::Schedule,
                    AuditKind::MalformedKList,
                    Some((i, j)),
                    None,
                    None,
                    format!("{} strategy tags for {} products", tags.len(), ks.len()),
                );
                continue;
            }
            let mut malformed = false;
            for (pos, &k) in ks.iter().enumerate() {
                r.checks += 1;
                if k as usize >= tk || (pos > 0 && ks[pos - 1] >= k) {
                    r.push(
                        AuditLayer::Schedule,
                        AuditKind::MalformedKList,
                        Some((i, j)),
                        Some(k as usize),
                        None,
                        format!("k-list {ks:?} is not strictly ascending within 0..{tk}"),
                    );
                    malformed = true;
                    break;
                }
            }
            if malformed {
                continue;
            }
            // Culling: walk every k once; `ks` is ascending so membership
            // is a single merge pass.
            let mut next = 0usize;
            for k in 0..tk {
                let survived = next < ks.len() && ks[next] as usize == k;
                if survived {
                    next += 1;
                }
                let bound = na.norms[(i, k)] * nb.norms[(k, j)];
                r.checks += 1;
                if survived && !(bound >= tau) {
                    r.push(
                        AuditLayer::Schedule,
                        AuditKind::SpuriousProduct,
                        Some((i, j)),
                        Some(k),
                        None,
                        format!("survivor with ‖A‖·‖B‖ = {bound:e} < τ = {tau:e}"),
                    );
                } else if !survived && bound >= tau {
                    r.push(
                        AuditLayer::Schedule,
                        AuditKind::MissedProduct,
                        Some((i, j)),
                        Some(k),
                        None,
                        format!("culled product with ‖A‖·‖B‖ = {bound:e} ≥ τ = {tau:e}"),
                    );
                }
            }
            // Strategy census + packed-run structure.
            let expected = expected_strategies(na, nb, density_threshold, i, j, ks);
            for (pos, (&got, &want)) in tags.iter().zip(&expected).enumerate() {
                r.checks += 1;
                if got != want {
                    let kind = if got == TileStrategy::Packed || want == TileStrategy::Packed {
                        AuditKind::BrokenPackedRun
                    } else {
                        AuditKind::StrategyMismatch
                    };
                    r.push(
                        AuditLayer::Schedule,
                        kind,
                        Some((i, j)),
                        Some(ks[pos] as usize),
                        None,
                        format!("product tagged {got:?}, census says {want:?}"),
                    );
                }
            }
        }
    }
    r
}

/// Structural equality of two schedules — same survivors and same
/// strategy tags per output tile.  The repair≡rebuild satellite check:
/// after `Schedule::repair`, the repaired schedule must be structurally
/// identical to a fresh `build_adaptive` at the same τ/threshold, not
/// just produce the same bits.
pub fn schedule_structural_diff(repaired: &Schedule, fresh: &Schedule) -> AuditReport {
    let mut r = AuditReport::default();
    r.checks += 1;
    if (repaired.tile_rows, repaired.tile_cols, repaired.tile_k)
        != (fresh.tile_rows, fresh.tile_cols, fresh.tile_k)
    {
        r.push(
            AuditLayer::Schedule,
            AuditKind::ShapeMismatch,
            None,
            None,
            None,
            format!(
                "grids differ: {}x{} (k {}) vs {}x{} (k {})",
                repaired.tile_rows,
                repaired.tile_cols,
                repaired.tile_k,
                fresh.tile_rows,
                fresh.tile_cols,
                fresh.tile_k
            ),
        );
        return r;
    }
    for i in 0..fresh.tile_rows {
        for j in 0..fresh.tile_cols {
            let slot = i * fresh.tile_cols + j;
            r.checks += 2;
            if repaired.valid_k[slot] != fresh.valid_k[slot] {
                r.push(
                    AuditLayer::Schedule,
                    AuditKind::MissedProduct,
                    Some((i, j)),
                    None,
                    None,
                    format!(
                        "survivor lists differ: repaired {:?} vs fresh {:?}",
                        repaired.valid_k[slot], fresh.valid_k[slot]
                    ),
                );
            } else if repaired.strategies[slot] != fresh.strategies[slot] {
                r.push(
                    AuditLayer::Schedule,
                    AuditKind::StrategyMismatch,
                    Some((i, j)),
                    None,
                    None,
                    format!(
                        "strategy tags differ: repaired {:?} vs fresh {:?}",
                        repaired.strategies[slot], fresh.strategies[slot]
                    ),
                );
            }
        }
    }
    r
}

// ---------------------------------------------------------------------
// Assignment exclusivity
// ---------------------------------------------------------------------

/// Every output tile of the schedule must be owned by exactly one
/// in-range device.  The owner vector makes multiple ownership
/// unrepresentable, so the checkable facts are coverage (one entry per
/// tile) and range.
pub fn audit_assignment(s: &Schedule, asg: &Assignment) -> AuditReport {
    let mut r = AuditReport::default();
    let tiles = s.tile_rows * s.tile_cols;
    r.checks += 1;
    if asg.owner.len() != tiles {
        r.push(
            AuditLayer::Assignment,
            AuditKind::OwnerMapMismatch,
            None,
            None,
            None,
            format!("owner map covers {} tiles, schedule has {tiles}", asg.owner.len()),
        );
        return r;
    }
    r.checks += 1;
    if asg.devices == 0 {
        r.push(
            AuditLayer::Assignment,
            AuditKind::OwnerMapMismatch,
            None,
            None,
            None,
            "assignment declares zero devices".into(),
        );
        return r;
    }
    for (t, &d) in asg.owner.iter().enumerate() {
        r.checks += 1;
        if d >= asg.devices {
            r.push(
                AuditLayer::Assignment,
                AuditKind::OwnerOutOfRange,
                Some((t / s.tile_cols, t % s.tile_cols)),
                None,
                None,
                format!("tile owned by device {d}, only {} exist", asg.devices),
            );
        }
    }
    r
}

// ---------------------------------------------------------------------
// Residency accounting
// ---------------------------------------------------------------------

/// Audit one device pool's accounting against a live-operand set:
/// the byte counter must equal the sum of resident payload bytes
/// exactly, and every pinned operand fingerprint must belong to a live
/// plan (`live` = the union of operand/leaf fingerprints of every
/// prepared plan pinned on this device).  Pass `None` for `live` to
/// skip the pin-ownership check (pool-only audits with no plan table in
/// scope).
pub fn audit_pool(pool: &ResidencyPool, live: Option<&HashSet<Fingerprint>>) -> AuditReport {
    let mut r = AuditReport::default();
    let snap = pool.audit_snapshot();
    let expected: usize = snap
        .tiles
        .iter()
        .map(|t| t.payload_len * std::mem::size_of::<f32>())
        .sum();
    r.checks += 1;
    if snap.bytes != expected {
        r.push(
            AuditLayer::Residency,
            AuditKind::ByteAccounting,
            None,
            None,
            None,
            format!(
                "pool accounts {} bytes, {} resident payloads sum to {expected}",
                snap.bytes,
                snap.tiles.len()
            ),
        );
    }
    for &(fp, count) in &snap.pinned {
        r.checks += 1;
        if count == 0 {
            r.push(
                AuditLayer::Residency,
                AuditKind::OrphanPin,
                None,
                None,
                Some(fp_hex(fp)),
                "pin entry with zero count survived unpinning".into(),
            );
        } else if let Some(live) = live {
            if !live.contains(&fp) {
                r.push(
                    AuditLayer::Residency,
                    AuditKind::OrphanPin,
                    None,
                    None,
                    Some(fp_hex(fp)),
                    format!("operand pinned {count}x but referenced by no live plan"),
                );
            }
        }
    }
    // Dense payloads must all be full tiles of one LoNum² size; packed
    // payloads are variable-length COO.  A dense payload whose length
    // disagrees with its siblings indicates a staging-layer bug.
    let mut dense_len: Option<usize> = None;
    for t in &snap.tiles {
        if t.fmt != TileFormat::Dense {
            continue;
        }
        r.checks += 1;
        match dense_len {
            None => dense_len = Some(t.payload_len),
            Some(l) if l == t.payload_len => {}
            Some(l) => r.push(
                AuditLayer::Residency,
                AuditKind::ByteAccounting,
                Some((t.tile.0, t.tile.1)),
                None,
                Some(fp_hex(t.op)),
                format!("dense payload of {} f32s among {l}-element tiles", t.payload_len),
            ),
        }
    }
    r
}

// ---------------------------------------------------------------------
// Warm-store integrity
// ---------------------------------------------------------------------

/// Cross-check every manifest entry against its on-disk object: schema
/// version, readability, byte size, and the 128-bit content checksum.
/// [`WarmStore::verify`] (and `cuspamm store verify`) delegate to this —
/// store verification has exactly one implementation.
pub fn audit_store(store: &WarmStore) -> AuditReport {
    let mut r = AuditReport::default();
    let entries = match store.entries() {
        Ok(e) => e,
        Err(e) => {
            r.checks += 1;
            r.push(
                AuditLayer::Store,
                AuditKind::StoreUnreadable,
                None,
                None,
                Some("manifest".into()),
                format!("manifest unreadable: {e}"),
            );
            return r;
        }
    };
    for (key, e) in &entries {
        r.checks += 1;
        if e.version != crate::store::SCHEMA_VERSION {
            r.push(
                AuditLayer::Store,
                AuditKind::StoreSchema,
                None,
                None,
                Some(key.clone()),
                format!(
                    "schema version {} (store is at {})",
                    e.version,
                    crate::store::SCHEMA_VERSION
                ),
            );
            continue;
        }
        let (bytes, sum) = match store.payload_digest(e) {
            Ok(d) => d,
            Err(err) => {
                r.push(
                    AuditLayer::Store,
                    AuditKind::StoreUnreadable,
                    None,
                    None,
                    Some(key.clone()),
                    format!("unreadable: {err}"),
                );
                continue;
            }
        };
        if bytes != e.bytes {
            r.push(
                AuditLayer::Store,
                AuditKind::StoreSizeMismatch,
                None,
                None,
                Some(key.clone()),
                format!("payload is {bytes} bytes, manifest says {}", e.bytes),
            );
            continue;
        }
        if sum != e.checksum {
            r.push(
                AuditLayer::Store,
                AuditKind::StoreChecksum,
                None,
                None,
                Some(key.clone()),
                "checksum mismatch".into(),
            );
        }
    }
    r
}

// ---------------------------------------------------------------------
// Plan-level composition helpers
// ---------------------------------------------------------------------

/// Audit a prepared multiply plan: schedule soundness against the
/// operand normmaps plus assignment exclusivity, with the assignment's
/// device set cross-checked against `pin_devices` (the pools the plan
/// pinned its operands into must be exactly the devices that own
/// tiles).
pub fn audit_multiply_plan(
    na: &NormMap,
    nb: &NormMap,
    tau: f32,
    density_threshold: f32,
    schedule: &Schedule,
    assignment: &Assignment,
    pin_devices: &[usize],
) -> AuditReport {
    let mut r = audit_schedule(na, nb, tau, density_threshold, schedule);
    r.merge(audit_assignment(schedule, assignment));
    let owners: HashSet<usize> = assignment.owner.iter().copied().collect();
    let pinned: HashSet<usize> = pin_devices.iter().copied().collect();
    r.checks += 1;
    if owners != pinned {
        let mut o: Vec<_> = owners.iter().copied().collect();
        let mut p: Vec<_> = pinned.iter().copied().collect();
        o.sort_unstable();
        p.sort_unstable();
        r.push(
            AuditLayer::Assignment,
            AuditKind::OwnerMapMismatch,
            None,
            None,
            None,
            format!("devices owning tiles {o:?} vs devices pinned {p:?}"),
        );
    }
    r
}

/// Audit a set of device pools against the union of live-plan operand
/// fingerprints per device (`live[d]` = fingerprints any live plan has
/// pinned on device `d`).
pub fn audit_pools(
    pools: &[std::sync::Arc<ResidencyPool>],
    live: &HashMap<usize, HashSet<Fingerprint>>,
) -> AuditReport {
    let mut r = AuditReport::default();
    static EMPTY: std::sync::OnceLock<HashSet<Fingerprint>> = std::sync::OnceLock::new();
    let empty = EMPTY.get_or_init(HashSet::new);
    for (d, pool) in pools.iter().enumerate() {
        r.merge(audit_pool(pool, Some(live.get(&d).unwrap_or(empty))));
    }
    r
}

#[cfg(test)]
mod tests;
