//! Mutation tests needing crate-private access: expression-plan
//! corruption (the plan's node fields are `pub(crate)`) and pool
//! byte-counter corruption (test-only hook).  Schedule, store, and
//! orphan-pin mutations live in `tests/audit.rs` against the public API.

use std::collections::HashSet;

use super::*;
use crate::config::SpammConfig;
use crate::coordinator::expr::{ExprGraph, ExprPlan};
use crate::coordinator::Approx;
use crate::matrix::Matrix;
use crate::spamm::cache::ExecCaches;

fn prepared_plan() -> ExprPlan {
    let cfg = SpammConfig::default();
    let caches = ExecCaches::new();
    let a = Matrix::decay_algebraic(2 * cfg.lonum, 0.1, 0.1, 7);
    let mut g = ExprGraph::new();
    let leaf = g.operand();
    let c2 = g.spamm(leaf, leaf, Approx::Tau(1e-6));
    let c3 = g.spamm(c2, leaf, Approx::Tau(1e-6));
    g.output(c3);
    g.prepare_placed(&caches, &cfg, &[], &[crate::coordinator::ExprSource::Host(&a)])
        .expect("host-side prepare")
}

#[test]
fn prepared_expr_plan_audits_clean() {
    let plan = prepared_plan();
    let r = audit_expr_plan(&plan);
    assert!(r.ok(), "clean plan flagged: {:?}", r.violations);
    assert!(r.checks > 0, "a clean report must have checked something");
}

#[test]
fn leaked_intermediate_is_caught() {
    let mut plan = prepared_plan();
    // Bump the intermediate's retirement count: the executor would wait
    // for a consumption event that never comes, leaking its tiles.
    let mid = plan
        .nodes
        .iter()
        .position(|n| n.uses > 0 && n.sched.is_some())
        .expect("plan has a spamm intermediate");
    plan.nodes[mid].uses += 1;
    let r = audit_expr_plan(&plan);
    let v = r
        .find(AuditKind::UseCountMismatch)
        .expect("leak not detected");
    assert_eq!(v.index, Some(mid));
    assert!(v.detail.contains("leaked"), "detail: {}", v.detail);
}

#[test]
fn free_before_last_use_is_caught() {
    let mut plan = prepared_plan();
    let mid = plan
        .nodes
        .iter()
        .position(|n| n.uses > 1)
        .or_else(|| plan.nodes.iter().position(|n| n.uses > 0))
        .expect("plan has a consumed node");
    plan.nodes[mid].uses -= 1;
    let r = audit_expr_plan(&plan);
    let v = r
        .find(AuditKind::UseCountMismatch)
        .expect("premature free not detected");
    assert_eq!(v.index, Some(mid));
    assert!(v.detail.contains("freed before"), "detail: {}", v.detail);
}

#[test]
fn duplicate_derived_fingerprint_is_caught() {
    let mut plan = prepared_plan();
    let computes: Vec<usize> = plan
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| n.sched.is_some())
        .map(|(i, _)| i)
        .collect();
    assert!(computes.len() >= 2, "need two compute nodes to collide");
    plan.nodes[computes[1]].fp = plan.nodes[computes[0]].fp;
    let r = audit_expr_plan(&plan);
    let v = r
        .find(AuditKind::FingerprintCollision)
        .expect("fingerprint collision not detected");
    assert_eq!(v.index, Some(computes[1]));
}

#[test]
fn missing_placement_map_is_caught() {
    let mut plan = prepared_plan();
    let mid = plan
        .nodes
        .iter()
        .position(|n| n.owner.is_some())
        .expect("plan has a placed compute node");
    plan.nodes[mid].owner = None;
    let r = audit_expr_plan(&plan);
    assert!(
        r.find(AuditKind::OwnerMapMismatch).is_some(),
        "missing placement map not detected: {:?}",
        r.violations
    );
}

#[test]
fn pool_byte_accounting_corruption_is_caught() {
    let pool = crate::runtime::residency::ResidencyPool::new(1 << 20);
    assert!(audit_pool(&pool, None).ok(), "fresh pool must audit clean");
    pool.corrupt_bytes_for_test(123);
    let r = audit_pool(&pool, None);
    assert!(
        r.find(AuditKind::ByteAccounting).is_some(),
        "byte-counter corruption not detected: {:?}",
        r.violations
    );
}

#[test]
fn pin_without_live_plan_is_caught() {
    let pool = crate::runtime::residency::ResidencyPool::new(1 << 20);
    let fp = Fingerprint(0xdead, 0xbeef);
    pool.pin_operand(fp);
    // With no live-set the pin is unaccountable but legal...
    assert!(audit_pool(&pool, None).ok());
    // ...against an (empty) live-plan set it is an orphan.
    let live: HashSet<Fingerprint> = HashSet::new();
    let r = audit_pool(&pool, Some(&live));
    let v = r.find(AuditKind::OrphanPin).expect("orphan pin not detected");
    assert_eq!(v.key.as_deref(), Some(fp_hex(fp).as_str()));
    // A pin that belongs to a live plan is clean.
    let live: HashSet<Fingerprint> = [fp].into_iter().collect();
    assert!(audit_pool(&pool, Some(&live)).ok());
}
