//! Small shared utilities: bf16 arithmetic, a seedable PRNG, and
//! statistics helpers (all substrates — the offline crate set ships none
//! of these).

pub mod bf16;
pub mod prng;
pub mod stats;

/// Round `x` up to the next multiple of `m` (m > 0).
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Integer ceiling division.
pub fn div_ceil(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 32), 0);
        assert_eq!(round_up(1, 32), 32);
        assert_eq!(round_up(32, 32), 32);
        assert_eq!(round_up(33, 32), 64);
    }

    #[test]
    fn div_ceil_basics() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
    }
}
