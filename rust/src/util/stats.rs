//! Summary statistics for the benchmark harness (criterion is not in the
//! offline crate set).

/// Summary of a sample of timings/values.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p95: f64,
}

impl Summary {
    pub fn from(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            mean,
            median: percentile(&sorted, 50.0),
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p95: percentile(&sorted, 95.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_constant() {
        let s = Summary::from(&[2.0, 2.0, 2.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.median, 2.0);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn summary_known() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert_eq!(percentile(&v, 0.0), 0.0);
        assert_eq!(percentile(&v, 50.0), 5.0);
        assert_eq!(percentile(&v, 100.0), 10.0);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        let _ = Summary::from(&[]);
    }
}
