//! Minimal bfloat16 support (no `half` crate in the offline set).
//!
//! bf16 is the TPU MXU's native operand format and our stand-in for the
//! paper's fp16 tensor-core inputs.  Conversion uses round-to-nearest-even,
//! matching XLA's `convert` semantics so host-side error analysis agrees
//! with what the artifacts compute.

/// A bfloat16 value stored as its raw bit pattern.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub struct Bf16(pub u16);

impl Bf16 {
    pub const ZERO: Bf16 = Bf16(0);

    /// Convert from f32 with round-to-nearest-even (XLA semantics).
    #[inline]
    pub fn from_f32(x: f32) -> Self {
        let bits = x.to_bits();
        if x.is_nan() {
            // Preserve NaN, force quiet bit.
            return Bf16(((bits >> 16) as u16) | 0x0040);
        }
        // Round to nearest even on the truncated 16 bits.
        let round_bit = 0x0000_8000u32;
        let lsb = (bits >> 16) & 1;
        let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
        let _ = round_bit;
        Bf16((rounded >> 16) as u16)
    }

    /// Widen back to f32 (exact).
    #[inline]
    pub fn to_f32(self) -> f32 {
        f32::from_bits((self.0 as u32) << 16)
    }
}

/// Round-trip an f32 through bf16 — the "what the MXU sees" operator.
#[inline]
pub fn quantize(x: f32) -> f32 {
    Bf16::from_f32(x).to_f32()
}

/// Quantize a whole slice in place.
pub fn quantize_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = quantize(*x);
    }
}

/// Max relative quantization step of bf16 (8 mantissa bits → 2^-8).
pub const EPS: f32 = 1.0 / 256.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers() {
        for i in -256..=256 {
            let x = i as f32;
            assert_eq!(quantize(x), x, "{x}");
        }
    }

    #[test]
    fn relative_error_bounded() {
        let mut x = 1.0e-30f32;
        while x < 1.0e30 {
            let q = quantize(x);
            assert!((q - x).abs() <= x * EPS, "x={x} q={q}");
            x *= 3.7;
        }
    }

    #[test]
    fn round_to_nearest_even() {
        // 1 + 2^-9 is exactly halfway between 1.0 and 1 + 2^-8; RNE → 1.0.
        let x = 1.0 + f32::powi(2.0, -9);
        assert_eq!(quantize(x), 1.0);
        // 1 + 3·2^-9 is halfway between 1+2^-8 and 1+2^-7; RNE → 1+2^-7.
        let x = 1.0 + 3.0 * f32::powi(2.0, -9);
        assert_eq!(quantize(x), 1.0 + f32::powi(2.0, -7));
    }

    #[test]
    fn specials() {
        assert_eq!(quantize(0.0), 0.0);
        assert_eq!(quantize(-0.0), -0.0);
        assert_eq!(quantize(f32::INFINITY), f32::INFINITY);
        assert_eq!(quantize(f32::NEG_INFINITY), f32::NEG_INFINITY);
        assert!(quantize(f32::NAN).is_nan());
    }

    #[test]
    fn negatives_mirror_positives() {
        for &x in &[0.1f32, 1.5, 123.456, 3.0e7] {
            assert_eq!(quantize(-x), -quantize(x));
        }
    }
}
