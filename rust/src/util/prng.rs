//! Seedable PRNG substrate (no `rand` crate offline): SplitMix64 for
//! seeding + xoshiro256** for the stream.  Deterministic across platforms —
//! benchmark workloads and property tests depend on that.

/// xoshiro256** seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to spread a small seed across the state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-12 {
                let u2 = self.next_f32();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        let mut mean = 0.0f64;
        const N: usize = 20_000;
        for _ in 0..N {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            mean += x as f64;
        }
        mean /= N as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        const N: usize = 50_000;
        let xs: Vec<f32> = (0..N).map(|_| r.normal()).collect();
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / N as f64;
        let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>()
            / N as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Rng::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }
}
