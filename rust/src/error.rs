//! Crate-wide error type.

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors produced by the cuspamm runtime and library layers.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Shape or divisibility constraint violated by caller input.
    #[error("shape error: {0}")]
    Shape(String),

    /// An artifact (HLO file, manifest entry, weight blob) is missing or
    /// does not match what the runtime expects.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// XLA/PJRT failure (compile, execute, literal conversion).
    #[error("xla error: {0}")]
    Xla(String),

    /// Config file / CLI parse problem.
    #[error("config error: {0}")]
    Config(String),

    /// JSON syntax or schema problem.
    #[error("json error: {0}")]
    Json(String),

    /// Binary tensor file problem.
    #[error("tensorio error: {0}")]
    TensorIo(String),

    /// Coordinator/device-worker failure (a worker died or a channel
    /// closed unexpectedly).
    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
