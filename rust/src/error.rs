//! Crate-wide error type (hand-rolled — proc-macro derive crates are not
//! in the offline crate set).

use std::fmt;

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

/// Errors produced by the cuspamm runtime and library layers.
#[derive(Debug)]
pub enum Error {
    /// Shape or divisibility constraint violated by caller input.
    Shape(String),

    /// An artifact (HLO file, manifest entry, weight blob) is missing or
    /// does not match what the runtime expects.
    Artifact(String),

    /// XLA/PJRT failure (compile, execute, literal conversion).
    Xla(String),

    /// Config file / CLI parse problem.
    Config(String),

    /// JSON syntax or schema problem.
    Json(String),

    /// Binary tensor file problem.
    TensorIo(String),

    /// Coordinator/device-worker failure (a worker died or a channel
    /// closed unexpectedly).
    Coordinator(String),

    /// Session front-end failure (admission rejected, unknown handle,
    /// worker terminated).
    Session(String),

    /// Warm-start store problem (manifest schema skew, truncated or
    /// corrupt payload).  Always recoverable: the store falls back cold.
    Store(String),

    /// Static invariant audit failure: an artifact (schedule, expression
    /// plan, residency pool, store manifest) violates a cross-layer
    /// invariant that [`crate::audit`] verifies without executing.
    Audit(String),

    /// Wire-protocol violation on the serving tier (bad magic/version,
    /// truncated or oversized frame, unknown message kind, malformed
    /// payload).  Always typed, never a panic: a server replies and a
    /// client surfaces the error instead of dropping the connection
    /// state on the floor.
    Protocol(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::TensorIo(m) => write!(f, "tensorio error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Session(m) => write!(f, "session error: {m}"),
            Error::Store(m) => write!(f, "store error: {m}"),
            Error::Audit(m) => write!(f, "audit error: {m}"),
            Error::Protocol(m) => write!(f, "protocol error: {m}"),
            Error::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
