//! Minimal JSON parser/writer substrate (serde is not in the offline crate
//! set).  Parses the artifact `manifest.json` and the CNN metadata; writes
//! benchmark result files.  Supports the full JSON grammar except `\u`
//! surrogate pairs beyond the BMP (not needed by our producers).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    pub fn as_object(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Ok(m),
            _ => Err(Error::Json(format!("expected object, got {self:?}"))),
        }
    }

    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(a) => Ok(a),
            _ => Err(Error::Json(format!("expected array, got {self:?}"))),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::String(s) => Ok(s),
            _ => Err(Error::Json(format!("expected string, got {self:?}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Number(x) => Ok(*x),
            _ => Err(Error::Json(format!("expected number, got {self:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(Error::Json(format!("expected non-negative integer, got {x}")));
        }
        Ok(x as usize)
    }

    /// Object field access with a path-aware error message.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_object()?
            .get(key)
            .ok_or_else(|| Error::Json(format!("missing key '{key}'")))
    }

    /// Optional field access.
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write_to(&mut s);
        s
    }

    fn write_to(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Number(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Value::String(s) => write_escaped(out, s),
            Value::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_to(out);
                }
                out.push(']');
            }
            Value::Object(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal (wanted {word})")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad \\u codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-1.5e2").unwrap(), Value::Number(-150.0));
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::String("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_array().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str()
                .unwrap(),
            "x"
        );
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Value::parse(r#""é""#).unwrap(),
            Value::String("é".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
        assert!(Value::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"s":"hi\n","t":true}"#;
        let v = Value::parse(src).unwrap();
        let v2 = Value::parse(&v.to_json()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"lonum": 32, "artifacts": [{"name": "dense_n256_f32",
            "file": "dense_n256_f32.hlo.txt", "n_outputs": 1,
            "inputs": [{"shape": [256, 256], "dtype": "f32"}],
            "params": {"precision": "f32"}}]}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(v.get("lonum").unwrap().as_usize().unwrap(), 32);
        let arts = v.get("artifacts").unwrap().as_array().unwrap();
        assert_eq!(
            arts[0].get("inputs").unwrap().as_array().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_array()
                .unwrap()
                .len(),
            2
        );
    }
}
