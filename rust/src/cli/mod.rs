//! Tiny declarative CLI argument parser substrate (clap is not in the
//! offline crate set).  Supports `--flag`, `--key value`, `--key=value`,
//! subcommands and positional arguments, with generated `--help`.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// One declared option.
#[derive(Clone, Debug)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// Declarative command spec: options + positionals.
#[derive(Clone, Debug, Default)]
pub struct Spec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

impl Spec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Spec {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            help,
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let kind = if o.is_flag {
                String::new()
            } else if let Some(d) = &o.default {
                format!(" <value, default {d}>")
            } else {
                " <value, required>".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", o.name, kind, o.help));
        }
        s
    }

    /// Parse a raw arg list (not including argv[0] / subcommand name).
    pub fn parse(&self, args: &[String]) -> Result<Args> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut explicit: std::collections::BTreeSet<String> = std::collections::BTreeSet::new();
        let mut positionals = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            if arg == "--help" || arg == "-h" {
                return Err(Error::Config(self.usage()));
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| {
                        Error::Config(format!("unknown option --{key}\n\n{}", self.usage()))
                    })?;
                let val = if opt.is_flag {
                    if inline_val.is_some() {
                        return Err(Error::Config(format!("--{key} takes no value")));
                    }
                    "true".to_string()
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    args.get(i)
                        .cloned()
                        .ok_or_else(|| Error::Config(format!("--{key} needs a value")))?
                };
                explicit.insert(key.clone());
                values.insert(key, val);
            } else {
                positionals.push(arg.clone());
            }
            i += 1;
        }
        // Fill defaults, check required.
        for o in &self.opts {
            if !values.contains_key(o.name) {
                if let Some(d) = &o.default {
                    values.insert(o.name.to_string(), d.clone());
                } else if !o.is_flag {
                    return Err(Error::Config(format!(
                        "missing required --{}\n\n{}",
                        o.name,
                        self.usage()
                    )));
                }
            }
        }
        Ok(Args {
            values,
            explicit,
            positionals,
        })
    }
}

/// Parsed arguments.
#[derive(Clone, Debug)]
pub struct Args {
    values: BTreeMap<String, String>,
    explicit: std::collections::BTreeSet<String>,
    pub positionals: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> &str {
        self.values
            .get(key)
            .map(|s| s.as_str())
            .unwrap_or_default()
    }

    /// Whether the user passed this option on the command line (as
    /// opposed to its declared default filling in).  Lets callers layer
    /// CLI > config file > built-in defaults without the CLI defaults
    /// silently clobbering file settings.
    pub fn provided(&self, key: &str) -> bool {
        self.explicit.contains(key)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.values.get(key).map(|v| v == "true").unwrap_or(false)
    }

    pub fn usize(&self, key: &str) -> Result<usize> {
        self.get(key)
            .parse()
            .map_err(|_| Error::Config(format!("--{key}: expected integer, got '{}'", self.get(key))))
    }

    /// Byte-sized option with optional `k`/`m`/`g` suffix, e.g.
    /// `--store-budget 512m`.
    pub fn bytes(&self, key: &str) -> Result<usize> {
        crate::config::parse_byte_size(&format!("--{key}"), self.get(key))
    }

    /// Unit-interval option (density thresholds, valid ratios): parses
    /// as f32 and rejects NaN / infinities / anything outside [0, 1].
    pub fn unit_interval(&self, key: &str) -> Result<f32> {
        crate::config::parse_unit_interval(&format!("--{key}"), self.get(key))
    }

    pub fn f64(&self, key: &str) -> Result<f64> {
        self.get(key)
            .parse()
            .map_err(|_| Error::Config(format!("--{key}: expected number, got '{}'", self.get(key))))
    }

    /// Comma-separated usize list, e.g. `--devices 1,2,4,8`.
    pub fn usize_list(&self, key: &str) -> Result<Vec<usize>> {
        self.get(key)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| Error::Config(format!("--{key}: bad list item '{s}'")))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec::new("test", "a test command")
            .opt("n", "1024", "matrix size")
            .req("ratio", "valid ratio")
            .flag("verbose", "chatty")
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let a = spec().parse(&args(&["--ratio", "0.1"])).unwrap();
        assert_eq!(a.usize("n").unwrap(), 1024);
        assert_eq!(a.f64("ratio").unwrap(), 0.1);
        assert!(!a.flag("verbose"));
        // Explicitness is tracked: --ratio was passed, --n defaulted.
        assert!(a.provided("ratio"));
        assert!(!a.provided("n"));
    }

    #[test]
    fn missing_required_fails() {
        assert!(spec().parse(&args(&[])).is_err());
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = spec()
            .parse(&args(&["--ratio=0.25", "--n=64", "--verbose"]))
            .unwrap();
        assert_eq!(a.usize("n").unwrap(), 64);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn unknown_option_fails() {
        assert!(spec().parse(&args(&["--bogus", "1"])).is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = spec().parse(&args(&["pos1", "--ratio", "0.1", "pos2"])).unwrap();
        assert_eq!(a.positionals, vec!["pos1", "pos2"]);
    }

    #[test]
    fn bytes_accepts_suffixes() {
        let s = Spec::new("t", "").opt("budget", "256m", "byte budget");
        let a = s.parse(&args(&[])).unwrap();
        assert_eq!(a.bytes("budget").unwrap(), 256 << 20);
        let a = s.parse(&args(&["--budget", "4k"])).unwrap();
        assert_eq!(a.bytes("budget").unwrap(), 4 << 10);
        let a = s.parse(&args(&["--budget", "nope"])).unwrap();
        assert!(a.bytes("budget").is_err());
    }

    #[test]
    fn unit_interval_validates() {
        let s = Spec::new("t", "").opt("density-threshold", "0.0", "format knob");
        let a = s.parse(&args(&[])).unwrap();
        assert_eq!(a.unit_interval("density-threshold").unwrap(), 0.0);
        for ok in ["0.25", "1", "1.0"] {
            let a = s.parse(&args(&["--density-threshold", ok])).unwrap();
            assert!(a.unit_interval("density-threshold").is_ok(), "{ok}");
        }
        for bad in ["-0.1", "1.5", "NaN", "inf", "-inf", "lots"] {
            let a = s.parse(&args(&["--density-threshold", bad])).unwrap();
            assert!(a.unit_interval("density-threshold").is_err(), "{bad}");
        }
    }

    #[test]
    fn usize_list_parses() {
        let s = Spec::new("t", "").opt("devices", "1,2,4", "device counts");
        let a = s.parse(&args(&[])).unwrap();
        assert_eq!(a.usize_list("devices").unwrap(), vec![1, 2, 4]);
    }
}
