//! Algorithm 4 execution: per-device worker threads, each owning a PJRT
//! client, processing its tile partition in P pipeline batches.
//!
//! Each device worker runs **one** stage pipeline across all of its P
//! batches ([`crate::spamm::executor::execute_batches`]): an independent
//! per-device *transfer queue* (the gather worker) streams operand-tile
//! handles — uploading residency-pool misses — while the worker thread
//! runs tile-GEMM and a scatter worker accumulates, so batch *i+1*'s
//! uploads overlap batch *i*'s compute instead of joining at a per-batch
//! stream-level sync.  Operand tiles live in a per-device
//! [`ResidencyPool`] that persists across multiplies: power chains,
//! purification, and repeated service requests skip phase-3 transfers on
//! warm operands, the §3.3 A-block reuse.  Normmaps and the compacted
//! schedule are memoized in the coordinator's [`ExecCaches`], covering
//! phases 1–2 the same way.
//!
//! Timing protocol: every worker first compiles/warms its executables,
//! then waits on a barrier; the wall clock runs from that barrier to the
//! last worker's completion — compile time is excluded, exactly like the
//! paper excludes warmup (§4.1 "the execution time ignores ... warmup").
//!
//! Chained workloads (powers, purification) should prefer the
//! expression-graph front-end ([`crate::coordinator::expr`]), which runs
//! whole iteration chains through this same executor with
//! device-resident intermediates instead of one `multiply` per step.

use std::sync::{Arc, Barrier, Mutex};
use std::time::Instant;

use crate::config::SpammConfig;
use crate::error::{Error, Result};
use crate::matrix::tiling::PaddedMatrix;
use crate::matrix::Matrix;
use crate::runtime::residency::{PatchOutcome, ResidencyPool};
use crate::runtime::{ArtifactBundle, Runtime};
use crate::spamm::cache::{fingerprint, fingerprint_patch, ExecCaches, Fingerprint};
use crate::spamm::executor::{
    check_inner_dims, execute_batches, MultiplyStats, Operand, OperandUpdate, TileAccumulator,
};
use crate::spamm::normmap::{normmap_with_density, resolve_density_threshold, NormMap};
use crate::spamm::schedule::Schedule;
use crate::spamm::tuner::{self, TuneParams, TuneResult};

use crate::spamm::balance::Assignment;

use super::metrics::MultiDeviceReport;
use super::partition::{batches_of, partition_ctx, DeviceWork, PartitionCtx};
use super::workers::DeviceWorkerPool;

/// Multi-device SpAMM coordinator.
pub struct Coordinator {
    bundle: ArtifactBundle,
    cfg: SpammConfig,
    caches: Arc<ExecCaches>,
    /// One operand-tile pool per device (empty under `--no-residency`).
    /// Device memory is per-GPU, so pools are never shared across workers.
    pools: Vec<Arc<ResidencyPool>>,
    /// Persistent per-device worker threads (one resident [`Runtime`]
    /// each), built lazily on the first dispatched multiply and reused for
    /// the life of the coordinator — warm requests pay zero recompiles.
    workers: Mutex<Option<Arc<DeviceWorkerPool>>>,
}

/// What one device worker returns: its owned output tiles and clocks.
/// Shared with the multi-device expression executor
/// ([`crate::coordinator::expr`]), which runs the same per-device
/// pipeline per graph node.
pub(crate) struct DeviceResult {
    pub(crate) device: usize,
    /// (tile coords, accumulated LoNum² data) per owned tile.
    pub(crate) tiles: Vec<((usize, usize), Vec<f32>)>,
    pub(crate) busy_secs: f64,
    pub(crate) compile_secs: f64,
    /// Fresh executable compiles this call charged its runtime — zero on
    /// a warm pool worker.
    pub(crate) compiles: u64,
    pub(crate) products: usize,
    /// Pipeline-stage breakdown of this worker's batches.
    pub(crate) stats: MultiplyStats,
}

impl Coordinator {
    pub fn new(bundle: &ArtifactBundle, cfg: SpammConfig) -> Result<Coordinator> {
        let caches = Arc::new(ExecCaches::with_store(crate::store::WarmStore::from_config(
            &cfg,
        )));
        Coordinator::with_shared(bundle, cfg, caches, None)
    }

    /// Construct a coordinator over externally-owned caches and residency
    /// pools.  The session front-end uses this: `prepare` runs on the
    /// caller thread against the same [`ExecCaches`] the worker's
    /// coordinator executes through, and the operand store pins/unpins
    /// tiles in the same per-device pools.  `pools: None` builds fresh
    /// pools from the config (what [`Coordinator::new`] does).
    pub fn with_shared(
        bundle: &ArtifactBundle,
        cfg: SpammConfig,
        caches: Arc<ExecCaches>,
        pools: Option<Vec<Arc<ResidencyPool>>>,
    ) -> Result<Coordinator> {
        cfg.validate()?;
        let pools = if !cfg.residency_enabled {
            Vec::new()
        } else if let Some(p) = pools {
            if p.len() != cfg.devices {
                return Err(Error::Coordinator(format!(
                    "{} residency pools for {} devices",
                    p.len(),
                    cfg.devices
                )));
            }
            p
        } else {
            (0..cfg.devices)
                .map(|_| Arc::new(ResidencyPool::new(cfg.device_mem_budget)))
                .collect()
        };
        Ok(Coordinator {
            bundle: bundle.clone(),
            cfg,
            caches,
            pools,
            workers: Mutex::new(None),
        })
    }

    /// The lazily-built persistent worker pool.  Shared by the multiply
    /// and expression executors so every dispatch path reuses the same
    /// per-device runtimes.
    pub(crate) fn worker_pool(&self) -> Result<Arc<DeviceWorkerPool>> {
        let mut slot = self.workers.lock().unwrap();
        if let Some(p) = slot.as_ref() {
            return Ok(p.clone());
        }
        let p = Arc::new(DeviceWorkerPool::new(&self.bundle, self.cfg.devices)?);
        *slot = Some(p.clone());
        Ok(p)
    }

    pub fn config(&self) -> &SpammConfig {
        &self.cfg
    }

    pub fn bundle(&self) -> &ArtifactBundle {
        &self.bundle
    }

    /// The coordinator's norm/schedule caches (hit/miss inspection).
    pub fn caches(&self) -> &ExecCaches {
        &self.caches
    }

    /// Per-device residency pools (empty under `--no-residency`).
    /// Operand-level pin/unpin lives on the pools themselves
    /// ([`ResidencyPool::pin_operand`]); the session front-end drives it
    /// directly from its operand store.
    pub fn residency_pools(&self) -> &[Arc<ResidencyPool>] {
        &self.pools
    }

    fn pool_of(&self, device: usize) -> Option<&ResidencyPool> {
        self.pools.get(device).map(|p| p.as_ref())
    }

    /// Cached host normmap of a padded operand (hit/miss lands in
    /// `stats`).
    fn cached_normmap(
        &self,
        p: &PaddedMatrix,
        stats: &mut MultiplyStats,
    ) -> Result<(Arc<NormMap>, Option<Fingerprint>)> {
        self.caches
            .normmap_via(self.cfg.cache_enabled, p, stats, || {
                Ok(normmap_with_density(p))
            })
    }

    /// Tune τ for a target valid ratio (host normmaps — the tuning kernel
    /// runs once per matrix pair, not per device).
    pub fn tune_tau(&self, a: &Matrix, b: &Matrix, target: f64) -> Result<TuneResult> {
        check_inner_dims("tune_tau", a, b)?;
        let mut scratch = MultiplyStats::default();
        let (na, fa) = self.cached_normmap(&PaddedMatrix::new(a, self.cfg.lonum), &mut scratch)?;
        let (nb, fb) = self.cached_normmap(&PaddedMatrix::new(b, self.cfg.lonum), &mut scratch)?;
        let params = TuneParams::default();
        // Both fingerprints known (caching on) → the tune result is
        // store-addressable.
        let key = match (fa, fb, self.caches.store()) {
            (Some(fa), Some(fb), Some(store)) => {
                let key = crate::store::TauKey::new(fa, fb, target, &params);
                if let Some(t) = store.load_tau(&key) {
                    return Ok(t);
                }
                Some(key)
            }
            _ => None,
        };
        let tuned = tuner::tune_tau(&na.norms, &nb.norms, target, params)?;
        if let (Some(key), Some(store)) = (key, self.caches.store()) {
            store.save_tau(&key, &tuned);
        }
        Ok(tuned)
    }

    /// Multi-device SpAMM multiply per Algorithm 4.
    pub fn multiply(&self, a: &Matrix, b: &Matrix, tau: f32) -> Result<MultiDeviceReport> {
        check_inner_dims("multiply", a, b)?;
        let lonum = self.cfg.lonum;
        let pa = Arc::new(PaddedMatrix::new(a, lonum));
        let pb = Arc::new(PaddedMatrix::new(b, lonum));
        // Phase 1 (Alg. 4 lines 4–9): normmaps for A and B — memoized, so
        // power/purification loops skip this phase on every repeat.  The
        // get-norm work is O(N²) vs the O(N³/ratio) multiply.  `front`
        // collects the cache hit/miss counts for the report's stage
        // stats.
        let mut front = MultiplyStats::default();
        let t = Instant::now();
        let (na, mut fa) = self.cached_normmap(&pa, &mut front)?;
        let (nb, mut fb) = self.cached_normmap(&pb, &mut front)?;
        front.norm_secs = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let dt = resolve_density_threshold(&self.cfg, &na, &nb);
        let sched = self
            .caches
            .schedule_via(fa, fb, tau, dt, &na, &nb, &mut front)?;
        front.schedule_secs = t.elapsed().as_secs_f64();
        // Residency keys on content fingerprints; compute them here even
        // when the norm cache (which normally provides them) is off.
        if !self.pools.is_empty() {
            fa = fa.or_else(|| Some(fingerprint(&pa)));
            fb = fb.or_else(|| Some(fingerprint(&pb)));
        }
        self.run_scheduled(&pa, &pb, fa, fb, &sched, front, a.rows(), b.cols(), None, None)
    }

    /// Execute a *prepared* multiply: operands already padded and
    /// fingerprinted (registered in a session's operand store) and the
    /// compacted schedule already built and pinned by `prepare` — the
    /// get-norm and scheduling phases are skipped entirely.
    pub fn multiply_prepared(
        &self,
        pa: &Arc<PaddedMatrix>,
        pb: &Arc<PaddedMatrix>,
        fa: Fingerprint,
        fb: Fingerprint,
        sched: &Arc<Schedule>,
    ) -> Result<MultiDeviceReport> {
        self.multiply_prepared_on(None, pa, pb, fa, fb, sched, None)
    }

    /// [`Coordinator::multiply_prepared`] with an optional long-lived
    /// runtime (session worker): on `devices == 1` the multiply executes
    /// directly on it, so compiled executables persist across requests;
    /// on `devices > 1` the persistent worker pool provides the same
    /// warm-runtime guarantee per device and `resident` is unused here
    /// (the expression executor uses it as its combine orchestrator).
    /// `placed` pins the tile→device assignment resolved at plan-prepare
    /// time — the devices the session pinned the operands into are
    /// exactly the devices that execute, even if pool residency shifted
    /// since (a live re-partition could otherwise land on unpinned
    /// devices).  Operands and schedule arrive as `Arc`s because pool
    /// jobs outlive the borrow scope of a call frame.
    #[allow(clippy::too_many_arguments)]
    pub fn multiply_prepared_on(
        &self,
        resident: Option<&Runtime>,
        pa: &Arc<PaddedMatrix>,
        pb: &Arc<PaddedMatrix>,
        fa: Fingerprint,
        fb: Fingerprint,
        sched: &Arc<Schedule>,
        placed: Option<&Assignment>,
    ) -> Result<MultiDeviceReport> {
        if pa.logical_cols != pb.logical_rows {
            return Err(Error::Shape(format!(
                "prepared multiply: inner dimensions disagree: A is {}x{}, B is {}x{}",
                pa.logical_rows, pa.logical_cols, pb.logical_rows, pb.logical_cols
            )));
        }
        if sched.tile_rows != pa.tile_rows()
            || sched.tile_k != pa.tile_cols()
            || sched.tile_cols != pb.tile_cols()
        {
            return Err(Error::Shape(format!(
                "prepared multiply: schedule grid {}x{}x{} does not match operands \
                 ({}x{} · {}x{} tiles)",
                sched.tile_rows,
                sched.tile_k,
                sched.tile_cols,
                pa.tile_rows(),
                pa.tile_cols(),
                pb.tile_rows(),
                pb.tile_cols()
            )));
        }
        self.run_scheduled(
            pa,
            pb,
            Some(fa),
            Some(fb),
            sched,
            MultiplyStats::default(),
            pa.logical_rows,
            pb.logical_cols,
            resident,
            placed,
        )
    }

    /// Apply a delta update to a prepared operand — the multi-device twin
    /// of [`crate::spamm::executor::SpammEngine::update_operand`].  Same
    /// incremental pipeline (patch padded tiles → derive fingerprint →
    /// patch cached norms → repair cached schedules), but the residency
    /// migration runs once per device pool, since every device holds its
    /// own partition of the operand's tiles.  The session front-end calls
    /// this from the caller thread against the shared caches/pools.
    pub fn update_operand(
        &self,
        padded: &PaddedMatrix,
        fp: Fingerprint,
        changed: &[(usize, usize)],
        data: &[f32],
    ) -> Result<OperandUpdate> {
        apply_operand_update(&self.cfg, &self.caches, &self.pools, padded, fp, changed, data)
    }

    /// Phase 2 (Alg. 4 lines 10–11): partition the schedule's output
    /// tiles over devices and run the per-device pipelines.  Shared by the
    /// full multiply (front phases just computed) and the prepared path
    /// (front phases skipped).  `resident` reuses a caller-owned runtime
    /// for the single-device case; everything else dispatches to the
    /// persistent worker pool ([`DeviceWorkerPool`]), whose per-device
    /// runtimes survive across multiplies.
    #[allow(clippy::too_many_arguments)]
    fn run_scheduled(
        &self,
        pa: &Arc<PaddedMatrix>,
        pb: &Arc<PaddedMatrix>,
        fa: Option<Fingerprint>,
        fb: Option<Fingerprint>,
        sched: &Arc<Schedule>,
        front: MultiplyStats,
        out_rows: usize,
        out_cols: usize,
        resident: Option<&Runtime>,
        placed: Option<&Assignment>,
    ) -> Result<MultiDeviceReport> {
        // A prepared plan pins its placement (the devices its operands
        // were pinned into must be the devices that execute); otherwise
        // partition live.  The residency context tells the
        // residency-aware policy where A/B tiles currently live.
        let work = match placed {
            Some(a)
                if a.devices == self.cfg.devices
                    && a.owner.len() == sched.tile_rows * sched.tile_cols =>
            {
                batches_of(sched, a, self.cfg.pipeline_batches)
            }
            _ => {
                let ctx = PartitionCtx {
                    pools: &self.pools,
                    fa,
                    fb,
                    tile_bytes: self.cfg.lonum * self.cfg.lonum * std::mem::size_of::<f32>(),
                };
                partition_ctx(
                    sched,
                    self.cfg.devices,
                    self.cfg.balance,
                    self.cfg.pipeline_batches,
                    Some(&ctx),
                )
            }
        };

        let device_load: Vec<usize> = work
            .iter()
            .map(|w| w.tiles().map(|(i, j)| sched.v(i, j)).sum())
            .collect();
        let valid = sched.valid_products();
        let mean_load = valid as f64 / self.cfg.devices as f64;
        let imbalance = if valid == 0 {
            1.0
        } else {
            *device_load.iter().max().unwrap() as f64 / mean_load
        };

        // Phase 2 (lines 10–11): per-device pipelines.
        let mut results: Vec<Option<DeviceResult>> = Vec::new();
        let wall_secs;
        if let (Some(rt), 1) = (resident, self.cfg.devices) {
            // Serving mode, single device: the caller (a session worker)
            // owns one long-lived runtime whose compiled executables
            // persist across requests; execute directly on the caller
            // thread (a runtime cannot cross threads).
            let solo = Barrier::new(1);
            let t0 = Instant::now();
            for w in &work {
                results.push(Some(run_device(
                    rt,
                    &self.cfg,
                    self.pool_of(w.device),
                    Operand::new(pa, fa),
                    Operand::new(pb, fb),
                    sched,
                    w,
                    &solo,
                )?));
            }
            wall_secs = t0.elapsed().as_secs_f64();
        } else if self.cfg.sequential_devices {
            // Modeled-device mode: run pipelines back-to-back so each busy
            // clock is contention-free (see SpammConfig::sequential_devices)
            // — dispatched one at a time to the persistent workers, so
            // even this mode keeps warm runtimes.
            let pool = self.worker_pool()?;
            let t0 = Instant::now();
            for w in work {
                let device = w.device;
                let job = self.device_job(pa, pb, fa, fb, sched, w, Arc::new(Barrier::new(1)));
                let mut replies = pool.dispatch(vec![(device, job)])?;
                let rx = replies.pop().expect("one reply per job");
                results.push(Some(rx.recv().map_err(|_| {
                    Error::Coordinator("device worker terminated".into())
                })??));
            }
            wall_secs = t0.elapsed().as_secs_f64();
        } else {
            // Dispatch the whole multiply to the persistent worker pool:
            // every device warms up (a no-op once its runtime is hot),
            // parks at the release barrier, and the wall clock runs from
            // the caller's barrier entry to the last reply — the same
            // compile-excluded timing protocol the scoped-thread executor
            // used, but with runtimes that outlive the request.
            let pool = self.worker_pool()?;
            let barrier = Arc::new(Barrier::new(work.len() + 1));
            let jobs: Vec<_> = work
                .into_iter()
                .map(|w| {
                    let device = w.device;
                    (
                        device,
                        self.device_job(pa, pb, fa, fb, sched, w, barrier.clone()),
                    )
                })
                .collect();
            let replies = pool.dispatch(jobs)?;
            // Release the workers together once they are all warmed up,
            // then time to completion.
            barrier.wait();
            let t0 = Instant::now();
            for rx in replies {
                results.push(Some(rx.recv().map_err(|_| {
                    Error::Coordinator("device worker terminated".into())
                })??));
            }
            wall_secs = t0.elapsed().as_secs_f64();
        }
        self.finish(
            out_rows,
            out_cols,
            sched,
            device_load,
            imbalance,
            results,
            wall_secs,
            front,
        )
    }

    /// Build one pool job: a closure owning `Arc` handles to everything a
    /// device pipeline needs, runnable on any worker's resident runtime.
    #[allow(clippy::too_many_arguments)]
    fn device_job(
        &self,
        pa: &Arc<PaddedMatrix>,
        pb: &Arc<PaddedMatrix>,
        fa: Option<Fingerprint>,
        fb: Option<Fingerprint>,
        sched: &Arc<Schedule>,
        work: DeviceWork,
        barrier: Arc<Barrier>,
    ) -> impl FnOnce(&Runtime) -> Result<DeviceResult> + Send + 'static {
        let pa = pa.clone();
        let pb = pb.clone();
        let sched = sched.clone();
        let cfg = self.cfg.clone();
        let rpool = self.pools.get(work.device).cloned();
        move |rt: &Runtime| {
            run_device(
                rt,
                &cfg,
                rpool.as_deref(),
                Operand::new(&pa, fa),
                Operand::new(&pb, fb),
                &sched,
                &work,
                &barrier,
            )
        }
    }

    /// Merge device results into the final report (each output tile has
    /// exactly one owner, so merging is a copy).
    #[allow(clippy::too_many_arguments)]
    fn finish(
        &self,
        out_rows: usize,
        out_cols: usize,
        sched: &Schedule,
        device_load: Vec<usize>,
        imbalance: f64,
        results: Vec<Option<DeviceResult>>,
        wall_secs: f64,
        front: MultiplyStats,
    ) -> Result<MultiDeviceReport> {
        let lonum = self.cfg.lonum;
        let mut pc = PaddedMatrix::new(&Matrix::zeros(out_rows, out_cols), lonum);
        let mut device_busy = vec![0.0; self.cfg.devices];
        let mut compile_secs = vec![0.0; self.cfg.devices];
        let mut device_transfer_secs = vec![0.0; self.cfg.devices];
        let mut device_transfer_bytes = vec![0u64; self.cfg.devices];
        let mut device_cross_bytes = vec![0u64; self.cfg.devices];
        // Stage stats: the front-end's cache counters + the per-device
        // workers' pipeline clocks.
        let mut stage = front;
        for r in results.into_iter().flatten() {
            device_busy[r.device] = r.busy_secs;
            compile_secs[r.device] = r.compile_secs;
            stage.compiles += r.compiles;
            stage.compile_secs += r.compile_secs;
            // The gather stage *is* the device's transfer queue: handle
            // resolution plus residency-miss uploads.
            device_transfer_secs[r.device] = r.stats.gather_secs;
            device_transfer_bytes[r.device] = r.stats.transfer_bytes;
            device_cross_bytes[r.device] = r.stats.cross_device_bytes;
            stage.absorb_stages(&r.stats);
            for ((i, j), data) in r.tiles {
                pc.inner.add_block(i * lonum, j * lonum, lonum, &data);
            }
        }
        let device_resident_bytes = self
            .pools
            .iter()
            .map(|p| p.resident_bytes() as u64)
            .collect();
        Ok(MultiDeviceReport {
            c: pc.crop(),
            wall_secs,
            device_busy,
            device_load,
            valid_products: sched.valid_products(),
            total_products: sched.total_products(),
            valid_ratio: sched.valid_ratio(),
            imbalance,
            compile_secs,
            device_transfer_secs,
            device_transfer_bytes,
            device_resident_bytes,
            device_cross_bytes,
            stage,
        })
    }

    /// Dense baseline across M devices: row-block partition of A, one dense
    /// artifact call per device — how one would run cuBLAS per GPU.  Only
    /// sizes with square dense artifacts are supported.
    pub fn dense(&self, a: &Matrix, b: &Matrix) -> Result<MultiDeviceReport> {
        // The dense artifacts are square-shaped; multi-device dense uses
        // the single-device artifact per worker on its row slice only when
        // devices == 1; otherwise fall back to one device (documented:
        // cuBLAS scaling in the paper is also per-GPU row partitioning,
        // but our artifact grid only carries square shapes — the Fig. 5
        // comparison uses single-GPU cuBLAS as its baseline, as the paper
        // does for speedup normalization).
        check_inner_dims("dense", a, b)?;
        let rt = Runtime::new(&self.bundle)?;
        let precision = self.cfg.precision.as_str();
        rt.dense(a, b, precision)?; // warmup (compile + first run)
        let t0 = Instant::now();
        let c = rt.dense(a, b, precision)?;
        let wall = t0.elapsed().as_secs_f64();
        Ok(MultiDeviceReport {
            c,
            wall_secs: wall,
            device_busy: vec![wall],
            device_load: vec![1],
            valid_products: 0,
            total_products: 0,
            valid_ratio: 1.0,
            imbalance: 1.0,
            compile_secs: vec![0.0],
            device_transfer_secs: vec![0.0],
            device_transfer_bytes: vec![0],
            device_resident_bytes: Vec::new(),
            device_cross_bytes: vec![0],
            stage: MultiplyStats::default(),
        })
    }
}

/// The shared delta-update front half — what [`Coordinator::update_operand`]
/// and the session's `update` both run: patch the padded operand, derive
/// the new fingerprint incrementally, patch the cached norm map, migrate
/// every residency pool's tiles, and repair cached schedules.  Free
/// function so the session front-end (whose coordinator lives inside the
/// worker thread) can run it on the caller thread against the shared
/// caches and pools.
pub(crate) fn apply_operand_update(
    cfg: &SpammConfig,
    caches: &ExecCaches,
    pools: &[Arc<ResidencyPool>],
    padded: &PaddedMatrix,
    fp: Fingerprint,
    changed: &[(usize, usize)],
    data: &[f32],
) -> Result<OperandUpdate> {
    let new_padded = padded.with_patched_tiles(changed, data)?;
    let mut tiles = changed.to_vec();
    tiles.sort_unstable();
    tiles.dedup();
    let new_fp = fingerprint_patch(fp, &new_padded, &tiles);
    let (nm, norm_patched) = match caches.patch_normmap(fp, new_fp, &new_padded, &tiles) {
        Some(nm) => (nm, true),
        None => {
            // Old norms not cached: take the full pass once and register
            // it so repair and the next submit share it.
            let nm = Arc::new(normmap_with_density(&new_padded));
            if cfg.cache_enabled {
                caches.norms.insert(new_fp, nm.clone());
            }
            (nm, false)
        }
    };
    let l2 = new_padded.lonum * new_padded.lonum;
    let mut pool = PatchOutcome::default();
    for p in pools {
        pool.absorb(&p.patch_operand(fp, new_fp, &tiles, l2, |t, buf| {
            new_padded.copy_tile(t.0, t.1, buf)
        }));
    }
    let repair = caches.repair_schedules(fp, new_fp, &nm, &tiles);
    Ok(OperandUpdate {
        padded: new_padded,
        fp: new_fp,
        norm_patched,
        norm_tiles_patched: if norm_patched { tiles.len() } else { 0 },
        pool,
        repair,
    })
}

/// One device's pipeline: warm up, wait at the barrier, then stream *all*
/// P tile batches through one gather ∥ tile-GEMM ∥ scatter pipeline (the
/// per-device transfer queue keeps uploading the next batch's tiles while
/// this batch computes — no per-batch stream-level sync).
///
/// The runtime is caller-owned: per-multiply workers build a fresh one,
/// the session's resident worker reuses one across requests (warm-up is a
/// no-op once its executables are compiled).
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_device(
    rt: &Runtime,
    cfg: &SpammConfig,
    pool: Option<&ResidencyPool>,
    pa: Operand<'_>,
    pb: Operand<'_>,
    sched: &Schedule,
    work: &DeviceWork,
    barrier: &Barrier,
) -> Result<DeviceResult> {
    let compile0 = rt.compile_secs();
    let compiles0 = rt.compiles();
    let precision = cfg.precision.as_str();
    // Warm up every tile-GEMM bucket this device may use.  A warm-up
    // failure is captured (not returned) until after the barrier: every
    // party reaches the barrier exactly once, so a broken artifact
    // surfaces as an error reply instead of stranding the releasing
    // caller and the sibling workers.
    let warm = (|| -> Result<()> {
        let buckets: Vec<String> = rt
            .bundle()
            .names()
            .filter(|n| {
                n.starts_with(&format!("tilegemm_l{}_", cfg.lonum)) && n.ends_with(precision)
            })
            .map(|s| s.to_string())
            .collect();
        for b in &buckets {
            rt.warmup(&[b])?;
        }
        Ok(())
    })();

    // Local accumulator for owned tiles (rejects unowned products).
    let mut sink = TileAccumulator::new(cfg.lonum, work.tiles());
    let mut stats = MultiplyStats::default();

    barrier.wait();
    warm?;
    let t0 = Instant::now();
    let batches: Vec<&[(usize, usize)]> =
        work.tile_batches.iter().map(|b| b.as_slice()).collect();
    let products_done =
        execute_batches(rt, cfg, pool, pa, pb, &mut sink, sched, &batches, &mut stats)?;
    let busy = t0.elapsed().as_secs_f64();

    Ok(DeviceResult {
        device: work.device,
        tiles: sink.into_tiles(),
        busy_secs: busy,
        // Compile delta of *this* call: zero on a warm resident runtime.
        compile_secs: rt.compile_secs() - compile0,
        compiles: rt.compiles() - compiles0,
        products: products_done,
        stats,
    })
}

// `products` is carried for debug assertions in tests.
impl DeviceResult {
    #[allow(dead_code)]
    fn products(&self) -> usize {
        self.products
    }
}

/// Convenience: single-call multi-device stats → MultiplyStats shape used
/// by some benches.
pub fn report_to_stats(r: &MultiDeviceReport) -> MultiplyStats {
    MultiplyStats {
        valid_products: r.valid_products,
        total_products: r.total_products,
        valid_ratio: r.valid_ratio,
        total_secs: r.wall_secs,
        ..r.stage.clone()
    }
}
