//! Algorithm 4 execution: per-device worker threads, each owning a PJRT
//! client, processing its tile partition in P pipeline batches.
//!
//! Timing protocol: every worker first compiles/warms its executables,
//! then waits on a barrier; the wall clock runs from that barrier to the
//! last worker's completion — compile time is excluded, exactly like the
//! paper excludes warmup (§4.1 "the execution time ignores ... warmup").

use std::sync::Barrier;
use std::time::Instant;

use crate::config::SpammConfig;
use crate::error::{Error, Result};
use crate::matrix::tiling::{gather_tiles, PaddedMatrix};
use crate::matrix::Matrix;
use crate::runtime::{ArtifactBundle, Runtime};
use crate::spamm::executor::MultiplyStats;
use crate::spamm::normmap::normmap;
use crate::spamm::schedule::{ProductRef, Schedule};
use crate::spamm::tuner::{self, TuneParams, TuneResult};

use super::metrics::MultiDeviceReport;
use super::partition::{partition, DeviceWork};

/// Multi-device SpAMM coordinator.
pub struct Coordinator {
    bundle: ArtifactBundle,
    cfg: SpammConfig,
}

/// What one device worker returns: its owned output tiles and clocks.
struct DeviceResult {
    device: usize,
    /// (tile coords, accumulated LoNum² data) per owned tile.
    tiles: Vec<((usize, usize), Vec<f32>)>,
    busy_secs: f64,
    compile_secs: f64,
    products: usize,
}

impl Coordinator {
    pub fn new(bundle: &ArtifactBundle, cfg: SpammConfig) -> Result<Coordinator> {
        cfg.validate()?;
        Ok(Coordinator {
            bundle: bundle.clone(),
            cfg,
        })
    }

    pub fn config(&self) -> &SpammConfig {
        &self.cfg
    }

    /// Tune τ for a target valid ratio (host normmaps — the tuning kernel
    /// runs once per matrix pair, not per device).
    pub fn tune_tau(&self, a: &Matrix, b: &Matrix, target: f64) -> Result<TuneResult> {
        let na = normmap(&PaddedMatrix::new(a, self.cfg.lonum));
        let nb = normmap(&PaddedMatrix::new(b, self.cfg.lonum));
        tuner::tune_tau(&na, &nb, target, TuneParams::default())
    }

    /// Multi-device SpAMM multiply per Algorithm 4.
    pub fn multiply(&self, a: &Matrix, b: &Matrix, tau: f32) -> Result<MultiDeviceReport> {
        let lonum = self.cfg.lonum;
        let pa = PaddedMatrix::new(a, lonum);
        let pb = PaddedMatrix::new(b, lonum);
        // Phase 1 (Alg. 4 lines 4–9): normmaps for A and B.  Host-side
        // here; the get-norm work is O(N²) vs the O(N³/ratio) multiply.
        let na = normmap(&pa);
        let nb = normmap(&pb);
        let sched = Schedule::build(&na, &nb, tau)?;
        let work = partition(&sched, self.cfg.devices, self.cfg.balance, self.cfg.pipeline_batches);

        let device_load: Vec<usize> = work
            .iter()
            .map(|w| w.tiles().map(|(i, j)| sched.v(i, j)).sum())
            .collect();
        let valid = sched.valid_products();
        let mean_load = valid as f64 / self.cfg.devices as f64;
        let imbalance = if valid == 0 {
            1.0
        } else {
            *device_load.iter().max().unwrap() as f64 / mean_load
        };

        // Phase 2 (lines 10–11): per-device pipelines.
        let mut results: Vec<Option<DeviceResult>> = Vec::new();
        let mut wall_secs = 0.0f64;
        if self.cfg.sequential_devices {
            // Modeled-device mode: run pipelines back-to-back so each busy
            // clock is contention-free (see SpammConfig::sequential_devices).
            let solo = Barrier::new(1);
            let t0 = Instant::now();
            for w in &work {
                results.push(Some(run_device(
                    &self.bundle,
                    &self.cfg,
                    &pa,
                    &pb,
                    &sched,
                    w,
                    &solo,
                )?));
            }
            wall_secs = t0.elapsed().as_secs_f64();
            return self.finish(a, b, &sched, device_load, imbalance, results, wall_secs);
        }
        let barrier = Barrier::new(self.cfg.devices + 1);
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            for w in &work {
                let barrier = &barrier;
                let bundle = &self.bundle;
                let cfg = &self.cfg;
                let (pa, pb, sched) = (&pa, &pb, &sched);
                handles.push(scope.spawn(move || -> Result<DeviceResult> {
                    run_device(bundle, cfg, pa, pb, sched, w, barrier)
                }));
            }
            // Release the workers together once they are all warmed up,
            // then time to completion.
            barrier.wait();
            let t0 = Instant::now();
            let mut collected = Vec::new();
            for h in handles {
                collected.push(Some(h.join().map_err(|_| {
                    Error::Coordinator("device worker panicked".into())
                })??));
            }
            wall_secs = t0.elapsed().as_secs_f64();
            results = collected;
            Ok(())
        })?;
        self.finish(a, b, &sched, device_load, imbalance, results, wall_secs)
    }

    /// Merge device results into the final report (each output tile has
    /// exactly one owner, so merging is a copy).
    #[allow(clippy::too_many_arguments)]
    fn finish(
        &self,
        a: &Matrix,
        b: &Matrix,
        sched: &Schedule,
        device_load: Vec<usize>,
        imbalance: f64,
        results: Vec<Option<DeviceResult>>,
        wall_secs: f64,
    ) -> Result<MultiDeviceReport> {
        let lonum = self.cfg.lonum;
        let mut pc = PaddedMatrix::new(&Matrix::zeros(a.rows(), b.cols()), lonum);
        let mut device_busy = vec![0.0; self.cfg.devices];
        let mut compile_secs = vec![0.0; self.cfg.devices];
        for r in results.into_iter().flatten() {
            device_busy[r.device] = r.busy_secs;
            compile_secs[r.device] = r.compile_secs;
            for ((i, j), data) in r.tiles {
                pc.inner.add_block(i * lonum, j * lonum, lonum, &data);
            }
        }
        Ok(MultiDeviceReport {
            c: pc.crop(),
            wall_secs,
            device_busy,
            device_load,
            valid_products: sched.valid_products(),
            total_products: sched.total_products(),
            valid_ratio: sched.valid_ratio(),
            imbalance,
            compile_secs,
        })
    }

    /// Dense baseline across M devices: row-block partition of A, one dense
    /// artifact call per device — how one would run cuBLAS per GPU.  Only
    /// sizes with square dense artifacts are supported.
    pub fn dense(&self, a: &Matrix, b: &Matrix) -> Result<MultiDeviceReport> {
        // The dense artifacts are square-shaped; multi-device dense uses
        // the single-device artifact per worker on its row slice only when
        // devices == 1; otherwise fall back to one device (documented:
        // cuBLAS scaling in the paper is also per-GPU row partitioning,
        // but our artifact grid only carries square shapes — the Fig. 5
        // comparison uses single-GPU cuBLAS as its baseline, as the paper
        // does for speedup normalization).
        let rt = Runtime::new(&self.bundle)?;
        let precision = self.cfg.precision.as_str();
        rt.dense(a, b, precision)?; // warmup (compile + first run)
        let t0 = Instant::now();
        let c = rt.dense(a, b, precision)?;
        let wall = t0.elapsed().as_secs_f64();
        Ok(MultiDeviceReport {
            c,
            wall_secs: wall,
            device_busy: vec![wall],
            device_load: vec![1],
            valid_products: 0,
            total_products: 0,
            valid_ratio: 1.0,
            imbalance: 1.0,
            compile_secs: vec![0.0],
        })
    }
}

/// One device's pipeline: warm up, wait at the barrier, then process the
/// P tile batches (gather → tile-GEMM → local scatter).
fn run_device(
    bundle: &ArtifactBundle,
    cfg: &SpammConfig,
    pa: &PaddedMatrix,
    pb: &PaddedMatrix,
    sched: &Schedule,
    work: &DeviceWork,
    barrier: &Barrier,
) -> Result<DeviceResult> {
    let rt = Runtime::new(bundle)?;
    let precision = cfg.precision.as_str();
    // Warm up every tile-GEMM bucket this device may use.
    let buckets: Vec<String> = bundle
        .names()
        .filter(|n| {
            n.starts_with(&format!("tilegemm_l{}_", cfg.lonum)) && n.ends_with(precision)
        })
        .map(|s| s.to_string())
        .collect();
    for b in &buckets {
        rt.warmup(&[b])?;
    }
    let lonum = cfg.lonum;
    let l2 = lonum * lonum;

    // Local accumulators for owned tiles.
    let owned: Vec<(usize, usize)> = work.tiles().collect();
    let mut acc: std::collections::BTreeMap<(usize, usize), Vec<f32>> = owned
        .iter()
        .map(|&t| (t, vec![0.0f32; l2]))
        .collect();

    barrier.wait();
    let t0 = Instant::now();
    let mut products_done = 0usize;
    let mut a_buf = Vec::new();
    let mut b_buf = Vec::new();

    for batch in &work.tile_batches {
        // Alg. 4: per pipeline batch, gather this batch's products and run.
        let products: Vec<ProductRef> =
            sched.products_for_tiles(batch.iter().copied()).collect();
        for chunk in crate::spamm::executor::pack_chunks(rt.bundle(), cfg, &products)? {
            let meta = rt.bundle().tilegemm(chunk.len(), cfg.lonum, precision)?;
            let cap = meta.param_usize("batch").unwrap_or(chunk.len());
            let a_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.a).collect();
            let b_ids: Vec<(usize, usize)> = chunk.iter().map(|p| p.b).collect();
            gather_tiles(pa, &a_ids, cap, &mut a_buf)?;
            gather_tiles(pb, &b_ids, cap, &mut b_buf)?;
            let out = rt.tile_gemm(&a_buf, &b_buf, cap, lonum, precision)?;
            for (slot, p) in chunk.iter().enumerate() {
                let dst = acc.get_mut(&p.c).ok_or_else(|| {
                    Error::Coordinator(format!("product for unowned tile {:?}", p.c))
                })?;
                for (d, s) in dst.iter_mut().zip(&out[slot * l2..(slot + 1) * l2]) {
                    *d += s;
                }
            }
            products_done += chunk.len();
        }
        // stream-level synchronize: implicit — tile_gemm is synchronous.
    }
    let busy = t0.elapsed().as_secs_f64();

    Ok(DeviceResult {
        device: work.device,
        tiles: acc.into_iter().collect(),
        busy_secs: busy,
        compile_secs: rt.compile_secs(),
        products: products_done,
    })
}

// `products` is carried for debug assertions in tests.
impl DeviceResult {
    #[allow(dead_code)]
    fn products(&self) -> usize {
        self.products
    }
}

/// Convenience: single-call multi-device stats → MultiplyStats shape used
/// by some benches.
pub fn report_to_stats(r: &MultiDeviceReport) -> MultiplyStats {
    MultiplyStats {
        valid_products: r.valid_products,
        total_products: r.total_products,
        valid_ratio: r.valid_ratio,
        total_secs: r.wall_secs,
        exec_secs: r.total_busy(),
        ..Default::default()
    }
}
