//! Work partitioning for Algorithm 4: each device owns a set of output
//! tiles (via [`crate::spamm::balance::Assignment`]) and processes them in
//! P pipeline batches.
//!
//! [`partition_ctx`] is the full entry point: given a residency context
//! (per-device pools + operand fingerprints) the
//! [`Balance::ResidencyAware`] policy scores candidate owners by the
//! bytes already resident on each device
//! ([`crate::runtime::residency::ResidencyPool::resident_bytes_of`]) and
//! by the device's memory budget, so warm devices keep their tiles and
//! each device's A/B working set fits its pool.  Without a context the
//! policy degrades to its cold greedy fill.

use std::sync::Arc;

use crate::config::Balance;
use crate::runtime::residency::ResidencyPool;
use crate::spamm::balance::{Assignment, DeviceView};
use crate::spamm::cache::Fingerprint;
use crate::spamm::schedule::Schedule;

/// Per-device work description.
#[derive(Clone, Debug)]
pub struct DeviceWork {
    pub device: usize,
    /// Output tiles owned by this device, grouped into P pipeline batches
    /// (Algorithm 4's batched transfer/compute loop).
    pub tile_batches: Vec<Vec<(usize, usize)>>,
}

impl DeviceWork {
    pub fn tiles(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.tile_batches.iter().flatten().copied()
    }

    pub fn tile_count(&self) -> usize {
        self.tile_batches.iter().map(|b| b.len()).sum()
    }
}

/// Residency context for [`partition_ctx`]: where the operands' tiles
/// currently live and how big one tile is on device.
pub struct PartitionCtx<'a> {
    /// Per-device pools (may be shorter than the device count).
    pub pools: &'a [Arc<ResidencyPool>],
    /// Content fingerprint of the A operand (None disables affinity).
    pub fa: Option<Fingerprint>,
    /// Content fingerprint of the B operand.
    pub fb: Option<Fingerprint>,
    /// Device bytes of one operand tile (LoNum²·4).
    pub tile_bytes: usize,
}

impl PartitionCtx<'_> {
    /// Snapshot the pools into per-device [`DeviceView`]s (one lock per
    /// pool per operand; no LRU perturbation).
    pub fn views(&self, devices: usize) -> Vec<DeviceView> {
        (0..devices)
            .map(|d| {
                let mut view = DeviceView::default();
                if let Some(pool) = self.pools.get(d) {
                    view.budget_bytes = pool.budget_bytes();
                    if let Some(fa) = self.fa {
                        view.a_resident = pool.resident_tiles_of(fa).into_iter().collect();
                    }
                    if let Some(fb) = self.fb {
                        view.b_resident = pool.resident_tiles_of(fb).into_iter().collect();
                    }
                }
                view
            })
            .collect()
    }
}

/// Build the tile→device assignment for the schedule under `policy`,
/// consulting the residency context for [`Balance::ResidencyAware`].
pub fn assignment_ctx(
    sched: &Schedule,
    devices: usize,
    policy: Balance,
    ctx: Option<&PartitionCtx<'_>>,
) -> Assignment {
    match (policy, ctx) {
        (Balance::ResidencyAware, Some(ctx)) if !ctx.pools.is_empty() => {
            let views = ctx.views(devices);
            Assignment::build_residency_aware(sched, devices, &views, ctx.tile_bytes)
        }
        _ => Assignment::build(sched, devices, policy),
    }
}

/// Partition the schedule's output tiles across `devices` workers using the
/// balance policy, then split each device's list into `p` pipeline batches.
pub fn partition(
    sched: &Schedule,
    devices: usize,
    policy: Balance,
    p: usize,
) -> Vec<DeviceWork> {
    partition_ctx(sched, devices, policy, p, None)
}

/// [`partition`] with a residency context (the [`Balance::ResidencyAware`]
/// policy needs pool state; the others ignore it).
pub fn partition_ctx(
    sched: &Schedule,
    devices: usize,
    policy: Balance,
    p: usize,
    ctx: Option<&PartitionCtx<'_>>,
) -> Vec<DeviceWork> {
    let assignment = assignment_ctx(sched, devices, policy, ctx);
    batches_of(sched, &assignment, p)
}

/// Split an assignment's per-device tile lists into P pipeline batches.
/// A device with no tiles gets zero batches — the executor treats an
/// empty batch list as zero work (see the `devices > tiles` regression
/// tests).
pub fn batches_of(sched: &Schedule, assignment: &Assignment, p: usize) -> Vec<DeviceWork> {
    (0..assignment.devices)
        .map(|d| {
            let tiles = assignment.tiles_of(sched, d);
            let p_eff = p.clamp(1, tiles.len().max(1));
            let per = tiles.len().div_ceil(p_eff).max(1);
            let tile_batches = tiles.chunks(per).map(|c| c.to_vec()).collect();
            DeviceWork {
                device: d,
                tile_batches,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::tiling::PaddedMatrix;
    use crate::matrix::Matrix;
    use crate::runtime::residency::TileKey;
    use crate::spamm::cache::fingerprint;
    use crate::spamm::normmap::normmap;

    fn sched(n: usize) -> Schedule {
        let a = Matrix::decay_algebraic(n, 0.1, 0.1, 1);
        let na = normmap(&PaddedMatrix::new(&a, 32));
        Schedule::build(&na, &na, 0.0).unwrap()
    }

    #[test]
    fn covers_all_tiles_once() {
        let s = sched(256);
        for policy in [Balance::RowBlock, Balance::ResidencyAware] {
            for devices in [1, 2, 3, 8] {
                for p in [1, 4, 100] {
                    let work = partition(&s, devices, policy, p);
                    assert_eq!(work.len(), devices);
                    let mut seen = std::collections::BTreeSet::new();
                    for w in &work {
                        for t in w.tiles() {
                            assert!(seen.insert(t), "tile {t:?} duplicated");
                        }
                    }
                    assert_eq!(seen.len(), s.tile_rows * s.tile_cols);
                }
            }
        }
    }

    #[test]
    fn respects_p_batching() {
        let s = sched(256); // 8x8 tiles
        let work = partition(&s, 1, Balance::RowBlock, 4);
        assert_eq!(work[0].tile_batches.len(), 4);
        // P larger than the tile count degrades gracefully.
        let work = partition(&s, 1, Balance::RowBlock, 1000);
        assert!(work[0].tile_batches.len() <= 64);
        assert_eq!(work[0].tile_count(), 64);
    }

    #[test]
    fn more_devices_than_rows() {
        let s = sched(64); // 2x2 tiles
        let work = partition(&s, 8, Balance::RowBlock, 2);
        let total: usize = work.iter().map(|w| w.tile_count()).sum();
        assert_eq!(total, 4);
        // The six idle devices carry zero batches, not empty batches —
        // the shape `execute_batches` must tolerate (regression:
        // devices > tiles).
        assert!(work.iter().skip(1).any(|w| w.tile_batches.is_empty()));
        for w in &work {
            assert!(w.tile_batches.iter().all(|b| !b.is_empty()));
        }
    }

    #[test]
    fn residency_ctx_prefers_warm_device() {
        let s = sched(64); // 2x2 tiles, full schedule at τ=0
        let a = Matrix::decay_algebraic(64, 0.1, 0.1, 1);
        let pa = PaddedMatrix::new(&a, 32);
        let fp = fingerprint(&pa);
        // Warm device 1 with every tile of the operand (A and B are the
        // same matrix here).
        let pools: Vec<Arc<ResidencyPool>> =
            (0..2).map(|_| Arc::new(ResidencyPool::new(0))).collect();
        for ti in 0..2 {
            for tj in 0..2 {
                pools[1].insert(TileKey::new(fp, (ti, tj)), vec![0.0; 32 * 32]);
            }
        }
        let ctx = PartitionCtx {
            pools: &pools,
            fa: Some(fp),
            fb: Some(fp),
            tile_bytes: 32 * 32 * 4,
        };
        let asg = assignment_ctx(&s, 2, Balance::ResidencyAware, Some(&ctx));
        // Every output tile's operands are fully resident on device 1.
        assert!(asg.owner.iter().all(|&d| d == 1), "owners: {:?}", asg.owner);
        // Without the context the policy falls back to a cold partition
        // that uses both devices.
        let cold = assignment_ctx(&s, 2, Balance::ResidencyAware, None);
        assert!(cold.owner.iter().any(|&d| d == 0));
        assert!(cold.owner.iter().any(|&d| d == 1));
    }
}
