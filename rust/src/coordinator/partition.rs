//! Work partitioning for Algorithm 4: each device owns a set of output
//! tiles (via [`crate::spamm::balance::Assignment`]) and processes them in
//! P pipeline batches.

use crate::config::Balance;
use crate::spamm::balance::Assignment;
use crate::spamm::schedule::Schedule;

/// Per-device work description.
#[derive(Clone, Debug)]
pub struct DeviceWork {
    pub device: usize,
    /// Output tiles owned by this device, grouped into P pipeline batches
    /// (Algorithm 4's batched transfer/compute loop).
    pub tile_batches: Vec<Vec<(usize, usize)>>,
}

impl DeviceWork {
    pub fn tiles(&self) -> impl Iterator<Item = (usize, usize)> + '_ {
        self.tile_batches.iter().flatten().copied()
    }

    pub fn tile_count(&self) -> usize {
        self.tile_batches.iter().map(|b| b.len()).sum()
    }
}

/// Partition the schedule's output tiles across `devices` workers using the
/// balance policy, then split each device's list into `p` pipeline batches.
pub fn partition(
    sched: &Schedule,
    devices: usize,
    policy: Balance,
    p: usize,
) -> Vec<DeviceWork> {
    let assignment = Assignment::build(sched, devices, policy);
    (0..devices)
        .map(|d| {
            let tiles = assignment.tiles_of(sched, d);
            let p_eff = p.clamp(1, tiles.len().max(1));
            let per = tiles.len().div_ceil(p_eff).max(1);
            let tile_batches = tiles.chunks(per).map(|c| c.to_vec()).collect();
            DeviceWork {
                device: d,
                tile_batches,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::tiling::PaddedMatrix;
    use crate::matrix::Matrix;
    use crate::spamm::normmap::normmap;

    fn sched(n: usize) -> Schedule {
        let a = Matrix::decay_algebraic(n, 0.1, 0.1, 1);
        let na = normmap(&PaddedMatrix::new(&a, 32));
        Schedule::build(&na, &na, 0.0).unwrap()
    }

    #[test]
    fn covers_all_tiles_once() {
        let s = sched(256);
        for devices in [1, 2, 3, 8] {
            for p in [1, 4, 100] {
                let work = partition(&s, devices, Balance::RowBlock, p);
                assert_eq!(work.len(), devices);
                let mut seen = std::collections::BTreeSet::new();
                for w in &work {
                    for t in w.tiles() {
                        assert!(seen.insert(t), "tile {t:?} duplicated");
                    }
                }
                assert_eq!(seen.len(), s.tile_rows * s.tile_cols);
            }
        }
    }

    #[test]
    fn respects_p_batching() {
        let s = sched(256); // 8x8 tiles
        let work = partition(&s, 1, Balance::RowBlock, 4);
        assert_eq!(work[0].tile_batches.len(), 4);
        // P larger than the tile count degrades gracefully.
        let work = partition(&s, 1, Balance::RowBlock, 1000);
        assert!(work[0].tile_batches.len() <= 64);
        assert_eq!(work[0].tile_count(), 64);
    }

    #[test]
    fn more_devices_than_rows() {
        let s = sched(64); // 2x2 tiles
        let work = partition(&s, 8, Balance::RowBlock, 2);
        let total: usize = work.iter().map(|w| w.tile_count()).sum();
        assert_eq!(total, 4);
    }
}
