//! Session front-end: the serving shape of the library.
//!
//! The paper's win is *reuse* — normmaps, compacted schedules, and
//! device-resident operand tiles amortized across repeated multiplies —
//! but a one-shot `multiply(&a, &b, τ)` API rediscovers all of it per
//! call.  [`SpammSession`] encodes the split the serving workload wants:
//!
//! * **register** — [`SpammSession::put`] stores an operand once and
//!   returns an [`OperandId`].  The store deduplicates by content
//!   fingerprint (two `put`s of identical data share one entry), is
//!   refcounted ([`SpammSession::release`]), and evicts released
//!   operands LRU-first under a byte budget (`store_budget`).  Operands
//!   referenced by prepared plans are pinned: never evicted.
//! * **prepare** — [`SpammSession::prepare`] resolves τ (running the
//!   §3.5.2 tuner once for valid-ratio targets), builds the compacted
//!   schedule through the shared [`ExecCaches`], pins it in the returned
//!   plan, records the expected shapes, and pins the operands' tiles in
//!   the device residency pools.  All host-side: no device round-trip.
//! * **execute** — [`SpammSession::submit`] enqueues a prepared plan
//!   (priority classes, bounded admission queue) and returns a
//!   [`Ticket`].  A background worker thread owns the [`Coordinator`]
//!   (the non-`Send` PJRT runtime never crosses threads) plus — single
//!   device — one *resident* runtime whose compiled executables persist
//!   across requests.  Completions are retrieved out of order via
//!   [`SpammSession::try_recv`] / [`SpammSession::wait`], each carrying
//!   its per-job [`MultiplyStats`].
//!
//! A warm request therefore skips get-norm, scheduling, τ tuning,
//! operand upload, *and* executable compilation — it pays for tile-GEMM
//! on the surviving products and nothing else.

use std::collections::{BTreeMap, BinaryHeap, HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::SpammConfig;
use crate::coordinator::expr::{ExprGraph, ExprNodeReport, ExprPlan, ExprSource};
use crate::coordinator::partition::{assignment_ctx, PartitionCtx};
use crate::coordinator::pipeline::{apply_operand_update, report_to_stats};
use crate::coordinator::service::Approx;
use crate::coordinator::Coordinator;
use crate::error::{Error, Result};
use crate::matrix::tiling::PaddedMatrix;
use crate::matrix::Matrix;
use crate::runtime::residency::ResidencyPool;
use crate::runtime::{ArtifactBundle, Runtime};
use crate::spamm::balance::Assignment;
use crate::spamm::cache::{fingerprint, ExecCaches, Fingerprint};
use crate::spamm::executor::MultiplyStats;
use crate::spamm::normmap::{normmap_with_density, resolve_density_threshold, NormMap};
use crate::spamm::schedule::Schedule;
use crate::spamm::tuner::{self, TuneParams};
use crate::util::prng::Rng;

/// Handle of a registered operand.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OperandId(u64);

impl OperandId {
    pub fn raw(self) -> u64 {
        self.0
    }
}

/// Handle of a prepared multiply plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PlanId(u64);

impl PlanId {
    pub fn raw(self) -> u64 {
        self.0
    }
}

/// Handle of a prepared expression plan ([`SpammSession::prepare_expr`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ExprPlanId(u64);

impl ExprPlanId {
    pub fn raw(self) -> u64 {
        self.0
    }
}

/// Handle of a submitted job; redeem with [`SpammSession::wait`] or
/// [`SpammSession::try_recv`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Ticket(u64);

/// Tickets of submitted expression graphs share the session's ticket
/// namespace — an expression is one queue job, redeemed exactly like a
/// multiply (its [`Completion`] additionally carries per-node reports).
pub type ExprTicket = Ticket;

impl Ticket {
    pub fn raw(self) -> u64 {
        self.0
    }
}

/// Admission priority class.  Higher classes are dequeued first; within a
/// class the queue is FIFO.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Priority {
    Low,
    #[default]
    Normal,
    High,
}

impl Priority {
    pub fn parse(s: &str) -> Result<Priority> {
        match s {
            "low" => Ok(Priority::Low),
            "normal" => Ok(Priority::Normal),
            "high" => Ok(Priority::High),
            _ => Err(Error::Config(format!(
                "unknown priority '{s}' (low | normal | high)"
            ))),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// One finished job.
#[derive(Clone, Debug)]
pub struct Completion {
    pub ticket: Ticket,
    /// The producing plan's id.  Multiply and expression plans share one
    /// id namespace, so this is unique across both; for expression jobs
    /// it carries the [`ExprPlanId`]'s raw id (redeem expression plans
    /// with [`SpammSession::release_expr_plan`], not `release_plan`).
    pub plan: PlanId,
    pub priority: Priority,
    /// The (cropped) product matrix.
    pub c: Matrix,
    /// τ the plan executed with (tuned once at prepare time for
    /// valid-ratio targets).
    pub tau: f32,
    pub valid_ratio: f64,
    /// Seconds from submit to completion (queueing + compute).
    pub latency_secs: f64,
    /// Worker-side wall seconds of the multiply (includes compile only on
    /// cold requests — a warm resident runtime has nothing to compile).
    pub compute_secs: f64,
    /// Modeled per-device busy seconds (time inside PJRT execute).
    pub device_busy: Vec<f64>,
    /// Per-job pipeline/cache/residency breakdown.
    pub stats: MultiplyStats,
    /// Per-node reports when this job was an expression graph
    /// ([`SpammSession::submit_expr`]); empty for plain multiplies.
    pub nodes: Vec<ExprNodeReport>,
}

/// What one [`SpammSession::update`] did incrementally — the receipt a
/// caller inspects to verify the delta stayed a delta (only touched
/// tiles re-fingerprinted/re-uploaded, schedules repaired not rebuilt).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct UpdateReport {
    /// Distinct tile coordinates patched.
    pub tiles_changed: usize,
    /// Whether the norm map was patched in place (vs. recomputed in full
    /// because the old operand's norms were not cached).
    pub norm_patched: bool,
    /// Touched tiles re-censused (norm + density); zero on the full
    /// recompute fallback.
    pub norm_tiles_patched: usize,
    /// Changed resident tiles re-uploaded across all device pools.
    pub uploaded_tiles: usize,
    /// Bytes of those uploads — the delta's whole transfer cost.
    pub uploaded_bytes: u64,
    /// Unchanged resident tiles re-keyed with zero transfer.
    pub rekeyed_tiles: usize,
    /// Stale packed payloads of changed tiles dropped from the pools.
    pub dropped_stale: usize,
    /// Cached schedules repaired in place (affected rows/columns only).
    pub schedules_repaired: usize,
    /// Cached schedules dropped (repair inputs missing; rebuilt on use).
    pub schedules_dropped: usize,
    /// Products that newly crossed τ across all repaired schedules.
    pub products_added: usize,
    /// Products newly culled across all repaired schedules.
    pub products_removed: usize,
    /// Surviving products whose tile strategy flipped.
    pub products_retagged: usize,
    /// Prepared multiply plans migrated to the new fingerprint (their
    /// next submit runs warm on the repaired schedule).
    pub plans_migrated: usize,
    /// Prepared expression plans re-prepared against the patched caches.
    pub expr_plans_migrated: usize,
}

/// Monotonic operand-store counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Total `put` calls.
    pub puts: u64,
    /// `put`s answered by an existing entry (content dedup).
    pub dedup_hits: u64,
    /// Entries evicted under the byte budget.
    pub evictions: u64,
    /// Currently-held bytes (padded operand data).
    pub resident_bytes: u64,
    pub resident_operands: u64,
}

// ---------------------------------------------------------------------
// Operand store
// ---------------------------------------------------------------------

struct OperandEntry {
    padded: Arc<PaddedMatrix>,
    fp: Fingerprint,
    bytes: usize,
    /// Live `put` acquisitions minus `release` calls.
    refs: u32,
    /// Prepared plans referencing this operand (never evicted while > 0).
    pins: u32,
    /// LRU stamp.
    last_use: u64,
}

struct OperandStore {
    entries: HashMap<u64, OperandEntry>,
    by_fp: HashMap<Fingerprint, u64>,
    bytes: usize,
    /// Byte budget (`usize::MAX` = unlimited).
    budget: usize,
    clock: u64,
    next_id: u64,
    stats: StoreStats,
}

impl OperandStore {
    fn new(budget_bytes: usize) -> OperandStore {
        OperandStore {
            entries: HashMap::new(),
            by_fp: HashMap::new(),
            bytes: 0,
            budget: if budget_bytes == 0 {
                usize::MAX
            } else {
                budget_bytes
            },
            clock: 0,
            next_id: 0,
            stats: StoreStats::default(),
        }
    }

    fn touch(&mut self, id: u64) {
        self.clock += 1;
        let clock = self.clock;
        if let Some(e) = self.entries.get_mut(&id) {
            e.last_use = clock;
        }
    }

    /// Evict released, unpinned entries LRU-first until `incoming` fits
    /// the budget.  Everything referenced stays — like the residency
    /// pool, the store overflows rather than invalidating live handles.
    /// An operand larger than the whole budget can never fit: it is
    /// admitted in overflow without pointlessly flushing the warm cache.
    fn evict_for(&mut self, incoming: usize) {
        if incoming > self.budget {
            return;
        }
        while self.bytes.saturating_add(incoming) > self.budget {
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.refs == 0 && e.pins == 0)
                .min_by_key(|(_, e)| e.last_use)
                .map(|(id, _)| *id);
            let Some(id) = victim else { break };
            if let Some(e) = self.entries.remove(&id) {
                self.by_fp.remove(&e.fp);
                self.bytes -= e.bytes;
                self.stats.evictions += 1;
            }
        }
    }

    fn put(&mut self, m: &Matrix, lonum: usize) -> OperandId {
        self.stats.puts += 1;
        let padded = PaddedMatrix::new(m, lonum);
        let fp = fingerprint(&padded);
        if let Some(&id) = self.by_fp.get(&fp) {
            self.stats.dedup_hits += 1;
            if let Some(e) = self.entries.get_mut(&id) {
                e.refs += 1;
            }
            self.touch(id);
            return OperandId(id);
        }
        let bytes = padded.inner.data().len() * std::mem::size_of::<f32>();
        self.evict_for(bytes);
        let id = self.next_id;
        self.next_id += 1;
        self.clock += 1;
        self.entries.insert(
            id,
            OperandEntry {
                padded: Arc::new(padded),
                fp,
                bytes,
                refs: 1,
                pins: 0,
                last_use: self.clock,
            },
        );
        self.by_fp.insert(fp, id);
        self.bytes += bytes;
        OperandId(id)
    }

    fn get(&mut self, id: OperandId) -> Result<(Arc<PaddedMatrix>, Fingerprint)> {
        self.touch(id.0);
        self.entries
            .get(&id.0)
            .map(|e| (e.padded.clone(), e.fp))
            .ok_or_else(|| {
                Error::Session(format!("operand {} not registered (released or evicted)", id.0))
            })
    }

    fn release(&mut self, id: OperandId) -> Result<()> {
        let e = self
            .entries
            .get_mut(&id.0)
            .ok_or_else(|| Error::Session(format!("operand {} not registered", id.0)))?;
        if e.refs == 0 {
            return Err(Error::Session(format!("operand {} already released", id.0)));
        }
        e.refs -= 1;
        // A fully-released entry stays cached (a later `put` of the same
        // content hits it) until budget pressure evicts it.
        self.evict_for(0);
        Ok(())
    }

    fn pin(&mut self, id: OperandId, on: bool) {
        if let Some(e) = self.entries.get_mut(&id.0) {
            if on {
                e.pins += 1;
            } else {
                e.pins = e.pins.saturating_sub(1);
            }
        }
    }

    /// Swap a delta-updated operand's content in place: same id, same
    /// refs/pins/LRU identity, new padded data and fingerprint.  Refuses
    /// if the entry's fingerprint moved since the caller snapshotted it
    /// (a concurrent update of the same operand).
    fn apply_update(
        &mut self,
        id: OperandId,
        old_fp: Fingerprint,
        new_fp: Fingerprint,
        padded: Arc<PaddedMatrix>,
    ) -> Result<()> {
        let e = self
            .entries
            .get_mut(&id.0)
            .ok_or_else(|| Error::Session(format!("operand {} not registered", id.0)))?;
        if e.fp != old_fp {
            return Err(Error::Session(format!(
                "operand {} changed during update (concurrent update?)",
                id.0
            )));
        }
        e.fp = new_fp;
        e.padded = padded;
        self.by_fp.remove(&old_fp);
        self.by_fp.insert(new_fp, id.0);
        self.touch(id.0);
        Ok(())
    }

    fn stats(&self) -> StoreStats {
        let mut s = self.stats;
        s.resident_bytes = self.bytes as u64;
        s.resident_operands = self.entries.len() as u64;
        s
    }
}

// ---------------------------------------------------------------------
// Plans
// ---------------------------------------------------------------------

/// Content key of a plan: which operands at which approximation level.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum ApproxKey {
    Tau(u32),
    Ratio(u64),
}

fn approx_key(a: Approx) -> ApproxKey {
    match a {
        Approx::Tau(t) => ApproxKey::Tau(t.to_bits()),
        Approx::ValidRatio(r) => ApproxKey::Ratio(r.to_bits()),
    }
}

struct Plan {
    id: u64,
    a: OperandId,
    b: OperandId,
    /// The padded operands themselves: a queued job is self-contained,
    /// so releasing the plan (or even evicting the store entries) can
    /// never fail a job that was already admitted.
    pa: Arc<PaddedMatrix>,
    pb: Arc<PaddedMatrix>,
    fa: Fingerprint,
    fb: Fingerprint,
    tau: f32,
    /// The density threshold the schedule was built with — the value
    /// `auto` resolved to at prepare time.  Delta updates migrate the
    /// plan at this exact threshold so the repaired schedule stays
    /// bitwise identical to a cold rebuild at the same τ/threshold.
    density_threshold: f32,
    /// The compacted schedule, pinned for the plan's lifetime (cache
    /// eviction cannot un-prepare a plan).
    schedule: Arc<Schedule>,
    /// Expected output shape.
    rows: usize,
    cols: usize,
    dedup: (OperandId, OperandId, ApproxKey),
    /// One-time analysis cost (normmaps, τ tuning, schedule compaction)
    /// paid at `prepare`.  Charged to the *first* job that executes the
    /// plan, so per-request `MultiplyStats` honestly show the cold
    /// request paying the front phases and warm requests skipping them.
    prepare_secs: f64,
    /// Front-phase breakdown (norm/schedule timings + cache counters)
    /// recorded at `prepare`, folded into the cold job's stats.
    front: MultiplyStats,
    /// Devices whose pools the plan pinned its operands into — the
    /// devices the prepare-time partition assigns work to.  A device
    /// with no tiles of this plan keeps its pool churn-free.
    pin_devices: Vec<usize>,
    /// The prepare-time tile→device assignment, pinned like the
    /// schedule: execution runs exactly this placement, so the pinned
    /// pools are exactly the pools that get used even when residency
    /// shifts between prepare and submit.
    assignment: Assignment,
    /// Whether a job has already been charged the prepare cost.
    cold_charged: std::sync::atomic::AtomicBool,
}

/// A prepared plan plus its handle refcount: `prepare` returning an
/// existing plan hands out another reference, so one holder's
/// `release_plan` cannot invalidate another's handle.
struct PlanEntry {
    plan: Arc<Plan>,
    refs: u32,
}

/// A prepared expression graph: the coordinator-level plan (shapes, τ,
/// bounds, derived fingerprints — self-contained, including the padded
/// operands) plus the pin bookkeeping mirrored from multiply plans.
struct ExprJob {
    id: u64,
    plan: ExprPlan,
    /// The source graph, kept so a delta update of an input operand can
    /// re-prepare the plan in place (warm: patched norms and repaired
    /// schedules are already cached).
    graph: ExprGraph,
    /// Store handles pinned for the plan's lifetime.
    operands: Vec<OperandId>,
    /// Operand fingerprints pinned in the device residency pools.
    fps: Vec<Fingerprint>,
    /// Devices whose pools the fps were pinned into — the devices the
    /// plan's placement maps assign work to (regression: pinning used
    /// to hit every pool even for devices the graph never touches).
    pin_devices: Vec<usize>,
    /// Whether a job has been charged the prepare cost (cold first job).
    cold_charged: std::sync::atomic::AtomicBool,
}

#[derive(Default)]
struct PlanTable {
    plans: HashMap<u64, PlanEntry>,
    dedup: HashMap<(OperandId, OperandId, ApproxKey), u64>,
    /// Shared by multiply and expression plans, so the raw id a
    /// [`Completion`] carries is unique across both tables — a
    /// `release_plan` on an expression completion's id errors instead of
    /// silently releasing an unrelated multiply plan.
    next_id: u64,
    exprs: HashMap<u64, Arc<ExprJob>>,
}

// ---------------------------------------------------------------------
// Queue / completions
// ---------------------------------------------------------------------

/// What a queued job executes: a prepared multiply or a whole prepared
/// expression graph (one queue slot either way).
enum JobPayload {
    Multiply(Arc<Plan>),
    Expr(Arc<ExprJob>),
}

struct QueuedJob {
    priority: Priority,
    /// Admission order; FIFO tie-break within a priority class.
    seq: u64,
    ticket: u64,
    payload: JobPayload,
    submitted: Instant,
}

impl PartialEq for QueuedJob {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}

impl Eq for QueuedJob {}

impl PartialOrd for QueuedJob {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueuedJob {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Max-heap: higher priority first, then earlier seq.
        self.priority
            .cmp(&other.priority)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

struct QueueState {
    heap: BinaryHeap<QueuedJob>,
    closed: bool,
    /// Jobs popped by the worker but not yet completed.
    inflight: usize,
}

type JobOutcome = Result<Completion>;

struct DoneState {
    map: HashMap<u64, JobOutcome>,
    /// Tickets submitted but not yet redeemed — lets `wait` distinguish
    /// "still coming" from "unknown or already received" without
    /// guessing from queue emptiness.
    outstanding: HashSet<u64>,
    /// The worker thread has exited (graceful close or death); waiters
    /// must not block on tickets that can never complete.
    dead: bool,
}

struct Shared {
    cfg: SpammConfig,
    caches: Arc<ExecCaches>,
    pools: Vec<Arc<ResidencyPool>>,
    store: Mutex<OperandStore>,
    /// Deferred deltas per operand, coalesced tile-wise (last writer
    /// wins) until the next submit — or an explicit flush — applies each
    /// operand's union as *one* patch.
    pending: Mutex<HashMap<OperandId, BTreeMap<(usize, usize), Vec<f32>>>>,
    plans: Mutex<PlanTable>,
    queue: Mutex<QueueState>,
    queue_cv: Condvar,
    done: Mutex<DoneState>,
    done_cv: Condvar,
}

/// Marks the worker dead on *any* exit path (including a panic) so
/// session-side waiters wake up instead of hanging.
struct DeadFlag(Arc<Shared>);

impl Drop for DeadFlag {
    fn drop(&mut self) {
        self.0.done.lock().unwrap().dead = true;
        self.0.done_cv.notify_all();
    }
}

// ---------------------------------------------------------------------
// The session
// ---------------------------------------------------------------------

/// Registered-operand, prepared-plan, async-ticketed SpAMM serving
/// front-end (see module docs for the lifecycle).
pub struct SpammSession {
    shared: Arc<Shared>,
    worker: Option<std::thread::JoinHandle<()>>,
    next_ticket: AtomicU64,
    next_seq: AtomicU64,
}

impl SpammSession {
    pub fn new(bundle: &ArtifactBundle, cfg: SpammConfig) -> Result<SpammSession> {
        cfg.validate()?;
        let caches = Arc::new(ExecCaches::with_store(crate::store::WarmStore::from_config(
            &cfg,
        )));
        let pools: Vec<Arc<ResidencyPool>> = if cfg.residency_enabled {
            (0..cfg.devices)
                .map(|_| Arc::new(ResidencyPool::new(cfg.device_mem_budget)))
                .collect()
        } else {
            Vec::new()
        };
        // The coordinator is constructed here (errors surface to the
        // caller) and moved into the worker thread, which it never
        // leaves: the non-`Send` PJRT runtimes it builds stay put.
        let shared_pools = (!pools.is_empty()).then(|| pools.clone());
        let coord = Coordinator::with_shared(bundle, cfg.clone(), caches.clone(), shared_pools)?;
        let store_budget = cfg.store_budget;
        let shared = Arc::new(Shared {
            cfg,
            caches,
            pools,
            store: Mutex::new(OperandStore::new(store_budget)),
            pending: Mutex::new(HashMap::new()),
            plans: Mutex::new(PlanTable::default()),
            queue: Mutex::new(QueueState {
                heap: BinaryHeap::new(),
                closed: false,
                inflight: 0,
            }),
            queue_cv: Condvar::new(),
            done: Mutex::new(DoneState {
                map: HashMap::new(),
                outstanding: HashSet::new(),
                dead: false,
            }),
            done_cv: Condvar::new(),
        });
        let worker_shared = shared.clone();
        let worker = std::thread::Builder::new()
            .name("spamm-session".into())
            .spawn(move || worker_loop(coord, worker_shared))?;
        Ok(SpammSession {
            shared,
            worker: Some(worker),
            next_ticket: AtomicU64::new(0),
            next_seq: AtomicU64::new(0),
        })
    }

    pub fn config(&self) -> &SpammConfig {
        &self.shared.cfg
    }

    /// The shared norm/schedule caches (hit/miss inspection).
    pub fn caches(&self) -> &ExecCaches {
        &self.shared.caches
    }

    /// The per-device residency pools (empty under `--no-residency`).
    pub fn residency_pools(&self) -> &[Arc<ResidencyPool>] {
        &self.shared.pools
    }

    // -- register ------------------------------------------------------

    /// Register an operand; content-identical `put`s return the same
    /// handle (and bump its refcount).
    pub fn put(&self, m: &Matrix) -> Result<OperandId> {
        if m.rows() == 0 || m.cols() == 0 {
            return Err(Error::Shape("put: empty operand".into()));
        }
        Ok(self.shared.store.lock().unwrap().put(m, self.shared.cfg.lonum))
    }

    /// Drop one reference to a registered operand.  The entry stays
    /// cached for future `put`s of the same content until the store
    /// budget evicts it; operands pinned by prepared plans are never
    /// evicted.
    pub fn release(&self, id: OperandId) -> Result<()> {
        self.shared.store.lock().unwrap().release(id)
    }

    /// Operand-store counters.
    pub fn store_stats(&self) -> StoreStats {
        self.shared.store.lock().unwrap().stats()
    }

    // -- incremental updates -------------------------------------------

    /// Delta-update a registered operand in place: overwrite the listed
    /// padded-grid tiles with `data` (one row-major LoNum² block per
    /// coordinate, concatenated in the order of `changed`) and propagate
    /// the change *incrementally* through every layer that knows the
    /// operand:
    ///
    /// * the content fingerprint is re-derived from the old fingerprint
    ///   plus the changed tiles only — no full re-hash;
    /// * the cached norm map is patched in place (norms + density census
    ///   of the touched tiles only);
    /// * device-resident tiles migrate to the new fingerprint — only the
    ///   changed tiles re-upload; unchanged tiles (dense *and* still-valid
    ///   packed payloads) re-key with zero transfer, and stale packed
    ///   payloads of changed tiles are dropped;
    /// * cached schedules involving the operand are *repaired* — only
    ///   products whose norms crossed τ or whose tile strategy flipped
    ///   are added/removed/retagged, in the affected rows/columns only —
    ///   and re-keyed, bitwise identical to a cold rebuild at the same
    ///   τ/threshold;
    /// * prepared plans referencing the operand survive: they migrate to
    ///   the new fingerprint (pins included) and their next submit runs
    ///   warm, with the repair accounted in that job's
    ///   [`MultiplyStats`].
    ///
    /// The operand keeps its [`OperandId`], refcount, and pins.  Jobs
    /// already submitted keep executing the pre-update snapshot.
    pub fn update(
        &self,
        id: OperandId,
        changed: &[(usize, usize)],
        data: &[f32],
    ) -> Result<UpdateReport> {
        // Route through the coalescing buffer: any deltas deferred for
        // this operand since the last submit merge with this one, and the
        // union lands as a single patch (one fingerprint derivation, one
        // norm patch, one repair sweep).
        self.update_deferred(id, changed, data)?;
        // An empty delta (and nothing previously deferred) is a no-op
        // receipt, not an error — flush_operand has nothing to apply.
        Ok(self.flush_operand(id)?.unwrap_or_default())
    }

    /// Defer a delta without applying it: the changed tiles merge into
    /// the operand's pending patch (tile-wise, last writer wins).  The
    /// patch applies as one [`SpammSession::update`]-equivalent pass at
    /// the next submit, an explicit [`SpammSession::flush_updates`], or a
    /// direct `update` of the same operand — whichever comes first.
    /// Returns the number of distinct tiles now pending for the operand.
    ///
    /// `data` holds one LoNum×LoNum row-major payload per entry of
    /// `changed`, in order; duplicate coordinates keep the last payload.
    pub fn update_deferred(
        &self,
        id: OperandId,
        changed: &[(usize, usize)],
        data: &[f32],
    ) -> Result<usize> {
        let (padded, _) = self.shared.store.lock().unwrap().get(id)?;
        let l2 = padded.lonum * padded.lonum;
        if data.len() != changed.len() * l2 {
            return Err(Error::Shape(format!(
                "update_deferred: {} changed tiles need {} values, got {}",
                changed.len(),
                changed.len() * l2,
                data.len()
            )));
        }
        let (tr, tc) = (padded.tile_rows(), padded.tile_cols());
        for &(ti, tj) in changed {
            if ti >= tr || tj >= tc {
                return Err(Error::Shape(format!(
                    "update_deferred: tile ({ti}, {tj}) outside the {tr}x{tc} grid"
                )));
            }
        }
        let mut pending = self.shared.pending.lock().unwrap();
        let entry = pending.entry(id).or_default();
        for (i, &t) in changed.iter().enumerate() {
            entry.insert(t, data[i * l2..(i + 1) * l2].to_vec());
        }
        Ok(entry.len())
    }

    /// Apply every pending deferred delta, one merged patch per operand.
    /// Returns the per-operand receipts in operand-id order; empty when
    /// nothing was pending.  Submits call this implicitly — jobs never
    /// run against half-flushed operands.
    pub fn flush_updates(&self) -> Result<Vec<(OperandId, UpdateReport)>> {
        let mut ids: Vec<OperandId> = self.shared.pending.lock().unwrap().keys().copied().collect();
        ids.sort_unstable_by_key(|id| id.0);
        let mut out = Vec::with_capacity(ids.len());
        for id in ids {
            if let Some(report) = self.flush_operand(id)? {
                out.push((id, report));
            }
        }
        Ok(out)
    }

    /// Apply (and clear) the pending patch of one operand, if any.
    fn flush_operand(&self, id: OperandId) -> Result<Option<UpdateReport>> {
        let Some(tiles) = self.shared.pending.lock().unwrap().remove(&id) else {
            return Ok(None);
        };
        if tiles.is_empty() {
            return Ok(None);
        }
        let mut changed = Vec::with_capacity(tiles.len());
        let mut data = Vec::with_capacity(tiles.len());
        for (t, payload) in &tiles {
            changed.push(*t);
            data.extend_from_slice(payload);
        }
        self.apply_merged_update(id, &changed, &data).map(Some)
    }

    /// The one-merged-patch application path behind `update`/`flush_*`.
    fn apply_merged_update(
        &self,
        id: OperandId,
        changed: &[(usize, usize)],
        data: &[f32],
    ) -> Result<UpdateReport> {
        let (old_padded, old_fp) = self.shared.store.lock().unwrap().get(id)?;
        let up = apply_operand_update(
            &self.shared.cfg,
            &self.shared.caches,
            &self.shared.pools,
            &old_padded,
            old_fp,
            changed,
            data,
        )?;
        let new_padded = Arc::new(up.padded);
        let new_fp = up.fp;
        self.shared
            .store
            .lock()
            .unwrap()
            .apply_update(id, old_fp, new_fp, new_padded.clone())?;

        let mut tiles = changed.to_vec();
        tiles.sort_unstable();
        tiles.dedup();
        let mut report = UpdateReport {
            tiles_changed: tiles.len(),
            norm_patched: up.norm_patched,
            norm_tiles_patched: up.norm_tiles_patched,
            uploaded_tiles: up.pool.uploaded_tiles,
            uploaded_bytes: up.pool.uploaded_bytes,
            rekeyed_tiles: up.pool.rekeyed_tiles,
            dropped_stale: up.pool.dropped_stale,
            schedules_repaired: up.repair.repaired,
            schedules_dropped: up.repair.dropped,
            products_added: up.repair.products_added,
            products_removed: up.repair.products_removed,
            products_retagged: up.repair.products_retagged,
            ..UpdateReport::default()
        };

        // Migrate every prepared plan referencing the operand.  Same lock
        // order as `prepare` (plans → store); in-flight jobs hold the old
        // plan Arc and complete on the pre-update snapshot.
        let mut plans = self.shared.plans.lock().unwrap();
        let plan_ids: Vec<u64> = plans
            .plans
            .iter()
            .filter(|(_, e)| e.plan.a == id || e.plan.b == id)
            .map(|(k, _)| *k)
            .collect();
        for pid in plan_ids {
            let old = plans
                .plans
                .get(&pid)
                .map(|e| e.plan.clone())
                .expect("plan id collected under this lock");
            let t_plan = Instant::now();
            let mut front = MultiplyStats::default();
            let (touched_a, touched_b) = (old.a == id, old.b == id);
            let pa = if touched_a { new_padded.clone() } else { old.pa.clone() };
            let pb = if touched_b { new_padded.clone() } else { old.pb.clone() };
            let fa = if touched_a { new_fp } else { old.fa };
            let fb = if touched_b { new_fp } else { old.fb };
            let na = self.norm_for(fa, &pa, &mut front)?;
            let nb = self.norm_for(fb, &pb, &mut front)?;
            // The repair sweep re-keyed the plan's cache entry to the new
            // fingerprint, so this lookup hits the *repaired* schedule —
            // a miss here means repair had to drop it (rebuild once).
            let schedule = if self.shared.cfg.cache_enabled {
                self.shared.caches.schedule_via(
                    Some(fa),
                    Some(fb),
                    old.tau,
                    old.density_threshold,
                    &na,
                    &nb,
                    &mut front,
                )?
            } else {
                Arc::new(Schedule::build_adaptive(
                    &na,
                    &nb,
                    old.tau,
                    old.density_threshold,
                )?)
            };
            if front.schedule_cache_hits > 0 {
                front.schedules_repaired = 1;
                front.repair_products_added = up.repair.products_added;
                front.repair_products_removed = up.repair.products_removed;
                front.repair_products_retagged = up.repair.products_retagged;
            }
            front.norm_tiles_patched = up.norm_tiles_patched;
            let assignment = {
                let cfg = &self.shared.cfg;
                let ctx = PartitionCtx {
                    pools: &self.shared.pools,
                    fa: Some(fa),
                    fb: Some(fb),
                    tile_bytes: cfg.lonum * cfg.lonum * std::mem::size_of::<f32>(),
                };
                assignment_ctx(&schedule, cfg.devices, cfg.balance, Some(&ctx))
            };
            let pin_devices: Vec<usize> = (0..self.shared.cfg.devices)
                .filter(|&d| assignment.owner.iter().any(|&o| o == d))
                .collect();
            // Pool pin counts for the touched fingerprint migrated
            // wholesale with the tiles; only the device *set* can drift.
            for &d in &old.pin_devices {
                if !pin_devices.contains(&d) {
                    if let Some(p) = self.shared.pools.get(d) {
                        p.unpin_operand(fa);
                        p.unpin_operand(fb);
                    }
                }
            }
            for &d in &pin_devices {
                if !old.pin_devices.contains(&d) {
                    if let Some(p) = self.shared.pools.get(d) {
                        p.pin_operand(fa);
                        p.pin_operand(fb);
                    }
                }
            }
            #[cfg(debug_assertions)]
            crate::audit::debug_assert_clean(
                &crate::audit::audit_multiply_plan(
                    &na,
                    &nb,
                    old.tau,
                    old.density_threshold,
                    &schedule,
                    &assignment,
                    &pin_devices,
                ),
                "session update (migrated plan)",
            );
            let migrated = Arc::new(Plan {
                id: old.id,
                a: old.a,
                b: old.b,
                pa,
                pb,
                fa,
                fb,
                tau: old.tau,
                density_threshold: old.density_threshold,
                schedule,
                rows: old.rows,
                cols: old.cols,
                dedup: old.dedup,
                prepare_secs: t_plan.elapsed().as_secs_f64(),
                front,
                pin_devices,
                assignment,
                cold_charged: std::sync::atomic::AtomicBool::new(false),
            });
            if let Some(e) = plans.plans.get_mut(&pid) {
                e.plan = migrated;
            }
            report.plans_migrated += 1;
        }

        // Re-prepare expression plans over the updated operand: warm by
        // construction — the patched norms and repaired schedules are
        // already cached under the new fingerprint.
        let expr_ids: Vec<u64> = plans
            .exprs
            .iter()
            .filter(|(_, j)| j.operands.contains(&id))
            .map(|(k, _)| *k)
            .collect();
        for eid in expr_ids {
            let old = plans
                .exprs
                .get(&eid)
                .cloned()
                .expect("expr id collected under this lock");
            let resolved: Vec<(Arc<PaddedMatrix>, Fingerprint)> = {
                let mut store = self.shared.store.lock().unwrap();
                old.operands
                    .iter()
                    .map(|oid| store.get(*oid))
                    .collect::<Result<Vec<_>>>()?
            };
            let sources: Vec<ExprSource<'_>> = resolved
                .iter()
                .map(|(p, f)| ExprSource::Padded(p.clone(), *f))
                .collect();
            let plan = old.graph.prepare_placed(
                &self.shared.caches,
                &self.shared.cfg,
                &self.shared.pools,
                &sources,
            )?;
            let fps = plan.input_fingerprints();
            let pin_devices = plan.devices_used();
            // The updated operand's pool pins migrated to the new
            // fingerprint with its tiles — translate before unpinning.
            let translated: Vec<Fingerprint> = old
                .fps
                .iter()
                .map(|f| if *f == old_fp { new_fp } else { *f })
                .collect();
            for &d in &old.pin_devices {
                if let Some(pool) = self.shared.pools.get(d) {
                    for f in &translated {
                        pool.unpin_operand(*f);
                    }
                }
            }
            for &d in &pin_devices {
                if let Some(pool) = self.shared.pools.get(d) {
                    for f in &fps {
                        pool.pin_operand(*f);
                    }
                }
            }
            plans.exprs.insert(
                eid,
                Arc::new(ExprJob {
                    id: old.id,
                    plan,
                    graph: old.graph.clone(),
                    operands: old.operands.clone(),
                    fps,
                    pin_devices,
                    cold_charged: std::sync::atomic::AtomicBool::new(false),
                }),
            );
            report.expr_plans_migrated += 1;
        }
        Ok(report)
    }

    /// Cached norm map by fingerprint (computing + registering on miss);
    /// bypasses the cache entirely under `--no-cache`.
    fn norm_for(
        &self,
        fp: Fingerprint,
        p: &Arc<PaddedMatrix>,
        front: &mut MultiplyStats,
    ) -> Result<Arc<NormMap>> {
        if self.shared.cfg.cache_enabled {
            self.shared
                .caches
                .normmap_keyed(fp, front, || Ok(normmap_with_density(p)))
        } else {
            Ok(Arc::new(normmap_with_density(p)))
        }
    }

    // -- prepare -------------------------------------------------------

    /// Prepare a multiply: resolve τ (tuner for valid-ratio targets),
    /// build + pin the compacted schedule, record expected shapes, pin
    /// the operands (store + device residency pools).  Identical
    /// `(a, b, approx)` triples return the same plan.
    pub fn prepare(&self, a: OperandId, b: OperandId, approx: Approx) -> Result<PlanId> {
        approx.validate()?;
        let key = (a, b, approx_key(approx));
        {
            let mut plans = self.shared.plans.lock().unwrap();
            if let Some(&id) = plans.dedup.get(&key) {
                if let Some(e) = plans.plans.get_mut(&id) {
                    e.refs += 1;
                }
                return Ok(PlanId(id));
            }
        }
        let (pa, fa, pb, fb) = {
            let mut store = self.shared.store.lock().unwrap();
            let (pa, fa) = store.get(a)?;
            let (pb, fb) = store.get(b)?;
            (pa, fa, pb, fb)
        };
        if pa.logical_cols != pb.logical_rows {
            return Err(Error::Shape(format!(
                "prepare: inner dimensions disagree: A is {}x{}, B is {}x{}",
                pa.logical_rows, pa.logical_cols, pb.logical_rows, pb.logical_cols
            )));
        }
        // Host-side analysis — deliberately outside the plan-table lock so
        // a slow cold prepare cannot stall submits of unrelated warm
        // plans.  Normmaps go through the shared caches keyed on the
        // store's fingerprints (no re-hash); the schedule is keyed on
        // (fa, fb, τ).  `--no-cache` computes without memoizing either.
        let t_prepare = Instant::now();
        let mut front = MultiplyStats::default();
        let t = Instant::now();
        let (na, nb) = if self.shared.cfg.cache_enabled {
            (
                self.shared
                    .caches
                    .normmap_keyed(fa, &mut front, || Ok(normmap_with_density(&pa)))?,
                self.shared
                    .caches
                    .normmap_keyed(fb, &mut front, || Ok(normmap_with_density(&pb)))?,
            )
        } else {
            (
                Arc::new(normmap_with_density(&pa)),
                Arc::new(normmap_with_density(&pb)),
            )
        };
        let tau = match approx {
            Approx::Tau(t) => t,
            Approx::ValidRatio(r) => {
                // Tuned τ is pure in (A, B, target, tuner params) — a
                // store hit restores the exact bisection result without
                // re-running the expansion/bisection loop.
                let params = TuneParams::default();
                let tkey = crate::store::TauKey::new(fa, fb, r, &params);
                let stored = self.shared.caches.store().and_then(|s| s.load_tau(&tkey));
                match stored {
                    Some(t) => {
                        front.store_tau_hits += 1;
                        t.tau
                    }
                    None => {
                        let tuned = tuner::tune_tau(&na.norms, &nb.norms, r, params)?;
                        front.tau_tuned += 1;
                        if let Some(s) = self.shared.caches.store() {
                            s.save_tau(&tkey, &tuned);
                        }
                        tuned.tau
                    }
                }
            }
        };
        // Norm phase of the plan's front stats spans normmaps + τ
        // resolution (MultiplyStats has no separate tuner clock).
        front.norm_secs = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let density_threshold = resolve_density_threshold(&self.shared.cfg, &na, &nb);
        let schedule = if self.shared.cfg.cache_enabled {
            self.shared.caches.schedule_via(
                Some(fa),
                Some(fb),
                tau,
                density_threshold,
                &na,
                &nb,
                &mut front,
            )?
        } else {
            Arc::new(Schedule::build_adaptive(&na, &nb, tau, density_threshold)?)
        };
        front.schedule_secs = t.elapsed().as_secs_f64();
        let prepare_secs = t_prepare.elapsed().as_secs_f64();
        // Double-checked insert: a concurrent prepare of the same triple
        // may have won while we computed — take a reference on its plan
        // and drop ours (no pins were taken yet).
        let mut plans = self.shared.plans.lock().unwrap();
        if let Some(&id) = plans.dedup.get(&key) {
            if let Some(e) = plans.plans.get_mut(&id) {
                e.refs += 1;
            }
            return Ok(PlanId(id));
        }
        {
            let mut store = self.shared.store.lock().unwrap();
            store.pin(a, true);
            store.pin(b, true);
        }
        // Pin the operands only in the pools of the devices the
        // prepare-time partition actually assigns tiles to — idle
        // devices (devices > tiles, or a residency-aware partition that
        // concentrates this plan elsewhere) keep their pools unpinned.
        // The assignment itself is pinned in the plan, so execution runs
        // exactly this placement.
        let assignment = {
            let cfg = &self.shared.cfg;
            let ctx = PartitionCtx {
                pools: &self.shared.pools,
                fa: Some(fa),
                fb: Some(fb),
                tile_bytes: cfg.lonum * cfg.lonum * std::mem::size_of::<f32>(),
            };
            assignment_ctx(&schedule, cfg.devices, cfg.balance, Some(&ctx))
        };
        let pin_devices: Vec<usize> = (0..self.shared.cfg.devices)
            .filter(|&d| assignment.owner.iter().any(|&o| o == d))
            .collect();
        for &d in &pin_devices {
            if let Some(p) = self.shared.pools.get(d) {
                p.pin_operand(fa);
                p.pin_operand(fb);
            }
        }
        #[cfg(debug_assertions)]
        crate::audit::debug_assert_clean(
            &crate::audit::audit_multiply_plan(
                &na,
                &nb,
                tau,
                density_threshold,
                &schedule,
                &assignment,
                &pin_devices,
            ),
            "session prepare",
        );
        let id = plans.next_id;
        plans.next_id += 1;
        plans.plans.insert(
            id,
            PlanEntry {
                plan: Arc::new(Plan {
                    id,
                    a,
                    b,
                    rows: pa.logical_rows,
                    cols: pb.logical_cols,
                    pa,
                    pb,
                    fa,
                    fb,
                    tau,
                    density_threshold,
                    schedule,
                    dedup: key,
                    prepare_secs,
                    front,
                    pin_devices,
                    assignment,
                    cold_charged: std::sync::atomic::AtomicBool::new(false),
                }),
                refs: 1,
            },
        );
        plans.dedup.insert(key, id);
        Ok(PlanId(id))
    }

    /// The τ a prepared plan resolved to, and its expected output shape.
    pub fn plan_info(&self, id: PlanId) -> Result<(f32, usize, usize)> {
        let plans = self.shared.plans.lock().unwrap();
        plans
            .plans
            .get(&id.0)
            .map(|e| (e.plan.tau, e.plan.rows, e.plan.cols))
            .ok_or_else(|| Error::Session(format!("plan {} not prepared", id.0)))
    }

    /// The schedule a prepared plan would execute, with the τ and
    /// density threshold it was built (or repaired) at — the auditor's
    /// window for repair≡rebuild structural checks.
    pub fn plan_schedule(&self, id: PlanId) -> Result<(Arc<Schedule>, f32, f32)> {
        let plans = self.shared.plans.lock().unwrap();
        plans
            .plans
            .get(&id.0)
            .map(|e| (e.plan.schedule.clone(), e.plan.tau, e.plan.density_threshold))
            .ok_or_else(|| Error::Session(format!("plan {} not prepared", id.0)))
    }

    /// The content fingerprints of a prepared plan's operands, tracking
    /// migrations: after [`SpammSession::update`] the returned pair is
    /// the *patched* operands'.  The serving tier derives its result-cache
    /// keys from these.
    pub fn plan_fingerprints(&self, id: PlanId) -> Result<(Fingerprint, Fingerprint)> {
        let plans = self.shared.plans.lock().unwrap();
        plans
            .plans
            .get(&id.0)
            .map(|e| (e.plan.fa, e.plan.fb))
            .ok_or_else(|| Error::Session(format!("plan {} not prepared", id.0)))
    }

    /// Statically audit every live artifact of the session: each
    /// prepared multiply plan (schedule soundness against the cached
    /// normmaps + assignment exclusivity), each prepared expression plan
    /// (dataflow liveness, fingerprints, placement), and the device
    /// residency pools (byte accounting; every pinned operand must
    /// belong to a live plan).  Executes nothing — see [`crate::audit`].
    pub fn audit(&self) -> Result<crate::audit::AuditReport> {
        let mut r = crate::audit::AuditReport::default();
        // Snapshot the live plan Arcs, then drop the plan-table lock
        // before any cache/pool work (lock order: plans → store → pools).
        let (plan_arcs, expr_arcs) = {
            let plans = self.shared.plans.lock().unwrap();
            (
                plans.plans.values().map(|e| e.plan.clone()).collect::<Vec<_>>(),
                plans.exprs.values().cloned().collect::<Vec<_>>(),
            )
        };
        let mut live: HashMap<usize, HashSet<Fingerprint>> = HashMap::new();
        for plan in &plan_arcs {
            let mut front = MultiplyStats::default();
            let na = self.norm_for(plan.fa, &plan.pa, &mut front)?;
            let nb = self.norm_for(plan.fb, &plan.pb, &mut front)?;
            r.merge(crate::audit::audit_multiply_plan(
                &na,
                &nb,
                plan.tau,
                plan.density_threshold,
                &plan.schedule,
                &plan.assignment,
                &plan.pin_devices,
            ));
            for &d in &plan.pin_devices {
                let fps = live.entry(d).or_default();
                fps.insert(plan.fa);
                fps.insert(plan.fb);
            }
        }
        for job in &expr_arcs {
            r.merge(crate::audit::audit_expr_plan(&job.plan));
            for &d in &job.pin_devices {
                live.entry(d).or_default().extend(job.fps.iter().copied());
            }
        }
        r.merge(crate::audit::audit_pools(&self.shared.pools, &live));
        Ok(r)
    }

    /// Drop one reference to a prepared plan.  Plan handles are
    /// refcounted (`prepare` of an identical triple returns another
    /// reference to the same plan); the plan itself — and its operand
    /// pins in the store and residency pools — goes away when the last
    /// reference is released.  In-flight jobs always complete: they hold
    /// the plan's data independently.
    pub fn release_plan(&self, id: PlanId) -> Result<()> {
        let plan = {
            let mut plans = self.shared.plans.lock().unwrap();
            let entry = plans
                .plans
                .get_mut(&id.0)
                .ok_or_else(|| Error::Session(format!("plan {} not prepared", id.0)))?;
            entry.refs -= 1;
            if entry.refs > 0 {
                return Ok(());
            }
            let entry = plans.plans.remove(&id.0).expect("entry exists under the lock");
            plans.dedup.remove(&entry.plan.dedup);
            entry.plan
        };
        {
            let mut store = self.shared.store.lock().unwrap();
            store.pin(plan.a, false);
            store.pin(plan.b, false);
        }
        for &d in &plan.pin_devices {
            if let Some(p) = self.shared.pools.get(d) {
                p.unpin_operand(plan.fa);
                p.unpin_operand(plan.fb);
            }
        }
        Ok(())
    }

    // -- execute -------------------------------------------------------

    /// Enqueue a prepared plan at [`Priority::Normal`].
    pub fn submit(&self, plan: PlanId) -> Result<Ticket> {
        self.submit_with(plan, Priority::Normal)
    }

    /// Enqueue a prepared plan at an explicit priority class.  Fails when
    /// the admission queue is at `queue_depth`.
    pub fn submit_with(&self, plan: PlanId, priority: Priority) -> Result<Ticket> {
        // Deferred deltas land before admission, so the job (and every
        // plan migration they trigger) sees the coalesced content.
        self.flush_updates()?;
        let plan = {
            let plans = self.shared.plans.lock().unwrap();
            plans
                .plans
                .get(&plan.0)
                .map(|e| e.plan.clone())
                .ok_or_else(|| Error::Session(format!("plan {} not prepared", plan.0)))?
        };
        // Always-on debug audit: re-verify the plan's pinned schedule and
        // placement against the (cached) normmaps at the moment of
        // admission — a migration or repair bug between prepare and
        // submit dies here instead of producing a silently wrong product.
        #[cfg(debug_assertions)]
        {
            let mut front = MultiplyStats::default();
            let na = self.norm_for(plan.fa, &plan.pa, &mut front)?;
            let nb = self.norm_for(plan.fb, &plan.pb, &mut front)?;
            crate::audit::debug_assert_clean(
                &crate::audit::audit_multiply_plan(
                    &na,
                    &nb,
                    plan.tau,
                    plan.density_threshold,
                    &plan.schedule,
                    &plan.assignment,
                    &plan.pin_devices,
                ),
                "session submit",
            );
        }
        self.enqueue(JobPayload::Multiply(plan), priority)
    }

    /// Shared admission tail of [`SpammSession::submit_with`] and
    /// [`SpammSession::submit_expr_with`].
    fn enqueue(&self, payload: JobPayload, priority: Priority) -> Result<Ticket> {
        // Lock order is done → queue everywhere; `done` is held across
        // the push so the ticket lands in `outstanding` atomically with
        // its admission.
        let mut d = self.shared.done.lock().unwrap();
        if d.dead {
            return Err(Error::Session("session is shut down".into()));
        }
        let mut q = self.shared.queue.lock().unwrap();
        if q.closed {
            return Err(Error::Session("session is shut down".into()));
        }
        if q.heap.len() >= self.shared.cfg.queue_depth {
            return Err(Error::Session(format!(
                "admission queue full ({} queued, depth {})",
                q.heap.len(),
                self.shared.cfg.queue_depth
            )));
        }
        let ticket = self.next_ticket.fetch_add(1, AtomicOrdering::Relaxed);
        let seq = self.next_seq.fetch_add(1, AtomicOrdering::Relaxed);
        q.heap.push(QueuedJob {
            priority,
            seq,
            ticket,
            payload,
            submitted: Instant::now(),
        });
        d.outstanding.insert(ticket);
        drop(q);
        drop(d);
        self.shared.queue_cv.notify_all();
        Ok(Ticket(ticket))
    }

    /// `prepare` + `submit` in one call (plans deduplicate, so repeated
    /// identical requests share one warm plan).  Each call takes a plan
    /// reference the session keeps until `release_plan`; fire-and-forget
    /// callers simply let the session own the plan for its lifetime.
    pub fn submit_once(&self, a: OperandId, b: OperandId, approx: Approx) -> Result<Ticket> {
        let plan = self.prepare(a, b, approx)?;
        self.submit(plan)
    }

    // -- expression graphs ---------------------------------------------

    /// Prepare an expression graph over registered operands (bound
    /// positionally to the graph's input slots).  The plan is
    /// self-contained — padded operands ride along, so store churn can
    /// never fail an admitted job — and pins its operands in the store
    /// and the device residency pools until
    /// [`SpammSession::release_expr_plan`].  All host-side: τ resolution,
    /// norm-bound propagation, schedule pinning ([`ExprGraph::prepare`]).
    pub fn prepare_expr(&self, g: &ExprGraph, inputs: &[OperandId]) -> Result<ExprPlanId> {
        let resolved: Vec<(Arc<PaddedMatrix>, Fingerprint)> = {
            let mut store = self.shared.store.lock().unwrap();
            inputs
                .iter()
                .map(|id| store.get(*id))
                .collect::<Result<Vec<_>>>()?
        };
        let sources: Vec<ExprSource<'_>> = resolved
            .iter()
            .map(|(p, fp)| ExprSource::Padded(p.clone(), *fp))
            .collect();
        let plan = g.prepare_placed(
            &self.shared.caches,
            &self.shared.cfg,
            &self.shared.pools,
            &sources,
        )?;
        let fps = plan.input_fingerprints();
        {
            let mut store = self.shared.store.lock().unwrap();
            for id in inputs {
                store.pin(*id, true);
            }
        }
        // Pin the leaves only where the plan's placement maps put work —
        // not blindly in device 0's pool (nor in every pool).
        let pin_devices = plan.devices_used();
        for &d in &pin_devices {
            if let Some(pool) = self.shared.pools.get(d) {
                for fp in &fps {
                    pool.pin_operand(*fp);
                }
            }
        }
        let mut plans = self.shared.plans.lock().unwrap();
        let id = plans.next_id;
        plans.next_id += 1;
        plans.exprs.insert(
            id,
            Arc::new(ExprJob {
                id,
                plan,
                graph: g.clone(),
                operands: inputs.to_vec(),
                fps,
                pin_devices,
                cold_charged: std::sync::atomic::AtomicBool::new(false),
            }),
        );
        Ok(ExprPlanId(id))
    }

    /// τ of the plan's final spamm node (None for spamm-free graphs) and
    /// the root output shape.
    pub fn expr_plan_info(&self, id: ExprPlanId) -> Result<(Option<f32>, usize, usize)> {
        let plans = self.shared.plans.lock().unwrap();
        plans
            .exprs
            .get(&id.0)
            .map(|e| {
                let (r, c) = e.plan.output_shape();
                (e.plan.final_tau(), r, c)
            })
            .ok_or_else(|| Error::Session(format!("expr plan {} not prepared", id.0)))
    }

    /// Enqueue a prepared expression graph at [`Priority::Normal`].  A
    /// graph is one queue job; its [`Completion`] carries the root
    /// output, aggregate stats, and per-node reports (`Completion::plan`
    /// holds the expression plan's raw id).
    pub fn submit_expr(&self, plan: ExprPlanId) -> Result<ExprTicket> {
        self.submit_expr_with(plan, Priority::Normal)
    }

    /// [`SpammSession::submit_expr`] at an explicit priority class.
    pub fn submit_expr_with(&self, plan: ExprPlanId, priority: Priority) -> Result<ExprTicket> {
        self.flush_updates()?;
        let job = {
            let plans = self.shared.plans.lock().unwrap();
            plans.exprs.get(&plan.0).cloned().ok_or_else(|| {
                Error::Session(format!("expr plan {} not prepared", plan.0))
            })?
        };
        #[cfg(debug_assertions)]
        crate::audit::debug_assert_clean(
            &crate::audit::audit_expr_plan(&job.plan),
            "session submit_expr",
        );
        self.enqueue(JobPayload::Expr(job), priority)
    }

    /// Release a prepared expression plan, unpinning its operands in the
    /// store and the residency pools.  Unlike multiply plans, expression
    /// plans are not deduplicated, so each `prepare_expr` handle is
    /// released exactly once.  In-flight jobs hold the plan independently
    /// and always complete.
    pub fn release_expr_plan(&self, id: ExprPlanId) -> Result<()> {
        let job = {
            let mut plans = self.shared.plans.lock().unwrap();
            plans.exprs.remove(&id.0).ok_or_else(|| {
                Error::Session(format!("expr plan {} not prepared", id.0))
            })?
        };
        {
            let mut store = self.shared.store.lock().unwrap();
            for op in &job.operands {
                store.pin(*op, false);
            }
        }
        for &d in &job.pin_devices {
            if let Some(pool) = self.shared.pools.get(d) {
                for fp in &job.fps {
                    pool.unpin_operand(*fp);
                }
            }
        }
        Ok(())
    }

    /// Jobs admitted but not yet completed (queued + in flight).
    pub fn pending(&self) -> usize {
        let q = self.shared.queue.lock().unwrap();
        q.heap.len() + q.inflight
    }

    /// Completions ready to be received.
    pub fn completed(&self) -> usize {
        self.shared.done.lock().unwrap().map.len()
    }

    /// Non-blocking: any finished job, in no particular order (use
    /// [`SpammSession::wait`] to redeem a specific ticket).  Completions
    /// are retained until redeemed — a caller that submits and never
    /// receives should drain here, or its results accumulate.  Each
    /// completion is delivered exactly once, to whichever receiver takes
    /// it first: don't race this against a `wait` on the same ticket.
    pub fn try_recv(&self) -> Option<Result<Completion>> {
        let mut d = self.shared.done.lock().unwrap();
        let k = *d.map.keys().next()?;
        d.outstanding.remove(&k);
        d.map.remove(&k)
    }

    /// Block until `ticket`'s job completes and return it.  A ticket
    /// that was never issued or was already redeemed errors immediately.
    pub fn wait(&self, ticket: Ticket) -> Result<Completion> {
        let mut d = self.shared.done.lock().unwrap();
        loop {
            if let Some(out) = d.map.remove(&ticket.0) {
                d.outstanding.remove(&ticket.0);
                return out;
            }
            if !d.outstanding.contains(&ticket.0) {
                return Err(Error::Session(format!(
                    "ticket {} is unknown or was already received",
                    ticket.0
                )));
            }
            if d.dead {
                return Err(Error::Session(format!(
                    "session worker terminated before ticket {} completed",
                    ticket.0
                )));
            }
            let (guard, _) = self
                .shared
                .done_cv
                .wait_timeout(d, Duration::from_millis(50))
                .unwrap();
            d = guard;
        }
    }

    /// Block until every admitted job has completed; returns the
    /// completions in ticket order.  If any job errored, the first error
    /// (by ticket) is returned and the successful completions stay
    /// redeemable via `wait`/`try_recv`.
    ///
    /// Like `try_recv`, this consumes completions: each is delivered
    /// exactly once, to whichever receiver takes it first — don't mix
    /// `wait_all`/`try_recv` with a concurrent `wait` on a specific
    /// ticket unless some other coordination decides who redeems it.
    pub fn wait_all(&self) -> Result<Vec<Completion>> {
        loop {
            {
                let q = self.shared.queue.lock().unwrap();
                if q.heap.is_empty() && q.inflight == 0 {
                    break;
                }
            }
            let d = self.shared.done.lock().unwrap();
            if d.dead {
                return Err(Error::Session(
                    "session worker terminated with jobs pending".into(),
                ));
            }
            let _ = self
                .shared
                .done_cv
                .wait_timeout(d, Duration::from_millis(50))
                .unwrap();
        }
        let mut d = self.shared.done.lock().unwrap();
        let mut tickets: Vec<u64> = d.map.keys().copied().collect();
        tickets.sort_unstable();
        // Surface the first error without consuming the successes — they
        // stay in the done map for later wait/try_recv.
        let bad = tickets
            .iter()
            .find(|t| matches!(d.map.get(t), Some(Err(_))))
            .copied();
        if let Some(bad) = bad {
            d.outstanding.remove(&bad);
            match d.map.remove(&bad) {
                Some(Err(e)) => return Err(e),
                _ => unreachable!("error outcome vanished under the lock"),
            }
        }
        let mut out = Vec::with_capacity(tickets.len());
        for t in tickets {
            d.outstanding.remove(&t);
            match d.map.remove(&t) {
                Some(Ok(c)) => out.push(c),
                Some(Err(_)) => unreachable!("first error was removed above"),
                None => unreachable!("ticket key vanished under the lock"),
            }
        }
        Ok(out)
    }
}

impl Drop for SpammSession {
    /// Cancels still-queued jobs (their results could never be
    /// received), lets the in-flight job finish, and joins the worker.
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.closed = true;
        }
        self.shared.queue_cv.notify_all();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------
// Worker
// ---------------------------------------------------------------------

fn worker_loop(coord: Coordinator, shared: Arc<Shared>) {
    let _dead = DeadFlag(shared.clone());
    // One long-lived runtime whose compiled executables persist across
    // requests: single-device jobs execute directly on it; multi-device
    // jobs dispatch to the coordinator's persistent per-device worker
    // pool and use this one as the expression orchestrator.
    let resident = match Runtime::new(coord.bundle()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            log::warn!(
                "session worker: resident runtime unavailable ({e}); \
                 falling back to per-request runtimes (compile is re-paid per job)"
            );
            None
        }
    };
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                // Close wins over backlog: a dropped session abandons its
                // queued jobs (nobody can receive them) instead of
                // executing the whole heap inside Drop.
                if q.closed {
                    q.heap.clear();
                    break None;
                }
                if let Some(j) = q.heap.pop() {
                    q.inflight += 1;
                    break Some(j);
                }
                q = shared.queue_cv.wait(q).unwrap();
            }
        };
        let Some(job) = job else { break };
        let outcome = run_job(&coord, resident.as_ref(), &job);
        {
            let mut d = shared.done.lock().unwrap();
            d.map.insert(job.ticket, outcome);
        }
        {
            let mut q = shared.queue.lock().unwrap();
            q.inflight -= 1;
        }
        shared.done_cv.notify_all();
    }
}

fn run_job(
    coord: &Coordinator,
    resident: Option<&Runtime>,
    job: &QueuedJob,
) -> Result<Completion> {
    match &job.payload {
        JobPayload::Multiply(plan) => run_multiply_job(coord, resident, job, plan),
        JobPayload::Expr(e) => run_expr_job(coord, resident, job, e),
    }
}

fn run_multiply_job(
    coord: &Coordinator,
    resident: Option<&Runtime>,
    job: &QueuedJob,
    plan: &Plan,
) -> Result<Completion> {
    let t0 = Instant::now();
    let rep = coord.multiply_prepared_on(
        resident,
        &plan.pa,
        &plan.pb,
        plan.fa,
        plan.fb,
        &plan.schedule,
        Some(&plan.assignment),
    )?;
    let mut compute = t0.elapsed().as_secs_f64();
    let mut stats = report_to_stats(&rep);
    // The plan's one-time analysis cost (normmaps, τ tuning, schedule
    // compaction) is charged to the cold first job; warm jobs carry
    // zeroed front phases — the reuse the session exists to expose.
    if !plan.cold_charged.swap(true, AtomicOrdering::Relaxed) {
        compute += plan.prepare_secs;
        stats.norm_secs += plan.front.norm_secs;
        stats.schedule_secs += plan.front.schedule_secs;
        stats.norm_cache_hits += plan.front.norm_cache_hits;
        stats.norm_cache_misses += plan.front.norm_cache_misses;
        stats.schedule_cache_hits += plan.front.schedule_cache_hits;
        stats.schedule_cache_misses += plan.front.schedule_cache_misses;
        stats.norm_tiles_patched += plan.front.norm_tiles_patched;
        stats.schedules_repaired += plan.front.schedules_repaired;
        stats.repair_products_added += plan.front.repair_products_added;
        stats.repair_products_removed += plan.front.repair_products_removed;
        stats.repair_products_retagged += plan.front.repair_products_retagged;
        stats.store_normmap_hits += plan.front.store_normmap_hits;
        stats.store_schedule_hits += plan.front.store_schedule_hits;
        stats.store_tau_hits += plan.front.store_tau_hits;
        stats.store_bundle_hits += plan.front.store_bundle_hits;
        stats.tau_tuned += plan.front.tau_tuned;
    }
    stats.total_secs = compute;
    Ok(Completion {
        ticket: Ticket(job.ticket),
        plan: PlanId(plan.id),
        priority: job.priority,
        c: rep.c,
        tau: plan.tau,
        valid_ratio: rep.valid_ratio,
        latency_secs: job.submitted.elapsed().as_secs_f64(),
        compute_secs: compute,
        device_busy: rep.device_busy,
        stats,
        nodes: Vec::new(),
    })
}

/// Execute one expression-graph job: the whole graph runs as a single
/// queue slot with device-resident intermediates; per-node
/// [`MultiplyStats`] ride back on the completion.
fn run_expr_job(
    coord: &Coordinator,
    resident: Option<&Runtime>,
    job: &QueuedJob,
    e: &ExprJob,
) -> Result<Completion> {
    let t0 = Instant::now();
    let rep = coord.execute_expr_on(resident, &e.plan)?;
    let mut compute = t0.elapsed().as_secs_f64();
    let mut stats = rep.stats.clone();
    // Like multiply plans, the one-time prepare cost (leaf normmaps, τ
    // resolution, bound propagation) is charged to the cold first job.
    if !e.cold_charged.swap(true, AtomicOrdering::Relaxed) {
        compute += e.plan.prepare_secs();
        let front = e.plan.front();
        stats.norm_secs += front.norm_secs;
        stats.schedule_secs += front.schedule_secs;
        stats.norm_cache_hits += front.norm_cache_hits;
        stats.norm_cache_misses += front.norm_cache_misses;
        stats.schedule_cache_hits += front.schedule_cache_hits;
        stats.schedule_cache_misses += front.schedule_cache_misses;
        stats.norm_tiles_patched += front.norm_tiles_patched;
        stats.schedules_repaired += front.schedules_repaired;
        stats.repair_products_added += front.repair_products_added;
        stats.repair_products_removed += front.repair_products_removed;
        stats.repair_products_retagged += front.repair_products_retagged;
        stats.store_normmap_hits += front.store_normmap_hits;
        stats.store_schedule_hits += front.store_schedule_hits;
        stats.store_tau_hits += front.store_tau_hits;
        stats.store_bundle_hits += front.store_bundle_hits;
        stats.tau_tuned += front.tau_tuned;
    }
    stats.total_secs = compute;
    let valid_ratio = rep.stats.valid_ratio;
    Ok(Completion {
        ticket: Ticket(job.ticket),
        plan: PlanId(e.id),
        priority: job.priority,
        // The completion crosses back to the caller as a host matrix —
        // this download is the job's one result transfer.
        c: rep.to_matrix(),
        tau: e.plan.final_tau().unwrap_or(0.0),
        valid_ratio,
        latency_secs: job.submitted.elapsed().as_secs_f64(),
        compute_secs: compute,
        // Per-device time inside the spamm pipelines — comparable to
        // the multiply path's per-device busy clocks (the expr wall also
        // contains host-side scheduling/gather, which is not "busy").
        device_busy: rep.device_busy,
        stats,
        nodes: rep.nodes,
    })
}

// ---------------------------------------------------------------------
// Session-aware workload generator
// ---------------------------------------------------------------------

/// One request of a session trace: indices into the trace's operand
/// pool, plus approximation and priority class.
#[derive(Clone, Copy, Debug)]
pub struct TraceRequest {
    pub a: usize,
    pub b: usize,
    pub approx: Approx,
    pub priority: Priority,
}

/// Session-aware workload: a pool of reusable operands plus a request
/// stream referencing them.
pub struct SessionTrace {
    pub operands: Vec<Matrix>,
    pub requests: Vec<TraceRequest>,
}

/// Generate a session workload with Zipf-distributed operand popularity
/// (exponent `zipf_s`; higher = a few hot matrices dominate, the pattern
/// behind model weights and Hamiltonian chains) and mixed priorities
/// (~20% high, ~60% normal, ~20% low).  Requests on the same operand
/// pair share the same approximation target, so they share one prepared
/// plan.  Deterministic in `seed`.
pub fn synthetic_session_trace(
    requests: usize,
    operands: usize,
    n: usize,
    zipf_s: f64,
    seed: u64,
) -> SessionTrace {
    let operands = operands.max(1);
    let mut rng = Rng::new(seed);
    let pool: Vec<Matrix> = (0..operands)
        .map(|i| {
            let s = seed.wrapping_add(i as u64 * 131).wrapping_add(1);
            if i % 2 == 0 {
                Matrix::decay_algebraic(n, 0.1, 0.1, s)
            } else {
                Matrix::decay_exponential(n, 1.0, 0.9, s)
            }
        })
        .collect();
    let weights: Vec<f64> = (0..operands)
        .map(|k| 1.0 / ((k + 1) as f64).powf(zipf_s))
        .collect();
    let total: f64 = weights.iter().sum();
    let draw = |rng: &mut Rng| -> usize {
        let u = rng.next_f32() as f64 * total;
        let mut acc = 0.0;
        for (k, w) in weights.iter().enumerate() {
            acc += w;
            if u < acc {
                return k;
            }
        }
        operands - 1
    };
    let reqs: Vec<TraceRequest> = (0..requests)
        .map(|_| {
            let a = draw(&mut rng);
            let b = draw(&mut rng);
            // Per-pair approximation target: repeated (a, b) pairs share
            // a plan, which is the reuse the session exists to exploit.
            let pair = ((a as u64) << 32) | b as u64;
            let mut pr = Rng::new(seed ^ pair.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let approx = if pr.next_f32() < 0.5 {
                Approx::ValidRatio(pr.range_f32(0.05, 0.3) as f64)
            } else {
                Approx::Tau(pr.range_f32(1e-6, 1e-2))
            };
            let x = rng.next_f32();
            let priority = if x < 0.2 {
                Priority::High
            } else if x < 0.8 {
                Priority::Normal
            } else {
                Priority::Low
            };
            TraceRequest {
                a,
                b,
                approx,
                priority,
            }
        })
        .collect();
    SessionTrace {
        operands: pool,
        requests: reqs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_dedups_and_refcounts() {
        let mut store = OperandStore::new(0);
        let m = Matrix::randn(32, 32, 1);
        let a = store.put(&m, 32);
        // Same seed → bit-identical content, independently generated.
        let b = store.put(&Matrix::randn(32, 32, 1), 32);
        assert_eq!(a, b, "identical content must dedup to one entry");
        let s = store.stats();
        assert_eq!(s.puts, 2);
        assert_eq!(s.dedup_hits, 1);
        assert_eq!(s.resident_operands, 1);
        store.release(a).unwrap();
        // Still one live ref: the entry must survive even at budget 0...
        assert!(store.get(a).is_ok());
        store.release(a).unwrap();
        assert!(store.release(a).is_err(), "double release");
    }

    #[test]
    fn store_evicts_released_lru_under_budget() {
        let m1 = Matrix::randn(32, 32, 1);
        let m2 = Matrix::randn(32, 32, 2);
        let m3 = Matrix::randn(32, 32, 3);
        let bytes = 32 * 32 * 4;
        let mut store = OperandStore::new(2 * bytes);
        let a = store.put(&m1, 32);
        let b = store.put(&m2, 32);
        store.release(a).unwrap();
        store.release(b).unwrap();
        // Touch a so b is LRU, then insert m3: b must go.
        store.get(a).unwrap();
        let _c = store.put(&m3, 32);
        assert!(store.get(a).is_ok());
        assert!(store.get(b).is_err(), "LRU released entry evicted");
        assert_eq!(store.stats().evictions, 1);
    }

    #[test]
    fn store_never_evicts_referenced_or_pinned() {
        let bytes = 32 * 32 * 4;
        let mut store = OperandStore::new(bytes);
        let a = store.put(&Matrix::randn(32, 32, 1), 32);
        // Referenced: overflows instead of evicting.
        let b = store.put(&Matrix::randn(32, 32, 2), 32);
        assert!(store.get(a).is_ok());
        assert!(store.get(b).is_ok());
        // Released but pinned by a plan: still never evicted.
        store.pin(a, true);
        store.release(a).unwrap();
        let _d = store.put(&Matrix::randn(32, 32, 4), 32);
        assert!(store.get(a).is_ok(), "pinned operand evicted");
    }

    #[test]
    fn queue_orders_by_priority_then_fifo() {
        let zeros = Arc::new(PaddedMatrix::new(&Matrix::zeros(1, 1), 1));
        let mk = |priority, seq| QueuedJob {
            priority,
            seq,
            ticket: seq,
            payload: JobPayload::Multiply(Arc::new(Plan {
                id: 0,
                a: OperandId(0),
                b: OperandId(0),
                pa: zeros.clone(),
                pb: zeros.clone(),
                fa: Fingerprint(0, 0),
                fb: Fingerprint(0, 0),
                tau: 0.0,
                density_threshold: 0.0,
                schedule: Arc::new(Schedule {
                    tile_rows: 0,
                    tile_cols: 0,
                    tile_k: 0,
                    valid_k: Vec::new(),
                    strategies: Vec::new(),
                }),
                rows: 0,
                cols: 0,
                dedup: (OperandId(0), OperandId(0), ApproxKey::Tau(0)),
                prepare_secs: 0.0,
                front: MultiplyStats::default(),
                pin_devices: Vec::new(),
                assignment: Assignment {
                    devices: 1,
                    owner: Vec::new(),
                },
                cold_charged: std::sync::atomic::AtomicBool::new(false),
            })),
            submitted: Instant::now(),
        };
        let mut heap = BinaryHeap::new();
        heap.push(mk(Priority::Low, 0));
        heap.push(mk(Priority::High, 1));
        heap.push(mk(Priority::Normal, 2));
        heap.push(mk(Priority::High, 3));
        let order: Vec<u64> = std::iter::from_fn(|| heap.pop().map(|j| j.seq)).collect();
        assert_eq!(order, vec![1, 3, 2, 0]);
    }

    #[test]
    fn zipf_trace_is_deterministic_and_skewed() {
        let t1 = synthetic_session_trace(64, 8, 32, 1.2, 9);
        let t2 = synthetic_session_trace(64, 8, 32, 1.2, 9);
        assert_eq!(t1.operands.len(), 8);
        assert_eq!(t1.requests.len(), 64);
        for (r1, r2) in t1.requests.iter().zip(&t2.requests) {
            assert_eq!((r1.a, r1.b), (r2.a, r2.b));
        }
        // Rank 0 must be the hottest operand by a clear margin.
        let mut counts = vec![0usize; 8];
        for r in &t1.requests {
            counts[r.a] += 1;
            counts[r.b] += 1;
        }
        assert!(counts[0] > counts[7], "zipf skew: {counts:?}");
        // Same operand pair → same approximation (one shared plan).
        let mut seen: HashMap<(usize, usize), ApproxKey> = HashMap::new();
        for r in &t1.requests {
            let k = approx_key(r.approx);
            if let Some(&prev) = seen.get(&(r.a, r.b)) {
                assert_eq!(prev, k);
            } else {
                seen.insert((r.a, r.b), k);
            }
        }
        // Mixed priorities appear.
        assert!(t1.requests.iter().any(|r| r.priority == Priority::High));
        assert!(t1.requests.iter().any(|r| r.priority == Priority::Normal));
    }

    #[test]
    fn priority_ordering_is_low_normal_high() {
        assert!(Priority::Low < Priority::Normal);
        assert!(Priority::Normal < Priority::High);
        assert_eq!(Priority::parse("high").unwrap(), Priority::High);
        assert!(Priority::parse("urgent").is_err());
    }
}
