//! SUMMA-style 2-D grid coordinator — the distributed extension the paper
//! defers to future work (§3.4: "our multiple GPU optimizations can be
//! further integrated with distributed matrix multiplication optimizations
//! such as CANNON and SUMMA").
//!
//! Devices form a pr×pc grid; each owns the output tiles of its grid cell.
//! The computation proceeds in K stages: at stage k every row of the grid
//! (logically) receives the A tile-column k and every column receives the
//! B tile-row k — so per-device communication volume is O(N²·(1/pr+1/pc))
//! instead of Algorithm 4's O(N²) full-B broadcast per device.  On this
//! single-node simulator the "broadcast" is a shared read; what we model
//! and report is the per-device *communication volume* each scheme would
//! move, alongside the same compute pipeline as the row coordinator.

use crate::config::SpammConfig;
use crate::error::{Error, Result};
use crate::matrix::Matrix;
use crate::runtime::ArtifactBundle;
use crate::spamm::schedule::Schedule;

/// Modeled communication cost of a partitioning scheme (bytes moved to
/// each device before compute, f32 elements × 4).
#[derive(Clone, Debug, PartialEq)]
pub struct CommModel {
    /// Per-device bytes for the A operand.
    pub a_bytes_per_device: usize,
    /// Per-device bytes for the B operand.
    pub b_bytes_per_device: usize,
    /// Total bytes moved across all devices.
    pub total_bytes: usize,
}

/// Choose a near-square pr×pc grid for `devices`.
pub fn grid_shape(devices: usize) -> (usize, usize) {
    let mut pr = (devices as f64).sqrt() as usize;
    while pr > 1 && devices % pr != 0 {
        pr -= 1;
    }
    (pr.max(1), devices / pr.max(1))
}

/// 2-D (SUMMA-style) assignment of output tiles to a device grid: device
/// (r, c) owns output tiles in its contiguous block of the tile grid.
pub fn grid_assignment(sched: &Schedule, pr: usize, pc: usize) -> Vec<Vec<(usize, usize)>> {
    let mut owned = vec![Vec::new(); pr * pc];
    for i in 0..sched.tile_rows {
        let r = (i * pr / sched.tile_rows.max(1)).min(pr - 1);
        for j in 0..sched.tile_cols {
            let c = (j * pc / sched.tile_cols.max(1)).min(pc - 1);
            owned[r * pc + c].push((i, j));
        }
    }
    owned
}

/// Communication model for the Algorithm-4 row scheme: every device
/// receives all of B plus its row slice of A.
pub fn comm_model_rows(n: usize, devices: usize) -> CommModel {
    let a_per = n * n / devices * 4;
    let b_per = n * n * 4;
    CommModel {
        a_bytes_per_device: a_per,
        b_bytes_per_device: b_per,
        total_bytes: devices * (a_per + b_per),
    }
}

/// Communication model for the SUMMA grid: device (r, c) receives the A
/// tile-rows of its output rows (N²/pr) and the B tile-cols of its output
/// cols (N²/pc).
pub fn comm_model_grid(n: usize, pr: usize, pc: usize) -> CommModel {
    let a_per = n * n / pr * 4;
    let b_per = n * n / pc * 4;
    CommModel {
        a_bytes_per_device: a_per,
        b_bytes_per_device: b_per,
        total_bytes: pr * pc * (a_per + b_per),
    }
}

/// SUMMA-style multiply: same compute path as the row coordinator but with
/// the 2-D output partition; returns the report plus the comm models of
/// both schemes for comparison.
pub struct SummaCoordinator {
    inner: super::pipeline::Coordinator,
    pr: usize,
    pc: usize,
}

impl SummaCoordinator {
    pub fn new(bundle: &ArtifactBundle, mut cfg: SpammConfig) -> Result<SummaCoordinator> {
        let (pr, pc) = grid_shape(cfg.devices);
        if pr * pc != cfg.devices {
            return Err(Error::Config(format!(
                "devices {} not factorable into a grid",
                cfg.devices
            )));
        }
        // The 2-D partition is expressed through the balance policy: a
        // strided assignment with stride pr interleaves tile rows across
        // grid rows; pipeline batches model the K stages.
        cfg.balance = crate::config::Balance::Strided(pr.max(1));
        let inner = super::pipeline::Coordinator::new(bundle, cfg)?;
        Ok(SummaCoordinator { inner, pr, pc })
    }

    pub fn grid(&self) -> (usize, usize) {
        (self.pr, self.pc)
    }

    pub fn multiply(
        &self,
        a: &Matrix,
        b: &Matrix,
        tau: f32,
    ) -> Result<(super::metrics::MultiDeviceReport, CommModel, CommModel)> {
        let rep = self.inner.multiply(a, b, tau)?;
        let n = a.rows().max(b.cols());
        let devices = self.pr * self.pc;
        Ok((
            rep,
            comm_model_grid(n, self.pr, self.pc),
            comm_model_rows(n, devices),
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::tiling::PaddedMatrix;
    use crate::spamm::normmap::normmap;

    #[test]
    fn grid_shapes_are_factorizations() {
        for d in 1..=16 {
            let (pr, pc) = grid_shape(d);
            assert_eq!(pr * pc, d, "devices {d}");
            assert!(pr <= pc);
        }
        assert_eq!(grid_shape(8), (2, 4));
        assert_eq!(grid_shape(9), (3, 3));
    }

    #[test]
    fn grid_assignment_partitions() {
        let a = Matrix::decay_algebraic(256, 0.1, 0.1, 1);
        let nm = normmap(&PaddedMatrix::new(&a, 32));
        let sched = Schedule::build(&nm, &nm, 0.0).unwrap();
        for (pr, pc) in [(1, 1), (2, 2), (2, 4)] {
            let owned = grid_assignment(&sched, pr, pc);
            assert_eq!(owned.len(), pr * pc);
            let total: usize = owned.iter().map(|v| v.len()).sum();
            assert_eq!(total, sched.tile_rows * sched.tile_cols);
            // disjointness
            let mut seen = std::collections::BTreeSet::new();
            for v in &owned {
                for t in v {
                    assert!(seen.insert(*t));
                }
            }
        }
    }

    #[test]
    fn summa_comm_beats_rows_at_scale() {
        // The point of the 2-D scheme: per-device B traffic shrinks by pc.
        for devices in [4usize, 8, 16] {
            let (pr, pc) = grid_shape(devices);
            let rows = comm_model_rows(1024, devices);
            let grid = comm_model_grid(1024, pr, pc);
            assert!(
                grid.total_bytes < rows.total_bytes,
                "devices {devices}: grid {} rows {}",
                grid.total_bytes,
                rows.total_bytes
            );
            assert!(grid.b_bytes_per_device <= rows.b_bytes_per_device);
        }
    }

    #[test]
    fn single_device_models_agree() {
        let rows = comm_model_rows(512, 1);
        let grid = comm_model_grid(512, 1, 1);
        assert_eq!(rows.total_bytes, grid.total_bytes);
    }
}
