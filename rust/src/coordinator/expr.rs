//! Expression graphs: chained SpAMM plans with device-resident
//! intermediates and norm propagation.
//!
//! The paper's headline applications are *iterated* products — matrix
//! powers for the ergo decay matrices (§4.3.1) and density-matrix
//! purification — yet a `multiply`-per-step driver scatters every
//! intermediate back to host, re-fingerprints it, recomputes its normmap,
//! and re-uploads the very tiles the previous step just produced on
//! device.  An [`ExprGraph`] turns the whole iteration into one prepared
//! plan:
//!
//! * **Device-resident intermediates** — a `spamm` node's output tiles
//!   scatter straight into the device [`ResidencyPool`] under a *derived*
//!   content fingerprint ([`Fingerprint::derive`]: hash of the input
//!   fingerprints + op + τ), and the consuming node's gather resolves
//!   them as pool hits — zero transfer bytes.  An intermediate's tiles
//!   are freed the moment its last consumer retires.
//! * **Norm propagation** — schedules for step *k+1* are built without
//!   pulling step *k* to host.  At prepare time, norm *upper bounds*
//!   flow through the graph (‖C_ij‖_F ≤ Σ_k ‖A_ik‖·‖B_kj‖ over the
//!   compacted schedule — [`Schedule::bound_normmap`]); they resolve τ
//!   (the §3.5.2 tuner for valid-ratio targets) and pin schedules for
//!   every node whose bound is already exact (leaf-fed nodes, τ = 0
//!   nodes, where pruning cannot differ).  Only when a τ > 0 node
//!   consumes a computed intermediate are *exact* norms needed — and they
//!   are refreshed lazily from the device-resident output tiles at
//!   scatter time (the device-side get-norm), bitwise identical to the
//!   host normmap, with no host round-trip and no re-hash.
//! * **Device-side combine** — [`ExprGraph::axpby`] (α·X + β·Y, e.g.
//!   McWeeny's 3P² − 2P³) runs as a batched tile kernel (the `axpby`
//!   artifact; hostsim + real bundles alike), so purification never
//!   leaves the pool.  `scale` and `add_diag` are the same idea for
//!   α·X and X + σI.
//!
//! * **Multi-device fan-out** — every compute node carries a tile→device
//!   placement map resolved at prepare (the balance policy;
//!   `residency-aware` scores candidate owners by the bytes already
//!   resident in each device's pool).  Execution drives all device
//!   workers per node: each device scatters its owned output tiles into
//!   its *own* pool, and a consumer device staging a tile produced
//!   elsewhere takes a host bounce, counted as
//!   [`MultiplyStats::cross_device_bytes`].
//!
//! Because the executor ([`execute_batches`]) and its product ordering
//! are shared with the one-`multiply`-per-step loop path — and tile
//! ownership is exclusive with per-tile k-order accumulation — an
//! expression run is **bitwise identical** to the loop at the same τ,
//! at any device count — the integration suite asserts this for
//! `spamm_power` and `mcweeny_purify`.

use std::sync::{Arc, Barrier};
use std::time::Instant;

use crate::config::{Balance, SpammConfig};
use crate::coordinator::partition::{batches_of, DeviceWork, PartitionCtx};
use crate::coordinator::pipeline::{run_device, DeviceResult};
use crate::coordinator::service::Approx;
use crate::coordinator::Coordinator;
use crate::error::{Error, Result};
use crate::matrix::tiling::PaddedMatrix;
use crate::matrix::Matrix;
use crate::runtime::residency::{ResidencyPool, ResidentOperand, TileKey};
use crate::runtime::Runtime;
use crate::spamm::balance::{rowblock_owner, Assignment};
use crate::spamm::cache::{fingerprint, ExecCaches, Fingerprint};
use crate::spamm::executor::{
    execute_batches, MultiplyStats, Operand, TileAccumulator, TileSource,
};
use crate::spamm::normmap::{normmap_with_density, resolve_density_threshold, NormMap};
use crate::spamm::schedule::Schedule;
use crate::spamm::tuner::{self, TuneParams};

/// Handle of a node inside one [`ExprGraph`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub(crate) usize);

impl NodeId {
    pub fn raw(self) -> usize {
        self.0
    }
}

/// One graph node (inputs refer to earlier nodes, so the vector order is
/// already topological).
#[derive(Clone, Copy, Debug)]
pub(crate) enum NodeKind {
    /// Graph input `slot` (bound at prepare time).
    Operand { slot: usize },
    /// SpAMM product A·B at the node's approximation level.
    Spamm { a: NodeId, b: NodeId, approx: Approx },
    /// Element-wise α·X + β·Y (same shape).
    Axpby {
        alpha: f32,
        x: NodeId,
        beta: f32,
        y: NodeId,
    },
    /// Element-wise s·X.
    Scale { s: f32, x: NodeId },
    /// X + σ·I (square X).
    AddDiag { shift: f32, x: NodeId },
    /// Scalar ‖X − Y‖_F (convergence probes, e.g. idempotency residual).
    DiffNorm { x: NodeId, y: NodeId },
}

/// Lazy expression DAG builder.
///
/// ```no_run
/// use cuspamm::coordinator::{Approx, ExprGraph};
/// let mut g = ExprGraph::new();
/// let a = g.operand();                               // input slot 0
/// let a2 = g.spamm(a, a, Approx::Tau(1e-4));         // A²
/// let a3 = g.spamm(a2, a, Approx::Tau(1e-4));        // A³ — A² never
/// g.output(a3);                                      //     leaves device
/// ```
#[derive(Clone, Debug, Default)]
pub struct ExprGraph {
    nodes: Vec<NodeKind>,
    root: Option<NodeId>,
    keeps: Vec<NodeId>,
    n_slots: usize,
}

impl ExprGraph {
    pub fn new() -> ExprGraph {
        ExprGraph::default()
    }

    fn push(&mut self, kind: NodeKind) -> NodeId {
        self.nodes.push(kind);
        NodeId(self.nodes.len() - 1)
    }

    /// A node must exist and carry a matrix (DiffNorm is a scalar).
    fn check_matrix_input(&self, id: NodeId, what: &str) {
        assert!(id.0 < self.nodes.len(), "{what}: unknown node {:?}", id);
        assert!(
            !matches!(self.nodes[id.0], NodeKind::DiffNorm { .. }),
            "{what}: scalar node {:?} used as a matrix",
            id
        );
    }

    /// Declare the next graph input; inputs are bound positionally at
    /// [`ExprGraph::prepare`].
    pub fn operand(&mut self) -> NodeId {
        let slot = self.n_slots;
        self.n_slots += 1;
        self.push(NodeKind::Operand { slot })
    }

    /// SpAMM product of two earlier nodes at `approx` (τ is resolved once
    /// at prepare; valid-ratio targets run the §3.5.2 tuner over the
    /// propagated norm bounds).
    pub fn spamm(&mut self, a: NodeId, b: NodeId, approx: Approx) -> NodeId {
        self.check_matrix_input(a, "spamm");
        self.check_matrix_input(b, "spamm");
        self.push(NodeKind::Spamm { a, b, approx })
    }

    /// Element-wise α·X + β·Y (device-side tiled kernel).
    pub fn axpby(&mut self, alpha: f32, x: NodeId, beta: f32, y: NodeId) -> NodeId {
        self.check_matrix_input(x, "axpby");
        self.check_matrix_input(y, "axpby");
        self.push(NodeKind::Axpby { alpha, x, beta, y })
    }

    /// Element-wise s·X.
    pub fn scale(&mut self, s: f32, x: NodeId) -> NodeId {
        self.check_matrix_input(x, "scale");
        self.push(NodeKind::Scale { s, x })
    }

    /// X + σ·I (X must be square).
    pub fn add_diag(&mut self, shift: f32, x: NodeId) -> NodeId {
        self.check_matrix_input(x, "add_diag");
        self.push(NodeKind::AddDiag { shift, x })
    }

    /// Scalar ‖X − Y‖_F, summed in row-major order — bitwise identical
    /// to `Matrix::error_fnorm` of the downloaded values, computed from
    /// the resident tiles without a host round-trip.
    pub fn diff_fnorm(&mut self, x: NodeId, y: NodeId) -> NodeId {
        self.check_matrix_input(x, "diff_fnorm");
        self.check_matrix_input(y, "diff_fnorm");
        self.push(NodeKind::DiffNorm { x, y })
    }

    /// Designate the graph's result (must be a computed matrix node).
    pub fn output(&mut self, n: NodeId) {
        self.check_matrix_input(n, "output");
        assert!(
            !matches!(self.nodes[n.0], NodeKind::Operand { .. }),
            "output: the graph result must be a computed node"
        );
        self.root = Some(n);
    }

    /// Keep an interior node's value device-resident past execution (it
    /// is returned alongside the root instead of being freed at
    /// retirement).
    pub fn keep(&mut self, n: NodeId) {
        self.check_matrix_input(n, "keep");
        if !self.keeps.contains(&n) {
            self.keeps.push(n);
        }
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn input_count(&self) -> usize {
        self.n_slots
    }

    /// Prepare this graph over concrete inputs: resolve shapes and τ,
    /// propagate norm bounds, derive intermediate fingerprints, and pin
    /// schedules wherever the bound is already exact.  Host-side only —
    /// no device work, no transfer.  `caches`/`cfg` come from the
    /// executing front-end ([`Coordinator::prepare_expr`] /
    /// `SpammSession::prepare_expr` pass their own).  Node placement
    /// uses cold residency views; pass the executing pools through
    /// [`ExprGraph::prepare_placed`] for residency-aware placement.
    pub fn prepare(
        &self,
        caches: &ExecCaches,
        cfg: &SpammConfig,
        inputs: &[ExprSource<'_>],
    ) -> Result<ExprPlan> {
        self.prepare_placed(caches, cfg, &[], inputs)
    }

    /// [`ExprGraph::prepare`] with the executing front-end's residency
    /// pools: every compute node's output tiles are assigned to devices
    /// at prepare ([`crate::config::Balance::ResidencyAware`] consults
    /// the pools, the baseline policies ignore them), so execution fans
    /// each node out across all device workers and the session can pin
    /// operands only where they will actually be used.
    pub fn prepare_placed(
        &self,
        caches: &ExecCaches,
        cfg: &SpammConfig,
        pools: &[Arc<ResidencyPool>],
        inputs: &[ExprSource<'_>],
    ) -> Result<ExprPlan> {
        let t_prepare = Instant::now();
        let root = self.root.ok_or_else(|| {
            Error::Coordinator("expression graph has no output node".into())
        })?;
        if inputs.len() != self.n_slots {
            return Err(Error::Coordinator(format!(
                "expression graph has {} input slots, got {} bindings",
                self.n_slots,
                inputs.len()
            )));
        }
        let lonum = cfg.lonum;
        let mut front = MultiplyStats::default();

        // Bind inputs: padded form, content fingerprint, exact normmap.
        let t = Instant::now();
        let mut bound_inputs: Vec<PlannedInput> = Vec::with_capacity(inputs.len());
        let mut input_norms: Vec<Arc<NormMap>> = Vec::with_capacity(inputs.len());
        for src in inputs {
            match src {
                ExprSource::Host(m) => {
                    if m.rows() == 0 || m.cols() == 0 {
                        return Err(Error::Shape("expr input: empty operand".into()));
                    }
                    let padded = PaddedMatrix::new(m, lonum);
                    let (nm, fp) = caches.normmap_via(cfg.cache_enabled, &padded, &mut front, || {
                        Ok(normmap_with_density(&padded))
                    })?;
                    let fp = fp.unwrap_or_else(|| fingerprint(&padded));
                    input_norms.push(nm);
                    bound_inputs.push(PlannedInput::Host {
                        padded: Arc::new(padded),
                        fp,
                    });
                }
                ExprSource::Padded(padded, fp) => {
                    let nm = if cfg.cache_enabled {
                        caches.normmap_keyed(*fp, &mut front, || Ok(normmap_with_density(padded)))?
                    } else {
                        Arc::new(normmap_with_density(padded))
                    };
                    input_norms.push(nm);
                    bound_inputs.push(PlannedInput::Host {
                        padded: padded.clone(),
                        fp: *fp,
                    });
                }
                ExprSource::Resident(v) => {
                    // A previous execution's device-resident result: its
                    // exact normmap was computed at scatter time — no
                    // host norm work at all.
                    front.norms_refreshed += 1;
                    // Scatter-time tiles carry a density census alongside
                    // the norms, so resident inputs stay eligible for the
                    // sparse tile path (they used to be forced dense).
                    input_norms.push(Arc::new(v.inner.norm_density_map()));
                    bound_inputs.push(PlannedInput::Resident(v.clone()));
                }
            }
        }
        front.norm_secs = t.elapsed().as_secs_f64();

        // Consumer counts (root/keeps count as one extra use so their
        // values survive execution).
        let mut uses = vec![0usize; self.nodes.len()];
        for kind in &self.nodes {
            match *kind {
                NodeKind::Operand { .. } => {}
                NodeKind::Spamm { a, b, .. } => {
                    uses[a.0] += 1;
                    uses[b.0] += 1;
                }
                NodeKind::Axpby { x, y, .. } | NodeKind::DiffNorm { x, y } => {
                    uses[x.0] += 1;
                    uses[y.0] += 1;
                }
                NodeKind::Scale { x, .. } | NodeKind::AddDiag { x, .. } => uses[x.0] += 1,
            }
        }
        uses[root.0] += 1;
        for k in &self.keeps {
            uses[k.0] += 1;
        }

        // Walk the (already topological) node list propagating shapes,
        // fingerprints, and norm bounds.
        let t_sched = Instant::now();
        let mut planned: Vec<PlannedNode> = Vec::with_capacity(self.nodes.len());
        for (idx, kind) in self.nodes.iter().enumerate() {
            let node = match *kind {
                NodeKind::Operand { slot } => {
                    let (fp, rows, cols, tr, tc) = match &bound_inputs[slot] {
                        PlannedInput::Host { padded, fp } => (
                            *fp,
                            padded.logical_rows,
                            padded.logical_cols,
                            padded.tile_rows(),
                            padded.tile_cols(),
                        ),
                        PlannedInput::Resident(v) => {
                            let r = v.inner.as_ref();
                            if r.lonum() != lonum {
                                return Err(Error::Shape(format!(
                                    "expr input: resident value has lonum {}, config wants {lonum}",
                                    r.lonum()
                                )));
                            }
                            (
                                r.fingerprint(),
                                r.logical_rows(),
                                r.logical_cols(),
                                r.tile_rows(),
                                r.tile_cols(),
                            )
                        }
                    };
                    PlannedNode {
                        kind: *kind,
                        fp,
                        rows,
                        cols,
                        tile_rows: tr,
                        tile_cols: tc,
                        tau: 0.0,
                        dt: 0.0,
                        bound: Some(input_norms[slot].clone()),
                        sched: None,
                        owner: None,
                        uses: uses[idx],
                    }
                }
                NodeKind::Spamm { a, b, approx } => {
                    approx.validate()?;
                    let (pa, pb) = (&planned[a.0], &planned[b.0]);
                    if pa.cols != pb.rows {
                        return Err(Error::Shape(format!(
                            "expr spamm: inner dimensions disagree: A is {}x{}, B is {}x{}",
                            pa.rows, pa.cols, pb.rows, pb.cols
                        )));
                    }
                    let na = pa.bound.as_ref().expect("matrix node").clone();
                    let nb = pb.bound.as_ref().expect("matrix node").clone();
                    let tau = match approx {
                        Approx::Tau(t) => t,
                        // Valid-ratio targets tune over the propagated
                        // bounds — exact for leaf-fed nodes, conservative
                        // (τ errs low, keeping more work) downstream.
                        Approx::ValidRatio(r) => {
                            tuner::tune_tau(&na.norms, &nb.norms, r, TuneParams::default())?.tau
                        }
                    };
                    let fp = Fingerprint::derive("spamm", &[pa.fp, pb.fp], &[tau]);
                    // The bound is exact — hence the schedule final — when
                    // both inputs carry exact norms (operand leaves) or
                    // τ = 0 prunes nothing.  Downstream τ > 0 schedules
                    // are provisional: execution refreshes exact norms
                    // from the resident tiles and rebuilds (cache-keyed
                    // on the derived fingerprints, so re-submits hit).
                    let inputs_exact = matches!(
                        (&planned[a.0].kind, &planned[b.0].kind),
                        (NodeKind::Operand { .. }, NodeKind::Operand { .. })
                    );
                    let pinned = inputs_exact || tau == 0.0;
                    let dt = resolve_density_threshold(cfg, &na, &nb);
                    let sched = if pinned && cfg.cache_enabled {
                        caches.schedule_via(
                            Some(pa.fp),
                            Some(pb.fp),
                            tau,
                            dt,
                            &na,
                            &nb,
                            &mut front,
                        )?
                    } else {
                        Arc::new(Schedule::build_adaptive(&na, &nb, tau, dt)?)
                    };
                    // Propagated bounds carry no density census — dense
                    // downstream, so provisional nodes never pick sparse
                    // off an inexact bound.
                    let bound = Arc::new(NormMap::dense_like(
                        sched.bound_normmap(&na.norms, &nb.norms),
                    ));
                    // Place this node's output tiles across the devices.
                    // The residency-aware policy scores candidate owners
                    // by the input tiles already resident in each pool
                    // PLUS the *planned* placement of computed inputs —
                    // an intermediate is never pool-resident at prepare
                    // time, but its owner map says exactly which device
                    // will hold each of its tiles, so chained spamm
                    // nodes stay producer-aligned instead of bouncing
                    // through the host.  For provisional (exact-refresh)
                    // nodes the bound-derived schedule is a placement
                    // estimate — the map covers the full grid either way.
                    let tile_bytes = lonum * lonum * std::mem::size_of::<f32>();
                    let owner = if cfg.balance == Balance::ResidencyAware {
                        let ctx = PartitionCtx {
                            pools,
                            fa: Some(pa.fp),
                            fb: Some(pb.fp),
                            tile_bytes,
                        };
                        let mut views = ctx.views(cfg.devices);
                        if let Some(o) = &pa.owner {
                            for (t, &d) in o.iter().enumerate() {
                                views[d]
                                    .a_resident
                                    .insert((t / pa.tile_cols, t % pa.tile_cols));
                            }
                        }
                        if let Some(o) = &pb.owner {
                            for (t, &d) in o.iter().enumerate() {
                                views[d]
                                    .b_resident
                                    .insert((t / pb.tile_cols, t % pb.tile_cols));
                            }
                        }
                        Arc::new(
                            Assignment::build_residency_aware(
                                &sched,
                                cfg.devices,
                                &views,
                                tile_bytes,
                            )
                            .owner,
                        )
                    } else {
                        Arc::new(Assignment::build(&sched, cfg.devices, cfg.balance).owner)
                    };
                    PlannedNode {
                        kind: *kind,
                        fp,
                        rows: pa.rows,
                        cols: pb.cols,
                        tile_rows: pa.tile_rows,
                        tile_cols: pb.tile_cols,
                        tau,
                        dt,
                        bound: Some(bound),
                        sched: pinned.then_some(sched),
                        owner: Some(owner),
                        uses: uses[idx],
                    }
                }
                NodeKind::Axpby { alpha, x, beta, y } => {
                    let (px, py) = (&planned[x.0], &planned[y.0]);
                    if px.rows != py.rows || px.cols != py.cols {
                        return Err(Error::Shape(format!(
                            "expr axpby: {}x{} vs {}x{}",
                            px.rows, px.cols, py.rows, py.cols
                        )));
                    }
                    let (nx, ny) = (
                        px.bound.as_ref().expect("matrix node"),
                        py.bound.as_ref().expect("matrix node"),
                    );
                    let mut bound = Matrix::zeros(px.tile_rows, px.tile_cols);
                    for i in 0..px.tile_rows {
                        for j in 0..px.tile_cols {
                            bound[(i, j)] = alpha.abs() * nx.norms[(i, j)]
                                + beta.abs() * ny.norms[(i, j)];
                        }
                    }
                    PlannedNode {
                        kind: *kind,
                        fp: Fingerprint::derive("axpby", &[px.fp, py.fp], &[alpha, beta]),
                        rows: px.rows,
                        cols: px.cols,
                        tile_rows: px.tile_rows,
                        tile_cols: px.tile_cols,
                        tau: 0.0,
                        dt: 0.0,
                        bound: Some(Arc::new(NormMap::dense_like(bound))),
                        sched: None,
                        // Element-wise: inherit X's placement so each
                        // output tile combines device-local inputs.
                        owner: inherit_owner(px, cfg.devices),
                        uses: uses[idx],
                    }
                }
                NodeKind::Scale { s, x } => {
                    let px = &planned[x.0];
                    let nx = px.bound.as_ref().expect("matrix node");
                    let mut bound = Matrix::zeros(px.tile_rows, px.tile_cols);
                    for i in 0..px.tile_rows {
                        for j in 0..px.tile_cols {
                            bound[(i, j)] = s.abs() * nx.norms[(i, j)];
                        }
                    }
                    PlannedNode {
                        kind: *kind,
                        fp: Fingerprint::derive("scale", &[px.fp], &[s]),
                        rows: px.rows,
                        cols: px.cols,
                        tile_rows: px.tile_rows,
                        tile_cols: px.tile_cols,
                        tau: 0.0,
                        dt: 0.0,
                        bound: Some(Arc::new(NormMap::dense_like(bound))),
                        sched: None,
                        owner: inherit_owner(px, cfg.devices),
                        uses: uses[idx],
                    }
                }
                NodeKind::AddDiag { shift, x } => {
                    let px = &planned[x.0];
                    if px.rows != px.cols {
                        return Err(Error::Shape(format!(
                            "expr add_diag: matrix must be square, got {}x{}",
                            px.rows, px.cols
                        )));
                    }
                    let nx = px.bound.as_ref().expect("matrix node");
                    let l = lonum;
                    let mut bound = Matrix::zeros(px.tile_rows, px.tile_cols);
                    for i in 0..px.tile_rows {
                        for j in 0..px.tile_cols {
                            let mut v = nx.norms[(i, j)];
                            if i == j {
                                // ‖σ·I restricted to this tile‖_F.
                                let d = px.rows.min((i + 1) * l).saturating_sub(i * l);
                                v += shift.abs() * (d as f32).sqrt();
                            }
                            bound[(i, j)] = v;
                        }
                    }
                    PlannedNode {
                        kind: *kind,
                        fp: Fingerprint::derive("add_diag", &[px.fp], &[shift]),
                        rows: px.rows,
                        cols: px.cols,
                        tile_rows: px.tile_rows,
                        tile_cols: px.tile_cols,
                        tau: 0.0,
                        dt: 0.0,
                        bound: Some(Arc::new(NormMap::dense_like(bound))),
                        sched: None,
                        owner: inherit_owner(px, cfg.devices),
                        uses: uses[idx],
                    }
                }
                NodeKind::DiffNorm { x, y } => {
                    let (px, py) = (&planned[x.0], &planned[y.0]);
                    if px.rows != py.rows || px.cols != py.cols {
                        return Err(Error::Shape(format!(
                            "expr diff_fnorm: {}x{} vs {}x{}",
                            px.rows, px.cols, py.rows, py.cols
                        )));
                    }
                    PlannedNode {
                        kind: *kind,
                        fp: Fingerprint::derive("diff_fnorm", &[px.fp, py.fp], &[]),
                        rows: px.rows,
                        cols: px.cols,
                        tile_rows: px.tile_rows,
                        tile_cols: px.tile_cols,
                        tau: 0.0,
                        dt: 0.0,
                        bound: None,
                        sched: None,
                        owner: None,
                        uses: uses[idx],
                    }
                }
            };
            planned.push(node);
        }
        front.schedule_secs = t_sched.elapsed().as_secs_f64();

        let plan = ExprPlan {
            lonum,
            devices: cfg.devices,
            nodes: planned,
            root: root.0,
            keeps: self.keeps.iter().map(|k| k.0).collect(),
            inputs: bound_inputs,
            front,
            prepare_secs: t_prepare.elapsed().as_secs_f64(),
        };
        // Always-on static audit (debug builds): every prepared
        // expression plan is verified before it can execute, so the
        // whole test suite fuzzes the dataflow invariants.
        #[cfg(debug_assertions)]
        crate::audit::debug_assert_clean(&crate::audit::audit_expr_plan(&plan), "expr prepare");
        Ok(plan)
    }
}

/// One bound graph input.
pub enum ExprSource<'a> {
    /// A host matrix — padded and fingerprinted at prepare.
    Host(&'a Matrix),
    /// An already padded + fingerprinted operand (a session store entry):
    /// no re-pad, no re-hash.
    Padded(Arc<PaddedMatrix>, Fingerprint),
    /// A previous execution's device-resident result — the chaining hook:
    /// fingerprint and exact normmap ride along, zero host work.
    Resident(&'a ExprValue),
}

/// A device-resident expression result: refcounted tile handles plus the
/// exact tile-norm map, never materialized on host until
/// [`ExprValue::to_matrix`].  Cloning shares the underlying tiles.
#[derive(Clone)]
pub struct ExprValue {
    pub(crate) inner: Arc<ResidentOperand>,
}

impl ExprValue {
    pub fn fingerprint(&self) -> Fingerprint {
        self.inner.fingerprint()
    }

    /// Logical (rows, cols).
    pub fn shape(&self) -> (usize, usize) {
        (self.inner.logical_rows(), self.inner.logical_cols())
    }

    /// ‖·‖_F computed from the resident tiles (bitwise identical to
    /// `Matrix::fnorm` of the downloaded matrix).
    pub fn fnorm(&self) -> f64 {
        self.inner.fnorm()
    }

    /// Device bytes held by this value's tiles.
    pub fn resident_bytes(&self) -> usize {
        self.inner.resident_bytes()
    }

    /// Download to host, cropped to the logical shape — the one transfer
    /// an expression result pays, at the very end.
    pub fn to_matrix(&self) -> Matrix {
        self.inner.to_matrix()
    }
}

enum PlannedInput {
    Host {
        padded: Arc<PaddedMatrix>,
        fp: Fingerprint,
    },
    Resident(ExprValue),
}

/// Element-wise placement: inherit the input node's map (its tiles are
/// device-local there) or fall back to the canonical row-block map
/// ([`crate::spamm::balance::rowblock_owner`]) for leaf inputs.
fn inherit_owner(px: &PlannedNode, devices: usize) -> Option<Arc<Vec<usize>>> {
    px.owner.clone().or_else(|| {
        Some(Arc::new(rowblock_owner(px.tile_rows, px.tile_cols, devices)))
    })
}

pub(crate) struct PlannedNode {
    pub(crate) kind: NodeKind,
    pub(crate) fp: Fingerprint,
    pub(crate) rows: usize,
    pub(crate) cols: usize,
    pub(crate) tile_rows: usize,
    pub(crate) tile_cols: usize,
    /// Resolved τ (spamm nodes; 0.0 elsewhere).
    pub(crate) tau: f32,
    /// Density threshold the node's schedule was built with (spamm
    /// nodes; 0.0 elsewhere) — recorded for the static auditor.
    pub(crate) dt: f32,
    /// Propagated tile-norm upper bound (exact for leaves; None for
    /// scalar nodes).  Leaves carry the real density census; computed
    /// bounds are density-dense so downstream nodes stay conservative.
    pub(crate) bound: Option<Arc<NormMap>>,
    /// Pinned schedule when the bound is already exact (leaf-fed or
    /// τ = 0) — cache eviction cannot un-prepare those nodes.
    pub(crate) sched: Option<Arc<Schedule>>,
    /// Tile→device placement of this node's output (compute nodes only).
    /// Multi-device execution fans the node out per this map; each
    /// device scatters its owned tiles into its *own* pool.
    pub(crate) owner: Option<Arc<Vec<usize>>>,
    /// Consumers + root/keep references; execution frees an
    /// intermediate's tiles when this many uses have retired.
    pub(crate) uses: usize,
}

/// A prepared expression: shapes resolved, τ fixed, bounds propagated,
/// derived fingerprints assigned.  Execute with
/// [`Coordinator::execute_expr`] (any number of times — warm re-submits
/// ride the schedule cache and the residency pool).
pub struct ExprPlan {
    pub(crate) lonum: usize,
    /// Device count the placement maps were built for (must match the
    /// executing coordinator's).
    pub(crate) devices: usize,
    pub(crate) nodes: Vec<PlannedNode>,
    pub(crate) root: usize,
    pub(crate) keeps: Vec<usize>,
    inputs: Vec<PlannedInput>,
    front: MultiplyStats,
    prepare_secs: f64,
}

impl ExprPlan {
    /// One-time host-side analysis cost of `prepare`.
    pub fn prepare_secs(&self) -> f64 {
        self.prepare_secs
    }

    /// Prepare-phase counters (leaf norm cache hits/misses, bound and
    /// schedule clocks).
    pub fn front(&self) -> &MultiplyStats {
        &self.front
    }

    /// The τ the *root-producing* spamm chain resolved to: τ of the last
    /// spamm node in the plan (None for spamm-free graphs).
    pub fn final_tau(&self) -> Option<f32> {
        self.nodes
            .iter()
            .rev()
            .find(|n| matches!(n.kind, NodeKind::Spamm { .. }))
            .map(|n| n.tau)
    }

    /// Logical shape of the root output.
    pub fn output_shape(&self) -> (usize, usize) {
        (self.nodes[self.root].rows, self.nodes[self.root].cols)
    }

    /// Fingerprints of the bound inputs (session pin bookkeeping).
    pub fn input_fingerprints(&self) -> Vec<Fingerprint> {
        self.inputs
            .iter()
            .map(|i| match i {
                PlannedInput::Host { fp, .. } => *fp,
                PlannedInput::Resident(v) => v.fingerprint(),
            })
            .collect()
    }

    /// Device count the plan's placement maps target.
    pub fn devices(&self) -> usize {
        self.devices
    }

    /// Sorted devices that own at least one tile of some compute node —
    /// the pools worth pinning operands into (session bookkeeping).
    /// `[0]` for a plan with no placed nodes.
    pub fn devices_used(&self) -> Vec<usize> {
        let mut used: Vec<usize> = self
            .nodes
            .iter()
            .filter_map(|n| n.owner.as_ref())
            .flat_map(|o| o.iter().copied())
            .collect();
        used.sort_unstable();
        used.dedup();
        if used.is_empty() {
            used.push(0);
        }
        used
    }
}

/// Per-node execution record.
#[derive(Clone, Debug)]
pub struct ExprNodeReport {
    pub node: NodeId,
    /// "spamm" | "axpby" | "scale" | "add_diag" | "diff_fnorm".
    pub op: &'static str,
    /// Schedule valid ratio (spamm nodes; 1.0 elsewhere).
    pub valid_ratio: f64,
    pub wall_secs: f64,
    /// ‖result‖_F from the resident tiles (0.0 for scalar nodes).
    pub result_fnorm: f64,
    pub stats: MultiplyStats,
}

/// Result of one expression execution.
///
/// The root output stays device-resident in [`ExprReport::value`];
/// download it with [`ExprReport::to_matrix`] when (and only when) a
/// host copy is needed — chained drivers that feed `value` into the
/// next graph never pay the transfer.
pub struct ExprReport {
    /// Root output, still device-resident — feed it back as
    /// [`ExprSource::Resident`] to chain without a host round-trip.
    pub value: ExprValue,
    /// Values of nodes retained with [`ExprGraph::keep`].
    pub kept: Vec<(NodeId, ExprValue)>,
    /// Scalar node results ([`ExprGraph::diff_fnorm`]).
    pub scalars: Vec<(NodeId, f64)>,
    /// Per-node breakdown, in execution order (compute nodes only).
    pub nodes: Vec<ExprNodeReport>,
    /// Aggregate over all nodes (stages, caches, residency, transfer).
    /// `stats.cross_device_bytes` is the multi-device host-bounce
    /// traffic (device-produced tiles consumed on another device).
    pub stats: MultiplyStats,
    /// Per-device seconds inside the spamm pipelines (one entry per
    /// configured device; a single-device run has one entry).
    pub device_busy: Vec<f64>,
    /// Tile products each device executed across all spamm nodes — the
    /// "every device did work" witness for multi-device graphs.
    pub device_products: Vec<usize>,
    /// Wall clock of the node loop (compile/warm-up excluded, like the
    /// coordinator's timing protocol).
    pub wall_secs: f64,
    pub compile_secs: f64,
}

impl ExprReport {
    /// Download the root output to host, cropped to the logical shape —
    /// the run's one (optional, caller-triggered) result transfer.
    pub fn to_matrix(&self) -> Matrix {
        self.value.to_matrix()
    }

    pub fn scalar(&self, id: NodeId) -> Option<f64> {
        self.scalars.iter().find(|(n, _)| *n == id).map(|(_, v)| *v)
    }

    pub fn kept_value(&self, id: NodeId) -> Option<&ExprValue> {
        self.kept.iter().find(|(n, _)| *n == id).map(|(_, v)| v)
    }

    pub fn node(&self, id: NodeId) -> Option<&ExprNodeReport> {
        self.nodes.iter().find(|r| r.node == id)
    }
}

/// A runtime value flowing between nodes.
#[derive(Clone)]
enum RunVal {
    Host {
        padded: Arc<PaddedMatrix>,
        fp: Fingerprint,
    },
    Resident(ExprValue),
}

impl RunVal {
    fn as_operand(&self) -> (TileSource<'_>, Fingerprint) {
        match self {
            RunVal::Host { padded, fp } => (TileSource::Host(padded.as_ref()), *fp),
            RunVal::Resident(v) => (TileSource::Resident(v.inner.as_ref()), v.fingerprint()),
        }
    }

    /// One padded row segment: tile row `ti`, in-tile row `r`, tile
    /// column `tj`.
    fn row_segment(&self, ti: usize, r: usize, tj: usize, l: usize) -> &[f32] {
        match self {
            RunVal::Host { padded, .. } => {
                let cols = padded.inner.cols();
                &padded.inner.data()[(ti * l + r) * cols + tj * l..][..l]
            }
            RunVal::Resident(v) => v.inner.row_segment(ti, r, tj),
        }
    }
}

/// Resolve one input tile through the pool (hits for resident tiles,
/// upload-once for host leaves), falling back to a direct copy when
/// residency is off.  `cross` (multi-device runs only) counts a miss on
/// a device-produced tile as a cross-device host bounce.
#[allow(clippy::too_many_arguments)]
fn stage_tile(
    pool: Option<&ResidencyPool>,
    src: TileSource<'_>,
    fp: Fingerprint,
    ti: usize,
    tj: usize,
    cross: bool,
    dst: &mut [f32],
    stats: &mut MultiplyStats,
) {
    let l2 = src.lonum() * src.lonum();
    let tile_bytes = (l2 * std::mem::size_of::<f32>()) as u64;
    match pool {
        Some(pool) => {
            let got = pool.acquire(TileKey::new(fp, (ti, tj)), l2, |d| {
                src.copy_tile(ti, tj, d)
            });
            dst[..l2].copy_from_slice(&got.handle.data);
            if got.hit {
                stats.residency_hits += 1;
                stats.transfer_saved_bytes += tile_bytes;
            } else {
                stats.residency_misses += 1;
                stats.transfer_bytes += tile_bytes;
                if cross && matches!(src, TileSource::Resident(_)) {
                    // Device-produced tile consumed by a device that does
                    // not hold it: a host bounce.
                    stats.cross_device_bytes += tile_bytes;
                }
            }
            stats.residency_evictions += got.evicted;
        }
        None => src.copy_tile(ti, tj, dst),
    }
}

/// Fold a node's stats (stages + cache counters + product counts) into
/// the aggregate.
fn fold_stats(agg: &mut MultiplyStats, s: &MultiplyStats) {
    agg.absorb_stages(s);
    agg.norm_secs += s.norm_secs;
    agg.schedule_secs += s.schedule_secs;
    agg.norm_cache_hits += s.norm_cache_hits;
    agg.norm_cache_misses += s.norm_cache_misses;
    agg.schedule_cache_hits += s.schedule_cache_hits;
    agg.schedule_cache_misses += s.schedule_cache_misses;
    agg.valid_products += s.valid_products;
    agg.total_products += s.total_products;
}

impl Coordinator {
    /// Prepare an expression graph over concrete inputs (host-side: τ
    /// resolution, bound propagation, schedule pinning, per-node device
    /// placement against this coordinator's pools — no device work).
    pub fn prepare_expr(
        &self,
        g: &ExprGraph,
        inputs: &[ExprSource<'_>],
    ) -> Result<ExprPlan> {
        g.prepare_placed(self.caches(), self.config(), self.residency_pools(), inputs)
    }

    /// Execute a prepared expression with device-resident intermediates.
    /// Single-device configurations run inline on device 0's pool and a
    /// fresh runtime (the session worker passes its long-lived runtime
    /// via [`Coordinator::execute_expr_on`]); multi-device
    /// configurations fan every compute node out across all device
    /// workers per the plan's placement maps.
    pub fn execute_expr(&self, plan: &ExprPlan) -> Result<ExprReport> {
        self.execute_expr_on(None, plan)
    }

    /// [`Coordinator::execute_expr`] with an optional caller-owned
    /// resident runtime (compiled executables persist across calls).  On
    /// `devices == 1` the whole walk runs on it; on `devices > 1` it
    /// serves as the combine orchestrator while spamm nodes fan out
    /// across the persistent worker pool per the plan's placement maps.
    pub fn execute_expr_on(
        &self,
        resident: Option<&Runtime>,
        plan: &ExprPlan,
    ) -> Result<ExprReport> {
        let cfg = self.config();
        if plan.lonum != cfg.lonum {
            return Err(Error::Config(format!(
                "expr plan was prepared at lonum {}, config wants {}",
                plan.lonum, cfg.lonum
            )));
        }
        if plan.devices != cfg.devices {
            return Err(Error::Config(format!(
                "expr plan was placed for {} devices, config wants {}",
                plan.devices, cfg.devices
            )));
        }
        if cfg.devices > 1 {
            return self.execute_expr_multi(resident, plan);
        }
        let lonum = plan.lonum;
        let l2 = lonum * lonum;
        let pool = self.residency_pools().first().map(|p| p.as_ref());

        let owned;
        let rt: &Runtime = match resident {
            Some(rt) => rt,
            None => {
                owned = Runtime::new(self.bundle())?;
                &owned
            }
        };
        // Warm up every tile-GEMM and axpby bucket the plan may use —
        // compile time is excluded from node walls, the coordinator's
        // timing protocol.
        let compile0 = rt.compile_secs();
        let compiles0 = rt.compiles();
        let precision = cfg.precision.as_str();
        let warm: Vec<String> = rt
            .bundle()
            .names()
            .filter(|n| {
                (n.starts_with(&format!("tilegemm_l{lonum}_")) && n.ends_with(precision))
                    || n.starts_with(&format!("axpby_l{lonum}_"))
            })
            .map(|s| s.to_string())
            .collect();
        for name in &warm {
            rt.warmup(&[name.as_str()])?;
        }
        let axpby_buckets = rt.bundle().axpby_buckets(lonum);

        let span = Instant::now();
        let mut uses: Vec<usize> = plan.nodes.iter().map(|n| n.uses).collect();
        let mut values: Vec<Option<RunVal>> = (0..plan.nodes.len()).map(|_| None).collect();
        let mut scalars: Vec<(NodeId, f64)> = Vec::new();
        let mut reports: Vec<ExprNodeReport> = Vec::new();
        let mut agg = MultiplyStats::default();

        for idx in 0..plan.nodes.len() {
            let node = &plan.nodes[idx];
            match node.kind {
                NodeKind::Operand { slot } => {
                    values[idx] = Some(match &plan.inputs[slot] {
                        PlannedInput::Host { padded, fp } => RunVal::Host {
                            padded: padded.clone(),
                            fp: *fp,
                        },
                        PlannedInput::Resident(v) => RunVal::Resident(v.clone()),
                    });
                }
                NodeKind::Spamm { a, b, .. } => {
                    let mut nstats = MultiplyStats::default();
                    let t_node = Instant::now();
                    let va = values[a.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: spamm input value missing".into())
                    })?;
                    let vb = values[b.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: spamm input value missing".into())
                    })?;
                    let tau = node.tau;
                    let (src_a, fa) = va.as_operand();
                    let (src_b, fb) = vb.as_operand();
                    // Schedule: pinned (exact at prepare) where possible,
                    // otherwise rebuilt from exact norms — leaf norms via
                    // the keyed cache, intermediate norms refreshed from
                    // the resident tiles (no host recompute).
                    let t = Instant::now();
                    let sched: Arc<Schedule> = match &node.sched {
                        Some(s) => {
                            nstats.norms_propagated += 1;
                            s.clone()
                        }
                        None => {
                            let na = self.exact_norm(&va, &plan.nodes[a.0], &mut nstats)?;
                            let nb = self.exact_norm(&vb, &plan.nodes[b.0], &mut nstats)?;
                            let t_s = Instant::now();
                            let dt = resolve_density_threshold(cfg, &na, &nb);
                            let sched = if cfg.cache_enabled {
                                self.caches().schedule_via(
                                    Some(fa),
                                    Some(fb),
                                    tau,
                                    dt,
                                    &na,
                                    &nb,
                                    &mut nstats,
                                )?
                            } else {
                                Arc::new(Schedule::build_adaptive(&na, &nb, tau, dt)?)
                            };
                            nstats.schedule_secs = t_s.elapsed().as_secs_f64();
                            sched
                        }
                    };
                    nstats.norm_secs = t.elapsed().as_secs_f64() - nstats.schedule_secs;
                    nstats.valid_products = sched.valid_products();
                    nstats.total_products = sched.total_products();
                    nstats.valid_ratio = sched.valid_ratio();

                    let all_tiles: Vec<(usize, usize)> = (0..node.tile_rows)
                        .flat_map(|i| (0..node.tile_cols).map(move |j| (i, j)))
                        .collect();
                    let mut sink = TileAccumulator::new(lonum, all_tiles.iter().copied());
                    execute_batches(
                        rt,
                        cfg,
                        pool,
                        Operand {
                            src: src_a,
                            fp: Some(fa),
                        },
                        Operand {
                            src: src_b,
                            fp: Some(fb),
                        },
                        &mut sink,
                        &sched,
                        &[all_tiles.as_slice()],
                        &mut nstats,
                    )?;
                    // Scatter lands straight in the pool under the derived
                    // fingerprint; the exact tile norms are computed here
                    // (device-side get-norm) for downstream schedules.
                    let resop = ResidentOperand::from_tiles(
                        node.fp,
                        lonum,
                        node.rows,
                        node.cols,
                        node.tile_rows,
                        node.tile_cols,
                        sink.into_tiles(),
                        pool,
                    )?;
                    let value = ExprValue {
                        inner: Arc::new(resop),
                    };
                    let fnorm = value.fnorm();
                    nstats.total_secs = t_node.elapsed().as_secs_f64();
                    reports.push(ExprNodeReport {
                        node: NodeId(idx),
                        op: "spamm",
                        valid_ratio: sched.valid_ratio(),
                        wall_secs: nstats.total_secs,
                        result_fnorm: fnorm,
                        stats: nstats,
                    });
                    values[idx] = Some(RunVal::Resident(value));
                }
                NodeKind::Axpby { alpha, x, beta, y } => {
                    let mut nstats = MultiplyStats::default();
                    let t_node = Instant::now();
                    let vx = values[x.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: axpby input value missing".into())
                    })?;
                    let vy = values[y.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: axpby input value missing".into())
                    })?;
                    let ids: Vec<(usize, usize)> = (0..node.tile_rows)
                        .flat_map(|i| (0..node.tile_cols).map(move |j| (i, j)))
                        .collect();
                    let tiles = self.run_axpby(
                        rt,
                        pool,
                        &axpby_buckets,
                        alpha,
                        &vx,
                        beta,
                        &vy,
                        &ids,
                        lonum,
                        false,
                        &mut nstats,
                    )?;
                    let resop = ResidentOperand::from_tiles(
                        node.fp,
                        lonum,
                        node.rows,
                        node.cols,
                        node.tile_rows,
                        node.tile_cols,
                        tiles,
                        pool,
                    )?;
                    let value = ExprValue {
                        inner: Arc::new(resop),
                    };
                    let fnorm = value.fnorm();
                    nstats.valid_ratio = 1.0;
                    nstats.total_secs = t_node.elapsed().as_secs_f64();
                    reports.push(ExprNodeReport {
                        node: NodeId(idx),
                        op: "axpby",
                        valid_ratio: 1.0,
                        wall_secs: nstats.total_secs,
                        result_fnorm: fnorm,
                        stats: nstats,
                    });
                    values[idx] = Some(RunVal::Resident(value));
                }
                NodeKind::Scale { s, x } | NodeKind::AddDiag { shift: s, x } => {
                    let is_scale = matches!(node.kind, NodeKind::Scale { .. });
                    let mut nstats = MultiplyStats::default();
                    let t_node = Instant::now();
                    let vx = values[x.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: input value missing".into())
                    })?;
                    let (src, fp) = vx.as_operand();
                    let mut tiles = Vec::with_capacity(node.tile_rows * node.tile_cols);
                    for ti in 0..node.tile_rows {
                        for tj in 0..node.tile_cols {
                            // Stage straight into the output tile (one
                            // copy), then apply the elementwise op.
                            let mut out = vec![0.0f32; l2];
                            stage_tile(pool, src, fp, ti, tj, false, &mut out, &mut nstats);
                            if is_scale {
                                for v in &mut out {
                                    *v *= s;
                                }
                            } else if ti == tj {
                                // X + σI: only diagonal tiles change.
                                for r in 0..lonum {
                                    if ti * lonum + r >= node.rows {
                                        break;
                                    }
                                    out[r * lonum + r] += s;
                                }
                            }
                            tiles.push(((ti, tj), out));
                        }
                    }
                    let resop = ResidentOperand::from_tiles(
                        node.fp,
                        lonum,
                        node.rows,
                        node.cols,
                        node.tile_rows,
                        node.tile_cols,
                        tiles,
                        pool,
                    )?;
                    let value = ExprValue {
                        inner: Arc::new(resop),
                    };
                    let fnorm = value.fnorm();
                    nstats.valid_ratio = 1.0;
                    nstats.total_secs = t_node.elapsed().as_secs_f64();
                    reports.push(ExprNodeReport {
                        node: NodeId(idx),
                        op: if is_scale { "scale" } else { "add_diag" },
                        valid_ratio: 1.0,
                        wall_secs: nstats.total_secs,
                        result_fnorm: fnorm,
                        stats: nstats,
                    });
                    values[idx] = Some(RunVal::Resident(value));
                }
                NodeKind::DiffNorm { x, y } => {
                    let t_node = Instant::now();
                    let vx = values[x.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: diff_fnorm input value missing".into())
                    })?;
                    let vy = values[y.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: diff_fnorm input value missing".into())
                    })?;
                    // Padded row-major traversal: padding contributes
                    // exact 0.0 terms, so the sum is bitwise identical to
                    // `Matrix::error_fnorm` over the logical matrices.
                    let mut acc = 0.0f64;
                    for ti in 0..node.tile_rows {
                        for r in 0..lonum {
                            for tj in 0..node.tile_cols {
                                let xs = vx.row_segment(ti, r, tj, lonum);
                                let ys = vy.row_segment(ti, r, tj, lonum);
                                for (xv, yv) in xs.iter().zip(ys) {
                                    let d = (xv - yv) as f64;
                                    acc += d * d;
                                }
                            }
                        }
                    }
                    let out = acc.sqrt();
                    scalars.push((NodeId(idx), out));
                    reports.push(ExprNodeReport {
                        node: NodeId(idx),
                        op: "diff_fnorm",
                        valid_ratio: 1.0,
                        wall_secs: t_node.elapsed().as_secs_f64(),
                        result_fnorm: 0.0,
                        stats: MultiplyStats::default(),
                    });
                }
            }

            // Retire inputs whose last consumer just ran: drop the value
            // (releasing its pin) and free an interior intermediate's
            // tiles from the pool immediately.
            let retire = |dep: NodeId,
                          uses: &mut Vec<usize>,
                          values: &mut Vec<Option<RunVal>>| {
                uses[dep.0] -= 1;
                if uses[dep.0] > 0 {
                    return;
                }
                let interior = !matches!(plan.nodes[dep.0].kind, NodeKind::Operand { .. });
                if let Some(RunVal::Resident(v)) = values[dep.0].take() {
                    let fp = v.fingerprint();
                    drop(v);
                    if interior {
                        if let Some(pool) = pool {
                            pool.remove_operand(fp);
                        }
                    }
                }
            };
            match plan.nodes[idx].kind {
                NodeKind::Operand { .. } => {}
                NodeKind::Spamm { a, b, .. } => {
                    retire(a, &mut uses, &mut values);
                    retire(b, &mut uses, &mut values);
                }
                NodeKind::Axpby { x, y, .. } | NodeKind::DiffNorm { x, y } => {
                    retire(x, &mut uses, &mut values);
                    retire(y, &mut uses, &mut values);
                }
                NodeKind::Scale { x, .. } | NodeKind::AddDiag { x, .. } => {
                    retire(x, &mut uses, &mut values);
                }
            }
        }

        for r in &reports {
            fold_stats(&mut agg, &r.stats);
        }
        if agg.total_products > 0 {
            agg.valid_ratio = agg.valid_products as f64 / agg.total_products as f64;
        }
        agg.total_secs = span.elapsed().as_secs_f64();
        agg.compiles = rt.compiles() - compiles0;
        agg.compile_secs = rt.compile_secs() - compile0;

        let value = match values[plan.root].clone() {
            Some(RunVal::Resident(v)) => v,
            _ => {
                return Err(Error::Coordinator(
                    "expr: root value missing after execution".into(),
                ))
            }
        };
        let kept = plan
            .keeps
            .iter()
            .filter_map(|&k| match values[k].clone() {
                Some(RunVal::Resident(v)) => Some((NodeId(k), v)),
                _ => None,
            })
            .collect();
        let device_busy = vec![agg.exec_secs];
        let device_products = vec![agg.valid_products];
        Ok(ExprReport {
            value,
            kept,
            scalars,
            nodes: reports,
            stats: agg,
            device_busy,
            device_products,
            wall_secs: span.elapsed().as_secs_f64(),
            compile_secs: agg.compile_secs,
        })
    }

    /// Multi-device expression execution: every spamm node fans out
    /// across all device workers per the plan's placement map — each
    /// device runs the shared per-device pipeline
    /// ([`crate::coordinator::pipeline`]'s `run_device`) over its owned
    /// output tiles and scatters them into its *own* pool under the
    /// node's derived fingerprint.  A host-side mirror of each
    /// intermediate backs cross-device consumption: a consumer device
    /// staging a tile another device produced takes a pool miss filled
    /// from the mirror — the host bounce, counted in
    /// [`MultiplyStats::cross_device_bytes`].  Element-wise nodes stage
    /// per owned tile through the owning device's pool.  Results are
    /// bitwise identical to the single-device path: tile ownership is
    /// exclusive and every output tile accumulates its products in the
    /// same k order regardless of the partition.
    fn execute_expr_multi(
        &self,
        resident: Option<&Runtime>,
        plan: &ExprPlan,
    ) -> Result<ExprReport> {
        let cfg = self.config();
        let devices = cfg.devices;
        let lonum = plan.lonum;
        let l2 = lonum * lonum;
        let pools = self.residency_pools();
        let pool_of = |d: usize| pools.get(d).map(|p| p.as_ref());

        // Orchestrator runtime: element-wise tile kernels only; spamm
        // nodes run on the persistent per-device pool workers below.  A
        // session worker passes its long-lived runtime as the
        // orchestrator so repeated expr submits stop recompiling the
        // combine kernels too.
        let owned;
        let rt: &Runtime = match resident {
            Some(rt) => rt,
            None => {
                owned = Runtime::new(self.bundle())?;
                &owned
            }
        };
        let compile0 = rt.compile_secs();
        let compiles0 = rt.compiles();
        let warm: Vec<String> = rt
            .bundle()
            .names()
            .filter(|n| n.starts_with(&format!("axpby_l{lonum}_")))
            .map(|s| s.to_string())
            .collect();
        for name in &warm {
            rt.warmup(&[name.as_str()])?;
        }
        let axpby_buckets = rt.bundle().axpby_buckets(lonum);
        let mut worker_compile = 0.0f64;
        let mut worker_compiles = 0u64;

        let span = Instant::now();
        let mut uses: Vec<usize> = plan.nodes.iter().map(|n| n.uses).collect();
        let mut values: Vec<Option<RunVal>> = (0..plan.nodes.len()).map(|_| None).collect();
        let mut scalars: Vec<(NodeId, f64)> = Vec::new();
        let mut reports: Vec<ExprNodeReport> = Vec::new();
        let mut agg = MultiplyStats::default();
        let mut device_busy = vec![0.0f64; devices];
        let mut device_products = vec![0usize; devices];

        for idx in 0..plan.nodes.len() {
            let node = &plan.nodes[idx];
            match node.kind {
                NodeKind::Operand { slot } => {
                    values[idx] = Some(match &plan.inputs[slot] {
                        PlannedInput::Host { padded, fp } => RunVal::Host {
                            padded: padded.clone(),
                            fp: *fp,
                        },
                        PlannedInput::Resident(v) => RunVal::Resident(v.clone()),
                    });
                }
                NodeKind::Spamm { a, b, .. } => {
                    let mut nstats = MultiplyStats::default();
                    let t_node = Instant::now();
                    let va = values[a.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: spamm input value missing".into())
                    })?;
                    let vb = values[b.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: spamm input value missing".into())
                    })?;
                    let tau = node.tau;
                    let (_, fa) = va.as_operand();
                    let (_, fb) = vb.as_operand();
                    // Schedule: pinned where the prepare-time bound was
                    // exact, otherwise rebuilt from exact norms (leaf
                    // norms via the keyed cache, intermediates refreshed
                    // from the mirror's scatter-time normmap).
                    let t = Instant::now();
                    let sched: Arc<Schedule> = match &node.sched {
                        Some(s) => {
                            nstats.norms_propagated += 1;
                            s.clone()
                        }
                        None => {
                            let na = self.exact_norm(&va, &plan.nodes[a.0], &mut nstats)?;
                            let nb = self.exact_norm(&vb, &plan.nodes[b.0], &mut nstats)?;
                            let t_s = Instant::now();
                            let dt = resolve_density_threshold(cfg, &na, &nb);
                            let sched = if cfg.cache_enabled {
                                self.caches().schedule_via(
                                    Some(fa),
                                    Some(fb),
                                    tau,
                                    dt,
                                    &na,
                                    &nb,
                                    &mut nstats,
                                )?
                            } else {
                                Arc::new(Schedule::build_adaptive(&na, &nb, tau, dt)?)
                            };
                            nstats.schedule_secs = t_s.elapsed().as_secs_f64();
                            sched
                        }
                    };
                    nstats.norm_secs = t.elapsed().as_secs_f64() - nstats.schedule_secs;
                    nstats.valid_products = sched.valid_products();
                    nstats.total_products = sched.total_products();
                    nstats.valid_ratio = sched.valid_ratio();

                    let owner = node
                        .owner
                        .clone()
                        .ok_or_else(|| Error::Coordinator("expr: unplaced spamm node".into()))?;
                    let assignment = Assignment {
                        devices,
                        owner: owner.as_ref().clone(),
                    };
                    let work = batches_of(&sched, &assignment, cfg.pipeline_batches);
                    let active: Vec<DeviceWork> =
                        work.into_iter().filter(|w| w.tile_count() > 0).collect();
                    // Fan out to the persistent pool workers: each job
                    // owns Arc handles to its inputs and schedule, and the
                    // node barrier spans only the active workers (the
                    // orchestrator just collects replies).
                    let barrier = Arc::new(Barrier::new(active.len()));
                    let jobs: Vec<_> = active
                        .into_iter()
                        .map(|w| {
                            let device = w.device;
                            let va = va.clone();
                            let vb = vb.clone();
                            let sched = sched.clone();
                            let cfg = cfg.clone();
                            let rpool = pools.get(w.device).cloned();
                            let barrier = barrier.clone();
                            let job = move |rt: &Runtime| -> Result<DeviceResult> {
                                let (src_a, fa) = va.as_operand();
                                let (src_b, fb) = vb.as_operand();
                                run_device(
                                    rt,
                                    &cfg,
                                    rpool.as_deref(),
                                    Operand {
                                        src: src_a,
                                        fp: Some(fa),
                                    },
                                    Operand {
                                        src: src_b,
                                        fp: Some(fb),
                                    },
                                    &sched,
                                    &w,
                                    &barrier,
                                )
                            };
                            (device, job)
                        })
                        .collect();
                    let replies = self.worker_pool()?.dispatch(jobs)?;
                    let mut results: Vec<DeviceResult> = Vec::with_capacity(replies.len());
                    for rx in replies {
                        results.push(rx.recv().map_err(|_| {
                            Error::Coordinator("expr device worker terminated".into())
                        })??);
                    }

                    // Merge: each device's tiles land in its own pool
                    // under the derived fingerprint (device-produced —
                    // no upload counters), and in the host mirror that
                    // backs cross-device gathers and norm refreshes.
                    let mut all: Vec<((usize, usize), Vec<f32>)> =
                        Vec::with_capacity(node.tile_rows * node.tile_cols);
                    for r in results {
                        device_busy[r.device] += r.busy_secs;
                        device_products[r.device] += r.products;
                        worker_compile += r.compile_secs;
                        worker_compiles += r.compiles;
                        nstats.absorb_stages(&r.stats);
                        for ((i, j), data) in r.tiles {
                            if let Some(p) = pool_of(r.device) {
                                p.insert(TileKey::new(node.fp, (i, j)), data.clone());
                            }
                            all.push(((i, j), data));
                        }
                    }
                    all.sort_by_key(|t| t.0);
                    let resop = ResidentOperand::from_tiles(
                        node.fp,
                        lonum,
                        node.rows,
                        node.cols,
                        node.tile_rows,
                        node.tile_cols,
                        all,
                        None,
                    )?;
                    let value = ExprValue {
                        inner: Arc::new(resop),
                    };
                    let fnorm = value.fnorm();
                    nstats.total_secs = t_node.elapsed().as_secs_f64();
                    reports.push(ExprNodeReport {
                        node: NodeId(idx),
                        op: "spamm",
                        valid_ratio: sched.valid_ratio(),
                        wall_secs: nstats.total_secs,
                        result_fnorm: fnorm,
                        stats: nstats,
                    });
                    values[idx] = Some(RunVal::Resident(value));
                }
                NodeKind::Axpby { alpha, x, beta, y } => {
                    let mut nstats = MultiplyStats::default();
                    let t_node = Instant::now();
                    let vx = values[x.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: axpby input value missing".into())
                    })?;
                    let vy = values[y.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: axpby input value missing".into())
                    })?;
                    let owner = node
                        .owner
                        .clone()
                        .ok_or_else(|| Error::Coordinator("expr: unplaced axpby node".into()))?;
                    // Per device: combine its owned tiles through its own
                    // pool (element-wise, so the device grouping cannot
                    // change the result), then insert them there.
                    let mut all: Vec<((usize, usize), Vec<f32>)> =
                        Vec::with_capacity(node.tile_rows * node.tile_cols);
                    for d in 0..devices {
                        let ids: Vec<(usize, usize)> = (0..node.tile_rows)
                            .flat_map(|i| (0..node.tile_cols).map(move |j| (i, j)))
                            .filter(|&(i, j)| owner[i * node.tile_cols + j] == d)
                            .collect();
                        if ids.is_empty() {
                            continue;
                        }
                        let tiles = self.run_axpby(
                            &rt,
                            pool_of(d),
                            &axpby_buckets,
                            alpha,
                            &vx,
                            beta,
                            &vy,
                            &ids,
                            lonum,
                            true,
                            &mut nstats,
                        )?;
                        for ((i, j), data) in tiles {
                            if let Some(p) = pool_of(d) {
                                p.insert(TileKey::new(node.fp, (i, j)), data.clone());
                            }
                            all.push(((i, j), data));
                        }
                    }
                    all.sort_by_key(|t| t.0);
                    let resop = ResidentOperand::from_tiles(
                        node.fp,
                        lonum,
                        node.rows,
                        node.cols,
                        node.tile_rows,
                        node.tile_cols,
                        all,
                        None,
                    )?;
                    let value = ExprValue {
                        inner: Arc::new(resop),
                    };
                    let fnorm = value.fnorm();
                    nstats.valid_ratio = 1.0;
                    nstats.total_secs = t_node.elapsed().as_secs_f64();
                    reports.push(ExprNodeReport {
                        node: NodeId(idx),
                        op: "axpby",
                        valid_ratio: 1.0,
                        wall_secs: nstats.total_secs,
                        result_fnorm: fnorm,
                        stats: nstats,
                    });
                    values[idx] = Some(RunVal::Resident(value));
                }
                NodeKind::Scale { s, x } | NodeKind::AddDiag { shift: s, x } => {
                    let is_scale = matches!(node.kind, NodeKind::Scale { .. });
                    let mut nstats = MultiplyStats::default();
                    let t_node = Instant::now();
                    let vx = values[x.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: input value missing".into())
                    })?;
                    let owner = node
                        .owner
                        .clone()
                        .ok_or_else(|| Error::Coordinator("expr: unplaced node".into()))?;
                    let (src, fp) = vx.as_operand();
                    let mut tiles = Vec::with_capacity(node.tile_rows * node.tile_cols);
                    for ti in 0..node.tile_rows {
                        for tj in 0..node.tile_cols {
                            let d = owner[ti * node.tile_cols + tj];
                            let pool_t = pool_of(d);
                            let mut out = vec![0.0f32; l2];
                            stage_tile(pool_t, src, fp, ti, tj, true, &mut out, &mut nstats);
                            if is_scale {
                                for v in &mut out {
                                    *v *= s;
                                }
                            } else if ti == tj {
                                for r in 0..lonum {
                                    if ti * lonum + r >= node.rows {
                                        break;
                                    }
                                    out[r * lonum + r] += s;
                                }
                            }
                            if let Some(p) = pool_t {
                                p.insert(TileKey::new(node.fp, (ti, tj)), out.clone());
                            }
                            tiles.push(((ti, tj), out));
                        }
                    }
                    let resop = ResidentOperand::from_tiles(
                        node.fp,
                        lonum,
                        node.rows,
                        node.cols,
                        node.tile_rows,
                        node.tile_cols,
                        tiles,
                        None,
                    )?;
                    let value = ExprValue {
                        inner: Arc::new(resop),
                    };
                    let fnorm = value.fnorm();
                    nstats.valid_ratio = 1.0;
                    nstats.total_secs = t_node.elapsed().as_secs_f64();
                    reports.push(ExprNodeReport {
                        node: NodeId(idx),
                        op: if is_scale { "scale" } else { "add_diag" },
                        valid_ratio: 1.0,
                        wall_secs: nstats.total_secs,
                        result_fnorm: fnorm,
                        stats: nstats,
                    });
                    values[idx] = Some(RunVal::Resident(value));
                }
                NodeKind::DiffNorm { x, y } => {
                    let t_node = Instant::now();
                    let vx = values[x.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: diff_fnorm input value missing".into())
                    })?;
                    let vy = values[y.0].clone().ok_or_else(|| {
                        Error::Coordinator("expr: diff_fnorm input value missing".into())
                    })?;
                    let mut acc = 0.0f64;
                    for ti in 0..node.tile_rows {
                        for r in 0..lonum {
                            for tj in 0..node.tile_cols {
                                let xs = vx.row_segment(ti, r, tj, lonum);
                                let ys = vy.row_segment(ti, r, tj, lonum);
                                for (xv, yv) in xs.iter().zip(ys) {
                                    let d = (xv - yv) as f64;
                                    acc += d * d;
                                }
                            }
                        }
                    }
                    scalars.push((NodeId(idx), acc.sqrt()));
                    reports.push(ExprNodeReport {
                        node: NodeId(idx),
                        op: "diff_fnorm",
                        valid_ratio: 1.0,
                        wall_secs: t_node.elapsed().as_secs_f64(),
                        result_fnorm: 0.0,
                        stats: MultiplyStats::default(),
                    });
                }
            }

            // Retire inputs whose last consumer just ran; an interior
            // intermediate's tiles are freed from *every* device pool.
            let retire = |dep: NodeId,
                          uses: &mut Vec<usize>,
                          values: &mut Vec<Option<RunVal>>| {
                uses[dep.0] -= 1;
                if uses[dep.0] > 0 {
                    return;
                }
                let interior = !matches!(plan.nodes[dep.0].kind, NodeKind::Operand { .. });
                if let Some(RunVal::Resident(v)) = values[dep.0].take() {
                    let fp = v.fingerprint();
                    drop(v);
                    if interior {
                        for p in pools {
                            p.remove_operand(fp);
                        }
                    }
                }
            };
            match plan.nodes[idx].kind {
                NodeKind::Operand { .. } => {}
                NodeKind::Spamm { a, b, .. } => {
                    retire(a, &mut uses, &mut values);
                    retire(b, &mut uses, &mut values);
                }
                NodeKind::Axpby { x, y, .. } | NodeKind::DiffNorm { x, y } => {
                    retire(x, &mut uses, &mut values);
                    retire(y, &mut uses, &mut values);
                }
                NodeKind::Scale { x, .. } | NodeKind::AddDiag { x, .. } => {
                    retire(x, &mut uses, &mut values);
                }
            }
        }

        for r in &reports {
            fold_stats(&mut agg, &r.stats);
        }
        if agg.total_products > 0 {
            agg.valid_ratio = agg.valid_products as f64 / agg.total_products as f64;
        }
        agg.total_secs = span.elapsed().as_secs_f64();

        let value = match values[plan.root].clone() {
            Some(RunVal::Resident(v)) => v,
            _ => {
                return Err(Error::Coordinator(
                    "expr: root value missing after execution".into(),
                ))
            }
        };
        let kept = plan
            .keeps
            .iter()
            .filter_map(|&k| match values[k].clone() {
                Some(RunVal::Resident(v)) => Some((NodeId(k), v)),
                _ => None,
            })
            .collect();
        agg.compiles = rt.compiles() - compiles0 + worker_compiles;
        agg.compile_secs = rt.compile_secs() - compile0 + worker_compile;
        Ok(ExprReport {
            value,
            kept,
            scalars,
            nodes: reports,
            stats: agg,
            device_busy,
            device_products,
            wall_secs: span.elapsed().as_secs_f64(),
            compile_secs: agg.compile_secs,
        })
    }

    /// Drop a chained value's tiles from the device pools.  Only tiles
    /// with no other live handle are freed, so it is always safe; call
    /// after the value's last use to reclaim device memory eagerly
    /// instead of waiting for LRU churn.
    pub fn evict_value(&self, v: ExprValue) {
        let fp = v.fingerprint();
        drop(v);
        for p in self.residency_pools() {
            p.remove_operand(fp);
        }
    }

    /// Exact tile norms of a spamm input: leaves go through the keyed
    /// norm cache (hits after prepare), intermediates carry the norms
    /// refreshed from their resident tiles — never a host recompute.
    fn exact_norm(
        &self,
        val: &RunVal,
        node: &PlannedNode,
        stats: &mut MultiplyStats,
    ) -> Result<Arc<NormMap>> {
        match val {
            RunVal::Host { padded, fp } => {
                if self.config().cache_enabled {
                    self.caches()
                        .normmap_keyed(*fp, stats, || Ok(normmap_with_density(padded)))
                } else {
                    // Leaf bounds are exact normmaps, recorded at prepare.
                    Ok(node.bound.clone().expect("leaf bound is its normmap"))
                }
            }
            RunVal::Resident(v) => {
                stats.norms_refreshed += 1;
                // Refresh norms *and* the density census from the
                // scatter-time tiles, so rebuilt downstream schedules can
                // still route genuinely sparse intermediates through the
                // sparse tile path.
                Ok(Arc::new(v.inner.norm_density_map()))
            }
        }
    }

    /// Batched device-side α·X + β·Y over `ids` (one device's owned
    /// tiles; the single-device path passes the full grid), chunked by
    /// the bundle's axpby buckets (element-wise, so chunking cannot
    /// change the result); bundles without axpby artifacts fall back to
    /// the same arithmetic on the staged tiles.
    #[allow(clippy::too_many_arguments)]
    fn run_axpby(
        &self,
        rt: &Runtime,
        pool: Option<&ResidencyPool>,
        buckets: &[usize],
        alpha: f32,
        vx: &RunVal,
        beta: f32,
        vy: &RunVal,
        ids: &[(usize, usize)],
        lonum: usize,
        cross: bool,
        stats: &mut MultiplyStats,
    ) -> Result<Vec<((usize, usize), Vec<f32>)>> {
        let l2 = lonum * lonum;
        let (src_x, fpx) = vx.as_operand();
        let (src_y, fpy) = vy.as_operand();
        let mut tiles: Vec<((usize, usize), Vec<f32>)> = Vec::with_capacity(ids.len());
        let mut rest: &[(usize, usize)] = ids;
        while !rest.is_empty() {
            let take = buckets
                .iter()
                .rev()
                .find(|&&b| b <= rest.len())
                .copied()
                .unwrap_or(rest.len())
                .min(rest.len());
            let (chunk, tail) = rest.split_at(take);
            rest = tail;
            if buckets.is_empty() {
                // No device kernel in this bundle: identical arithmetic
                // on the staged tiles (still zero host round-trips for
                // resident inputs).
                let mut xb = vec![0.0f32; l2];
                let mut yb = vec![0.0f32; l2];
                for &(ti, tj) in chunk {
                    stage_tile(pool, src_x, fpx, ti, tj, cross, &mut xb, stats);
                    stage_tile(pool, src_y, fpy, ti, tj, cross, &mut yb, stats);
                    let out: Vec<f32> = xb
                        .iter()
                        .zip(&yb)
                        .map(|(&xv, &yv)| alpha * xv + beta * yv)
                        .collect();
                    tiles.push(((ti, tj), out));
                }
                continue;
            }
            let cap = rt
                .bundle()
                .axpby(chunk.len(), lonum)?
                .param_usize("batch")
                .unwrap_or(chunk.len());
            let mut xb = vec![0.0f32; cap * l2];
            let mut yb = vec![0.0f32; cap * l2];
            for (slot, &(ti, tj)) in chunk.iter().enumerate() {
                stage_tile(
                    pool,
                    src_x,
                    fpx,
                    ti,
                    tj,
                    cross,
                    &mut xb[slot * l2..(slot + 1) * l2],
                    stats,
                );
                stage_tile(
                    pool,
                    src_y,
                    fpy,
                    ti,
                    tj,
                    cross,
                    &mut yb[slot * l2..(slot + 1) * l2],
                    stats,
                );
            }
            let t = Instant::now();
            let out = rt.tile_axpby(&xb, &yb, alpha, beta, cap, lonum)?;
            stats.exec_secs += t.elapsed().as_secs_f64();
            stats.batches += 1;
            for (slot, &(ti, tj)) in chunk.iter().enumerate() {
                tiles.push(((ti, tj), out[slot * l2..(slot + 1) * l2].to_vec()));
            }
        }
        Ok(tiles)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn graph_builder_tracks_slots_and_uses() {
        let mut g = ExprGraph::new();
        let a = g.operand();
        let p2 = g.spamm(a, a, Approx::Tau(0.0));
        let p3 = g.spamm(p2, a, Approx::Tau(0.0));
        let next = g.axpby(3.0, p2, -2.0, p3);
        let idem = g.diff_fnorm(p2, a);
        g.keep(p2);
        g.keep(p2); // duplicate keep is a no-op
        g.output(next);
        let _ = idem;
        assert_eq!(g.input_count(), 1);
        assert_eq!(g.node_count(), 5);
        assert_eq!(g.keeps.len(), 1);
        assert_eq!(g.root, Some(next));
    }

    #[test]
    #[should_panic(expected = "scalar node")]
    fn scalar_nodes_cannot_feed_matrix_ops() {
        let mut g = ExprGraph::new();
        let a = g.operand();
        let d = g.diff_fnorm(a, a);
        g.spamm(d, a, Approx::Tau(0.0));
    }

    #[test]
    #[should_panic(expected = "computed node")]
    fn output_must_be_computed() {
        let mut g = ExprGraph::new();
        let a = g.operand();
        g.output(a);
    }

    #[test]
    fn prepare_rejects_missing_output_and_bad_arity() {
        let caches = ExecCaches::new();
        let cfg = SpammConfig::default();
        let mut g = ExprGraph::new();
        let a = g.operand();
        let _ = g.spamm(a, a, Approx::Tau(0.0));
        let m = Matrix::decay_exponential(64, 1.0, 0.5, 1);
        // No output node.
        assert!(g.prepare(&caches, &cfg, &[ExprSource::Host(&m)]).is_err());
        let mut g2 = ExprGraph::new();
        let a2 = g2.operand();
        let p = g2.spamm(a2, a2, Approx::Tau(0.0));
        g2.output(p);
        // Arity mismatch.
        assert!(g2.prepare(&caches, &cfg, &[]).is_err());
        // Shape mismatch inside the graph.
        let mut g3 = ExprGraph::new();
        let x = g3.operand();
        let y = g3.operand();
        let p3 = g3.spamm(x, y, Approx::Tau(0.0));
        g3.output(p3);
        let rect = Matrix::randn(64, 96, 2);
        let err = g3.prepare(
            &caches,
            &cfg,
            &[ExprSource::Host(&rect), ExprSource::Host(&rect)],
        );
        assert!(err.is_err(), "inner dims 96 vs 64 must be rejected");
    }

    #[test]
    fn prepare_propagates_bounds_and_derives_fingerprints() {
        let caches = ExecCaches::new();
        let cfg = SpammConfig::default();
        let mut g = ExprGraph::new();
        let a = g.operand();
        let p2 = g.spamm(a, a, Approx::Tau(1e-4));
        let p3 = g.spamm(p2, a, Approx::Tau(1e-4));
        g.output(p3);
        let m = Matrix::decay_exponential(96, 1.0, 0.5, 3);
        let plan = g
            .prepare(&caches, &cfg, &[ExprSource::Host(&m)])
            .unwrap();
        assert_eq!(plan.output_shape(), (96, 96));
        assert_eq!(plan.final_tau(), Some(1e-4));
        // Derived fingerprints are distinct from the leaf and each other.
        let fps: Vec<Fingerprint> = plan.nodes.iter().map(|n| n.fp).collect();
        assert_ne!(fps[0], fps[1]);
        assert_ne!(fps[1], fps[2]);
        assert_ne!(fps[0], fps[2]);
        // Leaf-fed node pins its schedule; the downstream τ>0 node
        // (intermediate input) stays provisional for the exact refresh.
        assert!(plan.nodes[1].sched.is_some());
        assert!(plan.nodes[2].sched.is_none());
        // Same graph re-prepared → identical derived fingerprints (the
        // property that makes warm re-submits cache-sound).
        let plan2 = g
            .prepare(&caches, &cfg, &[ExprSource::Host(&m)])
            .unwrap();
        for (n1, n2) in plan.nodes.iter().zip(&plan2.nodes) {
            assert_eq!(n1.fp, n2.fp);
        }
        // τ = 0 downstream nodes pin too (bound pruning cannot differ).
        let mut g0 = ExprGraph::new();
        let a0 = g0.operand();
        let q2 = g0.spamm(a0, a0, Approx::Tau(0.0));
        let q3 = g0.spamm(q2, a0, Approx::Tau(0.0));
        g0.output(q3);
        let plan0 = g0
            .prepare(&caches, &cfg, &[ExprSource::Host(&m)])
            .unwrap();
        assert!(plan0.nodes[2].sched.is_some());
    }
}
