//! Multi-device coordinator — the paper's Algorithm 4 ("Scaling to
//! multiple GPUs") over simulated devices.
//!
//! The calculation is partitioned over M devices by output tiles (row
//! blocks by default, the §3.5.1 strided policy optionally), B is logically
//! broadcast (shared read-only here), per-device work is processed in P
//! pipeline batches, and each device is a worker thread owning its own
//! PJRT client (the one-context-per-GPU model) plus its own
//! [`crate::runtime::residency::ResidencyPool`] and transfer queue.  The
//! P batches stream through one per-device pipeline — batch *i+1*'s
//! uploads overlap batch *i*'s compute; host-level sync is the final join.

pub mod expr;
pub mod metrics;
pub mod partition;
pub mod pipeline;
pub mod service;
pub mod session;
pub mod summa;
pub(crate) mod workers;

pub use expr::{
    ExprGraph, ExprNodeReport, ExprPlan, ExprReport, ExprSource, ExprValue, NodeId,
};
pub use metrics::MultiDeviceReport;
pub use pipeline::Coordinator;
pub use service::Approx;
#[allow(deprecated)]
pub use service::SpammService;
pub use session::{
    Completion, ExprPlanId, ExprTicket, OperandId, PlanId, Priority, SpammSession, StoreStats,
    Ticket, UpdateReport,
};
pub use summa::SummaCoordinator;
