//! Result/metric types for multi-device runs.

use crate::matrix::Matrix;
use crate::spamm::executor::MultiplyStats;

/// Everything a multi-device multiply reports.
#[derive(Clone, Debug)]
pub struct MultiDeviceReport {
    /// The (cropped) product matrix.
    pub c: Matrix,
    /// Wall-clock seconds from the post-warmup barrier to the last join.
    pub wall_secs: f64,
    /// Modeled per-device busy seconds (time inside PJRT execute).
    pub device_busy: Vec<f64>,
    /// Per-device valid-product counts (the §3.5.1 load vector).
    pub device_load: Vec<usize>,
    pub valid_products: usize,
    pub total_products: usize,
    pub valid_ratio: f64,
    /// max(load)/mean(load) over devices — 1.0 is perfect balance.
    pub imbalance: f64,
    /// Seconds each device spent compiling executables (excluded from
    /// wall_secs via the warmup barrier).
    pub compile_secs: Vec<f64>,
    /// Seconds each device's transfer queue spent resolving/uploading
    /// operand tiles (the gather stage; overlaps compute when pipelined).
    pub device_transfer_secs: Vec<f64>,
    /// Bytes each device's gather stage actually uploaded host→device
    /// (residency misses; zero for a fully warm device).
    pub device_transfer_bytes: Vec<u64>,
    /// Bytes resident in each device's pool after the multiply (empty
    /// under `--no-residency`).
    pub device_resident_bytes: Vec<u64>,
    /// Bytes of device-produced tiles each device pulled through a host
    /// bounce (multi-device expression intermediates produced elsewhere).
    pub device_cross_bytes: Vec<u64>,
    /// Pipeline-stage seconds summed over the device workers
    /// (gather/exec/scatter/span + batch count); with stage overlap,
    /// `gather_secs + exec_secs + scatter_secs > exec_span_secs`.
    pub stage: MultiplyStats,
}

impl MultiDeviceReport {
    /// Aggregate busy time across devices.
    pub fn total_busy(&self) -> f64 {
        self.device_busy.iter().sum()
    }

    /// Parallel efficiency: total busy / (devices · wall).
    pub fn efficiency(&self) -> f64 {
        if self.wall_secs <= 0.0 || self.device_busy.is_empty() {
            return 0.0;
        }
        self.total_busy() / (self.device_busy.len() as f64 * self.wall_secs)
    }

    pub fn summary_line(&self) -> String {
        format!(
            "wall {:.3}s, busy {:?}, valid {}/{} ({:.1}%), imbalance {:.2}, eff {:.0}%, \
             transfers {} KiB ({} KiB saved, {} KiB cross-device)",
            self.wall_secs,
            self.device_busy
                .iter()
                .map(|b| (b * 1e3).round() / 1e3)
                .collect::<Vec<_>>(),
            self.valid_products,
            self.total_products,
            self.valid_ratio * 100.0,
            self.imbalance,
            self.efficiency() * 100.0,
            self.stage.transfer_bytes / 1024,
            self.stage.transfer_saved_bytes / 1024,
            self.stage.cross_device_bytes / 1024
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> MultiDeviceReport {
        MultiDeviceReport {
            c: Matrix::zeros(1, 1),
            wall_secs: 2.0,
            device_busy: vec![1.0, 1.0],
            device_load: vec![10, 10],
            valid_products: 20,
            total_products: 40,
            valid_ratio: 0.5,
            imbalance: 1.0,
            compile_secs: vec![0.0, 0.0],
            device_transfer_secs: vec![0.0, 0.0],
            device_transfer_bytes: vec![0, 0],
            device_resident_bytes: vec![0, 0],
            device_cross_bytes: vec![0, 0],
            stage: MultiplyStats::default(),
        }
    }

    #[test]
    fn efficiency_math() {
        let r = report();
        assert!((r.total_busy() - 2.0).abs() < 1e-12);
        assert!((r.efficiency() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn summary_is_renderable() {
        assert!(report().summary_line().contains("50.0%"));
    }
}
