//! Persistent per-device worker threads, each owning one long-lived
//! [`Runtime`] whose compiled executables persist across requests.
//!
//! The per-multiply scoped-thread executor paid a fresh [`Runtime::new`]
//! (PJRT client + empty executable cache) per device *per request* — the
//! recompile cost the paper's warmup-exclusion hides from wall clocks but
//! a serving tier pays on every call.  The pool moves runtime ownership
//! into the thread: a worker compiles an artifact at most once for the
//! life of the pool, so a warm request's compile delta is zero (the
//! invariant `MultiplyStats::compiles` pins in the `devices = 4`
//! regression test).
//!
//! Jobs are closures over the runtime, type-erased into boxes and
//! delivered over per-worker channels; each job carries its own reply
//! channel.  [`DeviceWorkerPool::dispatch`] enqueues one whole multiply's
//! jobs under a single dispatch lock so two concurrent multiplies can
//! never interleave on the per-worker queues — every worker sees the same
//! multiply order, which makes the per-multiply release barrier
//! deadlock-free (all workers park at multiply *i*'s barrier before any
//! touches multiply *i+1*).
//!
//! Construction is fallible end-to-end: every worker reports its
//! `Runtime::new` outcome over a ready channel before the pool is usable,
//! so a broken artifact bundle surfaces as an error at pool creation, not
//! as a hung barrier mid-request.

use std::sync::mpsc;
use std::sync::Mutex;
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::runtime::{ArtifactBundle, Runtime};

/// Type-erased unit of device work.  The closure owns everything it needs
/// (operands, schedule, reply channel) — the worker only lends its
/// runtime.
type Job = Box<dyn FnOnce(&Runtime) + Send + 'static>;

/// One worker thread per device, each with a private job queue and a
/// runtime built once at spawn.
pub(crate) struct DeviceWorkerPool {
    /// Job queues, guarded by the dispatch lock: a multiply's jobs are
    /// enqueued atomically across workers (see module docs).  Keeping the
    /// senders inside the mutex also makes the pool `Sync` by
    /// construction.
    queues: Mutex<Vec<mpsc::Sender<Job>>>,
    handles: Vec<JoinHandle<()>>,
}

impl DeviceWorkerPool {
    /// Spawn `devices` workers, each building its own runtime from
    /// `bundle`.  Fails (with all threads joined) if any worker's runtime
    /// construction fails.
    pub(crate) fn new(bundle: &ArtifactBundle, devices: usize) -> Result<DeviceWorkerPool> {
        if devices == 0 {
            return Err(Error::Coordinator("worker pool needs >= 1 device".into()));
        }
        let mut senders = Vec::with_capacity(devices);
        let mut handles = Vec::with_capacity(devices);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        for device in 0..devices {
            let (tx, rx) = mpsc::channel::<Job>();
            let bundle = bundle.clone();
            let ready = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("spamm-dev{device}"))
                .spawn(move || {
                    let rt = match Runtime::new(&bundle) {
                        Ok(rt) => {
                            let _ = ready.send(Ok(()));
                            rt
                        }
                        Err(e) => {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    };
                    drop(ready);
                    while let Ok(job) = rx.recv() {
                        job(&rt);
                    }
                })
                .map_err(|e| Error::Coordinator(format!("spawn device worker: {e}")))?;
            senders.push(tx);
            handles.push(handle);
        }
        drop(ready_tx);
        // Collect every worker's runtime-construction outcome before the
        // pool is usable: no job can ever land on a worker without a
        // runtime.
        let mut first_err = None;
        for r in ready_rx.iter().take(devices) {
            if let Err(e) = r {
                first_err.get_or_insert(e);
            }
        }
        if let Some(e) = first_err {
            drop(senders); // close queues so surviving workers exit
            for h in handles {
                let _ = h.join();
            }
            return Err(e);
        }
        Ok(DeviceWorkerPool {
            queues: Mutex::new(senders),
            handles,
        })
    }

    pub(crate) fn devices(&self) -> usize {
        self.handles.len()
    }

    /// Atomically enqueue one multiply's jobs — `(device, closure)` pairs
    /// — and return one reply receiver per job, in input order.  Device
    /// indices are validated before anything is enqueued, so a bad index
    /// can never strand half a multiply on the queues.
    pub(crate) fn dispatch<T, F>(
        &self,
        jobs: Vec<(usize, F)>,
    ) -> Result<Vec<mpsc::Receiver<Result<T>>>>
    where
        T: Send + 'static,
        F: FnOnce(&Runtime) -> Result<T> + Send + 'static,
    {
        let queues = self.queues.lock().unwrap();
        if let Some((bad, _)) = jobs.iter().find(|(d, _)| *d >= queues.len()) {
            return Err(Error::Coordinator(format!(
                "dispatch to device {bad} but pool has {} workers",
                queues.len()
            )));
        }
        let mut replies = Vec::with_capacity(jobs.len());
        for (device, f) in jobs {
            let (tx, rx) = mpsc::channel();
            let job: Job = Box::new(move |rt: &Runtime| {
                let _ = tx.send(f(rt));
            });
            queues[device]
                .send(job)
                .map_err(|_| Error::Coordinator("device worker terminated".into()))?;
            replies.push(rx);
        }
        Ok(replies)
    }
}

impl Drop for DeviceWorkerPool {
    fn drop(&mut self) {
        // Closing the queues ends each worker's recv loop; join so no
        // worker outlives the pool (a dangling worker would hold a PJRT
        // client past coordinator teardown).
        self.queues.lock().unwrap().clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
