//! Request service: a queued front-end over the coordinator, turning the
//! library into the deployable shape a framework user expects — submit a
//! stream of SpAMM jobs (mixed sizes, τ or valid-ratio targets), get
//! results plus latency/throughput statistics.
//!
//! Single-node by construction (like the paper's system); the queue gives
//! backpressure and the stats mirror what a serving stack would export.

use std::collections::VecDeque;
use std::time::Instant;

use crate::config::SpammConfig;
use crate::coordinator::Coordinator;
use crate::error::Result;
use crate::matrix::Matrix;
use crate::runtime::ArtifactBundle;
use crate::util::stats::Summary;

/// How the approximation level of a request is specified.
#[derive(Clone, Copy, Debug)]
pub enum Approx {
    /// Explicit threshold.
    Tau(f32),
    /// Valid-ratio target — the service runs the §3.5.2 tuner per request.
    ValidRatio(f64),
}

/// One multiplication job.
pub struct Request {
    pub id: u64,
    pub a: Matrix,
    pub b: Matrix,
    pub approx: Approx,
}

/// Completed job.
pub struct Response {
    pub id: u64,
    pub c: Matrix,
    pub tau: f32,
    pub valid_ratio: f64,
    /// Seconds from submit to completion (queueing + compute).
    pub latency_secs: f64,
    /// Seconds of pure compute (multiply wall).
    pub compute_secs: f64,
}

/// Service statistics over a drained queue.
#[derive(Clone, Debug)]
pub struct ServiceStats {
    pub completed: usize,
    pub wall_secs: f64,
    pub throughput_rps: f64,
    pub latency: Summary,
}

/// A FIFO service wrapping one coordinator.
pub struct SpammService {
    coord: Coordinator,
    queue: VecDeque<(Request, Instant)>,
    next_id: u64,
}

impl SpammService {
    pub fn new(bundle: &ArtifactBundle, cfg: SpammConfig) -> Result<SpammService> {
        Ok(SpammService {
            coord: Coordinator::new(bundle, cfg)?,
            queue: VecDeque::new(),
            next_id: 0,
        })
    }

    /// Enqueue a job; returns its id.
    pub fn submit(&mut self, a: Matrix, b: Matrix, approx: Approx) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((
            Request {
                id,
                a,
                b,
                approx,
            },
            Instant::now(),
        ));
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Process every queued request in FIFO order.
    pub fn drain(&mut self) -> Result<(Vec<Response>, ServiceStats)> {
        let t0 = Instant::now();
        let mut responses = Vec::with_capacity(self.queue.len());
        let mut latencies = Vec::with_capacity(self.queue.len());
        while let Some((req, submitted)) = self.queue.pop_front() {
            let tau = match req.approx {
                Approx::Tau(t) => t,
                Approx::ValidRatio(r) => self.coord.tune_tau(&req.a, &req.b, r)?.tau,
            };
            let rep = self.coord.multiply(&req.a, &req.b, tau)?;
            let latency = submitted.elapsed().as_secs_f64();
            latencies.push(latency);
            responses.push(Response {
                id: req.id,
                c: rep.c,
                tau,
                valid_ratio: rep.valid_ratio,
                latency_secs: latency,
                compute_secs: rep.wall_secs,
            });
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = ServiceStats {
            completed: responses.len(),
            wall_secs: wall,
            throughput_rps: responses.len() as f64 / wall.max(1e-12),
            latency: if latencies.is_empty() {
                Summary::from(&[0.0])
            } else {
                Summary::from(&latencies)
            },
        };
        Ok((responses, stats))
    }
}

/// Synthetic request-trace generator for the `serve` subcommand and the
/// service tests: mixed decay kinds and approximation targets.
pub fn synthetic_trace(count: usize, n: usize, seed: u64) -> Vec<(Matrix, Matrix, Approx)> {
    use crate::util::prng::Rng;
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|i| {
            let s = seed.wrapping_add(i as u64 * 17);
            let (a, b) = if rng.next_f32() < 0.5 {
                (
                    Matrix::decay_algebraic(n, 0.1, 0.1, s),
                    Matrix::decay_algebraic(n, 0.1, 0.1, s ^ 1),
                )
            } else {
                (
                    Matrix::decay_exponential(n, 1.0, 0.9, s),
                    Matrix::decay_exponential(n, 1.0, 0.9, s ^ 1),
                )
            };
            let approx = if rng.next_f32() < 0.5 {
                Approx::ValidRatio(rng.range_f32(0.05, 0.3) as f64)
            } else {
                Approx::Tau(rng.range_f32(1e-6, 1e-2))
            };
            (a, b, approx)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bundle() -> Option<ArtifactBundle> {
        // Real AOT bundle when present, offline hostsim bundle otherwise.
        crate::runtime::hostsim::find_or_test_bundle().ok()
    }

    #[test]
    fn drains_fifo_with_stats() {
        let Some(b) = bundle() else { return };
        let mut svc = SpammService::new(&b, SpammConfig::default()).unwrap();
        let trace = synthetic_trace(4, 96, 1);
        let mut ids = Vec::new();
        for (a, x, ap) in trace {
            ids.push(svc.submit(a, x, ap));
        }
        assert_eq!(svc.pending(), 4);
        let (resp, stats) = svc.drain().unwrap();
        assert_eq!(svc.pending(), 0);
        assert_eq!(stats.completed, 4);
        assert!(stats.throughput_rps > 0.0);
        // FIFO order and monotone ids.
        let got: Vec<u64> = resp.iter().map(|r| r.id).collect();
        assert_eq!(got, ids);
        // Latency ≥ compute; later requests queue longer.
        for r in &resp {
            assert!(r.latency_secs >= r.compute_secs * 0.5);
            assert!(r.valid_ratio <= 1.0);
            assert_eq!(r.c.rows(), 96);
        }
        assert!(resp.last().unwrap().latency_secs >= resp[0].latency_secs);
    }

    #[test]
    fn valid_ratio_requests_are_tuned() {
        let Some(b) = bundle() else { return };
        let mut svc = SpammService::new(&b, SpammConfig::default()).unwrap();
        let a = Matrix::decay_algebraic(128, 0.1, 0.1, 3);
        let x = Matrix::decay_algebraic(128, 0.1, 0.1, 4);
        svc.submit(a, x, Approx::ValidRatio(0.15));
        let (resp, _) = svc.drain().unwrap();
        assert!((resp[0].valid_ratio - 0.15).abs() < 0.05);
        assert!(resp[0].tau > 0.0);
    }

    #[test]
    fn empty_drain_is_ok() {
        let Some(b) = bundle() else { return };
        let mut svc = SpammService::new(&b, SpammConfig::default()).unwrap();
        let (resp, stats) = svc.drain().unwrap();
        assert!(resp.is_empty());
        assert_eq!(stats.completed, 0);
    }

    #[test]
    fn trace_generator_is_deterministic() {
        let t1 = synthetic_trace(3, 64, 9);
        let t2 = synthetic_trace(3, 64, 9);
        for ((a1, _, _), (a2, _, _)) in t1.iter().zip(&t2) {
            assert_eq!(a1, a2);
        }
    }
}
