//! Legacy request service — a thin, deprecated facade over
//! [`SpammSession`](crate::coordinator::session::SpammSession).
//!
//! The historical `SpammService` API (submit whole matrices, blocking
//! FIFO `drain`) forced every caller to re-pass dense operands per call,
//! so fingerprinting, τ tuning, and residency warm-up were rediscovered
//! from scratch on each request.  New code should use the session
//! lifecycle — `put` → `prepare` → `submit` → `wait` — directly; this
//! shim keeps existing callers compiling by driving a session through
//! the old signatures (each drained request registers its operands,
//! prepares a plan, executes, then releases everything).

use std::collections::VecDeque;
use std::time::Instant;

use crate::config::SpammConfig;
use crate::coordinator::session::{OperandId, SpammSession, Ticket};
use crate::error::{Error, Result};
use crate::matrix::Matrix;
use crate::runtime::ArtifactBundle;
use crate::util::stats::Summary;

/// How the approximation level of a request is specified.
#[derive(Clone, Copy, Debug)]
pub enum Approx {
    /// Explicit threshold.
    Tau(f32),
    /// Valid-ratio target — the §3.5.2 tuner runs once per prepared plan.
    ValidRatio(f64),
}

impl Approx {
    /// Reject targets that cannot be satisfied (non-positive or >1
    /// valid ratios, non-finite or negative τ).
    pub fn validate(&self) -> Result<()> {
        match *self {
            Approx::Tau(t) => {
                if !t.is_finite() || t < 0.0 {
                    return Err(Error::Config(format!(
                        "τ must be finite and ≥ 0, got {t}"
                    )));
                }
            }
            Approx::ValidRatio(r) => {
                if !r.is_finite() || r <= 0.0 || r > 1.0 {
                    return Err(Error::Config(format!(
                        "valid-ratio target must be in (0, 1], got {r}"
                    )));
                }
            }
        }
        Ok(())
    }
}

/// One multiplication job.
pub struct Request {
    pub id: u64,
    pub a: Matrix,
    pub b: Matrix,
    pub approx: Approx,
}

/// Completed job.
pub struct Response {
    pub id: u64,
    pub c: Matrix,
    pub tau: f32,
    pub valid_ratio: f64,
    /// Seconds from submit to completion (queueing + compute).
    pub latency_secs: f64,
    /// Seconds of pure compute (multiply wall).
    pub compute_secs: f64,
}

/// Service statistics over a drained queue.
#[derive(Clone, Debug)]
pub struct ServiceStats {
    pub completed: usize,
    pub wall_secs: f64,
    pub throughput_rps: f64,
    /// `None` when the drain completed nothing (an empty queue has no
    /// latency sample — the old code fabricated a `Summary::from(&[0.0])`
    /// here, which skewed aggregation).
    pub latency: Option<Summary>,
}

/// A FIFO service facade over one session.
///
/// Deprecated: use [`SpammSession`] directly — register operands once
/// with `put`, prepare plans, and submit asynchronously with priorities
/// instead of re-sending dense matrices per request.
#[deprecated(
    since = "0.3.0",
    note = "use SpammSession (put → prepare → submit → wait); see rust/README.md for the migration guide"
)]
pub struct SpammService {
    session: SpammSession,
    queue: VecDeque<(Request, Instant)>,
    next_id: u64,
}

#[allow(deprecated)]
impl SpammService {
    pub fn new(bundle: &ArtifactBundle, cfg: SpammConfig) -> Result<SpammService> {
        Ok(SpammService {
            session: SpammSession::new(bundle, cfg)?,
            queue: VecDeque::new(),
            next_id: 0,
        })
    }

    /// The backing session (migration escape hatch).
    pub fn session(&self) -> &SpammSession {
        &self.session
    }

    /// Enqueue a job; returns its id.
    pub fn submit(&mut self, a: Matrix, b: Matrix, approx: Approx) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back((
            Request {
                id,
                a,
                b,
                approx,
            },
            Instant::now(),
        ));
        id
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Process every queued request in FIFO order through the session:
    /// put → prepare → submit, windowed to the session's admission depth,
    /// then release the plan and operands once each response is in.
    pub fn drain(&mut self) -> Result<(Vec<Response>, ServiceStats)> {
        let t0 = Instant::now();
        let mut responses = Vec::with_capacity(self.queue.len());
        let mut latencies = Vec::with_capacity(self.queue.len());
        let mut inflight: VecDeque<Inflight> = VecDeque::new();
        let result = Self::drain_inner(
            &self.session,
            &mut self.queue,
            &mut inflight,
            &mut responses,
            &mut latencies,
        );
        if let Err(e) = result {
            // Do not orphan the window: release every still-in-flight
            // plan and operand ref so the failed drain leaks nothing
            // (their completions, if any, are abandoned).
            for f in inflight.drain(..) {
                let _ = self.session.release_plan(f.plan);
                let _ = self.session.release(f.a);
                let _ = self.session.release(f.b);
            }
            return Err(e);
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = ServiceStats {
            completed: responses.len(),
            wall_secs: wall,
            throughput_rps: responses.len() as f64 / wall.max(1e-12),
            latency: if latencies.is_empty() {
                None
            } else {
                Some(Summary::from(&latencies))
            },
        };
        Ok((responses, stats))
    }

    /// The drain loop proper; on error the caller cleans up `inflight`.
    fn drain_inner(
        session: &SpammSession,
        queue: &mut VecDeque<(Request, Instant)>,
        inflight: &mut VecDeque<Inflight>,
        responses: &mut Vec<Response>,
        latencies: &mut Vec<f64>,
    ) -> Result<()> {
        let depth = session.config().queue_depth.max(1);
        while let Some((req, submitted)) = queue.pop_front() {
            if inflight.len() == depth {
                let f = inflight.pop_front().expect("inflight window non-empty");
                Self::finish_one(f, session, responses, latencies)?;
            }
            let a = session.put(&req.a)?;
            let b = session.put(&req.b)?;
            let plan = match session.prepare(a, b, req.approx) {
                Ok(p) => p,
                Err(e) => {
                    let _ = session.release(a);
                    let _ = session.release(b);
                    return Err(e);
                }
            };
            let ticket = match session.submit(plan) {
                Ok(t) => t,
                Err(e) => {
                    let _ = session.release_plan(plan);
                    let _ = session.release(a);
                    let _ = session.release(b);
                    return Err(e);
                }
            };
            inflight.push_back(Inflight {
                id: req.id,
                a,
                b,
                plan,
                ticket,
                submitted,
            });
        }
        while let Some(f) = inflight.pop_front() {
            Self::finish_one(f, session, responses, latencies)?;
        }
        Ok(())
    }

    /// Wait one windowed job, record its response, release its handles
    /// (also on a failed wait — this job left the caller's cleanup set).
    fn finish_one(
        f: Inflight,
        session: &SpammSession,
        responses: &mut Vec<Response>,
        latencies: &mut Vec<f64>,
    ) -> Result<()> {
        let done = match session.wait(f.ticket) {
            Ok(d) => d,
            Err(e) => {
                let _ = session.release_plan(f.plan);
                let _ = session.release(f.a);
                let _ = session.release(f.b);
                return Err(e);
            }
        };
        let latency = f.submitted.elapsed().as_secs_f64();
        latencies.push(latency);
        responses.push(Response {
            id: f.id,
            c: done.c,
            tau: done.tau,
            valid_ratio: done.valid_ratio,
            latency_secs: latency,
            compute_secs: done.compute_secs,
        });
        // Plan handles are refcounted: deduplicated requests each hold a
        // reference to the shared plan, and this release drops exactly
        // this request's reference.
        session.release_plan(f.plan)?;
        session.release(f.a)?;
        session.release(f.b)?;
        Ok(())
    }
}

/// One windowed request in flight between submit and wait.
struct Inflight {
    id: u64,
    a: OperandId,
    b: OperandId,
    plan: crate::coordinator::session::PlanId,
    ticket: Ticket,
    submitted: Instant,
}

/// Synthetic request-trace generator for the legacy `drain` path and the
/// service tests: mixed decay kinds and approximation targets.  The
/// session-aware generator (shared hot operands, priorities) is
/// [`synthetic_session_trace`](crate::coordinator::session::synthetic_session_trace).
pub fn synthetic_trace(count: usize, n: usize, seed: u64) -> Vec<(Matrix, Matrix, Approx)> {
    use crate::util::prng::Rng;
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|i| {
            let s = seed.wrapping_add(i as u64 * 17);
            let (a, b) = if rng.next_f32() < 0.5 {
                (
                    Matrix::decay_algebraic(n, 0.1, 0.1, s),
                    Matrix::decay_algebraic(n, 0.1, 0.1, s ^ 1),
                )
            } else {
                (
                    Matrix::decay_exponential(n, 1.0, 0.9, s),
                    Matrix::decay_exponential(n, 1.0, 0.9, s ^ 1),
                )
            };
            let approx = if rng.next_f32() < 0.5 {
                Approx::ValidRatio(rng.range_f32(0.05, 0.3) as f64)
            } else {
                Approx::Tau(rng.range_f32(1e-6, 1e-2))
            };
            (a, b, approx)
        })
        .collect()
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;

    fn bundle() -> Option<ArtifactBundle> {
        // Real AOT bundle when present, offline hostsim bundle otherwise.
        crate::runtime::hostsim::find_or_test_bundle().ok()
    }

    #[test]
    fn drains_fifo_with_stats() {
        let Some(b) = bundle() else { return };
        let mut svc = SpammService::new(&b, SpammConfig::default()).unwrap();
        let trace = synthetic_trace(4, 96, 1);
        let mut ids = Vec::new();
        for (a, x, ap) in trace {
            ids.push(svc.submit(a, x, ap));
        }
        assert_eq!(svc.pending(), 4);
        let (resp, stats) = svc.drain().unwrap();
        assert_eq!(svc.pending(), 0);
        assert_eq!(stats.completed, 4);
        assert!(stats.throughput_rps > 0.0);
        assert!(stats.latency.is_some());
        // FIFO order and monotone ids.
        let got: Vec<u64> = resp.iter().map(|r| r.id).collect();
        assert_eq!(got, ids);
        for r in &resp {
            assert!(r.valid_ratio <= 1.0);
            assert_eq!(r.c.rows(), 96);
        }
        // Later requests queue at least as long as the first.
        assert!(resp.last().unwrap().latency_secs >= resp[0].latency_secs);
    }

    #[test]
    fn valid_ratio_requests_are_tuned() {
        let Some(b) = bundle() else { return };
        let mut svc = SpammService::new(&b, SpammConfig::default()).unwrap();
        let a = Matrix::decay_algebraic(128, 0.1, 0.1, 3);
        let x = Matrix::decay_algebraic(128, 0.1, 0.1, 4);
        svc.submit(a, x, Approx::ValidRatio(0.15));
        let (resp, _) = svc.drain().unwrap();
        assert!((resp[0].valid_ratio - 0.15).abs() < 0.05);
        assert!(resp[0].tau > 0.0);
    }

    #[test]
    fn empty_drain_has_no_latency_sample() {
        let Some(b) = bundle() else { return };
        let mut svc = SpammService::new(&b, SpammConfig::default()).unwrap();
        let (resp, stats) = svc.drain().unwrap();
        assert!(resp.is_empty());
        assert_eq!(stats.completed, 0);
        // Regression: the old code fabricated Summary::from(&[0.0]) here.
        assert!(stats.latency.is_none());
    }

    #[test]
    fn trace_generator_is_deterministic() {
        let t1 = synthetic_trace(3, 64, 9);
        let t2 = synthetic_trace(3, 64, 9);
        for ((a1, _, _), (a2, _, _)) in t1.iter().zip(&t2) {
            assert_eq!(a1, a2);
        }
    }

    #[test]
    fn approx_validation() {
        assert!(Approx::Tau(0.0).validate().is_ok());
        assert!(Approx::Tau(1e-3).validate().is_ok());
        assert!(Approx::Tau(-1.0).validate().is_err());
        assert!(Approx::Tau(f32::NAN).validate().is_err());
        assert!(Approx::ValidRatio(0.1).validate().is_ok());
        assert!(Approx::ValidRatio(1.0).validate().is_ok());
        assert!(Approx::ValidRatio(0.0).validate().is_err());
        assert!(Approx::ValidRatio(-0.2).validate().is_err());
        assert!(Approx::ValidRatio(1.5).validate().is_err());
    }
}
